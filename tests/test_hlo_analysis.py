"""The roofline's HLO analyzer must get trip-count multipliers and dot
FLOPs right — it is the measurement instrument for §Roofline/§Perf."""
import os
import subprocess
import sys
import textwrap

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.hlo_analysis import analyze, parse  # noqa: E402

# A lax.scan program compiled for 8 virtual devices must run in a fresh
# process (device count locks at first jax init).
_PROG = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    def f(x, w):
        def body(c, wi):
            return jnp.tanh(c @ wi), None
        y, _ = jax.lax.scan(body, x, w)
        return y
    xs = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    ws = jax.ShapeDtypeStruct((5, 256, 256), jnp.float32)
    with mesh:
        c = jax.jit(f, in_shardings=(
            NamedSharding(mesh, P("data", "model")),
            NamedSharding(mesh, P(None, None, "model")),
        )).lower(xs, ws).compile()
    print(c.as_text())
""")


@pytest.fixture(scope="module")
def scan_hlo(tmp_path_factory):
    out = subprocess.run(
        [sys.executable, "-c", _PROG], capture_output=True, text=True,
        timeout=300,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    return out.stdout


def test_trip_count_multiplies_dot_flops(scan_hlo):
    r = analyze(scan_hlo)
    # per device: x block [64, 256], w gathered to [256, 64-col shard] —
    # one dot of 2*64*256*64 per iteration, 5 iterations
    assert r["dot_flops"] == 5 * 2 * 64 * 256 * 64


def test_collectives_detected(scan_hlo):
    r = analyze(scan_hlo)
    assert r["collective_bytes"] > 0
    assert any(r.get(k, 0) > 0 for k in ("all-gather", "all-reduce"))


def test_parse_finds_entry_and_symbols(scan_hlo):
    comps, entry = parse(scan_hlo)
    assert entry in comps
    assert comps[entry].symbols  # parameters + instruction types resolved


def test_convert_fusions_tracked():
    hlo = textwrap.dedent("""
        HloModule m
        %fused_convert (p: bf16[128,128]) -> f32[128,128] {
          ROOT %r = f32[128,128] convert(%p)
        }
        ENTRY %main (param.0: bf16[128,128]) -> f32[128,128] {
          %param.0 = bf16[128,128] parameter(0)
          ROOT %wrapped_convert = f32[128,128]{1,0} fusion(%param.0), kind=kLoop, calls=%fused_convert
        }
    """)
    r = analyze(hlo)
    assert r["convert_bytes"] == 128 * 128 * (2 + 4)
    assert r["hbm_bytes"] == 128 * 128 * (2 + 4)
