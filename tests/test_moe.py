"""MoE routing/dispatch invariants (paper Eqs. 4-5 + the unified-kernel
dispatch): sort-based grouped dispatch, GShard capacity dispatch, and
router properties — property-based where it pays."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hyp_compat import given, settings, st

from repro.core.moe.dispatch import (
    capacity,
    ep_exchange_plan,
    expert_of_sorted_rows,
    grouped_combine,
    grouped_dispatch,
    gshard_dispatch_combine,
)
from repro.core.moe.router import route_topk


def _dense_moe_reference(x, experts, weights, w_per_expert):
    """Direct Eq. 5 evaluation: sum_k w_k * E_{e_k}(x)."""
    T, k = experts.shape
    out = np.zeros((T, w_per_expert.shape[-1]), np.float32)
    for t in range(T):
        for j in range(k):
            e = int(experts[t, j])
            out[t] += float(weights[t, j]) * (
                np.asarray(x[t]) @ np.asarray(w_per_expert[e])
            )
    return out


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 6), st.integers(1, 2), st.integers(5, 40))
def test_grouped_dispatch_combine_equals_dense(E, k, T):
    rng = np.random.default_rng(E * 1000 + k * 100 + T)
    D, F = 8, 6
    x = jnp.asarray(rng.standard_normal((T, D)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((E, D, F)), jnp.float32)
    experts = jnp.asarray(rng.integers(0, E, (T, k)), jnp.int32)
    weights = jnp.asarray(rng.random((T, k)), jnp.float32)
    d = grouped_dispatch(x, experts, weights, E)
    # invariants
    assert int(jnp.sum(d.group_sizes)) == T * k
    seg = np.repeat(np.arange(E), np.asarray(d.group_sizes))
    # rows arrive sorted by expert id
    from repro.kernels.ref import grouped_matmul_ref

    y_sorted = grouped_matmul_ref(d.x_sorted, w, d.group_sizes)
    y = grouped_combine(y_sorted, d, T)
    ref = _dense_moe_reference(experts=np.asarray(experts),
                               weights=np.asarray(weights),
                               x=np.asarray(x), w_per_expert=np.asarray(w))
    np.testing.assert_allclose(np.asarray(y), ref, atol=1e-4)


def test_gshard_matches_grouped_when_capacity_ample(rng):
    """With capacity >= T, no token drops: GShard == grouped == dense."""
    T, D, F, E, k = 32, 8, 8, 4, 2
    x = jnp.asarray(rng.standard_normal((T, D)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((E, D, F)), jnp.float32)
    experts = jnp.asarray(rng.integers(0, E, (T, k)), jnp.int32)
    weights = jnp.asarray(rng.random((T, k)), jnp.float32)
    disp, comb = gshard_dispatch_combine(x, experts, weights, E, cap=T)
    ein = jnp.einsum("tec,td->ecd", disp, x)
    eout = jnp.einsum("ecd,edf->ecf", ein, w)
    y = jnp.einsum("tec,ecf->tf", comb, eout)
    ref = _dense_moe_reference(np.asarray(x), np.asarray(experts),
                               np.asarray(weights), np.asarray(w))
    np.testing.assert_allclose(np.asarray(y), ref, atol=1e-4)


def test_gshard_capacity_drops_excess(rng):
    """Tokens beyond an expert's capacity are dropped, never duplicated."""
    T, E, k = 16, 2, 1
    x = jnp.ones((T, 4), jnp.float32)
    experts = jnp.zeros((T, k), jnp.int32)  # всё to expert 0
    weights = jnp.ones((T, k), jnp.float32)
    cap = 4
    disp, comb = gshard_dispatch_combine(x, experts, weights, E, cap)
    assert float(jnp.sum(disp)) == cap  # exactly cap tokens admitted
    # each (expert, slot) holds at most one token
    assert float(jnp.max(jnp.sum(disp, axis=0))) <= 1.0 + 1e-6


def test_router_topk_selects_largest(rng):
    T, D, E, k = 10, 8, 6, 2
    x = jnp.asarray(rng.standard_normal((T, D)), jnp.float32)
    wg = jnp.asarray(rng.standard_normal((D, E)), jnp.float32)
    r = route_topk(x, wg, None, k)
    logits = np.asarray(x @ wg)
    for t in range(T):
        top = set(np.argsort(logits[t])[-k:])
        assert set(np.asarray(r.experts[t])) == top
    # combine weights: softmax over the selected logits, sum to 1
    np.testing.assert_allclose(np.asarray(jnp.sum(r.weights, -1)),
                               np.ones(T), rtol=1e-5)
    assert float(r.aux_loss) >= 1.0 - 1e-4  # E * sum f*p >= 1 at optimum


def test_grouped_and_gshard_impl_agree_end_to_end(rng):
    """The same MoE layer under both impls (ample capacity) agrees."""
    import repro.models as M
    from repro.configs import get_shape, smoke_config

    shape = get_shape("train_4k").replace(seq_len=16, global_batch=2)
    cfg_g = smoke_config("olmoe-1b-7b").replace(remat=False)
    import dataclasses

    cfg_grouped = cfg_g.replace(
        moe=dataclasses.replace(cfg_g.moe, impl="grouped"))
    cfg_gshard = cfg_g.replace(
        moe=dataclasses.replace(cfg_g.moe, impl="gshard",
                                capacity_factor=64.0))
    params = M.init_model_params(cfg_grouped, jax.random.PRNGKey(0))
    batch = M.synth_batch(cfg_grouped, shape, jax.random.PRNGKey(1))
    y1, _ = M.forward(params, cfg_grouped, batch)
    y2, _ = M.forward(params, cfg_gshard, batch)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-3)


def test_gshard_grouped_parity_documents_capacity_divergence(rng):
    """Parity on IDENTICAL routing, and the one place the paths diverge.

    ``grouped`` (sort-based unified kernel) is dropless: every routed
    (token, slot) pair is computed. ``gshard`` admits at most ``cap``
    tokens per expert in routing-priority (= token) order and **drops the
    overflow** — dropped slots contribute exactly zero to the combine.
    That divergence is inherent to capacity dispatch (why serving forces
    ``impl="grouped"``, see ``serving.engine.serving_config``); this test
    pins down its exact shape: admitted rows match grouped bit-for-bit in
    structure, overflow rows are zero.
    """
    from repro.kernels.ref import grouped_matmul_ref

    T, D, F, E, k = 16, 8, 6, 2, 1
    x = jnp.asarray(rng.standard_normal((T, D)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((E, D, F)), jnp.float32)
    # skewed routing: every token to expert 0 — overflow is guaranteed
    experts = jnp.zeros((T, k), jnp.int32)
    weights = jnp.asarray(rng.random((T, k)), jnp.float32)

    d = grouped_dispatch(x, experts, weights, E)
    y_grouped = grouped_combine(
        grouped_matmul_ref(d.x_sorted, w, d.group_sizes), d, T)

    cap = 5  # < T: tokens 5..15 overflow expert 0 and are dropped
    disp, comb = gshard_dispatch_combine(x, experts, weights, E, cap)
    ein = jnp.einsum("tec,td->ecd", disp, x)
    eout = jnp.einsum("ecd,edf->ecf", ein, w)
    y_gshard = jnp.einsum("tec,ecf->tf", comb, eout)

    # admitted prefix (priority order == token order for k=1): parity
    np.testing.assert_allclose(np.asarray(y_gshard[:cap]),
                               np.asarray(y_grouped[:cap]), atol=1e-4)
    # overflow: grouped still computes them, gshard drops them to zero
    np.testing.assert_allclose(np.asarray(y_gshard[cap:]),
                               np.zeros((T - cap, F)), atol=1e-6)
    assert float(jnp.min(jnp.abs(y_grouped[cap:]).sum(-1))) > 0.0


def test_capacity_function_bounds():
    assert capacity(100, 2, 8, 1.25) >= 100 * 2 * 1.25 / 8
    assert capacity(100, 2, 8, 1.25) <= 100
    assert capacity(2, 1, 64, 1.0) >= 4  # floor


def test_ep_exchange_plan_is_a_partition(rng):
    """The expert-parallel send plan assigns every sorted row exactly one
    (dest shard, position) slot, positions are dense per shard, and local
    expert ids are consistent with the global sort."""
    E, n_shards, T, k = 8, 4, 13, 2
    e_local = E // n_shards
    experts = jnp.asarray(rng.integers(0, E, (T, k)), jnp.int32)
    x = jnp.asarray(rng.standard_normal((T, 4)), jnp.float32)
    w = jnp.ones((T, k), jnp.float32)
    d = grouped_dispatch(x, experts, w, E)
    R = T * k
    plan = ep_exchange_plan(d.group_sizes, n_shards, R)
    assert int(plan.shard_counts.sum()) == R
    # (shard, pos) pairs are unique and dense: pos < count of that shard
    pairs = set()
    for s, p0 in zip(np.asarray(plan.row_shard), np.asarray(plan.row_pos)):
        assert 0 <= p0 < int(plan.shard_counts[s])
        pairs.add((int(s), int(p0)))
    assert len(pairs) == R
    glob = expert_of_sorted_rows(d.group_sizes, R)
    np.testing.assert_array_equal(
        np.asarray(plan.row_shard) * e_local + np.asarray(plan.row_local_expert),
        np.asarray(glob))
