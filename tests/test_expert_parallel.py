"""Expert-parallel grouped MoE (distributed/expert_parallel.py).

Equivalence contract: the shard_map EP path — expert stacks sharded over
the 'model' axis, tokens exchanged with all_to_all — must reproduce the
single-device grouped output (the exchange is dropless by construction),
for both fp32 and materialized-int8 QuantizedParams trees.

These tests need a multi-device backend; on a single CPU device they skip
(CI's multi-device step fakes 8 devices via
``XLA_FLAGS=--xla_force_host_platform_device_count=8``).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import requires_devices

import repro.models as M
from repro.configs import get_shape, smoke_config
from repro.core.quant.ptq import calibrate_model, ptq_model, quantized_config
from repro.distributed.expert_parallel import (
    expert_parallel_moe,
    use_ep_mesh,
    validate_ep,
)
from repro.launch.mesh import make_ep_mesh


def _ep(cfg):
    return cfg.replace(
        moe=dataclasses.replace(cfg.moe, moe_exec="expert_parallel"))


@pytest.fixture(scope="module")
def trees():
    cfg = smoke_config("m3vit-small").replace(remat=False)
    shape = get_shape("train_4k").replace(seq_len=24, global_batch=2)
    params = M.init_model_params(cfg, jax.random.PRNGKey(0))
    batches = [M.synth_batch(cfg, shape, jax.random.PRNGKey(i))
               for i in range(2)]
    taps = calibrate_model(cfg, params, batches)
    p_int8 = ptq_model(cfg, params, taps, materialize="int8")
    p_int4 = ptq_model(cfg, params, taps, materialize="int4")
    batch = M.synth_batch(cfg, shape, jax.random.PRNGKey(7))
    return cfg, params, p_int8, p_int4, batch


@requires_devices(8)
def test_ep_fp32_matches_single_device(trees):
    cfg, params, _, _, batch = trees
    y_ref, aux_ref = M.forward(params, cfg, batch)
    with use_ep_mesh(make_ep_mesh(8)):
        y_ep, aux_ep = M.forward(params, _ep(cfg), batch)
    np.testing.assert_allclose(np.asarray(y_ep), np.asarray(y_ref),
                               atol=1e-4)
    np.testing.assert_allclose(float(aux_ep), float(aux_ref), rtol=1e-5)


@requires_devices(8)
def test_ep_int8_matches_single_device_int8(trees):
    """Acceptance: expert-parallel int8 MoE-ViT forward on an 8-device mesh
    matches the single-device materialized-int8 output."""
    cfg, _, p_int8, _, batch = trees
    qcfg = quantized_config(cfg)
    y_ref, _ = M.forward(p_int8, qcfg, batch)
    with use_ep_mesh(make_ep_mesh(8)):
        y_ep, _ = M.forward(p_int8, _ep(qcfg), batch)
    # int8 contractions are exact; only the Eq. 5 combine order can differ
    np.testing.assert_allclose(np.asarray(y_ep), np.asarray(y_ref),
                               atol=1e-3)


@requires_devices(8)
def test_ep_classify_top1_matches(trees):
    cfg, _, p_int8, _, _ = trees
    qcfg = quantized_config(cfg)
    rng = np.random.default_rng(5)
    x = jnp.asarray(
        rng.standard_normal((3, cfg.image_tokens - 1, 768)), jnp.float32)
    ref = M.classify(p_int8, qcfg, x, top_k=3)
    with use_ep_mesh(make_ep_mesh(8)):
        out = M.classify(p_int8, _ep(qcfg), x, top_k=3)
    np.testing.assert_array_equal(np.asarray(out["classes"]),
                                  np.asarray(ref["classes"]))
    np.testing.assert_array_equal(np.asarray(out["expert_tokens"]),
                                  np.asarray(ref["expert_tokens"]))


@requires_devices(8)
def test_ep_jaxpr_shards_expert_stacks_and_exchanges_tokens(trees):
    """Acceptance: the jaxpr shows sharded expert weights — the shard_map
    body computes on E/n-expert local slices (never the full stack) — and
    an all_to_all token exchange."""
    cfg, _, p_int8, _, _ = trees
    qcfg = _ep(quantized_config(cfg))
    x = jnp.zeros((2, cfg.image_tokens - 1, 768), jnp.float32)
    with use_ep_mesh(make_ep_mesh(8)):
        jaxpr = str(jax.make_jaxpr(
            lambda p, b: M.classify(p, qcfg, b, top_k=5))(p_int8, x))
    E, D = qcfg.moe.num_experts, qcfg.d_model
    hid = qcfg.moe.d_ff * (2 if qcfg.glu else 1)
    e_local = E // 8
    assert "all_to_all" in jaxpr, "no token exchange in the EP program"
    assert f"i8[{e_local},{D},{hid}]" in jaxpr, \
        "per-shard compute does not consume a local expert slice"
    assert f"i8[{e_local},{qcfg.moe.d_ff},{D}]" in jaxpr


@requires_devices(8)
def test_ep_int4_bit_identical_to_single_device(trees):
    """Acceptance: expert-parallel forward over the mixed int4/int8 tree on
    8 fake devices is BIT-IDENTICAL to single-device. Unlike fp32, every
    contraction on this path is exact int32 arithmetic and each token's
    expert partials are combined in router order on both paths, so sharding
    must not change a single ulp."""
    cfg, _, _, p_int4, batch = trees
    qcfg = quantized_config(cfg)
    y_ref, _ = M.forward(p_int4, qcfg, batch)
    with use_ep_mesh(make_ep_mesh(8)):
        y_ep, _ = M.forward(p_int4, _ep(qcfg), batch)
    np.testing.assert_array_equal(np.asarray(y_ep), np.asarray(y_ref))


@requires_devices(8)
def test_ep_jaxpr_shards_packed_int4_stacks(trees):
    """The shard_map body consumes uint8 nibble-packed LOCAL expert slices
    (E/n experts, ceil(Din/2) rows) — sharding does not unpack — and the
    token exchange still moves int8 rows (auto-enabled for packed trees)."""
    from repro.core.quant.qtypes import packed_rows

    cfg, _, _, p_int4, _ = trees
    qcfg = _ep(quantized_config(cfg))
    x = jnp.zeros((2, cfg.image_tokens - 1, 768), jnp.float32)
    with use_ep_mesh(make_ep_mesh(8)):
        jaxpr = str(jax.make_jaxpr(
            lambda p, b: M.classify(p, qcfg, b, top_k=5))(p_int4, x))
    E, D = qcfg.moe.num_experts, qcfg.d_model
    hid = qcfg.moe.d_ff * (2 if qcfg.glu else 1)
    e_local = E // 8
    assert f"u8[{e_local},{packed_rows(D)},{hid}]" in jaxpr, \
        "per-shard compute does not consume a packed local expert slice"
    assert f"u8[{e_local},{packed_rows(qcfg.moe.d_ff)},{D}]" in jaxpr
    a2a = [ln for ln in jaxpr.splitlines() if "all_to_all" in ln]
    assert any(":i8[" in ln for ln in a2a), \
        f"token exchange of the packed tree still moves fp rows: {a2a}"


@requires_devices(2)
def test_ep_works_at_two_shards(trees):
    """E=8 over 2 shards (4 local experts): same equivalence."""
    cfg, params, _, _, batch = trees
    y_ref, _ = M.forward(params, cfg, batch)
    with use_ep_mesh(make_ep_mesh(2)):
        y_ep, _ = M.forward(params, _ep(cfg), batch)
    np.testing.assert_allclose(np.asarray(y_ep), np.asarray(y_ref),
                               atol=1e-4)


@requires_devices(2)
def test_ep_layer_level_counts_and_aux(trees):
    """Layer-level call: routed-token counts match the replicated router's
    histogram and every (token, slot) pair is preserved (dropless)."""
    cfg, params, _, _, _ = trees
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((2, 9, cfg.d_model)), jnp.float32)
    lp = jax.tree.map(lambda a: a[0], params["pairs_moe"])["moe"]
    with use_ep_mesh(make_ep_mesh(2)):
        y, aux, counts = expert_parallel_moe(x, lp, _ep(cfg))
    assert y.shape == x.shape
    assert int(jnp.sum(counts)) == 2 * 9 * cfg.moe.top_k
    assert np.isfinite(float(aux))


@requires_devices(2)
def test_ep_int8_exchange_matches_fp32_exchange(trees):
    """Quantizing the token all_to_all payload (int8 rows, folded fc1
    activation scale) is elementwise-before vs elementwise-after the
    exchange — the output must be *bit-identical* to moving fp32 rows and
    letting the grouped kernel quantize them post-exchange."""
    cfg, _, p_int8, _, _ = trees
    qcfg = _ep(quantized_config(cfg))
    rng = np.random.default_rng(11)
    x = jnp.asarray(rng.standard_normal((2, 9, cfg.d_model)), jnp.float32)
    lp = jax.tree.map(lambda a: a[0], p_int8["pairs_moe"])["moe"]
    with use_ep_mesh(make_ep_mesh(2)):
        y_fp, _, _ = expert_parallel_moe(x, lp, qcfg,
                                         quantize_exchange=False)
        y_q, _, _ = expert_parallel_moe(x, lp, qcfg,
                                        quantize_exchange=True)
    np.testing.assert_array_equal(np.asarray(y_q), np.asarray(y_fp))


@requires_devices(2)
def test_ep_int8_tree_exchanges_int8_payload(trees):
    """The forward token exchange of a materialized-int8 tree moves int8
    rows (auto-enabled quantize_exchange): the jaxpr carries an int8
    all_to_all alongside the f32 return exchange."""
    cfg, _, p_int8, _, _ = trees
    qcfg = _ep(quantized_config(cfg))
    x = jnp.zeros((2, 9, cfg.d_model), jnp.float32)
    lp = jax.tree.map(lambda a: a[0], p_int8["pairs_moe"])["moe"]
    with use_ep_mesh(make_ep_mesh(2)):
        jaxpr = str(jax.make_jaxpr(
            lambda xx, pp: expert_parallel_moe(xx, pp, qcfg))(x, lp))
    a2a = [ln for ln in jaxpr.splitlines() if "all_to_all" in ln]
    assert any(":i8[" in ln for ln in a2a), \
        f"token exchange still moves fp rows: {a2a}"


def test_quantize_ep_payload_matches_kernel_quantizer(rng):
    """The payload quantizer is the same grid kernels.ops applies to fp
    rows entering an int8 grouped matmul (quantize_sym on the folded
    scale)."""
    from repro.core.moe.dispatch import quantize_ep_payload
    from repro.core.quant.qtypes import quantize_sym

    x = jnp.asarray(rng.standard_normal((12, 16)), jnp.float32)
    s = jnp.float32(0.11)
    np.testing.assert_array_equal(
        np.asarray(quantize_ep_payload(x, s, 8)),
        np.asarray(quantize_sym(x, s, 8)))


def test_validate_ep_rejects_bad_configs():
    cfg = smoke_config("m3vit-small")  # 8 experts
    mesh = make_ep_mesh(1)
    validate_ep(cfg, mesh)  # 1 shard always divides
    bad = cfg.replace(moe=dataclasses.replace(cfg.moe, num_experts=6))
    if jax.device_count() >= 4:
        with pytest.raises(ValueError, match="not divisible"):
            validate_ep(bad, make_ep_mesh(4))
    gshard = cfg.replace(moe=dataclasses.replace(cfg.moe, impl="gshard"))
    with pytest.raises(ValueError, match="grouped"):
        validate_ep(gshard, mesh)
    dense = smoke_config("vit-tiny")
    with pytest.raises(ValueError, match="no MoE"):
        validate_ep(dense, mesh)


def test_ep_without_mesh_raises(trees):
    cfg, params, _, _, batch = trees
    with pytest.raises(RuntimeError, match="no EP mesh"):
        M.forward(params, _ep(cfg), batch)
