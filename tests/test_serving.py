"""Serving engine: greedy generation equals step-by-step reference;
continuous batching with ragged slot positions; quantized path sanity."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.models as M
from repro.configs import smoke_config
from repro.serving.engine import Request, ServeEngine


def _greedy_reference(cfg, params, prompt, n_new):
    """Teacher-forced re-run per token: the slowest correct generation."""
    mod = M.module_for(cfg)
    toks = list(map(int, prompt))
    out = []
    for _ in range(n_new):
        logits, _ = mod.forward(
            params, cfg, jnp.asarray([toks], jnp.int32))
        nxt = int(jnp.argmax(logits[0, -1]))
        out.append(nxt)
        toks.append(nxt)
    return out


@pytest.mark.parametrize("arch", ["llama3-8b", "falcon-mamba-7b"])
def test_engine_matches_teacher_forced_reference(arch):
    cfg = smoke_config(arch).replace(remat=False)
    params = M.init_model_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab_size, 7).astype(np.int32)
    n_new = 5
    ref = _greedy_reference(cfg, params, prompt, n_new)
    eng = ServeEngine(cfg, params, batch_slots=2, max_len=32)
    eng.submit(Request(uid=0, prompt=prompt, max_new_tokens=n_new))
    eng.run_until_drained()
    got = eng.queue or None
    # request finished; compare generated stream
    assert ref == _last_generated(eng, 0)[:n_new]


def _last_generated(engine, uid):
    # finished requests are removed from active; track via closure of test
    # (the engine mutates the submitted Request object in place)
    for req in engine._all_requests:
        if req.uid == uid:
            return req.generated
    raise KeyError(uid)


@pytest.fixture(autouse=True)
def _track_requests(monkeypatch):
    """Record every submitted request so tests can inspect results."""
    orig = ServeEngine.submit

    def wrapped(self, req):
        if not hasattr(self, "_all_requests"):
            self._all_requests = []
        self._all_requests.append(req)
        return orig(self, req)

    monkeypatch.setattr(ServeEngine, "submit", wrapped)


def test_continuous_batching_ragged_slots():
    """Requests of different lengths served concurrently must each match
    their solo runs (per-slot positions actually work)."""
    cfg = smoke_config("llama3-8b").replace(remat=False)
    params = M.init_model_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (4, 9, 6)]
    solo = [_greedy_reference(cfg, params, p, 4) for p in prompts]
    eng = ServeEngine(cfg, params, batch_slots=2, max_len=32)
    for i, p in enumerate(prompts):
        eng.submit(Request(uid=i, prompt=p, max_new_tokens=4))
    eng.run_until_drained()
    for i in range(3):
        assert _last_generated(eng, i)[:4] == solo[i], f"request {i}"


def test_batched_prefill_single_dispatch_and_parity():
    """Prompts admitted together prefill as ONE packed dispatch (not n
    sequential single-prompt runs) and still reproduce the solo-run
    generations exactly. The packed path must be in use (the grouped
    per-length ``prefill`` entry is never called) and the padding-waste
    counters must account for every buffer slot."""
    cfg = smoke_config("llama3-8b").replace(remat=False)
    params = M.init_model_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(11)
    prompts = [rng.integers(0, cfg.vocab_size, 6).astype(np.int32)
               for _ in range(4)]
    solo = [_greedy_reference(cfg, params, p, 3) for p in prompts]
    eng = ServeEngine(cfg, params, batch_slots=4, max_len=32)
    calls = []

    class SpyMod:
        def __init__(self, mod):
            self._mod = mod

        def __getattr__(self, name):
            return getattr(self._mod, name)

        def prefill(self, params_, cfg_, toks, **kw):
            calls.append(tuple(toks.shape))
            return self._mod.prefill(params_, cfg_, toks, **kw)

    eng.mod = SpyMod(eng.mod)
    assert eng._packed, "transformer family must take the packed path"
    for i, p in enumerate(prompts):
        eng.submit(Request(uid=i, prompt=p, max_new_tokens=3))
    eng.run_until_drained()
    assert calls == [], "grouped prefill must not run in packed mode"
    assert eng.metrics.counters["prefill_batches"] == 1
    c = eng.metrics.counters
    assert c["pack_real_tokens"] == 24  # 4 prompts x 6 tokens, one dispatch
    total = c["pack_real_tokens"] + c["pack_pad_tokens"]
    assert total in eng._buckets, (total, eng._buckets)
    for i in range(4):
        assert _last_generated(eng, i)[:3] == solo[i], f"request {i}"


def test_quantized_engine_generates_finite():
    cfg = smoke_config("llama3-8b").replace(remat=False)
    cfg = cfg.replace(quant=dataclasses.replace(cfg.quant, enable=True))
    params = M.init_model_params(cfg, jax.random.PRNGKey(0))
    # int8 KV cache is allocated when quant.enable
    eng = ServeEngine(cfg, params, batch_slots=2, max_len=32)
    assert eng.cache["k"].dtype == jnp.int8
    rng = np.random.default_rng(0)
    eng.submit(Request(uid=0,
                       prompt=rng.integers(0, cfg.vocab_size, 5).astype(np.int32),
                       max_new_tokens=4))
    eng.run_until_drained()
    toks = _last_generated(eng, 0)
    assert len(toks) == 4
    assert all(0 <= t < cfg.vocab_size for t in toks)
