"""Per-kernel validation: Pallas (interpret=True) vs the pure-jnp oracles in
kernels/ref.py, swept over shapes, dtypes, and feature flags."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.expert_linear import grouped_matmul
from repro.kernels.int8_matmul import int8_matmul
from repro.kernels.quant_attention import streaming_attention


def _t(rng, *shape, dtype=jnp.float32):
    return jnp.asarray(rng.standard_normal(shape), dtype)


# ---------------------------------------------------------------------------
# Streaming quantized attention
# ---------------------------------------------------------------------------

ATTN_CASES = [
    # (B, Sq, Sk, H, KVH, hd, causal, quant_bits, softcap, window)
    (2, 64, 64, 4, 2, 64, True, 0, 0.0, 0),
    (2, 64, 64, 4, 2, 64, True, 4, 0.0, 0),
    (1, 128, 128, 4, 4, 64, False, 4, 0.0, 0),  # ViT-style bidirectional
    (1, 96, 96, 8, 1, 32, True, 3, 0.0, 0),  # MQA, 3-bit
    (2, 48, 96, 4, 1, 64, True, 0, 50.0, 32),  # softcap + local window
    (2, 48, 96, 4, 2, 64, True, 4, 30.0, 16),
    (1, 1, 128, 8, 2, 64, True, 4, 0.0, 0),  # decode
    (3, 17, 33, 2, 2, 16, True, 4, 0.0, 0),  # ragged (padding paths)
]


@pytest.mark.parametrize(
    "B,Sq,Sk,H,KVH,hd,causal,qb,cap,win", ATTN_CASES
)
def test_attention_matches_ref(rng, B, Sq, Sk, H, KVH, hd, causal, qb, cap, win):
    q, k, v = _t(rng, B, Sq, H, hd), _t(rng, B, Sk, KVH, hd), _t(rng, B, Sk, KVH, hd)
    off = Sk - Sq if causal else 0
    valid = jnp.full((B,), Sk, jnp.int32)
    kw = dict(causal=causal, q_offset=off, quant_bits=qb, logit_softcap=cap,
              local_window=win, kv_valid_len=valid)
    out_k = streaming_attention(q, k, v, block_q=32, block_k=32,
                                interpret=True, **kw)
    out_r = ref.flash_attention_ref(q, k, v, **kw)
    np.testing.assert_allclose(out_k, out_r, atol=2e-5, rtol=2e-5)


def test_attention_bf16_inputs(rng):
    B, Sq, Sk, H, KVH, hd = 2, 32, 32, 4, 2, 64
    q = _t(rng, B, Sq, H, hd, dtype=jnp.bfloat16)
    k = _t(rng, B, Sk, KVH, hd, dtype=jnp.bfloat16)
    v = _t(rng, B, Sk, KVH, hd, dtype=jnp.bfloat16)
    out_k = streaming_attention(q, k, v, causal=True, quant_bits=4,
                                block_q=16, block_k=16, interpret=True)
    out_r = ref.flash_attention_ref(q, k, v, causal=True, quant_bits=4)
    assert out_k.dtype == jnp.bfloat16
    np.testing.assert_allclose(
        out_k.astype(np.float32), out_r.astype(np.float32), atol=2e-2
    )


def test_attention_int8_kv_cache(rng):
    from repro.models.layers import quantize_kv

    B, Sq, Sk, H, KVH, hd = 2, 1, 96, 4, 2, 64
    q, k, v = _t(rng, B, Sq, H, hd), _t(rng, B, Sk, KVH, hd), _t(rng, B, Sk, KVH, hd)
    kq, ks = quantize_kv(k)
    vq, vs = quantize_kv(v)
    valid = jnp.asarray([64, 96], jnp.int32)
    kw = dict(causal=True, q_offset=63, quant_bits=4, k_scale=ks, v_scale=vs,
              kv_valid_len=valid)
    out_k = streaming_attention(qq := q, k=kq, v=vq, block_q=8, block_k=32,
                                interpret=True, **kw)
    out_r = ref.flash_attention_ref(q, kq, vq, **kw)
    np.testing.assert_allclose(out_k, out_r, atol=2e-5)


def test_attention_per_slot_offsets(rng):
    """Continuous batching: vector q_offset (per-slot positions)."""
    B, Sk, H, KVH, hd = 3, 64, 4, 2, 32
    q, k, v = _t(rng, B, 1, H, hd), _t(rng, B, Sk, KVH, hd), _t(rng, B, Sk, KVH, hd)
    offs = jnp.asarray([5, 20, 63], jnp.int32)
    valid = offs + 1
    out_k = streaming_attention(q, k, v, causal=True, q_offset=offs,
                                quant_bits=4, kv_valid_len=valid,
                                block_q=8, block_k=16, interpret=True)
    out_r = ref.flash_attention_ref(q, k, v, causal=True, q_offset=offs,
                                    quant_bits=4, kv_valid_len=valid)
    np.testing.assert_allclose(out_k, out_r, atol=2e-5)
    # each slot must equal its own single-sequence computation
    for i, (o, vl) in enumerate(zip([5, 20, 63], [6, 21, 64])):
        solo = ref.flash_attention_ref(
            q[i:i+1], k[i:i+1], v[i:i+1], causal=True, q_offset=o,
            quant_bits=4, kv_valid_len=jnp.asarray([vl], jnp.int32))
        np.testing.assert_allclose(out_k[i:i+1], solo, atol=2e-5)


def test_attention_mixed_slot_offsets_and_fill_levels(rng):
    """Continuous-batching admission: multi-token q chunks where every slot
    sits at a *different* fill level — per-batch q_offset [B] mixed with
    per-batch kv_valid_len [B], over an int8 K/V cache with dequant scales
    (the state ServeEngine decodes from after ragged prefills)."""
    from repro.models.layers import quantize_kv

    B, Sq, Sk, H, KVH, hd = 3, 4, 64, 4, 2, 32
    q = _t(rng, B, Sq, H, hd)
    k, v = _t(rng, B, Sk, KVH, hd), _t(rng, B, Sk, KVH, hd)
    kq, ks = quantize_kv(k)
    vq, vs = quantize_kv(v)
    offs = jnp.asarray([0, 17, 60], jnp.int32)  # slot fill levels
    valid = offs + Sq  # cache valid through each slot's chunk
    kw = dict(causal=True, q_offset=offs, quant_bits=4,
              k_scale=ks, v_scale=vs, kv_valid_len=valid)
    out_k = streaming_attention(q, kq, vq, block_q=8, block_k=16,
                                interpret=True, **kw)
    out_r = ref.flash_attention_ref(q, kq, vq, **kw)
    np.testing.assert_allclose(out_k, out_r, atol=2e-5)
    # per-slot equivalence: each slot must match its solo run at its own
    # (offset, fill) pair — the batched kernel adds no cross-slot coupling
    for i in range(B):
        solo = ref.flash_attention_ref(
            q[i:i+1], kq[i:i+1], vq[i:i+1], causal=True,
            q_offset=int(offs[i]), quant_bits=4,
            k_scale=ks[i:i+1], v_scale=vs[i:i+1],
            kv_valid_len=valid[i:i+1])
        np.testing.assert_allclose(out_k[i:i+1], solo, atol=2e-5)


@pytest.mark.parametrize("qb", [0, 4])
def test_attention_segment_ids_packed_prefill(rng, qb):
    """Packed prefill (DESIGN.md section 10): several prompts concatenated
    into one batch row, attention confined to equal segment ids. Every
    segment of the packed output must equal its own solo causal run —
    contiguous segments make buffer-index causality equal within-segment
    causality, so no cross-prompt leakage and no position skew."""
    lens = [24, 40, 32]
    S, H, KVH, hd = sum(lens), 4, 2, 32
    q, k, v = _t(rng, 1, S, H, hd), _t(rng, 1, S, KVH, hd), _t(rng, 1, S, KVH, hd)
    seg = jnp.asarray(np.repeat(np.arange(len(lens)), lens)[None], jnp.int32)
    kw = dict(causal=True, quant_bits=qb,
              q_segment_ids=seg, kv_segment_ids=seg)
    out_k = streaming_attention(q, k, v, block_q=16, block_k=32,
                                interpret=True, **kw)
    out_r = ref.flash_attention_ref(q, k, v, **kw)
    np.testing.assert_allclose(out_k, out_r, atol=2e-5, rtol=2e-5)
    o = 0
    for L in lens:
        solo = ref.flash_attention_ref(
            q[:, o:o+L], k[:, o:o+L], v[:, o:o+L],
            causal=True, quant_bits=qb)
        np.testing.assert_allclose(out_k[:, o:o+L], solo, atol=2e-5,
                                   err_msg=f"segment at offset {o}")
        o += L


# ---------------------------------------------------------------------------
# Unified sparse/dense grouped matmul
# ---------------------------------------------------------------------------

GROUP_CASES = [
    (4, 64, 96, [40, 0, 17, 71]),
    (1, 128, 64, [200]),  # dense mode (the paper's mode switch)
    (8, 32, 32, [0, 0, 5, 0, 123, 1, 0, 16]),
    (3, 256, 512, [128, 128, 128]),
    (5, 64, 64, [0, 300, 0, 0, 1]),
    (2, 16, 16, [1, 1]),
]


@pytest.mark.parametrize("G,Din,Dout,sizes", GROUP_CASES)
def test_grouped_matmul_matches_ref(rng, G, Din, Dout, sizes):
    T = sum(sizes)
    x = _t(rng, T, Din)
    w = _t(rng, G, Din, Dout)
    gs = jnp.asarray(sizes, jnp.int32)
    y = grouped_matmul(x, w, gs, block_m=32, block_n=64, interpret=True)
    yr = ref.grouped_matmul_ref(x, w, gs)
    np.testing.assert_allclose(y, yr, atol=1e-4)


def test_grouped_matmul_int8_experts(rng):
    """Per-expert int8 weights + per-channel dequant scale (W8 experts)."""
    G, Din, Dout = 4, 64, 64
    sizes = [33, 12, 0, 55]
    T = sum(sizes)
    x = _t(rng, T, Din)
    wf = rng.standard_normal((G, Din, Dout)).astype(np.float32)
    wsc = np.abs(wf).max(axis=1) / 127.0
    wq = np.clip(np.round(wf / wsc[:, None, :]), -127, 127).astype(np.int8)
    y = grouped_matmul(x, jnp.asarray(wq), jnp.asarray(sizes, jnp.int32),
                       w_scale=jnp.asarray(wsc), block_m=32, interpret=True)
    yr = ref.grouped_matmul_ref(
        x, jnp.asarray(wq.astype(np.float32) * wsc[:, None, :]),
        jnp.asarray(sizes, jnp.int32))
    np.testing.assert_allclose(y, yr, atol=1e-3)


def test_grouped_matmul_matches_ragged_dot(rng):
    """The XLA fast path (lax.ragged_dot) and the Pallas kernel agree."""
    G, Din, Dout = 4, 32, 48
    sizes = [10, 30, 0, 24]
    x = _t(rng, sum(sizes), Din)
    w = _t(rng, G, Din, Dout)
    gs = jnp.asarray(sizes, jnp.int32)
    y_pl = grouped_matmul(x, w, gs, block_m=16, interpret=True)
    y_xla = jax.lax.ragged_dot(x, w, gs)
    np.testing.assert_allclose(y_pl, y_xla, atol=1e-4)


# ---------------------------------------------------------------------------
# INT8 tiled matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("M,K,N,bias", [
    (100, 200, 150, True),
    (32, 64, 32, False),
    (7, 500, 13, True),  # ragged tiles
])
def test_int8_matmul_matches_ref(rng, M, K, N, bias):
    xq = jnp.asarray(rng.integers(-127, 128, (M, K)), jnp.int8)
    wq = jnp.asarray(rng.integers(-127, 128, (K, N)), jnp.int8)
    xs = jnp.float32(0.013)
    ws = jnp.asarray(np.abs(rng.standard_normal(N)) * 0.01, jnp.float32)
    b = jnp.asarray(rng.standard_normal(N), jnp.float32) if bias else None
    y = int8_matmul(xq, wq, xs, ws, b, block_m=32, block_n=64, block_k=64,
                    interpret=True)
    yr = ref.int8_matmul_ref(xq, wq, xs, ws, b)
    np.testing.assert_allclose(y, yr, atol=1e-3)


# ---------------------------------------------------------------------------
# Selective scan (Mamba-1)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,di,N,bs,bd", [
    (2, 64, 32, 8, 16, 16),
    (1, 100, 64, 16, 32, 32),  # ragged S (padding = identity steps)
    (2, 17, 16, 4, 8, 16),
])
def test_selective_scan_matches_ref(rng, B, S, di, N, bs, bd):
    from repro.kernels.selective_scan import selective_scan

    x = _t(rng, B, S, di)
    dt = jnp.abs(_t(rng, B, S, di)) * 0.1
    b = _t(rng, B, S, N)
    c = _t(rng, B, S, N)
    a = -jnp.abs(_t(rng, di, N))
    d = _t(rng, di)
    y, h_last = selective_scan(x, dt, b, c, a, d, block_s=bs, block_d=bd,
                               interpret=True)
    yr = ref.selective_scan_ref(x, dt, b, c, a, d)
    np.testing.assert_allclose(y, yr, atol=1e-4)
    assert h_last.shape == (B, di, N)
    assert bool(jnp.isfinite(h_last).all())


def test_mamba1_kernel_path_equals_chunked(monkeypatch):
    """Full falcon-mamba forward: Pallas kernel (interpret) == chunked scan."""
    import os

    import repro.models as M
    from repro.configs import smoke_config

    cfg = smoke_config("falcon-mamba-7b").replace(remat=False)
    mod = M.module_for(cfg)
    params = M.init_model_params(cfg, jax.random.PRNGKey(0))
    tok = jax.random.randint(jax.random.PRNGKey(2), (2, 24), 0,
                             cfg.vocab_size)
    monkeypatch.setenv("REPRO_PALLAS", "ref")
    ref_logits, _ = mod.forward(params, cfg, tok)
    monkeypatch.setenv("REPRO_PALLAS", "interpret")
    k_logits, _ = mod.forward(params, cfg, tok)
    np.testing.assert_allclose(np.asarray(k_logits), np.asarray(ref_logits),
                               atol=1e-3)


def test_int8_matmul_exact_integer_accumulation(rng):
    """int32 accumulation is exact — unlike f32 fake-quant, big-K sums must
    not lose integer precision."""
    M, K, N = 4, 8192, 4
    xq = jnp.asarray(rng.integers(-127, 128, (M, K)), jnp.int8)
    wq = jnp.asarray(rng.integers(-127, 128, (K, N)), jnp.int8)
    y = int8_matmul(xq, wq, jnp.float32(1.0), jnp.ones((N,), jnp.float32),
                    interpret=True)
    exact = (np.asarray(xq, np.int64) @ np.asarray(wq, np.int64)).astype(np.float64)
    np.testing.assert_allclose(np.asarray(y, np.float64), exact, rtol=1e-6)
