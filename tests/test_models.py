"""Per-architecture smoke tests (reduced same-family configs) + serving-path
coherence: one forward/train step on CPU, shape checks, no NaNs; prefill +
decode must reproduce the teacher-forced forward exactly."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.models as M
from repro.configs import ASSIGNED, PAPER_ARCHS, get_shape, smoke_config

SMALL_TRAIN = get_shape("train_4k").replace(seq_len=32, global_batch=2)


@pytest.mark.parametrize("arch", sorted(ASSIGNED))
def test_smoke_forward(arch):
    cfg = smoke_config(arch)
    params = M.init_model_params(cfg, jax.random.PRNGKey(0))
    batch = M.synth_batch(cfg, SMALL_TRAIN, jax.random.PRNGKey(1))
    logits, aux = M.forward(params, cfg, batch)
    B = batch["labels"].shape[0] if "labels" in batch else 2
    assert logits.shape[0] == B
    assert logits.shape[-1] == (cfg.vocab_size or cfg.num_classes)
    assert bool(jnp.isfinite(logits).all()), f"{arch} produced NaN/Inf"


@pytest.mark.parametrize("arch", sorted(ASSIGNED))
def test_smoke_train_step(arch):
    """One real optimizer step on the host mesh; loss finite, params move."""
    from repro.launch.mesh import make_host_mesh
    from repro.optim import constant, make_optimizer
    from repro.train.train_step import build_train_step, init_train_state

    cfg = smoke_config(arch)
    shape = SMALL_TRAIN
    mesh = make_host_mesh()
    opt = make_optimizer(cfg.optimizer, constant(1e-3))
    with mesh:
        step = build_train_step(cfg, shape, mesh, opt, donate=False)
        state = init_train_state(cfg, opt, jax.random.PRNGKey(0))
        batch = M.synth_batch(cfg, shape, jax.random.PRNGKey(1))
        new_state, metrics = step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert int(new_state.step) == 1
    moved = jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))),
        state.params, new_state.params))
    assert max(moved) > 0, "optimizer step did not change params"


@pytest.mark.parametrize("arch", sorted(PAPER_ARCHS))
def test_paper_arch_forward(arch):
    cfg = smoke_config(arch)
    params = M.init_model_params(cfg, jax.random.PRNGKey(0))
    batch = M.synth_batch(cfg, SMALL_TRAIN, jax.random.PRNGKey(1))
    logits, aux = M.forward(params, cfg, batch)
    assert logits.shape == (2, cfg.num_classes)
    assert bool(jnp.isfinite(logits).all())


DECODE_ARCHS = ["llama3-8b", "qwen3-moe-235b-a22b", "falcon-mamba-7b",
                "zamba2-7b", "seamless-m4t-medium", "gemma2-2b",
                "internvl2-26b", "gemma-7b", "nemotron-4-340b", "olmoe-1b-7b"]


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_prefill_decode_matches_forward(arch):
    """prefill(prompt) + decode_step(next) == teacher-forced forward."""
    from repro.serving.engine import serving_config

    # serving path: MoE archs run the dropless grouped (unified) kernel
    cfg = serving_config(smoke_config(arch).replace(remat=False))
    mod = M.module_for(cfg)
    params = M.init_model_params(cfg, jax.random.PRNGKey(0))
    rng = jax.random.PRNGKey(2)
    B, S = 2, 12
    tok = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    fe = None
    n_front = 0
    if cfg.frontend and cfg.family != "encdec":
        n_front = 8
        fe = jax.random.normal(rng, (B, n_front, cfg.frontend_dim), jnp.float32)
    elif cfg.family == "encdec":
        fe = jax.random.normal(rng, (B, 8, cfg.frontend_dim), jnp.float32)
    full, _ = mod.forward(params, cfg, tok, frontend_embeds=fe)
    lg, cache = mod.prefill(params, cfg, tok[:, :8], frontend_embeds=fe,
                            max_len=S + n_front)
    # frontend tokens prepend to the decoder stream (vlm); the teacher-forced
    # logit at text position 7 sits at stream position n_front + 7
    np.testing.assert_allclose(
        np.asarray(lg[:, 0]), np.asarray(full[:, n_front + 7]),
        rtol=5e-4, atol=5e-4)
    idx = jnp.asarray(8 + n_front, jnp.int32)
    lg2, cache = mod.decode_step(params, cfg, tok[:, 8:9], cache, idx)
    np.testing.assert_allclose(
        np.asarray(lg2[:, 0]), np.asarray(full[:, n_front + 8]),
        rtol=5e-4, atol=5e-4)


def test_gemma2_local_global_alternation():
    """Local layers must not see beyond the window (structural check)."""
    cfg = smoke_config("gemma2-2b").replace(remat=False)
    assert cfg.attn.alternate_local_global and cfg.attn.local_window == 16
    params = M.init_model_params(cfg, jax.random.PRNGKey(0))
    S = 24
    tok = jax.random.randint(jax.random.PRNGKey(1), (1, S), 0, cfg.vocab_size)
    base, _ = M.module_for(cfg).forward(params, cfg, tok)
    # perturbing a token *outside* every local window but *inside* causal
    # range must still change the last-position logits (global layers see it)
    tok2 = tok.at[0, 0].set((tok[0, 0] + 1) % cfg.vocab_size)
    pert, _ = M.module_for(cfg).forward(params, cfg, tok2)
    assert float(jnp.max(jnp.abs(base[:, -1] - pert[:, -1]))) > 0


def test_gemma2_ring_cache_wraparound():
    """Sliding-window ring cache: decoding far past the window must still
    reproduce teacher-forced logits (slots rotate, RoPE is absolute)."""
    cfg = smoke_config("gemma2-2b").replace(remat=False)
    W = cfg.attn.local_window  # 16 in the smoke config
    mod = M.module_for(cfg)
    params = M.init_model_params(cfg, jax.random.PRNGKey(0))
    S = W + 9  # force wraparound
    tok = jax.random.randint(jax.random.PRNGKey(1), (1, S), 0, cfg.vocab_size)
    full, _ = mod.forward(params, cfg, tok)
    lg, cache = mod.prefill(params, cfg, tok[:, :4], max_len=S)
    assert cache["local"]["k"].shape[2] == W  # ring allocation
    for t in range(4, S):
        np.testing.assert_allclose(
            np.asarray(lg[:, 0]), np.asarray(full[:, t - 1]),
            rtol=1e-3, atol=1e-3)
        lg, cache = mod.decode_step(params, cfg, tok[:, t:t + 1], cache,
                                    jnp.asarray(t, jnp.int32))
    np.testing.assert_allclose(np.asarray(lg[:, 0]),
                               np.asarray(full[:, S - 1]),
                               rtol=1e-3, atol=1e-3)


def test_long_context_decode_is_state_size_independent():
    """SSM decode state is O(1) in context length (the long_500k property)."""
    cfg = smoke_config("falcon-mamba-7b")
    mod = M.module_for(cfg)
    c1 = mod.init_cache(cfg, 1, 1024)
    c2 = mod.init_cache(cfg, 1, 1024 * 512)
    b1 = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(c1))
    b2 = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(c2))
    assert b1 == b2


def test_chunked_scan_matches_reference_recurrence(rng):
    from repro.models import ssm

    a = jnp.asarray(rng.uniform(0.8, 1.0, (2, 40, 4, 3)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((2, 40, 4, 3)), jnp.float32)
    h_ref = ssm.linear_recurrence(a, b)

    def body(h0, sl):
        h, hl = ssm._chunk_recurrence(sl[0], sl[1], h0)
        return hl, h

    hl, hs = jax.lax.scan(
        body, jnp.zeros((2, 4, 3)),
        (ssm._pad_chunks(a, 8), ssm._pad_chunks(b, 8)),
    )
    h_chunk = jnp.moveaxis(hs, 0, 1).reshape(2, -1, 4, 3)
    np.testing.assert_allclose(h_chunk, h_ref, atol=2e-5)
    np.testing.assert_allclose(hl, h_ref[:, -1], atol=2e-5)


def test_param_count_roughly_matches_materialized():
    """ModelConfig.param_count agrees with the actual tree (sanity on the
    roofline's MODEL_FLOPS term)."""
    from repro.models.param import param_count_tree

    for arch in ["llama3-8b", "olmoe-1b-7b", "falcon-mamba-7b"]:
        cfg = smoke_config(arch)
        tree = M.abstract_params(cfg)
        actual = param_count_tree(tree)
        approx = cfg.param_count()
        assert abs(actual - approx) / actual < 0.15, (
            arch, actual, approx)
