"""Property-testing shim: the real `hypothesis` when installed, otherwise a
small deterministic fallback.

The container image does not ship hypothesis, and a hard import made three
test modules fail *collection*, taking the whole tier-1 suite down with
them. Tests import ``given``/``settings``/``st`` from here instead:

  * with hypothesis installed (declared as a dev dependency in
    pyproject.toml) the real shrinking/edge-case generator runs;
  * without it, each ``@given`` test runs a fixed number of seeded random
    examples (seed derived from the test name, so failures reproduce).

The fallback supports exactly the strategy surface the suite uses:
integers, floats, lists, sampled_from, dictionaries, recursive.
"""
from __future__ import annotations

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import functools
    import inspect
    import zlib

    import numpy as np

    # Cap fallback example counts: without hypothesis's dedup/shrinking,
    # examples are raw reruns — keep the suite fast on the 1-core box.
    _MAX_FALLBACK_EXAMPLES = 10

    class _Strategy:
        def __init__(self, sample):
            self._sample = sample

        def example(self, rng):
            return self._sample(rng)

    class _St:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1))
            )

        @staticmethod
        def floats(min_value, max_value, allow_nan=False,
                   allow_infinity=False):
            return _Strategy(
                lambda rng: float(rng.uniform(min_value, max_value))
            )

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(
                lambda rng: elements[int(rng.integers(0, len(elements)))]
            )

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            def sample(rng):
                n = int(rng.integers(min_size, max_size + 1))
                return [elements.example(rng) for _ in range(n)]

            return _Strategy(sample)

        @staticmethod
        def dictionaries(keys, values, min_size=0, max_size=5):
            def sample(rng):
                n = int(rng.integers(min_size, max_size + 1))
                return {
                    keys.example(rng): values.example(rng) for _ in range(n)
                }

            return _Strategy(sample)

        @staticmethod
        def recursive(base, extend, max_leaves=10):
            def sample(rng, depth=0):
                if depth >= 3 or rng.random() < 0.4:
                    return base.example(rng)
                inner = _Strategy(lambda r: sample(r, depth + 1))
                return extend(inner).example(rng)

            return _Strategy(sample)

    st = _St()

    def settings(max_examples=20, deadline=None, **_kw):
        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco

    def given(*strats):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper():
                n = min(
                    getattr(wrapper, "_max_examples", 20),
                    _MAX_FALLBACK_EXAMPLES,
                )
                seed = zlib.crc32(fn.__name__.encode())
                rng = np.random.default_rng(seed)
                for _ in range(n):
                    fn(*[s.example(rng) for s in strats])

            # empty signature: pytest must not treat the original strategy
            # params as fixtures
            wrapper.__signature__ = inspect.Signature()
            return wrapper

        return deco
