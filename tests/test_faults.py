"""Fault tolerance (DESIGN.md section 14): deterministic chaos injection,
watchdog eviction + standby backfill, in-flight re-dispatch with the
at-most-once retirement guard, degraded-mode admission, and the
watchdog/autoscaler interplay — all under a fake clock with fake replicas
(the machinery is pure host-side bookkeeping)."""
import dataclasses
import json
import threading
import urllib.error
import urllib.request

import numpy as np
import pytest

from repro.configs.base import AutoscaleConfig, FaultConfig
from repro.distributed.fault_tolerance import run_step_with_retry
from repro.serving.autoscaler import Autoscaler
from repro.serving.cluster import ServingCluster
from repro.serving.events import EventLog
from repro.serving.faults import (
    FaultInjector,
    FaultyReplica,
    InjectedFault,
    InjectedOOM,
    ReplicaWatchdog,
)
from repro.serving.metrics import ClusterMetrics, EngineMetrics
from repro.serving.metrics_server import MetricsServer, cluster_healthz
from repro.serving.replica import EngineReplica
from repro.serving.scheduler import Backpressure


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


@dataclasses.dataclass
class FakeRequest:
    uid: int
    submitted_at: float = None
    on_done: object = None
    trace_id: int = None
    status: str = "pending"
    redispatched: int = 0
    evicted: bool = False


class ChaosFakeReplica:
    """Deterministic ``EngineReplica`` with the optional ``evict()`` hook:
    serves ``capacity`` queued requests per step (firing ``on_done``), can
    be wedged by assigning ``fail`` an exception instance."""

    def __init__(self, mesh, clock, *, capacity=2, max_pending=8):
        self.mesh = mesh
        self._clock = clock
        self.capacity = capacity
        self.max_pending = max_pending
        self._queue = []
        self.fail = None  # exception raised by every step while set
        self.metrics = EngineMetrics(clock=clock)

    def submit(self, req):
        if len(self._queue) >= self.max_pending:
            self.metrics.inc("rejected")
            raise Backpressure("fake replica full")
        if req.submitted_at is None:
            req.submitted_at = self._clock()
        self._queue.append(req)
        self.metrics.inc("submitted")

    def step(self):
        if self.fail is not None:
            raise self.fail
        now = self._clock()
        served, self._queue = (self._queue[:self.capacity],
                               self._queue[self.capacity:])
        for req in served:
            req.status = "completed"
            self.metrics.inc("completed")
            self.metrics.work_done(1, "frames")
            self.metrics.request_latency.record(
                max(0.0, now - req.submitted_at))
            if req.on_done is not None:
                req.on_done(req)

    def warmup(self):
        pass

    def flush(self):
        while self._queue:
            self.step()

    def reset_metrics(self):
        self.metrics = EngineMetrics(clock=self._clock)

    def evict(self):
        out = []
        for req in self._queue:
            if req.status == "pending":
                req.evicted = True
                out.append(req)
        self._queue = []
        return out

    @property
    def load(self):
        return len(self._queue)

    @property
    def free_room(self):
        return max(0, self.max_pending - len(self._queue))

    @property
    def idle(self):
        return not self._queue


def _cluster(clock, *, replicas=2, standby=1, capacity=2, max_pending=8,
             faults=None, events=None, **kw):
    replicas_built = []

    def factory(mesh):
        eng = ChaosFakeReplica(mesh, clock, capacity=capacity,
                               max_pending=max_pending)
        replicas_built.append(eng)
        return eng

    cluster = ServingCluster(None, None, replicas=replicas, standby=standby,
                             engine=factory, clock=clock, faults=faults,
                             events=events, **kw)
    return cluster, replicas_built


# -- chaos injector -----------------------------------------------------------


def test_injector_is_deterministic_per_seed_and_ordinal():
    cfg = FaultConfig(inject=True, seed=9, step_error_rate=0.3,
                      oom_rate=0.1, step_stall_rate=0.2, stall_s=0.0)

    def run(ordinal):
        inj = FaultInjector(cfg, ordinal, stall_fn=lambda s: None)
        seq = []
        for _ in range(50):
            try:
                inj.before_step()
                seq.append("ok")
            except InjectedOOM:
                seq.append("oom")
            except InjectedFault:
                seq.append("err")
        return seq, dict(inj.injected)

    a_seq, a_counts = run(0)
    b_seq, b_counts = run(0)
    assert a_seq == b_seq and a_counts == b_counts  # pure fn of (seed, ord)
    assert a_counts  # rates actually fired
    c_seq, _ = run(1)
    assert a_seq != c_seq  # per-replica independent streams


def test_kill_schedule_overrides_draws_and_dead_is_permanent():
    cfg = FaultConfig(inject=True, kill_schedule=((0, 3, "dead"),
                                                  (1, 2, "error")))
    inj = FaultInjector(cfg, 0)
    inj.before_step()
    inj.before_step()  # steps 1-2 clean (no rates configured)
    for _ in range(4):  # step 3 kills; every later step raises too
        with pytest.raises(InjectedFault):
            inj.before_step()
    assert inj.dead and inj.injected == {"dead": 1}
    other = FaultInjector(cfg, 1)  # ordinal filtering
    other.before_step()
    with pytest.raises(InjectedFault):
        other.before_step()
    assert not other.dead


def test_faulty_replica_wraps_protocol_and_injects_at_boundaries():
    clock = FakeClock()
    inner = ChaosFakeReplica(None, clock)
    wrapped = FaultyReplica(inner, FaultInjector(
        FaultConfig(inject=True, submit_reject_rate=1.0), 0))
    assert isinstance(wrapped, EngineReplica)
    with pytest.raises(Backpressure):
        wrapped.submit(FakeRequest(uid=0))
    # callback poisoning: the user callback still runs, then the wrapper
    # raises (terminal delivery survives the poison)
    fired = []
    poison = FaultyReplica(inner, FaultInjector(
        FaultConfig(inject=True, callback_poison_rate=1.0), 0))
    req = FakeRequest(uid=1, on_done=lambda r: fired.append(r.uid))
    poison.submit(req)
    with pytest.raises(InjectedFault):
        req.on_done(req)
    assert fired == [1]
    assert wrapped.load == inner.load and wrapped.idle == inner.idle


# -- watchdog + quarantine ----------------------------------------------------


def test_error_budget_evicts_redispatches_and_backfills():
    clock = FakeClock()
    events = EventLog(clock=clock)
    fc = FaultConfig(error_budget=2, retry_budget=2)
    cluster, built = _cluster(clock, replicas=2, standby=1, capacity=1,
                              faults=fc, events=events)
    done = []
    reqs = [FakeRequest(uid=i, on_done=lambda r: done.append(r.uid))
            for i in range(8)]
    for r in reqs:
        cluster.submit(r)
    cluster._route()
    victim = built[0]
    assert victim in cluster.engines and victim.load > 0
    victim.fail = RuntimeError("wedged device")
    for _ in range(20):
        cluster.step()
        clock.advance(0.01)
    cluster.flush()
    # eviction happened, the standby backfilled, nothing was lost: every
    # accepted request got exactly one terminal callback
    assert victim not in cluster.engines
    assert cluster.num_replicas == 2 and cluster.standby_replicas == 0
    assert sorted(done) == list(range(8)) and len(done) == 8
    assert all(r.status == "completed" for r in reqs)
    counters = cluster.metrics.snapshot()["aggregate"]["counters"]
    assert counters["replicas_evicted"] == 1
    assert counters["replicas_replaced"] == 1
    assert counters["replica_step_errors"] == 2  # budget, not one
    assert counters["cluster_redispatched"] >= 1
    assert counters.get("cluster_failed", 0) == 0
    assert events.events("replica_replaced")
    ev = events.events("replica_evicted")[0]
    # full watchdog inputs ride on the eviction record
    assert ev["reason"] == "step_errors"
    assert ev["consecutive_errors"] == 2 and "last_error" in ev
    assert not cluster.degraded


def test_oom_classified_error_evicts_on_first_hit():
    clock = FakeClock()
    fc = FaultConfig(error_budget=5)
    cluster, built = _cluster(clock, replicas=2, standby=1, capacity=1,
                              faults=fc)
    built[0].fail = InjectedOOM("RESOURCE_EXHAUSTED: fake")
    cluster.step()
    assert built[0] not in cluster.engines
    counters = cluster.metrics.snapshot()["aggregate"]["counters"]
    assert counters["replicas_evicted"] == 1
    assert counters["replica_step_errors"] == 1  # no retry into a full heap
    assert cluster._evicted[0]["reason"] == "oom"


def test_retry_budget_exhaustion_terminates_as_failed():
    clock = FakeClock()
    fc = FaultConfig(error_budget=1, retry_budget=1)
    # every replica wedged: each re-dispatch lands on a replica that gets
    # evicted too, burning the budget down to terminal failed
    cluster, built = _cluster(clock, replicas=2, standby=2, capacity=1,
                              faults=fc)
    done = []
    req = FakeRequest(uid=0, on_done=lambda r: done.append(r.status))
    cluster.submit(req)
    cluster._route()
    for eng in built:
        eng.fail = RuntimeError("wedged")
    for _ in range(10):
        if not cluster.engines:
            break
        cluster.step()
    assert req.status == "failed" and req.redispatched == 2
    assert done == ["failed"]  # terminal callback delivered exactly once
    counters = cluster.metrics.snapshot()["aggregate"]["counters"]
    assert counters["cluster_failed"] == 1


def test_injected_stall_evicts_under_fake_clock_despite_cooldown():
    """Satellite: eviction-driven standby promotion must not wait on the
    autoscaler's cooldown. Stalls are injected via the fake clock (the
    injector's stall_fn advances time instead of sleeping)."""
    clock = FakeClock()
    fc = FaultConfig(inject=True, step_stall_rate=1.0, stall_s=1.0,
                     step_timeout_s=0.5, stall_budget=2, watchdog=True)
    cluster, built = _cluster(clock, replicas=1, standby=1, capacity=1,
                              faults=fc, fault_stall_fn=clock.advance)
    scaler = Autoscaler(cluster, AutoscaleConfig(
        min_replicas=1, max_replicas=2, cooldown=100,
        up_patience=10**9, down_patience=10**9,
        slo_p95_ms=1e9, min_window_samples=10**9))
    scaler._cooldown = 100  # controller frozen for 100 evaluations
    for i in range(3):
        cluster.submit(FakeRequest(uid=i))
    before = cluster.standby_replicas
    for _ in range(10):  # stall_budget=2 steps of 1.0s > 0.5s timeout
        cluster.step()
        assert scaler.tick() is None  # cooldown holds the controller
        if cluster._evicted:
            break
    assert len(cluster._evicted) == 1
    assert cluster._evicted[0]["reason"] == "stalled"
    # standby promoted by quarantine() directly, cooldown notwithstanding
    assert cluster.standby_replicas == before - 1
    assert cluster.num_replicas == 1


def test_quarantined_replica_metrics_fold_without_deadlock():
    """Satellite: ClusterMetrics folds a quarantined (never-drained)
    replica's tracker while another thread records into it — bounded time,
    no deadlock on the metrics locks."""
    clock = FakeClock()
    m = EngineMetrics(clock=clock)
    cm = ClusterMetrics([m], clock=clock)
    stop = threading.Event()

    def hammer():
        while not stop.is_set():
            m.request_latency.record(0.01)
            m.inc("completed")
            cm.inc("cluster_submitted")

    t = threading.Thread(target=hammer, daemon=True)
    t.start()
    try:
        folder = threading.Thread(target=lambda: cm.remove_replica(m),
                                  daemon=True)
        folder.start()
        folder.join(timeout=10.0)
        assert not folder.is_alive(), "remove_replica deadlocked"
    finally:
        stop.set()
        t.join(timeout=5.0)
    assert cm.num_replicas == 0
    # folded distribution is non-empty and later records are replica-local
    assert len(cm.merged_request_latency()) > 0


# -- at-most-once retirement --------------------------------------------------


def test_duplicate_retirement_is_exactly_once_through_real_engine():
    """Satellite: replay a duplicate retirement for the same trace_id
    through the real ServeEngine consume path — exactly-once delivery, the
    duplicate counted, and a raising on_done neither double-fires nor
    drops the terminal event."""
    import jax

    import repro.models as M
    from repro.configs import smoke_config
    from repro.serving.engine import Request, ServeEngine

    cfg = smoke_config("llama3-8b").replace(remat=False)
    params = M.init_model_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, batch_slots=2, max_len=32)
    fired = []

    def cb(r):
        fired.append(r.uid)
        raise RuntimeError("user callback bug")

    req = Request(uid=7, prompt=np.zeros(4, np.int32), max_new_tokens=2,
                  generated=[], submitted_at=0.0, on_done=cb, trace_id=42)
    ev = {"now": 1.0, "retired": [(req, 1.0, False)]}
    eng._consume(ev)
    eng._consume(ev)  # duplicate replay, same trace_id
    assert fired == [7], "terminal callback must fire exactly once"
    assert req.status == "completed"
    assert eng.metrics.counters["completed"] == 1
    assert eng.metrics.counters["duplicate_retirements"] == 1
    assert eng.metrics.counters["callback_errors"] == 1


def test_cluster_on_done_guard_suppresses_cross_replica_duplicates():
    clock = FakeClock()
    cluster, built = _cluster(clock, replicas=1, standby=0, capacity=1,
                              faults=FaultConfig())
    fired = []
    req = FakeRequest(uid=3, on_done=lambda r: fired.append(r.uid))
    cluster.submit(req)
    guarded = req.on_done
    guarded(req)
    guarded(req)  # a second replica replaying the same terminal event
    assert fired == [3]
    counters = cluster.metrics.snapshot()["aggregate"]["counters"]
    assert counters["duplicate_retirements"] == 1


def test_evicted_requests_ignore_stale_retirements():
    clock = FakeClock()
    eng = ChaosFakeReplica(None, clock, capacity=2)
    req = FakeRequest(uid=0)
    eng.submit(req)
    stranded = eng.evict()
    assert stranded == [req] and req.evicted and eng.idle


# -- degraded mode ------------------------------------------------------------


def test_degraded_mode_sheds_load_and_recovers_on_scale_up():
    clock = FakeClock()
    events = EventLog(clock=clock)
    fc = FaultConfig(error_budget=1)
    cluster, built = _cluster(clock, replicas=2, standby=0, capacity=0,
                              max_pending=2, faults=fc, events=events,
                              max_pending_per_replica=2)
    built[0].fail = RuntimeError("dead")
    cluster.step()
    assert cluster.degraded and cluster.num_replicas == 1
    assert events.events("cluster_degraded")
    # degraded admission: front bound tightens to active x per-replica cap
    admitted = 0
    with pytest.raises(Backpressure):
        for i in range(10):
            cluster.submit(FakeRequest(uid=i))
            admitted += 1
    assert admitted == 2  # 1 surviving replica x cap 2
    counters = cluster.metrics.snapshot()["aggregate"]["counters"]
    assert counters["cluster_shed"] >= 1
    # the controller must not fight recovery
    assert not cluster.scale_down()
    # restoring capacity clears degraded mode
    assert cluster.scale_up()
    assert not cluster.degraded
    assert events.events("cluster_recovered")


def test_healthz_folds_watchdog_state_and_eviction_ledger():
    clock = FakeClock()
    fc = FaultConfig(error_budget=1)
    cluster, built = _cluster(clock, replicas=2, standby=0, capacity=1,
                              faults=fc)
    built[0].fail = RuntimeError("dead")
    cluster.step()
    health = cluster_healthz(cluster)
    assert health["status"] == "degraded" and health["degraded"]
    assert len(health["evicted"]) == 1
    assert health["evicted"][0]["reason"] == "step_errors"
    assert all(v["health"] == "healthy"
               for v in health["replicas"].values())
    # served over HTTP: degraded reports 503 (load balancers pull the node)
    server = MetricsServer(lambda: "", snapshot_fn=None,
                           healthz_fn=lambda: cluster_healthz(cluster))
    server.start()
    try:
        url = f"{server.url}/healthz"
        with pytest.raises(urllib.error.HTTPError) as err:
            urllib.request.urlopen(url)
        assert err.value.code == 503
        body = json.loads(err.value.read())
        assert body["status"] == "degraded"
    finally:
        server.close()
    # close() joined the daemon thread and is idempotent
    assert server._thread is None and server._httpd is None
    server.close()


# -- seed utilities (satellite regression) ------------------------------------


def test_run_step_with_retry_backoff_and_give_up_contract():
    sleeps, retries = [], []

    def flaky_factory(fail_times):
        calls = {"n": 0}

        def fn(x):
            calls["n"] += 1
            if calls["n"] <= fail_times:
                raise RuntimeError("transient")
            return x * 2

        return fn

    # succeeds within budget: retries with exponential backoff 0.1 * 2^k
    out = run_step_with_retry(flaky_factory(2), 21, max_retries=2,
                              on_retry=retries.append,
                              sleep=sleeps.append)
    assert out == 42
    assert retries == [0, 1]
    assert sleeps == pytest.approx([0.1, 0.2])
    # gives up: the final attempt's exception propagates, no extra sleep
    sleeps.clear()
    with pytest.raises(RuntimeError, match="transient"):
        run_step_with_retry(flaky_factory(5), 1, max_retries=2,
                            sleep=sleeps.append)
    assert sleeps == pytest.approx([0.1, 0.2])
    # non-retryable exceptions pass straight through
    def boom(_):
        raise ValueError("not transient")

    with pytest.raises(ValueError):
        run_step_with_retry(boom, 0, sleep=sleeps.append)


def test_watchdog_streak_resets():
    fc = FaultConfig(error_budget=3, stall_budget=2, step_timeout_s=0.5)
    wd = ReplicaWatchdog(fc)
    assert wd.record_error(RuntimeError("a")) is None
    assert wd.record_error(RuntimeError("b")) is None
    assert wd.record_step(0.01) is None  # success resets the error streak
    assert wd.consecutive_errors == 0
    assert wd.record_error(RuntimeError("c")) is None  # streak restarts
    assert wd.record_step(1.0) is None  # stall 1/2 (absolute timeout)
    assert wd.record_step(0.01) is None  # healthy step resets stalls
    assert wd.record_step(1.0) is None
    verdict = wd.record_step(1.0)
    assert verdict is not None and verdict["reason"] == "stalled"
    assert verdict["consecutive_stalls"] == 2
