"""Live performance introspection (DESIGN.md section 12): per-program
cost capture must cover every AOT program, degrade to analytic estimates
instead of ever failing warmup, join with measured step latencies into
MFU/roofline rows that survive elasticity folds, watch expert routing for
drift, and serve it all over a scrapeable endpoint."""
import json
import os
import sys
import urllib.request

import jax
import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import repro.models as M  # noqa: E402
from repro.configs import smoke_config  # noqa: E402
from repro.serving.engine import Request, ServeEngine  # noqa: E402
from repro.serving.events import EventLog  # noqa: E402
from repro.serving.introspect import (  # noqa: E402
    ExpertHealthMonitor,
    analytic_program_cost,
    capture_cost,
    memory_watermark,
    normalize_cost_analysis,
    parse_program_key,
    program_cost_from_compiled,
)
from repro.serving.metrics import (  # noqa: E402
    ClusterMetrics,
    EngineMetrics,
    program_perf,
)
from repro.serving.metrics_server import (  # noqa: E402
    MetricsServer,
    cluster_healthz,
)
from repro.serving.vision import VisionEngine, synth_requests  # noqa: E402
from benchmarks.provenance import stamp  # noqa: E402
from tools.bench_diff import comparable, diff, flatten  # noqa: E402


# ---------------------------------------------------------------- unit layer


def test_parse_program_key():
    prog, dims = parse_program_key("serve/packed_prefill|B=4|S=128|"
                                   "bucket=64|n=3")
    assert prog == "serve/packed_prefill"
    assert dims == {"B": 4, "S": 128, "bucket": 64, "n": 3}
    prog, dims = parse_program_key("classify|b=8")
    assert prog == "classify" and dims == {"b": 8}
    assert parse_program_key("bare")[1] == {}


def test_normalize_cost_analysis_quirks():
    # jax versions disagree on the return shape: list-of-dict, bare dict,
    # None, or garbage. All must normalize without raising.
    d = {"flops": 10.0, "bytes accessed": 20.0, "utilization": "high"}
    assert normalize_cost_analysis([d])["flops"] == 10.0
    assert normalize_cost_analysis(d)["bytes accessed"] == 20.0
    assert normalize_cost_analysis(None) == {}
    assert normalize_cost_analysis("garbage") == {}
    assert normalize_cost_analysis([]) == {}
    assert normalize_cost_analysis([None]) == {}
    # non-numeric values are filtered, numerics coerced to float
    out = normalize_cost_analysis({"flops": 5, "name": "dot"})
    assert out == {"flops": 5.0}


def test_program_cost_from_real_compiled():
    compiled = jax.jit(lambda x: x @ x).lower(
        jax.ShapeDtypeStruct((64, 64), jnp.float32)).compile()
    row = program_cost_from_compiled(compiled)
    assert row is not None
    assert row["flops"] > 0
    assert row["hbm_bytes"] > 0
    assert row["estimated"] is False
    assert "cost_analysis" in row["source"] or "hlo" in row["source"]


class _BrokenCompiled:
    def cost_analysis(self):
        raise RuntimeError("unimplemented on this backend")

    def memory_analysis(self):
        raise RuntimeError("nope")

    def as_text(self):
        raise RuntimeError("nope")


def test_capture_cost_degrades_to_analytic():
    cfg = smoke_config("olmoe-1b-7b")
    row = capture_cost(_BrokenCompiled(), "serve/decode|B=4|S=128", cfg,
                       param_bytes=1 << 20, cache_bytes=1 << 16)
    assert row["estimated"] is True
    assert row["flops"] > 0 and row["hbm_bytes"] > 0
    assert "analytic" in row["source"]
    # even with no cfg there must be a row, never an exception
    row2 = capture_cost(None, "serve/decode|B=4|S=128", None)
    assert row2["estimated"] is True


def test_analytic_cost_scales_with_tokens():
    cfg = smoke_config("olmoe-1b-7b")
    small = analytic_program_cost("serve/decode|B=2|S=128", cfg)
    big = analytic_program_cost(
        "serve/packed_prefill|B=2|S=128|bucket=64|n=2", cfg)
    assert big["flops"] > small["flops"]  # 64 tokens vs 2 decode tokens


def test_memory_watermark_analytic_fallback():
    # CPU devices report no memory_stats -> analytic path, flagged
    mem = memory_watermark(jax.devices(), param_bytes=1000,
                           cache_bytes=500,
                           program_costs={"k": {"temp_bytes": 200.0}})
    assert mem["watermark_bytes"] >= 1700 or mem["estimated"] is False
    if mem["estimated"]:
        assert mem["param_bytes"] == 1000
        assert mem["kv_cache_bytes"] == 500


# ------------------------------------------------------- engine integration


@pytest.fixture(scope="module")
def lm_engine():
    cfg = smoke_config("olmoe-1b-7b")
    params = M.init_model_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, batch_slots=2, max_len=64)
    eng.warmup()
    rng = np.random.default_rng(0)
    for uid in range(2):
        eng.submit(Request(
            uid=uid,
            prompt=rng.integers(0, cfg.vocab_size, 8).astype(np.int32),
            max_new_tokens=4))
    eng.run_until_drained()
    return eng


def test_every_lm_program_has_cost_row(lm_engine):
    assert lm_engine._programs, "packed engine should have an AOT grid"
    missing = set(lm_engine._programs) - set(lm_engine.metrics.program_costs)
    assert not missing, f"programs without ProgramCost rows: {missing}"


def test_lm_snapshot_has_mfu_join(lm_engine):
    perf = lm_engine.metrics.snapshot()["program_perf"]
    assert perf
    measured = [v for v in perf.values() if v.get("mfu") is not None]
    assert measured, "served programs must join cost x latency into MFU"
    for row in measured:
        assert 0 < row["mfu"] < 1.5  # plausible fraction of peak
        assert row["achieved_hbm_gbps"] is not None
        assert row["bound"] in ("compute", "memory", "collective")


def test_lm_snapshot_has_memory_block(lm_engine):
    mem = lm_engine.metrics.snapshot()["memory"]
    assert mem is not None
    assert mem["watermark_bytes"] > 0


def test_warmup_survives_cost_analysis_failure(monkeypatch):
    # cost surfaces raising on every program must degrade to analytic
    # estimates, not break warmup (satellite: cost_analysis() quirks)
    import repro.serving.introspect as I

    def broken(compiled):
        raise RuntimeError("cost surface unavailable")

    monkeypatch.setattr(I, "program_cost_from_compiled", broken)
    cfg = smoke_config("olmoe-1b-7b")
    params = M.init_model_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, batch_slots=2, max_len=64)
    eng.warmup()  # must not raise
    costs = eng.metrics.program_costs
    assert set(eng._programs) <= set(costs)
    assert all(c["estimated"] for c in costs.values())


def test_mfu_survives_scale_down_fold(lm_engine):
    cm = ClusterMetrics([lm_engine.metrics])
    live = cm.snapshot()["aggregate"]["program_perf"]
    assert any(v.get("mfu") is not None for v in live.values())
    cm.remove_replica(lm_engine.metrics)  # retire the only replica
    folded = cm.snapshot()["aggregate"]["program_perf"]
    assert any(v.get("mfu") is not None for v in folded.values()), \
        "MFU rows must survive a scale_down fold into the retired pool"


@pytest.fixture(scope="module")
def vision_engine():
    cfg = smoke_config("m3vit-tiny")
    params = M.init_model_params(cfg, jax.random.PRNGKey(0))
    eng = VisionEngine(cfg, params, batch_buckets=(1, 2), max_wait_s=0.0,
                       max_pending=0)
    eng.warmup()
    for r in synth_requests(cfg, 4, seed=0):
        eng.submit(r)
    eng.flush()
    return eng


def test_every_vision_bucket_has_cost_row(vision_engine):
    costs = vision_engine.metrics.program_costs
    assert {"classify|b=1", "classify|b=2"} <= set(costs)


def test_vision_snapshot_has_mfu_join(vision_engine):
    snap = vision_engine.metrics.snapshot()
    perf = snap["program_perf"]
    assert any(v.get("mfu") is not None for v in perf.values())
    assert snap["expert_health"] is not None


# --------------------------------------------------------- expert drift


def test_expert_drift_fires_on_skewed_routing():
    events = EventLog()
    fired = []
    mon = ExpertHealthMonitor(4, window_tokens=64, drift_threshold=0.25,
                              events=events, label="t",
                              on_drift=fired.append)
    uniform = np.array([16, 16, 16, 16])
    for _ in range(4):  # establish the uniform baseline
        mon.update(uniform)
    assert not events.events("expert_drift")
    skew = np.array([58, 2, 2, 2])
    for _ in range(4):
        mon.update(skew)
    drifts = events.events("expert_drift")
    assert drifts, "skewed routing must emit expert_drift events"
    assert fired and fired[0]["l1_vs_ref"] > 0.25
    snap = mon.snapshot()
    assert snap["hot_cold_skew"] > 1.0
    assert 0.0 <= snap["entropy"] <= 1.0
    assert snap["drift_events"] == len(drifts)


def test_expert_monitor_entropy_bounds():
    mon = ExpertHealthMonitor(8, window_tokens=8)
    mon.update(np.full(8, 1))  # perfectly uniform window
    assert mon.snapshot()["entropy"] == pytest.approx(1.0)
    mon2 = ExpertHealthMonitor(8, window_tokens=8)
    counts = np.zeros(8, np.int64)
    counts[3] = 8  # fully collapsed window
    mon2.update(counts)
    assert mon2.snapshot()["entropy"] == pytest.approx(0.0)


# ------------------------------------------------------------- endpoint


def _get(url):
    with urllib.request.urlopen(url, timeout=5) as r:
        return r.status, r.headers.get("Content-Type", ""), r.read()


def test_metrics_server_routes(lm_engine):
    cm = ClusterMetrics([lm_engine.metrics])
    with MetricsServer(cm.export_prometheus, snapshot_fn=cm.snapshot,
                       healthz_fn=lambda: {"status": "ok"}) as srv:
        status, ctype, body = _get(srv.url + "/metrics")
        assert status == 200 and "text/plain" in ctype
        text = body.decode()
        assert "repro_program_mfu" in text
        assert "repro_replica_memory_bytes" in text
        for line in text.splitlines():
            if line and not line.startswith("#"):
                name, val = line.rsplit(" ", 1)
                float(val)  # every sample value parses
        status, ctype, body = _get(srv.url + "/healthz")
        assert status == 200 and json.loads(body)["status"] == "ok"
        status, _, body = _get(srv.url + "/snapshot")
        assert status == 200 and isinstance(json.loads(body), dict)
        try:
            status, _, _ = _get(srv.url + "/nope")
        except urllib.error.HTTPError as e:
            status = e.code
        assert status == 404


def test_reset_metrics_keeps_static_cost_surface(lm_engine):
    # runs AFTER the endpoint tests: it intentionally wipes the measured
    # histograms (fresh EngineMetrics) while adopt_static carries the
    # static cost surface across
    lm_engine.reset_metrics()
    assert lm_engine.metrics.program_costs, \
        "adopt_static must carry ProgramCost rows across reset_metrics"
    assert lm_engine.metrics.peaks is not None


def test_healthz_degrades_on_errors():
    class _C:
        class metrics:
            @staticmethod
            def snapshot():
                return {"replicas_active": 1,
                        "aggregate": {"counters": {"retire_errors": 1,
                                                   "completed": 3}}}

    hz = cluster_healthz(_C())
    assert hz["status"] == "degraded"
    assert hz["retire_errors"] == 1 and hz["completed"] == 3


# -------------------------------------------- provenance + bench_diff


def test_provenance_stamp_keys():
    rep = stamp({"fps": 1.0}, "unit_test")
    p = rep["provenance"]
    for k in ("bench", "schema_version", "git_sha", "timestamp",
              "timestamp_iso", "backend", "device_kind", "device_count"):
        assert k in p, f"provenance missing {k}"
    assert p["bench"] == "unit_test"


def test_bench_diff_flags_beyond_noise():
    old = stamp({"fps": 100.0, "lat": {"p50": 10.0}}, "b")
    new = stamp({"fps": 90.0, "lat": {"p50": 10.2}}, "b")
    ok, _ = comparable(old, new)
    assert ok
    rows = {r["metric"]: r for r in diff(old, new, noise=0.05)}
    assert rows["fps"]["beyond_noise"] is True
    assert rows["lat.p50"]["beyond_noise"] is False
    assert not any(m.startswith("provenance.") for m in rows)


def test_bench_diff_incomparable():
    old = stamp({"fps": 1.0}, "bench_a")
    new = stamp({"fps": 1.0}, "bench_b")
    ok, reason = comparable(old, new)
    assert not ok and "bench" in reason
    ok, reason = comparable({"fps": 1.0}, new)
    assert not ok and "provenance" in reason


def test_flatten_drops_bools_and_nans():
    flat = flatten({"a": True, "b": float("nan"), "c": [1, {"d": 2.5}]})
    assert flat == {"c.0": 1.0, "c.1.d": 2.5}
