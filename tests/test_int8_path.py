"""The executable int8 parameter path (DESIGN.md section 4).

Covers the QuantizedParams contract end to end: the int8 grouped kernel vs
the f32 oracle (including empty groups, in interpret mode), the
materialization contract of ``ptq_model(..., materialize="int8")``, logit
fidelity of the real-int8 forward against the fake-quant oracle, the
no-fp-expert-copy property of the jitted forward, and serving decode on a
quantized tree.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.models as M
from repro.configs import get_shape, smoke_config
from repro.core.quant.qtypes import quantize_sym
from repro.core.quant.ptq import calibrate_model, ptq_model, quantized_config
from repro.kernels import ref
from repro.kernels.expert_linear import grouped_matmul
from repro.serving.engine import Request, ServeEngine, build_serve_step


# ---------------------------------------------------------------------------
# Kernel level: int8_full grouped matmul + w_scale/a_scale dequant
# ---------------------------------------------------------------------------

INT8_GROUP_CASES = [
    (4, 64, 96, [40, 0, 17, 71]),
    (1, 64, 64, [130]),  # dense mode
    (8, 32, 32, [0, 0, 5, 0, 123, 1, 0, 16]),  # mostly-empty groups
    (3, 32, 48, [0, 0, 0]),  # fully empty: zero tokens routed
    (5, 64, 64, [0, 300, 0, 0, 1]),
]


@pytest.mark.parametrize("G,Din,Dout,sizes", INT8_GROUP_CASES)
@pytest.mark.parametrize("with_ascale", [False, True])
def test_grouped_matmul_int8_full_matches_f32_ref(rng, G, Din, Dout, sizes,
                                                  with_ascale):
    """int8 x int8 grouped kernel (interpret mode, real kernel body on CPU)
    vs the f32 dequantized reference across ragged group sizes."""
    T = sum(sizes)
    gs = jnp.asarray(sizes, jnp.int32)
    xf = rng.standard_normal((T, Din)).astype(np.float32)
    a_scale = jnp.asarray(max(np.abs(xf).max(), 1e-6) / 127.0, jnp.float32) \
        if T else jnp.asarray(0.05, jnp.float32)
    x_q = quantize_sym(jnp.asarray(xf), a_scale, 8)
    wf = rng.standard_normal((G, Din, Dout)).astype(np.float32)
    w_scale = np.maximum(np.abs(wf).max(axis=1), 1e-8) / 127.0  # [G, Dout]
    w_q = np.clip(np.round(wf / w_scale[:, None, :]), -127, 127).astype(np.int8)

    y = grouped_matmul(
        x_q, jnp.asarray(w_q), gs,
        w_scale=jnp.asarray(w_scale),
        a_scale=a_scale if with_ascale else None,
        block_m=32, block_n=32, interpret=True,
    )
    # f32 reference over the dequantized operands
    y_ref = ref.grouped_matmul_ref(
        x_q.astype(jnp.float32) * (a_scale if with_ascale else 1.0),
        jnp.asarray(w_q.astype(np.float32) * w_scale[:, None, :]), gs,
    )
    assert y.shape == (T, Dout) and y.dtype == jnp.float32
    # kernel accumulates exactly in int32; the reference rounds per-fma in
    # f32, so the tolerance covers the *reference's* accumulation error
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-4, atol=1e-3)
    # and against the dedicated int8 oracle (exact int32 accumulation)
    y_q_ref = ref.grouped_matmul_q_ref(
        x_q, jnp.asarray(w_q), gs, jnp.asarray(w_scale),
        a_scale if with_ascale else None,
    )
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_q_ref),
                               rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# PTQ materialization + end-to-end fidelity on the paper's MoE-ViT
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def moe_vit_ptq():
    cfg = smoke_config("m3vit-small").replace(remat=False)
    shape = get_shape("train_4k").replace(seq_len=24, global_batch=2)
    params = M.init_model_params(cfg, jax.random.PRNGKey(0))
    batches = [M.synth_batch(cfg, shape, jax.random.PRNGKey(i))
               for i in range(2)]
    taps = calibrate_model(cfg, params, batches)
    return cfg, params, batches, taps


def test_int8_materialization_contract(moe_vit_ptq):
    """Quantized weight leaves are stored jnp.int8 with per-output-channel
    scale siblings and folded per-site activation scales."""
    cfg, params, batches, taps = moe_vit_ptq
    p = ptq_model(cfg, params, taps, materialize="int8")
    moe = p["pairs_moe"]["moe"]
    n_pairs = cfg.num_layers // 2
    E, D = cfg.moe.num_experts, cfg.d_model
    hid = cfg.moe.d_ff * (2 if cfg.glu else 1)
    assert moe["wi"].dtype == jnp.int8
    assert moe["wi"].shape == (n_pairs, E, D, hid)
    assert moe["wi_scale"].shape == (n_pairs, E, hid)
    assert moe["wi_as"].shape == (n_pairs,)  # folded ln2 s_tilde
    assert moe["wo"].dtype == jnp.int8
    assert moe["wo_scale"].shape == (n_pairs, E, D)
    assert moe["wo_a_scale"].shape == (n_pairs,)
    attn = p["pairs_dense"]["attn"]
    for k in ("wq", "wk", "wv", "wo"):
        assert attn[k].dtype == jnp.int8
        assert attn[k + "_scale"].dtype == jnp.float32
    for k in ("wq", "wk", "wv"):  # post-norm consumers: folded s_tilde
        assert attn[k + "_as"].shape == (n_pairs,)
    # the out-proj reuses the oracle's wo_a_scale leaf (no wo_as duplicate)
    assert "wo_as" not in attn and attn["wo_a_scale"].shape == (n_pairs,)
    assert p["head"].dtype == jnp.int8
    assert p["patch_proj"].dtype == jnp.int8  # weight-only site: no _as
    assert "patch_proj_as" not in p
    # the fake-quant oracle keeps fp leaves everywhere
    p_fake = ptq_model(cfg, params, taps)
    assert all(leaf.dtype != jnp.int8 for leaf in jax.tree.leaves(p_fake))


def test_int8_forward_matches_fake_quant_oracle(moe_vit_ptq):
    """Real-int8 execution and the quantize-dequantize simulation are the
    same computation up to accumulation-order rounding."""
    cfg, params, batches, taps = moe_vit_ptq
    qcfg = quantized_config(cfg)
    p_fake = ptq_model(cfg, params, taps)
    p_int8 = ptq_model(cfg, params, taps, materialize="int8")
    lg_fake, _ = M.forward(p_fake, qcfg, batches[0])
    lg_int8, _ = M.forward(p_int8, qcfg, batches[0])
    assert bool(jnp.isfinite(lg_int8).all())
    scale = float(jnp.std(lg_fake)) + 1e-9
    assert float(jnp.max(jnp.abs(lg_fake - lg_int8))) / scale < 1e-4


def test_fold_only_remains_fp_equivalent(moe_vit_ptq):
    """materialize= must not disturb the fold_only contract: no int8
    leaves, numerically equivalent to FP."""
    cfg, params, batches, taps = moe_vit_ptq
    p_fold = ptq_model(cfg, params, taps, fold_only=True,
                       materialize="int8")
    assert all(leaf.dtype != jnp.int8 for leaf in jax.tree.leaves(p_fold))
    lg0, _ = M.forward(params, cfg, batches[0])
    lg1, _ = M.forward(p_fold, cfg, batches[0])
    scale = float(jnp.std(lg0)) + 1e-9
    assert float(jnp.max(jnp.abs(lg0 - lg1))) / scale < 1e-3


def test_jitted_forward_materializes_no_fp_expert_copy(moe_vit_ptq):
    """The jitted moe_vit forward consumes the int8 expert stacks directly
    (grouped int8 contraction); no f32/bf16 dequantized copy of the expert
    weights appears anywhere in the program."""
    cfg, params, batches, taps = moe_vit_ptq
    qcfg = quantized_config(cfg)
    p_int8 = ptq_model(cfg, params, taps, materialize="int8")
    jaxpr = str(jax.make_jaxpr(
        lambda p, b: M.forward(p, qcfg, b)[0]
    )(p_int8, batches[0]))
    n_pairs = cfg.num_layers // 2
    E, D = qcfg.moe.num_experts, qcfg.d_model
    hid = qcfg.moe.d_ff * (2 if qcfg.glu else 1)
    fp_expert_shapes = [
        f"{dt}[{dims}]"
        for dt in ("f32", "bf16")
        for dims in (
            f"{E},{D},{hid}", f"{n_pairs},{E},{D},{hid}",
            f"{E},{qcfg.moe.d_ff},{D}", f"{n_pairs},{E},{qcfg.moe.d_ff},{D}",
        )
    ]
    leaked = [s for s in fp_expert_shapes if s in jaxpr]
    assert not leaked, f"fp dequantized expert weight copies found: {leaked}"
    # the int8 stacks themselves are consumed by the program
    assert f"i8[{n_pairs},{E},{D},{hid}]" in jaxpr
    # and the grouped contraction executes on them (ragged_dot is the
    # CPU/ref lowering of kernels.ops.grouped_matmul; TPU runs the Pallas
    # kernel, validated in interpret mode above)
    assert "ragged_dot" in jaxpr


# ---------------------------------------------------------------------------
# Serving: ServeEngine decode + build_serve_step over a QuantizedParams tree
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def moe_lm_ptq():
    cfg = smoke_config("olmoe-1b-7b").replace(remat=False)
    shape = get_shape("train_4k").replace(seq_len=24, global_batch=2)
    params = M.init_model_params(cfg, jax.random.PRNGKey(0))
    batches = [M.synth_batch(cfg, shape, jax.random.PRNGKey(i))
               for i in range(2)]
    taps = calibrate_model(cfg, params, batches)
    qcfg = quantized_config(cfg)
    return qcfg, ptq_model(cfg, params, taps), \
        ptq_model(cfg, params, taps, materialize="int8")


def test_serve_engine_decodes_int8_params(moe_lm_ptq):
    """Continuous-batching decode over the stored-int8 tree matches the
    fake-quant engine token for token (greedy)."""
    qcfg, p_fake, p_int8 = moe_lm_ptq
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, qcfg.vocab_size, n).astype(np.int32)
               for n in (5, 3)]
    outs = []
    for p in (p_int8, p_fake):
        eng = ServeEngine(qcfg, p, batch_slots=2, max_len=32)
        reqs = [Request(uid=i, prompt=pr, max_new_tokens=4)
                for i, pr in enumerate(prompts)]
        for r in reqs:
            eng.submit(r)
        eng.run_until_drained()
        outs.append([tuple(r.generated) for r in reqs])
    assert outs[0] == outs[1]


def test_build_serve_step_accepts_quantized_params(moe_lm_ptq):
    """The jitted decode step lowers and runs with int8 weight leaves and
    their scale siblings (specs fitted to the actual tree)."""
    from repro.launch.mesh import make_host_mesh

    qcfg, _, p_int8 = moe_lm_ptq
    B, S = 2, 16
    shape = get_shape("decode_32k").replace(seq_len=S, global_batch=B)
    mesh = make_host_mesh()
    step = build_serve_step(qcfg, shape, mesh, donate_cache=False,
                            params=p_int8)
    mod = M.module_for(qcfg)
    cache = mod.init_cache(qcfg, B, S, dtype=jnp.bfloat16)
    tokens = jnp.zeros((B, 1), jnp.int32)
    logits, new_cache = step(p_int8, tokens, cache,
                             jnp.zeros((), jnp.int32))
    assert logits.shape == (B, 1, qcfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
