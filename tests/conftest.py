import os

# Tests run against the pure-jnp reference path by default; kernel tests opt
# into interpret mode per-call. (Never force 512 fake devices here — smoke
# tests and benches must see the real single CPU device.)
os.environ.setdefault("REPRO_PALLAS", "ref")

import jax
import numpy as np
import pytest

jax.config.update("jax_enable_x64", False)


def requires_devices(n: int):
    """Skip (never fail) a multi-device test when the process has fewer
    devices. The CI multi-device step fakes them with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``; a plain tier-1
    run on one CPU device skips these gracefully."""
    return pytest.mark.skipif(
        jax.device_count() < n,
        reason=(
            f"needs >= {n} devices; run with "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={n}"
        ),
    )


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches():
    """Cap jit-executable accumulation across the suite (the box has one
    core and modest RAM; LLVM OOMs otherwise late in the run)."""
    yield
    jax.clear_caches()


@pytest.fixture
def rng():
    return np.random.default_rng(0)


@pytest.fixture
def key():
    return jax.random.PRNGKey(0)
