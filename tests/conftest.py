import os

# Tests run against the pure-jnp reference path by default; kernel tests opt
# into interpret mode per-call. (Never force 512 fake devices here — smoke
# tests and benches must see the real single CPU device.)
os.environ.setdefault("REPRO_PALLAS", "ref")

import jax
import numpy as np
import pytest

jax.config.update("jax_enable_x64", False)


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches():
    """Cap jit-executable accumulation across the suite (the box has one
    core and modest RAM; LLVM OOMs otherwise late in the run)."""
    yield
    jax.clear_caches()


@pytest.fixture
def rng():
    return np.random.default_rng(0)


@pytest.fixture
def key():
    return jax.random.PRNGKey(0)
