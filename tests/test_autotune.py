"""Kernel autotuner (kernels/autotune.py): cache round-trip determinism,
interpret-mode parity across every swept candidate (int8 bit-identical),
graceful stale/corrupt-cache fallback, and the warmup cache-hit contract
(second warmup on the same device kind sweeps nothing)."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import AutotuneConfig
from repro.kernels import autotune, ref
from repro.kernels.expert_linear import grouped_matmul, legal_gmm_blocks
from repro.kernels.quant_attention import (
    legal_attn_blocks,
    streaming_attention,
)


@pytest.fixture(autouse=True)
def _isolated_table():
    """Never leak an active table (process-global state) across tests."""
    autotune.deactivate()
    yield
    autotune.deactivate()


def _gmm_req(int8=True):
    dt = jnp.int8 if int8 else jnp.float32
    return autotune.gmm_request(100, 4, 32, 48, x_dtype=dt, w_dtype=dt,
                                scaled=int8, ascaled=int8)


# ---------------------------------------------------------------------------
# Tile legality (the clamp-rounding satellite)
# ---------------------------------------------------------------------------

def test_clamped_blocks_round_up_to_legal_tiles():
    # T=1 decode used to clamp to a 1-row tile; now sublane-rounded
    assert legal_gmm_blocks(128, 128, 1, 48, jnp.float32) == (8, 128)
    assert legal_gmm_blocks(128, 128, 1, 48, jnp.bfloat16) == (16, 128)
    assert legal_gmm_blocks(128, 128, 1, 48, jnp.int8) == (32, 128)
    assert legal_gmm_blocks(256, 300, 1000, 300, jnp.float32) == (256, 384)
    assert legal_attn_blocks(128, 256, 1, 16) == (8, 128)
    assert legal_attn_blocks(128, 256, 1, 16, jnp.bfloat16) == (16, 128)
    assert legal_attn_blocks(48, 200, 1000, 1000) == (48, 256)


def test_decode_shaped_grouped_matmul_still_exact(rng):
    """T=1 (the shape the old clamp made a 1-row tile for)."""
    x = jnp.asarray(rng.standard_normal((1, 16)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((4, 16, 24)), jnp.float32)
    gs = jnp.asarray([0, 1, 0, 0], jnp.int32)
    y = grouped_matmul(x, w, gs, interpret=True)
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(ref.grouped_matmul_ref(x, w, gs)),
                               rtol=1e-5, atol=1e-5)


def test_candidate_grids_are_legal_and_default_first():
    req = _gmm_req(int8=True)
    cands = autotune.gmm_candidates(req)
    assert cands[0] == legal_gmm_blocks(*autotune.GMM_DEFAULT, req.get("T"),
                                        req.get("dout"), jnp.int8)
    for bm, bn in cands:
        assert bm % 32 == 0 and bn % 128 == 0  # int8 sublane + lane
    areq = autotune.attn_request(2, 2, 2, 32, 8, 64, causal=True,
                                 quant_bits=0, scaled=False,
                                 q_dtype=jnp.float32, k_dtype=jnp.float32)
    acands = autotune.attn_candidates(areq)
    assert acands[0] == legal_attn_blocks(*autotune.ATTN_DEFAULT, 8, 64)
    for bq, bk in acands:
        assert bq % 8 == 0 and bk % 128 == 0
    # candidate lists are deduped
    assert len(set(cands)) == len(cands)
    assert len(set(acands)) == len(acands)


# ---------------------------------------------------------------------------
# Interpret-mode parity across every swept candidate
# ---------------------------------------------------------------------------

def test_int8_gmm_bit_identical_across_all_candidates(rng):
    """Tile choice is a layout decision only: the int8 contraction is
    exact, so every candidate config must produce the *bit-identical*
    output the default config produces."""
    req = _gmm_req(int8=True)
    T, G, Din, Dout = 100, 4, 32, 48
    x = jnp.asarray(rng.integers(-127, 128, (T, Din)), jnp.int8)
    w = jnp.asarray(rng.integers(-127, 128, (G, Din, Dout)), jnp.int8)
    gs = jnp.asarray([40, 0, 25, 35], jnp.int32)
    ws = jnp.asarray(rng.uniform(0.01, 0.1, (G, Dout)), jnp.float32)
    a = jnp.float32(0.037)
    outs = {}
    for blocks in autotune.gmm_candidates(req):
        y = grouped_matmul(x, w, gs, w_scale=ws, a_scale=a,
                           block_m=blocks[0], block_n=blocks[1],
                           interpret=True)
        outs[blocks] = np.asarray(y)
    base = outs[autotune.gmm_candidates(req)[0]]
    for blocks, y in outs.items():
        np.testing.assert_array_equal(y, base, err_msg=str(blocks))


def test_attention_parity_across_all_candidates(rng):
    """fp accumulation order shifts with block_k, so allclose (not
    bit-identical) across the candidate grid; int8 K/V + 4-bit codes."""
    req = autotune.attn_request(2, 2, 2, 32, 16, 48, causal=True,
                                quant_bits=4, scaled=True,
                                q_dtype=jnp.float32, k_dtype=jnp.int8)
    B, Sq, Sk, H, hd = 2, 16, 48, 2, 32
    q = jnp.asarray(rng.standard_normal((B, Sq, H, hd)), jnp.float32)
    k = jnp.asarray(rng.integers(-127, 128, (B, Sk, H, hd)), jnp.int8)
    v = jnp.asarray(rng.integers(-127, 128, (B, Sk, H, hd)), jnp.int8)
    ks = jnp.asarray(rng.uniform(0.01, 0.05, (B, Sk, H)), jnp.float32)
    vs = jnp.asarray(rng.uniform(0.01, 0.05, (B, Sk, H)), jnp.float32)
    offs = jnp.full((B,), Sk - Sq, jnp.int32)
    base = None
    for blocks in autotune.attn_candidates(req):
        y = np.asarray(streaming_attention(
            q, k, v, causal=True, q_offset=offs, quant_bits=4,
            k_scale=ks, v_scale=vs, block_q=blocks[0], block_k=blocks[1],
            interpret=True))
        if base is None:
            base = y
        np.testing.assert_allclose(y, base, atol=1e-5, err_msg=str(blocks))


# ---------------------------------------------------------------------------
# Table persistence: round trip, corrupt, stale
# ---------------------------------------------------------------------------

def test_table_round_trip_is_deterministic(tmp_path):
    path = str(tmp_path / "t.json")
    t = autotune.TuningTable("cpu", path)
    t.put("grouped_matmul|T=64|x", (64, 128), 1.25, "swept")
    t.put("streaming_attention|sq=8|y", (8, 256), None, "default")
    t.save()
    t2 = autotune.TuningTable.load(path, "cpu")
    assert t2.entries == t.entries
    assert t2.stats == {"hits": 0, "misses": 0, "swept": 0}
    t2.save()  # second save round-trips byte-identically
    assert autotune.TuningTable.load(path, "cpu").entries == t.entries


def test_corrupt_cache_falls_back_to_empty(tmp_path):
    path = str(tmp_path / "t.json")
    with open(path, "w") as f:
        f.write("{this is not json")
    t = autotune.TuningTable.load(path, "cpu")
    assert t.entries == {}
    t.put("grouped_matmul|k", (128, 128), None, "default")
    t.save()  # save over the corrupt file works
    assert autotune.TuningTable.load(path, "cpu").entries != {}


def test_stale_kernel_version_and_foreign_device_dropped(tmp_path):
    path = str(tmp_path / "t.json")
    t = autotune.TuningTable("cpu", path)
    t.put("grouped_matmul|a", (64, 128), 1.0, "swept")
    t.put("streaming_attention|b", (64, 256), 2.0, "swept")
    raw = t.to_json()
    raw["kernel_versions"]["grouped_matmul"] -= 1  # stale gmm entries
    with open(path, "w") as f:
        json.dump(raw, f)
    t2 = autotune.TuningTable.load(path, "cpu")
    assert "grouped_matmul|a" not in t2.entries
    assert "streaming_attention|b" in t2.entries
    # device-kind mismatch discards everything
    assert autotune.TuningTable.load(path, "TPU v4").entries == {}
    # malformed entry blocks are dropped, not fatal
    raw = t.to_json()
    raw["entries"]["grouped_matmul|a"]["blocks"] = "nope"
    with open(path, "w") as f:
        json.dump(raw, f)
    t3 = autotune.TuningTable.load(path, "cpu")
    assert "grouped_matmul|a" not in t3.entries


# ---------------------------------------------------------------------------
# Sweep selection + ops threading
# ---------------------------------------------------------------------------

def test_sweep_picks_fastest_candidate_with_injected_timer():
    req = _gmm_req(int8=True)
    want = autotune.gmm_candidates(req)[2]
    timer = lambda fn, blocks, reps=1: 1.0 if blocks == want else 5.0
    entry = autotune.sweep_request(req, AutotuneConfig(budget=32), timer=timer)
    assert tuple(entry["blocks"]) == want
    assert entry["source"] == "swept" and entry["ms"] == 1.0


def test_sweep_without_tpu_returns_deterministic_defaults():
    req = _gmm_req(int8=False)
    e1 = autotune.sweep_request(req, AutotuneConfig())
    e2 = autotune.sweep_request(req, AutotuneConfig())
    assert e1 == e2
    assert e1["source"] == "default" and e1["ms"] is None
    assert tuple(e1["blocks"]) == autotune.gmm_candidates(req)[0]


def test_active_table_threads_blocks_into_kernel(monkeypatch):
    """An override entry for a shape bucket must reach the Pallas kernel's
    block_m/block_n arguments through kernels.ops."""
    import repro.kernels.expert_linear as el
    from repro.kernels import ops

    monkeypatch.setenv("REPRO_PALLAS", "interpret")
    T, G, Din, Dout = 20, 4, 32, 48
    req = autotune.gmm_request(T, G, Din, Dout, x_dtype=jnp.float32,
                               w_dtype=jnp.float32, scaled=False,
                               ascaled=False)
    table = autotune.TuningTable("cpu")
    table.put(req.key, (64, 256), None, "override")
    seen = {}
    orig = el.grouped_matmul

    def spy(*a, **kw):
        seen["blocks"] = (kw.get("block_m"), kw.get("block_n"))
        return orig(*a, **kw)

    monkeypatch.setattr(el, "grouped_matmul", spy)
    x = jnp.ones((T, Din), jnp.float32)
    w = jnp.ones((G, Din, Dout), jnp.float32)
    gs = jnp.asarray([5, 5, 5, 5], jnp.int32)
    autotune.activate(table)
    ops.grouped_matmul(x, w, gs)
    assert seen["blocks"] == (64, 256)
    autotune.deactivate()
    ops.grouped_matmul(x, w, gs)
    assert seen["blocks"] == autotune.GMM_DEFAULT


# ---------------------------------------------------------------------------
# Warmup integration: collect -> fill -> pure cache hit
# ---------------------------------------------------------------------------

def _tiny_lm_cfg(tmp_path):
    import repro.models as M
    from repro.configs import smoke_config

    cfg = smoke_config("olmoe-1b-7b").replace(
        remat=False, num_layers=2,
        autotune=AutotuneConfig(enable=True, cache_dir=str(tmp_path)))
    params = M.init_model_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_warmup_tunes_then_second_warmup_is_pure_cache_hit(
        tmp_path, monkeypatch):
    """Acceptance: warmup collects this replica's kernel keys and fills the
    table; a second warmup (same engine, a fresh engine, or a table
    reloaded from disk) sweeps nothing."""
    monkeypatch.setenv("REPRO_PALLAS", "interpret")
    from repro.serving.engine import ServeEngine

    cfg, params = _tiny_lm_cfg(tmp_path)
    eng = ServeEngine(cfg, params, batch_slots=2, max_len=16)
    eng.warmup()
    table = autotune.active_table()
    assert table is not None
    swept = table.stats["swept"]
    assert swept > 0  # decode + prefill keys for both kernels
    assert any(k.startswith("grouped_matmul|") for k in table.entries)
    assert any(k.startswith("streaming_attention|") for k in table.entries)
    assert os.path.exists(autotune.table_path(cfg.autotune))

    eng.warmup()  # same engine again
    assert table.stats["swept"] == swept, "re-sweep on warm table"

    eng2 = ServeEngine(cfg, params, batch_slots=2, max_len=16)
    eng2.warmup()  # fresh replica, same device kind
    assert table.stats["swept"] == swept

    autotune.deactivate()  # simulate a new process: reload from disk
    eng3 = ServeEngine(cfg, params, batch_slots=2, max_len=16)
    eng3.warmup()
    t2 = autotune.active_table()
    assert t2 is not table and t2.stats["swept"] == 0
    assert t2.entries == table.entries


def test_warmup_survives_corrupt_cache_file(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_PALLAS", "interpret")
    from repro.serving.engine import ServeEngine

    cfg, params = _tiny_lm_cfg(tmp_path)
    path = autotune.table_path(cfg.autotune)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        f.write("]]corrupt[[")
    eng = ServeEngine(cfg, params, batch_slots=2, max_len=16)
    eng.warmup()  # no raise: rebuilt from scratch
    assert autotune.active_table().stats["swept"] > 0
    assert autotune.TuningTable.load(path, autotune.device_kind()).entries


def test_overrides_take_precedence_and_persist(tmp_path):
    req = _gmm_req(int8=False)
    cfg = AutotuneConfig(enable=True, cache_dir=str(tmp_path),
                         overrides=((req.key, (64, 256)),))
    table = autotune.ensure_tuned(cfg, None)
    assert table.get(req.key) == {"blocks": [64, 256], "ms": None,
                                  "source": "override"}
    reloaded = autotune.TuningTable.load(autotune.table_path(cfg),
                                         autotune.device_kind())
    assert reloaded.get(req.key)["source"] == "override"


def test_ensure_tuned_disabled_is_inert(tmp_path):
    cfg = AutotuneConfig(enable=False, cache_dir=str(tmp_path))
    assert autotune.ensure_tuned(cfg, None) is None
    assert not os.listdir(tmp_path)
