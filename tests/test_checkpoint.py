"""CheckpointManager: atomicity, GC, async error surfacing, re-mesh restore."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "params": {"w": jnp.asarray(rng.standard_normal((4, 8)), jnp.float32),
                   "b": jnp.asarray(rng.standard_normal(8), jnp.bfloat16)},
        "opt": [jnp.zeros((3,), jnp.int32), jnp.ones((2, 2))],
        "step": jnp.asarray(7, jnp.int32),
    }


def test_roundtrip_bit_exact(tmp_path):
    m = CheckpointManager(str(tmp_path))
    t = _tree()
    m.save(7, t, blocking=True)
    r = m.restore(t)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(r)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_keep_last_k(tmp_path):
    m = CheckpointManager(str(tmp_path), keep_last_k=2)
    t = _tree()
    for s in (1, 2, 3, 4):
        m.save(s, t, blocking=True)
    assert m.steps() == [3, 4]


def test_atomic_no_tmp_left_behind(tmp_path):
    m = CheckpointManager(str(tmp_path))
    m.save(1, _tree(), blocking=True)
    assert not [d for d in os.listdir(tmp_path) if d.endswith(".tmp")]
    # manifest carries global shapes
    man = json.load(open(tmp_path / "step_00000001" / "manifest.json"))
    assert man["leaves"]["params/w"]["shape"] == [4, 8]


def test_restore_latest_and_specific(tmp_path):
    m = CheckpointManager(str(tmp_path))
    t1, t2 = _tree(1), _tree(2)
    m.save(1, t1, blocking=True)
    m.save(2, t2, blocking=True)
    np.testing.assert_array_equal(
        np.asarray(m.restore(t1)["params"]["w"]),
        np.asarray(t2["params"]["w"]))
    np.testing.assert_array_equal(
        np.asarray(m.restore(t1, step=1)["params"]["w"]),
        np.asarray(t1["params"]["w"]))


def test_restore_onto_sharding(tmp_path):
    """Elastic re-mesh: restore places global arrays on the current mesh."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.launch.mesh import make_host_mesh

    m = CheckpointManager(str(tmp_path))
    t = _tree()
    m.save(1, t, blocking=True)
    mesh = make_host_mesh()
    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), t)
    r = m.restore(t, shardings=sh)
    assert r["params"]["w"].sharding == NamedSharding(mesh, P())


def test_quantized_tree_roundtrip_and_structure_free_restore(tmp_path):
    """A QuantizedParams tree (int8 weights + f32 scale siblings) keeps
    exact dtypes on disk, and ``restore(None)`` rebuilds the nested tree
    from the manifest alone — no abstract-param template describes a PTQ'd
    structure."""
    rng = np.random.default_rng(0)
    tree = {
        "layers": {
            "attn": {
                "wq": jnp.asarray(
                    rng.integers(-128, 128, (2, 8, 8)), jnp.int8),
                "wq_scale": jnp.asarray(rng.random((2, 8)), jnp.float32),
                "wq_as": jnp.asarray(rng.random(2), jnp.float32),
            },
            "ln1": {"scale": jnp.ones((2, 8)), "a_scale": jnp.ones((2,))},
        },
        "head": jnp.asarray(rng.integers(-128, 128, (8, 4)), jnp.int8),
        "head_scale": jnp.asarray(rng.random(4), jnp.float32),
    }
    m = CheckpointManager(str(tmp_path))
    m.save(1, tree, blocking=True)
    for restored in (m.restore(tree), m.restore(None)):
        flat_t = {k: v for k, v in _flatten_pairs(tree)}
        flat_r = {k: v for k, v in _flatten_pairs(restored)}
        assert flat_t.keys() == flat_r.keys()
        for k in flat_t:
            assert flat_t[k].dtype == flat_r[k].dtype, k
            np.testing.assert_array_equal(
                np.asarray(flat_t[k]), np.asarray(flat_r[k]))
    # int8 leaves are stored int8 (1 byte/param) on disk
    arr = np.load(tmp_path / "step_00000001" / "layers__attn__wq.npy")
    assert arr.dtype == np.int8


def test_int4_packed_tree_roundtrip_and_structure_free_restore(tmp_path):
    """A mixed int4/int8 QuantizedParams tree — nibble-packed ``uint8``
    expert stacks next to int8 sensitive sites and f32 scale siblings —
    keeps exact dtypes and bytes on disk, and ``restore(None)`` rebuilds it
    from the manifest alone (serving loads PTQ'd trees without a template)."""
    from repro.core.quant.qtypes import pack_int4

    rng = np.random.default_rng(0)
    q = rng.integers(-8, 8, (2, 4, 7, 8)).astype(np.int8)  # odd Din: pad row
    tree = {
        "moe": {
            "wi": pack_int4(jnp.asarray(q)),
            "wi_scale": jnp.asarray(rng.random((2, 4, 8)), jnp.float32),
            "wi_as": jnp.asarray(rng.random(2), jnp.float32),
            "gate": jnp.asarray(rng.integers(-128, 128, (2, 8, 4)), jnp.int8),
            "gate_scale": jnp.asarray(rng.random((2, 4)), jnp.float32),
        },
    }
    assert tree["moe"]["wi"].dtype == jnp.uint8
    m = CheckpointManager(str(tmp_path))
    m.save(1, tree, blocking=True)
    for restored in (m.restore(tree), m.restore(None)):
        flat_t = dict(_flatten_pairs(tree))
        flat_r = dict(_flatten_pairs(restored))
        assert flat_t.keys() == flat_r.keys()
        for k in flat_t:
            assert flat_t[k].dtype == flat_r[k].dtype, k
            np.testing.assert_array_equal(
                np.asarray(flat_t[k]), np.asarray(flat_r[k]))
    # packed leaves are stored uint8 (two weights per byte) on disk
    arr = np.load(tmp_path / "step_00000001" / "moe__wi.npy")
    assert arr.dtype == np.uint8 and arr.shape == (2, 4, 4, 8)


def _flatten_pairs(tree, prefix=""):
    if isinstance(tree, dict):
        for k, v in tree.items():
            yield from _flatten_pairs(v, f"{prefix}{k}/")
    else:
        yield prefix[:-1], tree


def test_async_save_overlaps_and_waits(tmp_path):
    m = CheckpointManager(str(tmp_path))
    t = _tree()
    m.save(1, t)  # non-blocking
    m.wait()
    assert m.latest_step() == 1
