"""Data pipeline determinism + sharding-rule resolution."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

import repro.models as M
from repro.configs import ASSIGNED, get_shape, smoke_config, get_config
from repro.data import SyntheticPipeline
from repro.distributed.sharding_rules import (
    DEFAULT_RULES,
    opt_state_specs,
    param_specs,
    spec_for_axes,
)

SHAPE = get_shape("train_4k").replace(seq_len=32, global_batch=8)


# ---------------------------------------------------------------------------
# Data pipeline
# ---------------------------------------------------------------------------

def test_pipeline_deterministic_across_instances():
    cfg = smoke_config("llama3-8b")
    a = SyntheticPipeline(cfg, SHAPE, seed=1).batch_for_step(17)
    b = SyntheticPipeline(cfg, SHAPE, seed=1).batch_for_step(17)
    for k in a:
        np.testing.assert_array_equal(a[k], b[k])


def test_pipeline_steps_differ():
    cfg = smoke_config("llama3-8b")
    p = SyntheticPipeline(cfg, SHAPE, seed=1)
    assert not np.array_equal(p.batch_for_step(0)["tokens"],
                              p.batch_for_step(1)["tokens"])


def test_pipeline_host_slices_differ_and_split_batch():
    cfg = smoke_config("llama3-8b")
    g = SyntheticPipeline(cfg, SHAPE, seed=1)
    h0 = SyntheticPipeline(cfg, SHAPE, seed=1, host_id=0, num_hosts=2)
    h1 = SyntheticPipeline(cfg, SHAPE, seed=1, host_id=1, num_hosts=2)
    b0, b1 = h0.batch_for_step(3), h1.batch_for_step(3)
    assert b0["tokens"].shape[0] == SHAPE.global_batch // 2
    assert not np.array_equal(b0["tokens"], b1["tokens"])


def test_bigram_task_is_learnable_structure():
    """90% of transitions follow the fixed permutation (the signal a
    trained bigram model exploits)."""
    cfg = smoke_config("llama3-8b")
    p = SyntheticPipeline(cfg, SHAPE, seed=0)
    b = p.batch_for_step(0)
    toks, labels = b["tokens"], b["labels"]
    follows = p._perm[toks] == labels
    assert 0.8 < follows.mean() < 0.99


def test_labels_are_next_tokens():
    cfg = smoke_config("llama3-8b")
    b = SyntheticPipeline(cfg, SHAPE, seed=0).batch_for_step(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


# ---------------------------------------------------------------------------
# Sharding rules
# ---------------------------------------------------------------------------

def test_spec_dedupes_mesh_axes():
    # MoE expert tensor: expert wins 'model', mlp degrades to None
    spec = spec_for_axes(("layers", "expert", "embed", "mlp"))
    assert spec == P(None, "model", "data", None)


def test_spec_respects_divisibility():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    # vocab 256206 is not divisible by the model axis in a 16x16 mesh; here
    # axis size is 1 so anything divides — exercise the code path
    spec = spec_for_axes(("vocab", "embed"), shape=(256206, 1024), mesh=mesh)
    assert spec == P("model", "data")


@pytest.mark.parametrize("arch", sorted(ASSIGNED))
def test_param_specs_tree_matches_param_tree(arch):
    cfg = smoke_config(arch)
    specs = param_specs(cfg)
    shapes = M.model_param_shapes(cfg)
    jax.tree.map(lambda s, sh: None, specs, shapes,
                 is_leaf=lambda x: isinstance(x, P))  # same structure
    for s, sh in zip(
        jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P)),
        jax.tree.leaves(shapes),
    ):
        assert isinstance(s, P)
        assert len(s) <= len(sh.shape)


def test_opt_state_specs_inherit_param_spec():
    from repro.optim import adamw, adafactor, constant

    cfg = smoke_config("llama3-8b")
    p_specs = param_specs(cfg)
    p_shapes = M.model_param_shapes(cfg)
    opt = adamw(constant(1e-3))
    o_specs = opt.state_specs(p_specs, p_shapes)
    # m/v trees mirror the param specs exactly (ZeRO sharding)
    for a, b in zip(
        jax.tree.leaves(o_specs["m"], is_leaf=lambda x: isinstance(x, P)),
        jax.tree.leaves(p_specs, is_leaf=lambda x: isinstance(x, P)),
    ):
        assert a == b

    fct = adafactor(constant(1e-3))
    f_specs = fct.state_specs(p_specs, p_shapes)
    # structure matches the real state; factored leaves replicate
    f_state = jax.eval_shape(fct.init, p_shapes)
    jax.tree.map(lambda spec, sh: None, f_specs, f_state,
                 is_leaf=lambda x: isinstance(x, P))
    leaves = jax.tree.leaves(f_specs, is_leaf=lambda x: isinstance(x, P))
    assert all(isinstance(s, P) for s in leaves)


def test_input_shardings_match_input_specs_structure():
    from repro.distributed.sharding_rules import input_shardings

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    for arch in ("llama3-8b", "falcon-mamba-7b", "seamless-m4t-medium"):
        cfg = get_config(arch)
        for shape_name in ("train_4k", "decode_32k"):
            from repro.configs import get_shape, shape_applicable

            shape = get_shape(shape_name)
            if not shape_applicable(cfg, shape)[0]:
                continue
            tree = M.input_specs(cfg, shape)
            specs = input_shardings(cfg, shape, mesh, tree)
            assert set(specs) == set(tree)
