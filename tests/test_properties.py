"""Hypothesis property tests on system invariants: the grouped-matmul work
router, sharding-spec fitting, the ring cache, and the chunked scan."""
import jax
import jax.numpy as jnp
import numpy as np
from hyp_compat import given, settings, st

from repro.kernels.expert_linear import _route_metadata


# ---------------------------------------------------------------------------
# Work-item router (the megablox-style "RR router table")
# ---------------------------------------------------------------------------

@settings(max_examples=60, deadline=None)
@given(st.lists(st.integers(0, 200), min_size=1, max_size=12),
       st.sampled_from([8, 32, 128]))
def test_route_metadata_covers_every_row_exactly_once(sizes, block_m):
    """Every sorted token row is claimed by exactly one active work item of
    its own group, and out-tile visits are contiguous (flush correctness)."""
    G = len(sizes)
    T = sum(sizes)
    n_m = max(-(-T // block_m), 1)
    n_work = n_m + G
    g_ids, m_ids, rs, re = _route_metadata(
        jnp.asarray(sizes, jnp.int32), block_m, n_work)
    g_ids, m_ids = np.asarray(g_ids), np.asarray(m_ids)
    rs, re = np.asarray(rs), np.asarray(re)
    starts = np.cumsum([0] + sizes)[:-1]
    claimed = np.zeros(T, np.int32)
    for w in range(n_work):
        lo = max(rs[w], m_ids[w] * block_m)
        hi = min(re[w], (m_ids[w] + 1) * block_m)
        if lo < hi:
            # the work item's row range must lie inside its group
            assert rs[w] == starts[g_ids[w]]
            claimed[lo:hi] += 1
    assert (claimed == 1).all(), "row coverage must be exactly once"
    # m_ids non-decreasing => all visits to one out tile are consecutive
    assert (np.diff(m_ids) >= 0).all()


# ---------------------------------------------------------------------------
# Sharding-spec fitting
# ---------------------------------------------------------------------------

@settings(max_examples=60, deadline=None)
@given(st.lists(st.integers(1, 4096), min_size=1, max_size=4),
       st.lists(st.sampled_from(
           ["embed", "vocab", "mlp", "expert", None]), min_size=1, max_size=4))
def test_spec_never_produces_nondivisible_sharding(dims, axes):
    from repro.distributed.sharding_rules import spec_for_axes

    n = min(len(dims), len(axes))
    dims, axes = tuple(dims[:n]), tuple(axes[:n])
    mesh = jax.make_mesh((1, 1), ("data", "model"))

    class FakeMesh:
        shape = {"data": 16, "model": 16}

    spec = spec_for_axes(axes, shape=dims, mesh=FakeMesh())
    sizes = {"data": 16, "model": 16}
    used = []
    for dim, entry in zip(dims, tuple(spec)):
        if entry is None:
            continue
        assert dim % sizes[entry] == 0
        used.append(entry)
    assert len(used) == len(set(used)), "mesh axis reused in one spec"


# ---------------------------------------------------------------------------
# Ring cache == full cache within the window
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(st.integers(2, 6), st.integers(1, 8))
def test_ring_cache_equals_full_history_attention(extra, prompt_len):
    """For any decode position past the window, ring attention equals
    attention over the last `window` positions of a full cache."""
    from repro.configs import smoke_config
    import repro.models as M

    cfg = smoke_config("gemma2-2b").replace(remat=False)
    W = cfg.attn.local_window
    mod = M.module_for(cfg)
    params = M.init_model_params(cfg, jax.random.PRNGKey(0))
    S = W + extra
    tok = jax.random.randint(jax.random.PRNGKey(extra), (1, S), 0,
                             cfg.vocab_size)
    full, _ = mod.forward(params, cfg, tok)
    lg, cache = mod.prefill(params, cfg, tok[:, :prompt_len], max_len=S)
    for t in range(prompt_len, S):
        lg, cache = mod.decode_step(params, cfg, tok[:, t:t + 1], cache,
                                    jnp.asarray(t, jnp.int32))
    np.testing.assert_allclose(np.asarray(lg[:, 0]),
                               np.asarray(full[:, S - 1]),
                               rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# Chunked recurrence == reference for arbitrary chunk sizes
# ---------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(st.integers(1, 50), st.integers(1, 16))
def test_chunked_recurrence_any_chunk_size(S, chunk):
    from repro.models import ssm

    rng = np.random.default_rng(S * 100 + chunk)
    a = jnp.asarray(rng.uniform(0.5, 1.0, (1, S, 3)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((1, S, 3)), jnp.float32)
    h_ref = ssm.linear_recurrence(a, b)
    # pad with identity (a=1, b=0) like the model does
    nch = -(-S // chunk)
    pad = nch * chunk - S
    ap = jnp.pad(a, ((0, 0), (0, pad), (0, 0)), constant_values=1.0)
    bp = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))

    def body(h0, sl):
        h, hl = ssm._chunk_recurrence(sl[0], sl[1], h0)
        return hl, h

    hl, hs = jax.lax.scan(
        body, jnp.zeros((1, 3)),
        (ssm._pad_chunks(ap, chunk), ssm._pad_chunks(bp, chunk)))
    h_chunk = jnp.moveaxis(hs, 0, 1).reshape(1, -1, 3)[:, :S]
    np.testing.assert_allclose(h_chunk, h_ref, atol=2e-5)
    np.testing.assert_allclose(hl, h_ref[:, -1], atol=2e-5)


# ---------------------------------------------------------------------------
# Checkpoint flatten/unflatten is a bijection over mixed trees
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(st.recursive(
    st.sampled_from([0, 1, 2]),
    lambda children: st.dictionaries(
        st.sampled_from(["a", "b", "c"]), children, min_size=1, max_size=3),
    max_leaves=8,
))
def test_checkpoint_flatten_roundtrip(tree_shape):
    from repro.checkpoint.manager import _flatten, _unflatten_into

    counter = [0]

    def build(t):
        if isinstance(t, dict):
            return {k: build(v) for k, v in t.items()}
        counter[0] += 1
        return np.full((2,), counter[0], np.int32)

    tree = build(tree_shape)
    flat = _flatten(tree)
    rebuilt = _unflatten_into(tree, flat)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(rebuilt)):
        np.testing.assert_array_equal(a, b)
