"""Continuous-batching serving path (DESIGN.md section 10): packed-prefill
parity with solo runs (fp32, int8 fake-quant, EP on 8 fake devices), AOT
warmup (zero retraces in steady state), QoS deadline cancellation, and the
admission-safety contract (unservable prompts rejected at submit)."""
import dataclasses
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.models as M
from repro.configs import smoke_config
from repro.serving.cluster import replica_meshes
from repro.serving.engine import Request, ServeEngine
from repro.serving.metrics import EngineMetrics

from conftest import requires_devices


class FakeClock:
    def __init__(self, t: float = 0.0) -> None:
        self.t = t

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


def _greedy_reference(cfg, params, prompt, n_new):
    """Teacher-forced re-run per token: the slowest correct generation."""
    mod = M.module_for(cfg)
    toks = list(map(int, prompt))
    out = []
    for _ in range(n_new):
        logits, _ = mod.forward(params, cfg, jnp.asarray([toks], jnp.int32))
        nxt = int(jnp.argmax(logits[0, -1]))
        out.append(nxt)
        toks.append(nxt)
    return out


def _mixed_prompts(cfg, lengths, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, L).astype(np.int32)
            for L in lengths]


def _serve(cfg, params, prompts, n_new, **kw):
    eng = ServeEngine(cfg, params, **kw)
    assert eng._packed, "packed path must engage for this family"
    reqs = [Request(uid=i, prompt=p, max_new_tokens=n_new)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_drained()
    return eng, reqs


@pytest.mark.parametrize("quant", [False, True],
                         ids=["fp32", "int8-fakequant"])
def test_packed_mixed_length_parity(quant):
    """Mixed-length prompts admitted through ONE packed dispatch reproduce
    each prompt's solo teacher-forced generation exactly — segment masking,
    within-segment RoPE, and the scatter-merge into decode slots leak
    nothing across prompts, in fp32 and through the quantized path."""
    cfg = smoke_config("llama3-8b").replace(remat=False)
    if quant:
        cfg = cfg.replace(quant=dataclasses.replace(cfg.quant, enable=True))
    params = M.init_model_params(cfg, jax.random.PRNGKey(0))
    prompts = _mixed_prompts(cfg, (4, 11, 7, 9), seed=13)
    # sequential baseline: ONE prompt at a time through the same engine —
    # the identical decode program, so any difference is packing leakage
    # (teacher-forced full re-runs differ by summation order and flip
    # near-tie argmaxes on random smoke weights)
    solo_eng = ServeEngine(cfg, params, batch_slots=4, max_len=32)
    solo = []
    for i, p in enumerate(prompts):
        req = Request(uid=100 + i, prompt=p, max_new_tokens=3)
        solo_eng.submit(req)
        solo_eng.run_until_drained()
        solo.append(req.generated[:3])
    eng, reqs = _serve(cfg, params, prompts, 3, batch_slots=4, max_len=32)
    assert eng.metrics.counters["prefill_batches"] == 1
    assert solo_eng.metrics.counters["prefill_batches"] == len(prompts)
    for i, r in enumerate(reqs):
        assert r.generated[:3] == solo[i], f"request {i}"


@requires_devices(8)
def test_packed_parity_under_expert_parallel_mesh():
    """Packed prefill through an 8-way expert-parallel mesh: the sharded
    all_to_all MoE dispatch inside the packed program matches the
    single-device grouped execution token for token."""
    base = smoke_config("olmoe-1b-7b").replace(remat=False)
    params = M.init_model_params(base, jax.random.PRNGKey(0))
    prompts = _mixed_prompts(base, (5, 9, 6), seed=3)
    ep_cfg = base.replace(
        moe=dataclasses.replace(base.moe, moe_exec="expert_parallel"))
    mesh = replica_meshes(1)[0]
    assert mesh.shape["model"] == jax.device_count()
    solo_eng = ServeEngine(ep_cfg, params, batch_slots=4, max_len=32,
                           mesh=mesh)
    solo = []
    for i, p in enumerate(prompts):
        req = Request(uid=100 + i, prompt=p, max_new_tokens=3)
        solo_eng.submit(req)
        solo_eng.run_until_drained()
        solo.append(req.generated[:3])
    eng, reqs = _serve(ep_cfg, params, prompts, 3,
                       batch_slots=4, max_len=32, mesh=mesh)
    assert eng.metrics.counters["prefill_batches"] == 1
    assert solo_eng.metrics.counters["prefill_batches"] == len(prompts)
    for i, r in enumerate(reqs):
        assert r.generated[:3] == solo[i], f"request {i}"


def test_warmup_compiles_everything_zero_retraces():
    """After warmup() every serving-path program is an AOT cache hit: the
    ``retraces`` counter stays 0 across mixed-length admission waves and
    the whole decode, and warmup populated the full program grid (decode
    tick + every prefill-bucket x prompt-count pairing)."""
    cfg = smoke_config("llama3-8b").replace(remat=False)
    params = M.init_model_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, batch_slots=4, max_len=32)
    eng.warmup()
    want = 1 + len(eng._buckets) * len(eng._nb_ladder)
    assert len(eng._programs) == want, (len(eng._programs), want)
    prompts = _mixed_prompts(cfg, (3, 12, 5, 8, 6, 10), seed=7)
    for i, p in enumerate(prompts):
        eng.submit(Request(uid=i, prompt=p, max_new_tokens=4))
    eng.run_until_drained()
    assert eng.metrics.counters.get("retraces", 0) == 0
    assert eng.metrics.counters["completed"] == len(prompts)


def test_deadline_drops_queued_request():
    """A request whose deadline expires while it still waits in the
    admission queue is retired as cancelled without touching the device;
    its on_done callback still fires."""
    clk = FakeClock()
    cfg = smoke_config("llama3-8b").replace(remat=False)
    params = M.init_model_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, batch_slots=1, max_len=32, clock=clk)
    rng = np.random.default_rng(0)
    done = []
    r0 = Request(uid=0, prompt=rng.integers(0, cfg.vocab_size, 5)
                 .astype(np.int32), max_new_tokens=4)
    r1 = Request(uid=1, prompt=rng.integers(0, cfg.vocab_size, 5)
                 .astype(np.int32), max_new_tokens=4,
                 deadline=0.5, on_done=done.append)
    eng.submit(r0)
    eng.submit(r1)
    eng.step()  # r0 takes the only decode slot; r1 queues
    assert len(eng.active) == 1 and eng.scheduler.depth == 1
    clk.advance(1.0)  # r1's deadline passes while queued
    eng.run_until_drained()
    assert r0.generated is not None and len(r0.generated) == 4
    assert r1.generated == [], "cancelled request must never prefill"
    assert eng.metrics.counters["cancelled"] == 1
    assert eng.metrics.counters["completed"] == 1
    assert done == [r1], "on_done fires for cancelled requests too"


def test_deadline_cancels_mid_generation():
    """A deadline that passes mid-decode frees the slot on the next tick:
    the stream stops short, the request counts as cancelled, and the freed
    slot immediately serves the next queued prompt."""
    clk = FakeClock()
    cfg = smoke_config("llama3-8b").replace(remat=False)
    params = M.init_model_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, batch_slots=1, max_len=64, clock=clk)
    rng = np.random.default_rng(1)
    r0 = Request(uid=0, prompt=rng.integers(0, cfg.vocab_size, 5)
                 .astype(np.int32), max_new_tokens=40, deadline=0.5)
    r1 = Request(uid=1, prompt=rng.integers(0, cfg.vocab_size, 5)
                 .astype(np.int32), max_new_tokens=3)
    eng.submit(r0)
    eng.submit(r1)
    for _ in range(3):
        eng.step()
    assert 0 < len(r0.generated) < 40, "r0 must be mid-generation"
    clk.advance(1.0)  # r0's deadline passes with the slot occupied
    eng.step()
    assert not any(req.uid == 0 for req in eng.active.values()), \
        "expired request must release its decode slot"
    eng.run_until_drained()
    assert len(r0.generated) < 40
    assert r1.generated is not None and len(r1.generated) == 3
    assert eng.metrics.counters["cancelled"] == 1
    assert eng.metrics.counters["completed"] == 1


def test_eos_frees_slot_early():
    """eos_id observed in the stream ends the request before
    max_new_tokens: the retirement path flags it, the decode loop frees
    the slot, and the request counts as completed (not cancelled)."""
    cfg = smoke_config("llama3-8b").replace(remat=False)
    params = M.init_model_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(5)
    prompt = rng.integers(0, cfg.vocab_size, 6).astype(np.int32)
    ref = _greedy_reference(cfg, params, prompt, 8)
    eos = ref[2]  # greedy stream hits this at step 3 -> early stop
    eng = ServeEngine(cfg, params, batch_slots=2, max_len=32, eos_id=eos)
    req = Request(uid=0, prompt=prompt, max_new_tokens=8)
    eng.submit(req)
    eng.run_until_drained()
    assert req.generated == ref[:3], "stream must end AT the eos token"
    assert eng.metrics.counters["completed"] == 1
    assert eng.metrics.counters.get("cancelled", 0) == 0


def test_submit_rejects_unservable_prompts():
    """A prompt that can never be served — here exactly max_len tokens,
    which would leave no cache row for its first decode tick — is rejected
    AT SUBMIT (counted in ``rejected``) instead of reaching the queue head
    and wedging the pack planner; the engine keeps serving admissible
    requests, including one of the maximal length max_len - 1."""
    cfg = smoke_config("llama3-8b").replace(remat=False)
    params = M.init_model_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, batch_slots=2, max_len=32)
    rng = np.random.default_rng(0)
    bad = Request(uid=0, prompt=rng.integers(0, cfg.vocab_size, 32)
                  .astype(np.int32), max_new_tokens=2)
    with pytest.raises(ValueError, match="exceeds this engine's limit"):
        eng.submit(bad)
    assert eng.metrics.counters["rejected"] == 1
    assert eng.scheduler.depth == 0, "rejected request must never queue"
    ok = Request(uid=1, prompt=rng.integers(0, cfg.vocab_size, 31)
                 .astype(np.int32), max_new_tokens=2)
    eng.submit(ok)
    eng.run_until_drained()
    assert ok.generated is not None and len(ok.generated) == 2
    assert eng.metrics.counters["completed"] == 1


def test_max_prefill_beyond_cache_is_a_config_error():
    """serve.max_prefill larger than the K/V cache would silently truncate
    merged rows; the engine must refuse the configuration loudly."""
    cfg = smoke_config("llama3-8b").replace(remat=False)
    cfg = cfg.replace(serve=dataclasses.replace(cfg.serve, max_prefill=64))
    params = M.init_model_params(cfg, jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="max_prefill"):
        ServeEngine(cfg, params, batch_slots=2, max_len=32)


def test_retirement_thread_survives_poisoned_event():
    """One malformed retirement event must not kill the retirement daemon:
    the error is counted in ``retire_errors`` and every later stream still
    retires normally."""
    cfg = smoke_config("llama3-8b").replace(remat=False)
    params = M.init_model_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, batch_slots=2, max_len=32)
    assert eng._async, "async retirement must engage for this family"
    eng._emit({"tok": None, "append": [(object(), 0)]})  # poisoned payload
    rng = np.random.default_rng(2)
    req = Request(uid=0, prompt=rng.integers(0, cfg.vocab_size, 5)
                  .astype(np.int32), max_new_tokens=3)
    eng.submit(req)
    eng.run_until_drained()
    assert req.generated is not None and len(req.generated) == 3
    assert eng.metrics.counters["retire_errors"] == 1
    assert eng.metrics.counters["completed"] == 1


def test_engine_metrics_concurrent_mutation_is_exact():
    """Retirement-thread metric writes race the decode loop's: counter
    increments, latency records, step-time records, and tracer spans from N
    threads must all land (the shared locks close the read-modify-write
    races) and snapshot() must not tear."""
    from repro.serving.trace import Tracer, validate_request_timelines

    m = EngineMetrics(num_experts=4)
    tr = Tracer()
    errs = []

    def hammer(k):
        try:
            for i in range(500):
                m.inc("completed")
                m.request_latency.record(1e-3)
                m.add_expert_tokens([1, 0, 1, 0])
                m.record_step("serve/decode|B=4|S=32", 1e-3)
                tid = k * 500 + i
                tr.begin(tid, "queue", t=float(i))
                tr.transition(tid, "queue", "decode", t=float(i) + 0.5)
                tr.end(tid, "decode", t=float(i) + 1.0)
                tr.record_span("serve/decode|B=4|S=32", float(i),
                               float(i) + 1e-3)
                m.snapshot()
        except Exception as e:  # pragma: no cover - failure path
            errs.append(e)

    threads = [threading.Thread(target=hammer, args=(k,)) for k in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs
    assert m.counters["completed"] == 8 * 500
    assert m.request_latency.snapshot()["n"] == 8 * 500
    assert m.expert_tokens.tolist() == [4000, 0, 4000, 0]
    assert m.snapshot()["step_latency_ms"]["serve/decode|B=4|S=32"]["n"] \
        == 8 * 500
    # 2 spans per iteration (queue+decode phases) + 1 step span, none lost
    assert tr.recorder.total == 8 * 500 * 3
    assert tr.open_count() == 0
    assert validate_request_timelines(tr.recorder.spans()) > 0
