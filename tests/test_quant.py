"""Quantization scheme tests (paper section 3): quantizer math, the log-sqrt2
reparameterization identities (Eqs. 17-21), the post-norm reparam equivalence
(Eqs. 10-16), and the end-to-end PTQ driver. Property tests use hypothesis."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hyp_compat import given, settings, st

import repro.models as M
from repro.configs import get_shape, smoke_config
from repro.core.quant import (
    AsymParams,
    apply_to_consumer,
    apply_to_layernorm,
    calibrate_per_channel_asym,
    dequantize_asym,
    dequantize_sym,
    logsqrt2_dequantize,
    logsqrt2_quantize,
    logsqrt2_scale_factor,
    parity_decomposition,
    quantize_asym,
    quantize_sym,
    reparam_factors,
    sym_scale_from_absmax,
    transform_activation,
)
from repro.core.quant.ptq import calibrate_model, ptq_model, quantized_config

SQRT2 = np.sqrt(2.0)


# ---------------------------------------------------------------------------
# Uniform quantizers (Eqs. 6-7)
# ---------------------------------------------------------------------------

@settings(max_examples=50, deadline=None)
@given(st.lists(st.floats(-100, 100, allow_nan=False), min_size=4,
                max_size=64),
       st.sampled_from([4, 8]))
def test_symmetric_roundtrip_error_bound(vals, bits):
    x = jnp.asarray(vals, jnp.float32)
    scale = sym_scale_from_absmax(jnp.max(jnp.abs(x)), bits)
    err = jnp.abs(dequantize_sym(quantize_sym(x, scale, bits), scale) - x)
    assert float(jnp.max(err)) <= float(scale) / 2 + 1e-6


@settings(max_examples=50, deadline=None)
@given(st.lists(st.floats(-50, 150, allow_nan=False), min_size=4,
                max_size=64))
def test_asymmetric_roundtrip_error_bound(vals):
    x = jnp.asarray(vals, jnp.float32)
    from repro.core.quant import asym_params_from_minmax

    p = asym_params_from_minmax(jnp.min(x), jnp.max(x), 8)
    xq = quantize_asym(x, p, 8)
    err = jnp.abs(dequantize_asym(xq, p) - x)
    assert float(jnp.max(err)) <= float(p.scale) / 2 + 1e-5


# ---------------------------------------------------------------------------
# log-sqrt2 post-softmax quantizer (Eqs. 17-21)
# ---------------------------------------------------------------------------

def test_logsqrt2_codes_are_exact_on_grid():
    """Values 2^{-k/2} quantize to code k and dequantize exactly."""
    codes = np.arange(0, 16)
    vals = jnp.asarray(2.0 ** (-codes / 2.0), jnp.float32)
    q = logsqrt2_quantize(vals, bits=4)
    np.testing.assert_array_equal(np.asarray(q), codes)
    deq = logsqrt2_dequantize(q)
    np.testing.assert_allclose(deq, vals, rtol=1e-6)


@settings(max_examples=100, deadline=None)
@given(st.floats(1e-4, 1.0))
def test_logsqrt2_relative_error_bound(a):
    """Within range, relative quantization error <= 2^{1/4} - 1 (~19%)."""
    v = jnp.asarray([a], jnp.float32)
    deq = float(logsqrt2_dequantize(logsqrt2_quantize(v, bits=8))[0])
    assert abs(deq - a) / a <= 2 ** 0.25 - 1 + 1e-3


def test_eq19_parity_identity():
    """Eq. 19: 2^{-A_q/2} == 2^{-ceil(A_q/2)} (1 + odd(A_q)(sqrt2-1))."""
    codes = jnp.arange(0, 16, dtype=jnp.int32)
    direct = 2.0 ** (-codes.astype(jnp.float32) / 2.0)
    reparam = logsqrt2_dequantize(codes)
    np.testing.assert_allclose(reparam, direct, rtol=1e-6)


def test_eq20_scale_factor():
    codes = jnp.arange(0, 16, dtype=jnp.int32)
    s = logsqrt2_scale_factor(codes)
    expected = np.where(np.arange(16) % 2 == 1, SQRT2 - 1 + 1, 1.0)
    np.testing.assert_allclose(s, expected, rtol=1e-6)


def test_parity_decomposition_matmul_exactness(rng):
    """Eq. 21 analogue: A_hat @ V == (A_even @ V) + sqrt2 (A_odd @ V), with
    both planes exact powers of two (zero mantissa error)."""
    codes = jnp.asarray(rng.integers(0, 16, (8, 16)), jnp.int32)
    v = jnp.asarray(rng.standard_normal((16, 4)), jnp.float32)
    a_hat = logsqrt2_dequantize(codes)
    a_even, a_odd = parity_decomposition(codes)
    lhs = a_hat @ v
    rhs = a_even @ v + SQRT2 * (a_odd @ v)
    # identity is exact in math; fp32 summation order differs by ~1 ulp
    np.testing.assert_allclose(lhs, rhs, rtol=1e-5, atol=1e-6)
    # power-of-two planes are exact in bf16
    for plane in (a_even, a_odd):
        pl16 = plane.astype(jnp.bfloat16).astype(jnp.float32)
        np.testing.assert_array_equal(np.asarray(pl16), np.asarray(plane))


# ---------------------------------------------------------------------------
# Post-norm reparameterization (Eqs. 10-16)
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(st.integers(2, 16), st.integers(4, 32))
def test_reparam_linear_equivalence(d, n):
    """Eq. 13: X W + b == X' (diag(r1) W) + (b - W^T (s . r2))."""
    rng = np.random.default_rng(d * 100 + n)
    x = jnp.asarray(rng.standard_normal((n, d)) * rng.uniform(0.1, 5, d)
                    + rng.uniform(-3, 3, d), jnp.float32)
    w = jnp.asarray(rng.standard_normal((d, 3)), jnp.float32)
    b = jnp.asarray(rng.standard_normal(3), jnp.float32)
    s, z = calibrate_per_channel_asym(x, 8)
    f = reparam_factors(s, z, 8)
    x_p = transform_activation(x, f)
    w_p, b_p = apply_to_consumer(w, b, f)
    np.testing.assert_allclose(x @ w + b, x_p @ w_p + b_p, rtol=2e-4,
                               atol=2e-4)


def test_reparam_integer_grid_alignment(rng):
    """round(X'/s_tilde) reproduces the per-channel asymmetric integer grid
    (the precision-preservation claim of section 3.1)."""
    d, n = 8, 256
    x = jnp.asarray(rng.standard_normal((n, d)) * rng.uniform(0.1, 5, d)
                    + rng.uniform(-3, 3, d), jnp.float32)
    s, z = calibrate_per_channel_asym(x, 8)
    f = reparam_factors(s, z, 8)
    x_p = transform_activation(x, f)
    grid_sym = jnp.round(x_p / f.s_tilde)
    grid_asym = jnp.round(x / s) + z - 2.0**7
    np.testing.assert_allclose(grid_sym, grid_asym, atol=1 + 1e-5)


def test_reparam_layernorm_fold(rng):
    """Folding into (gamma, beta) produces X' without runtime ops (Eq. 11)."""
    from repro.models.layers import layernorm

    d, n = 16, 64
    x = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)
    gamma = jnp.asarray(rng.uniform(0.5, 2, d), jnp.float32)
    beta = jnp.asarray(rng.standard_normal(d), jnp.float32)
    y = layernorm(x, gamma, beta)
    s, z = calibrate_per_channel_asym(y, 8)
    f = reparam_factors(s, z, 8)
    g_p, b_p = apply_to_layernorm(gamma, beta, f)
    y_folded = layernorm(x, g_p, b_p)
    np.testing.assert_allclose(
        y_folded, transform_activation(y, f), rtol=2e-4, atol=2e-4
    )


# ---------------------------------------------------------------------------
# End-to-end PTQ driver
# ---------------------------------------------------------------------------

PTQ_ARCHS = ["m3vit-small", "vit-base", "llama3-8b", "nemotron-4-340b",
             "olmoe-1b-7b", "gemma2-2b", "zamba2-7b", "falcon-mamba-7b",
             "seamless-m4t-medium"]


@pytest.mark.parametrize("arch", PTQ_ARCHS)
def test_ptq_fold_only_is_equivalent(arch):
    """Eqs. 10-16 fold alone must not change the model function."""
    cfg = smoke_config(arch).replace(remat=False)
    shape = get_shape("train_4k").replace(seq_len=24, global_batch=2)
    params = M.init_model_params(cfg, jax.random.PRNGKey(0))
    batches = [M.synth_batch(cfg, shape, jax.random.PRNGKey(i))
               for i in range(2)]
    taps = calibrate_model(cfg, params, batches)
    p_fold = ptq_model(cfg, params, taps, fold_only=True)
    lg0, _ = M.forward(params, cfg, batches[0])
    lg1, _ = M.forward(p_fold, cfg, batches[0])
    scale = float(jnp.std(lg0)) + 1e-9
    assert float(jnp.max(jnp.abs(lg0 - lg1))) / scale < 1e-2


@pytest.mark.parametrize("arch", ["m3vit-small", "llama3-8b"])
def test_ptq_quantized_model_is_finite_and_close(arch):
    cfg = smoke_config(arch).replace(remat=False)
    shape = get_shape("train_4k").replace(seq_len=24, global_batch=2)
    params = M.init_model_params(cfg, jax.random.PRNGKey(0))
    batches = [M.synth_batch(cfg, shape, jax.random.PRNGKey(i))
               for i in range(2)]
    taps = calibrate_model(cfg, params, batches)
    p_q = ptq_model(cfg, params, taps)
    lg0, _ = M.forward(params, cfg, batches[0])
    lgq, _ = M.forward(p_q, quantized_config(cfg), batches[0])
    assert bool(jnp.isfinite(lgq).all())
    sqnr = 10 * np.log10(
        float(jnp.sum(lg0.astype(jnp.float64) ** 2))
        / max(float(jnp.sum((lg0 - lgq).astype(jnp.float64) ** 2)), 1e-30)
    )
    assert sqnr > 10.0, f"SQNR {sqnr:.1f} dB too low"


def test_ptq_inserts_activation_scales():
    cfg = smoke_config("llama3-8b").replace(remat=False)
    shape = get_shape("train_4k").replace(seq_len=16, global_batch=2)
    params = M.init_model_params(cfg, jax.random.PRNGKey(0))
    taps = calibrate_model(
        cfg, params, [M.synth_batch(cfg, shape, jax.random.PRNGKey(0))]
    )
    p_q = ptq_model(cfg, params, taps)
    assert "a_scale" in p_q["layers"]["ln1"]
    assert p_q["layers"]["ln1"]["a_scale"].shape == (cfg.num_layers,)
    assert "wo_a_scale" in p_q["layers"]["attn"]
    # weights became int8 grids: every weight value is a multiple of its
    # per-channel scale (check one)
    w = p_q["layers"]["attn"]["wq"]
    assert bool(jnp.isfinite(w).all())
