"""Autoscaler: target-range control law, warm-standby lifecycle, drain
safety, and the windowed pooled-p95 signal (DESIGN.md section 8).

All tests run under a fake clock with a ``FakeReplica`` implementing the
``EngineReplica`` protocol — the controller is pure host-side bookkeeping,
so no model math is needed to pin its behavior down deterministically.
"""
import dataclasses
import math

import numpy as np
import pytest

from repro.configs.base import AutoscaleConfig
from repro.serving.autoscaler import Autoscaler
from repro.serving.cluster import ServingCluster
from repro.serving.metrics import EngineMetrics
from repro.serving.replica import EngineReplica
from repro.serving.scheduler import Backpressure


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


@dataclasses.dataclass
class FakeRequest:
    uid: int
    submitted_at: float = None


class FakeReplica:
    """Deterministic ``EngineReplica``: serves up to ``capacity`` queued
    requests per ``step()``; request latency = fake-clock queue wait."""

    def __init__(self, mesh, clock, *, capacity=2, max_pending=4):
        self.mesh = mesh
        self._clock = clock
        self.capacity = capacity
        self.max_pending = max_pending
        self._queue = []
        self.metrics = EngineMetrics(clock=clock)

    def submit(self, req):
        if len(self._queue) >= self.max_pending:
            self.metrics.inc("rejected")
            raise Backpressure("fake replica full")
        if req.submitted_at is None:
            req.submitted_at = self._clock()
        self._queue.append(req)
        self.metrics.inc("submitted")
        self.metrics.observe_queue_depth(len(self._queue))

    def step(self):
        now = self._clock()
        for req in self._queue[:self.capacity]:
            self.metrics.inc("completed")
            self.metrics.work_done(1, "frames")
            self.metrics.request_latency.record(
                max(0.0, now - req.submitted_at))
        del self._queue[:self.capacity]

    def warmup(self):
        pass

    def flush(self):
        while self._queue:
            self.step()

    def reset_metrics(self):
        self.metrics = EngineMetrics(clock=self._clock)

    @property
    def load(self):
        return len(self._queue)

    @property
    def free_room(self):
        return max(0, self.max_pending - len(self._queue))

    @property
    def idle(self):
        return not self._queue


def _fake_cluster(clock, *, replicas=1, standby=2, capacity=2,
                  max_pending=4, front_pending=0):
    factory = lambda mesh: FakeReplica(mesh, clock, capacity=capacity,
                                       max_pending=max_pending)
    return ServingCluster(None, None, replicas=replicas, standby=standby,
                          engine=factory, max_pending=front_pending,
                          clock=clock)


def test_fake_replica_satisfies_protocol():
    clock = FakeClock()
    assert isinstance(FakeReplica(None, clock), EngineReplica)


def test_scale_up_on_queue_pressure_then_down_when_idle():
    clock = FakeClock()
    cluster = _fake_cluster(clock, replicas=1, standby=2, capacity=1,
                            max_pending=2)
    policy = AutoscaleConfig(min_replicas=1, max_replicas=3,
                             depth_high=2.0, up_patience=2,
                             depth_low=0.0, down_patience=4, cooldown=1,
                             slo_p95_ms=1e9, min_window_samples=10**9)
    scaler = Autoscaler(cluster, policy)
    uid = 0
    # burst: 12 arrivals over 4 ticks with 1 replica serving 1/tick ->
    # front-end depth builds past depth_high * n
    for _ in range(4):
        for _ in range(3):
            cluster.submit(FakeRequest(uid=uid))
            uid += 1
        cluster.step()
        scaler.tick()
        clock.advance(0.01)
    assert cluster.num_replicas > 1, "pressure never triggered scale-up"
    # keep serving (no new arrivals) until drained; controller scales back
    for _ in range(60):
        cluster.step()
        scaler.tick()
        clock.advance(0.01)
    assert cluster.idle
    assert cluster.num_replicas == 1, "idle cluster should shrink to min"
    snap = cluster.metrics.snapshot()
    # no request lost across the whole up/down cycle (drained replicas'
    # counters survive in the retired accumulator)
    assert snap["aggregate"]["counters"]["completed"] == uid
    assert snap["aggregate"]["counters"]["cluster_submitted"] == uid
    # replica-count timeline rose then fell back
    counts = [n for _, n in snap["replica_timeline"]]
    assert max(counts) > 1 and counts[0] == 1 and counts[-1] == 1
    # standby pool got its replicas back
    assert cluster.standby_replicas == 2 and cluster.draining_replicas == 0


def test_scale_up_on_slo_breach_without_front_depth():
    """Replica-internal queueing (front depth 0) still triggers scale-up
    through the windowed pooled-p95 signal."""
    clock = FakeClock()
    # deep per-replica queue: the router always finds room, so the front
    # depth stays 0 and only the latency signal can fire
    cluster = _fake_cluster(clock, replicas=1, standby=1, capacity=1,
                            max_pending=100)
    policy = AutoscaleConfig(min_replicas=1, max_replicas=2,
                             depth_high=1e9, up_patience=1,
                             slo_p95_ms=50.0, min_window_samples=4,
                             down_patience=10**9, cooldown=0)
    scaler = Autoscaler(cluster, policy)
    uid = 0
    for _ in range(20):
        for _ in range(3):
            cluster.submit(FakeRequest(uid=uid))
            uid += 1
        cluster.step()
        scaler.tick()
        clock.advance(0.1)  # waits grow ~100ms/tick >> 50ms SLO
        assert cluster.depth == 0, "front depth must stay empty here"
        if cluster.num_replicas == 2:
            break
    assert cluster.num_replicas == 2, "SLO breach never triggered scale-up"
    assert scaler.window_p95_ms > policy.slo_p95_ms


def test_hysteresis_patience_and_cooldown():
    clock = FakeClock()
    cluster = _fake_cluster(clock, replicas=1, standby=2, capacity=0,
                            max_pending=1)
    policy = AutoscaleConfig(min_replicas=1, max_replicas=3,
                             depth_high=0.5, up_patience=3, cooldown=5,
                             down_patience=10**9,
                             slo_p95_ms=1e9, min_window_samples=10**9)
    scaler = Autoscaler(cluster, policy)
    for i in range(8):  # enough to keep depth > depth_high * max_replicas
        cluster.submit(FakeRequest(uid=i))
    cluster._route()
    assert cluster.depth >= 7  # replica bound 1 -> pressure at the front
    # patience: two breached ticks do nothing, the third scales
    assert scaler.tick() is None
    assert scaler.tick() is None
    assert scaler.tick() == "up"
    # cooldown: sustained pressure cannot scale again for `cooldown` ticks
    fired = [scaler.tick() for _ in range(policy.cooldown)]
    assert fired == [None] * policy.cooldown
    assert scaler.tick() == "up"


def test_drain_serves_inflight_before_standby_return():
    clock = FakeClock()
    cluster = _fake_cluster(clock, replicas=2, standby=0, capacity=1,
                            max_pending=10)
    reqs = [FakeRequest(uid=i) for i in range(6)]
    for r in reqs:
        cluster.submit(r)
    cluster._route()
    assert all(e.load > 0 for e in cluster.engines)
    # drain one replica while it still holds queued work
    assert cluster.scale_down()
    assert cluster.num_replicas == 1 and cluster.draining_replicas == 1
    for _ in range(10):
        cluster.step()
        clock.advance(0.01)
    assert cluster.idle
    # the draining replica served its queue, then returned to standby
    assert cluster.draining_replicas == 0 and cluster.standby_replicas == 1
    agg = cluster.metrics.snapshot()["aggregate"]
    assert agg["counters"]["completed"] == len(reqs), "requests lost in drain"
    # its latency distribution survived the leave (retired accumulator)
    assert agg["latency_ms"]["n"] == len(reqs)


def test_scale_down_refuses_last_replica():
    clock = FakeClock()
    cluster = _fake_cluster(clock, replicas=1, standby=0)
    assert not cluster.scale_down()
    assert cluster.num_replicas == 1


def test_cold_spawn_past_standby_pool():
    """Scaling beyond the pre-built pool spawns (and warms) a new replica
    instead of failing."""
    clock = FakeClock()
    cluster = _fake_cluster(clock, replicas=1, standby=1)
    assert cluster.scale_up()  # standby promote
    assert cluster.standby_replicas == 0
    assert cluster.scale_up()  # cold spawn
    assert cluster.num_replicas == 3
    timeline = cluster.metrics.snapshot()["replica_timeline"]
    assert [n for _, n in timeline] == [1, 2, 3]


def test_stale_p95_expires_and_idle_cluster_scales_back_down():
    """A p95 breach measured during a surge must age out once traffic
    stops: without the TTL the stale estimate reads as a live SLO breach
    forever — scaling an idle cluster to max and blocking scale-down."""
    clock = FakeClock()
    cluster = _fake_cluster(clock, replicas=1, standby=2, capacity=10,
                            max_pending=100)
    policy = AutoscaleConfig(min_replicas=1, max_replicas=3,
                             depth_high=1e9, up_patience=1,
                             slo_p95_ms=50.0, min_window_samples=4,
                             down_patience=2, cooldown=0, p95_ttl=5)
    scaler = Autoscaler(cluster, policy)
    # surge: 4 requests wait ~200ms >> SLO, close a breached window
    for i in range(4):
        cluster.submit(FakeRequest(uid=i))
    cluster._route()
    clock.advance(0.2)
    cluster.step()
    assert scaler.tick() == "up"  # breach reacts
    assert scaler.window_p95_ms > policy.slo_p95_ms
    # traffic stops: the stale breach must not keep scaling up, and after
    # p95_ttl evaluations the estimate expires and the cluster shrinks
    for _ in range(20):
        cluster.step()
        scaler.tick()
        clock.advance(0.01)
    assert math.isnan(scaler.window_p95_ms)
    assert cluster.num_replicas == 1, "idle cluster must fall back to min"


def test_windowed_p95_across_replica_churn():
    """The autoscaler's latency window stays correct when a replica drains
    mid-window: its samples fold into the retired histogram, so the delta
    between evaluations never loses (or double-counts) mass."""
    clock = FakeClock()
    cluster = _fake_cluster(clock, replicas=2, standby=0, capacity=1,
                            max_pending=10)
    policy = AutoscaleConfig(min_window_samples=4, slo_p95_ms=50.0,
                             down_patience=10**9, up_patience=10**9)
    scaler = Autoscaler(cluster, policy)
    # window 1: 6 requests at ~10ms wait
    for i in range(6):
        cluster.submit(FakeRequest(uid=i))
    cluster._route()
    clock.advance(0.01)
    for _ in range(5):
        cluster.step()
        clock.advance(0.0)
    scaler.tick()
    n_before = int(scaler._window_hist.sum())
    assert n_before == 6
    # a replica drains (folds its samples into retired) mid-stream
    assert cluster.scale_down()
    for _ in range(5):
        cluster.step()
    # window 2: 4 more requests at ~100ms wait through the remaining replica
    for i in range(4):
        cluster.submit(FakeRequest(uid=100 + i))
    cluster._route()
    clock.advance(0.1)
    for _ in range(6):
        cluster.step()
    scaler.tick()
    # delta histogram must contain exactly the 4 new samples (~100ms each)
    assert int(scaler._window_hist.sum()) == 10
    assert 50.0 < scaler.window_p95_ms < 200.0
