"""Serving-stack tracing layer (DESIGN.md section 11): span-timeline
invariants (non-overlapping, phase-ordered, summing to the recorded
end-to-end latency), flight-recorder bounds and thread safety, Chrome-trace
export validity, the structured event log, step-latency histograms through
the metrics roll-up and elasticity, and the autoscaler decision journal."""
import dataclasses
import threading

import numpy as np
import pytest

from repro.configs.base import AutoscaleConfig, TraceConfig
from repro.serving.autoscaler import Autoscaler
from repro.serving.cluster import ServingCluster
from repro.serving.events import EventLog, read_jsonl
from repro.serving.metrics import (
    _BIN_EDGES,
    ClusterMetrics,
    EngineMetrics,
    LatencyTracker,
    hist_percentile,
)
from repro.serving.trace import (
    NULL_TRACER,
    FlightRecorder,
    Span,
    Tracer,
    chrome_trace,
    make_tracer,
    request_timelines,
    validate_chrome_trace,
    validate_request_timelines,
)

from test_autoscaler import FakeClock, FakeReplica, FakeRequest


# -- tracer + flight recorder ------------------------------------------------


def test_timeline_partitions_recorded_latency():
    """The acceptance invariant, deterministically: adjacent phases share
    boundary timestamps, so queue+pack+prefill+decode sums EXACTLY to the
    end-to-end latency, and retire extends past it (off the latency path)."""
    clk = FakeClock()
    tr = Tracer(clock=clk)
    tr.begin(7, "queue", t=0.0)
    tr.transition(7, "queue", "pack", t=1.5)
    tr.transition(7, "pack", "prefill", t=2.0)
    tr.transition(7, "prefill", "decode", t=3.25)
    tr.transition(7, "decode", "retire", t=9.0)
    tr.end(7, "retire", t=9.5, latency_s=9.0)
    assert tr.open_count() == 0
    spans = tr.recorder.spans()
    assert validate_request_timelines(spans) == 1
    tl = request_timelines(spans)[7]
    assert [s.name for s in tl] == ["queue", "pack", "prefill", "decode",
                                    "retire"]
    service = sum(s.dur for s in tl if s.name != "retire")
    assert service == pytest.approx(9.0, abs=1e-12)
    assert tl[-1].attrs["latency_s"] == 9.0
    assert tl[-1].t1 > 9.0, "retire extends past the latency window"


def test_vision_phase_subsequence_validates():
    """Vision requests skip pack/prefill/decode: queue -> infer -> retire is
    a valid subsequence of the phase order."""
    tr = Tracer()
    tr.begin(0, "queue", t=0.0)
    tr.transition(0, "queue", "infer", t=1.0)
    tr.transition(0, "infer", "retire", t=2.0)
    tr.end(0, "retire", t=2.5)
    assert validate_request_timelines(tr.recorder.spans()) == 1


def test_end_without_begin_is_silent_noop():
    tr = Tracer()
    tr.end(3, "decode", t=1.0)  # never begun: must not raise or record
    assert tr.recorder.total == 0 and tr.open_count() == 0


def test_flight_recorder_bounded_ring_counts_drops():
    rec = FlightRecorder(capacity=4)
    for i in range(10):
        rec.record(Span(None, f"s{i}", "step", float(i), float(i) + 0.5))
    assert len(rec) == 4
    assert rec.total == 10 and rec.dropped == 6
    assert [s.name for s in rec.spans()] == ["s6", "s7", "s8", "s9"], \
        "the ring must keep the most recent window"
    assert [s.name for s in rec.spans(t0=8.2)] == ["s8", "s9"]
    assert [s.name for s in rec.spans(t1=6.9)] == ["s6"]
    rec.clear()
    assert len(rec) == 0 and rec.total == 0


def test_flight_recorder_concurrent_records_all_land():
    rec = FlightRecorder(capacity=100_000)
    errs = []

    def hammer(k):
        try:
            for i in range(1000):
                rec.record(Span(k, "decode", "request", float(i),
                                float(i) + 1))
                if i % 100 == 0:
                    rec.spans()  # concurrent snapshot must not tear
        except Exception as e:  # pragma: no cover - failure path
            errs.append(e)

    threads = [threading.Thread(target=hammer, args=(k,)) for k in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs
    assert rec.total == 8000 and rec.dropped == 0


def test_disabled_tracer_records_nothing():
    """make_tracer compiles the layer out when disabled: the shared
    NULL_TRACER answers every site, records nothing, allocates nothing."""
    assert make_tracer(None) is NULL_TRACER
    assert make_tracer(TraceConfig(enable=False)) is NULL_TRACER
    nt = make_tracer(TraceConfig(enable=False))
    assert not nt.enabled
    nt.begin(0, "queue")
    nt.transition(0, "queue", "decode")
    nt.record_span("serve/decode", 0.0, 1.0)
    nt.end(0, "decode")
    assert nt.recorder.total == 0 and nt.open_count() == 0
    tr = make_tracer(TraceConfig(enable=True, capacity=16), label="r0")
    assert tr.enabled and tr.label == "r0" and tr.recorder.capacity == 16


# -- chrome-trace export -----------------------------------------------------


def _two_replica_recorders():
    a, b = Tracer(label="replica0"), Tracer(label="replica1")
    for tr, tid in ((a, 0), (b, 1)):
        tr.begin(tid, "queue", t=0.0)
        tr.transition(tid, "queue", "decode", t=1.0)
        tr.end(tid, "decode", t=2.0)
        tr.record_span("serve/decode|B=4|S=32", 1.0, 2.0, n=1)
    return {a.label: a.recorder, b.label: b.recorder}


def test_chrome_trace_layout_and_validity():
    doc = chrome_trace(_two_replica_recorders())
    n = validate_chrome_trace(doc)
    assert n == 6  # 3 spans per replica
    evs = doc["traceEvents"]
    pids = {e["pid"] for e in evs}
    assert pids == {0, 1}, "one process per replica"
    # step spans ride tid 0; request spans ride tid = trace_id + 1
    steps = [e for e in evs if e["ph"] == "X" and e.get("cat") == "step"]
    assert all(e["tid"] == 0 for e in steps)
    reqs = [e for e in evs if e["ph"] == "X" and e.get("cat") == "request"]
    assert {e["tid"] for e in reqs} == {1, 2}
    names = [e for e in evs if e["ph"] == "M"
             and e["name"] == "process_name"]
    assert sorted(e["args"]["name"] for e in names) == \
        ["replica0", "replica1"]
    # timestamps are microseconds
    q = next(e for e in reqs if e["name"] == "queue")
    assert q["dur"] == pytest.approx(1e6)


def test_chrome_trace_accepts_bare_tracer():
    tr = Tracer(label="solo")
    tr.record_span("classify|b=4", 0.0, 0.5, n=4)
    doc = chrome_trace(tr)
    assert validate_chrome_trace(doc) == 1


def test_validate_chrome_trace_rejects_malformed():
    with pytest.raises(ValueError, match="traceEvents"):
        validate_chrome_trace({"events": []})
    with pytest.raises(ValueError, match="missing"):
        validate_chrome_trace(
            {"traceEvents": [{"ph": "X", "name": "x", "ts": 0.0,
                              "pid": 0, "tid": 0}]})
    with pytest.raises(ValueError, match="negative"):
        validate_chrome_trace(
            {"traceEvents": [{"ph": "X", "name": "x", "ts": 0.0,
                              "dur": -1.0, "pid": 0, "tid": 0}]})


def test_validate_request_timelines_rejects_violations():
    bad_order = [Span(0, "decode", "request", 0.0, 1.0),
                 Span(0, "queue", "request", 1.0, 2.0)]
    with pytest.raises(ValueError, match="out of order"):
        validate_request_timelines(bad_order)
    overlap = [Span(1, "queue", "request", 0.0, 2.0),
               Span(1, "decode", "request", 1.0, 3.0)]
    with pytest.raises(ValueError, match="overlaps"):
        validate_request_timelines(overlap)
    with pytest.raises(ValueError, match="unknown phase"):
        validate_request_timelines([Span(2, "mystery", "request", 0, 1)])


# -- event log ---------------------------------------------------------------


def test_event_log_ring_counts_and_stream(tmp_path):
    path = tmp_path / "events.jsonl"
    log = EventLog(capacity=4, path=str(path))
    for i in range(6):
        log.emit("reject", t=float(i), reason="backpressure", depth=i)
    log.emit("scale_up", t=9.0, replicas_before=1, replicas_after=2)
    log.close()
    assert log.total == 7 and log.dropped == 3
    assert len(log.events()) == 4, "ring keeps the recent window"
    assert [e["type"] for e in log.events("scale_up")] == ["scale_up"]
    assert log.counts() == {"reject": 3, "scale_up": 1}
    # the streaming sink saw EVERY event, including ring-evicted ones
    rows = read_jsonl(str(path))
    assert len(rows) == 7
    assert rows[0] == {"t": 0.0, "type": "reject",
                       "reason": "backpressure", "depth": 0}
    assert rows[-1]["type"] == "scale_up"


def test_event_log_jsonl_roundtrip_and_fallback(tmp_path):
    log = EventLog()
    log.emit("cancel", t=1.0, where="queued",
             arr=np.int64(3))  # non-JSON type must not break export
    path = tmp_path / "out.jsonl"
    log.write_jsonl(str(path))
    rows = read_jsonl(str(path))
    assert rows[0]["type"] == "cancel" and rows[0]["arr"] == 3


# -- percentile edge cases + merged accuracy (satellite 1) -------------------


def test_hist_percentile_edge_cases():
    empty = np.zeros(_BIN_EDGES.size + 1, np.int64)
    assert hist_percentile(empty, 95) == 0.0
    single = empty.copy()
    single[np.searchsorted(_BIN_EDGES, 0.0123, side="right")] = 1
    assert hist_percentile(single, 50, max_value=0.0123) == 0.0123
    # without the caller-supplied sample the midpoint answers
    assert hist_percentile(single, 50) == pytest.approx(0.0123, rel=0.1)


def test_latency_tracker_percentile_edge_cases():
    t = LatencyTracker()
    assert t.percentile(50) == 0.0 and t.percentile(99) == 0.0
    t.record(0.25)
    assert t.percentile(1) == 0.25 and t.percentile(99) == 0.25, \
        "a single-sample tracker answers the sample itself"
    snap = t.snapshot()
    assert snap["p50"] == snap["p99"] == pytest.approx(250.0)


@pytest.mark.parametrize("p", [50, 90, 95, 99])
def test_merged_tracker_percentile_within_one_log_bin(p):
    """Merged-tracker percentiles come from the pooled histogram once the
    reservoirs overflow; the log-spaced bins (8/decade) bound the error to
    one bin ratio (10^(1/8)) of the exact pooled percentile."""
    rng = np.random.default_rng(42)
    trackers, pooled = [], []
    for r in range(4):
        t = LatencyTracker(maxlen=16)  # force the histogram path
        samples = rng.lognormal(mean=-4.0 + 0.5 * r, sigma=0.8, size=400)
        for s in samples:
            t.record(float(s))
        trackers.append(t)
        pooled.extend(samples)
    merged = LatencyTracker.merged(trackers)
    assert merged.snapshot()["n"] == 1600
    exact = float(np.percentile(np.asarray(pooled), p))
    got = merged.percentile(p)
    bin_ratio = 10 ** (1 / 8)
    assert exact / bin_ratio <= got <= exact * bin_ratio, \
        f"p{p}: pooled {got} vs exact {exact}"


# -- step-latency histograms through the roll-up (satellite 3) ---------------


def test_step_latency_in_engine_and_cluster_snapshots():
    m = EngineMetrics()
    for _ in range(8):
        m.record_step("serve/decode|B=4|S=32", 1e-3)
    m.record_step("serve/packed_prefill|B=4|S=32|bucket=64|n=4", 5e-3)
    snap = m.snapshot()
    assert snap["step_latency_ms"]["serve/decode|B=4|S=32"]["n"] == 8
    cm = ClusterMetrics([m])
    agg = cm.snapshot()["aggregate"]["step_latency_ms"]
    assert agg["serve/decode|B=4|S=32"]["n"] == 8
    assert agg["serve/packed_prefill|B=4|S=32|bucket=64|n=4"]["n"] == 1


def test_step_histograms_survive_elasticity_fold():
    """scale_down lifecycle: fold into the retired accumulator, reset the
    engine's metrics, rejoin later — per-program step history is never lost
    and never double-counted, while a live thread keeps recording."""
    clk = FakeClock()
    m = EngineMetrics(clock=clk)
    cm = ClusterMetrics([m], clock=clk)
    tr = Tracer(clock=clk)
    stop = threading.Event()
    errs = []

    def retirement_thread():
        try:
            i = 0
            while not stop.is_set():
                m.record_step("serve/decode|B=2|S=16", 2e-3)
                tr.record_span("serve/decode|B=2|S=16", i * 1e-3,
                               i * 1e-3 + 2e-3)
                i += 1
        except Exception as e:  # pragma: no cover - failure path
            errs.append(e)

    th = threading.Thread(target=retirement_thread)
    th.start()
    for _ in range(200):
        m.record_step("serve/packed_prefill|B=2|S=16|bucket=32|n=2", 1e-3)
    # replica leaves mid-traffic: fold + reset with the recorder thread live
    cm.remove_replica(m)
    snap_mid = cm.snapshot()["aggregate"]["step_latency_ms"]
    assert snap_mid[
        "serve/packed_prefill|B=2|S=16|bucket=32|n=2"]["n"] == 200
    stop.set()
    th.join()
    assert not errs
    folded = cm._ret_steps["serve/decode|B=2|S=16"].snapshot()["n"]
    m2 = EngineMetrics(clock=clk)  # the reset engine rejoins fresh
    for _ in range(50):
        m2.record_step("serve/decode|B=2|S=16", 3e-3)
    cm.add_replica(m2)
    agg = cm.snapshot()["aggregate"]["step_latency_ms"]
    assert agg["serve/decode|B=2|S=16"]["n"] == folded + 50
    assert agg[
        "serve/packed_prefill|B=2|S=16|bucket=32|n=2"]["n"] == 200
    assert tr.recorder.total == tr.recorder.dropped + len(tr.recorder)


def test_prometheus_export_covers_counters_and_step_histograms():
    m = EngineMetrics()
    m.inc("completed", 3)
    m.request_latency.record(0.01)
    m.record_step("serve/decode|B=4|S=32", 1e-3)
    cm = ClusterMetrics([m])
    cm.inc("cluster_submitted", 3)
    text = cm.export_prometheus()
    assert 'repro_serving_events_total{event="completed"} 3' in text
    assert 'repro_serving_events_total{event="cluster_submitted"} 3' in text
    assert "# TYPE repro_request_latency_seconds histogram" in text
    assert 'le="+Inf"} 1' in text
    assert 'repro_step_latency_seconds_bucket{program=' \
        '"serve/decode|B=4|S=32",le=' in text
    assert "repro_request_latency_seconds_count 1" in text


# -- autoscaler decision journal (tentpole exporter #2) ----------------------


def test_autoscaler_journals_decisions_with_controller_inputs():
    clk = FakeClock()
    events = EventLog(clock=clk)
    factory = lambda mesh: FakeReplica(mesh, clk, capacity=0, max_pending=1)
    cluster = ServingCluster(None, None, replicas=1, standby=2,
                             engine=factory, clock=clk, events=events)
    policy = AutoscaleConfig(min_replicas=1, max_replicas=3,
                             depth_high=0.5, up_patience=1, cooldown=0,
                             down_patience=10**9,
                             slo_p95_ms=1e9, min_window_samples=10**9)
    scaler = Autoscaler(cluster, policy)
    assert scaler.event_log is events, \
        "autoscaler must default to the cluster's event log"
    for i in range(8):
        cluster.submit(FakeRequest(uid=i))
    cluster._route()
    assert scaler.tick() == "up"
    (ev,) = events.events("scale_up")
    assert ev["replicas_before"] == 1 and ev["replicas_after"] == 2
    assert ev["depth"] >= 1 and ev["up_streak"] >= 1
    assert ev["slo_breach"] is False and ev["p95_ms"] is None
    assert ev["t"] == clk.t


def test_cluster_journals_rejections_and_drains():
    clk = FakeClock()
    events = EventLog(clock=clk)
    factory = lambda mesh: FakeReplica(mesh, clk, capacity=1, max_pending=1)
    cluster = ServingCluster(None, None, replicas=2, standby=0,
                             engine=factory, max_pending=1, clock=clk,
                             events=events)
    for i in range(4):  # front bound is 1: three submits bounce
        try:
            cluster.submit(FakeRequest(uid=i))
        except Exception:
            pass
    assert events.counts().get("cluster_reject", 0) == 3
    for rej in events.events("cluster_reject"):
        assert rej["reason"] == "backpressure" and rej["depth"] >= 1
    assert cluster.scale_down()
    for _ in range(10):
        cluster.step()
        clk.advance(0.01)
    assert events.counts().get("replica_drained", 0) == 1
    (dr,) = events.events("replica_drained")
    assert dr["replica"].startswith("replica")


# -- cluster trace-id assignment + recorder collection -----------------------


class TracedFakeReplica(FakeReplica):
    """FakeReplica carrying a real tracer: exercises the cluster's
    trace-id assignment and flight-recorder collection without model math
    (tracer/events are deliberately outside the EngineReplica protocol)."""

    def __init__(self, mesh, clock, **kw):
        super().__init__(mesh, clock, **kw)
        self.tracer = Tracer(clock=clock)

    def submit(self, req):
        super().submit(req)
        self.tracer.begin(req.trace_id, "queue", t=self._clock())

    def step(self):
        now = self._clock()
        for req in self._queue[:self.capacity]:
            self.tracer.transition(req.trace_id, "queue", "retire", t=now)
            self.tracer.end(req.trace_id, "retire", t=now)
        super().step()


def test_cluster_assigns_unique_trace_ids_and_labels_replicas():
    clk = FakeClock()
    factory = lambda mesh: TracedFakeReplica(mesh, clk, capacity=2,
                                             max_pending=8)
    cluster = ServingCluster(None, None, replicas=2, standby=0,
                             engine=factory, clock=clk)
    for i in range(6):
        cluster.submit(FakeRequest(uid=0))  # colliding uids: ids still unique
    for _ in range(4):
        cluster.step()
        clk.advance(0.01)
    recs = cluster.flight_recorders()
    assert sorted(recs) == ["replica0", "replica1"]
    spans = [s for r in recs.values() for s in r.spans()]
    tids = {s.trace_id for s in spans}
    assert tids == set(range(6)), \
        "cluster-assigned trace ids must be unique despite uid collisions"
    assert validate_request_timelines(spans) == 6
    doc = chrome_trace(recs)
    assert validate_chrome_trace(doc) > 0


# -- traced engines end to end (model-backed integration) --------------------


def _traced(cfg):
    return cfg.replace(trace=dataclasses.replace(cfg.trace, enable=True))


def test_serve_engine_traced_run_satisfies_invariants():
    """A real packed continuous-batching run under tracing: every request's
    spans are valid, service phases sum to the recorded latency, step
    histograms land under the AOT program keys, and nothing stays open."""
    import jax

    import repro.models as M
    from repro.configs import smoke_config
    from repro.serving.engine import Request, ServeEngine

    cfg = _traced(smoke_config("llama3-8b").replace(remat=False))
    params = M.init_model_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, batch_slots=4, max_len=32)
    assert eng._packed and eng.tracer.enabled
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab_size, 4 + i)
                    .astype(np.int32),
                    max_new_tokens=3)
            for i in range(5)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_drained()
    assert eng.tracer.open_count() == 0
    spans = eng.tracer.recorder.spans()
    assert validate_request_timelines(spans) == 5
    for tid, tl in request_timelines(spans).items():
        names = [s.name for s in tl]
        assert names[0] == "queue" and names[-1] == "retire"
        ret = tl[-1]
        service = sum(s.dur for s in tl if s.name != "retire")
        assert service == pytest.approx(ret.attrs["latency_s"], abs=1e-6)
    step_keys = list(eng.metrics.snapshot()["step_latency_ms"])
    assert any(k.startswith("serve/decode|") for k in step_keys)
    assert any(k.startswith("serve/packed_prefill|") for k in step_keys)
    assert validate_chrome_trace(chrome_trace(eng.tracer)) == \
        len(spans)


def test_vision_engine_traced_run_satisfies_invariants():
    import jax

    import repro.models as M
    from repro.configs import smoke_config
    from repro.serving.vision import VisionEngine, synth_requests

    cfg = _traced(smoke_config("vit-tiny").replace(remat=False))
    params = M.init_model_params(cfg, jax.random.PRNGKey(0))
    eng = VisionEngine(cfg, params, batch_buckets=(1, 2), max_wait_s=0.0)
    reqs = synth_requests(cfg, 4, seed=2)
    for r in reqs:
        eng.submit(r)
        eng.step()
    eng.flush()
    assert eng.tracer.open_count() == 0
    spans = eng.tracer.recorder.spans()
    assert validate_request_timelines(spans) == 4
    for tid, tl in request_timelines(spans).items():
        assert [s.name for s in tl] == ["queue", "infer", "retire"]
        service = sum(s.dur for s in tl if s.name != "retire")
        assert service == pytest.approx(tl[-1].attrs["latency_s"],
                                        abs=1e-6)
    step_keys = list(eng.metrics.snapshot()["step_latency_ms"])
    assert any(k.startswith("classify|b=") for k in step_keys)


def test_disabled_engine_has_null_tracer_and_no_step_hists():
    import dataclasses

    import jax

    import repro.models as M
    from repro.configs import smoke_config
    from repro.serving.engine import Request, ServeEngine

    # tracing off is not enough to go fully dark anymore: introspection
    # (on by default, DESIGN.md section 12) keeps step timing alive for
    # the MFU join — only disabling both drops every per-dispatch cost
    cfg = smoke_config("llama3-8b").replace(remat=False)
    cfg = cfg.replace(introspect=dataclasses.replace(cfg.introspect,
                                                     enable=False))
    params = M.init_model_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, batch_slots=2, max_len=32)
    assert eng.tracer is NULL_TRACER and not eng._step_times
    rng = np.random.default_rng(1)
    req = Request(uid=0, prompt=rng.integers(0, cfg.vocab_size, 5)
                  .astype(np.int32), max_new_tokens=2)
    eng.submit(req)
    eng.run_until_drained()
    assert eng.metrics.snapshot()["step_latency_ms"] == {}
    assert eng.tracer.recorder.total == 0


def test_untraced_engine_still_records_step_times_for_mfu():
    import jax

    import repro.models as M
    from repro.configs import smoke_config
    from repro.serving.engine import Request, ServeEngine

    # default config: tracing off, introspection on -> no spans, but the
    # per-program step histograms the MFU join needs DO accumulate
    cfg = smoke_config("llama3-8b").replace(remat=False)
    params = M.init_model_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, batch_slots=2, max_len=32)
    assert eng.tracer is NULL_TRACER and eng._step_times
    rng = np.random.default_rng(1)
    req = Request(uid=0, prompt=rng.integers(0, cfg.vocab_size, 5)
                  .astype(np.int32), max_new_tokens=2)
    eng.submit(req)
    eng.run_until_drained()
    assert eng.metrics.snapshot()["step_latency_ms"] != {}
    assert eng.tracer.recorder.total == 0
