"""The executable int4 expert-stack path (DESIGN.md section 13).

Covers the mixed-scheme sub-int8 contract end to end: nibble pack/unpack
round-trips, the packed grouped kernel bit-identical to the int4 fake-quant
oracle (including odd contraction dims and empty groups, in interpret
mode), the materialization contract of ``ptq_model(..., materialize="int4")``
(experts packed uint8, sensitive sites int8), the scheme-map validation
surface, logit fidelity of the real-int4 forward against the mixed fake
oracle, the no-unpacked-expert-copy property of the jitted forward (neither
fp NOR full-width int8), dtype-aware memory accounting, and serving decode
on a mixed int4/int8 tree.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.models as M
from repro.configs import get_shape, smoke_config
from repro.core.quant.linear_quant import quantize_weight
from repro.core.quant.ptq import (
    DEFAULT_INT4_SCHEME, calibrate_model, ptq_model, quantized_config,
)
from repro.core.quant.qtypes import (
    is_int4_leaf, is_int8_leaf, pack_int4, packed_rows, quantize_sym,
    unpack_int4,
)
from repro.kernels import ref
from repro.kernels.expert_linear import grouped_matmul
from repro.serving.engine import Request, ServeEngine


def _scheme_cfg(cfg, scheme_map=DEFAULT_INT4_SCHEME):
    return cfg.replace(
        quant=dataclasses.replace(cfg.quant, scheme_map=scheme_map))


# ---------------------------------------------------------------------------
# Packing primitives
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("din", [8, 7, 2, 1])
def test_pack_unpack_roundtrip_exact(rng, din):
    """pack_int4 -> unpack_int4 is the identity on int4-range values, for
    even and odd (zero-padded) contraction dims."""
    q = rng.integers(-8, 8, (3, din, 5)).astype(np.int8)
    packed = pack_int4(jnp.asarray(q))
    assert packed.dtype == jnp.uint8
    assert packed.shape == (3, packed_rows(din), 5)
    back = unpack_int4(packed, din)
    assert back.dtype == jnp.int8
    np.testing.assert_array_equal(np.asarray(back), q)
    # without the logical dim, the padded even length comes back
    full = unpack_int4(packed)
    assert full.shape == (3, 2 * packed_rows(din), 5)
    np.testing.assert_array_equal(np.asarray(full[:, :din]), q)
    if din % 2:  # the phantom odd row is the zero pad
        np.testing.assert_array_equal(np.asarray(full[:, din]),
                                      np.zeros((3, 5), np.int8))


def test_nibble_layout_low_even_high_odd():
    """byte[p] = (q[2p+1] & 0xF) << 4 | (q[2p] & 0xF): LOW nibble holds the
    EVEN row — the layout the Pallas kernel unpacks in-tile."""
    q = jnp.asarray([[[3], [-2]]], jnp.int8)  # rows 0, 1 of one column
    b = int(np.asarray(pack_int4(q))[0, 0, 0])
    assert b & 0xF == 3  # low nibble: even row
    assert (b >> 4) & 0xF == (-2) & 0xF  # high nibble: odd row


def test_int4_leaf_predicate():
    w4 = jnp.zeros((2, 3, 4), jnp.uint8)
    w8 = jnp.zeros((2, 3, 4), jnp.int8)
    assert is_int4_leaf(w4) and not is_int4_leaf(w8)
    assert is_int8_leaf(w8) and not is_int8_leaf(w4)
    assert not is_int4_leaf(jnp.zeros((4,), jnp.uint8))  # scalars/vectors


# ---------------------------------------------------------------------------
# Kernel level: nibble-packed grouped matmul vs the int4 oracle
# ---------------------------------------------------------------------------

INT4_GROUP_CASES = [
    (4, 64, 96, [40, 0, 17, 71]),
    (1, 64, 64, [130]),  # dense mode
    (8, 32, 32, [0, 0, 5, 0, 123, 1, 0, 16]),  # mostly-empty groups
    (3, 32, 48, [0, 0, 0]),  # fully empty: zero tokens routed
    (4, 31, 40, [9, 0, 4, 6]),  # odd Din: zero-padded last nibble row
]


@pytest.mark.parametrize("G,Din,Dout,sizes", INT4_GROUP_CASES)
@pytest.mark.parametrize("with_ascale", [False, True])
def test_grouped_matmul_int4_packed_bit_identical_to_oracle(
        rng, G, Din, Dout, sizes, with_ascale):
    """Packed int4 x int8 grouped kernel (interpret mode, real kernel body
    on CPU) is BIT-IDENTICAL to grouped_matmul_q4_ref — both accumulate the
    same int32 products and apply the same f32 rescale."""
    T = sum(sizes)
    gs = jnp.asarray(sizes, jnp.int32)
    xf = rng.standard_normal((T, Din)).astype(np.float32)
    a_scale = jnp.asarray(max(np.abs(xf).max(), 1e-6) / 127.0, jnp.float32) \
        if T else jnp.asarray(0.05, jnp.float32)
    x_q = quantize_sym(jnp.asarray(xf), a_scale, 8)
    wf = jnp.asarray(rng.standard_normal((G, Din, Dout)), jnp.float32)
    w_q, w_scale = quantize_weight(wf, 4)  # int4 grid, per-out-channel
    w_packed = pack_int4(w_q)
    assert w_packed.shape == (G, packed_rows(Din), Dout)

    y = grouped_matmul(
        x_q, w_packed, gs,
        w_scale=w_scale,
        a_scale=a_scale if with_ascale else None,
        block_m=32, block_n=32, interpret=True,
    )
    y_ref = ref.grouped_matmul_q4_ref(
        x_q, w_packed, gs, w_scale,
        a_scale if with_ascale else None,
    )
    assert y.shape == (T, Dout) and y.dtype == jnp.float32
    np.testing.assert_array_equal(np.asarray(y), np.asarray(y_ref))


def test_grouped_matmul_int4_rejects_fp_activations(rng):
    """W4A8 means int8 activations — fp rows against a packed stack is a
    caller bug, not something to quantize silently at this layer."""
    x = jnp.asarray(rng.standard_normal((8, 16)), jnp.float32)
    w = jnp.zeros((2, 8, 8), jnp.uint8)
    gs = jnp.asarray([5, 3], jnp.int32)
    with pytest.raises(TypeError, match="int8"):
        grouped_matmul(x, w, gs, w_scale=jnp.ones((2, 8)), interpret=True)


def test_grouped_matmul_int4_rejects_wrong_packed_rows(rng):
    x = jnp.zeros((8, 16), jnp.int8)
    w = jnp.zeros((2, 16, 8), jnp.uint8)  # should be ceil(16/2) = 8 rows
    gs = jnp.asarray([5, 3], jnp.int32)
    with pytest.raises(ValueError, match="pack"):
        grouped_matmul(x, w, gs, w_scale=jnp.ones((2, 8)), interpret=True)


# ---------------------------------------------------------------------------
# PTQ materialization + end-to-end fidelity on the paper's MoE-ViT
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def moe_vit_int4():
    cfg = smoke_config("m3vit-small").replace(remat=False)
    shape = get_shape("train_4k").replace(seq_len=24, global_batch=2)
    params = M.init_model_params(cfg, jax.random.PRNGKey(0))
    batches = [M.synth_batch(cfg, shape, jax.random.PRNGKey(i))
               for i in range(2)]
    taps = calibrate_model(cfg, params, batches)
    p_int4 = ptq_model(cfg, params, taps, materialize="int4")
    # the mixed fake oracle: same scheme map, quantize-dequantize in f32
    p_fake = ptq_model(_scheme_cfg(cfg), params, taps)
    return cfg, params, batches, taps, p_int4, p_fake


def test_int4_materialization_contract(moe_vit_int4):
    """Expert stacks are stored nibble-packed uint8 (half the input rows)
    with per-output-channel scales; every sensitive site stays int8."""
    cfg, params, batches, taps, p, _ = moe_vit_int4
    moe = p["pairs_moe"]["moe"]
    n_pairs = cfg.num_layers // 2
    E, D = cfg.moe.num_experts, cfg.d_model
    hid = cfg.moe.d_ff * (2 if cfg.glu else 1)
    assert moe["wi"].dtype == jnp.uint8
    assert moe["wi"].shape == (n_pairs, E, packed_rows(D), hid)
    assert moe["wi_scale"].shape == (n_pairs, E, hid)
    assert moe["wi_as"].shape == (n_pairs,)
    assert moe["wo"].dtype == jnp.uint8
    assert moe["wo"].shape == (n_pairs, E, packed_rows(cfg.moe.d_ff), D)
    assert moe["wo_scale"].shape == (n_pairs, E, D)
    assert moe["wo_a_scale"].shape == (n_pairs,)
    # sensitive sites: router, attention, head, patch all stay int8
    assert moe["gate"].dtype == jnp.int8
    for grp in ("pairs_dense", "pairs_moe"):
        for k in ("wq", "wk", "wv", "wo"):
            assert p[grp]["attn"][k].dtype == jnp.int8
    assert p["head"].dtype == jnp.int8
    assert p["patch_proj"].dtype == jnp.int8
    # the packed stacks round-trip to the same int4 codes the oracle uses
    w_q, _ = quantize_weight(
        ptq_model(cfg, params, taps, fold_only=True)["pairs_moe"]["moe"]["wi"],
        4)
    np.testing.assert_array_equal(
        np.asarray(unpack_int4(moe["wi"], D)), np.asarray(w_q))


def test_int4_fake_oracle_keeps_fp_leaves(moe_vit_int4):
    """The mixed fake-quant oracle simulates the 4-bit grid in f32 — no
    stored-integer leaf anywhere."""
    _, _, _, _, _, p_fake = moe_vit_int4
    assert all(leaf.dtype not in (jnp.int8, jnp.uint8)
               for leaf in jax.tree.leaves(p_fake))


def test_materialize_mode_validation(moe_vit_int4):
    cfg, params, _, taps, _, _ = moe_vit_int4
    with pytest.raises(ValueError, match="fake, int8, int4"):
        ptq_model(cfg, params, taps, materialize="int2")


def test_scheme_map_validation(moe_vit_int4):
    cfg, params, _, taps, _, _ = moe_vit_int4
    # unknown scheme name
    with pytest.raises(ValueError, match="unknown scheme"):
        ptq_model(_scheme_cfg(cfg, (("moe.wi", "int2"),)), params, taps,
                  materialize="int4")
    # int4 at a sensitive site is rejected up front
    with pytest.raises(ValueError, match="sensitive sites"):
        ptq_model(_scheme_cfg(cfg, (("attn.wq", "int4"),)), params, taps,
                  materialize="int4")
    # int4 materialization with an all-int8 map names no int4 site
    with pytest.raises(ValueError, match="names no int4"):
        ptq_model(_scheme_cfg(cfg, (("moe.wi", "int8"),)), params, taps,
                  materialize="int4")
    # int8 materialization must not silently ignore an int4-bearing map
    with pytest.raises(ValueError, match="materialize='int4'"):
        ptq_model(_scheme_cfg(cfg), params, taps, materialize="int8")


def test_int4_on_dense_model_raises():
    """No MoE expert stack -> nothing int4 can target: loud error, not a
    silently all-int8 tree."""
    cfg = smoke_config("vit-tiny").replace(remat=False)
    shape = get_shape("train_4k").replace(seq_len=24, global_batch=2)
    params = M.init_model_params(cfg, jax.random.PRNGKey(0))
    taps = calibrate_model(
        cfg, params, [M.synth_batch(cfg, shape, jax.random.PRNGKey(0))])
    with pytest.raises(ValueError, match="no int4 leaves"):
        ptq_model(cfg, params, taps, materialize="int4")


def test_int4_forward_matches_mixed_fake_oracle(moe_vit_int4):
    """Real packed-int4 execution and the mixed quantize-dequantize
    simulation are the same computation up to accumulation-order rounding."""
    cfg, _, batches, _, p_int4, p_fake = moe_vit_int4
    qcfg = quantized_config(cfg)
    lg_fake, _ = M.forward(p_fake, qcfg, batches[0])
    lg_int4, _ = M.forward(p_int4, qcfg, batches[0])
    assert bool(jnp.isfinite(lg_int4).all())
    scale = float(jnp.std(lg_fake)) + 1e-9
    assert float(jnp.max(jnp.abs(lg_fake - lg_int4))) / scale < 1e-4


def test_jitted_forward_materializes_no_unpacked_expert_copy(moe_vit_int4):
    """The jitted forward consumes the packed uint8 stacks directly; no
    dequantized fp copy AND no unpacked full-width int8 copy of the expert
    weights appears anywhere in the program (the nibble-planar CPU lowering
    contracts half-width planes; TPU unpacks in-tile)."""
    cfg, _, batches, _, p_int4, _ = moe_vit_int4
    qcfg = quantized_config(cfg)
    jaxpr = str(jax.make_jaxpr(
        lambda p, b: M.forward(p, qcfg, b)[0]
    )(p_int4, batches[0]))
    n_pairs = cfg.num_layers // 2
    E, D = qcfg.moe.num_experts, qcfg.d_model
    hid = qcfg.moe.d_ff * (2 if qcfg.glu else 1)
    leaked = [
        f"{dt}[{dims}]"
        for dt in ("f32", "bf16", "i8")  # i8 = unpacked int4 would defeat
        for dims in (                    # the memory win
            f"{E},{D},{hid}", f"{n_pairs},{E},{D},{hid}",
            f"{E},{qcfg.moe.d_ff},{D}", f"{n_pairs},{E},{qcfg.moe.d_ff},{D}",
        )
        if f"{dt}[{dims}]" in jaxpr
    ]
    assert not leaked, f"unpacked expert weight copies found: {leaked}"
    # the packed stacks themselves are consumed by the program
    assert f"u8[{n_pairs},{E},{packed_rows(D)},{hid}]" in jaxpr
    assert f"u8[{n_pairs},{E},{packed_rows(qcfg.moe.d_ff)},{D}]" in jaxpr
    assert "ragged_dot" in jaxpr


def test_param_byte_breakdown_halves_expert_bytes(moe_vit_int4):
    """Dtype-aware accounting (memory watermark input): the int4 tree's
    expert-stack bytes are exactly half the int8 tree's, and the packed
    bytes are attributed to the uint8 bucket."""
    from repro.serving.introspect import param_byte_breakdown

    cfg, params, _, taps, p_int4, _ = moe_vit_int4
    p_int8 = ptq_model(cfg, params, taps, materialize="int8")
    b8 = param_byte_breakdown(p_int8)
    b4 = param_byte_breakdown(p_int4)
    assert b8["int4_packed_bytes"] == 0
    assert b4["int4_packed_bytes"] > 0
    # even dims here: ceil(D/2) = D/2 exactly
    assert b4["expert_stack_bytes"] * 2 == b8["expert_stack_bytes"]
    assert b4["by_dtype"]["uint8"] == b4["int4_packed_bytes"]
    assert b4["int4_packed_bytes"] == b4["expert_stack_bytes"]


# ---------------------------------------------------------------------------
# Serving: ServeEngine decode over a mixed int4/int8 QuantizedParams tree
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def moe_lm_int4():
    cfg = smoke_config("olmoe-1b-7b").replace(remat=False)
    shape = get_shape("train_4k").replace(seq_len=24, global_batch=2)
    params = M.init_model_params(cfg, jax.random.PRNGKey(0))
    batches = [M.synth_batch(cfg, shape, jax.random.PRNGKey(i))
               for i in range(2)]
    taps = calibrate_model(cfg, params, batches)
    qcfg = quantized_config(cfg)
    return qcfg, ptq_model(_scheme_cfg(cfg), params, taps), \
        ptq_model(cfg, params, taps, materialize="int4")


def test_serve_engine_decodes_int4_params(moe_lm_int4):
    """Continuous-batching decode over the mixed int4/int8 tree matches the
    mixed fake-quant engine token for token (greedy)."""
    qcfg, p_fake, p_int4 = moe_lm_int4
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, qcfg.vocab_size, n).astype(np.int32)
               for n in (5, 3)]
    outs = []
    for p in (p_int4, p_fake):
        eng = ServeEngine(qcfg, p, batch_slots=2, max_len=32)
        reqs = [Request(uid=i, prompt=pr, max_new_tokens=4)
                for i, pr in enumerate(prompts)]
        for r in reqs:
            eng.submit(r)
        eng.run_until_drained()
        outs.append([tuple(r.generated) for r in reqs])
    assert outs[0] == outs[1]


def test_build_serve_step_accepts_int4_params(moe_lm_int4):
    """The jitted decode step lowers and runs with packed uint8 expert
    leaves and their scale siblings."""
    from repro.launch.mesh import make_host_mesh
    from repro.serving.engine import build_serve_step

    qcfg, _, p_int4 = moe_lm_int4
    B, S = 2, 16
    shape = get_shape("decode_32k").replace(seq_len=S, global_batch=B)
    step = build_serve_step(qcfg, shape, make_host_mesh(),
                            donate_cache=False, params=p_int4)
    mod = M.module_for(qcfg)
    cache = mod.init_cache(qcfg, B, S, dtype=jnp.bfloat16)
    tokens = jnp.zeros((B, 1), jnp.int32)
    logits, _ = step(p_int4, tokens, cache, jnp.zeros((), jnp.int32))
    assert logits.shape == (B, 1, qcfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
