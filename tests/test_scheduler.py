"""MicroBatcher semantics: bucketed admission, FIFO order, deadline flush,
backpressure, drain, and the pad ladder (DESIGN.md section 6)."""
import pytest

from repro.serving.scheduler import Backpressure, MicroBatcher


class FakeClock:
    def __init__(self, t: float = 0.0) -> None:
        self.t = t

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


def test_bucketed_admission_never_mixes_buckets():
    """Items with different bucket keys must never share a batch."""
    clk = FakeClock()
    mb = MicroBatcher(bucket_of=lambda s: len(s), batch_sizes=(4,),
                      max_wait_s=0.0, clock=clk)
    for item in ("a", "bb", "c", "dd", "e"):
        mb.submit(item)
    seen = []
    while (batch := mb.poll()) is not None:
        assert len({len(i) for i in batch.items}) == 1, "mixed-shape batch"
        seen.append(batch.items)
    assert mb.depth == 0
    # oldest-head bucket releases first
    assert seen[0] == ("a", "c", "e")
    assert seen[1] == ("bb", "dd")


def test_fifo_within_bucket():
    clk = FakeClock()
    mb = MicroBatcher(batch_sizes=(2,), max_wait_s=0.0, clock=clk)
    for i in range(5):
        mb.submit(i)
    order = []
    while (batch := mb.poll()) is not None:
        order.extend(batch.items)
    assert order == [0, 1, 2, 3, 4]
    assert mb.pending_items() == []


def test_full_bucket_releases_before_deadline():
    clk = FakeClock()
    mb = MicroBatcher(batch_sizes=(1, 4), max_wait_s=10.0, clock=clk)
    for i in range(4):
        mb.submit(i)
    batch = mb.poll()  # full batch: no waiting for the deadline
    assert batch is not None and len(batch.items) == 4
    assert batch.pad_to == 4


def test_deadline_flushes_partial_batch():
    clk = FakeClock()
    mb = MicroBatcher(batch_sizes=(8,), max_wait_s=1.0, clock=clk)
    mb.submit("x")
    assert mb.poll() is None, "partial batch must wait for the deadline"
    clk.advance(0.5)
    assert mb.poll() is None
    clk.advance(0.6)  # oldest item has now waited 1.1s > max_wait
    batch = mb.poll()
    assert batch is not None and batch.items == ("x",)
    assert batch.waited_s == pytest.approx(1.1)


def test_backpressure_bound():
    mb = MicroBatcher(batch_sizes=(4,), max_pending=2, clock=FakeClock())
    mb.submit(0)
    mb.submit(1)
    with pytest.raises(Backpressure):
        mb.submit(2)
    # forming a batch frees queue space again
    assert mb.poll() is not None
    mb.submit(2)


def test_drain_releases_partials_immediately():
    clk = FakeClock()
    mb = MicroBatcher(batch_sizes=(8,), max_wait_s=100.0, clock=clk)
    for i in range(3):
        mb.submit(i)
    assert mb.poll() is None
    mb.drain()
    batch = mb.poll()
    assert batch is not None and batch.items == (0, 1, 2)
    assert mb.depth == 0
    mb.drain(False)
    mb.submit(9)
    assert mb.poll() is None, "deadline semantics restored after drain"


def test_pad_ladder_and_limit():
    clk = FakeClock()
    mb = MicroBatcher(batch_sizes=(1, 4, 8), max_wait_s=0.0, clock=clk)
    for i in range(6):
        mb.submit(i)
    # limit caps the batch below max_batch (ServeEngine free-slot admission)
    batch = mb.poll(limit=3)
    assert len(batch.items) == 3 and batch.pad_to == 4
    batch = mb.poll()
    assert len(batch.items) == 3 and batch.pad_to == 4
    mb.submit(9)
    batch = mb.poll()
    assert len(batch.items) == 1 and batch.pad_to == 1
    assert mb.poll() is None


def test_pack_fills_fifo_prefix_to_budget():
    """poll_pack takes the maximal FIFO prefix whose lengths fit the
    budget — and stops at the first non-fitting request (strict prefix)."""
    clk = FakeClock()
    mb = MicroBatcher(batch_sizes=(8,), max_wait_s=100.0, clock=clk)
    for L in (10, 20, 30, 5):
        mb.submit(L)
    plan = mb.poll_pack(budget=64, length_of=lambda x: x)
    # 10+20+30 = 60 fits; 5 would too, but the pack is ready the moment it
    # cannot grow with the NEXT item... here 60+5=65 > 64: blocked -> ready
    assert plan is not None
    assert plan.items == (10, 20, 30)
    assert plan.total == 60 and plan.budget == 64
    assert mb.depth == 1 and mb.pending_items() == [5]


def test_pack_waits_for_deadline_then_flushes():
    """An unblocked partial pack coalesces until max_wait, then releases
    (deadline flush); drain releases it immediately."""
    clk = FakeClock()
    mb = MicroBatcher(batch_sizes=(8,), max_wait_s=1.0, clock=clk)
    mb.submit(4)
    mb.submit(4)
    assert mb.poll_pack(budget=64, length_of=lambda x: x) is None, \
        "pack can still grow and the deadline has not passed"
    clk.advance(1.5)
    plan = mb.poll_pack(budget=64, length_of=lambda x: x)
    assert plan is not None and plan.items == (4, 4)
    assert plan.waited_s == pytest.approx(1.5)
    mb.submit(7)
    mb.drain()
    plan = mb.poll_pack(budget=64, length_of=lambda x: x)
    assert plan is not None and plan.items == (7,)
    mb.drain(False)


def test_pack_long_prompt_never_starved():
    """Strict-prefix formation: a long prompt at the head is next no matter
    how many smaller prompts queue behind it (no skip-ahead starvation)."""
    clk = FakeClock()
    mb = MicroBatcher(batch_sizes=(8,), max_wait_s=0.0, clock=clk)
    mb.submit(50)  # long head: fills most of the budget alone
    for _ in range(6):
        mb.submit(8)
    plan = mb.poll_pack(budget=64, length_of=lambda x: x)
    assert plan.items[0] == 50, "head must lead the pack"
    assert plan.items == (50, 8)  # 50+8=58; +8 more would exceed 64
    plan = mb.poll_pack(budget=64, length_of=lambda x: x)
    assert plan.items == (8,) * 5


def test_pack_item_limit_and_oversized_head():
    clk = FakeClock()
    mb = MicroBatcher(batch_sizes=(8,), max_wait_s=0.0, clock=clk)
    for _ in range(5):
        mb.submit(4)
    plan = mb.poll_pack(budget=64, length_of=lambda x: x, limit=2)
    assert plan is not None and plan.items == (4, 4), \
        "limit caps pack size (engine passes its free decode slots)"
    mb.submit(100)
    for _ in range(3):  # clear the short ones first
        mb.poll_pack(budget=64, length_of=lambda x: x, limit=1)
    with pytest.raises(ValueError, match="exceeds the pack budget"):
        mb.poll_pack(budget=64, length_of=lambda x: x)


def test_oldest_wait_and_depth_tracking():
    clk = FakeClock()
    mb = MicroBatcher(batch_sizes=(4,), max_wait_s=100.0, clock=clk)
    assert mb.oldest_wait() == 0.0
    mb.submit("a")
    clk.advance(2.0)
    mb.submit("b")
    assert mb.depth == 2
    assert mb.oldest_wait() == pytest.approx(2.0)
    assert mb.pending_items() == ["a", "b"]
