"""VisionEngine end to end: the MoE-ViT request path over fp, fake-quant,
and materialized-int8 QuantizedParams trees (DESIGN.md section 6).

The fidelity contract mirrors tests/test_int8_path.py: the fake-quant tree
(quantize-dequantize executed in f32) is the numerical oracle for the
stored-int8 execution — served top-1 classes must agree.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.models as M
from repro.configs import get_shape, smoke_config
from repro.core.quant.ptq import calibrate_model, ptq_model, quantized_config
from repro.serving.scheduler import Backpressure
from repro.serving.vision import VisionEngine, VisionRequest, synth_requests


@pytest.fixture(scope="module")
def moe_vit_trees():
    cfg = smoke_config("m3vit-small").replace(remat=False)
    shape = get_shape("train_4k").replace(seq_len=24, global_batch=2)
    params = M.init_model_params(cfg, jax.random.PRNGKey(0))
    batches = [M.synth_batch(cfg, shape, jax.random.PRNGKey(i))
               for i in range(2)]
    taps = calibrate_model(cfg, params, batches)
    return (cfg, params, ptq_model(cfg, params, taps),
            ptq_model(cfg, params, taps, materialize="int8"))


def _serve(cfg, params, reqs, **kw):
    eng = VisionEngine(cfg, params, **kw)
    for r in reqs:
        eng.submit(r)
        eng.step()
    eng.flush()
    assert all(r.done for r in reqs)
    return eng


def test_vision_engine_serves_and_meters(moe_vit_trees):
    """Responses are well-formed; counters, FPS window, and per-expert
    occupancy are all populated."""
    cfg, params, _, _ = moe_vit_trees
    reqs = synth_requests(cfg, 7, seed=3)
    eng = _serve(cfg, params, reqs, batch_buckets=(1, 4), max_wait_s=0.0,
                 top_k=3)
    for r in reqs:
        assert r.classes.shape == (3,) and r.probs.shape == (3,)
        assert all(0 <= c < cfg.num_classes for c in r.classes)
        assert np.all(np.diff(r.probs) <= 0), "probs must be descending"
        assert r.latency_s is not None and r.latency_s >= 0
    snap = eng.metrics.snapshot()
    assert snap["counters"]["submitted"] == 7
    assert snap["counters"]["completed"] == 7
    assert snap["counters"]["frames"] == 7
    assert snap["latency_ms"]["n"] == 7
    assert np.isfinite(snap["fps"]) and snap["fps"] > 0
    # every MoE layer routes top_k slots per token: occupancy accumulated
    assert sum(snap["expert_tokens"]) > 0
    assert sum(snap["expert_occupancy"]) == pytest.approx(1.0)


def test_engine_results_match_direct_forward(moe_vit_trees):
    """Batched/padded engine serving must return exactly the classes of the
    bare jitted forward on each single image (padding never leaks)."""
    cfg, params, _, _ = moe_vit_trees
    reqs = synth_requests(cfg, 5, seed=11)
    _serve(cfg, params, reqs, batch_buckets=(4,), max_wait_s=0.0, top_k=4)
    for r in reqs:
        out = M.classify(params, cfg, jnp.asarray(r.patches)[None], top_k=4)
        np.testing.assert_array_equal(r.classes, np.asarray(out["classes"])[0])
        np.testing.assert_allclose(r.probs, np.asarray(out["probs"])[0],
                                   rtol=1e-5, atol=1e-6)


def test_int8_serving_matches_fake_quant_oracle_top1(moe_vit_trees):
    """End-to-end: serving the materialized-int8 tree reproduces the f32
    fake-quant oracle's top-1 class per image (same quantization grid)."""
    cfg, _, p_fake, p_int8 = moe_vit_trees
    qcfg = quantized_config(cfg)
    reqs_a = synth_requests(cfg, 9, seed=5)
    reqs_b = synth_requests(cfg, 9, seed=5)
    _serve(qcfg, p_fake, reqs_a, batch_buckets=(1, 4), max_wait_s=0.0)
    _serve(qcfg, p_int8, reqs_b, batch_buckets=(1, 4), max_wait_s=0.0)
    top1_fake = [int(r.classes[0]) for r in reqs_a]
    top1_int8 = [int(r.classes[0]) for r in reqs_b]
    assert top1_fake == top1_int8
    for a, b in zip(reqs_a, reqs_b):
        np.testing.assert_allclose(a.probs, b.probs, rtol=1e-3, atol=1e-4)


def test_int8_serving_materializes_no_fp_expert_copy(moe_vit_trees):
    """The engine's jitted unit of work consumes the int8 expert stacks
    directly — no f32/bf16 dequantized expert-weight copy in the program."""
    cfg, _, _, p_int8 = moe_vit_trees
    qcfg = quantized_config(cfg)
    x = jnp.zeros((2, cfg.image_tokens - 1, 768), jnp.float32)
    jaxpr = str(jax.make_jaxpr(
        lambda p, b: M.classify(p, qcfg, b, top_k=5)
    )(p_int8, x))
    n_pairs = qcfg.num_layers // 2
    E, D = qcfg.moe.num_experts, qcfg.d_model
    hid = qcfg.moe.d_ff * (2 if qcfg.glu else 1)
    fp_expert_shapes = [
        f"{dt}[{dims}]"
        for dt in ("f32", "bf16")
        for dims in (
            f"{E},{D},{hid}", f"{n_pairs},{E},{D},{hid}",
            f"{E},{qcfg.moe.d_ff},{D}", f"{n_pairs},{E},{qcfg.moe.d_ff},{D}",
        )
    ]
    leaked = [s for s in fp_expert_shapes if s in jaxpr]
    assert not leaked, f"fp dequantized expert weight copies found: {leaked}"
    assert f"i8[{n_pairs},{E},{D},{hid}]" in jaxpr


def test_backpressure_surfaces_to_clients(moe_vit_trees):
    cfg, params, _, _ = moe_vit_trees
    eng = VisionEngine(cfg, params, batch_buckets=(4,), max_wait_s=100.0,
                       max_pending=2)
    reqs = synth_requests(cfg, 3)
    eng.submit(reqs[0])
    eng.submit(reqs[1])
    with pytest.raises(Backpressure):
        eng.submit(reqs[2])
    assert eng.metrics.counters["rejected"] == 1
    eng.flush()  # queued work still completes
    assert reqs[0].done and reqs[1].done


def test_double_buffered_dispatch_keeps_batches_in_flight(moe_vit_trees):
    """With enough queued work, a second batch is dispatched before the
    first is retired (the enqueue-N+1-while-N-in-flight property)."""
    cfg, params, _, _ = moe_vit_trees
    eng = VisionEngine(cfg, params, batch_buckets=(2,), max_wait_s=0.0,
                       max_inflight=2)
    for r in synth_requests(cfg, 4, seed=1):
        eng.submit(r)
    eng._dispatch_ready()
    assert len(eng._inflight) == 2, "both batches should be in flight"
    eng.flush()
    assert eng.metrics.counters["frames"] == 4


def test_plain_vit_family_serves_without_expert_metrics():
    cfg = smoke_config("vit-tiny").replace(remat=False)
    params = M.init_model_params(cfg, jax.random.PRNGKey(0))
    reqs = synth_requests(cfg, 3, seed=2)
    eng = _serve(cfg, params, reqs, batch_buckets=(1, 2), max_wait_s=0.0)
    assert eng.metrics.snapshot()["expert_tokens"] == []
    assert all(r.done for r in reqs)
