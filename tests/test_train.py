"""Training-substrate integration: optimization actually works, checkpoints
round-trip bit-exactly, resume is deterministic, preemption drains, INT8
gradient compression converges via error feedback."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.models as M
from repro.configs import get_shape, smoke_config
from repro.data import SyntheticPipeline
from repro.launch.mesh import make_host_mesh
from repro.optim import (
    adafactor,
    adamw,
    compress_grads,
    constant,
    decompress_sum,
    init_compress_state,
    make_optimizer,
    warmup_cosine,
)
from repro.train.trainer import Trainer, TrainerConfig

SHAPE = get_shape("train_4k").replace(seq_len=64, global_batch=4)


def _mini_cfg(arch="llama3-8b"):
    cfg = smoke_config(arch)
    return cfg.replace(num_layers=2, remat=False)


def test_loss_decreases_on_bigram_task(tmp_path):
    cfg = _mini_cfg()
    tc = TrainerConfig(total_steps=30, lr=5e-3, warmup_steps=5, log_every=100)
    tr = Trainer(cfg, SHAPE, make_host_mesh(), tc)
    tr.run()
    first = np.mean([h["loss"] for h in tr.history[:5]])
    last = np.mean([h["loss"] for h in tr.history[-5:]])
    assert last < first - 0.2, (first, last)


def test_checkpoint_resume_is_deterministic(tmp_path):
    """Train 10 steps straight vs 5 + restore + 5: identical final loss."""
    cfg = _mini_cfg()
    mesh = make_host_mesh()
    tc_a = TrainerConfig(total_steps=10, lr=1e-3, log_every=100,
                         checkpoint_dir=str(tmp_path / "a"),
                         checkpoint_every=100)
    tr_a = Trainer(cfg, SHAPE, mesh, tc_a)
    state_a = tr_a.run()

    tc_b5 = TrainerConfig(total_steps=5, lr=1e-3, log_every=100,
                          checkpoint_dir=str(tmp_path / "b"),
                          checkpoint_every=5)
    tr_b = Trainer(cfg, SHAPE, mesh, tc_b5)
    tr_b.run()
    tc_b10 = TrainerConfig(total_steps=10, lr=1e-3, log_every=100,
                           checkpoint_dir=str(tmp_path / "b"),
                           checkpoint_every=100)
    tr_b2 = Trainer(cfg, SHAPE, mesh, tc_b10)
    state_b = tr_b2.run()  # restores step-5 checkpoint, runs 5 more

    assert int(state_a.step) == int(state_b.step) == 10
    for a, b in zip(jax.tree.leaves(state_a.params),
                    jax.tree.leaves(state_b.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_preemption_drains_with_checkpoint(tmp_path):
    cfg = _mini_cfg()
    tc = TrainerConfig(total_steps=50, lr=1e-3, log_every=100,
                       checkpoint_dir=str(tmp_path), checkpoint_every=1000)
    tr = Trainer(cfg, SHAPE, make_host_mesh(), tc)

    def on_step(step, rec):
        if step == 3:
            tr.guard.request()  # simulated SIGTERM

    state = tr.run(on_step=on_step)
    assert int(state.step) == 4  # drained right after the preempt signal
    assert tr.ckpt.latest_step() == 4  # checkpoint written on drain


def test_grad_compression_error_feedback(rng):
    """Quantize->dequantize with error feedback: the *accumulated* gradient
    over steps is unbiased (residual carries rounding error forward)."""
    g_true = {"w": jnp.asarray(rng.standard_normal((32, 32)), jnp.float32)}
    state = init_compress_state(g_true)
    applied = jnp.zeros_like(g_true["w"])
    for _ in range(50):
        codes, scales, state = compress_grads(g_true, state)
        deq = decompress_sum(
            jax.tree.map(lambda c: c.astype(jnp.int32), codes), scales, 1)
        applied = applied + deq["w"]
    # mean applied gradient ~= true gradient (error feedback keeps bias ~0)
    np.testing.assert_allclose(np.asarray(applied) / 50,
                               np.asarray(g_true["w"]), atol=1e-3)


def test_grad_compression_trains(tmp_path):
    cfg = _mini_cfg()
    tc = TrainerConfig(total_steps=20, lr=5e-3, warmup_steps=5,
                       log_every=100, grad_compress=True)
    tr = Trainer(cfg, SHAPE, make_host_mesh(), tc)
    tr.run()
    first = np.mean([h["loss"] for h in tr.history[:5]])
    last = np.mean([h["loss"] for h in tr.history[-5:]])
    assert last < first - 0.1


def test_microbatch_accumulation_matches_full_batch():
    """Gradient accumulation must match the single-batch gradient."""
    from repro.train.train_step import build_train_step, init_train_state

    cfg = _mini_cfg().replace(microbatch_size=0)
    cfg_mb = cfg.replace(microbatch_size=2)
    mesh = make_host_mesh()
    opt = make_optimizer("adamw", constant(1e-3))
    batch = {
        k: jnp.asarray(v)
        for k, v in SyntheticPipeline(cfg, SHAPE, seed=0)
        .batch_for_step(0).items()
    }
    with mesh:
        s0 = init_train_state(cfg, opt, jax.random.PRNGKey(0))
        full = build_train_step(cfg, SHAPE, mesh, opt, donate=False)
        micro = build_train_step(cfg_mb, SHAPE, mesh, opt, donate=False)
        s_full, m_full = full(s0, batch)
        s_micro, m_micro = micro(s0, batch)
    for a, b in zip(jax.tree.leaves(s_full.params),
                    jax.tree.leaves(s_micro.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-5, rtol=1e-4)


@pytest.mark.parametrize("opt_name", ["adamw", "adafactor"])
def test_optimizers_reduce_quadratic(opt_name):
    """Both optimizers minimize a toy quadratic."""
    target = jnp.asarray(np.random.default_rng(0).standard_normal((8, 8)),
                         jnp.float32)
    params = {"w": jnp.zeros((8, 8), jnp.float32)}
    opt = make_optimizer(opt_name, constant(0.1))
    state = opt.init(params)
    for step in range(200):
        grads = {"w": params["w"] - target}
        params, state = opt.update(grads, state, params,
                                   jnp.asarray(step, jnp.int32))
    assert float(jnp.mean(jnp.abs(params["w"] - target))) < 0.05


def test_straggler_monitor():
    from repro.distributed.fault_tolerance import StragglerMonitor

    mon = StragglerMonitor(alpha=0.5, threshold=2.0, warmup_steps=2)
    for i in range(10):
        assert not mon.record(1.0, step=i)
    assert mon.record(5.0, step=10)  # 5x EMA -> straggler
    assert len(mon.events) == 1
    assert not mon.record(1.0, step=11)  # EMA not poisoned by the outlier


def test_elastic_mesh_shrinks_data_axis():
    from repro.distributed.fault_tolerance import elastic_mesh

    mesh = elastic_mesh((8, 1), ("data", "model"))  # only 1 CPU device
    assert mesh.shape["data"] == 1 and mesh.shape["model"] == 1
