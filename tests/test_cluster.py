"""ServingCluster: multi-replica request path, least-loaded routing,
two-level backpressure, drain, and the merge-safe metrics roll-up
(DESIGN.md section 7).

Most tests run replicas that share the single CPU device (host-side DP —
the routing/metrics logic is device-count-independent); the expert-parallel
replica test skips below 8 devices.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import requires_devices

import repro.models as M
from repro.configs import get_shape, smoke_config
from repro.core.quant.ptq import calibrate_model, ptq_model, quantized_config
from repro.serving.cluster import ServingCluster, replica_meshes
from repro.serving.metrics import ClusterMetrics, EngineMetrics, LatencyTracker
from repro.serving.scheduler import Backpressure
from repro.serving.vision import synth_requests


@pytest.fixture(scope="module")
def moe_vit_trees():
    cfg = smoke_config("m3vit-small").replace(remat=False)
    shape = get_shape("train_4k").replace(seq_len=24, global_batch=2)
    params = M.init_model_params(cfg, jax.random.PRNGKey(0))
    batches = [M.synth_batch(cfg, shape, jax.random.PRNGKey(i))
               for i in range(2)]
    taps = calibrate_model(cfg, params, batches)
    return cfg, params, ptq_model(cfg, params, taps, materialize="int8")


def _serve(cluster, reqs):
    for r in reqs:
        cluster.submit(r)
        cluster.step()
    cluster.flush()
    assert all(r.done for r in reqs)


def test_replica_meshes_split_and_oversubscribe():
    n_dev = jax.device_count()
    meshes = replica_meshes(1)
    assert len(meshes) == 1 and meshes[0].axis_names == ("model",)
    assert meshes[0].shape["model"] == n_dev
    # equal contiguous split when devices are plentiful
    meshes = replica_meshes(n_dev)
    assert len(meshes) == n_dev
    assert all(m.shape["model"] == 1 for m in meshes)
    # more replicas than devices: they share devices rather than failing
    meshes = replica_meshes(n_dev + 2)
    assert len(meshes) == n_dev + 2
    assert all(m.shape["model"] == 1 for m in meshes)


def test_cluster_serves_across_replicas(moe_vit_trees):
    cfg, params, _ = moe_vit_trees
    cluster = ServingCluster(cfg, params, replicas=2, batch_buckets=(1, 2),
                             max_wait_s=0.0, top_k=3)
    reqs = synth_requests(cfg, 10, seed=1)
    _serve(cluster, reqs)
    snap = cluster.metrics.snapshot()
    agg = snap["aggregate"]
    assert len(snap["replicas"]) == 2
    assert agg["counters"]["frames"] == 10
    assert agg["counters"]["completed"] == 10
    assert agg["counters"]["cluster_submitted"] == 10
    assert agg["latency_ms"]["n"] == 10
    assert np.isfinite(agg["fps"]) and agg["fps"] > 0
    # least-loaded routing engaged both replicas under a 10-request stream
    per_replica = [r["counters"].get("frames", 0) for r in snap["replicas"]]
    assert all(n > 0 for n in per_replica)
    assert sum(per_replica) == 10


def test_cluster_results_match_direct_forward(moe_vit_trees):
    """Routing through replicas never changes the answer (padding and
    placement leak nothing)."""
    cfg, params, _ = moe_vit_trees
    cluster = ServingCluster(cfg, params, replicas=2, batch_buckets=(2,),
                             max_wait_s=0.0, top_k=4)
    reqs = synth_requests(cfg, 6, seed=11)
    _serve(cluster, reqs)
    for r in reqs:
        out = M.classify(params, cfg, jnp.asarray(r.patches)[None], top_k=4)
        np.testing.assert_array_equal(r.classes,
                                      np.asarray(out["classes"])[0])


def test_cluster_int8_tree_serves(moe_vit_trees):
    cfg, _, p_int8 = moe_vit_trees
    qcfg = quantized_config(cfg)
    cluster = ServingCluster(qcfg, p_int8, replicas=2, batch_buckets=(1, 2),
                             max_wait_s=0.0)
    reqs = synth_requests(cfg, 5, seed=2)
    _serve(cluster, reqs)
    agg = cluster.metrics.snapshot()["aggregate"]
    assert agg["counters"]["frames"] == 5
    # occupancy summed across replicas still normalizes to 1
    assert sum(agg["expert_occupancy"]) == pytest.approx(1.0)
    assert sum(agg["expert_tokens"]) > 0


def test_cluster_two_level_backpressure(moe_vit_trees):
    cfg, params, _ = moe_vit_trees
    cluster = ServingCluster(cfg, params, replicas=2, batch_buckets=(4,),
                             max_wait_s=100.0, max_pending=3,
                             max_pending_per_replica=1)
    reqs = synth_requests(cfg, 6, seed=3)
    # per-replica bound (1 each) fills first; the front-end holds the rest
    cluster.submit(reqs[0])
    cluster.submit(reqs[1])
    cluster._route()
    assert cluster.depth == 0  # both routed, one per replica
    cluster.submit(reqs[2])
    cluster._route()
    assert cluster.depth == 1  # replicas full -> held at the front
    cluster.submit(reqs[3])
    cluster.submit(reqs[4])
    with pytest.raises(Backpressure):  # front-end bound (3) reached
        cluster.submit(reqs[5])
    assert cluster.metrics.counters["cluster_rejected"] == 1
    cluster.flush()  # everything admitted still completes
    assert all(r.done for r in reqs[:5])


@requires_devices(8)
def test_cluster_ep_replica_end_to_end(moe_vit_trees):
    """DP x EP composition: one replica spanning all devices with sharded
    expert stacks serves correctly through the cluster front-end."""
    cfg, _, p_int8 = moe_vit_trees
    qcfg = quantized_config(cfg).replace(
        moe=dataclasses.replace(quantized_config(cfg).moe,
                                moe_exec="expert_parallel"))
    cluster = ServingCluster(qcfg, p_int8, replicas=1, batch_buckets=(1, 2),
                             max_wait_s=0.0)
    assert cluster.meshes[0].shape["model"] == jax.device_count()
    reqs_a = synth_requests(cfg, 4, seed=9)
    _serve(cluster, reqs_a)
    # EP serving returns the same classes as the single-device int8 forward
    base = quantized_config(cfg)
    for r in reqs_a:
        out = M.classify(p_int8, base, jnp.asarray(r.patches)[None], top_k=5)
        np.testing.assert_array_equal(r.classes,
                                      np.asarray(out["classes"])[0])


# ---------------------------------------------------------------------------
# Merge-safe metrics
# ---------------------------------------------------------------------------

def test_latency_tracker_merge_pools_not_averages():
    """Merged percentiles come from the pooled distribution. Averaging
    per-replica p99s would be wrong — construct a case where the two
    disagree and assert we produce the pooled answer."""
    a, b = LatencyTracker(), LatencyTracker()
    for _ in range(98):
        a.record(0.010)
    a.record(1.000)
    a.record(1.000)  # a: 2% 1s tail -> per-replica p99 = 1s
    for _ in range(900):
        b.record(0.010)  # b: all 10ms
    merged = LatencyTracker.merged([a, b])
    assert len(merged) == 1000
    pooled_p99 = merged.percentile(99)
    avg_of_p99 = (a.percentile(99) + b.percentile(99)) / 2
    # pooled: the 1s outliers are 0.2% of the union -> p99 stays ~10ms;
    # averaging per-replica p99s would report ~0.5s
    assert pooled_p99 < 0.05
    assert avg_of_p99 > 0.4
    np.testing.assert_allclose(merged.percentile(50), 0.010, rtol=1e-6)


def test_latency_tracker_histogram_survives_reservoir_eviction():
    """Beyond the reservoir bound the histogram still answers percentiles
    over the FULL population (a deque-only tracker forgets old samples)."""
    t = LatencyTracker(maxlen=64)
    for _ in range(1000):
        t.record(0.001)  # old mass: 1ms
    for _ in range(10):
        t.record(1.0)  # recent mass: 1s
    assert not t.exact
    # reservoir holds only the most recent 64 (mostly 1s); the histogram
    # remembers that 99% of the population was ~1ms
    p50 = t.percentile(50)
    assert p50 < 0.01, f"p50 forgot the evicted population: {p50}"
    assert t.snapshot()["n"] == 1010


def test_cluster_metrics_window_union_fps():
    clock_t = [0.0]
    clock = lambda: clock_t[0]
    m1, m2 = EngineMetrics(clock=clock), EngineMetrics(clock=clock)
    clock_t[0] = 0.0
    m1.inc("submitted")
    m2.inc("submitted")
    clock_t[0] = 1.0
    m1.work_done(30, "frames")
    clock_t[0] = 2.0
    m2.work_done(30, "frames")
    cm = ClusterMetrics([m1, m2])
    # 60 frames over the union window [0, 2] -> 30 FPS (NOT 30+15=45)
    assert cm.fps == pytest.approx(30.0)
