"""ServingCluster: multi-replica request path, least-loaded routing,
two-level backpressure, drain, LM (ServeEngine) cluster parity through the
engine-agnostic replica protocol, and the merge-safe metrics roll-up under
replica churn (DESIGN.md sections 7-8).

Most tests run replicas that share the single CPU device (host-side DP —
the routing/metrics logic is device-count-independent); the expert-parallel
replica tests skip below 8 devices.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import requires_devices

import repro.models as M
from repro.configs import get_shape, smoke_config
from repro.core.quant.ptq import calibrate_model, ptq_model, quantized_config
from repro.serving.cluster import ServingCluster, replica_meshes
from repro.serving.engine import Request, ServeEngine
from repro.serving.metrics import ClusterMetrics, EngineMetrics, LatencyTracker
from repro.serving.replica import EngineReplica
from repro.serving.scheduler import Backpressure
from repro.serving.vision import synth_requests


@pytest.fixture(scope="module")
def moe_vit_trees():
    cfg = smoke_config("m3vit-small").replace(remat=False)
    shape = get_shape("train_4k").replace(seq_len=24, global_batch=2)
    params = M.init_model_params(cfg, jax.random.PRNGKey(0))
    batches = [M.synth_batch(cfg, shape, jax.random.PRNGKey(i))
               for i in range(2)]
    taps = calibrate_model(cfg, params, batches)
    return cfg, params, ptq_model(cfg, params, taps, materialize="int8")


def _serve(cluster, reqs):
    for r in reqs:
        cluster.submit(r)
        cluster.step()
    cluster.flush()
    assert all(r.done for r in reqs)


def test_replica_meshes_split_and_oversubscribe():
    n_dev = jax.device_count()
    meshes = replica_meshes(1)
    assert len(meshes) == 1 and meshes[0].axis_names == ("model",)
    assert meshes[0].shape["model"] == n_dev
    # equal contiguous split when devices are plentiful
    meshes = replica_meshes(n_dev)
    assert len(meshes) == n_dev
    assert all(m.shape["model"] == 1 for m in meshes)
    # more replicas than devices: they share devices rather than failing
    meshes = replica_meshes(n_dev + 2)
    assert len(meshes) == n_dev + 2
    assert all(m.shape["model"] == 1 for m in meshes)


def test_cluster_serves_across_replicas(moe_vit_trees):
    cfg, params, _ = moe_vit_trees
    cluster = ServingCluster(cfg, params, replicas=2, batch_buckets=(1, 2),
                             max_wait_s=0.0, top_k=3)
    reqs = synth_requests(cfg, 10, seed=1)
    _serve(cluster, reqs)
    snap = cluster.metrics.snapshot()
    agg = snap["aggregate"]
    assert len(snap["replicas"]) == 2
    assert agg["counters"]["frames"] == 10
    assert agg["counters"]["completed"] == 10
    assert agg["counters"]["cluster_submitted"] == 10
    assert agg["latency_ms"]["n"] == 10
    assert np.isfinite(agg["fps"]) and agg["fps"] > 0
    # least-loaded routing engaged both replicas under a 10-request stream
    per_replica = [r["counters"].get("frames", 0) for r in snap["replicas"]]
    assert all(n > 0 for n in per_replica)
    assert sum(per_replica) == 10


def test_cluster_results_match_direct_forward(moe_vit_trees):
    """Routing through replicas never changes the answer (padding and
    placement leak nothing)."""
    cfg, params, _ = moe_vit_trees
    cluster = ServingCluster(cfg, params, replicas=2, batch_buckets=(2,),
                             max_wait_s=0.0, top_k=4)
    reqs = synth_requests(cfg, 6, seed=11)
    _serve(cluster, reqs)
    for r in reqs:
        out = M.classify(params, cfg, jnp.asarray(r.patches)[None], top_k=4)
        np.testing.assert_array_equal(r.classes,
                                      np.asarray(out["classes"])[0])


def test_cluster_int8_tree_serves(moe_vit_trees):
    cfg, _, p_int8 = moe_vit_trees
    qcfg = quantized_config(cfg)
    cluster = ServingCluster(qcfg, p_int8, replicas=2, batch_buckets=(1, 2),
                             max_wait_s=0.0)
    reqs = synth_requests(cfg, 5, seed=2)
    _serve(cluster, reqs)
    agg = cluster.metrics.snapshot()["aggregate"]
    assert agg["counters"]["frames"] == 5
    # occupancy summed across replicas still normalizes to 1
    assert sum(agg["expert_occupancy"]) == pytest.approx(1.0)
    assert sum(agg["expert_tokens"]) > 0


def test_cluster_two_level_backpressure(moe_vit_trees):
    cfg, params, _ = moe_vit_trees
    cluster = ServingCluster(cfg, params, replicas=2, batch_buckets=(4,),
                             max_wait_s=100.0, max_pending=3,
                             max_pending_per_replica=1)
    reqs = synth_requests(cfg, 6, seed=3)
    # per-replica bound (1 each) fills first; the front-end holds the rest
    cluster.submit(reqs[0])
    cluster.submit(reqs[1])
    cluster._route()
    assert cluster.depth == 0  # both routed, one per replica
    cluster.submit(reqs[2])
    cluster._route()
    assert cluster.depth == 1  # replicas full -> held at the front
    cluster.submit(reqs[3])
    cluster.submit(reqs[4])
    with pytest.raises(Backpressure):  # front-end bound (3) reached
        cluster.submit(reqs[5])
    assert cluster.metrics.counters["cluster_rejected"] == 1
    cluster.flush()  # everything admitted still completes
    assert all(r.done for r in reqs[:5])


@requires_devices(8)
def test_cluster_ep_replica_end_to_end(moe_vit_trees):
    """DP x EP composition: one replica spanning all devices with sharded
    expert stacks serves correctly through the cluster front-end."""
    cfg, _, p_int8 = moe_vit_trees
    qcfg = quantized_config(cfg).replace(
        moe=dataclasses.replace(quantized_config(cfg).moe,
                                moe_exec="expert_parallel"))
    cluster = ServingCluster(qcfg, p_int8, replicas=1, batch_buckets=(1, 2),
                             max_wait_s=0.0)
    assert cluster.meshes[0].shape["model"] == jax.device_count()
    reqs_a = synth_requests(cfg, 4, seed=9)
    _serve(cluster, reqs_a)
    # EP serving returns the same classes as the single-device int8 forward
    base = quantized_config(cfg)
    for r in reqs_a:
        out = M.classify(p_int8, base, jnp.asarray(r.patches)[None], top_k=5)
        np.testing.assert_array_equal(r.classes,
                                      np.asarray(out["classes"])[0])


# ---------------------------------------------------------------------------
# LM cluster parity (engine-agnostic replica protocol)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def moe_lm_trees():
    cfg = smoke_config("olmoe-1b-7b").replace(remat=False)
    shape = get_shape("train_4k").replace(seq_len=24, global_batch=2)
    params = M.init_model_params(cfg, jax.random.PRNGKey(0))
    batches = [M.synth_batch(cfg, shape, jax.random.PRNGKey(i))
               for i in range(2)]
    taps = calibrate_model(cfg, params, batches)
    return cfg, params, ptq_model(cfg, params, taps, materialize="int8")


def _lm_requests(cfg, n, seed=0, max_new=4):
    rng = np.random.default_rng(seed)
    return [
        Request(uid=i,
                prompt=rng.integers(0, cfg.vocab_size,
                                    int(rng.integers(3, 9))).astype(np.int32),
                max_new_tokens=max_new)
        for i in range(n)
    ]


def test_engines_satisfy_replica_protocol(moe_lm_trees, moe_vit_trees):
    """Both engine families present the full EngineReplica surface (the
    cluster and the autoscaler only ever touch that surface)."""
    lm_cfg, lm_params, _ = moe_lm_trees
    vit_cfg, vit_params, _ = moe_vit_trees
    from repro.serving.vision import VisionEngine

    eng = ServeEngine(lm_cfg, lm_params, batch_slots=2, max_len=16)
    vis = VisionEngine(vit_cfg, vit_params, batch_buckets=(1,))
    for e in (eng, vis):
        assert isinstance(e, EngineReplica)
        assert e.idle and e.load == 0 and e.free_room > 0


def test_lm_cluster_greedy_parity_int8(moe_lm_trees):
    """Acceptance: >=2 ServeEngine replicas over the cluster front-end, int8
    params, fake clock — drains to the same greedy outputs as a
    single-engine run (routing, placement, and slot sharing leak nothing)."""
    cfg, _, p_int8 = moe_lm_trees
    qcfg = quantized_config(cfg)
    solo_reqs = _lm_requests(cfg, 6, seed=5)
    eng = ServeEngine(qcfg, p_int8, batch_slots=2, max_len=32)
    for r in solo_reqs:
        eng.submit(r)
    eng.run_until_drained()

    clock_t = [100.0]
    clock = lambda: clock_t[0]
    cluster = ServingCluster(qcfg, p_int8, replicas=2, engine="lm",
                             batch_slots=2, max_len=32,
                             max_pending_per_replica=2, clock=clock)
    reqs = _lm_requests(cfg, 6, seed=5)
    for r in reqs:
        cluster.submit(r)
        cluster.step()
        clock_t[0] += 0.25
    cluster.flush()
    for got, want in zip(reqs, solo_reqs):
        assert got.generated == want.generated, got.uid

    snap = cluster.metrics.snapshot()
    agg = snap["aggregate"]
    assert len(snap["replicas"]) == 2
    assert agg["counters"]["completed"] == 6
    assert agg["counters"]["cluster_submitted"] == 6
    assert agg["latency_ms"]["n"] == 6
    # fake clock drove the latency/FPS windows -> finite, deterministic
    assert np.isfinite(agg["fps"]) and agg["fps"] > 0
    # decode slots as the load signal: both replicas decoded tokens
    per_replica = [r["counters"].get("tokens", 0) for r in snap["replicas"]]
    assert all(n > 0 for n in per_replica)
    # MoE decode path reported per-expert occupancy through the roll-up
    assert sum(agg["expert_tokens"]) > 0
    # queue_wait was recorded at admission (before prefill) on each replica
    assert agg["queue_wait_ms"]["n"] == 6


def test_lm_engine_free_room_counts_decode_slots(moe_lm_trees):
    cfg, params, _ = moe_lm_trees
    eng = ServeEngine(cfg, params, batch_slots=3, max_len=16, max_pending=2)
    assert eng.free_slots == 3 and eng.free_room == 5  # 3 slots + 2 queue
    reqs = _lm_requests(cfg, 4, seed=7, max_new=8)
    for r in reqs[:3]:
        eng.submit(r)
    eng.step()  # admits all three into slots
    assert eng.inflight == 3 and eng.free_slots == 0
    assert eng.load == 3 and eng.free_room == 2  # queue room only
    eng.submit(reqs[3])
    assert eng.load == 4 and eng.free_room == 1
    assert not eng.idle
    eng.flush()
    assert eng.idle and eng.free_room == 5


def test_lm_cluster_drops_unservable_prompt(moe_lm_trees):
    """A prompt no replica can ever serve (here == the replica cache
    length) is rejected at the replica's submit and dropped by the route
    pump — counted in both rejection counters — instead of crashing
    ``step()`` or wedging the front queue; admissible traffic behind it
    still completes."""
    cfg, params, _ = moe_lm_trees
    cluster = ServingCluster(cfg, params, replicas=1, engine="lm",
                             batch_slots=2, max_len=16)
    rng = np.random.default_rng(21)
    bad = Request(uid=0, prompt=rng.integers(0, cfg.vocab_size, 16)
                  .astype(np.int32), max_new_tokens=2)
    ok = Request(uid=1, prompt=rng.integers(0, cfg.vocab_size, 5)
                 .astype(np.int32), max_new_tokens=2)
    cluster.submit(bad)
    cluster.submit(ok)
    cluster.flush()
    assert ok.generated is not None and len(ok.generated) == 2
    assert bad.generated is None, "unservable prompt must never prefill"
    counters = cluster.metrics.snapshot()["aggregate"]["counters"]
    assert counters["rejected"] == 1
    assert counters["cluster_rejected"] == 1
    assert counters["completed"] == 1


@requires_devices(8)
def test_lm_cluster_ep_replica_end_to_end(moe_lm_trees):
    """DP x EP for the LM family: one ServeEngine replica spanning all
    devices with sharded expert stacks decodes the same greedy tokens as
    the single-device int8 engine."""
    cfg, _, p_int8 = moe_lm_trees
    qcfg = quantized_config(cfg)
    solo_reqs = _lm_requests(cfg, 3, seed=11)
    eng = ServeEngine(qcfg, p_int8, batch_slots=2, max_len=32)
    for r in solo_reqs:
        eng.submit(r)
    eng.run_until_drained()

    ep_cfg = qcfg.replace(moe=dataclasses.replace(
        qcfg.moe, moe_exec="expert_parallel"))
    cluster = ServingCluster(ep_cfg, p_int8, replicas=1, engine="lm",
                             batch_slots=2, max_len=32)
    assert cluster.meshes[0].shape["model"] == jax.device_count()
    reqs = _lm_requests(cfg, 3, seed=11)
    for r in reqs:
        cluster.submit(r)
        cluster.step()
    cluster.flush()
    for got, want in zip(reqs, solo_reqs):
        assert got.generated == want.generated, got.uid


# ---------------------------------------------------------------------------
# Merge-safe metrics
# ---------------------------------------------------------------------------

def test_latency_tracker_merge_pools_not_averages():
    """Merged percentiles come from the pooled distribution. Averaging
    per-replica p99s would be wrong — construct a case where the two
    disagree and assert we produce the pooled answer."""
    a, b = LatencyTracker(), LatencyTracker()
    for _ in range(98):
        a.record(0.010)
    a.record(1.000)
    a.record(1.000)  # a: 2% 1s tail -> per-replica p99 = 1s
    for _ in range(900):
        b.record(0.010)  # b: all 10ms
    merged = LatencyTracker.merged([a, b])
    assert len(merged) == 1000
    pooled_p99 = merged.percentile(99)
    avg_of_p99 = (a.percentile(99) + b.percentile(99)) / 2
    # pooled: the 1s outliers are 0.2% of the union -> p99 stays ~10ms;
    # averaging per-replica p99s would report ~0.5s
    assert pooled_p99 < 0.05
    assert avg_of_p99 > 0.4
    np.testing.assert_allclose(merged.percentile(50), 0.010, rtol=1e-6)


def test_latency_tracker_histogram_survives_reservoir_eviction():
    """Beyond the reservoir bound the histogram still answers percentiles
    over the FULL population (a deque-only tracker forgets old samples)."""
    t = LatencyTracker(maxlen=64)
    for _ in range(1000):
        t.record(0.001)  # old mass: 1ms
    for _ in range(10):
        t.record(1.0)  # recent mass: 1s
    assert not t.exact
    # reservoir holds only the most recent 64 (mostly 1s); the histogram
    # remembers that 99% of the population was ~1ms
    p50 = t.percentile(50)
    assert p50 < 0.01, f"p50 forgot the evicted population: {p50}"
    assert t.snapshot()["n"] == 1010


def test_cluster_metrics_window_union_fps():
    clock_t = [0.0]
    clock = lambda: clock_t[0]
    m1, m2 = EngineMetrics(clock=clock), EngineMetrics(clock=clock)
    clock_t[0] = 0.0
    m1.inc("submitted")
    m2.inc("submitted")
    clock_t[0] = 1.0
    m1.work_done(30, "frames")
    clock_t[0] = 2.0
    m2.work_done(30, "frames")
    cm = ClusterMetrics([m1, m2])
    # 60 frames over the union window [0, 2] -> 30 FPS (NOT 30+15=45)
    assert cm.fps == pytest.approx(30.0)


def test_cluster_metrics_replica_churn():
    """Autoscaling churn: a replica joins mid-window, another drains out.
    Percentiles must stay *pooled* across both transitions (the drained
    replica's distribution folds into the retired accumulator — never
    averaged, never dropped), expert-occupancy sums stay stable, and the
    timeline records every transition."""
    clock_t = [0.0]
    clock = lambda: clock_t[0]
    m1 = EngineMetrics(num_experts=4, clock=clock)
    cm = ClusterMetrics([m1], clock=clock)
    cm.mark_replicas(1)
    # replica 1: 98 fast + 2 slow requests, experts 0/1 hot
    clock_t[0] = 0.0
    m1.inc("submitted", 100)
    for _ in range(98):
        m1.request_latency.record(0.010)
    m1.request_latency.record(1.0)
    m1.request_latency.record(1.0)
    m1.inc("completed", 100)
    m1.work_done(100, "frames")
    m1.add_expert_tokens(np.array([6, 4, 0, 0]))
    # replica 2 joins mid-window and serves the fast tail
    m2 = EngineMetrics(num_experts=4, clock=clock)
    clock_t[0] = 1.0
    cm.add_replica(m2)
    cm.mark_replicas(2)
    m2.inc("submitted", 900)
    for _ in range(900):
        m2.request_latency.record(0.010)
    m2.inc("completed", 900)
    m2.work_done(900, "frames")
    m2.add_expert_tokens(np.array([0, 0, 7, 3]))

    before = cm.snapshot()["aggregate"]
    assert before["latency_ms"]["n"] == 1000
    tokens_before = before["expert_tokens"]
    assert sum(tokens_before) == 20

    # replica 1 drains: fold + reset (the cluster's leave protocol)
    clock_t[0] = 2.0
    cm.remove_replica(m1)
    cm.mark_replicas(1)

    after = cm.snapshot()["aggregate"]
    # nothing lost: counts, distribution mass, occupancy all stable
    assert after["latency_ms"]["n"] == 1000
    assert after["counters"]["completed"] == 1000
    assert after["expert_tokens"] == tokens_before
    assert sum(after["expert_occupancy"]) == pytest.approx(1.0)
    # percentiles still POOLED: the 1s outliers are 0.2% of the union, so
    # p99 stays ~10ms; averaging per-replica p99s would report ~0.5s
    pooled = cm.merged_request_latency()
    assert pooled.percentile(99) < 0.05
    avg_of_p99 = (m2.request_latency.percentile(99) + 1.0) / 2
    assert avg_of_p99 > 0.4
    # fps window unions the drained replica's window with the live one
    assert np.isfinite(cm.fps) and cm.fps == pytest.approx(1000 / 1.0)
    # timeline recorded join and leave
    assert [n for _, n in cm.replica_timeline] == [1, 2, 1]
    # the drained replica rejoins with FRESH metrics -> no double count
    m1_fresh = EngineMetrics(num_experts=4, clock=clock)
    cm.add_replica(m1_fresh)
    cm.mark_replicas(2)
    assert cm.snapshot()["aggregate"]["latency_ms"]["n"] == 1000
