#!/usr/bin/env python
"""Diff two BENCH_* artifacts against a noise floor.

Matches artifacts by provenance (same bench name, schema version, and
device kind — numbers from different hardware or report layouts are not
comparable), flattens every numeric leaf into dotted metric paths, and
reports per-metric deltas, flagging the ones whose relative change
exceeds the noise floor. CI runs it as a soft-fail step against the
previous successful run's artifacts:

  python tools/bench_diff.py BENCH_old.json BENCH_new.json \
      --noise 0.05 --out bench_diff.json

Exit code is 0 unless ``--hard`` is given (then regressions beyond the
noise floor exit 1). Incomparable artifacts report why and exit 0 —
a provenance mismatch is a fact about the runs, not a failure.
"""
from __future__ import annotations

import argparse
import json
import math
import sys
from typing import Dict, List, Optional, Tuple


def flatten(obj, prefix: str = "") -> Dict[str, float]:
    """Numeric leaves of a nested report as {dotted.path: float}. Lists
    index numerically; NaNs drop (they mean "no data", not a value)."""
    out: Dict[str, float] = {}
    if isinstance(obj, dict):
        for k, v in obj.items():
            out.update(flatten(v, f"{prefix}{k}." if prefix or k else k))
    elif isinstance(obj, (list, tuple)):
        for i, v in enumerate(obj):
            out.update(flatten(v, f"{prefix}{i}."))
    elif isinstance(obj, bool):
        pass
    elif isinstance(obj, (int, float)):
        if not math.isnan(obj):
            out[prefix.rstrip(".")] = float(obj)
    return out


def comparable(a: dict, b: dict) -> Tuple[bool, str]:
    """Whether two artifacts may be compared, and why not if not."""
    pa, pb = a.get("provenance"), b.get("provenance")
    if pa is None or pb is None:
        missing = "old" if pa is None else "new"
        return False, f"{missing} artifact has no provenance block " \
                      "(predates benchmarks/provenance.py)"
    for field in ("bench", "schema_version", "device_kind", "backend"):
        va, vb = pa.get(field), pb.get(field)
        if va != vb:
            return False, f"provenance mismatch on {field!r}: " \
                          f"{va!r} vs {vb!r}"
    return True, ""


def diff(old: dict, new: dict, noise: float = 0.05,
         ignore_prefixes: Tuple[str, ...] = ("provenance.", "meta.")
         ) -> List[dict]:
    """Per-metric rows for every path present in both artifacts."""
    fa, fb = flatten(old), flatten(new)
    rows: List[dict] = []
    for path in sorted(set(fa) & set(fb)):
        if any(path.startswith(p) for p in ignore_prefixes):
            continue
        a, b = fa[path], fb[path]
        delta = b - a
        rel = (delta / abs(a)) if a else (0.0 if delta == 0 else math.inf)
        rows.append({
            "metric": path,
            "old": a,
            "new": b,
            "delta": delta,
            "rel": None if math.isinf(rel) else round(rel, 6),
            "beyond_noise": abs(rel) > noise,
        })
    return rows


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("old", help="previous BENCH_*.json")
    ap.add_argument("new", help="current BENCH_*.json")
    ap.add_argument("--noise", type=float, default=0.05,
                    help="relative noise floor (default 5%%)")
    ap.add_argument("--out", default="", help="write the diff report here")
    ap.add_argument("--hard", action="store_true",
                    help="exit 1 on any beyond-noise change")
    ap.add_argument("--top", type=int, default=20,
                    help="print at most this many beyond-noise rows")
    args = ap.parse_args(argv)

    with open(args.old) as f:
        old = json.load(f)
    with open(args.new) as f:
        new = json.load(f)

    ok, reason = comparable(old, new)
    report = {
        "old": args.old,
        "new": args.new,
        "noise": args.noise,
        "comparable": ok,
    }
    if not ok:
        report["reason"] = reason
        print(f"bench_diff: incomparable artifacts — {reason}")
        rows = []
    else:
        rows = diff(old, new, noise=args.noise)
        flagged = [r for r in rows if r["beyond_noise"]]
        report["metrics"] = len(rows)
        report["beyond_noise"] = len(flagged)
        report["rows"] = rows
        print(f"bench_diff: {len(rows)} shared metrics, "
              f"{len(flagged)} beyond the {args.noise:.0%} noise floor")
        for r in flagged[:args.top]:
            rel = "inf" if r["rel"] is None else f"{r['rel']:+.1%}"
            print(f"  {r['metric']}: {r['old']:g} -> {r['new']:g} ({rel})")
        if len(flagged) > args.top:
            print(f"  ... and {len(flagged) - args.top} more")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(report, f, indent=1)
        print(f"wrote {args.out}")
    if args.hard and ok and report.get("beyond_noise"):
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
