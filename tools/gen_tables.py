"""Generate EXPERIMENTS.md tables from dry-run artifacts.

  PYTHONPATH=src:. python tools/gen_tables.py
writes experiments/dryrun_table.md and experiments/roofline_table.md.
"""
import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks import hw  # noqa: E402
from benchmarks.roofline import model_flops, roofline_row  # noqa: E402


def fmt_bytes(b):
    if b < 0:
        return "-"
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if b < 1024:
            return f"{b:.1f} {unit}"
        b /= 1024
    return f"{b:.1f} PB"


def dryrun_table(out):
    rows = []
    for path in sorted(glob.glob("experiments/dryrun/*.json")):
        name = os.path.basename(path)[:-5]
        if name.endswith("__q") or name.endswith("__gc"):
            continue  # quantized / grad-compressed variants live in §Perf
        rec = json.load(open(path))
        arch, shape, mesh = name.split("__")[:3]
        if rec["status"] == "skipped":
            rows.append((arch, shape, mesh, "skip: " + rec["reason"][:40],
                         "-", "-", "-"))
            continue
        colls = rec["collective_kinds"]
        sched = "+".join(k.replace("all-", "a").replace("reduce-scatter", "rs")
                         .replace("collective-permute", "cp")
                         for k, v in colls.items() if v > 0) or "none"
        rows.append((
            arch, shape, mesh,
            f"ok ({rec['compile_s']}s)",
            fmt_bytes(rec["memory"]["argument_bytes"]),
            fmt_bytes(rec["memory"]["temp_bytes"]),
            sched,
        ))
    with open(out, "w") as f:
        f.write("| arch | shape | mesh | compile | args/dev | temp/dev | collectives |\n")
        f.write("|---|---|---|---|---|---|---|\n")
        for r in rows:
            f.write("| " + " | ".join(str(x) for x in r) + " |\n")
    print(f"wrote {out} ({len(rows)} rows)")


_NOTES = {
    "compute": "compute-bound: push MXU utilization (larger microbatch, "
               "int8 path)",
    "memory": "memory-bound: raise arithmetic intensity (quantize weights/KV"
              ", fuse, larger per-chip batch)",
    "collective": "collective-bound: reshard to cut cross-chip bytes or "
                  "overlap with compute",
}


def roofline_table(out, mesh="16x16"):
    from repro.configs import get_config, get_shape

    lines = ["| arch | shape | compute s | memory s | coll s | dominant | "
             "MODEL/HLO flops | roofline-frac | what would move it |",
             "|---|---|---|---|---|---|---|---|---|"]
    n = 0
    for path in sorted(glob.glob("experiments/dryrun/*.json")):
        if path.endswith("__q.json"):
            continue
        rec = json.load(open(path))
        if rec.get("status") != "ok" or rec.get("mesh") != mesh:
            continue
        cfg = get_config(rec["arch"])
        shape = get_shape(rec["shape"])
        r = roofline_row(rec, cfg, shape)
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.4f} | "
            f"{r['t_memory_s']:.4f} | {r['t_collective_s']:.4f} | "
            f"{r['dominant']} | {r['useful_ratio']:.2f} | "
            f"{100*r['roofline_fraction']:.2f}% | {_NOTES[r['dominant']]} |"
        )
        n += 1
    with open(out, "w") as f:
        f.write("\n".join(lines) + "\n")
    print(f"wrote {out} ({n} rows)")


if __name__ == "__main__":
    os.makedirs("experiments", exist_ok=True)
    dryrun_table("experiments/dryrun_table.md")
    roofline_table("experiments/roofline_table.md")
