"""Serving-stack tracing: per-request span timelines, a bounded flight
recorder, and Chrome-trace/Perfetto export (DESIGN.md section 11).

CoQMoE's contribution is latency *orchestration* — streaming attention and
reusable operators scheduled to hide latency — and the serving stack needs
the runtime equivalent of the paper's per-stage accounting: where did this
request's p99 go? ``Tracer`` answers that with a typed span timeline per
request:

  queue   submit -> pack-planner selection (admission-queue + front-end wait)
  pack    planner selection -> program dispatch (host-side buffer build)
  prefill prefill dispatch window (the packed ``[1, bucket]`` program)
  decode  decode-slot residency (first token ready -> slot freed)
  retire  retirement handoff -> tokens materialized / callbacks fired

The five phases share their boundary timestamps, so a completed request's
queue+pack+prefill+decode durations sum *exactly* to its recorded
end-to-end latency (the acceptance invariant tests/test_trace.py asserts);
``retire`` extends past it (retirement is off the latency path by design —
DESIGN.md section 10).

Spans land in a ``FlightRecorder``: a bounded, thread-safe ring buffer with
the same lock discipline as ``EngineMetrics`` (one RLock; the retirement
thread records while the decode loop records and an exporter snapshots).
When full, the oldest spans are evicted and counted in ``dropped`` — the
recorder always holds the most recent window, which is what a flight
recorder is for.

Overhead contract: engines hold ``NULL_TRACER`` (``enabled = False``) when
``cfg.trace.enable`` is off, and every instrumentation site is guarded by
that flag — the disabled path is one attribute read per call site, nothing
allocates, nothing locks (benchmarks/serve_trace_overhead.py measures both
paths).

Export: ``chrome_trace`` renders any window of one or more recorders as
Chrome-trace JSON (the Perfetto UI's native format): one *process* per
replica, one *thread* track per request plus thread 0 for the engine's
per-program step spans. ``validate_chrome_trace`` and
``validate_request_timelines`` are the well-formedness checks CI runs on
the exported artifact.
"""
from __future__ import annotations

import json
import threading
import time
from collections import deque
from typing import Any, Callable, Dict, Iterable, List, Mapping, NamedTuple, Optional

# span phases, in required timeline order (a request's spans must be a
# subsequence of this — validate_request_timelines enforces it). LM requests
# use queue/pack/prefill/decode/retire; vision requests use queue/infer/retire
# (one batched classify forward is the whole service phase).
REQUEST_PHASES = ("queue", "pack", "prefill", "infer", "decode", "retire")
# kind of span: request-phase spans carry a trace id; step spans are the
# engine's per-program dispatch windows (tid 0 in the export)
KIND_REQUEST = "request"
KIND_STEP = "step"


class Span(NamedTuple):
    """One completed span. Times are engine-clock seconds (monotonic or an
    injected fake clock — the tracer never reads ``time`` itself)."""

    trace_id: Optional[int]  # request trace id; None for engine-step spans
    name: str  # phase (queue/pack/...) or program key (step spans)
    kind: str  # KIND_REQUEST | KIND_STEP
    t0: float
    t1: float
    attrs: Optional[dict] = None

    @property
    def dur(self) -> float:
        return self.t1 - self.t0


class FlightRecorder:
    """Bounded thread-safe span ring buffer.

    ``record`` is the hot path: one lock acquisition, one deque append
    (evicting the oldest entry at capacity). ``spans`` copies under the
    lock so exporters never see a torn window. ``dropped`` counts evicted
    spans — a nonzero value means the exported window is the *recent* tail,
    not the full history.
    """

    def __init__(self, capacity: int = 65536) -> None:
        self.capacity = int(capacity)
        self._lock = threading.RLock()
        self._ring: deque = deque(maxlen=max(1, self.capacity))
        self._total = 0

    def record(self, span: Span) -> None:
        with self._lock:
            self._ring.append(span)
            self._total += 1

    def __len__(self) -> int:
        with self._lock:
            return len(self._ring)

    @property
    def total(self) -> int:
        """Spans ever recorded (including evicted ones)."""
        with self._lock:
            return self._total

    @property
    def dropped(self) -> int:
        """Spans evicted by the ring bound."""
        with self._lock:
            return self._total - len(self._ring)

    def spans(self, t0: Optional[float] = None,
              t1: Optional[float] = None) -> List[Span]:
        """Snapshot of the recorded window, optionally clipped to spans
        overlapping [t0, t1]."""
        with self._lock:
            out = list(self._ring)
        if t0 is not None:
            out = [s for s in out if s.t1 >= t0]
        if t1 is not None:
            out = [s for s in out if s.t0 <= t1]
        return out

    def clear(self) -> None:
        with self._lock:
            self._ring.clear()
            self._total = 0


class Tracer:
    """Per-request span timeline recorder over one ``FlightRecorder``.

    ``begin(tid, phase, t)`` opens a span; ``end(tid, phase, t)`` closes it
    into the recorder. Open spans live in a small dict keyed (tid, phase) —
    a request has at most one phase open at a time, so the dict stays the
    size of the in-flight population. ``record_span`` records a completed
    interval directly (the engine's per-program step windows).

    Thread-safe: begin/end/record_span take the tracer lock (the decode
    loop opens ``retire`` spans that the retirement thread closes).
    ``enabled`` is True on real tracers; engines test it once per call site
    so a disabled engine never reaches these methods (see ``NULL_TRACER``).
    """

    enabled = True

    def __init__(self, capacity: int = 65536,
                 clock: Callable[[], float] = time.monotonic,
                 label: str = "engine") -> None:
        self.recorder = FlightRecorder(capacity)
        self.label = label  # replica name in the export (cluster sets it)
        self._clock = clock
        self._lock = threading.RLock()
        self._open: Dict[tuple, tuple] = {}  # (tid, name) -> (t0, attrs)

    def begin(self, trace_id: int, name: str,
              t: Optional[float] = None, **attrs: Any) -> None:
        t = self._clock() if t is None else t
        with self._lock:
            self._open[(trace_id, name)] = (t, attrs or None)

    def end(self, trace_id: int, name: str,
            t: Optional[float] = None, **attrs: Any) -> None:
        """Close an open span into the recorder. Ending a span that was
        never begun is a silent no-op — a half-instrumented path must not
        crash serving."""
        t = self._clock() if t is None else t
        with self._lock:
            ent = self._open.pop((trace_id, name), None)
            if ent is None:
                return
            t0, a0 = ent
            if attrs:
                a0 = {**(a0 or {}), **attrs}
            self.recorder.record(
                Span(trace_id, name, KIND_REQUEST, t0, max(t, t0), a0))

    def transition(self, trace_id: int, from_name: Optional[str],
                   to_name: Optional[str], t: Optional[float] = None,
                   **attrs: Any) -> None:
        """Close ``from_name`` and open ``to_name`` at the same instant —
        the one-call way to keep adjacent phases exactly contiguous (their
        shared boundary is what makes span durations sum to the recorded
        end-to-end latency)."""
        t = self._clock() if t is None else t
        if from_name is not None:
            self.end(trace_id, from_name, t=t, **attrs)
        if to_name is not None:
            self.begin(trace_id, to_name, t=t)

    def record_span(self, name: str, t0: float, t1: float,
                    kind: str = KIND_STEP,
                    trace_id: Optional[int] = None, **attrs: Any) -> None:
        """Record an already-completed interval (engine step windows)."""
        self.recorder.record(
            Span(trace_id, name, kind, t0, max(t1, t0), attrs or None))

    def open_count(self) -> int:
        with self._lock:
            return len(self._open)


class _NullTracer:
    """The disabled path: every method is a no-op, ``enabled`` is False.
    Engines guard instrumentation with ``if self.tracer.enabled`` so the
    per-call cost with tracing off is one attribute read."""

    enabled = False
    label = "disabled"
    recorder = FlightRecorder(1)

    def begin(self, *a: Any, **k: Any) -> None:
        pass

    def end(self, *a: Any, **k: Any) -> None:
        pass

    def transition(self, *a: Any, **k: Any) -> None:
        pass

    def record_span(self, *a: Any, **k: Any) -> None:
        pass

    def open_count(self) -> int:
        return 0


NULL_TRACER = _NullTracer()


def make_tracer(trace_cfg, clock: Callable[[], float] = time.monotonic,
                label: str = "engine"):
    """Tracer for a ``TraceConfig`` (configs/base.py): a real ``Tracer``
    when enabled, the shared ``NULL_TRACER`` otherwise. Engines also flip
    the kernel-annotation flag here so device profiles carry kernel-level
    names (kernels/ops.py) without every engine repeating the wiring."""
    if trace_cfg is None or not trace_cfg.enable:
        return NULL_TRACER
    if trace_cfg.annotate_kernels:
        from repro.kernels import ops

        ops.set_kernel_annotations(True)
    return Tracer(capacity=trace_cfg.capacity, clock=clock, label=label)


# -- export -----------------------------------------------------------------


def chrome_trace(recorders, t0: Optional[float] = None,
                 t1: Optional[float] = None) -> dict:
    """Render recorder windows as Chrome-trace JSON (Perfetto-loadable).

    ``recorders`` is a mapping ``{replica_label: FlightRecorder}`` (or a
    single recorder / tracer). Layout: one *process* (pid) per replica; in
    each process, thread 0 is the engine's per-program step track and every
    request gets its own thread (``tid = trace_id + 1``) so its phase spans
    read as one horizontal timeline. Timestamps are microseconds, as the
    format requires; span ``attrs`` land in ``args``.
    """
    if isinstance(recorders, (FlightRecorder, Tracer, _NullTracer)):
        rec = getattr(recorders, "recorder", recorders)
        recorders = {getattr(recorders, "label", "engine"): rec}
    events: List[dict] = []
    for pid, (label, rec) in enumerate(sorted(recorders.items())):
        events.append({"ph": "M", "name": "process_name", "pid": pid,
                       "tid": 0, "args": {"name": label}})
        events.append({"ph": "M", "name": "thread_name", "pid": pid,
                       "tid": 0, "args": {"name": "engine steps"}})
        named_tids = set()
        for s in rec.spans(t0, t1):
            tid = 0 if s.trace_id is None else int(s.trace_id) + 1
            if tid and tid not in named_tids:
                named_tids.add(tid)
                events.append({
                    "ph": "M", "name": "thread_name", "pid": pid, "tid": tid,
                    "args": {"name": f"request {s.trace_id}"},
                })
            ev = {
                "ph": "X",
                "name": s.name,
                "cat": s.kind,
                "ts": s.t0 * 1e6,
                "dur": max(0.0, s.dur) * 1e6,
                "pid": pid,
                "tid": tid,
            }
            if s.attrs:
                ev["args"] = dict(s.attrs)
            events.append(ev)
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def write_chrome_trace(path: str, recorders, t0: Optional[float] = None,
                       t1: Optional[float] = None) -> dict:
    doc = chrome_trace(recorders, t0, t1)
    with open(path, "w") as f:
        json.dump(doc, f)
    return doc


# -- validation (tests + CI artifact checks) --------------------------------


def validate_chrome_trace(doc: dict) -> int:
    """Schema check for an exported trace: returns the number of duration
    events, raises ``ValueError`` on malformed structure. This is the CI
    gate on the uploaded artifact."""
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        raise ValueError("chrome trace must be an object with traceEvents")
    n = 0
    for ev in doc["traceEvents"]:
        if not isinstance(ev, dict) or "ph" not in ev:
            raise ValueError(f"malformed event: {ev!r}")
        if ev["ph"] == "M":
            if "name" not in ev or "args" not in ev:
                raise ValueError(f"malformed metadata event: {ev!r}")
            continue
        if ev["ph"] != "X":
            raise ValueError(f"unexpected phase {ev['ph']!r}")
        for key in ("name", "ts", "dur", "pid", "tid"):
            if key not in ev:
                raise ValueError(f"duration event missing {key!r}: {ev!r}")
        if ev["dur"] < 0:
            raise ValueError(f"negative duration: {ev!r}")
        n += 1
    return n


def request_timelines(spans: Iterable[Span]) -> Dict[int, List[Span]]:
    """Group request-phase spans by trace id, each timeline sorted by
    start time (step spans are excluded)."""
    out: Dict[int, List[Span]] = {}
    for s in spans:
        if s.kind == KIND_REQUEST and s.trace_id is not None:
            out.setdefault(s.trace_id, []).append(s)
    for tl in out.values():
        tl.sort(key=lambda s: (s.t0, REQUEST_PHASES.index(s.name)
                               if s.name in REQUEST_PHASES else -1))
    return out


def validate_request_timelines(spans: Iterable[Span],
                               eps: float = 1e-9) -> int:
    """The acceptance invariant: every request's spans are non-overlapping,
    phase-ordered (a subsequence of ``REQUEST_PHASES``), and contiguous
    phases share boundaries. Returns the number of validated requests;
    raises ``ValueError`` with the offending trace id otherwise."""
    timelines = request_timelines(spans)
    for tid, tl in timelines.items():
        last_t1 = None
        last_rank = -1
        for s in tl:
            if s.name not in REQUEST_PHASES:
                raise ValueError(f"request {tid}: unknown phase {s.name!r}")
            rank = REQUEST_PHASES.index(s.name)
            if rank <= last_rank:
                raise ValueError(
                    f"request {tid}: phase {s.name!r} out of order")
            last_rank = rank
            if s.t1 < s.t0 - eps:
                raise ValueError(f"request {tid}: span {s.name!r} ends "
                                 "before it starts")
            if last_t1 is not None and s.t0 < last_t1 - eps:
                raise ValueError(
                    f"request {tid}: span {s.name!r} overlaps the previous "
                    f"phase ({s.t0} < {last_t1})")
            last_t1 = s.t1
    return len(timelines)
