"""Vision serving engine: dynamic-batching MoE-ViT inference
(DESIGN.md section 6 — the serving half of the paper's headline FPS result).

Request path:

  submit(VisionRequest) -> MicroBatcher (bucketed admission, max-wait
  deadline, backpressure) -> padded bucket batch -> jitted
  ``models.classify`` forward (fp / fake-quant / materialized-int8
  QuantizedParams trees all flow through the same ``quant_linear`` seam)
  -> top-k class responses + per-expert routed-token occupancy.

Dispatch is **double-buffered**: up to ``max_inflight`` device batches are
outstanding at once — batch N+1 is padded, transferred, and enqueued while
batch N's device work is still in flight (JAX async dispatch), so the host
never serializes the device. Results are only synchronized (``np.asarray``)
when a batch is *retired* — when the in-flight window is full or at drain.

Batch shapes are quantized to the ``batch_buckets`` ladder (pad rows of
zeros), so the engine compiles exactly ``len(batch_buckets)`` programs and
never re-traces at serving time; call ``warmup()`` to move all compiles out
of the measured path.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, List, NamedTuple, Optional, Sequence

import contextlib

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import models
from repro.configs.base import ModelConfig
from repro.models import vit
from repro.serving.engine import serving_config
from repro.serving.events import EventLog
from repro.serving.metrics import EngineMetrics
from repro.serving.scheduler import MicroBatcher
from repro.serving.trace import make_tracer


@dataclasses.dataclass
class VisionRequest:
    """One image to classify. ``patches`` is the flattened patch sequence
    [image_tokens - 1, PATCH_DIM]; results are filled in at retirement."""

    uid: int
    patches: np.ndarray
    classes: Optional[np.ndarray] = None  # [k] int32, most-probable first
    probs: Optional[np.ndarray] = None  # [k] f32, descending
    latency_s: Optional[float] = None
    # None = not yet admitted; a 0.0 stamp from a fake clock is a real stamp
    submitted_at: Optional[float] = None
    # span-timeline identity (serving/trace.py); cluster-assigned, falls
    # back to uid on a standalone engine. None with tracing off.
    trace_id: Optional[int] = None
    # terminal-delivery callback (same contract as engine.Request.on_done):
    # fired exactly once at retirement, off the dispatch path; the chaos
    # benchmark counts terminal callbacks per accepted request through it
    on_done: Optional[Callable[["VisionRequest"], None]] = None
    # lifecycle + eviction bookkeeping, mirroring engine.Request (the
    # cluster's at-most-once/re-dispatch machinery is engine-agnostic)
    status: str = dataclasses.field(default="pending", repr=False)
    redispatched: int = dataclasses.field(default=0, repr=False)
    evicted: bool = dataclasses.field(default=False, repr=False)

    @property
    def done(self) -> bool:
        return self.classes is not None


class _InFlight(NamedTuple):
    reqs: tuple  # the real requests in this device batch
    pad_to: int  # padded batch size actually dispatched
    out: dict  # device arrays from classify (not yet synchronized)
    dispatched_at: float


class VisionEngine:
    """Dynamic-batching MoE-ViT classifier engine (single-host driver)."""

    def __init__(
        self,
        cfg: ModelConfig,
        params,
        *,
        batch_buckets: Sequence[int] = (1, 4, 8),
        max_wait_s: float = 2e-3,
        max_pending: int = 1024,
        top_k: int = 5,
        max_inflight: int = 2,
        mesh: Optional[Mesh] = None,
        events: Optional[EventLog] = None,
        clock: Callable[[], float] = time.monotonic,
    ) -> None:
        if cfg.family not in ("vit", "vit_moe"):
            raise ValueError(f"vision families only, got {cfg.family!r}")
        # dropless grouped MoE for serving, same rule as the LM engine
        self.cfg = serving_config(cfg)
        self.params = params
        # observability (DESIGN.md section 11): vision timelines are
        # queue -> infer -> retire (one batched forward is the service)
        self.tracer = make_tracer(self.cfg.trace, clock=clock)
        self.events = events
        # step timing serves tracing AND the introspection MFU join
        self._step_times = ((self.tracer.enabled
                             and self.cfg.trace.step_times)
                            or self.cfg.introspect.enable)
        self.top_k = min(top_k, cfg.num_classes)
        self.n_patches = cfg.image_tokens - 1
        self._clock = clock
        self.scheduler = MicroBatcher(
            batch_sizes=batch_buckets, max_wait_s=max_wait_s,
            max_pending=max_pending, clock=clock,
        )
        self.metrics = EngineMetrics(
            num_experts=cfg.moe.num_experts if cfg.moe is not None else 0,
            clock=clock,
        )
        self.expert_health = None
        if self.cfg.introspect.enable and cfg.moe is not None:
            from repro.serving.introspect import ExpertHealthMonitor

            self.expert_health = ExpertHealthMonitor(
                cfg.moe.num_experts,
                window_tokens=self.cfg.introspect.drift_window_tokens,
                drift_threshold=self.cfg.introspect.drift_threshold,
                baseline_alpha=self.cfg.introspect.baseline_alpha,
                events=events, label="vision", clock=clock,
                on_drift=lambda info: self.metrics.inc("expert_drift"))
            self.metrics.expert_health = self.expert_health
        self.max_inflight = max(1, int(max_inflight))
        self._inflight: deque = deque()
        self.mesh = mesh
        self._ep = (cfg.moe is not None
                    and cfg.moe.moe_exec == "expert_parallel")
        cfg_c, k = self.cfg, self.top_k
        fwd = lambda prm, x: models.classify(prm, cfg_c, x, top_k=k)
        self._ep_scope = contextlib.nullcontext
        if mesh is None:
            if self._ep:
                raise ValueError(
                    "moe_exec='expert_parallel' needs mesh= (a 'model'-axis "
                    "mesh whose size divides num_experts)")
            self._classify = jax.jit(fwd)
            self._lowerable = self._classify
        else:
            # pin this replica to its mesh slice: params device_put with
            # per-leaf specs (expert stacks sharded over 'model' under EP,
            # everything replicated otherwise), forward jitted against them
            from repro.distributed.expert_parallel import (
                use_ep_mesh,
                validate_ep,
            )
            from repro.distributed.sharding_rules import (
                EXPERT_PARALLEL_RULES,
                fit_specs_to_tree,
                param_specs,
            )

            if self._ep:
                validate_ep(self.cfg, mesh)
                specs = fit_specs_to_tree(
                    param_specs(self.cfg, mesh, rules=EXPERT_PARALLEL_RULES),
                    params,
                )
            else:
                specs = jax.tree.map(lambda _: P(), params)
            named = lambda tree: jax.tree.map(
                lambda s: NamedSharding(mesh, s), tree,
                is_leaf=lambda x: isinstance(x, P),
            )
            self.params = jax.device_put(params, named(specs))
            jitted = jax.jit(fwd, in_shardings=(
                named(specs), NamedSharding(mesh, P())))
            self._lowerable = jitted  # warmup AOT-lowers it for cost rows
            ep_scope = (
                (lambda: use_ep_mesh(mesh)) if self._ep
                else contextlib.nullcontext
            )
            self._ep_scope = ep_scope

            def call(prm, x):
                # the EP mesh is ambient trace-time state; entering the
                # scope on every call keeps retraces (new bucket shapes)
                # correct and costs nothing once compiled
                with ep_scope():
                    return jitted(prm, x)

            self._classify = call

    # -- lifecycle ----------------------------------------------------------

    def _tune_trace(self) -> None:
        """Abstract (eval_shape) trace of every bucket's classify program,
        so the autotuner collects this replica's kernel shape keys without
        compiling anything. Under EP the trace runs in the replica's mesh
        scope and sees the per-shard local shapes."""
        for b in self.scheduler.batch_sizes:
            x = jax.ShapeDtypeStruct((b, self.n_patches, vit.PATCH_DIM),
                                     jnp.float32)
            with self._ep_scope():
                jax.eval_shape(
                    lambda prm, xx: models.classify(prm, self.cfg, xx,
                                                    top_k=self.top_k),
                    self.params, x)

    def warmup(self) -> None:
        """Tune tile configs for this replica's shapes (pure cache hit
        after the first warmup on a device kind), then compile every
        bucket size up front (keeps XLA compiles out of the measured
        serving path; the benchmark calls this before timing)."""
        if self.cfg.autotune.enable:
            from repro.kernels import autotune

            autotune.ensure_tuned(self.cfg.autotune, self._tune_trace)
        for b in self.scheduler.batch_sizes:
            x = jnp.zeros((b, self.n_patches, vit.PATCH_DIM), jnp.float32)
            jax.block_until_ready(self._classify(self.params, x))
        if self.cfg.introspect.enable:
            # AOT-lower each bucket program once, purely to read its cost
            # surfaces (warmup is untimed; capture_cost degrades per key)
            programs = {}
            for b in self.scheduler.batch_sizes:
                exe = None
                try:
                    x = jax.ShapeDtypeStruct(
                        (b, self.n_patches, vit.PATCH_DIM), jnp.float32)
                    with self._ep_scope():
                        exe = self._lowerable.lower(self.params, x).compile()
                except Exception:
                    exe = None
                programs[f"classify|b={b}"] = exe
            from repro.serving import introspect

            devices = (list(self.mesh.devices.flat)
                       if self.mesh is not None else None)
            introspect.install(self.metrics, cfg=self.cfg,
                               programs=programs, params=self.params,
                               devices=devices)

    @property
    def inflight(self) -> int:
        """Requests inside dispatched (not yet retired) device batches —
        the public in-flight surface (the cluster never reads
        ``_inflight``)."""
        return sum(len(f.reqs) for f in self._inflight)

    @property
    def load(self) -> int:
        """Queued + in-flight requests — the cluster's least-loaded routing
        signal (DESIGN.md section 7)."""
        return self.scheduler.depth + self.inflight

    @property
    def idle(self) -> bool:
        return self.scheduler.depth == 0 and not self._inflight

    @property
    def free_room(self) -> float:
        """Admission slots left before ``submit`` raises ``Backpressure``
        (inf when unbounded)."""
        return self.scheduler.room

    def reset_metrics(self) -> None:
        """Fresh ``EngineMetrics`` (cluster replica leave — the old one was
        folded into the retired accumulator)."""
        old = self.metrics
        self.metrics = EngineMetrics(
            num_experts=old.expert_tokens.size, clock=self._clock)
        self.metrics.adopt_static(old)

    def submit(self, req: VisionRequest) -> None:
        """Enqueue one image; raises ``scheduler.Backpressure`` when the
        pending queue is at ``max_pending``. A ``submitted_at`` already
        stamped upstream (the cluster front-end) is preserved so request
        latency includes admission-queue wait, not just replica time."""
        if req.submitted_at is None:
            req.submitted_at = self._clock()
        try:
            self.scheduler.submit(req)
        except Exception:
            self.metrics.inc("rejected")
            if self.events is not None:
                self.events.emit("reject", uid=req.uid,
                                 reason="backpressure",
                                 depth=self.scheduler.depth)
            raise
        self.metrics.inc("submitted")
        if self.tracer.enabled:
            if req.trace_id is None:
                req.trace_id = req.uid
            self.tracer.begin(req.trace_id, "queue", t=req.submitted_at)
        self.metrics.observe_queue_depth(self.scheduler.depth)

    def step(self) -> None:
        """One pump: retire finished batches (device results already
        materialized — no blocking), force-retire the oldest if the
        in-flight window is still full, then dispatch every ready batch the
        window has room for. Call from the submit loop to overlap host and
        device."""
        while self._inflight and self._head_ready():
            self._retire_one()
        if len(self._inflight) >= self.max_inflight:
            self._retire_one()
        self._dispatch_ready()

    def flush(self) -> None:
        """Drain: release partial batches immediately, dispatch everything
        queued, and retire every in-flight batch."""
        self.scheduler.drain(True)
        try:
            while self.scheduler.depth or self._inflight:
                self._dispatch_ready()
                if self._inflight:
                    self._retire_one()
        finally:
            self.scheduler.drain(False)

    run_until_drained = flush

    def evict(self) -> List[VisionRequest]:
        """Quarantine support (serving/cluster.py): strand-and-return every
        request this replica holds — queued plus in dispatched batches —
        without waiting on (possibly wedged) device work. Dispatched device
        batches are abandoned unsynchronized; their requests are marked
        ``evicted`` so a late retirement of the same batch object is a
        no-op."""
        stranded = list(self.scheduler.clear())
        for ent in self._inflight:
            stranded.extend(ent.reqs)
        self._inflight.clear()
        out = []
        for req in stranded:
            if req.status != "pending":
                continue  # terminal before the eviction: nothing to redo
            req.evicted = True
            out.append(req)
        return out

    # -- internals ----------------------------------------------------------

    def _head_ready(self) -> bool:
        """Whether the oldest in-flight batch's device work has finished —
        retiring it then stamps request latency at actual completion, not
        at the next forced sync (open-loop percentiles stay honest)."""
        head = self._inflight[0].out["classes"]
        is_ready = getattr(head, "is_ready", None)
        return bool(is_ready()) if is_ready is not None else False

    def _dispatch_ready(self) -> None:
        while len(self._inflight) < self.max_inflight:
            batch = self.scheduler.poll()
            if batch is None:
                return
            reqs = batch.items
            x = np.zeros((batch.pad_to, self.n_patches, vit.PATCH_DIM),
                         np.float32)
            for i, r in enumerate(reqs):
                x[i] = r.patches
            t0 = self._clock()
            for r in reqs:
                # per-request admission wait measured from the submitted_at
                # stamp (cluster front-end or engine submit) to dispatch —
                # the same semantics ServeEngine records before prefill, so
                # queue_wait_ms compares across engine families
                self.metrics.queue_wait.record(max(0.0, t0 - r.submitted_at))
                if self.tracer.enabled:
                    self.tracer.transition(r.trace_id, "queue", "infer",
                                           t=t0, pad_to=batch.pad_to)
            # async dispatch: returns device futures; nothing blocks here
            out = self._classify(self.params, jnp.asarray(x))
            self._inflight.append(_InFlight(reqs, batch.pad_to, out, t0))
            self.metrics.inc("batches")
            self.metrics.inc("padded_frames", batch.pad_to - len(reqs))
            # padding-waste observability in *token* units, comparable with
            # the LM engine's pack buffer counters (DESIGN.md section 10):
            # every row carries n_patches patch tokens, pad rows included
            self.metrics.inc("pack_real_tokens", len(reqs) * self.n_patches)
            self.metrics.inc("pack_pad_tokens",
                             (batch.pad_to - len(reqs)) * self.n_patches)
            self.metrics.observe_queue_depth(self.scheduler.depth)

    def _retire_one(self) -> None:
        ent = self._inflight.popleft()
        classes = np.asarray(ent.out["classes"])  # synchronizes the batch
        probs = np.asarray(ent.out["probs"])
        now = self._clock()
        self.metrics.batch_latency.record(now - ent.dispatched_at)
        trace = self.tracer.enabled
        if self._step_times:
            # per-bucket step latency, keyed like the autotune/program-key
            # namespace so cluster snapshots read as one schema
            self.metrics.record_step(f"classify|b={ent.pad_to}",
                                     now - ent.dispatched_at)
        if trace:
            self.tracer.record_span(f"classify|b={ent.pad_to}",
                                    ent.dispatched_at, now,
                                    n=len(ent.reqs), pad_to=ent.pad_to)
        et = ent.out.get("expert_tokens")
        if et is not None and et.size:
            # NB: includes the pad rows' routed tokens — interpret together
            # with counters["padded_frames"] (DESIGN.md section 6)
            self.metrics.add_expert_tokens(np.asarray(et))
        for i, req in enumerate(ent.reqs):
            if req.evicted or req.status != "pending":
                # evicted mid-flight (the cluster owns it) or a duplicate
                # retirement of an already-terminal request — exactly-once
                if not req.evicted:
                    self.metrics.inc("duplicate_retirements")
                continue
            req.classes = classes[i]
            req.probs = probs[i]
            req.latency_s = now - req.submitted_at
            req.status = "completed"
            self.metrics.request_latency.record(req.latency_s)
            self.metrics.inc("completed")
            if req.on_done is not None:
                try:
                    req.on_done(req)
                except Exception as e:
                    self.metrics.inc("callback_errors")
                    if self.events is not None:
                        self.events.emit("callback_error", uid=req.uid,
                                         error=repr(e))
            if trace:
                # infer ends at the SAME `now` the latency record uses —
                # queue+infer sums to latency_s; retire is result fill-in
                self.tracer.transition(req.trace_id, "infer", "retire",
                                       t=now)
                self.tracer.end(req.trace_id, "retire",
                                latency_s=req.latency_s)
        self.metrics.work_done(len(ent.reqs), "frames")


def synth_requests(cfg: ModelConfig, n: int, seed: int = 0,
                   scale: float = 1.0) -> List[VisionRequest]:
    """n synthetic image-patch requests for benchmarks/examples/tests."""
    rng = np.random.default_rng(seed)
    T = cfg.image_tokens - 1
    return [
        VisionRequest(
            uid=i,
            patches=(scale * rng.standard_normal((T, vit.PATCH_DIM)))
            .astype(np.float32),
        )
        for i in range(n)
    ]
