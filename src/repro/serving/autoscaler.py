"""Target-range autoscaling admission controller (DESIGN.md section 8).

ROADMAP item "Autoscaling admission": ``ServingCluster`` used to run a
fixed replica set regardless of load. ``Autoscaler`` closes the loop: a
small hysteretic controller that watches two pressure signals —

  * **front-end queue depth** per active replica (requests the router
    could not place because every replica's admission is full), sampled on
    the route path by ``ClusterMetrics.observe_queue_depth``;
  * **windowed pooled p95 request latency** vs the SLO. The window is the
    *difference of two pooled latency histograms* (live replicas + the
    retired accumulator — ``ClusterMetrics.pooled_request_hist``), which is
    the only way to window percentiles across replica churn: a drained
    replica's samples fold into the retired histogram, so the pooled total
    is monotone and the delta between two evaluations is exactly the
    latency population of that window, no matter which replicas served it.

Control law (evaluated once per ``tick()``):

  scale **up** when ``depth > depth_high * n_active`` OR ``p95 > slo``,
  sustained for ``up_patience`` consecutive evaluations — the cluster
  promotes a **pre-warmed standby** replica into the router (compile cost
  never lands in the serving path; only an empty pool spawns cold).

  scale **down** when total load (front + replicas) is at/below
  ``depth_low`` AND ``p95 < down_margin * slo`` (or no window yet),
  sustained for ``down_patience`` evaluations — the cluster stops routing
  to the least-loaded replica and *drains* it: in-flight and queued
  requests are served to completion, then the replica returns to standby
  and its metrics fold into the retired accumulator. No request is ever
  lost across a drain.

  After any action the controller holds for ``cooldown`` evaluations
  (hysteresis: patience filters noise on the way in, cooldown prevents
  relaxation-oscillation on the way out), and the replica count is clamped
  to ``[min_replicas, max_replicas]``.

The controller is pure host-side bookkeeping driven by the same injectable
clock as the cluster, so tests run it deterministically under a fake clock.

Interplay with fault tolerance (DESIGN.md section 14): watchdog evictions
bypass this controller entirely — ``ServingCluster.quarantine`` promotes a
standby directly, so the cooldown never delays capacity recovery — and
``scale_down`` refuses while the cluster is degraded, so a down-streak
accumulated before a fault cannot fight the recovery.
"""
from __future__ import annotations

import math
from typing import List, Optional, Tuple

import numpy as np

from repro.configs.base import AutoscaleConfig
from repro.serving.cluster import ServingCluster
from repro.serving.events import EventLog
from repro.serving.metrics import hist_percentile


class Autoscaler:
    """Hysteretic target-range controller over a ``ServingCluster``.

    ``event_log`` (defaults to the cluster's ``EventLog``, when it has one)
    receives one ``scale_up`` / ``scale_down`` record per decision carrying
    the controller inputs that triggered it — the decision journal DESIGN.md
    section 11 specifies, answering "why did the cluster scale here" from
    the artifact alone."""

    def __init__(self, cluster: ServingCluster,
                 policy: Optional[AutoscaleConfig] = None,
                 event_log: Optional[EventLog] = None) -> None:
        self.cluster = cluster
        self.policy = policy or AutoscaleConfig()
        self.event_log = (event_log if event_log is not None
                          else getattr(cluster, "events", None))
        self._up_streak = 0
        self._down_streak = 0
        self._cooldown = 0
        self._window_hist: Optional[np.ndarray] = None
        self._p95_ms = float("nan")
        self._evals_since_close = 0
        # (t, action, active-count-after) — "up" | "down"
        self.events: List[Tuple[float, str, int]] = []

    # -- signals -------------------------------------------------------------

    @property
    def window_p95_ms(self) -> float:
        """Last windowed pooled p95 estimate (nan before enough samples)."""
        return self._p95_ms

    def _update_p95(self) -> float:
        pooled = self.cluster.metrics.pooled_request_hist()
        if self._window_hist is None:
            self._window_hist = np.zeros_like(pooled)
        delta = pooled - self._window_hist
        n = int(delta.sum())
        if n >= self.policy.min_window_samples:
            # enough samples: close the window, advance its start
            self._p95_ms = hist_percentile(delta, 95.0) * 1e3
            self._window_hist = pooled
            self._evals_since_close = 0
        else:
            # no window close: the estimate ages out after p95_ttl
            # evaluations — a p95 measured during a surge must not keep
            # reading as a live SLO breach once traffic has stopped (that
            # would scale an idle cluster up and block scale-down forever)
            self._evals_since_close += 1
            if self._evals_since_close > self.policy.p95_ttl:
                self._p95_ms = float("nan")
        return self._p95_ms

    # -- control law ---------------------------------------------------------

    def tick(self) -> Optional[str]:
        """One control evaluation; returns "up" / "down" when the cluster
        was scaled this tick, else None. Call it from the serving pump (one
        evaluation per pump, or rate-limit it upstream)."""
        c, p = self.cluster, self.policy
        n = c.num_replicas
        depth = c.depth
        p95 = self._update_p95()
        slo_breach = not math.isnan(p95) and p95 > p.slo_p95_ms
        pressure = depth > p.depth_high * n or slo_breach
        relaxed = (c.total_load <= p.depth_low
                   and (math.isnan(p95)
                        or p95 < p.down_margin * p.slo_p95_ms))
        if pressure:
            self._up_streak += 1
            self._down_streak = 0
        elif relaxed:
            self._down_streak += 1
            self._up_streak = 0
        else:
            self._up_streak = 0
            self._down_streak = 0
        if self._cooldown > 0:
            self._cooldown -= 1
            return None
        if (self._up_streak >= p.up_patience and n < p.max_replicas
                and c.scale_up()):
            self._log_decision("scale_up", n, depth, p95, slo_breach)
            self._up_streak = 0
            self._cooldown = p.cooldown
            self.events.append((c.clock(), "up", c.num_replicas))
            return "up"
        if (self._down_streak >= p.down_patience and n > p.min_replicas
                and c.scale_down()):
            self._log_decision("scale_down", n, depth, p95, slo_breach)
            self._down_streak = 0
            self._cooldown = p.cooldown
            self.events.append((c.clock(), "down", c.num_replicas))
            return "down"
        return None

    def _log_decision(self, action: str, n_before: int, depth: int,
                      p95: float, slo_breach: bool) -> None:
        """Journal one scale decision with the controller inputs that
        produced it (streaks still hold their pre-reset values here)."""
        if self.event_log is None:
            return
        c, p = self.cluster, self.policy
        self.event_log.emit(
            action, t=c.clock(),
            replicas_before=n_before, replicas_after=c.num_replicas,
            depth=depth, total_load=c.total_load,
            p95_ms=None if math.isnan(p95) else p95,
            slo_p95_ms=p.slo_p95_ms, slo_breach=slo_breach,
            up_streak=self._up_streak, down_streak=self._down_streak)

    def state(self) -> dict:
        """Controller observability snapshot (the benchmark's trace rows)."""
        return {
            "replicas": self.cluster.num_replicas,
            "standby": self.cluster.standby_replicas,
            "draining": self.cluster.draining_replicas,
            "depth": self.cluster.depth,
            "total_load": self.cluster.total_load,
            "p95_ms": self._p95_ms,
            "up_streak": self._up_streak,
            "down_streak": self._down_streak,
            "cooldown": self._cooldown,
        }
