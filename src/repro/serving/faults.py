"""Serving fault model: deterministic chaos injection + replica watchdog
(DESIGN.md section 14).

Two halves, configured by one ``FaultConfig`` (configs/base.py):

**Chaos injection** — ``FaultInjector`` is a seedable fault source attached
to one replica. ``ServingCluster`` activates it by wrapping every replica it
builds in a ``FaultyReplica`` decorator when ``cfg.faults.inject`` is on;
the wrapper injects at the replica *boundary* (the exact surface the
``EngineReplica`` protocol defines), so the engines themselves stay
fault-free and any custom replica is chaos-testable for free:

  * ``step()``  — raise ``InjectedFault`` (transient step error), raise
    ``InjectedOOM`` (an allocation failure shaped like the runtime's
    RESOURCE_EXHAUSTED), stall for ``stall_s`` before running (via a
    pluggable ``stall_fn`` so fake-clock tests advance time instead of
    sleeping), or die permanently (``"dead"`` — every later step raises
    too, modelling a crashed process rather than a transient fault);
  * ``submit()`` — raise ``scheduler.Backpressure`` (a replica refusing
    admission it advertised room for);
  * ``on_done`` — poison the callback: the user callback runs, then the
    wrapper raises (the retirement daemon must survive and count it).

Faults fire from per-rate Bernoulli draws of a generator seeded with
``(seed, replica_ordinal)`` — the whole chaos run is a pure function of the
config — or from the explicit ``kill_schedule`` (replica_ordinal,
local_step, kind) triples, which override the draws at their step.

With ``inject`` off, nothing is wrapped: the injection path does not exist
at runtime. ``NULL_INJECTOR`` exists for call sites that want an
always-present attribute (one ``enabled`` read, the ``NULL_TRACER``
discipline), but the cluster does not pay even that.

**Watchdog** — ``ReplicaWatchdog`` is the per-replica health monitor the
cluster consults around every ``step()``: a consecutive-error budget
(OOM-classified errors evict immediately), plus a stall detector combining
an absolute step-timeout with ``StragglerMonitor``'s EMA-relative threshold
(distributed/fault_tolerance.py — the same "slower than k x the running
p50" idea the §12 per-program step histograms measure offline, run live
here). ``record_step``/``record_error`` return an eviction *verdict* dict
(the full watchdog inputs, journaled into the ``replica_evicted`` event)
when a budget is exhausted; the cluster then takes the ``quarantine()``
path (serving/cluster.py).
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, Optional

import numpy as np

from repro.configs.base import FaultConfig
from repro.distributed.fault_tolerance import StragglerMonitor
from repro.serving.scheduler import Backpressure


class InjectedFault(RuntimeError):
    """A chaos-harness fault: transient replica step/submit failure."""


class InjectedOOM(InjectedFault):
    """A chaos-harness allocation failure, shaped like the runtime's
    RESOURCE_EXHAUSTED so OOM classification paths treat it as real."""


def is_oom_error(exc: BaseException) -> bool:
    """Whether an exception looks like a device allocation failure."""
    if isinstance(exc, InjectedOOM):
        return True
    msg = repr(exc).upper()
    return "RESOURCE_EXHAUSTED" in msg or "OUT OF MEMORY" in msg


class _NullInjector:
    """Disabled injector: one ``enabled`` attribute read per site."""

    enabled = False
    dead = False

    def before_step(self) -> None:
        pass

    def on_submit(self) -> bool:
        return False

    def wrap_callback(self, cb):
        return cb


NULL_INJECTOR = _NullInjector()


class FaultInjector:
    """Seeded per-replica fault source (see module docstring).

    ``stall_fn`` implements the injected hang: ``time.sleep`` by default,
    a fake clock's ``advance`` in deterministic tests — either way the
    watchdog sees a step that took ``stall_s`` on *its* clock.
    """

    enabled = True

    def __init__(self, cfg: FaultConfig, ordinal: int = 0,
                 stall_fn: Optional[Callable[[float], None]] = None) -> None:
        self.cfg = cfg
        self.ordinal = int(ordinal)
        self._rng = np.random.default_rng((cfg.seed, self.ordinal))
        self._stall = stall_fn if stall_fn is not None else time.sleep
        self._step = 0
        self.dead = False
        # per-kind injection counts — the chaos benchmark's provenance that
        # the run actually exercised each fault class
        self.injected: Dict[str, int] = {}
        self._schedule = {
            int(step): kind
            for (ordn, step, kind) in cfg.kill_schedule
            if int(ordn) == self.ordinal
        }

    def _count(self, kind: str) -> None:
        self.injected[kind] = self.injected.get(kind, 0) + 1

    def _draw(self, rate: float) -> bool:
        return rate > 0.0 and float(self._rng.random()) < rate

    def before_step(self) -> None:
        """Called at the top of every replica ``step()``; raises or stalls
        per the schedule/rates. A dead replica raises forever."""
        self._step += 1
        if self.dead:
            raise InjectedFault(
                f"replica ordinal {self.ordinal} is dead (scheduled kill)")
        kind = self._schedule.get(self._step)
        if kind is None:
            cfg = self.cfg
            if self._draw(cfg.step_error_rate):
                kind = "error"
            elif self._draw(cfg.oom_rate):
                kind = "oom"
            elif self._draw(cfg.step_stall_rate):
                kind = "stall"
        if kind is None:
            return
        if kind == "dead":
            self.dead = True
            self._count("dead")
            raise InjectedFault(
                f"replica ordinal {self.ordinal} killed at step {self._step}")
        if kind == "error":
            self._count("error")
            raise InjectedFault(
                f"injected step error (ordinal {self.ordinal}, "
                f"step {self._step})")
        if kind == "oom":
            self._count("oom")
            raise InjectedOOM(
                f"RESOURCE_EXHAUSTED: injected allocation failure "
                f"(ordinal {self.ordinal}, step {self._step})")
        if kind == "stall":
            self._count("stall")
            self._stall(self.cfg.stall_s)
            return
        raise ValueError(f"unknown fault kind in kill_schedule: {kind!r}")

    def on_submit(self) -> bool:
        """True = reject this submit (the wrapper raises Backpressure)."""
        if self._draw(self.cfg.submit_reject_rate):
            self._count("submit_reject")
            return True
        return False

    def wrap_callback(self, cb: Optional[Callable]) -> Optional[Callable]:
        """Maybe poison a request's ``on_done``: the original callback (if
        any) still runs — the terminal event must be *delivered* — then the
        wrapper raises, exercising the retirement daemon's error path."""
        if not self._draw(self.cfg.callback_poison_rate):
            return cb
        self._count("callback_poison")

        def poisoned(req, _cb=cb):
            if _cb is not None:
                _cb(req)
            raise InjectedFault("injected poisoned on_done callback")

        return poisoned


class FaultyReplica:
    """Chaos decorator around an ``EngineReplica``: delegates the whole
    protocol surface, injecting at the submit/step boundaries. Everything
    not explicitly wrapped (``tracer``, ``events``, ``queue``, ``active``,
    ``evict``, ...) passes through to the inner engine."""

    def __init__(self, inner: Any, injector: FaultInjector) -> None:
        self.inner = inner
        self.injector = injector

    # -- injected boundaries -------------------------------------------------

    def submit(self, req) -> None:
        if self.injector.on_submit():
            raise Backpressure("injected submit rejection")
        cb = getattr(req, "on_done", None)
        poisoned = self.injector.wrap_callback(cb)
        if poisoned is not cb:
            req.on_done = poisoned
        self.inner.submit(req)

    def step(self) -> None:
        self.injector.before_step()
        self.inner.step()

    def flush(self) -> None:
        # a dead replica cannot drain — the cluster's flush loop routes the
        # failure through the watchdog/quarantine path instead
        if self.injector.dead:
            raise InjectedFault(
                f"replica ordinal {self.injector.ordinal} is dead")
        self.inner.flush()

    run_until_drained = flush

    # -- plain delegation ----------------------------------------------------

    def warmup(self) -> None:
        self.inner.warmup()

    def reset_metrics(self) -> None:
        self.inner.reset_metrics()

    @property
    def metrics(self):
        return self.inner.metrics

    @property
    def mesh(self):
        return self.inner.mesh

    @property
    def load(self):
        return self.inner.load

    @property
    def free_room(self):
        return self.inner.free_room

    @property
    def idle(self):
        return self.inner.idle

    def __getattr__(self, name: str):
        return getattr(self.inner, name)


class ReplicaWatchdog:
    """Per-replica health monitor (cluster-side, pure host bookkeeping).

    The cluster wraps every routed ``step()`` in a clock read + one of
    ``record_step`` / ``record_error``. Both return ``None`` while the
    replica is healthy, or an eviction **verdict** — a dict carrying the
    reason plus every watchdog input (the ``replica_evicted`` event
    payload) — once a budget is exhausted:

      * ``record_error``: consecutive step exceptions reach
        ``error_budget`` (an OOM-classified error evicts on the first hit:
        retrying into a full allocator wedges the pump);
      * ``record_step``: consecutive stalls reach ``stall_budget``, where
        a stall is a step over the absolute ``step_timeout_s`` OR over
        ``stall_threshold`` x the healthy-step EMA (``StragglerMonitor``
        with stalls excluded from the EMA, armed after ``warmup_steps``).

    A successful step resets the error streak; a healthy-speed step resets
    the stall streak.
    """

    def __init__(self, cfg: FaultConfig, label: str = "replica?") -> None:
        self.cfg = cfg
        self.label = label
        self._straggler = StragglerMonitor(
            alpha=0.2, threshold=cfg.stall_threshold,
            warmup_steps=cfg.warmup_steps)
        self.steps = 0
        self.consecutive_errors = 0
        self.consecutive_stalls = 0
        self.last_step_s = 0.0
        self.last_error: Optional[str] = None

    def record_step(self, duration_s: float) -> Optional[dict]:
        """A step that returned; verdict when the stall budget trips.

        The relative verdict only counts above ``stall_floor_s``: a
        serving pump spins through idle no-op ticks whose microsecond
        durations seed the EMA, and without the floor any step that does
        real work reads as a many-x relative stall."""
        self.steps += 1
        self.last_step_s = float(duration_s)
        self.consecutive_errors = 0
        slow_rel = (self._straggler.record(duration_s, step=self.steps)
                    and duration_s > self.cfg.stall_floor_s)
        slow_abs = duration_s > self.cfg.step_timeout_s
        if slow_rel or slow_abs:
            self.consecutive_stalls += 1
            if self.consecutive_stalls >= self.cfg.stall_budget:
                return self._verdict("stalled")
        else:
            self.consecutive_stalls = 0
        return None

    def record_error(self, exc: BaseException) -> Optional[dict]:
        """A step that raised; verdict when the error budget trips."""
        self.consecutive_errors += 1
        self.last_error = repr(exc)
        oom = is_oom_error(exc)
        budget = 1 if oom else self.cfg.error_budget
        if self.consecutive_errors >= budget:
            return self._verdict("oom" if oom else "step_errors")
        return None

    def state(self) -> dict:
        """The watchdog inputs — healthz per-replica detail and the
        eviction-event payload."""
        suspect = (self.consecutive_errors > 0
                   or self.consecutive_stalls > 0)
        return {
            "health": "suspect" if suspect else "healthy",
            "steps": self.steps,
            "consecutive_errors": self.consecutive_errors,
            "consecutive_stalls": self.consecutive_stalls,
            "last_step_s": self.last_step_s,
            "step_ema_s": self._straggler.ema,
            "last_error": self.last_error,
        }

    def _verdict(self, reason: str) -> dict:
        return {"reason": reason, **self.state()}
