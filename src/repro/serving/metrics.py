"""Serving metrics shared by both engines (DESIGN.md section 6).

``EngineMetrics`` is host-side instrumentation only — counters, latency
reservoirs, queue-depth samples, and the per-expert routed-token occupancy
accumulator. Engines feed it from already-materialized host values (never
from inside a traced function), and ``snapshot()`` renders the documented
metrics schema that ``BENCH_serving.json`` and the examples print.
"""
from __future__ import annotations

import time
from collections import deque
from typing import Callable, Dict, Optional

import numpy as np


class LatencyTracker:
    """Bounded reservoir of latency samples with percentile readout."""

    def __init__(self, maxlen: int = 8192) -> None:
        self._samples: deque = deque(maxlen=maxlen)

    def record(self, seconds: float) -> None:
        self._samples.append(float(seconds))

    def __len__(self) -> int:
        return len(self._samples)

    def percentile(self, p: float) -> float:
        """p-th percentile in seconds (nan when empty)."""
        if not self._samples:
            return float("nan")
        return float(np.percentile(np.asarray(self._samples), p))

    def snapshot(self) -> Dict[str, float]:
        """Milliseconds, the unit the paper's latency tables use."""
        if not self._samples:
            return {"n": 0, "p50": float("nan"), "p95": float("nan"),
                    "p99": float("nan"), "mean": float("nan"),
                    "max": float("nan")}
        a = np.asarray(self._samples) * 1e3
        return {
            "n": int(a.size),
            "p50": float(np.percentile(a, 50)),
            "p95": float(np.percentile(a, 95)),
            "p99": float(np.percentile(a, 99)),
            "mean": float(a.mean()),
            "max": float(a.max()),
        }


class EngineMetrics:
    """Counters + latency + occupancy for one engine instance.

    Counter names in use (an engine touches the subset that applies):
      submitted / completed / rejected — request lifecycle
      batches                         — device batches dispatched
      frames                          — images completed (vision)
      padded_frames                   — pad rows added to fill a bucket
      tokens                          — decode tokens produced (LM)
    """

    def __init__(self, num_experts: int = 0,
                 clock: Callable[[], float] = time.monotonic) -> None:
        self._clock = clock
        self.counters: Dict[str, int] = {}
        self.request_latency = LatencyTracker()
        self.batch_latency = LatencyTracker()
        self.expert_tokens = np.zeros(max(0, num_experts), np.int64)
        self._depth_sum = 0
        self._depth_max = 0
        self._depth_last = 0
        self._depth_n = 0
        self._first_t: Optional[float] = None
        self._last_t: Optional[float] = None

    # -- feeding ------------------------------------------------------------

    def inc(self, name: str, n: int = 1) -> None:
        self.counters[name] = self.counters.get(name, 0) + n
        if name == "submitted" and self._first_t is None:
            self._first_t = self._clock()  # FPS window opens at first arrival

    def observe_queue_depth(self, depth: int) -> None:
        self._depth_sum += depth
        self._depth_max = max(self._depth_max, depth)
        self._depth_last = depth
        self._depth_n += 1

    def add_expert_tokens(self, counts) -> None:
        """Accumulate a routed-token histogram (host array, [num_experts])."""
        a = np.asarray(counts, np.int64)
        if a.size and self.expert_tokens.size == a.size:
            self.expert_tokens += a

    def work_done(self, n: int, unit: str = "frames") -> None:
        """Mark n units (frames/tokens) complete; drives the FPS window."""
        self.inc(unit, n)
        now = self._clock()
        if self._first_t is None:
            self._first_t = now
        self._last_t = now

    # -- readout ------------------------------------------------------------

    @property
    def fps(self) -> float:
        """Completed frames (or tokens for LM engines) per wall second,
        measured from the first submission to the last completion event."""
        n = self.counters.get("frames", 0) or self.counters.get("tokens", 0)
        if self._first_t is None or self._last_t is None \
                or self._last_t <= self._first_t:
            return float("nan")
        return n / (self._last_t - self._first_t)

    def occupancy(self) -> np.ndarray:
        """Per-expert fraction of all routed (token, slot) pairs."""
        total = self.expert_tokens.sum()
        if total == 0:
            return np.zeros_like(self.expert_tokens, np.float64)
        return self.expert_tokens / float(total)

    def snapshot(self) -> dict:
        """The metrics schema (DESIGN.md section 6)."""
        return {
            "counters": dict(self.counters),
            "fps": self.fps,
            "latency_ms": self.request_latency.snapshot(),
            "batch_latency_ms": self.batch_latency.snapshot(),
            "queue_depth": {
                "mean": (self._depth_sum / self._depth_n)
                if self._depth_n else 0.0,
                "max": self._depth_max,
                "last": self._depth_last,
            },
            "expert_tokens": self.expert_tokens.tolist(),
            "expert_occupancy": [round(float(x), 6)
                                 for x in self.occupancy()],
        }
