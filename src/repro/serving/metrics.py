"""Serving metrics shared by engines and the cluster (DESIGN.md §6-7).

``EngineMetrics`` is host-side instrumentation only — counters, latency
trackers, queue-depth samples, and the per-expert routed-token occupancy
accumulator. Engines feed it from already-materialized host values (never
from inside a traced function), and ``snapshot()`` renders the documented
metrics schema that ``BENCH_serving.json`` and the examples print.

``LatencyTracker`` is **merge-safe**: besides the exact-sample reservoir it
keeps a fixed log-spaced histogram that every ``record`` lands in, so
trackers from N replicas combine by summing histograms (and pooling the
sample arrays while they are complete). ``ClusterMetrics`` rolls replica
metrics up that way — cluster percentiles come from the *pooled
distribution*, never from averaging per-replica percentiles (averaging
percentiles is statistically meaningless: the p99 of a union is not the
mean of the p99s).
"""
from __future__ import annotations

import threading
import time
from collections import deque
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

# Log-spaced latency bins: 10 us .. 100 s, 8 bins per decade. Records
# outside the range clamp into the first/last bin.
_BIN_EDGES = np.logspace(-5, 2, 7 * 8 + 1)


def hist_percentile(hist: np.ndarray, p: float,
                    max_value: Optional[float] = None) -> float:
    """p-th percentile of a ``_BIN_EDGES`` histogram (geometric bin
    midpoint). One formula shared by ``LatencyTracker`` and the
    autoscaler's *windowed* p95 (which differences two pooled histograms —
    a deque of raw samples could not be windowed across replica churn).

    Edge cases: an **empty** histogram answers 0.0 — there is nothing to
    interpolate across, and callers that need "no data" semantics check
    the count before asking (``LatencyTracker.snapshot`` keeps its NaN
    fields; the autoscaler only closes a window at ``min_window_samples``).
    A **single-sample** histogram answers ``max_value`` when the caller
    supplies it (the sample itself) instead of the bin midpoint."""
    total = int(hist.sum())
    if total == 0:
        return 0.0
    if total == 1 and max_value is not None:
        return float(max_value)
    target = (p / 100.0) * total
    cum = np.cumsum(hist)
    b = int(np.searchsorted(cum, max(target, 1), side="left"))
    if b == 0:
        return float(_BIN_EDGES[0])
    if b >= _BIN_EDGES.size:
        hi = _BIN_EDGES[-1]
        return float(min(hi, max_value) if max_value is not None else hi)
    return float(np.sqrt(_BIN_EDGES[b - 1] * _BIN_EDGES[b]))


class LatencyTracker:
    """Latency distribution: exact-sample reservoir + mergeable histogram.

    While at most ``maxlen`` samples have been recorded the reservoir holds
    the complete population and percentiles are exact. Beyond that the
    fixed log-bin histogram (which never evicts) answers percentile
    queries, so long-running and *merged* trackers stay correct.

    Thread-safe: the engine's retirement thread records completions while
    the main thread records queue waits and the cluster reads snapshots.
    ``lock`` lets ``EngineMetrics`` share ONE reentrant lock across its
    trackers and counters so a snapshot never tears across fields.
    """

    def __init__(self, maxlen: int = 8192, lock=None) -> None:
        self._maxlen = maxlen
        self._lock = lock if lock is not None else threading.RLock()
        self._samples: deque = deque(maxlen=maxlen)
        self._hist = np.zeros(_BIN_EDGES.size + 1, np.int64)
        self._total = 0
        self._sum = 0.0
        self._max = float("-inf")

    def record(self, seconds: float) -> None:
        s = float(seconds)
        with self._lock:
            self._samples.append(s)
            self._hist[np.searchsorted(_BIN_EDGES, s, side="right")] += 1
            self._total += 1
            self._sum += s
            self._max = max(self._max, s)

    def __len__(self) -> int:
        return self._total

    @property
    def exact(self) -> bool:
        """Whether the reservoir still holds every recorded sample."""
        return self._total <= self._maxlen

    def merge(self, other: "LatencyTracker") -> None:
        """Fold another tracker's distribution into this one (cluster
        roll-up). Histograms add; samples pool while both sides are
        complete, after which the histogram carries the percentiles.
        The source is copied under its own lock (a live replica keeps
        recording during a roll-up), then folded in under ours —
        sequential, never nested, so two-way merges cannot deadlock."""
        with other._lock:
            hist = other._hist.copy()
            total, ssum, smax = other._total, other._sum, other._max
            samples = list(other._samples)
        with self._lock:
            self._hist += hist
            self._total += total
            self._sum += ssum
            self._max = max(self._max, smax)
            for s in samples:
                self._samples.append(s)

    @classmethod
    def merged(cls, trackers: Sequence["LatencyTracker"],
               maxlen: int = 65536) -> "LatencyTracker":
        out = cls(maxlen=maxlen)
        for t in trackers:
            out.merge(t)
        return out

    def _hist_percentile(self, p: float) -> float:
        """Percentile from the log-bin histogram (geometric bin midpoint)."""
        return hist_percentile(self._hist, p, max_value=self._max)

    def percentile(self, p: float) -> float:
        """p-th percentile in seconds (0.0 when empty — nothing recorded
        means no latency, and the NaN "no data" signal lives in
        ``snapshot``'s fields). A single-sample tracker answers the sample
        itself (``_max``), never a bin midpoint. Exact while the sample
        reservoir is complete; histogram-interpolated after."""
        with self._lock:
            if self._total == 0:
                return 0.0
            if self._total == 1:
                return self._max
            if self.exact and len(self._samples) == self._total:
                return float(np.percentile(np.asarray(self._samples), p))
            return self._hist_percentile(p)

    def hist_data(self):
        """(bin_edges, counts, total, sum, max) copied under the lock —
        the raw material for ``ClusterMetrics.export_prometheus``'s
        cumulative-bucket rendering."""
        with self._lock:
            return (_BIN_EDGES, self._hist.copy(), int(self._total),
                    float(self._sum), float(self._max))

    def snapshot(self) -> Dict[str, float]:
        """Milliseconds, the unit the paper's latency tables use."""
        with self._lock:
            if self._total == 0:
                return {"n": 0, "p50": float("nan"), "p95": float("nan"),
                        "p99": float("nan"), "mean": float("nan"),
                        "max": float("nan")}
            return {
                "n": int(self._total),
                "p50": self.percentile(50) * 1e3,
                "p95": self.percentile(95) * 1e3,
                "p99": self.percentile(99) * 1e3,
                "mean": (self._sum / self._total) * 1e3,
                "max": self._max * 1e3,
            }


class EngineMetrics:
    """Counters + latency + occupancy for one engine instance.

    Counter names in use (an engine touches the subset that applies):
      submitted / completed / rejected — request lifecycle
      cancelled                       — QoS deadline drops (queued or mid-
                                        generation; serving/engine.py)
      batches                         — device batches dispatched
      prefill_batches                 — prefill dispatches (LM admission)
      frames                          — images completed (vision)
      padded_frames                   — pad rows added to fill a bucket
      tokens                          — decode tokens produced (LM)
      pack_real_tokens                — prompt tokens in prefill dispatches
      pack_pad_tokens                 — padding tokens in prefill dispatches
                                        (LM pack buffer / vision pad ladder;
                                        real+pad = dispatched buffer size)
      retraces                        — serving-path program compiles after
                                        construction; must stay 0 once
                                        ``warmup()`` has run (DESIGN.md §10)
      callback_errors                 — Request.on_done raised
      retire_errors                   — retirement events whose processing
                                        raised (event payload lost; the
                                        retirement thread itself survives)

    Thread-safe: async retirement mutates completion counters and latency
    trackers from the retirement thread while the decode loop writes
    dispatch counters and the cluster reads ``snapshot()``; one shared
    reentrant lock covers the counters and all three trackers.
    """

    def __init__(self, num_experts: int = 0,
                 clock: Callable[[], float] = time.monotonic) -> None:
        self._clock = clock
        self._lock = threading.RLock()
        self.counters: Dict[str, int] = {}
        self.request_latency = LatencyTracker(lock=self._lock)
        self.batch_latency = LatencyTracker(lock=self._lock)
        # admission-queue wait, stamped when a request leaves the queue
        # (LM: before its prefill starts; vision: at batch dispatch)
        self.queue_wait = LatencyTracker(lock=self._lock)
        # per-program step wall times, keyed by the section-10 AOT program
        # key (serve/decode|B=..|S=.., serve/packed_prefill|...|bucket=..,
        # classify|b=..): the per-bucket step-latency signal the ROADMAP
        # autotuner-drift item needs. Trackers share the metrics lock, so
        # a snapshot never tears across programs.
        self.step_latency: Dict[str, LatencyTracker] = {}
        # ProgramCost table (serving/introspect.py, DESIGN.md section 12):
        # one row per AOT program, same keys as step_latency, captured at
        # warmup(). Static after capture — snapshot() joins it with the
        # measured step histograms into per-program MFU / achieved-HBM-BW /
        # roofline classification.
        self.program_costs: Dict[str, dict] = {}
        # resolved roofline peaks ({peak_flops, hbm_bw, ici_bw, ...} from
        # repro.analysis.hw.device_peaks) — the MFU denominator
        self.peaks: Optional[dict] = None
        # live memory-watermark probe (introspect.memory_watermark closure);
        # snapshot() calls it outside the lock and caches the last answer
        self.memory_probe: Optional[Callable[[], dict]] = None
        self._memory: Optional[dict] = None
        # expert-routing health monitor (introspect.ExpertHealthMonitor),
        # fed by add_expert_tokens OUTSIDE the metrics lock — the monitor
        # has its own lock and may call back into inc() on drift
        self.expert_health = None
        self.expert_tokens = np.zeros(max(0, num_experts), np.int64)
        self._depth_sum = 0
        self._depth_max = 0
        self._depth_last = 0
        self._depth_n = 0
        self._first_t: Optional[float] = None
        self._last_t: Optional[float] = None

    # -- feeding ------------------------------------------------------------

    def inc(self, name: str, n: int = 1) -> None:
        with self._lock:
            self.counters[name] = self.counters.get(name, 0) + n
            if name == "submitted" and self._first_t is None:
                # FPS window opens at first arrival
                self._first_t = self._clock()

    def observe_queue_depth(self, depth: int) -> None:
        with self._lock:
            self._depth_sum += depth
            self._depth_max = max(self._depth_max, depth)
            self._depth_last = depth
            self._depth_n += 1

    def add_expert_tokens(self, counts) -> None:
        """Accumulate a routed-token histogram (host array, [num_experts])."""
        a = np.asarray(counts, np.int64)
        with self._lock:
            if a.size and self.expert_tokens.size == a.size:
                self.expert_tokens += a
            monitor = self.expert_health
        if monitor is not None:
            # outside our lock: the monitor takes its own lock and calls
            # back into inc() on drift (monitor -> metrics, never reverse)
            monitor.update(a)

    def set_program_cost(self, key: str, cost: dict) -> None:
        with self._lock:
            self.program_costs[key] = cost

    def set_peaks(self, peaks: dict) -> None:
        with self._lock:
            self.peaks = peaks

    def set_memory(self, mem: dict) -> None:
        with self._lock:
            self._memory = mem

    def adopt_static(self, other: "EngineMetrics") -> None:
        """Carry another metrics object's *static* introspection surface
        (ProgramCost rows, peaks, memory probe, health monitor) into this
        one. Engines call it from ``reset_metrics()``: cost rows describe
        compiled programs, not accumulated load, so a drained replica that
        rejoins keeps them without any double-counting."""
        with other._lock:
            costs = dict(other.program_costs)
            peaks = other.peaks
            probe = other.memory_probe
            mem = other._memory
            monitor = other.expert_health
        with self._lock:
            self.program_costs.update(costs)
            self.peaks = peaks if peaks is not None else self.peaks
            self.memory_probe = probe
            self._memory = mem
            self.expert_health = monitor

    def record_step(self, key: str, seconds: float) -> None:
        """Record one program dispatch's wall time under its AOT program
        key (decode tick, packed-prefill bucket, classify bucket)."""
        with self._lock:
            t = self.step_latency.get(key)
            if t is None:
                t = self.step_latency[key] = LatencyTracker(
                    maxlen=4096, lock=self._lock)
            t.record(seconds)

    def work_done(self, n: int, unit: str = "frames") -> None:
        """Mark n units (frames/tokens) complete; drives the FPS window."""
        with self._lock:
            self.inc(unit, n)
            now = self._clock()
            if self._first_t is None:
                self._first_t = now
            self._last_t = now

    # -- readout ------------------------------------------------------------

    @property
    def window(self):
        """(first_submission_t, last_completion_t) — the FPS window bounds
        (either may be None). ``ClusterMetrics`` unions replica windows."""
        return self._first_t, self._last_t

    @property
    def fps(self) -> float:
        """Completed frames (or tokens for LM engines) per wall second,
        measured from the first submission to the last completion event."""
        n = self.counters.get("frames", 0) or self.counters.get("tokens", 0)
        if self._first_t is None or self._last_t is None \
                or self._last_t <= self._first_t:
            return float("nan")
        return n / (self._last_t - self._first_t)

    def occupancy(self) -> np.ndarray:
        """Per-expert fraction of all routed (token, slot) pairs."""
        total = self.expert_tokens.sum()
        if total == 0:
            return np.zeros_like(self.expert_tokens, np.float64)
        return self.expert_tokens / float(total)

    def snapshot(self) -> dict:
        """The metrics schema (DESIGN.md section 6)."""
        mem = None
        probe = self.memory_probe
        if probe is not None:
            try:
                mem = probe()  # device memory_stats outside the lock
            except Exception:
                mem = None
        with self._lock:
            if mem is not None:
                self._memory = mem
            return self._snapshot_locked()

    def _snapshot_locked(self) -> dict:
        monitor = self.expert_health
        return {
            "counters": dict(self.counters),
            "fps": self.fps,
            "latency_ms": self.request_latency.snapshot(),
            "batch_latency_ms": self.batch_latency.snapshot(),
            "queue_wait_ms": self.queue_wait.snapshot(),
            "queue_depth": {
                "mean": (self._depth_sum / self._depth_n)
                if self._depth_n else 0.0,
                "max": self._depth_max,
                "last": self._depth_last,
            },
            "step_latency_ms": {k: t.snapshot()
                                for k, t in sorted(self.step_latency.items())},
            "program_perf": program_perf(self.program_costs,
                                         self.step_latency, self.peaks),
            "memory": self._memory,
            "expert_health": (monitor.snapshot()
                              if monitor is not None else None),
            "expert_tokens": self.expert_tokens.tolist(),
            "expert_occupancy": _occupancy_of(self.expert_tokens),
        }


def _occupancy_of(tokens: np.ndarray) -> List[float]:
    """Normalized + rounded occupancy — the one formula both the replica
    and the aggregate snapshot fields render with."""
    total = tokens.sum()
    if total == 0:
        return [0.0] * int(tokens.size)
    return [round(float(x), 6) for x in tokens / float(total)]


def _occupancy_stats(tokens: np.ndarray) -> Optional[dict]:
    """Entropy + hot/cold skew of a routed-token histogram — the pooled
    (whole-run) counterpart of the drift monitor's per-window stats."""
    total = float(tokens.sum()) if tokens.size else 0.0
    if total == 0:
        return None
    occ = tokens / total
    nz = occ[occ > 0]
    e = int(tokens.size)
    entropy = (float(-(nz * np.log(nz)).sum() / np.log(e))
               if e > 1 else 1.0)
    hot, cold = float(occ.max()), float(occ.min())
    return {
        "entropy": round(entropy, 6),
        "hot_cold_skew": round(hot / max(cold, 1.0 / (e * 1e3)), 3),
        "hot_expert": int(occ.argmax()),
        "cold_expert": int(occ.argmin()),
    }


def program_perf(costs: Dict[str, dict],
                 steps: Dict[str, "LatencyTracker"],
                 peaks: Optional[dict]) -> Dict[str, dict]:
    """Join the ProgramCost table with measured per-program step-latency
    histograms (DESIGN.md section 12): per program this yields

      * the roofline terms t_compute = flops/peak_flops, t_memory =
        hbm_bytes/hbm_bw, t_collective = collective_bytes/ici_bw, with
        ``bound`` naming the dominant term;
      * measured MFU = flops / (p50 step seconds * peak_flops) and
        achieved HBM bandwidth = hbm_bytes / p50 step seconds;
      * ``roofline_frac`` = roofline-predicted step time over measured
        p50 (1.0 means the program runs at the hardware limit).

    p50 (not mean) anchors the measured side: step-time distributions are
    long-tailed (host jitter, retirement interleaving) and MFU should
    describe the typical dispatch. Rows appear for any key with a cost OR
    a measurement; the join fields only when both sides exist."""
    out: Dict[str, dict] = {}
    pf = float(peaks.get("peak_flops", 0)) if peaks else 0.0
    bw = float(peaks.get("hbm_bw", 0)) if peaks else 0.0
    ici = float(peaks.get("ici_bw", 0)) if peaks else 0.0
    for key in sorted(set(costs) | set(steps)):
        c = costs.get(key)
        row: dict = {}
        flops = hbm = coll = -1.0
        if c:
            flops = float(c.get("flops", -1.0))
            hbm = float(c.get("hbm_bytes", -1.0))
            coll = float(c.get("collective_bytes", 0.0) or 0.0)
            row["flops"] = flops
            row["hbm_bytes"] = hbm
            row["collective_bytes"] = coll
            row["estimated"] = bool(c.get("estimated", False))
            row["source"] = c.get("source", "")
            t_c = flops / pf if (flops > 0 and pf) else 0.0
            t_m = hbm / bw if (hbm > 0 and bw) else 0.0
            t_x = coll / ici if (coll > 0 and ici) else 0.0
            if t_c or t_m or t_x:
                terms = {"compute": t_c, "memory": t_m, "collective": t_x}
                row["t_compute_s"] = t_c
                row["t_memory_s"] = t_m
                row["t_collective_s"] = t_x
                row["bound"] = max(terms, key=terms.get)
                row["roofline_step_s"] = max(t_c, t_m, t_x)
        t = steps.get(key)
        if t is not None and len(t):
            sec = t.percentile(50)
            row["steps"] = len(t)
            row["step_p50_ms"] = round(sec * 1e3, 4)
            if sec > 0 and c:
                if flops > 0 and pf:
                    row["mfu"] = round(flops / sec / pf, 6)
                if hbm > 0:
                    row["achieved_hbm_gbps"] = round(hbm / sec / 1e9, 3)
                    if bw:
                        row["hbm_util"] = round(hbm / sec / bw, 6)
                rf = row.get("roofline_step_s", 0.0)
                if rf > 0:
                    row["roofline_frac"] = round(rf / sec, 6)
        if row:
            out[key] = row
    return out


class ClusterMetrics:
    """Merge-safe roll-up over N replica ``EngineMetrics`` (DESIGN.md §7-8).

    Aggregation rules:
      * counters — summed;
      * FPS — total frames over the *union* of replica windows (earliest
        first-submission to latest completion), not a sum of replica FPS
        (replica windows overlap under shared load);
      * latency percentiles — ``LatencyTracker.merged`` over the pooled
        distribution (histogram-sum + sample pooling), never an average of
        per-replica percentiles;
      * per-expert occupancy — routed-token histograms summed across
        replicas, then normalized.

    Membership is **dynamic** (autoscaling): ``add_replica`` joins a
    replica's metrics to the live set; ``remove_replica`` folds the leaving
    replica's whole distribution into a *retired accumulator* (histogram
    merge — exactly what makes ``LatencyTracker`` merge-safe), so cluster
    totals, percentiles, and the FPS window never lose a drained replica's
    history. The cluster resets the engine's own ``EngineMetrics`` after
    the fold, so a replica that later rejoins is never double-counted.
    ``mark_replicas`` records the (t, active-count) timeline the autoscale
    benchmark plots.
    """

    def __init__(self, replicas: Sequence[EngineMetrics],
                 clock: Callable[[], float] = time.monotonic) -> None:
        self._replicas = list(replicas)
        self._clock = clock
        self._first_t: Optional[float] = None
        # cluster-front-end counters (admission rejections etc.). Guarded:
        # replica retirement daemons feed the at-most-once guard's
        # duplicate counter (serving/cluster.py) off the pump thread.
        self._counter_lock = threading.Lock()
        self.counters: Dict[str, int] = {}
        # front-end queue-depth samples (the autoscaler's pressure signal)
        self._depth_sum = 0
        self._depth_max = 0
        self._depth_last = 0
        self._depth_n = 0
        # retired accumulator: drained replicas fold in here
        self._ret_request = LatencyTracker(maxlen=65536)
        self._ret_batch = LatencyTracker(maxlen=65536)
        self._ret_queue_wait = LatencyTracker(maxlen=65536)
        self._ret_steps: Dict[str, LatencyTracker] = {}
        # ProgramCost rows + peaks survive replica churn here: cost rows
        # are static program properties (no double-count concern), so the
        # fold just unions keys, preferring measured over estimated rows
        self._ret_costs: Dict[str, dict] = {}
        self._ret_peaks: Optional[dict] = None
        self._ret_counters: Dict[str, int] = {}
        self._ret_tokens: Optional[np.ndarray] = None
        self._ret_first: Optional[float] = None
        self._ret_last: Optional[float] = None
        # (t, active-replica-count) — appended by mark_replicas on every
        # scale event (and at cluster construction)
        self._timeline: List[tuple] = []

    # -- membership ---------------------------------------------------------

    @property
    def num_replicas(self) -> int:
        return len(self._replicas)

    def add_replica(self, m: EngineMetrics) -> None:
        """Join a replica's metrics to the live set (replica scale-up)."""
        if m not in self._replicas:
            self._replicas.append(m)

    def remove_replica(self, m: EngineMetrics) -> None:
        """Fold a leaving replica's distribution into the retired
        accumulator (replica drain). The caller must reset the engine's
        metrics afterwards (``engine.reset_metrics()``) or a rejoin would
        double-count."""
        if m in self._replicas:
            self._replicas.remove(m)
        self._ret_request.merge(m.request_latency)
        self._ret_batch.merge(m.batch_latency)
        self._ret_queue_wait.merge(m.queue_wait)
        # per-program step histograms fold key-by-key: a replica that
        # rejoins after a drain starts fresh, the retired accumulator keeps
        # its whole step-latency history per bucket
        with m._lock:
            step_items = list(m.step_latency.items())
        for k, t in step_items:
            acc = self._ret_steps.get(k)
            if acc is None:
                acc = self._ret_steps[k] = LatencyTracker(maxlen=65536)
            acc.merge(t)
        with m._lock:
            costs = dict(m.program_costs)
            peaks = m.peaks
        for k, c in costs.items():
            old = self._ret_costs.get(k)
            if old is None or (old.get("estimated")
                               and not c.get("estimated")):
                self._ret_costs[k] = c
        if peaks is not None:
            self._ret_peaks = peaks
        for k, v in m.counters.items():
            self._ret_counters[k] = self._ret_counters.get(k, 0) + v
        if m.expert_tokens.size:
            if self._ret_tokens is None:
                self._ret_tokens = m.expert_tokens.astype(np.int64).copy()
            elif self._ret_tokens.size == m.expert_tokens.size:
                self._ret_tokens += m.expert_tokens
        f, l = m.window
        if f is not None:
            self._ret_first = f if self._ret_first is None \
                else min(self._ret_first, f)
        if l is not None:
            self._ret_last = l if self._ret_last is None \
                else max(self._ret_last, l)

    def mark_replicas(self, n: int) -> None:
        """Append (now, active-replica-count) to the scale timeline."""
        self._timeline.append((self._clock(), int(n)))

    @property
    def replica_timeline(self) -> List[tuple]:
        return list(self._timeline)

    # -- feeding ------------------------------------------------------------

    def inc(self, name: str, n: int = 1) -> None:
        with self._counter_lock:
            self.counters[name] = self.counters.get(name, 0) + n
            if name == "cluster_submitted" and self._first_t is None:
                self._first_t = self._clock()  # window opens at admission

    def observe_queue_depth(self, depth: int) -> None:
        """Sample the *front-end* queue depth (cluster route path)."""
        with self._counter_lock:
            self._depth_sum += depth
            self._depth_max = max(self._depth_max, depth)
            self._depth_last = depth
            self._depth_n += 1

    # -- readout ------------------------------------------------------------

    @property
    def fps(self) -> float:
        frames = sum(
            m.counters.get("frames", 0) or m.counters.get("tokens", 0)
            for m in self._replicas
        )
        frames += (self._ret_counters.get("frames", 0)
                   or self._ret_counters.get("tokens", 0))
        firsts = [m.window[0] for m in self._replicas
                  if m.window[0] is not None]
        if self._first_t is not None:
            firsts.append(self._first_t)  # front-end admission opens earlier
        if self._ret_first is not None:
            firsts.append(self._ret_first)
        lasts = [m.window[1] for m in self._replicas
                 if m.window[1] is not None]
        if self._ret_last is not None:
            lasts.append(self._ret_last)
        if not firsts or not lasts or max(lasts) <= min(firsts):
            return float("nan")
        return frames / (max(lasts) - min(firsts))

    def merged_request_latency(self) -> LatencyTracker:
        t = LatencyTracker.merged(
            [m.request_latency for m in self._replicas])
        t.merge(self._ret_request)
        return t

    def pooled_request_hist(self) -> np.ndarray:
        """Pooled request-latency histogram (live replicas + retired).

        Monotone non-decreasing over time as long as the leave protocol is
        followed (fold into retired, then reset), which is what lets the
        autoscaler difference two snapshots into a *windowed* percentile."""
        h = self._ret_request._hist.copy()
        for m in self._replicas:
            with m.request_latency._lock:
                h = h + m.request_latency._hist
        return h

    def merged_step_latency(self) -> Dict[str, LatencyTracker]:
        """Per-program step-latency trackers pooled over live replicas plus
        the retired accumulator (same merge rule as request latency)."""
        out: Dict[str, LatencyTracker] = {}
        sources: List[Dict[str, LatencyTracker]] = [self._ret_steps]
        for m in self._replicas:
            with m._lock:
                sources.append(dict(m.step_latency))
        for src in sources:
            for k, t in src.items():
                acc = out.get(k)
                if acc is None:
                    acc = out[k] = LatencyTracker(maxlen=65536)
                acc.merge(t)
        return out

    def merged_program_costs(self) -> Dict[str, dict]:
        """ProgramCost union over retired + live replicas. Live rows win
        over retired ones (and measured over estimated): replicas compile
        the same program grid, so same-key rows describe the same program."""
        out = dict(self._ret_costs)
        for m in self._replicas:
            with m._lock:
                costs = dict(m.program_costs)
            for k, c in costs.items():
                old = out.get(k)
                if old is None or (old.get("estimated")
                                   and not c.get("estimated")):
                    out[k] = c
        return out

    def merged_peaks(self) -> Optional[dict]:
        """Roofline peaks for the aggregate join — replicas are homogeneous
        (one device kind per cluster), so any replica's answer serves."""
        for m in self._replicas:
            if m.peaks is not None:
                return m.peaks
        return self._ret_peaks

    def snapshot(self) -> dict:
        counters: Dict[str, int] = dict(self.counters)
        for k, v in self._ret_counters.items():
            counters[k] = counters.get(k, 0) + v
        for m in self._replicas:
            for k, v in m.counters.items():
                counters[k] = counters.get(k, 0) + v
        sizes = {m.expert_tokens.size for m in self._replicas}
        if self._ret_tokens is not None:
            sizes.add(self._ret_tokens.size)
        if len(sizes) == 1 and (self._replicas
                                or self._ret_tokens is not None):
            tokens = np.sum(
                [m.expert_tokens for m in self._replicas]
                + ([self._ret_tokens] if self._ret_tokens is not None
                   else []),
                axis=0)
        else:
            tokens = np.zeros(0, np.int64)
        batch_lat = LatencyTracker.merged(
            [m.batch_latency for m in self._replicas])
        batch_lat.merge(self._ret_batch)
        queue_wait = LatencyTracker.merged(
            [m.queue_wait for m in self._replicas])
        queue_wait.merge(self._ret_queue_wait)
        replica_snaps = [m.snapshot() for m in self._replicas]
        mem_rows = [s["memory"] for s in replica_snaps
                    if s.get("memory") is not None]
        memory = None
        if mem_rows:
            memory = {
                "replicas": len(mem_rows),
                "param_bytes": sum(r.get("param_bytes", 0)
                                   for r in mem_rows),
                "kv_cache_bytes": sum(r.get("kv_cache_bytes", 0)
                                      for r in mem_rows),
                "watermark_bytes": sum(r.get("watermark_bytes", 0)
                                       for r in mem_rows),
                "estimated": any(r.get("estimated", True)
                                 for r in mem_rows),
            }
        health = _occupancy_stats(tokens)
        if health is not None:
            # the expert_drift counter folds through retirement like any
            # other counter, so this survives replica churn
            health["drift_events"] = counters.get("expert_drift", 0)
        return {
            "replicas": replica_snaps,
            "aggregate": {
                "counters": counters,
                "fps": self.fps,
                "latency_ms": self.merged_request_latency().snapshot(),
                "batch_latency_ms": batch_lat.snapshot(),
                "queue_wait_ms": queue_wait.snapshot(),
                "step_latency_ms": {
                    k: t.snapshot()
                    for k, t in sorted(self.merged_step_latency().items())},
                "program_perf": program_perf(self.merged_program_costs(),
                                             self.merged_step_latency(),
                                             self.merged_peaks()),
                "memory": memory,
                "expert_health": health,
                "front_queue_depth": {
                    "mean": (self._depth_sum / self._depth_n)
                    if self._depth_n else 0.0,
                    "max": self._depth_max,
                    "last": self._depth_last,
                },
                "expert_tokens": tokens.tolist(),
                "expert_occupancy": _occupancy_of(tokens),
            },
            "replicas_active": (self._timeline[-1][1] if self._timeline
                                else len(self._replicas)),
            "replica_timeline": [[t, n] for t, n in self._timeline],
        }

    def export_prometheus(self) -> str:
        """Prometheus text-exposition rendering of every aggregate counter,
        gauge, and latency histogram (DESIGN.md section 11).

        Counters land as one ``repro_serving_events_total`` family labeled
        by counter name; latency distributions render as cumulative
        histograms over the log-spaced ``_BIN_EDGES`` (``le`` in seconds,
        +Inf closing bucket, ``_sum``/``_count`` series); per-program step
        latencies carry a ``program`` label. The bucket boundaries are the
        same merge-safe bins the autoscaler windows over, so a scrape and a
        scale decision read one distribution."""
        snap = self.snapshot()
        agg = snap["aggregate"]
        lines: List[str] = []

        lines.append("# TYPE repro_serving_events_total counter")
        for k, v in sorted(agg["counters"].items()):
            lines.append(f'repro_serving_events_total{{event="{k}"}} {v}')

        fps = agg["fps"]
        lines.append("# TYPE repro_serving_fps gauge")
        lines.append("repro_serving_fps "
                     f"{0.0 if fps != fps else fps}")
        lines.append("# TYPE repro_serving_replicas_active gauge")
        lines.append(f"repro_serving_replicas_active "
                     f"{snap['replicas_active']}")
        depth = agg["front_queue_depth"]
        lines.append("# TYPE repro_serving_front_queue_depth gauge")
        for stat in ("mean", "max", "last"):
            lines.append(f'repro_serving_front_queue_depth{{stat="{stat}"}} '
                         f"{depth[stat]}")
        if agg["expert_tokens"]:
            lines.append("# TYPE repro_serving_expert_tokens_total counter")
            for i, v in enumerate(agg["expert_tokens"]):
                lines.append(
                    f'repro_serving_expert_tokens_total{{expert="{i}"}} {v}')

        batch_lat = LatencyTracker.merged(
            [m.batch_latency for m in self._replicas])
        batch_lat.merge(self._ret_batch)
        queue_wait = LatencyTracker.merged(
            [m.queue_wait for m in self._replicas])
        queue_wait.merge(self._ret_queue_wait)
        for name, tracker in (
            ("repro_request_latency_seconds", self.merged_request_latency()),
            ("repro_batch_latency_seconds", batch_lat),
            ("repro_queue_wait_seconds", queue_wait),
        ):
            lines += _prom_histogram(name, tracker)
        steps = self.merged_step_latency()
        if steps:
            lines.append("# TYPE repro_step_latency_seconds histogram")
            for key, tracker in sorted(steps.items()):
                lines += _prom_histogram(
                    "repro_step_latency_seconds", tracker,
                    labels=f'program="{key}"', typed=False)

        # -- introspection surface (DESIGN.md section 12) -------------------
        perf = agg.get("program_perf") or {}
        for metric, field in (
            ("repro_program_mfu", "mfu"),
            ("repro_program_achieved_hbm_bytes_per_second", None),
            ("repro_program_flops", "flops"),
            ("repro_program_hbm_bytes", "hbm_bytes"),
            ("repro_program_roofline_frac", "roofline_frac"),
            ("repro_program_cost_estimated", "estimated"),
        ):
            rows = []
            for key, row in sorted(perf.items()):
                if metric == "repro_program_achieved_hbm_bytes_per_second":
                    v = row.get("achieved_hbm_gbps")
                    v = v * 1e9 if v is not None else None
                elif field == "estimated":
                    v = float(bool(row["estimated"])) \
                        if "estimated" in row else None
                else:
                    v = row.get(field)
                    if v is not None and v < 0:
                        v = None
                if v is not None:
                    rows.append((key, v))
            if rows:
                lines.append(f"# TYPE {metric} gauge")
                for key, v in rows:
                    lines.append(f'{metric}{{program="{key}"}} {v:g}')
        bound_rows = [(k, r["bound"]) for k, r in sorted(perf.items())
                      if "bound" in r]
        if bound_rows:
            lines.append("# TYPE repro_program_roofline_bound gauge")
            for key, bound in bound_rows:
                lines.append('repro_program_roofline_bound'
                             f'{{program="{key}",bound="{bound}"}} 1')

        mem_lines = []
        for i, rsnap in enumerate(snap["replicas"]):
            mem = rsnap.get("memory")
            if not mem:
                continue
            for kind in ("param_bytes", "kv_cache_bytes",
                         "watermark_bytes", "bytes_in_use", "bytes_limit",
                         "expert_stack_bytes", "int4_packed_bytes"):
                if kind in mem:
                    mem_lines.append(
                        'repro_replica_memory_bytes'
                        f'{{replica="{i}",kind="{kind}"}} {mem[kind]}')
        if mem_lines:
            lines.append("# TYPE repro_replica_memory_bytes gauge")
            lines += mem_lines

        health = agg.get("expert_health")
        if health:
            lines.append("# TYPE repro_expert_occupancy_entropy gauge")
            lines.append("repro_expert_occupancy_entropy "
                         f"{health['entropy']}")
            lines.append("# TYPE repro_expert_hot_cold_skew gauge")
            lines.append("repro_expert_hot_cold_skew "
                         f"{health['hot_cold_skew']}")
        return "\n".join(lines) + "\n"


def _prom_histogram(name: str, tracker: LatencyTracker,
                    labels: str = "", typed: bool = True) -> List[str]:
    """Cumulative Prometheus histogram series from a ``LatencyTracker``'s
    log-bin histogram (le= boundaries in seconds)."""
    edges, counts, total, ssum, _ = tracker.hist_data()
    sep = "," if labels else ""
    out: List[str] = []
    if typed:
        out.append(f"# TYPE {name} histogram")
    cum = 0
    for i, edge in enumerate(edges):
        cum += int(counts[i])
        out.append(f'{name}_bucket{{{labels}{sep}le="{edge:g}"}} {cum}')
    out.append(f'{name}_bucket{{{labels}{sep}le="+Inf"}} {total}')
    out.append(f"{name}_sum{{{labels}}} {ssum}" if labels
               else f"{name}_sum {ssum}")
    out.append(f"{name}_count{{{labels}}} {total}" if labels
               else f"{name}_count {total}")
    return out
