"""Batched serving engine: prefill + decode with the CoQMoE quantized
inference path (INT8 K/V cache + 4-bit log-sqrt2 attention probabilities
when ``cfg.quant.enable``).

``build_serve_step`` is the unit the multi-pod dry-run lowers for decode
shape cells: one new token per sequence against a seq_len-deep cache.

``ServeEngine`` adds slot-based continuous batching on top: a fixed batch of
decode slots; finished sequences release their slot and queued prompts are
admitted from the shared ``MicroBatcher`` scheduler (DESIGN.md section 6 —
the same scheduler ``VisionEngine`` batches on) and prefilled into it (cache
writes at the slot index). The decode tick runs through ``build_serve_step``
so the K/V cache buffer is *donated* — updated in place, never copied per
token.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import models
from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed.sharding_rules import (
    EXPERT_PARALLEL_RULES,
    SERVING_RULES,
    cache_specs,
    fit_specs_to_tree,
    input_shardings,
    param_specs,
)
from repro.launch.mesh import make_host_mesh
from repro.serving.metrics import EngineMetrics
from repro.serving.scheduler import MicroBatcher


def serving_config(cfg: ModelConfig) -> ModelConfig:
    """Serving always uses the *dropless* grouped (unified-kernel) MoE path:
    capacity-based GShard dispatch may drop tokens, which is acceptable in
    training but makes generation non-deterministic vs the prompt run."""
    if cfg.moe is not None and cfg.moe.impl != "grouped":
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, impl="grouped"))
    return cfg


def lowering_config(cfg: ModelConfig) -> ModelConfig:
    """Cost-model stand-in for the dry-run: on TPU the grouped path is the
    Pallas megablox kernel (each expert's weights stream HBM->VMEM once);
    XLA's ragged_dot lowering on the host backend is a *dense* all-experts
    contraction, which would overstate decode FLOPs ~1000x. The GShard
    einsum with generous capacity has the kernel's true cost shape —
    weights read once, compute proportional to routed tokens — so decode
    cells lower through it (EXPERIMENTS.md section Perf, qwen3 iteration)."""
    if cfg.moe is not None:
        cfg = cfg.replace(moe=dataclasses.replace(
            cfg.moe, impl="gshard", capacity_factor=4.0))
    return cfg


def build_serve_step(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                     *, donate_cache: bool = True, for_lowering: bool = False,
                     params=None, with_stats: bool = False,
                     rules=SERVING_RULES):
    """Jitted decode step: (params, tokens [B,1], cache, index) ->
    (logits, new_cache). The cache buffer is donated (updated in place).

    ``params``: pass the *actual* (possibly PTQ-transformed) param tree when
    it differs structurally from ``models.abstract_params`` — e.g. a
    QuantizedParams tree from ``ptq_model(..., materialize="int8")`` with
    int8 weight leaves plus ``_scale``/``_as`` siblings. The in_shardings
    are fitted to that tree (int8 weights inherit their fp ancestors' specs;
    scale leaves replicate) so the decode step executes the stored int8
    format directly through the int8 kernels.

    ``with_stats=True`` (transformer MoE families) appends the per-step
    routed-token histogram to the outputs: (logits, new_cache,
    {"expert_tokens": [E] int32}).

    ``rules``: sharding rules for the param specs. With
    ``EXPERT_PARALLEL_RULES`` only the expert stacks shard over 'model' and
    every activation/cache buffer replicates — the EP exchange happens
    inside ``shard_map`` on tokens, so a context-parallel cache layout
    would only fight the all_to_all (and the eager prefill merge).

    Kernel tile configs are resolved at trace time from the ambient
    autotune table (kernels/autotune.py): compile this step *after*
    ``autotune.ensure_tuned`` (engine ``warmup()`` orders the two) and the
    decode program bakes the device-tuned tiles."""
    cfg = lowering_config(cfg) if for_lowering else serving_config(cfg)
    mod = models.module_for(cfg)
    # value (not identity) comparison: an equal copy of the EP rules must
    # get the same replicated-activation layout
    replicate_activations = dict(rules) == dict(EXPERT_PARALLEL_RULES)

    def serve_step(params, tokens, cache, index):
        if with_stats:
            return mod.decode_step(params, cfg, tokens, cache, index,
                                   with_stats=True)
        return mod.decode_step(params, cfg, tokens, cache, index)

    p_specs = param_specs(cfg, mesh, rules=rules)
    if params is not None:
        p_specs = fit_specs_to_tree(p_specs, params)
    in_tree = models.input_specs(cfg, shape)
    b_specs = input_shardings(cfg, shape, mesh, in_tree)
    if replicate_activations:
        b_specs = jax.tree.map(lambda _: P(), b_specs,
                               is_leaf=lambda x: isinstance(x, P))
    named = lambda tree: jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda x: isinstance(x, P),
    )
    return jax.jit(
        serve_step,
        in_shardings=(
            named(p_specs),
            named(b_specs["tokens"]),
            named(b_specs["cache"]),
            named(b_specs["index"]),
        ),
        out_shardings=None,
        donate_argnums=(2,) if donate_cache else (),
    )


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray  # [S] int32
    max_new_tokens: int
    generated: Optional[List[int]] = None
    # stamped by submit() (None = not yet admitted anywhere); drives the
    # latency metrics. A 0.0 stamp from a fake clock is a real stamp.
    submitted_at: Optional[float] = None


class ServeEngine:
    """Slot-based batched generation — an ``EngineReplica``
    (serving/replica.py; single-host driver).

    greedy sampling; per-slot bookkeeping on host, all model math jitted.
    ``params`` may be an FP tree, a fake-quant PTQ tree, or a QuantizedParams
    tree (``ptq_model(..., materialize="int8")``) — the int8 case decodes
    through the int8 kernels via the ``quant_linear``/``grouped_mlp`` seams,
    executing the weights in their stored format.

    Admission runs through a ``MicroBatcher`` in greedy mode (``max_wait_s=0``
    — a queued prompt is admitted the moment a decode slot frees; the batch
    limit per poll is the number of free slots, and each admitted prompt's
    ``queue_wait`` is recorded *before* its prefill starts). ``max_pending >
    0`` bounds the queue: ``submit`` then raises ``scheduler.Backpressure``
    when full. ``metrics`` exposes tokens/s, request latency percentiles,
    queue depth, and (MoE archs) per-expert routed-token occupancy.

    ``mesh=`` pins the replica to a device-mesh slice (the cluster's
    ``replica_meshes`` hand one to every replica; None keeps the process
    host mesh). With ``cfg.moe.moe_exec == "expert_parallel"`` the slice's
    ``'model'`` axis shards the expert stacks and both prefill and the
    decode tick run inside the ambient ``use_ep_mesh`` scope — DP across
    cluster replicas x EP within one. ``clock=`` injects a fake clock for
    deterministic tests (the engine never reads ``time`` directly).
    """

    def __init__(self, cfg: ModelConfig, params, *, batch_slots: int = 4,
                 max_len: int = 512, max_pending: int = 0,
                 mesh: Optional[Mesh] = None,
                 clock: Callable[[], float] = time.monotonic) -> None:
        assert cfg.family not in ("vit", "vit_moe"), "decoder families only"
        self.cfg = serving_config(cfg)
        cfg = self.cfg
        self.params = params
        self.mod = models.module_for(cfg)
        self.B = batch_slots
        self.max_len = max_len
        self.mesh = mesh
        self._clock = clock
        self.cache = self.mod.init_cache(cfg, batch_slots, max_len)
        self.pos = np.zeros(batch_slots, np.int32)  # cache fill per slot
        self.active: Dict[int, Request] = {}  # slot -> request
        self.scheduler = MicroBatcher(batch_sizes=(batch_slots,),
                                      max_wait_s=0.0, max_pending=max_pending,
                                      clock=clock)
        self._with_stats = (cfg.moe is not None
                            and cfg.family in ("dense", "moe", "vlm"))
        self.metrics = EngineMetrics(
            num_experts=cfg.moe.num_experts if self._with_stats else 0,
            clock=clock)
        self._ep = (cfg.moe is not None
                    and cfg.moe.moe_exec == "expert_parallel")
        if self._ep:
            from repro.distributed.expert_parallel import (
                use_ep_mesh,
                validate_ep,
            )

            if mesh is None:
                raise ValueError(
                    "moe_exec='expert_parallel' needs mesh= (a 'model'-axis "
                    "mesh whose size divides num_experts)")
            validate_ep(cfg, mesh)
            self._scope = lambda: use_ep_mesh(mesh)
        else:
            self._scope = contextlib.nullcontext
        rules = EXPERT_PARALLEL_RULES if self._ep else SERVING_RULES
        if mesh is not None:
            # pin the replica to its slice: eager prefill math follows the
            # committed params; the jitted decode in_shardings match below
            specs = fit_specs_to_tree(
                param_specs(cfg, mesh, rules=rules), params)
            named = jax.tree.map(
                lambda s: NamedSharding(mesh, s), specs,
                is_leaf=lambda x: isinstance(x, P))
            self.params = jax.device_put(params, named)
        # the decode tick: donated cache (in-place K/V update, no per-token
        # copy), shardings fitted to the actual — possibly int8 — param tree
        shape = ShapeConfig("engine_decode", "decode",
                            seq_len=max_len, global_batch=batch_slots)
        self._decode = build_serve_step(
            cfg, shape, mesh if mesh is not None else make_host_mesh(),
            params=params, with_stats=self._with_stats, rules=rules,
        )

    # -- replica surface (serving/replica.py) --------------------------------

    @property
    def queue(self) -> List[Request]:
        """Pending (not yet admitted) requests in FIFO order."""
        return self.scheduler.pending_items()

    @property
    def free_slots(self) -> int:
        """Unoccupied decode slots — the LM load signal's numerator."""
        return self.B - len(self.active)

    @property
    def inflight(self) -> int:
        """Requests occupying decode slots (public in-flight surface)."""
        return len(self.active)

    @property
    def load(self) -> int:
        """Queued + in-flight requests (least-loaded routing key)."""
        return self.scheduler.depth + len(self.active)

    @property
    def free_room(self) -> float:
        """Admission headroom: free decode slots plus scheduler queue room
        (inf when the queue is unbounded). Decode slots are the load
        signal — a replica with open slots admits even at queue bound 0."""
        room = self.scheduler.room
        if room == float("inf"):
            return float("inf")
        return self.free_slots + room

    @property
    def idle(self) -> bool:
        return not self.active and self.scheduler.depth == 0

    def reset_metrics(self) -> None:
        """Fresh ``EngineMetrics`` (cluster replica leave — the old one was
        folded into the retired accumulator)."""
        self.metrics = EngineMetrics(
            num_experts=self.metrics.expert_tokens.size, clock=self._clock)

    def _tune_trace(self) -> None:
        """Abstract (eval_shape — no compile, no device work) trace of the
        programs this replica runs, so the autotuner collects the exact
        kernel shape-bucket keys before anything compiles. Runs inside the
        replica's EP scope: under expert parallelism the shard_map body
        traces with the *local* per-shard shapes, which is what the
        per-shard kernels look up at serving time."""
        tokens = jnp.zeros((self.B, 1), jnp.int32)
        index = jnp.asarray(self.pos, jnp.int32)
        # representative prefill: prompt lengths bucket to powers of two,
        # so one pow2-length trace covers the common admission shapes;
        # batch-parallel admission prefills up to `B` same-length prompts
        # at once, so trace the single-prompt AND full-batch shapes
        plen = min(64, max(8, self.max_len // 2))
        with self._scope():
            jax.eval_shape(
                lambda p, t, c, i: self.mod.decode_step(p, self.cfg, t, c, i),
                self.params, tokens, self.cache, index)
            for n in sorted({1, self.B}):
                jax.eval_shape(
                    lambda p, t: self.mod.prefill(p, self.cfg, t,
                                                  max_len=self.max_len),
                    self.params, jnp.zeros((n, plen), jnp.int32))

    def warmup(self) -> None:
        """Tune (once per device kind — later replicas are pure cache
        hits), then compile the decode step outside the measured path. The
        dummy tick writes K/V rows at the (empty) slots' positions;
        prefill overwrites a slot's full cache row at admission, so
        nothing leaks."""
        if self.cfg.autotune.enable:
            from repro.kernels import autotune

            autotune.ensure_tuned(self.cfg.autotune, self._tune_trace)
        tokens = jnp.zeros((self.B, 1), jnp.int32)
        index = jnp.asarray(self.pos, jnp.int32)
        with self._scope():
            out = self._decode(self.params, tokens, self.cache, index)
        self.cache = out[1]
        jax.block_until_ready(jax.tree.leaves(self.cache)[0])

    def submit(self, req: Request) -> None:
        req.generated = []
        if req.submitted_at is None:  # cluster front-end may have stamped it
            req.submitted_at = self._clock()
        if self.scheduler.room == 0 and self.free_slots > 0:
            # queue full but decode slots free: admit queued prompts into
            # slots first, so free_room (slots + queue room) is exactly the
            # number of submits that succeed — the router relies on that
            self._admit()
        try:
            self.scheduler.submit(req)  # raises Backpressure when full
        except Exception:
            self.metrics.inc("rejected")
            raise
        self.metrics.inc("submitted")
        self.metrics.observe_queue_depth(self.scheduler.depth)

    def _admit(self) -> None:
        """Batch-parallel prefill admission: admit up to ``free_slots``
        prompts per tick; same-length prompts prefill as ONE batched
        forward (a [n, S] batch instead of n sequential [1, S] runs — the
        prompt math is where admission time goes), then each row's cache
        slice is merged into its slot. Grouping by exact length keeps the
        batch unpadded, so every row's last position is its true last
        token and the batched logits match the solo runs. Each prompt's
        queue wait is recorded before its prefill starts (prefill time is
        service time, not queue time)."""
        free = [s for s in range(self.B) if s not in self.active]
        while free:
            batch = self.scheduler.poll(limit=len(free))
            if batch is None:
                return
            now = self._clock()
            groups: Dict[int, List[Request]] = {}
            for req in batch.items:
                groups.setdefault(len(req.prompt), []).append(req)
            for _, reqs in sorted(groups.items()):
                slots = [free.pop(0) for _ in reqs]
                for req in reqs:
                    self.metrics.queue_wait.record(
                        max(0.0, now - req.submitted_at))
                toks = jnp.asarray(np.stack([r.prompt for r in reqs]),
                                   jnp.int32)
                with self._scope():
                    logits, part_cache = self.mod.prefill(
                        self.params, self.cfg, toks, max_len=self.max_len,
                    )
                self.metrics.inc("prefill_batches")
                first = np.asarray(jnp.argmax(logits[:, -1, :], axis=-1))
                for i, (slot, req) in enumerate(zip(slots, reqs)):
                    # merge row i of the group's prefilled cache into this
                    # slot's rows of the engine cache
                    def merge(full, part, slot=slot, i=i):
                        row = jax.lax.dynamic_slice_in_dim(part, i, 1, axis=1)
                        return jax.lax.dynamic_update_slice(
                            full, row.astype(full.dtype),
                            (0, slot) + (0,) * (full.ndim - 2),
                        )
                    self.cache = jax.tree.map(merge, self.cache, part_cache)
                    self.pos[slot] = len(req.prompt)
                    req.generated.append(int(first[i]))
                    self.active[slot] = req

    def step(self) -> None:
        """One engine tick: admit queued prompts, decode one token for every
        active slot, retire finished sequences."""
        self._admit()
        if not self.active:
            return
        tokens = np.zeros((self.B, 1), np.int32)
        for slot, req in self.active.items():
            tokens[slot, 0] = req.generated[-1]
        # per-slot cache positions: slots decode at their own fill level
        index = jnp.asarray(self.pos, jnp.int32)
        with self._scope():
            out = self._decode(self.params, jnp.asarray(tokens), self.cache,
                               index)
        if self._with_stats:
            logits, self.cache, stats = out
            self.metrics.add_expert_tokens(np.asarray(stats["expert_tokens"]))
        else:
            logits, self.cache = out
        nxt = np.asarray(jnp.argmax(logits[:, 0, :], axis=-1))
        self.metrics.work_done(len(self.active), "tokens")
        self.metrics.observe_queue_depth(self.scheduler.depth)
        done = []
        now = self._clock()
        for slot, req in self.active.items():
            req.generated.append(int(nxt[slot]))
            self.pos[slot] += 1
            if len(req.generated) >= req.max_new_tokens or \
                    self.pos[slot] >= self.max_len - 1:
                done.append(slot)
        for slot in done:
            req = self.active.pop(slot)
            self.metrics.inc("completed")
            self.metrics.request_latency.record(now - req.submitted_at)

    def flush(self, max_ticks: int = 10_000) -> None:
        """Blocking drain: serve everything queued and in flight."""
        for _ in range(max_ticks):
            if self.idle:
                return
            self.step()

    run_until_drained = flush
