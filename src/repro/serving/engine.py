"""Batched serving engine: prefill + decode with the CoQMoE quantized
inference path (INT8 K/V cache + 4-bit log-sqrt2 attention probabilities
when ``cfg.quant.enable``).

``build_serve_step`` is the unit the multi-pod dry-run lowers for decode
shape cells: one new token per sequence against a seq_len-deep cache.

``ServeEngine`` adds slot-based continuous batching on top: a fixed batch of
decode slots; finished sequences release their slot and queued prompts are
admitted from the shared ``MicroBatcher`` scheduler (DESIGN.md section 6 —
the same scheduler ``VisionEngine`` batches on) and prefilled into it (cache
writes at the slot index). The decode tick runs through ``build_serve_step``
so the K/V cache buffer is *donated* — updated in place, never copied per
token.
"""
from __future__ import annotations

import contextlib
import dataclasses
import queue
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import models
from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed.sharding_rules import (
    EXPERT_PARALLEL_RULES,
    SERVING_RULES,
    cache_specs,
    fit_specs_to_tree,
    input_shardings,
    param_specs,
)
from repro.launch.mesh import make_host_mesh
from repro.serving.events import EventLog
from repro.serving.metrics import EngineMetrics
from repro.serving.scheduler import MicroBatcher
from repro.serving.trace import make_tracer


def serving_config(cfg: ModelConfig) -> ModelConfig:
    """Serving always uses the *dropless* grouped (unified-kernel) MoE path:
    capacity-based GShard dispatch may drop tokens, which is acceptable in
    training but makes generation non-deterministic vs the prompt run."""
    if cfg.moe is not None and cfg.moe.impl != "grouped":
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, impl="grouped"))
    return cfg


def lowering_config(cfg: ModelConfig) -> ModelConfig:
    """Cost-model stand-in for the dry-run: on TPU the grouped path is the
    Pallas megablox kernel (each expert's weights stream HBM->VMEM once);
    XLA's ragged_dot lowering on the host backend is a *dense* all-experts
    contraction, which would overstate decode FLOPs ~1000x. The GShard
    einsum with generous capacity has the kernel's true cost shape —
    weights read once, compute proportional to routed tokens — so decode
    cells lower through it (EXPERIMENTS.md section Perf, qwen3 iteration)."""
    if cfg.moe is not None:
        cfg = cfg.replace(moe=dataclasses.replace(
            cfg.moe, impl="gshard", capacity_factor=4.0))
    return cfg


def build_serve_step(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                     *, donate_cache: bool = True, for_lowering: bool = False,
                     params=None, with_stats: bool = False,
                     rules=SERVING_RULES):
    """Jitted decode step: (params, tokens [B,1], cache, index) ->
    (logits, new_cache). The cache buffer is donated (updated in place).

    ``params``: pass the *actual* (possibly PTQ-transformed) param tree when
    it differs structurally from ``models.abstract_params`` — e.g. a
    QuantizedParams tree from ``ptq_model(..., materialize="int8")`` with
    int8 weight leaves plus ``_scale``/``_as`` siblings. The in_shardings
    are fitted to that tree (int8 weights inherit their fp ancestors' specs;
    scale leaves replicate) so the decode step executes the stored int8
    format directly through the int8 kernels.

    ``with_stats=True`` (transformer MoE families) appends the per-step
    routed-token histogram to the outputs: (logits, new_cache,
    {"expert_tokens": [E] int32}).

    ``rules``: sharding rules for the param specs. With
    ``EXPERT_PARALLEL_RULES`` only the expert stacks shard over 'model' and
    every activation/cache buffer replicates — the EP exchange happens
    inside ``shard_map`` on tokens, so a context-parallel cache layout
    would only fight the all_to_all (and the eager prefill merge).

    Kernel tile configs are resolved at trace time from the ambient
    autotune table (kernels/autotune.py): compile this step *after*
    ``autotune.ensure_tuned`` (engine ``warmup()`` orders the two) and the
    decode program bakes the device-tuned tiles."""
    cfg = lowering_config(cfg) if for_lowering else serving_config(cfg)
    mod = models.module_for(cfg)
    # value (not identity) comparison: an equal copy of the EP rules must
    # get the same replicated-activation layout
    replicate_activations = dict(rules) == dict(EXPERT_PARALLEL_RULES)

    def serve_step(params, tokens, cache, index):
        if with_stats:
            return mod.decode_step(params, cfg, tokens, cache, index,
                                   with_stats=True)
        return mod.decode_step(params, cfg, tokens, cache, index)

    p_specs = param_specs(cfg, mesh, rules=rules)
    if params is not None:
        p_specs = fit_specs_to_tree(p_specs, params)
    in_tree = models.input_specs(cfg, shape)
    b_specs = input_shardings(cfg, shape, mesh, in_tree)
    if replicate_activations:
        b_specs = jax.tree.map(lambda _: P(), b_specs,
                               is_leaf=lambda x: isinstance(x, P))
    named = lambda tree: jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda x: isinstance(x, P),
    )
    return jax.jit(
        serve_step,
        in_shardings=(
            named(p_specs),
            named(b_specs["tokens"]),
            named(b_specs["cache"]),
            named(b_specs["index"]),
        ),
        out_shardings=None,
        donate_argnums=(2,) if donate_cache else (),
    )


def _pow2_ladder(lo: int, hi: int) -> Tuple[int, ...]:
    """Doubling ladder from lo up to (and always including) hi."""
    lo, hi = max(1, int(lo)), max(1, int(hi))
    out, b = [], lo
    while b < hi:
        out.append(b)
        b *= 2
    out.append(hi)
    return tuple(sorted(set(out)))


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray  # [S] int32
    max_new_tokens: int
    generated: Optional[List[int]] = None
    # stamped by submit() (None = not yet admitted anywhere); drives the
    # latency metrics. A 0.0 stamp from a fake clock is a real stamp.
    submitted_at: Optional[float] = None
    # QoS deadline in seconds after submit (None = unbounded). An expired
    # request is dropped from the queue before prefill, or cancelled
    # mid-generation (its decode slot frees on the next tick); both count
    # in the engine's ``cancelled`` metric.
    deadline: Optional[float] = None
    # invoked by the retirement path once the request finishes (or is
    # cancelled) — detokenize/response callbacks run here, OFF the decode
    # tick when async retirement is on.
    on_done: Optional[Callable[["Request"], None]] = None
    # set by the retirement path when eos_id is produced; the decode loop
    # observes it and frees the slot on its next tick
    eos_seen: bool = dataclasses.field(default=False, repr=False)
    # span-timeline identity (serving/trace.py). The cluster front-end
    # assigns a globally unique id at submit; a standalone engine falls
    # back to ``uid``. None with tracing off — requests pay nothing.
    trace_id: Optional[int] = None
    # lifecycle: "pending" until the first terminal retirement flips it to
    # "completed"/"cancelled" ("failed" is cluster-assigned when the retry
    # budget runs out). Terminal is sticky — the at-most-once contract
    # (DESIGN.md section 14) keys duplicate-retirement suppression on it.
    status: str = dataclasses.field(default="pending", repr=False)
    # times the cluster re-dispatched this request after a quarantine
    redispatched: int = dataclasses.field(default=0, repr=False)
    # set by ``evict()`` while the request is stranded on a quarantined
    # replica: retirement events still in flight for it are ignored (the
    # cluster owns it until re-dispatch clears the flag)
    evicted: bool = dataclasses.field(default=False, repr=False)


class ServeEngine:
    """Slot-based batched generation — an ``EngineReplica``
    (serving/replica.py; single-host driver).

    greedy sampling; per-slot bookkeeping on host, all model math jitted.
    ``params`` may be an FP tree, a fake-quant PTQ tree, or a QuantizedParams
    tree (``ptq_model(..., materialize="int8")``) — the int8 case decodes
    through the int8 kernels via the ``quant_linear``/``grouped_mlp`` seams,
    executing the weights in their stored format.

    Admission runs through a ``MicroBatcher`` in greedy mode (``max_wait_s=0``
    — a queued prompt is admitted the moment a decode slot frees; the batch
    limit per poll is the number of free slots, and each admitted prompt's
    ``queue_wait`` is recorded *before* its prefill starts). ``max_pending >
    0`` bounds the queue: ``submit`` then raises ``scheduler.Backpressure``
    when full. ``metrics`` exposes tokens/s, request latency percentiles,
    queue depth, and (MoE archs) per-expert routed-token occupancy.

    ``mesh=`` pins the replica to a device-mesh slice (the cluster's
    ``replica_meshes`` hand one to every replica; None keeps the process
    host mesh). With ``cfg.moe.moe_exec == "expert_parallel"`` the slice's
    ``'model'`` axis shards the expert stacks and both prefill and the
    decode tick run inside the ambient ``use_ep_mesh`` scope — DP across
    cluster replicas x EP within one. ``clock=`` injects a fake clock for
    deterministic tests (the engine never reads ``time`` directly).
    """

    def __init__(self, cfg: ModelConfig, params, *, batch_slots: int = 4,
                 max_len: int = 512, max_pending: int = 0,
                 mesh: Optional[Mesh] = None, eos_id: Optional[int] = None,
                 events: Optional[EventLog] = None,
                 clock: Callable[[], float] = time.monotonic) -> None:
        assert cfg.family not in ("vit", "vit_moe"), "decoder families only"
        self.cfg = serving_config(cfg)
        cfg = self.cfg
        self.params = params
        # observability (DESIGN.md section 11): NULL_TRACER when
        # cfg.trace.enable is off — every site below guards on
        # ``self.tracer.enabled`` so the disabled path is one attr read
        self.tracer = make_tracer(cfg.trace, clock=clock)
        self.events = events
        # per-program step timing feeds two consumers: trace span records
        # (when tracing is on) and the MFU/roofline join (introspection,
        # on by default — its cost is one clock read + histogram insert
        # per dispatch, bounded by benchmarks/serve_introspect.py)
        self._step_times = ((self.tracer.enabled and cfg.trace.step_times)
                            or cfg.introspect.enable)
        self.mod = models.module_for(cfg)
        self.B = batch_slots
        self.max_len = max_len
        self.mesh = mesh
        self._clock = clock
        self.cache = self.mod.init_cache(cfg, batch_slots, max_len)
        self.pos = np.zeros(batch_slots, np.int32)  # cache fill per slot
        self.active: Dict[int, Request] = {}  # slot -> request
        self.scheduler = MicroBatcher(batch_sizes=(batch_slots,),
                                      max_wait_s=0.0, max_pending=max_pending,
                                      clock=clock)
        self._with_stats = (cfg.moe is not None
                            and cfg.family in ("dense", "moe", "vlm"))
        self.metrics = EngineMetrics(
            num_experts=cfg.moe.num_experts if self._with_stats else 0,
            clock=clock)
        self.expert_health = None
        if cfg.introspect.enable and self._with_stats:
            from repro.serving.introspect import ExpertHealthMonitor

            # fed by add_expert_tokens outside the metrics lock; the drift
            # hook resolves self.metrics at fire time so the counter lands
            # in whichever EngineMetrics is current after a reset
            self.expert_health = ExpertHealthMonitor(
                cfg.moe.num_experts,
                window_tokens=cfg.introspect.drift_window_tokens,
                drift_threshold=cfg.introspect.drift_threshold,
                baseline_alpha=cfg.introspect.baseline_alpha,
                events=events, label="lm", clock=clock,
                on_drift=lambda info: self.metrics.inc("expert_drift"))
            self.metrics.expert_health = self.expert_health
        self._ep = (cfg.moe is not None
                    and cfg.moe.moe_exec == "expert_parallel")
        if self._ep:
            from repro.distributed.expert_parallel import (
                use_ep_mesh,
                validate_ep,
            )

            if mesh is None:
                raise ValueError(
                    "moe_exec='expert_parallel' needs mesh= (a 'model'-axis "
                    "mesh whose size divides num_experts)")
            validate_ep(cfg, mesh)
            self._scope = lambda: use_ep_mesh(mesh)
        else:
            self._scope = contextlib.nullcontext
        rules = EXPERT_PARALLEL_RULES if self._ep else SERVING_RULES
        if mesh is not None:
            # pin the replica to its slice: eager prefill math follows the
            # committed params; the jitted decode in_shardings match below
            specs = fit_specs_to_tree(
                param_specs(cfg, mesh, rules=rules), params)
            named = jax.tree.map(
                lambda s: NamedSharding(mesh, s), specs,
                is_leaf=lambda x: isinstance(x, P))
            self.params = jax.device_put(params, named)
        # the decode tick: donated cache (in-place K/V update, no per-token
        # copy), shardings fitted to the actual — possibly int8 — param tree
        shape = ShapeConfig("engine_decode", "decode",
                            seq_len=max_len, global_batch=batch_slots)
        self._mesh_eff = mesh if mesh is not None else make_host_mesh()
        self._decode = build_serve_step(
            cfg, shape, self._mesh_eff,
            params=params, with_stats=self._with_stats, rules=rules,
        )

        # ---- continuous batching (DESIGN.md section 10) -------------------
        self.serve = cfg.serve
        self._eos_id = eos_id
        # packed prefill needs the transformer-family prefill_packed entry
        # and a non-ring cache layout; other archs (ssm/hybrid/alternating
        # local-global) keep the grouped same-length admission path.
        self._packed = bool(
            self.serve.packed_prefill
            and cfg.attn is not None
            and not cfg.attn.alternate_local_global
            and cfg.family in ("dense", "moe", "vlm")
            and hasattr(self.mod, "prefill_packed")
        )
        self.max_prefill = int(self.serve.max_prefill or max_len)
        if self.max_prefill > max_len:
            raise ValueError(
                f"serve.max_prefill={self.max_prefill} exceeds the K/V "
                f"cache length (max_len={max_len}): pack buckets beyond "
                "the cache would silently truncate merged rows")
        # longest admissible prompt: it must fit one pack dispatch AND
        # leave a free cache row for its first decode tick — a prompt
        # filling the whole cache would decode at index max_len, clamping
        # onto (and corrupting) its last prompt row before the post-tick
        # bound check retires it
        self._prompt_limit = (min(self.max_prefill, max_len - 1)
                              if self._packed else max_len - 1)
        self._buckets = _pow2_ladder(
            min(self.serve.min_bucket, self.max_prefill), self.max_prefill)
        self._nb_ladder = _pow2_ladder(1, batch_slots)
        # AOT program cache: key -> compiled executable (see _program_key);
        # warmup() pre-populates it so steady-state serving never traces
        # (EngineMetrics "retraces" counts on-path compiles).
        self._programs: Dict[str, Any] = {}
        self._emitted = np.zeros(batch_slots, np.int64)  # tokens per slot
        # async retirement: decode ticks push device token arrays here; the
        # retirement thread materializes them (the only device->host sync),
        # appends to Request.generated, and fires callbacks/metrics.
        self._async = bool(self.serve.async_retire) and self._packed
        self._rq: "queue.Queue" = queue.Queue()
        self._rthread: Optional[threading.Thread] = None
        self._mlock = threading.Lock()
        if self._packed:
            named = lambda tree: jax.tree.map(
                lambda s: NamedSharding(self._mesh_eff, s), tree,
                is_leaf=lambda x: isinstance(x, P))
            self._repl_sh = NamedSharding(self._mesh_eff, P())
            p_specs = fit_specs_to_tree(
                param_specs(cfg, self._mesh_eff, rules=rules), self.params)
            self._param_sh = named(p_specs)
            in_tree = models.input_specs(cfg, shape)
            c_specs = input_shardings(cfg, shape, self._mesh_eff,
                                      in_tree)["cache"]
            if self._ep:
                c_specs = jax.tree.map(lambda _: P(), c_specs,
                                       is_leaf=lambda x: isinstance(x, P))
            self._cache_sh = named(c_specs)
            self.cache = jax.device_put(self.cache, self._cache_sh)
            # next-token feed: device-resident, written by the tick program
            # itself (never synced on the decode path)
            self._tok = jax.device_put(
                jnp.zeros((batch_slots,), jnp.int32), self._repl_sh)

    # -- replica surface (serving/replica.py) --------------------------------

    @property
    def queue(self) -> List[Request]:
        """Pending (not yet admitted) requests in FIFO order."""
        return self.scheduler.pending_items()

    @property
    def free_slots(self) -> int:
        """Unoccupied decode slots — the LM load signal's numerator."""
        return self.B - len(self.active)

    @property
    def inflight(self) -> int:
        """Requests occupying decode slots (public in-flight surface)."""
        return len(self.active)

    @property
    def load(self) -> int:
        """Queued + in-flight requests (least-loaded routing key)."""
        return self.scheduler.depth + len(self.active)

    @property
    def free_room(self) -> float:
        """Admission headroom: free decode slots plus scheduler queue room
        (inf when the queue is unbounded). Decode slots are the load
        signal — a replica with open slots admits even at queue bound 0."""
        room = self.scheduler.room
        if room == float("inf"):
            return float("inf")
        return self.free_slots + room

    @property
    def idle(self) -> bool:
        """Nothing queued, in flight, or pending async retirement."""
        return (not self.active and self.scheduler.depth == 0
                and self._pending_retire() == 0)

    def reset_metrics(self) -> None:
        """Fresh ``EngineMetrics`` (cluster replica leave — the old one was
        folded into the retired accumulator). The static introspection
        surface (ProgramCost rows, peaks, memory probe, health monitor)
        carries over: it describes the compiled programs, not load."""
        old = self.metrics
        self.metrics = EngineMetrics(
            num_experts=old.expert_tokens.size, clock=self._clock)
        self.metrics.adopt_static(old)

    # -- AOT program cache (DESIGN.md section 10) ----------------------------

    def _program_key(self, prog: str, **kv) -> str:
        """Compile-cache key, same ``name|k=v|...`` schema as the autotuner's
        TuningTable entries (kernels/autotune.py) so a dumped serving state
        reads as one namespace: ``serve/<prog>|B=..|S=..|...``."""
        parts = [f"serve/{prog}", f"B={self.B}", f"S={self.max_len}"]
        parts += [f"{k}={v}" for k, v in sorted(kv.items())]
        return "|".join(parts)

    def _compiled(self, key: str, build: Callable[[], Any],
                  count_miss: bool = True):
        """Fetch (or compile) the executable for ``key``. A miss on the
        serving path increments ``retraces`` — after ``warmup()`` that
        counter must stay at 0 (the continuous-batching acceptance bar)."""
        exe = self._programs.get(key)
        if exe is None:
            if count_miss:
                self.metrics.inc("retraces")
            with self._scope():
                exe = build()
            self._programs[key] = exe
        return exe

    def _build_tick(self):
        """AOT-compile the fused decode tick: embed last tokens, decode one
        position per slot against the donated cache, argmax ON DEVICE so
        the tick returns the next-token feed without a host sync."""
        cfg, mod, with_stats = self.cfg, self.mod, self._with_stats

        def tick(params, tok, cache, index):
            out = mod.decode_step(params, cfg, tok[:, None], cache, index,
                                  with_stats=True) if with_stats else \
                mod.decode_step(params, cfg, tok[:, None], cache, index)
            if with_stats:
                logits, new_cache, stats = out
            else:
                logits, new_cache = out
            nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
            if with_stats:
                return nxt, new_cache, stats["expert_tokens"]
            return nxt, new_cache

        r = self._repl_sh
        jitted = jax.jit(
            tick,
            in_shardings=(self._param_sh, r, self._cache_sh, r),
            out_shardings=((r, self._cache_sh, r) if with_stats
                           else (r, self._cache_sh)),
            donate_argnums=(2,),
        )
        sds = jax.ShapeDtypeStruct
        cache_sds = jax.tree.map(lambda x: sds(x.shape, x.dtype), self.cache)
        return jitted.lower(
            self.params, sds((self.B,), jnp.int32), cache_sds,
            sds((self.B,), jnp.int32),
        ).compile()

    def _build_admit(self, bucket: int, nb: int):
        """AOT-compile one packed-admission program: a single segment-masked
        forward over ``[1, bucket]`` packed tokens, per-prompt first-token
        argmax, and the scatter-merge of every segment's K/V rows into its
        donated decode slot (the ``insert_partial`` analogue).

        Dummy pack entries (prompt-count padded up the pow2 ladder) carry
        ``len == 0``: their merge mask is all-false and their slot write in
        the next-token feed drops, so they are exact no-ops."""
        cfg, mod, B = self.cfg, self.mod, self.B
        chunk = min(self.max_len, bucket)  # per-prompt merge window

        def admit(params, tokens, positions, seg, last_idx, starts, lens,
                  slots, cache, tok):
            logits, part = mod.prefill_packed(
                params, cfg, tokens, positions, seg, last_idx,
                max_len=bucket)
            first = jnp.argmax(logits, axis=-1).astype(jnp.int32)  # [nb]

            def merge(full, p):
                # full [L, B, Smax, ...]; p [L, 1, bucket, ...]
                pr = p[:, 0]
                out = full
                for i in range(nb):
                    # gather this segment's rows; writes are sequential so
                    # duplicate dummy slots stay exact no-ops
                    idx = jnp.clip(starts[i] + jnp.arange(chunk),
                                   0, bucket - 1)
                    rows = jnp.take(pr, idx, axis=1)[:, None]  # [L,1,chunk,..]
                    at = (0, slots[i]) + (0,) * (out.ndim - 2)
                    cur = jax.lax.dynamic_slice(
                        out, at,
                        (out.shape[0], 1, chunk) + out.shape[3:])
                    keep = (jnp.arange(chunk) < lens[i]).reshape(
                        (1, 1, chunk) + (1,) * (out.ndim - 3))
                    out = jax.lax.dynamic_update_slice(
                        out, jnp.where(keep, rows.astype(out.dtype), cur), at)
                return out

            new_cache = jax.tree.map(merge, cache, part)
            # dummy entries route to index B -> dropped by mode="drop"
            new_tok = tok.at[jnp.where(lens > 0, slots, B)].set(
                first, mode="drop")
            return first, new_cache, new_tok

        r = self._repl_sh
        jitted = jax.jit(
            admit,
            in_shardings=(self._param_sh, r, r, r, r, r, r, r,
                          self._cache_sh, r),
            out_shardings=(r, self._cache_sh, r),
            donate_argnums=(8,),
        )
        sds = jax.ShapeDtypeStruct
        i32 = jnp.int32
        cache_sds = jax.tree.map(lambda x: sds(x.shape, x.dtype), self.cache)
        return jitted.lower(
            self.params, sds((1, bucket), i32), sds((bucket,), i32),
            sds((bucket,), i32), sds((nb,), i32), sds((nb,), i32),
            sds((nb,), i32), sds((nb,), i32), cache_sds, sds((B,), i32),
        ).compile()

    # -- async retirement ----------------------------------------------------

    def _ensure_thread(self) -> None:
        if self._rthread is None or not self._rthread.is_alive():
            self._rthread = threading.Thread(
                target=self._retire_loop, daemon=True,
                name=f"retire-{id(self):x}")
            self._rthread.start()

    def _retire_loop(self) -> None:
        while True:
            ev = self._rq.get()
            try:
                self._consume(ev)
            except Exception as e:
                # a poisoned event must not kill the retirement thread —
                # its death would strand every later event's tokens and
                # completion metrics; this event's own payload is lost,
                # which the counter makes visible
                self.metrics.inc("retire_errors")
                if self.events is not None:
                    self.events.emit("retire_error", error=repr(e))
            finally:
                self._rq.task_done()

    def _emit(self, ev: dict) -> None:
        """Hand a retirement event to the consumer: the retirement thread
        when async, inline otherwise (same code path, same ordering)."""
        if self._async:
            self._ensure_thread()
            self._rq.put(ev)
        else:
            self._consume(ev)

    def _consume(self, ev: dict) -> None:
        """Retire one event: materialize the tick's token array (the only
        device->host sync — off the decode tick when async), append to each
        request's stream, check EOS, record completion metrics, and fire
        ``on_done`` callbacks. ``ev["now"]`` is stamped by the decode loop,
        so latency stays deterministic under fake clocks."""
        tok = np.asarray(ev["tok"]) if ev.get("tok") is not None else None
        with self._mlock:
            for req, i in ev.get("append", ()):
                if req.eos_seen or req.evicted:
                    continue  # stream ended early (or the request was
                    # evicted mid-flight and will restart elsewhere)
                t = int(tok[i])
                req.generated.append(t)
                if self._eos_id is not None and t == self._eos_id:
                    req.eos_seen = True
            if ev.get("stats") is not None:
                self.metrics.add_expert_tokens(np.asarray(ev["stats"]))
            for req, latency, cancelled in ev.get("retired", ()):
                if getattr(req, "evicted", False):
                    continue  # the cluster owns it until re-dispatch
                if getattr(req, "status", "pending") != "pending":
                    # already terminal: a duplicate retirement (e.g. the
                    # same trace_id replayed across an eviction) must be
                    # exactly-once — count it, deliver nothing
                    self.metrics.inc("duplicate_retirements")
                    continue
                req.status = "cancelled" if cancelled else "completed"
                if cancelled:
                    self.metrics.inc("cancelled")
                else:
                    self.metrics.inc("completed")
                    self.metrics.request_latency.record(latency)
                if req.on_done is not None:
                    try:
                        req.on_done(req)
                    except Exception as e:
                        self.metrics.inc("callback_errors")
                        if self.events is not None:
                            self.events.emit("callback_error",
                                             uid=getattr(req, "uid", None),
                                             error=repr(e))
                if self.tracer.enabled:
                    # close the retire span the decode loop opened; it
                    # extends past the recorded latency by design (token
                    # materialization + callbacks are off the latency path)
                    self.tracer.end(getattr(req, "trace_id", None), "retire",
                                    latency_s=latency, cancelled=cancelled)

    def _pending_retire(self) -> int:
        return self._rq.unfinished_tasks if self._async else 0

    def _cancel_expired(self) -> None:
        """Free decode slots whose request exceeded its deadline (QoS
        cancellation) or whose stream already hit EOS (observed from the
        retirement thread's flag, one tick behind the token)."""
        if not self.active:
            return
        now = self._clock()
        for slot in list(self.active):
            req = self.active[slot]
            expired = (req.deadline is not None
                       and now - req.submitted_at > req.deadline)
            if expired or req.eos_seen:
                self.active.pop(slot)
                cancelled = bool(expired and not req.eos_seen)
                if self.tracer.enabled:
                    self.tracer.transition(req.trace_id, "decode", "retire",
                                           t=now)
                if self.events is not None and cancelled:
                    self.events.emit("cancel", t=now, uid=req.uid,
                                     where="mid_generation",
                                     waited_s=now - req.submitted_at,
                                     deadline_s=req.deadline)
                self._emit({"now": now, "retired": [
                    (req, now - req.submitted_at, cancelled)]})

    def _tune_trace(self) -> None:
        """Abstract (eval_shape — no compile, no device work) trace of the
        programs this replica runs, so the autotuner collects the exact
        kernel shape-bucket keys before anything compiles. Runs inside the
        replica's EP scope: under expert parallelism the shard_map body
        traces with the *local* per-shard shapes, which is what the
        per-shard kernels look up at serving time."""
        tokens = jnp.zeros((self.B, 1), jnp.int32)
        index = jnp.asarray(self.pos, jnp.int32)
        # representative prefill: prompt lengths bucket to powers of two,
        # so one pow2-length trace covers the common admission shapes;
        # batch-parallel admission prefills up to `B` same-length prompts
        # at once, so trace the single-prompt AND full-batch shapes
        plen = min(64, max(8, self.max_len // 2))
        with self._scope():
            jax.eval_shape(
                lambda p, t, c, i: self.mod.decode_step(p, self.cfg, t, c, i),
                self.params, tokens, self.cache, index)
            for n in sorted({1, self.B}):
                jax.eval_shape(
                    lambda p, t: self.mod.prefill(p, self.cfg, t,
                                                  max_len=self.max_len),
                    self.params, jnp.zeros((n, plen), jnp.int32))
            if self._packed:
                # packed buffers hit attention at [1, bucket] — collect
                # every bucket's kernel shape keys before anything compiles
                for bucket in self._buckets:
                    jax.eval_shape(
                        lambda p, t, pos, seg, li, b=bucket:
                        self.mod.prefill_packed(p, self.cfg, t, pos, seg,
                                                li, max_len=b),
                        self.params, jnp.zeros((1, bucket), jnp.int32),
                        jnp.zeros((bucket,), jnp.int32),
                        jnp.zeros((bucket,), jnp.int32),
                        jnp.zeros((self._nb_ladder[-1],), jnp.int32))

    def warmup(self) -> None:
        """Tune (once per device kind — later replicas are pure cache
        hits), then compile every serving program outside the measured
        path. In packed mode this AOT-lowers and compiles the decode tick
        plus every (prefill bucket x prompt-count) admission program, so
        steady-state serving never traces (``retraces`` stays 0). The
        dummy tick writes K/V rows at the (empty) slots' positions;
        prefill overwrites a slot's full cache row at admission, so
        nothing leaks."""
        if self.cfg.autotune.enable:
            from repro.kernels import autotune

            autotune.ensure_tuned(self.cfg.autotune, self._tune_trace)
        if self._packed:
            exe = self._compiled(self._program_key("decode"),
                                 self._build_tick, count_miss=False)
            if self.serve.aot_warmup:
                for bucket in self._buckets:
                    for nb in self._nb_ladder:
                        self._compiled(
                            self._program_key("packed_prefill",
                                              bucket=bucket, n=nb),
                            lambda b=bucket, n=nb: self._build_admit(b, n),
                            count_miss=False)
            index = jax.device_put(
                jnp.asarray(self.pos, jnp.int32), self._repl_sh)
            out = exe(self.params, self._tok, self.cache, index)
            self._tok, self.cache = out[0], out[1]
            jax.block_until_ready(jax.tree.leaves(self.cache)[0])
            self._install_introspection(dict(self._programs))
            return
        tokens = jnp.zeros((self.B, 1), jnp.int32)
        index = jnp.asarray(self.pos, jnp.int32)
        with self._scope():
            out = self._decode(self.params, tokens, self.cache, index)
        self.cache = out[1]
        jax.block_until_ready(jax.tree.leaves(self.cache)[0])
        # the grouped path runs its decode through plain jit (no AOT
        # grid) — lower the decode program once, purely to read its cost
        # surfaces, so this engine's decode key still gets a ProgramCost row
        programs: Dict[str, Any] = {}
        if self.cfg.introspect.enable:
            try:
                sds = jax.ShapeDtypeStruct
                cache_sds = jax.tree.map(
                    lambda x: sds(x.shape, x.dtype), self.cache)
                with self._scope():
                    programs[self._program_key("decode")] = self._decode.lower(
                        self.params, sds((self.B, 1), jnp.int32), cache_sds,
                        sds((self.B,), jnp.int32)).compile()
            except Exception:
                programs[self._program_key("decode")] = None
        self._install_introspection(programs)

    def _install_introspection(self, programs: Dict[str, Any]) -> None:
        """ProgramCost capture + peaks + memory probe (DESIGN.md §12).
        Best-effort by contract: a backend with no cost surfaces degrades
        to analytic estimates and never fails the warmup."""
        if not self.cfg.introspect.enable:
            return
        from repro.serving import introspect

        introspect.install(
            self.metrics, cfg=self.cfg, programs=programs,
            params=self.params, cache=self.cache,
            devices=list(self._mesh_eff.devices.flat))

    def submit(self, req: Request) -> None:
        if len(req.prompt) > self._prompt_limit:
            # reject unservable prompts HERE: an oversized request that
            # reached the queue head would raise from poll_pack on every
            # tick without ever being dequeued, wedging the replica
            self.metrics.inc("rejected")
            if self.events is not None:
                self.events.emit("reject", uid=req.uid, reason="unservable",
                                 prompt_len=len(req.prompt),
                                 limit=self._prompt_limit)
            raise ValueError(
                f"prompt of {len(req.prompt)} tokens exceeds this engine's "
                f"limit of {self._prompt_limit} (max_prefill="
                f"{self.max_prefill}, max_len={self.max_len})")
        req.generated = []
        if req.submitted_at is None:  # cluster front-end may have stamped it
            req.submitted_at = self._clock()
        if self.scheduler.room == 0 and self.free_slots > 0:
            # queue full but decode slots free: admit queued prompts into
            # slots first, so free_room (slots + queue room) is exactly the
            # number of submits that succeed — the router relies on that
            self._admit()
        try:
            self.scheduler.submit(req)  # raises Backpressure when full
        except Exception:
            self.metrics.inc("rejected")
            if self.events is not None:
                self.events.emit("reject", uid=req.uid,
                                 reason="backpressure",
                                 depth=self.scheduler.depth)
            raise
        self.metrics.inc("submitted")
        if self.tracer.enabled:
            if req.trace_id is None:  # cluster assigns; standalone uses uid
                req.trace_id = req.uid
            self.tracer.begin(req.trace_id, "queue", t=req.submitted_at)
        self.metrics.observe_queue_depth(self.scheduler.depth)

    def _drop_expired(self, items, now: float) -> List[Request]:
        """Split polled requests into live ones; expired ones are retired
        as cancelled without ever touching the device."""
        live = []
        for req in items:
            if req.deadline is not None and \
                    now - req.submitted_at > req.deadline:
                if self.tracer.enabled:
                    # never dispatched: the timeline is queue -> retire
                    self.tracer.transition(req.trace_id, "queue", "retire",
                                           t=now)
                if self.events is not None:
                    self.events.emit("cancel", t=now, uid=req.uid,
                                     where="queued",
                                     waited_s=now - req.submitted_at,
                                     deadline_s=req.deadline)
                self._emit({"now": now,
                            "retired": [(req, now - req.submitted_at, True)]})
            else:
                live.append(req)
        return live

    def _admit(self) -> None:
        if self._packed:
            self._admit_packed()
        else:
            self._admit_grouped()

    def _admit_packed(self) -> None:
        """Continuous-batching admission: the pack planner hands back the
        maximal FIFO prefix of the queue that fits the token budget; the
        prompts are concatenated into ONE ``[1, bucket]`` buffer (segment
        ids + within-segment positions) and a single AOT-compiled program
        runs the segment-masked forward, scatters each segment's K/V rows
        into its decode slot, and writes first tokens into the device-side
        next-token feed. Mixed lengths share one dispatch — the grouped
        path needed one dispatch per distinct length."""
        while True:
            free = [s for s in range(self.B) if s not in self.active]
            if not free:
                return
            plan = self.scheduler.poll_pack(
                self.max_prefill, lambda r: len(r.prompt), limit=len(free))
            if plan is None:
                return
            # the planner-selection timestamp is the queue->pack boundary
            # every request in this plan shares (serving/trace.py)
            now = plan.formed_at
            reqs = self._drop_expired(plan.items, now)
            if not reqs:
                continue
            total = sum(len(r.prompt) for r in reqs)
            bucket = next(b for b in self._buckets if b >= total)
            nb = next(n for n in self._nb_ladder if n >= len(reqs))
            tokens = np.zeros((1, bucket), np.int32)
            positions = np.zeros(bucket, np.int32)
            seg = np.full(bucket, -1, np.int32)
            starts = np.zeros(nb, np.int32)
            lens = np.zeros(nb, np.int32)
            slots = np.zeros(nb, np.int32)
            last_idx = np.zeros(nb, np.int32)
            cursor = 0
            taken = []
            for i, req in enumerate(reqs):
                n = len(req.prompt)
                slot = free.pop(0)
                tokens[0, cursor:cursor + n] = req.prompt
                positions[cursor:cursor + n] = np.arange(n)
                seg[cursor:cursor + n] = i
                starts[i], lens[i], slots[i] = cursor, n, slot
                last_idx[i] = cursor + n - 1
                cursor += n
                taken.append((slot, req))
                self.metrics.queue_wait.record(
                    max(0.0, now - req.submitted_at))
                if self.tracer.enabled:
                    # planner selected the request at `now`: queue ends and
                    # the host-side pack/buffer-build phase begins
                    self.tracer.transition(req.trace_id, "queue", "pack",
                                           t=now, waited_s=now
                                           - req.submitted_at)
            self.metrics.inc("prefill_batches")
            self.metrics.inc("pack_real_tokens", total)
            self.metrics.inc("pack_pad_tokens", bucket - total)
            key = self._program_key("packed_prefill", bucket=bucket, n=nb)
            exe = self._compiled(
                key, lambda b=bucket, n=nb: self._build_admit(b, n))
            trace = self.tracer.enabled
            if trace or self._step_times:
                t_d = self._clock()  # pack ends, prefill dispatch begins
                if trace:
                    for _, req in taken:
                        self.tracer.transition(req.trace_id, "pack",
                                               "prefill", t=t_d,
                                               bucket=bucket, n=len(taken))
            put = lambda a: jax.device_put(jnp.asarray(a), self._repl_sh)
            first, self.cache, self._tok = exe(
                self.params, put(tokens), put(positions), put(seg),
                put(last_idx), put(starts), put(lens), put(slots),
                self.cache, self._tok)
            if trace or self._step_times:
                t_e = self._clock()
                if self._step_times:
                    self.metrics.record_step(key, t_e - t_d)
                if trace:
                    self.tracer.record_span(key, t_d, t_e, n=len(taken),
                                            real_tokens=total)
                    for _, req in taken:
                        self.tracer.transition(req.trace_id, "prefill",
                                               "decode", t=t_e)
            append = []
            for i, (slot, req) in enumerate(taken):
                self.pos[slot] = lens[i]
                self._emitted[slot] = 1
                self.active[slot] = req
                append.append((req, i))
            self._emit({"tok": first, "now": now, "append": append})

    def _admit_grouped(self) -> None:
        """Batch-parallel prefill admission: admit up to ``free_slots``
        prompts per tick; same-length prompts prefill as ONE batched
        forward (a [n, S] batch instead of n sequential [1, S] runs — the
        prompt math is where admission time goes), then each row's cache
        slice is merged into its slot. Grouping by exact length keeps the
        batch unpadded, so every row's last position is its true last
        token and the batched logits match the solo runs. Each prompt's
        queue wait is recorded before its prefill starts (prefill time is
        service time, not queue time)."""
        free = [s for s in range(self.B) if s not in self.active]
        while free:
            batch = self.scheduler.poll(limit=len(free))
            if batch is None:
                return
            now = batch.formed_at  # the shared queue-phase end boundary
            groups: Dict[int, List[Request]] = {}
            for req in self._drop_expired(batch.items, now):
                groups.setdefault(len(req.prompt), []).append(req)
            for L, reqs in sorted(groups.items()):
                slots = [free.pop(0) for _ in reqs]
                for req in reqs:
                    self.metrics.queue_wait.record(
                        max(0.0, now - req.submitted_at))
                    if self.tracer.enabled:
                        # no pack phase on this path: queue -> prefill (the
                        # group's batched forward, incl. host grouping time)
                        self.tracer.transition(req.trace_id, "queue",
                                               "prefill", t=now)
                toks = jnp.asarray(np.stack([r.prompt for r in reqs]),
                                   jnp.int32)
                trace = self.tracer.enabled
                if trace or self._step_times:
                    t_d = self._clock()
                with self._scope():
                    logits, part_cache = self.mod.prefill(
                        self.params, self.cfg, toks, max_len=self.max_len,
                    )
                self.metrics.inc("prefill_batches")
                first = np.asarray(jnp.argmax(logits[:, -1, :], axis=-1))
                if trace or self._step_times:
                    t_e = self._clock()
                    key = self._program_key("grouped_prefill", L=L,
                                            n=len(reqs))
                    if self._step_times:
                        self.metrics.record_step(key, t_e - t_d)
                    if trace:
                        self.tracer.record_span(key, t_d, t_e, n=len(reqs))
                        for req in reqs:
                            self.tracer.transition(req.trace_id, "prefill",
                                                   "decode", t=t_e)
                for i, (slot, req) in enumerate(zip(slots, reqs)):
                    # merge row i of the group's prefilled cache into this
                    # slot's rows of the engine cache
                    def merge(full, part, slot=slot, i=i):
                        row = jax.lax.dynamic_slice_in_dim(part, i, 1, axis=1)
                        return jax.lax.dynamic_update_slice(
                            full, row.astype(full.dtype),
                            (0, slot) + (0,) * (full.ndim - 2),
                        )
                    self.cache = jax.tree.map(merge, self.cache, part_cache)
                    self.pos[slot] = len(req.prompt)
                    req.generated.append(int(first[i]))
                    self.active[slot] = req

    def step(self) -> None:
        """One engine tick: cancel expired requests, admit queued prompts,
        decode one token for every active slot, retire finished
        sequences."""
        self._cancel_expired()
        self._admit()
        if self._packed:
            self._step_packed()
        else:
            self._step_grouped()

    def _step_packed(self) -> None:
        """The continuous-batching decode tick: zero host syncs. The input
        token feed is the previous tick's on-device argmax; the output feed
        and the per-slot stats histogram go to the retirement thread as
        device arrays. Slot lifetime is host-deterministic (emission
        counts), so slots free without reading token values."""
        if not self.active:
            return
        key = self._program_key("decode")
        exe = self._compiled(key, self._build_tick)
        trace = self.tracer.enabled
        if trace or self._step_times:
            t_d = self._clock()
        index = jax.device_put(jnp.asarray(self.pos, jnp.int32),
                               self._repl_sh)
        out = exe(self.params, self._tok, self.cache, index)
        if self._with_stats:
            nxt, self.cache, stats = out
        else:
            (nxt, self.cache), stats = out, None
        self._tok = nxt
        now = self._clock()
        if self._step_times:
            self.metrics.record_step(key, now - t_d)
        if trace:
            self.tracer.record_span(key, t_d, now, n=len(self.active))
        self.metrics.work_done(len(self.active), "tokens")
        self.metrics.observe_queue_depth(self.scheduler.depth)
        append, retired = [], []
        for slot in list(self.active):
            req = self.active[slot]
            append.append((req, slot))
            self._emitted[slot] += 1
            self.pos[slot] += 1
            if self._emitted[slot] >= req.max_new_tokens or \
                    self.pos[slot] >= self.max_len - 1:
                self.active.pop(slot)
                retired.append((req, now - req.submitted_at, False))
                if trace:
                    # decode ends at the SAME timestamp the latency record
                    # uses, so queue+pack+prefill+decode sums exactly to
                    # the recorded end-to-end latency (the section 11
                    # acceptance invariant); retire closes in _consume
                    self.tracer.transition(req.trace_id, "decode", "retire",
                                           t=now)
        self._emit({"tok": nxt, "now": now, "append": append,
                    "retired": retired, "stats": stats})

    def _step_grouped(self) -> None:
        if not self.active:
            return
        tokens = np.zeros((self.B, 1), np.int32)
        for slot, req in self.active.items():
            tokens[slot, 0] = req.generated[-1]
        # per-slot cache positions: slots decode at their own fill level
        index = jnp.asarray(self.pos, jnp.int32)
        trace = self.tracer.enabled
        if trace or self._step_times:
            t_d = self._clock()
        with self._scope():
            out = self._decode(self.params, jnp.asarray(tokens), self.cache,
                               index)
        if self._with_stats:
            logits, self.cache, stats = out
            self.metrics.add_expert_tokens(np.asarray(stats["expert_tokens"]))
        else:
            logits, self.cache = out
        nxt = np.asarray(jnp.argmax(logits[:, 0, :], axis=-1))
        now = self._clock()
        if self._step_times:
            self.metrics.record_step(self._program_key("decode"), now - t_d)
        if trace:
            self.tracer.record_span(self._program_key("decode"), t_d, now,
                                    n=len(self.active))
        self.metrics.work_done(len(self.active), "tokens")
        self.metrics.observe_queue_depth(self.scheduler.depth)
        done = []
        for slot, req in self.active.items():
            tok = int(nxt[slot])
            req.generated.append(tok)
            self.pos[slot] += 1
            if len(req.generated) >= req.max_new_tokens or \
                    self.pos[slot] >= self.max_len - 1 or \
                    (self._eos_id is not None and tok == self._eos_id):
                done.append(slot)
        for slot in done:
            req = self.active.pop(slot)
            if trace:
                self.tracer.transition(req.trace_id, "decode", "retire",
                                       t=now)
            self._emit({"now": now,
                        "retired": [(req, now - req.submitted_at, False)]})

    def flush(self, max_ticks: int = 10_000) -> None:
        """Blocking drain: serve everything queued and in flight, then wait
        for the retirement thread to finish materializing token streams."""
        for _ in range(max_ticks):
            if not self.active and self.scheduler.depth == 0:
                break
            self.step()
        if self._async:
            self._rq.join()

    run_until_drained = flush

    def evict(self) -> List[Request]:
        """Quarantine support (serving/cluster.py): strand-and-return every
        request this replica holds — queued and mid-decode, in global FIFO
        order — without running any more device work.

        Already-emitted retirement events are drained first (``_rq.join``),
        so a request whose terminal event beat the eviction keeps its
        terminal status and the duplicate guard in ``_consume`` applies;
        everything returned here is marked ``evicted`` (in-flight events
        that still reference it become no-ops) and its decode slot, cache
        position, and emission count are reset so a promoted standby — or
        this engine, were it ever revived — starts clean."""
        if self._async:
            self._rq.join()
        stranded = list(self.scheduler.clear())
        for slot in sorted(self.active):
            stranded.append(self.active[slot])
        self.active.clear()
        self.pos[:] = 0
        self._emitted[:] = 0
        out = []
        for req in stranded:
            if getattr(req, "status", "pending") != "pending":
                continue  # terminal before the eviction: nothing to redo
            req.evicted = True
            out.append(req)
        return out
