"""Model-agnostic dynamic micro-batcher (DESIGN.md section 6).

One scheduler serves both engines: ``ServeEngine`` polls it with the number
of free decode slots as the batch limit (greedy admission, ``max_wait_s=0``),
``VisionEngine`` lets requests coalesce up to a batch-size bucket or a
max-wait deadline, whichever comes first, and pads the formed batch up to
the bucket ladder so the jitted forward compiles once per bucket shape.

Semantics:

  * **shape-bucketed admission** — ``bucket_of(item)`` maps each request to a
    hashable bucket key; only same-bucket requests batch together (requests
    of different padded shapes must never share a device batch).
  * **FIFO** — strict submission order within a bucket; across buckets the
    bucket whose head request is oldest releases first.
  * **deadline flush** — a partial batch is released once its oldest request
    has waited ``max_wait_s`` (0 means release immediately: greedy batching).
  * **backpressure** — ``submit`` raises ``Backpressure`` once ``max_pending``
    requests are queued (0 = unbounded); callers surface this to clients
    instead of growing the queue without bound.
  * **drain** — ``drain()`` releases partial batches immediately regardless
    of deadline, for end-of-stream flush.

The scheduler is pure host-side bookkeeping: it never touches device state,
and a ``clock`` can be injected for deterministic tests.
"""
from __future__ import annotations

import time
from collections import deque
from typing import Any, Callable, Dict, List, NamedTuple, Optional, Sequence


class Backpressure(RuntimeError):
    """``submit`` refused: the scheduler's pending bound has been reached."""


class MicroBatch(NamedTuple):
    key: Any  # bucket key the batch was formed from
    items: tuple  # requests in FIFO order (len <= pad_to)
    pad_to: int  # ladder size the engine should pad the batch up to
    waited_s: float  # queue wait of the oldest item at formation time
    # formation timestamp (scheduler clock) — the queue-phase end boundary
    # the span timelines use (serving/trace.py); 0.0 only from legacy
    # construction sites that predate the field
    formed_at: float = 0.0


class PackPlan(NamedTuple):
    """A packed-prefill plan: the maximal FIFO prefix of the queue whose
    token lengths fit a budget (DESIGN.md section 10)."""

    items: tuple  # requests in global FIFO order
    lengths: tuple  # token length per item (same order)
    total: int  # sum(lengths) — real tokens in the pack buffer
    budget: int  # token budget the plan was formed against
    waited_s: float  # queue wait of the oldest item at formation time
    # planner-selection timestamp — where each packed request's queue span
    # ends and its pack span begins (serving/trace.py)
    formed_at: float = 0.0


class MicroBatcher:
    """Request queue with bucketed batch formation (see module docstring)."""

    def __init__(
        self,
        *,
        bucket_of: Optional[Callable[[Any], Any]] = None,
        batch_sizes: Sequence[int] = (1,),
        max_wait_s: float = 0.0,
        max_pending: int = 0,
        clock: Callable[[], float] = time.monotonic,
    ) -> None:
        sizes = tuple(sorted(set(int(s) for s in batch_sizes)))
        if not sizes or sizes[0] < 1:
            raise ValueError(f"batch_sizes must be positive: {batch_sizes!r}")
        self.batch_sizes = sizes
        self.max_batch = sizes[-1]
        self.max_wait_s = float(max_wait_s)
        self.max_pending = int(max_pending)
        self._bucket_of = bucket_of or (lambda item: None)
        self._clock = clock
        # bucket key -> deque of (seq, enqueue_t, item); seq is a global
        # submission counter so cross-bucket age order is total and
        # deterministic even under a frozen test clock.
        self._buckets: Dict[Any, deque] = {}
        self._seq = 0
        self._depth = 0
        self._draining = False

    # -- admission ----------------------------------------------------------

    def submit(self, item: Any, now: Optional[float] = None) -> None:
        if self.max_pending and self._depth >= self.max_pending:
            raise Backpressure(
                f"scheduler full: {self._depth} pending "
                f"(max_pending={self.max_pending})"
            )
        now = self._clock() if now is None else now
        key = self._bucket_of(item)
        self._buckets.setdefault(key, deque()).append((self._seq, now, item))
        self._seq += 1
        self._depth += 1

    # -- inspection ---------------------------------------------------------

    @property
    def depth(self) -> int:
        """Total queued (not yet formed into a batch) requests."""
        return self._depth

    @property
    def draining(self) -> bool:
        return self._draining

    @property
    def room(self) -> float:
        """Admission headroom: how many more ``submit`` calls succeed before
        ``Backpressure`` (inf when ``max_pending`` is 0 = unbounded). Both
        engines derive their ``free_room`` routing signal from this."""
        if self.max_pending == 0:
            return float("inf")
        return max(0, self.max_pending - self._depth)

    def pending_items(self) -> List[Any]:
        """Queued requests in global FIFO (submission) order."""
        entries = [e for q in self._buckets.values() for e in q]
        entries.sort(key=lambda e: e[0])
        return [e[2] for e in entries]

    def clear(self) -> List[Any]:
        """Remove and return every queued request in global FIFO order.

        The eviction path (``ServingCluster.quarantine``): a quarantined
        replica's queued-but-not-yet-admitted requests are stranded host-side
        state, reclaimed here for re-dispatch to healthy replicas.
        """
        items = self.pending_items()
        self._buckets.clear()
        self._depth = 0
        return items

    def oldest_wait(self, now: Optional[float] = None) -> float:
        """Queue wait of the oldest pending request (0 when empty)."""
        heads = [q[0] for q in self._buckets.values() if q]
        if not heads:
            return 0.0
        now = self._clock() if now is None else now
        return max(0.0, now - min(t for _, t, _ in heads))

    # -- batch formation ----------------------------------------------------

    def drain(self, on: bool = True) -> None:
        """Enter (or leave) drain mode: partial batches release immediately."""
        self._draining = on

    def poll(self, now: Optional[float] = None,
             limit: Optional[int] = None) -> Optional[MicroBatch]:
        """Form and return the next ready batch, or None.

        ``limit`` caps the batch size below ``max_batch`` for callers whose
        downstream capacity varies per tick (ServeEngine's free decode
        slots). A bucket is *ready* when it holds a full batch, its head has
        exceeded the deadline, or the scheduler is draining; among ready
        buckets the one with the oldest head wins.
        """
        if self._depth == 0:
            return None
        cap = self.max_batch if limit is None else min(int(limit), self.max_batch)
        if cap <= 0:
            return None
        now = self._clock() if now is None else now
        best = None  # (head_seq, key)
        for key, q in self._buckets.items():
            if not q:
                continue
            ready = (
                len(q) >= cap
                or self._draining
                or (now - q[0][1]) >= self.max_wait_s
            )
            if ready and (best is None or q[0][0] < best[0]):
                best = (q[0][0], key)
        if best is None:
            return None
        q = self._buckets[best[1]]
        n = min(len(q), cap)
        waited = max(0.0, now - q[0][1])
        items = tuple(q.popleft()[2] for _ in range(n))
        self._depth -= n
        if not q:
            # drop emptied buckets: an unbounded bucket_of key space must
            # not grow the dict (or poll's scan) without bound
            del self._buckets[best[1]]
        return MicroBatch(key=best[1], items=items, pad_to=self._pad_to(n),
                          waited_s=waited, formed_at=now)

    def poll_pack(
        self,
        budget: int,
        length_of: Callable[[Any], int],
        now: Optional[float] = None,
        limit: Optional[int] = None,
    ) -> Optional[PackPlan]:
        """Form a packed-prefill plan: the maximal *strict FIFO prefix* of
        the queue (across buckets, in submission order) whose lengths sum to
        at most ``budget`` tokens, capped at ``limit`` items.

        Strict-prefix semantics are the starvation guarantee: formation
        stops at the first request that does not fit, rather than skipping
        it for smaller later ones — so a long prompt at the head is next no
        matter what arrives behind it. A plan is *ready* when it cannot grow
        (the next request does not fit, or ``limit`` is reached, or the
        whole queue is in it and the deadline/drain says go); otherwise the
        pack keeps coalescing until ``max_wait_s``.
        """
        if self._depth == 0:
            return None
        cap = self._depth if limit is None else int(limit)
        if cap <= 0 or budget <= 0:
            return None
        now = self._clock() if now is None else now
        entries = [e for q in self._buckets.values() for e in q]
        entries.sort(key=lambda e: e[0])
        head_len = length_of(entries[0][2])
        if head_len > budget:
            raise ValueError(
                f"prompt of {head_len} tokens exceeds the pack budget "
                f"({budget}) — raise max_prefill or reject at submit"
            )
        take, used = [], 0
        for e in entries:
            if len(take) >= cap:
                break
            n = length_of(e[2])
            if used + n > budget:
                break
            take.append(e)
            used += n
        blocked = len(take) < len(entries)  # pack is full: cannot grow
        ready = (
            blocked
            or self._draining
            or (now - take[0][1]) >= self.max_wait_s
        )
        if not ready:
            return None
        taken = {e[0] for e in take}
        for key in list(self._buckets):
            q = self._buckets[key]
            kept = deque(e for e in q if e[0] not in taken)
            if kept:
                self._buckets[key] = kept
            else:
                del self._buckets[key]
        self._depth -= len(take)
        return PackPlan(
            items=tuple(e[2] for e in take),
            lengths=tuple(length_of(e[2]) for e in take),
            total=used,
            budget=int(budget),
            waited_s=max(0.0, now - take[0][1]),
            formed_at=now,
        )

    def _pad_to(self, n: int) -> int:
        """Smallest ladder size that fits n (n never exceeds max_batch)."""
        for s in self.batch_sizes:
            if s >= n:
                return s
        return self.max_batch
