"""Scrapeable live metrics endpoint (DESIGN.md §12).

A stdlib ``http.server`` on a daemon thread so a serving cluster is
observable *while it runs* instead of only via the final snapshot dump:

  * ``GET /metrics``  — Prometheus text exposition (the same
    ``ClusterMetrics.export_prometheus`` rendering the benchmarks write),
  * ``GET /healthz``  — JSON liveness summary (replica counts, retire /
    callback error counters, drift events),
  * ``GET /snapshot`` — the full JSON metrics snapshot.

The handler calls back into snapshot providers on the request thread;
everything those providers touch is behind the metrics locks, so a scrape
never tears a snapshot and never blocks the decode loop for longer than
one snapshot assembly. Binding to port 0 picks a free port (tests); the
bound port is ``server.port`` after ``start()``.
"""
from __future__ import annotations

import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Callable, Optional

PROM_CONTENT_TYPE = "text/plain; version=0.0.4; charset=utf-8"


class MetricsServer:
    """Daemon-thread HTTP server over callable metric providers."""

    def __init__(self, prometheus_fn: Callable[[], str],
                 healthz_fn: Optional[Callable[[], dict]] = None,
                 snapshot_fn: Optional[Callable[[], dict]] = None,
                 host: str = "127.0.0.1", port: int = 0) -> None:
        self._prometheus_fn = prometheus_fn
        self._healthz_fn = healthz_fn
        self._snapshot_fn = snapshot_fn
        self._host = host
        self._port = port
        self._httpd: Optional[ThreadingHTTPServer] = None
        self._thread: Optional[threading.Thread] = None

    # -- lifecycle ----------------------------------------------------------

    def start(self) -> "MetricsServer":
        outer = self

        class Handler(BaseHTTPRequestHandler):
            def do_GET(self) -> None:  # noqa: N802 (stdlib casing)
                try:
                    outer._route(self)
                except BrokenPipeError:
                    pass
                except Exception as e:
                    try:
                        self.send_error(500, explain=repr(e))
                    except Exception:
                        pass

            def log_message(self, *a) -> None:
                pass  # scrapes must not spam the serving process's stderr

        self._httpd = ThreadingHTTPServer((self._host, self._port), Handler)
        self._httpd.daemon_threads = True
        self._port = self._httpd.server_address[1]
        self._thread = threading.Thread(
            target=self._httpd.serve_forever, name="metrics-server",
            daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        """Shut the listener down, close its socket, and join the daemon
        thread — tests and ``launch/serve.py`` exit without leaked sockets
        or threads. Idempotent."""
        if self._httpd is not None:
            self._httpd.shutdown()
            self._httpd.server_close()
            self._httpd = None
        if self._thread is not None:
            self._thread.join(timeout=5.0)
            self._thread = None

    close = stop  # conventional alias: the clean-shutdown contract

    def __enter__(self) -> "MetricsServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    @property
    def port(self) -> int:
        return self._port

    @property
    def url(self) -> str:
        return f"http://{self._host}:{self._port}"

    # -- routing ------------------------------------------------------------

    def _route(self, handler: BaseHTTPRequestHandler) -> None:
        path = handler.path.split("?", 1)[0].rstrip("/") or "/"
        if path == "/metrics":
            body = self._prometheus_fn().encode()
            self._reply(handler, 200, PROM_CONTENT_TYPE, body)
        elif path == "/healthz":
            health = (self._healthz_fn() if self._healthz_fn is not None
                      else {"status": "ok"})
            code = 200 if health.get("status") == "ok" else 503
            self._reply(handler, code, "application/json",
                        json.dumps(health).encode())
        elif path == "/snapshot" and self._snapshot_fn is not None:
            self._reply(handler, 200, "application/json",
                        json.dumps(self._snapshot_fn()).encode())
        else:
            handler.send_error(404)

    @staticmethod
    def _reply(handler: BaseHTTPRequestHandler, code: int,
               ctype: str, body: bytes) -> None:
        handler.send_response(code)
        handler.send_header("Content-Type", ctype)
        handler.send_header("Content-Length", str(len(body)))
        handler.end_headers()
        handler.wfile.write(body)


_STATUS_RANK = {"ok": 0, "degraded": 1, "unhealthy": 2}


def cluster_healthz(cluster) -> dict:
    """Liveness summary for a ``ServingCluster``: the watchdog roll-up
    (``cluster.health()`` — per-replica state, degraded flag, eviction
    ledger; DESIGN.md section 14) combined with the retirement-fault check
    (retire_errors — a lost completion is the one error class that corrupts
    results silently). Overall status is the worst of the two."""
    snap = cluster.metrics.snapshot()
    counters = snap["aggregate"]["counters"]
    retire_errors = counters.get("retire_errors", 0)
    status = "ok" if retire_errors == 0 else "degraded"
    out = {
        "replicas_active": snap["replicas_active"],
        "standby": len(getattr(cluster, "_standby", ())),
        "draining": len(getattr(cluster, "_draining", ())),
        "completed": counters.get("completed", 0),
        "rejected": counters.get("rejected", 0),
        "failed": counters.get("cluster_failed", 0),
        "retire_errors": retire_errors,
        "callback_errors": counters.get("callback_errors", 0),
        "expert_drift_events": counters.get("expert_drift", 0),
    }
    health_fn = getattr(cluster, "health", None)
    if callable(health_fn):
        wd = health_fn()
        if _STATUS_RANK.get(wd.get("status"), 0) > _STATUS_RANK[status]:
            status = wd["status"]
        out["replicas"] = wd.get("replicas", {})
        out["evicted"] = wd.get("evicted", [])
        out["degraded"] = wd.get("degraded", False)
    out["status"] = status
    return out


def serve_cluster_metrics(cluster, host: str = "127.0.0.1",
                          port: int = 0) -> MetricsServer:
    """Wire a ``ServingCluster``'s metrics to a started ``MetricsServer``
    (the ``launch/serve.py --metrics-port`` path)."""
    return MetricsServer(
        cluster.metrics.export_prometheus,
        healthz_fn=lambda: cluster_healthz(cluster),
        snapshot_fn=cluster.metrics.snapshot,
        host=host, port=port,
    ).start()
