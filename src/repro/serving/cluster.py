"""Engine-agnostic multi-replica serving cluster (DESIGN.md sections 7-8).

``ServingCluster`` runs N engine replicas over disjoint device-mesh slices
behind one admission front-end:

  client -> cluster ``MicroBatcher`` (FIFO + global backpressure + drain)
         -> least-loaded routing (replica with the smallest queued +
            in-flight load that still has admission room)
         -> replica (own scheduler, own jitted program on its mesh slice,
            own ``EngineMetrics``)

The cluster is generic over the ``EngineReplica`` protocol
(serving/replica.py): the replica factory is pluggable, and the default
builds ``VisionEngine`` replicas for the vit families and ``ServeEngine``
(LM decode — free decode slots as the load signal) replicas for everything
else. An LM cluster therefore works exactly like the vision one: DP across
replicas, and with ``cfg.moe.moe_exec == "expert_parallel"`` EP within a
replica's slice.

Replica layout: the device list is split into ``replicas + standby``
contiguous groups of equal size; each group becomes a ``('model',)`` mesh.
With one device per group this is pure data parallelism (params replicated
per replica); with EP each replica runs the sharded-expert all_to_all path
of ``distributed/expert_parallel.py`` inside its slice.

Backpressure is two-level: each replica bounds its own admission
(``max_pending_per_replica``; the router only offers work to replicas with
room) and the front-end bounds total admission (``max_pending`` — beyond
it ``submit`` raises ``scheduler.Backpressure`` to the client).

**Elasticity** (serving/autoscaler.py drives this): ``scale_up()`` moves a
pre-warmed standby replica into the router (or spawns + warms a new one
when the pool is empty); ``scale_down()`` stops routing to the least-loaded
replica and moves it to the *draining* set — it keeps being ticked until it
has served everything queued and in flight, then returns to standby, its
metrics folded into ``ClusterMetrics``' retired accumulator (no request and
no metric is ever lost across a drain). ``ClusterMetrics.mark_replicas``
records the (t, active-count) timeline on every transition.

``metrics`` is a ``ClusterMetrics`` roll-up: aggregate FPS over the union
window, latency percentiles merged from replica distributions (pooled, not
averaged), per-expert occupancy summed across replicas.
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Union

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.serving.events import EventLog
from repro.serving.metrics import ClusterMetrics
from repro.serving.replica import EngineReplica
from repro.serving.scheduler import MicroBatcher
from repro.serving.trace import FlightRecorder, write_chrome_trace

EngineFactory = Callable[[Any], EngineReplica]  # mesh -> replica


def replica_meshes(n_replicas: int, devices=None) -> List[jax.sharding.Mesh]:
    """Split the device list into ``n_replicas`` contiguous equal groups,
    each a 1-axis ``('model',)`` mesh. More replicas than devices is
    allowed (replicas then share devices — host-side concurrency only,
    useful for tests on one CPU device)."""
    devices = list(devices if devices is not None else jax.devices())
    n = max(1, int(n_replicas))
    if len(devices) >= n:
        per = len(devices) // n
        groups = [devices[i * per:(i + 1) * per] for i in range(n)]
    else:
        groups = [[devices[i % len(devices)]] for i in range(n)]
    return [
        jax.sharding.Mesh(np.asarray(g, object).reshape(len(g)), ("model",))
        for g in groups
    ]


class ServingCluster:
    """N-replica serving cluster behind one admission queue, generic over
    the ``EngineReplica`` protocol."""

    def __init__(
        self,
        cfg: Optional[ModelConfig],
        params=None,
        *,
        replicas: int = 0,
        standby: int = 0,
        devices=None,
        engine: Union[None, str, EngineFactory] = None,
        # vision replica knobs (engine="vision")
        batch_buckets: Sequence[int] = (1, 4, 8),
        max_wait_s: float = 2e-3,
        top_k: int = 5,
        max_inflight: int = 2,
        # LM replica knobs (engine="lm")
        batch_slots: int = 4,
        max_len: int = 512,
        # shared admission bounds
        max_pending: int = 4096,
        max_pending_per_replica: int = 64,
        events: Optional[EventLog] = None,
        clock: Callable[[], float] = time.monotonic,
    ) -> None:
        devices = list(devices if devices is not None else jax.devices())
        self._devices = devices
        ep = (cfg is not None and cfg.moe is not None
              and cfg.moe.moe_exec == "expert_parallel")
        self._ep = ep
        if replicas <= 0:
            # default: one replica per device (pure DP); EP defaults to a
            # single replica spanning every device
            replicas = 1 if ep else len(devices)
        self._clock = clock
        # observability: the shared event log (autoscaler decisions land
        # here too) and the cluster-global trace-id counter — uids are
        # caller-chosen and may collide across clients, trace ids may not
        self.events = events
        self._next_trace_id = 0
        self._replica_seq = 0
        # id(engine) -> stable "replicaN" name; kept cluster-side so event
        # records name untraced replicas too (a tracer only mirrors it)
        self._labels: Dict[int, str] = {}
        self._factory = self._resolve_factory(
            cfg, params, engine,
            batch_buckets=batch_buckets, max_wait_s=max_wait_s,
            top_k=top_k, max_inflight=max_inflight,
            batch_slots=batch_slots, max_len=max_len,
            max_pending_per_replica=max_pending_per_replica,
        )
        self.meshes = self._build_meshes(replicas + standby)
        self._next_mesh_i = replicas + standby
        built = [self._factory(mesh) for mesh in self.meshes]
        for e in built:
            self._label_replica(e)
        self.engines: List[EngineReplica] = built[:replicas]  # routable
        self._standby: List[EngineReplica] = built[replicas:]  # warm pool
        self._tracing = any(
            getattr(e, "tracer", None) is not None
            and e.tracer.enabled for e in built)
        self._draining: List[EngineReplica] = []  # no admission, still ticked
        # admission front-end: FIFO + global backpressure + drain; routing
        # pulls single requests (batch formation happens per replica, where
        # the bucket ladder lives)
        self._front = MicroBatcher(
            batch_sizes=(1,), max_wait_s=0.0, max_pending=max_pending,
            clock=clock,
        )
        self.metrics = ClusterMetrics([e.metrics for e in self.engines],
                                      clock=clock)
        self.metrics.mark_replicas(len(self.engines))

    # -- construction internals ---------------------------------------------

    def _resolve_factory(self, cfg, params, engine, *, batch_buckets,
                         max_wait_s, top_k, max_inflight, batch_slots,
                         max_len, max_pending_per_replica) -> EngineFactory:
        if callable(engine):
            return engine
        if engine is None:
            if cfg is None:
                raise ValueError("engine factory required when cfg is None")
            engine = "vision" if cfg.family in ("vit", "vit_moe") else "lm"
        clock = self._clock
        events = self.events
        if engine == "vision":
            from repro.serving.vision import VisionEngine

            return lambda mesh: VisionEngine(
                cfg, params,
                batch_buckets=batch_buckets, max_wait_s=max_wait_s,
                max_pending=max_pending_per_replica, top_k=top_k,
                max_inflight=max_inflight, mesh=mesh, events=events,
                clock=clock,
            )
        if engine == "lm":
            from repro.serving.engine import ServeEngine

            return lambda mesh: ServeEngine(
                cfg, params, batch_slots=batch_slots, max_len=max_len,
                max_pending=max_pending_per_replica, mesh=mesh,
                events=events, clock=clock,
            )
        raise ValueError(
            f"engine must be 'vision', 'lm', or a factory: {engine!r}")

    def _label_replica(self, eng) -> None:
        """Stable replica name, mirrored onto the engine's tracer when it
        has one — the process track in the Perfetto export. Custom factories
        without a tracer attr are fine (EngineReplica does not require
        one); event records still carry the cluster-side name."""
        label = f"replica{self._replica_seq}"
        self._replica_seq += 1
        self._labels[id(eng)] = label
        tr = getattr(eng, "tracer", None)
        if tr is not None and tr.enabled:
            tr.label = label

    def _build_meshes(self, n: int) -> List[jax.sharding.Mesh]:
        meshes = replica_meshes(n, self._devices)
        if not self._ep:
            # without expert parallelism a multi-device slice would run the
            # identical replicated program on every device of the slice —
            # pin each replica to its first device instead
            meshes = [
                m if m.size == 1 else jax.sharding.Mesh(
                    np.asarray(list(m.devices.flat)[:1], object), ("model",))
                for m in meshes
            ]
        return meshes

    def _next_mesh(self) -> jax.sharding.Mesh:
        """Mesh slice for a replica grown past the pre-built pool: EP
        replicas span all devices; DP replicas take a device no live
        replica is pinned to (falling back to round-robin only once every
        device is occupied — blindly cycling indices would double up on an
        active replica's device while others sit free)."""
        if self._ep:
            return self._build_meshes(1)[0]
        used = {
            d for e in self.engines + self._draining + self._standby
            if e.mesh is not None for d in e.mesh.devices.flat
        }
        free = [d for d in self._devices if d not in used]
        if free:
            d = free[0]
        else:
            d = self._devices[self._next_mesh_i % len(self._devices)]
            self._next_mesh_i += 1
        return jax.sharding.Mesh(np.asarray([d], object), ("model",))

    # -- properties ---------------------------------------------------------

    @property
    def clock(self) -> Callable[[], float]:
        return self._clock

    @property
    def num_replicas(self) -> int:
        """Routable (active) replicas."""
        return len(self.engines)

    @property
    def standby_replicas(self) -> int:
        return len(self._standby)

    @property
    def draining_replicas(self) -> int:
        return len(self._draining)

    @property
    def depth(self) -> int:
        """Requests held at the front-end (not yet routed to a replica)."""
        return self._front.depth

    @property
    def total_load(self) -> int:
        """Front-end depth + every serving replica's queued + in-flight."""
        return self._front.depth + sum(
            e.load for e in self.engines + self._draining)

    @property
    def idle(self) -> bool:
        return (self._front.depth == 0
                and all(e.idle for e in self.engines)
                and all(e.idle for e in self._draining))

    # -- elasticity (driven by serving/autoscaler.py) ------------------------

    def scale_up(self) -> bool:
        """Admit one more replica to the router. Preference order: (1)
        re-admit a *draining* replica — it is warm, still holds devices, and
        re-admitting it keeps active + draining within the operator's cap
        instead of piling a new engine on top of one that has not left yet;
        (2) promote a pre-warmed standby; (3) cold-spawn. The cold-spawn
        branch warms (compiles) synchronously — the pump that called it
        stalls for the compile, so size the standby pool to cover the
        expected surge (the autoscale benchmark sets
        ``standby = max_replicas - 1``) and treat cold spawns as a last
        resort, not the steady-state path."""
        if self._draining:
            eng = self._draining.pop()  # most recently drained
        elif self._standby:
            eng = self._standby.pop(0)
        else:
            eng = self._factory(self._next_mesh())
            self._label_replica(eng)
            eng.warmup()
        self.engines.append(eng)
        self.metrics.add_replica(eng.metrics)
        self.metrics.mark_replicas(len(self.engines))
        self.metrics.inc("cluster_scale_up")
        return True

    def scale_down(self) -> bool:
        """Stop routing to the least-loaded replica and start draining it:
        it keeps being ticked until everything queued + in flight on it is
        served, then returns to standby (``_reap_drained``). Refuses to
        drop the last active replica."""
        if len(self.engines) <= 1:
            return False
        eng = min(self.engines, key=lambda e: e.load)
        self.engines.remove(eng)
        self._draining.append(eng)
        self.metrics.mark_replicas(len(self.engines))
        self.metrics.inc("cluster_scale_down")
        return True

    def _reap_drained(self) -> None:
        """Move fully drained replicas to the standby pool, folding their
        metrics into the retired accumulator (then resetting them so a
        rejoin is never double-counted)."""
        still: List[EngineReplica] = []
        for e in self._draining:
            if e.idle:
                self.metrics.remove_replica(e.metrics)
                e.reset_metrics()
                self._standby.append(e)
                if self.events is not None:
                    self.events.emit(
                        "replica_drained",
                        replica=self._labels.get(id(e)),
                        active=len(self.engines),
                        standby=len(self._standby))
            else:
                still.append(e)
        self._draining = still

    # -- request path -------------------------------------------------------

    def submit(self, req) -> None:
        """Admit one request; raises ``scheduler.Backpressure`` when the
        cluster-wide admission bound is reached. Latency is stamped HERE —
        client-observed percentiles include front-end queue wait, not just
        time on the replica that eventually served the request."""
        req.submitted_at = self._clock()
        if self._tracing and getattr(req, "trace_id", None) is None:
            req.trace_id = self._next_trace_id
            self._next_trace_id += 1
        try:
            self._front.submit(req)
        except Exception:
            self.metrics.inc("cluster_rejected")
            if self.events is not None:
                self.events.emit("cluster_reject",
                                 uid=getattr(req, "uid", None),
                                 reason="backpressure",
                                 depth=self._front.depth)
            raise
        self.metrics.inc("cluster_submitted")

    def _route(self) -> None:
        """Move front-end requests to replicas, least-loaded first. Only
        pulls what the replicas can admit — per-replica backpressure keeps
        the remainder queued at the front in FIFO order. The front-end
        depth left after routing is sampled into the cluster metrics (the
        autoscaler's pressure signal)."""
        while self._front.depth:
            open_engines = [e for e in self.engines if e.free_room > 0]
            if not open_engines:
                break
            batch = self._front.poll(limit=1)
            if batch is None:
                break
            target = min(open_engines, key=lambda e: e.load)
            try:
                target.submit(batch.items[0])
            except ValueError:
                # unservable request (e.g. prompt longer than the engine's
                # cache): the replica counted it in `rejected`; drop it
                # instead of letting one bad request crash the route pump
                self.metrics.inc("cluster_rejected")
                if self.events is not None:
                    self.events.emit(
                        "cluster_reject",
                        uid=getattr(batch.items[0], "uid", None),
                        reason="unservable")
        self.metrics.observe_queue_depth(self._front.depth)

    def step(self) -> None:
        """One cluster pump: route queued requests, tick every serving
        replica (admit / dispatch / retire), and reap drained ones."""
        self._route()
        for e in self.engines:
            e.step()
        for e in self._draining:
            e.step()
        if self._draining:
            self._reap_drained()

    # -- observability export (DESIGN.md section 11) -------------------------

    def flight_recorders(self) -> Dict[str, FlightRecorder]:
        """Every tracing replica's flight recorder keyed by its stable
        label — active, draining, and standby alike (a drained replica's
        recorder still holds the spans it served)."""
        out: Dict[str, FlightRecorder] = {}
        for e in self.engines + self._draining + self._standby:
            tr = getattr(e, "tracer", None)
            if tr is not None and tr.enabled:
                out[tr.label] = tr.recorder
        return out

    def export_trace(self, path: str, t0: Optional[float] = None,
                     t1: Optional[float] = None) -> dict:
        """Write the cluster-wide Chrome-trace/Perfetto JSON (one process
        track per replica) and return the document."""
        return write_chrome_trace(path, self.flight_recorders(), t0, t1)

    def warmup(self) -> None:
        """Compile every program on every replica — active and standby (a
        standby must be warm *before* the autoscaler routes to it) —
        outside the measured path."""
        for e in self.engines + self._standby:
            e.warmup()

    def flush(self) -> None:
        """Drain: push everything queued through the replicas and retire
        every in-flight batch on each of them (draining replicas too)."""
        self._front.drain(True)
        try:
            while not self.idle:
                self._route()
                for e in self.engines + self._draining:
                    if not e.idle:
                        e.flush()
            self._reap_drained()
        finally:
            self._front.drain(False)

    run_until_drained = flush
