"""Engine-agnostic multi-replica serving cluster (DESIGN.md sections 7-8).

``ServingCluster`` runs N engine replicas over disjoint device-mesh slices
behind one admission front-end:

  client -> cluster ``MicroBatcher`` (FIFO + global backpressure + drain)
         -> least-loaded routing (replica with the smallest queued +
            in-flight load that still has admission room)
         -> replica (own scheduler, own jitted program on its mesh slice,
            own ``EngineMetrics``)

The cluster is generic over the ``EngineReplica`` protocol
(serving/replica.py): the replica factory is pluggable, and the default
builds ``VisionEngine`` replicas for the vit families and ``ServeEngine``
(LM decode — free decode slots as the load signal) replicas for everything
else. An LM cluster therefore works exactly like the vision one: DP across
replicas, and with ``cfg.moe.moe_exec == "expert_parallel"`` EP within a
replica's slice.

Replica layout: the device list is split into ``replicas + standby``
contiguous groups of equal size; each group becomes a ``('model',)`` mesh.
With one device per group this is pure data parallelism (params replicated
per replica); with EP each replica runs the sharded-expert all_to_all path
of ``distributed/expert_parallel.py`` inside its slice.

Backpressure is two-level: each replica bounds its own admission
(``max_pending_per_replica``; the router only offers work to replicas with
room) and the front-end bounds total admission (``max_pending`` — beyond
it ``submit`` raises ``scheduler.Backpressure`` to the client).

**Elasticity** (serving/autoscaler.py drives this): ``scale_up()`` moves a
pre-warmed standby replica into the router (or spawns + warms a new one
when the pool is empty); ``scale_down()`` stops routing to the least-loaded
replica and moves it to the *draining* set — it keeps being ticked until it
has served everything queued and in flight, then returns to standby, its
metrics folded into ``ClusterMetrics``' retired accumulator (no request and
no metric is ever lost across a drain). ``ClusterMetrics.mark_replicas``
records the (t, active-count) timeline on every transition.

``metrics`` is a ``ClusterMetrics`` roll-up: aggregate FPS over the union
window, latency percentiles merged from replica distributions (pooled, not
averaged), per-expert occupancy summed across replicas.

**Fault tolerance** (DESIGN.md section 14, serving/faults.py): with
``FaultConfig.watchdog`` on (the default), every replica ``step()`` runs
under a ``ReplicaWatchdog`` — consecutive step exceptions past the error
budget (OOM immediately), or consecutive stalls past the stall budget, take
the ``quarantine()`` path: the replica leaves the router *without* being
ticked again (unlike ``scale_down``'s graceful drain — a quarantined
replica may be wedged), its metrics fold into the retired accumulator, its
stranded in-flight requests are reclaimed via the optional ``evict()``
replica method and re-dispatched to healthy replicas (bounded by
``retry_budget``, then terminal ``failed``), and capacity is backfilled
from the standby pool — directly, not through the autoscaler, so the
controller's cooldown never delays recovery. ``on_done`` delivery is
at-most-once cluster-wide: ``submit`` wraps the callback with an idempotent
guard so a duplicate retirement (replayed across an eviction) is counted,
not delivered. With no standby left the cluster enters *degraded* mode:
admission tightens to what the surviving replicas can actually absorb
(reject-with-reason, never queue collapse), ``health()``/`/healthz` report
``degraded`` with the evicted-replica ledger, and ``scale_down`` refuses.
``FaultConfig.inject`` additionally wraps each replica in the deterministic
chaos ``FaultyReplica`` decorator (benchmarks/serve_chaos.py drives it).
"""
from __future__ import annotations

import threading
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Union

import jax
import numpy as np

from repro.configs.base import FaultConfig, ModelConfig
from repro.serving.events import EventLog
from repro.serving.faults import FaultInjector, FaultyReplica, ReplicaWatchdog
from repro.serving.metrics import ClusterMetrics
from repro.serving.replica import EngineReplica
from repro.serving.scheduler import Backpressure, MicroBatcher
from repro.serving.trace import FlightRecorder, write_chrome_trace

EngineFactory = Callable[[Any], EngineReplica]  # mesh -> replica


def replica_meshes(n_replicas: int, devices=None) -> List[jax.sharding.Mesh]:
    """Split the device list into ``n_replicas`` contiguous equal groups,
    each a 1-axis ``('model',)`` mesh. More replicas than devices is
    allowed (replicas then share devices — host-side concurrency only,
    useful for tests on one CPU device)."""
    devices = list(devices if devices is not None else jax.devices())
    n = max(1, int(n_replicas))
    if len(devices) >= n:
        per = len(devices) // n
        groups = [devices[i * per:(i + 1) * per] for i in range(n)]
    else:
        groups = [[devices[i % len(devices)]] for i in range(n)]
    return [
        jax.sharding.Mesh(np.asarray(g, object).reshape(len(g)), ("model",))
        for g in groups
    ]


class ServingCluster:
    """N-replica serving cluster behind one admission queue, generic over
    the ``EngineReplica`` protocol."""

    def __init__(
        self,
        cfg: Optional[ModelConfig],
        params=None,
        *,
        replicas: int = 0,
        standby: int = 0,
        devices=None,
        engine: Union[None, str, EngineFactory] = None,
        # vision replica knobs (engine="vision")
        batch_buckets: Sequence[int] = (1, 4, 8),
        max_wait_s: float = 2e-3,
        top_k: int = 5,
        max_inflight: int = 2,
        # LM replica knobs (engine="lm")
        batch_slots: int = 4,
        max_len: int = 512,
        # shared admission bounds
        max_pending: int = 4096,
        max_pending_per_replica: int = 64,
        events: Optional[EventLog] = None,
        clock: Callable[[], float] = time.monotonic,
        # fault model (None -> cfg.faults when cfg is given, else defaults);
        # fault_stall_fn overrides the injected-stall sleep for fake-clock
        # tests (serving/faults.py)
        faults: Optional[FaultConfig] = None,
        fault_stall_fn: Optional[Callable[[float], None]] = None,
    ) -> None:
        devices = list(devices if devices is not None else jax.devices())
        self._devices = devices
        ep = (cfg is not None and cfg.moe is not None
              and cfg.moe.moe_exec == "expert_parallel")
        self._ep = ep
        if replicas <= 0:
            # default: one replica per device (pure DP); EP defaults to a
            # single replica spanning every device
            replicas = 1 if ep else len(devices)
        self._clock = clock
        # observability: the shared event log (autoscaler decisions land
        # here too) and the cluster-global trace-id counter — uids are
        # caller-chosen and may collide across clients, trace ids may not
        self.events = events
        self._next_trace_id = 0
        self._replica_seq = 0
        # id(engine) -> stable "replicaN" name; kept cluster-side so event
        # records name untraced replicas too (a tracer only mirrors it)
        self._labels: Dict[int, str] = {}
        # fault model: chaos injection (replica decorator) + watchdog state
        if faults is None:
            faults = (cfg.faults if cfg is not None
                      and getattr(cfg, "faults", None) is not None
                      else FaultConfig())
        self.faults = faults
        self._wd_enabled = bool(faults.watchdog)
        self._watchdogs: Dict[int, ReplicaWatchdog] = {}
        self._retire_lock = threading.Lock()  # at-most-once on_done guard
        self._degraded = False
        self._evicted: List[dict] = []  # eviction ledger (healthz)
        self._evicted_engines: List[EngineReplica] = []
        self._per_replica_cap = int(max_pending_per_replica)
        self._factory = self._resolve_factory(
            cfg, params, engine,
            batch_buckets=batch_buckets, max_wait_s=max_wait_s,
            top_k=top_k, max_inflight=max_inflight,
            batch_slots=batch_slots, max_len=max_len,
            max_pending_per_replica=max_pending_per_replica,
        )
        if faults.inject:
            # every replica this cluster ever builds (including autoscaler
            # cold-spawns) gets its own seeded injector; build order matches
            # label order so injector ordinals line up with "replicaN"
            base_factory = self._factory
            self._inject_seq = 0

            def chaotic(mesh, _f=base_factory):
                inj = FaultInjector(self.faults, ordinal=self._inject_seq,
                                    stall_fn=fault_stall_fn)
                self._inject_seq += 1
                return FaultyReplica(_f(mesh), inj)

            self._factory = chaotic
        self.meshes = self._build_meshes(replicas + standby)
        self._next_mesh_i = replicas + standby
        built = [self._factory(mesh) for mesh in self.meshes]
        for e in built:
            self._label_replica(e)
        self.engines: List[EngineReplica] = built[:replicas]  # routable
        self._standby: List[EngineReplica] = built[replicas:]  # warm pool
        self._tracing = any(
            getattr(e, "tracer", None) is not None
            and e.tracer.enabled for e in built)
        self._draining: List[EngineReplica] = []  # no admission, still ticked
        # admission front-end: FIFO + global backpressure + drain; routing
        # pulls single requests (batch formation happens per replica, where
        # the bucket ladder lives)
        self._front = MicroBatcher(
            batch_sizes=(1,), max_wait_s=0.0, max_pending=max_pending,
            clock=clock,
        )
        self.metrics = ClusterMetrics([e.metrics for e in self.engines],
                                      clock=clock)
        self.metrics.mark_replicas(len(self.engines))

    # -- construction internals ---------------------------------------------

    def _resolve_factory(self, cfg, params, engine, *, batch_buckets,
                         max_wait_s, top_k, max_inflight, batch_slots,
                         max_len, max_pending_per_replica) -> EngineFactory:
        if callable(engine):
            return engine
        if engine is None:
            if cfg is None:
                raise ValueError("engine factory required when cfg is None")
            engine = "vision" if cfg.family in ("vit", "vit_moe") else "lm"
        clock = self._clock
        events = self.events
        if engine == "vision":
            from repro.serving.vision import VisionEngine

            return lambda mesh: VisionEngine(
                cfg, params,
                batch_buckets=batch_buckets, max_wait_s=max_wait_s,
                max_pending=max_pending_per_replica, top_k=top_k,
                max_inflight=max_inflight, mesh=mesh, events=events,
                clock=clock,
            )
        if engine == "lm":
            from repro.serving.engine import ServeEngine

            return lambda mesh: ServeEngine(
                cfg, params, batch_slots=batch_slots, max_len=max_len,
                max_pending=max_pending_per_replica, mesh=mesh,
                events=events, clock=clock,
            )
        raise ValueError(
            f"engine must be 'vision', 'lm', or a factory: {engine!r}")

    def _label_replica(self, eng) -> None:
        """Stable replica name, mirrored onto the engine's tracer when it
        has one — the process track in the Perfetto export. Custom factories
        without a tracer attr are fine (EngineReplica does not require
        one); event records still carry the cluster-side name."""
        label = f"replica{self._replica_seq}"
        self._replica_seq += 1
        self._labels[id(eng)] = label
        tr = getattr(eng, "tracer", None)
        if tr is not None and tr.enabled:
            tr.label = label

    def _build_meshes(self, n: int) -> List[jax.sharding.Mesh]:
        meshes = replica_meshes(n, self._devices)
        if not self._ep:
            # without expert parallelism a multi-device slice would run the
            # identical replicated program on every device of the slice —
            # pin each replica to its first device instead
            meshes = [
                m if m.size == 1 else jax.sharding.Mesh(
                    np.asarray(list(m.devices.flat)[:1], object), ("model",))
                for m in meshes
            ]
        return meshes

    def _next_mesh(self) -> jax.sharding.Mesh:
        """Mesh slice for a replica grown past the pre-built pool: EP
        replicas span all devices; DP replicas take a device no live
        replica is pinned to (falling back to round-robin only once every
        device is occupied — blindly cycling indices would double up on an
        active replica's device while others sit free)."""
        if self._ep:
            return self._build_meshes(1)[0]
        used = {
            d for e in self.engines + self._draining + self._standby
            if e.mesh is not None for d in e.mesh.devices.flat
        }
        free = [d for d in self._devices if d not in used]
        if free:
            d = free[0]
        else:
            d = self._devices[self._next_mesh_i % len(self._devices)]
            self._next_mesh_i += 1
        return jax.sharding.Mesh(np.asarray([d], object), ("model",))

    # -- properties ---------------------------------------------------------

    @property
    def clock(self) -> Callable[[], float]:
        return self._clock

    @property
    def num_replicas(self) -> int:
        """Routable (active) replicas."""
        return len(self.engines)

    @property
    def standby_replicas(self) -> int:
        return len(self._standby)

    @property
    def draining_replicas(self) -> int:
        return len(self._draining)

    @property
    def depth(self) -> int:
        """Requests held at the front-end (not yet routed to a replica)."""
        return self._front.depth

    @property
    def total_load(self) -> int:
        """Front-end depth + every serving replica's queued + in-flight."""
        return self._front.depth + sum(
            e.load for e in self.engines + self._draining)

    @property
    def idle(self) -> bool:
        return (self._front.depth == 0
                and all(e.idle for e in self.engines)
                and all(e.idle for e in self._draining))

    # -- elasticity (driven by serving/autoscaler.py) ------------------------

    def scale_up(self) -> bool:
        """Admit one more replica to the router. Preference order: (1)
        re-admit a *draining* replica — it is warm, still holds devices, and
        re-admitting it keeps active + draining within the operator's cap
        instead of piling a new engine on top of one that has not left yet;
        (2) promote a pre-warmed standby; (3) cold-spawn. The cold-spawn
        branch warms (compiles) synchronously — the pump that called it
        stalls for the compile, so size the standby pool to cover the
        expected surge (the autoscale benchmark sets
        ``standby = max_replicas - 1``) and treat cold spawns as a last
        resort, not the steady-state path."""
        if self._draining:
            eng = self._draining.pop()  # most recently drained
        elif self._standby:
            eng = self._standby.pop(0)
        else:
            eng = self._factory(self._next_mesh())
            self._label_replica(eng)
            eng.warmup()
        self.engines.append(eng)
        self.metrics.add_replica(eng.metrics)
        self.metrics.mark_replicas(len(self.engines))
        self.metrics.inc("cluster_scale_up")
        if self._degraded:
            # capacity restored: leave degraded mode (admission un-tightens)
            self._degraded = False
            if self.events is not None:
                self.events.emit("cluster_recovered",
                                 active=len(self.engines),
                                 standby=len(self._standby))
        return True

    def scale_down(self) -> bool:
        """Stop routing to the least-loaded replica and start draining it:
        it keeps being ticked until everything queued + in flight on it is
        served, then returns to standby (``_reap_drained``). Refuses to
        drop the last active replica, and refuses entirely while degraded —
        a cluster that just lost capacity to an eviction must not let the
        controller's scale-down streak fight the recovery."""
        if len(self.engines) <= 1 or self._degraded:
            return False
        eng = min(self.engines, key=lambda e: e.load)
        self.engines.remove(eng)
        self._draining.append(eng)
        self.metrics.mark_replicas(len(self.engines))
        self.metrics.inc("cluster_scale_down")
        return True

    def _reap_drained(self) -> None:
        """Move fully drained replicas to the standby pool, folding their
        metrics into the retired accumulator (then resetting them so a
        rejoin is never double-counted)."""
        still: List[EngineReplica] = []
        for e in self._draining:
            if e.idle:
                self.metrics.remove_replica(e.metrics)
                e.reset_metrics()
                self._standby.append(e)
                if self.events is not None:
                    self.events.emit(
                        "replica_drained",
                        replica=self._labels.get(id(e)),
                        active=len(self.engines),
                        standby=len(self._standby))
            else:
                still.append(e)
        self._draining = still

    # -- fault tolerance (DESIGN.md section 14) ------------------------------

    def _watchdog(self, eng) -> ReplicaWatchdog:
        wd = self._watchdogs.get(id(eng))
        if wd is None:
            wd = ReplicaWatchdog(
                self.faults, label=self._labels.get(id(eng), "replica?"))
            self._watchdogs[id(eng)] = wd
        return wd

    def _step_replica(self, eng) -> None:
        """Tick one replica under the watchdog: time the step, feed the
        outcome to the replica's monitor, quarantine on a verdict. With the
        watchdog disabled this is exactly ``eng.step()``."""
        if not self._wd_enabled:
            eng.step()
            return
        wd = self._watchdog(eng)
        t0 = self._clock()
        try:
            eng.step()
        except Exception as e:
            self.metrics.inc("replica_step_errors")
            if self.events is not None:
                self.events.emit("replica_step_error",
                                 replica=self._labels.get(id(eng)),
                                 error=repr(e))
            verdict = wd.record_error(e)
            if verdict is not None:
                self.quarantine(eng, verdict)
            return
        verdict = wd.record_step(self._clock() - t0)
        if verdict is not None:
            self.quarantine(eng, verdict)

    def quarantine(self, eng, verdict: Optional[dict] = None) -> None:
        """Evict a suspect replica NOW — no drain, no further ticks (it may
        be wedged). Its metrics fold into the retired accumulator exactly as
        a drain would; its stranded queued/in-flight requests are reclaimed
        (optional replica ``evict()``) and re-dispatched to healthy
        replicas; capacity is backfilled from the standby pool directly —
        deliberately NOT via the autoscaler, whose cooldown must never
        delay recovery. With no standby left the cluster goes degraded."""
        if isinstance(verdict, str):
            verdict = {"reason": verdict}
        verdict = dict(verdict or {"reason": "manual"})
        was_active = eng in self.engines
        if was_active:
            self.engines.remove(eng)
        elif eng in self._draining:
            self._draining.remove(eng)
        else:
            return  # already quarantined/drained — idempotent
        self.metrics.remove_replica(eng.metrics)
        try:
            eng.reset_metrics()
        except Exception:
            pass  # a wedged replica's reset must not abort the eviction
        stranded: List[Any] = []
        evict = getattr(eng, "evict", None)
        if callable(evict):
            try:
                stranded = list(evict())
            except Exception:
                pass  # best-effort reclaim; unreturned requests fail below
        self._watchdogs.pop(id(eng), None)
        self._evicted_engines.append(eng)  # keep its flight recorder
        label = self._labels.get(id(eng))
        self.metrics.inc("replicas_evicted")
        if self.events is not None:
            # full watchdog inputs ride along — the eviction is replayable
            # from the journal
            self.events.emit("replica_evicted", replica=label,
                             stranded=len(stranded), **verdict)
        backfilled = None
        if was_active and self._standby:
            new = self._standby.pop(0)
            backfilled = self._labels.get(id(new))
            self.engines.append(new)
            self.metrics.add_replica(new.metrics)
            self.metrics.inc("replicas_replaced")
            if self.events is not None:
                self.events.emit("replica_replaced", evicted=label,
                                 replacement=backfilled,
                                 standby=len(self._standby))
        elif was_active:
            # serving capacity lost with no standby to promote: degrade
            if not self._degraded:
                self._degraded = True
                self.metrics.inc("cluster_degraded")
                if self.events is not None:
                    self.events.emit("cluster_degraded",
                                     active=len(self.engines),
                                     evicted=len(self._evicted) + 1)
        self._evicted.append({
            "t": self._clock(), "replica": label,
            "stranded": len(stranded), "backfilled": backfilled, **verdict,
        })
        self.metrics.mark_replicas(len(self.engines))
        for req in stranded:
            self._redispatch(req)

    def _redispatch(self, req) -> None:
        """Re-queue an evicted in-flight request at the front-end (original
        ``submitted_at`` stamp preserved — client latency includes the
        failure), bounded by ``retry_budget`` re-dispatches, then terminal
        ``failed``."""
        req.redispatched = getattr(req, "redispatched", 0) + 1
        if req.redispatched > self.faults.retry_budget:
            self._fail(req, "retry_budget_exhausted")
            return
        req.evicted = False
        if hasattr(req, "eos_seen"):
            req.eos_seen = False
        if hasattr(req, "generated"):
            req.generated = None  # restart the stream from the prompt
        self.metrics.inc("cluster_redispatched")
        if self.events is not None:
            self.events.emit("request_redispatched",
                             uid=getattr(req, "uid", None),
                             attempt=req.redispatched)
        try:
            self._front.submit(req)
        except Backpressure:
            self._fail(req, "redispatch_backpressure")

    def _fail(self, req, reason: str) -> None:
        """Terminal ``failed``: counted, journaled, and delivered through
        the (at-most-once-guarded) ``on_done`` exactly like a completion."""
        req.status = "failed"
        req.evicted = False
        self.metrics.inc("cluster_failed")
        if self.events is not None:
            self.events.emit("request_failed", uid=getattr(req, "uid", None),
                             reason=reason,
                             redispatched=getattr(req, "redispatched", 0))
        cb = getattr(req, "on_done", None)
        if cb is not None:
            try:
                cb(req)
            except Exception as e:
                self.metrics.inc("cluster_callback_errors")
                if self.events is not None:
                    self.events.emit("callback_error",
                                     uid=getattr(req, "uid", None),
                                     error=repr(e))

    def _guard_done(self, req) -> None:
        """Wrap ``on_done`` with the cluster-wide at-most-once guard: the
        first terminal delivery (any thread — replica retirement daemons
        and the cluster's ``_fail`` race across an eviction) wins; later
        ones are counted as ``duplicate_retirements`` and dropped."""
        if getattr(req, "_ft_guarded", False):
            return
        inner = getattr(req, "on_done", None)
        lock = self._retire_lock
        metrics = self.metrics

        def once(r, _inner=inner):
            with lock:
                if getattr(r, "_done_fired", False):
                    metrics.inc("duplicate_retirements")
                    return
                r._done_fired = True
            if _inner is not None:
                _inner(r)

        req.on_done = once
        req._ft_guarded = True

    def health(self) -> dict:
        """Watchdog roll-up for ``/healthz`` (serving/metrics_server.py):
        overall status, per-replica watchdog state, and the eviction
        ledger."""
        if not self.engines:
            status = "unhealthy"
        elif self._degraded:
            status = "degraded"
        else:
            status = "ok"
        reps = {}
        for e in self.engines + self._draining:
            label = self._labels.get(id(e), "replica?")
            wd = self._watchdogs.get(id(e))
            reps[label] = (wd.state() if wd is not None
                           else {"health": "healthy"})
        return {
            "status": status,
            "degraded": self._degraded,
            "active": len(self.engines),
            "standby": len(self._standby),
            "draining": len(self._draining),
            "replicas": reps,
            "evicted": list(self._evicted),
        }

    @property
    def degraded(self) -> bool:
        return self._degraded

    # -- request path -------------------------------------------------------

    def submit(self, req) -> None:
        """Admit one request; raises ``scheduler.Backpressure`` when the
        cluster-wide admission bound is reached. Latency is stamped HERE —
        client-observed percentiles include front-end queue wait, not just
        time on the replica that eventually served the request.

        Degraded mode tightens admission: the front-end bound shrinks from
        ``max_pending`` to what the surviving replicas can actually absorb
        (active x per-replica cap) — load is shed with an explicit reason
        instead of queueing toward collapse."""
        if self._degraded and self._per_replica_cap:
            cap = max(1, len(self.engines)) * self._per_replica_cap
            if self._front.depth >= cap:
                self.metrics.inc("cluster_shed")
                self.metrics.inc("cluster_rejected")
                if self.events is not None:
                    self.events.emit("cluster_reject",
                                     uid=getattr(req, "uid", None),
                                     reason="degraded_shed",
                                     depth=self._front.depth, cap=cap)
                raise Backpressure(
                    f"degraded: admission tightened to {cap} "
                    f"({len(self.engines)} surviving replicas)")
        req.submitted_at = self._clock()
        if (self._tracing or self._wd_enabled) \
                and getattr(req, "trace_id", None) is None:
            req.trace_id = self._next_trace_id
            self._next_trace_id += 1
        if self._wd_enabled:
            self._guard_done(req)
        try:
            self._front.submit(req)
        except Exception:
            self.metrics.inc("cluster_rejected")
            if self.events is not None:
                self.events.emit("cluster_reject",
                                 uid=getattr(req, "uid", None),
                                 reason="backpressure",
                                 depth=self._front.depth)
            raise
        self.metrics.inc("cluster_submitted")

    def _route(self) -> None:
        """Move front-end requests to replicas, least-loaded first. Only
        pulls what the replicas can admit — per-replica backpressure keeps
        the remainder queued at the front in FIFO order. The front-end
        depth left after routing is sampled into the cluster metrics (the
        autoscaler's pressure signal)."""
        while self._front.depth:
            open_engines = [e for e in self.engines if e.free_room > 0]
            if not open_engines:
                break
            batch = self._front.poll(limit=1)
            if batch is None:
                break
            target = min(open_engines, key=lambda e: e.load)
            try:
                target.submit(batch.items[0])
            except Backpressure:
                # a replica refusing admission it advertised room for
                # (injected rejection, or a real race): requeue at the
                # front and stop this pump — retrying in the same loop
                # against a deterministic rejector would spin forever
                self.metrics.inc("replica_submit_rejected")
                self._front.submit(batch.items[0])
                break
            except ValueError:
                # unservable request (e.g. prompt longer than the engine's
                # cache): the replica counted it in `rejected`; drop it
                # instead of letting one bad request crash the route pump
                self.metrics.inc("cluster_rejected")
                if self.events is not None:
                    self.events.emit(
                        "cluster_reject",
                        uid=getattr(batch.items[0], "uid", None),
                        reason="unservable")
        self.metrics.observe_queue_depth(self._front.depth)

    def step(self) -> None:
        """One cluster pump: route queued requests, tick every serving
        replica (admit / dispatch / retire) under the watchdog, and reap
        drained ones. List copies because a quarantine verdict mutates the
        pools mid-iteration."""
        self._route()
        for e in list(self.engines):
            self._step_replica(e)
        for e in list(self._draining):
            self._step_replica(e)
        if self._draining:
            self._reap_drained()

    # -- observability export (DESIGN.md section 11) -------------------------

    def flight_recorders(self) -> Dict[str, FlightRecorder]:
        """Every tracing replica's flight recorder keyed by its stable
        label — active, draining, and standby alike (a drained replica's
        recorder still holds the spans it served)."""
        out: Dict[str, FlightRecorder] = {}
        pools = (self.engines + self._draining + self._standby
                 + self._evicted_engines)
        for e in pools:
            tr = getattr(e, "tracer", None)
            if tr is not None and tr.enabled:
                out[tr.label] = tr.recorder
        return out

    def export_trace(self, path: str, t0: Optional[float] = None,
                     t1: Optional[float] = None) -> dict:
        """Write the cluster-wide Chrome-trace/Perfetto JSON (one process
        track per replica) and return the document."""
        return write_chrome_trace(path, self.flight_recorders(), t0, t1)

    def warmup(self) -> None:
        """Compile every program on every replica — active and standby (a
        standby must be warm *before* the autoscaler routes to it) —
        outside the measured path."""
        for e in self.engines + self._standby:
            e.warmup()

    def flush(self) -> None:
        """Drain: push everything queued through the replicas and retire
        every in-flight batch on each of them (draining replicas too). A
        replica whose flush raises goes through the watchdog (quarantine
        once its error budget trips) instead of aborting the drain; if
        every replica is lost, remaining queued requests terminate as
        ``failed`` — flush never deadlocks on a dead cluster."""
        self._front.drain(True)
        try:
            rounds = 0
            while not self.idle:
                rounds += 1
                if rounds > 100_000:
                    # pathological no-progress spin (e.g. an injector
                    # rejecting every submit): shed what is left as failed
                    for req in self._front.clear():
                        self._fail(req, "flush_no_progress")
                    break
                if not self.engines and not self._draining:
                    # nothing left to serve on: deliver terminal failures
                    # rather than spinning on an unroutable queue
                    for req in self._front.clear():
                        self._fail(req, "no_replicas")
                    break
                self._route()
                for e in list(self.engines) + list(self._draining):
                    if e.idle:
                        continue
                    if not self._wd_enabled:
                        e.flush()
                        continue
                    try:
                        e.flush()
                    except Exception as exc:
                        verdict = self._watchdog(e).record_error(exc)
                        if verdict is not None:
                            self.quarantine(e, verdict)
            self._reap_drained()
        finally:
            self._front.drain(False)

    run_until_drained = flush
