"""Multi-replica vision serving cluster (DESIGN.md section 7).

``ServingCluster`` runs N ``VisionEngine`` replicas over disjoint
device-mesh slices behind one admission front-end:

  client -> cluster ``MicroBatcher`` (FIFO + global backpressure + drain)
         -> least-loaded routing (replica with the smallest queued +
            in-flight load that still has admission room)
         -> replica ``VisionEngine`` (own scheduler, own jitted forward on
            its mesh slice, own ``EngineMetrics``)

Replica layout: the device list is split into ``replicas`` contiguous
groups of equal size; each group becomes a ``('model',)`` mesh. With one
device per group this is pure data parallelism (params replicated per
replica); with ``cfg.moe.moe_exec == "expert_parallel"`` each replica runs
the sharded-expert all_to_all path of ``distributed/expert_parallel.py``
inside its slice — DP across replicas x EP within a replica.

Backpressure is two-level: each replica bounds its own queue
(``max_pending_per_replica``; the router only offers work to replicas with
room) and the front-end bounds total admission (``max_pending`` — beyond
it ``submit`` raises ``scheduler.Backpressure`` to the client).

``metrics`` is a ``ClusterMetrics`` roll-up: aggregate FPS over the union
window, latency percentiles merged from replica distributions (pooled, not
averaged), per-expert occupancy summed across replicas.
"""
from __future__ import annotations

import time
from typing import Callable, List, Sequence

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.serving.metrics import ClusterMetrics
from repro.serving.scheduler import MicroBatcher
from repro.serving.vision import VisionEngine, VisionRequest


def replica_meshes(n_replicas: int, devices=None) -> List[jax.sharding.Mesh]:
    """Split the device list into ``n_replicas`` contiguous equal groups,
    each a 1-axis ``('model',)`` mesh. More replicas than devices is
    allowed (replicas then share devices — host-side concurrency only,
    useful for tests on one CPU device)."""
    devices = list(devices if devices is not None else jax.devices())
    n = max(1, int(n_replicas))
    if len(devices) >= n:
        per = len(devices) // n
        groups = [devices[i * per:(i + 1) * per] for i in range(n)]
    else:
        groups = [[devices[i % len(devices)]] for i in range(n)]
    return [
        jax.sharding.Mesh(np.asarray(g, object).reshape(len(g)), ("model",))
        for g in groups
    ]


class ServingCluster:
    """N-replica MoE-ViT serving cluster behind one admission queue."""

    def __init__(
        self,
        cfg: ModelConfig,
        params,
        *,
        replicas: int = 0,
        devices=None,
        batch_buckets: Sequence[int] = (1, 4, 8),
        max_wait_s: float = 2e-3,
        max_pending: int = 4096,
        max_pending_per_replica: int = 64,
        top_k: int = 5,
        max_inflight: int = 2,
        clock: Callable[[], float] = time.monotonic,
    ) -> None:
        devices = list(devices if devices is not None else jax.devices())
        ep = cfg.moe is not None and cfg.moe.moe_exec == "expert_parallel"
        if replicas <= 0:
            # default: one replica per device (pure DP); EP defaults to a
            # single replica spanning every device
            replicas = 1 if ep else len(devices)
        self.meshes = replica_meshes(replicas, devices)
        if not ep:
            # without expert parallelism a multi-device slice would run the
            # identical replicated program on every device of the slice —
            # pin each replica to its first device instead
            self.meshes = [
                m if m.size == 1 else jax.sharding.Mesh(
                    np.asarray(list(m.devices.flat)[:1], object), ("model",))
                for m in self.meshes
            ]
        self._clock = clock
        self.engines: List[VisionEngine] = [
            VisionEngine(
                cfg, params,
                batch_buckets=batch_buckets, max_wait_s=max_wait_s,
                max_pending=max_pending_per_replica, top_k=top_k,
                max_inflight=max_inflight, mesh=mesh, clock=clock,
            )
            for mesh in self.meshes
        ]
        # admission front-end: FIFO + global backpressure + drain; routing
        # pulls single requests (batch formation happens per replica, where
        # the bucket ladder lives)
        self._front = MicroBatcher(
            batch_sizes=(1,), max_wait_s=0.0, max_pending=max_pending,
            clock=clock,
        )
        self.metrics = ClusterMetrics([e.metrics for e in self.engines],
                                      clock=clock)

    # -- properties ---------------------------------------------------------

    @property
    def num_replicas(self) -> int:
        return len(self.engines)

    @property
    def depth(self) -> int:
        """Requests held at the front-end (not yet routed to a replica)."""
        return self._front.depth

    @property
    def idle(self) -> bool:
        return self._front.depth == 0 and all(e.idle for e in self.engines)

    # -- request path -------------------------------------------------------

    def submit(self, req: VisionRequest) -> None:
        """Admit one request; raises ``scheduler.Backpressure`` when the
        cluster-wide admission bound is reached. Latency is stamped HERE —
        client-observed percentiles include front-end queue wait, not just
        time on the replica that eventually served the request."""
        req.submitted_at = self._clock()
        try:
            self._front.submit(req)
        except Exception:
            self.metrics.inc("cluster_rejected")
            raise
        self.metrics.inc("cluster_submitted")

    def _route(self) -> None:
        """Move front-end requests to replicas, least-loaded first. Only
        pulls what the replicas can admit — per-replica backpressure keeps
        the remainder queued at the front in FIFO order."""
        while self._front.depth:
            open_engines = [e for e in self.engines if e.free_room > 0]
            if not open_engines:
                return
            batch = self._front.poll(limit=1)
            if batch is None:
                return
            target = min(open_engines, key=lambda e: e.load)
            target.submit(batch.items[0])

    def step(self) -> None:
        """One cluster pump: route queued requests, then tick every replica
        (retire finished device batches, dispatch ready ones)."""
        self._route()
        for e in self.engines:
            e.step()

    def warmup(self) -> None:
        """Compile every bucket on every replica outside the measured path."""
        for e in self.engines:
            e.warmup()

    def flush(self) -> None:
        """Drain: push everything queued through the replicas and retire
        every in-flight batch on each of them."""
        self._front.drain(True)
        try:
            while not self.idle:
                self._route()
                for e in self.engines:
                    if e.scheduler.depth or e._inflight:
                        e.flush()
        finally:
            self._front.drain(False)

    run_until_drained = flush
