"""Live performance introspection for the serving stack (DESIGN.md §12).

Answers the north-star question — "are we running as fast as the hardware
allows?" — *while serving* instead of in an offline dry-run:

  * ``ProgramCost`` — per-AOT-program resource accounting captured at
    ``warmup()`` from ``compiled.cost_analysis()`` + ``memory_analysis()``
    + the call-graph-aware ``repro.analysis.hlo`` analyzer, keyed by the
    same ``serve/<prog>|B=..|S=..`` keys as ``EngineMetrics.step_latency``
    so cost rows join measured step-latency histograms into live MFU,
    achieved-HBM-bandwidth, and a compute/memory/collective roofline
    classification (the join itself lives in serving/metrics.py).
  * Backends differ in what they expose (``cost_analysis`` returns a
    list on CPU, a dict elsewhere, sometimes nothing at all), so every
    capture degrades field-by-field to an **analytic estimate** marked
    ``estimated=True`` — introspection must never fail a warmup.
  * Memory watermarks — device ``memory_stats()`` where the backend has
    it, analytic param-bytes + K/V-cache-bytes + peak-temp fallback on
    hosts (CPU CI) that answer ``None``.
  * ``ExpertHealthMonitor`` — windowed occupancy entropy / hot-cold skew
    over the routed-token stream, emitting ``expert_drift`` events into
    the serving ``EventLog`` when a window's occupancy moves more than a
    total-variation threshold from the reference: the observability
    precursor to the ROADMAP's expert-rebalancing item.

Everything here is host-side and warmup-time; the only steady-state cost
is the drift monitor's histogram accumulation (bounded alongside tracing
by ``benchmarks/serve_introspect.py``).
"""
from __future__ import annotations

import math
import threading
import time
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.analysis import hlo, hw

# ProgramCost rows are plain dicts with exactly these keys (DESIGN.md §12).
# -1 marks "backend did not say"; ``flops``/``hbm_bytes`` are the best
# estimates the MFU/roofline join consumes, preferring call-graph HLO
# numbers (scan trip counts applied) over raw cost_analysis over analytic.
PROGRAM_COST_FIELDS = (
    "flops", "dot_flops", "cost_flops", "hbm_bytes", "convert_bytes",
    "collective_bytes", "argument_bytes", "output_bytes", "temp_bytes",
    "generated_code_bytes", "estimated", "source",
)


def parse_program_key(key: str) -> Tuple[str, Dict[str, int]]:
    """Split an AOT program key (``serve/decode|B=4|S=512`` /
    ``classify|b=8``) into its program name and integer k=v fields."""
    parts = key.split("|")
    kv: Dict[str, int] = {}
    for p in parts[1:]:
        if "=" not in p:
            continue
        k, _, v = p.partition("=")
        try:
            kv[k] = int(v)
        except ValueError:
            pass
    return parts[0], kv


def normalize_cost_analysis(raw) -> Dict[str, float]:
    """Flatten the backend-dependent ``cost_analysis()`` return into one
    ``{metric: float}`` dict: CPU answers a list of per-executable dicts,
    TPU a plain dict, some backends ``None`` or ``[]``. Non-numeric values
    drop; anything unrecognizable answers ``{}`` (degrade, never raise)."""
    if isinstance(raw, (list, tuple)):
        raw = raw[0] if raw else {}
    if not isinstance(raw, dict):
        return {}
    out: Dict[str, float] = {}
    for k, v in raw.items():
        if isinstance(v, (int, float)) and not isinstance(v, bool):
            out[str(k)] = float(v)
    return out


def program_cost_from_compiled(compiled) -> Optional[dict]:
    """Best-effort ProgramCost row from a compiled executable's own
    introspection surfaces. Returns None when *no* surface yielded
    anything (caller falls back to the analytic model)."""
    row = {
        "flops": -1.0, "dot_flops": 0.0, "cost_flops": -1.0,
        "hbm_bytes": -1.0, "convert_bytes": 0.0, "collective_bytes": -1.0,
        "argument_bytes": -1, "output_bytes": -1, "temp_bytes": -1,
        "generated_code_bytes": -1, "estimated": False, "source": "",
    }
    sources: List[str] = []

    try:
        cost = normalize_cost_analysis(compiled.cost_analysis())
    except Exception:
        cost = {}
    if cost:
        sources.append("cost_analysis")
        row["cost_flops"] = cost.get("flops", -1.0)
        row["hbm_bytes"] = cost.get("bytes accessed", -1.0)

    mem = None
    try:
        mem = compiled.memory_analysis()
    except Exception:
        mem = None
    if mem is not None:
        got_mem = False
        for field, attr in (
            ("argument_bytes", "argument_size_in_bytes"),
            ("output_bytes", "output_size_in_bytes"),
            ("temp_bytes", "temp_size_in_bytes"),
            ("generated_code_bytes", "generated_code_size_in_bytes"),
        ):
            v = getattr(mem, attr, None)
            if isinstance(v, (int, float)) and not isinstance(v, bool):
                row[field] = int(v)
                got_mem = True
        if got_mem:
            sources.append("memory_analysis")

    deep: dict = {}
    try:
        text = compiled.as_text()
        if text:
            deep = hlo.analyze(text)
    except Exception:
        deep = {}
    if deep:
        sources.append("hlo")
        row["dot_flops"] = float(deep.get("dot_flops", 0))
        row["convert_bytes"] = float(deep.get("convert_bytes", 0))
        row["collective_bytes"] = float(deep.get("collective_bytes", 0))
        hbm = float(deep.get("hbm_bytes", 0))
        if hbm > 0:
            # fusion-boundary traffic with scan trip counts applied beats
            # "bytes accessed" (which counts while bodies once)
            row["hbm_bytes"] = hbm

    if not sources:
        return None
    if row["dot_flops"] > 0:
        row["flops"] = row["dot_flops"]
    elif row["cost_flops"] > 0:
        row["flops"] = row["cost_flops"]
    row["source"] = "+".join(sources)
    return row


def analytic_program_cost(key: str, cfg=None, *, param_bytes: int = 0,
                          cache_bytes: int = 0) -> dict:
    """Analytic ProgramCost fallback (``estimated=True``) from the config's
    derived sizes and the program key's shape fields — the serving-grid
    analogue of ``benchmarks/roofline.model_flops``. Deliberately rough:
    it exists so the MFU join has *a* denominator on backends whose
    executables expose nothing, and is always flagged."""
    prog, kv = parse_program_key(key)
    active = d = n_layers = q_dim = 0
    if cfg is not None:
        try:
            active = cfg.active_param_count()
            d = cfg.d_model
            n_layers = cfg.num_layers
            q_dim = cfg.attn.q_dim if cfg.attn is not None else d
        except Exception:
            pass
    tokens = ctx = 0
    if "decode" in prog:
        tokens = kv.get("B", 1)
        ctx = kv.get("S", 0)
    elif "packed_prefill" in prog:
        tokens = kv.get("bucket", 1)
        ctx = tokens
    elif "grouped_prefill" in prog:
        tokens = kv.get("L", 1) * max(1, kv.get("n", 1))
        ctx = kv.get("L", 1)
    elif prog == "classify":
        seq = cfg.image_tokens if cfg is not None and cfg.image_tokens else 1
        tokens = kv.get("b", 1) * seq
        ctx = seq
    else:
        tokens = kv.get("B", kv.get("b", 1))
        ctx = kv.get("S", 0)
    # 2*active matmul flops per token + attention score/value contractions
    flops = 2.0 * active * tokens + 4.0 * q_dim * ctx * tokens * n_layers
    # weights stream once per dispatch; decode re-reads the K/V cache
    hbm = float(param_bytes + cache_bytes) + 4.0 * d * tokens
    return {
        "flops": flops if flops > 0 else -1.0,
        "dot_flops": 0.0, "cost_flops": -1.0,
        "hbm_bytes": hbm if hbm > 0 else -1.0,
        "convert_bytes": 0.0, "collective_bytes": 0.0,
        "argument_bytes": int(param_bytes), "output_bytes": -1,
        "temp_bytes": -1, "generated_code_bytes": -1,
        "estimated": True, "source": "analytic",
    }


def capture_cost(compiled, key: str, cfg=None, *, param_bytes: int = 0,
                 cache_bytes: int = 0) -> dict:
    """ProgramCost for one program: executable introspection first,
    analytic hole-filling second. Never raises — the contract that lets
    ``warmup()`` call this unconditionally."""
    row = None
    if compiled is not None:
        try:
            row = program_cost_from_compiled(compiled)
        except Exception:
            row = None
    est = analytic_program_cost(key, cfg, param_bytes=param_bytes,
                                cache_bytes=cache_bytes)
    if row is None:
        return est
    for field in ("flops", "hbm_bytes"):
        if row.get(field, -1) is None or row.get(field, -1) <= 0:
            row[field] = est[field]
            row["estimated"] = True
            if "analytic" not in row["source"]:
                row["source"] = (row["source"] + "+analytic").lstrip("+")
    return row


def tree_bytes(tree) -> int:
    """Total on-device bytes of a pytree's array leaves (0 for None)."""
    if tree is None:
        return 0
    try:
        import jax

        return int(sum(int(getattr(x, "nbytes", 0) or 0)
                       for x in jax.tree_util.tree_leaves(tree)))
    except Exception:
        return 0


def param_byte_breakdown(tree) -> dict:
    """Dtype/packing-aware parameter byte accounting (DESIGN.md §13).

    Sizes every leaf from its ACTUAL storage dtype (``nbytes``) — never an
    assumed int8/fp32 width — and splits out:

      * ``by_dtype``: bytes per storage dtype name (``uint8`` = the
        nibble-packed int4 leaves, two weights per byte);
      * ``expert_stack_bytes``: bytes of the MoE expert stacks (``wi``/
        ``wo`` leaves under a ``moe`` subtree) — the operand the int4
        scheme halves;
      * ``int4_packed_bytes``: bytes of nibble-packed leaves anywhere.
    """
    out = {"by_dtype": {}, "expert_stack_bytes": 0, "int4_packed_bytes": 0}
    if tree is None:
        return out
    try:
        import jax

        from repro.core.quant.qtypes import is_int4_leaf

        for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
            n = int(getattr(leaf, "nbytes", 0) or 0)
            if not n:
                continue
            dt = str(getattr(leaf, "dtype", "unknown"))
            out["by_dtype"][dt] = out["by_dtype"].get(dt, 0) + n
            keys = [getattr(k, "key", None) for k in path]
            if keys and keys[-1] in ("wi", "wo") and "moe" in keys[:-1]:
                out["expert_stack_bytes"] += n
            if is_int4_leaf(leaf):
                out["int4_packed_bytes"] += n
    except Exception:
        pass
    return out


def memory_watermark(devices=None, *, param_bytes: int = 0,
                     cache_bytes: int = 0,
                     program_costs: Optional[Dict[str, dict]] = None,
                     param_breakdown: Optional[dict] = None) -> dict:
    """Replica memory watermark: real allocator stats summed over the
    replica's devices when the backend exposes ``memory_stats()`` (TPU/GPU),
    else the analytic model — resident params + K/V cache + the largest
    compiled temp arena across the replica's programs — marked estimated.

    ``param_bytes`` (and the optional ``param_breakdown`` from
    :func:`param_byte_breakdown`) are sized from actual leaf dtypes
    including nibble packing, so an int4 expert tree reports ~2x fewer
    expert bytes than int8 even on the analytic (CPU) path."""
    if devices is None:
        try:
            import jax

            devices = jax.local_devices()
        except Exception:
            devices = []
    rows = []
    for dev in devices:
        try:
            s = dev.memory_stats()
        except Exception:
            s = None
        if s:
            rows.append(s)
    peak_temp = 0
    for c in (program_costs or {}).values():
        t = c.get("temp_bytes", 0)
        if isinstance(t, (int, float)) and t > 0:
            peak_temp = max(peak_temp, int(t))
    out = {
        "param_bytes": int(param_bytes),
        "kv_cache_bytes": int(cache_bytes),
        "peak_temp_bytes": peak_temp,
        "devices": len(rows) if rows else len(list(devices)),
    }
    if param_breakdown:
        out["param_bytes_by_dtype"] = dict(param_breakdown.get("by_dtype",
                                                               {}))
        out["expert_stack_bytes"] = int(
            param_breakdown.get("expert_stack_bytes", 0))
        out["int4_packed_bytes"] = int(
            param_breakdown.get("int4_packed_bytes", 0))
    if rows:
        out["source"] = "device"
        out["estimated"] = False
        out["bytes_in_use"] = sum(int(r.get("bytes_in_use", 0)) for r in rows)
        out["peak_bytes_in_use"] = sum(
            int(r.get("peak_bytes_in_use", r.get("bytes_in_use", 0)))
            for r in rows)
        out["bytes_limit"] = sum(int(r.get("bytes_limit", 0)) for r in rows)
        out["watermark_bytes"] = out["peak_bytes_in_use"]
    else:
        out["source"] = "analytic"
        out["estimated"] = True
        out["watermark_bytes"] = int(param_bytes) + int(cache_bytes) \
            + peak_temp
    return out


def install(metrics, *, cfg, programs: Dict[str, object], params=None,
            cache=None, devices=None) -> None:
    """Attach the whole introspection surface to an ``EngineMetrics``:
    one ProgramCost row per AOT program, the resolved roofline peaks, and
    a live memory-watermark probe. Called from ``warmup()``; swallows
    everything — introspection must never fail a warmup."""
    try:
        param_bytes = tree_bytes(params)
        param_breakdown = param_byte_breakdown(params)
        cache_bytes = tree_bytes(cache)
        dev = None
        try:
            dev = list(devices)[0] if devices else None
        except Exception:
            dev = None
        use_int8 = hw.pick_int8(
            params, getattr(getattr(cfg, "quant", None), "enable", False))
        metrics.set_peaks(hw.device_peaks(dev, use_int8=use_int8))
        for key, exe in programs.items():
            try:
                metrics.set_program_cost(
                    key, capture_cost(exe, key, cfg,
                                      param_bytes=param_bytes,
                                      cache_bytes=cache_bytes))
            except Exception:
                pass
        costs = metrics.program_costs  # static after warmup; probe re-reads

        def probe() -> dict:
            return memory_watermark(devices, param_bytes=param_bytes,
                                    cache_bytes=cache_bytes,
                                    program_costs=costs,
                                    param_breakdown=param_breakdown)

        metrics.memory_probe = probe
        metrics.set_memory(probe())
    except Exception:
        pass


class ExpertHealthMonitor:
    """Windowed expert-routing health over the routed-token stream.

    ``update(counts)`` accumulates per-expert routed-token histograms (the
    same host arrays ``EngineMetrics.add_expert_tokens`` receives). Every
    ``window_tokens`` routings the window closes: normalized occupancy
    entropy and the hot/cold skew ratio are computed, and the window's
    occupancy is compared (total-variation distance, L1/2) against a
    slowly-tracking reference. Distance above ``drift_threshold`` fires
    one ``expert_drift`` event into the ``EventLog`` (plus the optional
    ``on_drift`` hook — engines count it as an ``expert_drift`` metrics
    counter) and re-baselines, so a regime change is reported once, not
    on every subsequent window.

    Thread-safe behind its own lock, fed *outside* the metrics lock: the
    only lock order is monitor -> (events | metrics), never the reverse.
    """

    def __init__(self, num_experts: int, *, window_tokens: int = 4096,
                 drift_threshold: float = 0.25, baseline_alpha: float = 0.1,
                 events=None, label: str = "engine",
                 on_drift: Optional[Callable[[dict], None]] = None,
                 clock: Callable[[], float] = time.monotonic) -> None:
        self.num_experts = int(num_experts)
        self.window_tokens = int(window_tokens)
        self.drift_threshold = float(drift_threshold)
        self.baseline_alpha = float(baseline_alpha)
        self.events = events
        self.label = label
        self.on_drift = on_drift
        self._clock = clock
        self._lock = threading.Lock()
        self._win = np.zeros(self.num_experts, np.int64)
        self._ref: Optional[np.ndarray] = None
        self._last: dict = {}
        self.windows = 0
        self.drift_events = 0

    def update(self, counts) -> None:
        a = np.asarray(counts, np.int64).reshape(-1)
        if a.size != self.num_experts or self.num_experts == 0:
            return
        fire = None
        with self._lock:
            self._win += a
            if int(self._win.sum()) >= self.window_tokens:
                fire = self._close_window_locked()
        if fire is not None:
            if self.events is not None:
                try:
                    self.events.emit("expert_drift", t=self._clock(), **fire)
                except Exception:
                    pass
            if self.on_drift is not None:
                try:
                    self.on_drift(fire)
                except Exception:
                    pass

    def _close_window_locked(self) -> Optional[dict]:
        total = float(self._win.sum())
        occ = self._win / total
        nz = occ[occ > 0]
        e = self.num_experts
        entropy = (float(-(nz * np.log(nz)).sum() / math.log(e))
                   if e > 1 else 1.0)
        hot = float(occ.max())
        cold = float(occ.min())
        skew = hot / max(cold, 1.0 / (e * 1e3))  # floor keeps it finite
        l1 = (0.5 * float(np.abs(occ - self._ref).sum())
              if self._ref is not None else 0.0)
        drifted = self._ref is not None and l1 > self.drift_threshold
        self.windows += 1
        self._last = {
            "entropy": round(entropy, 6),
            "hot_cold_skew": round(skew, 3),
            "hot_expert": int(occ.argmax()),
            "cold_expert": int(occ.argmin()),
            "l1_vs_ref": round(l1, 6),
            "window_tokens": int(total),
        }
        if self._ref is None or drifted:
            self._ref = occ
        else:
            a = self.baseline_alpha
            self._ref = (1.0 - a) * self._ref + a * occ
        self._win[:] = 0
        if not drifted:
            return None
        self.drift_events += 1
        return dict(self._last, label=self.label)

    def snapshot(self) -> dict:
        with self._lock:
            out = {
                "num_experts": self.num_experts,
                "windows": self.windows,
                "drift_events": self.drift_events,
                "drift_threshold": self.drift_threshold,
            }
            out.update(self._last)
            return out
