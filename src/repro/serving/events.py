"""Structured serving event log (DESIGN.md section 11).

``EventLog`` is the serving stack's decision journal: where the flight
recorder (serving/trace.py) answers "where did this request's time go",
the event log answers "why did the system do that" — every autoscaler
scale_up/scale_down with the controller inputs that triggered it, every
admission rejection, deadline cancellation, drain completion, and
retirement fault, as one append-only sequence of typed records.

Records are plain dicts ``{"t": <clock seconds>, "type": <str>, ...}``.
The log keeps a bounded in-memory ring (same flight-recorder discipline as
the span buffer: newest window wins, ``dropped`` counts evictions) and can
*stream* to a JSONL sink as events are emitted (``path=``), so a crashed
process still leaves its decision trail on disk. ``emit`` is thread-safe —
the retirement thread logs faults while the control loop logs scale
decisions.

Event types in use (producers add fields freely; ``type`` + ``t`` are the
only required keys):

  scale_up / scale_down  — autoscaler decisions, with the controller
                           inputs (depth, windowed p95, streaks, load)
  replica_drained        — a scale_down target finished draining and
                           returned to standby (cluster reap path)
  reject                 — engine admission rejection (unservable prompt
                           or backpressure), with the reason
  cluster_reject         — front-end admission rejection
  cancel                 — QoS deadline cancellation (queued or mid-
                           generation — ``where`` says which)
  retire_error           — a poisoned retirement event (the daemon
                           survived; the payload is lost)
  callback_error         — a request's on_done callback raised
  replica_step_error     — a replica step() raised (watchdog input;
                           DESIGN.md section 14)
  replica_evicted        — watchdog quarantine, with the full verdict
                           (reason, error/stall streaks, EMA, last error)
  replica_replaced       — standby promoted to backfill an eviction
  request_redispatched   — an evicted in-flight request re-queued
  request_failed         — retry budget exhausted: terminal failed status
  cluster_degraded       — eviction with no standby left (admission
                           tightens); cluster_recovered on scale_up
"""
from __future__ import annotations

import json
import threading
import time
from collections import deque
from typing import Any, Callable, Dict, List, Optional


class EventLog:
    """Bounded, thread-safe, optionally file-backed event journal."""

    def __init__(self, capacity: int = 65536, path: Optional[str] = None,
                 clock: Callable[[], float] = time.monotonic) -> None:
        self._lock = threading.RLock()
        self._ring: deque = deque(maxlen=max(1, int(capacity)))
        self._total = 0
        self._clock = clock
        self._path = path
        self._sink = open(path, "w") if path else None

    def emit(self, etype: str, t: Optional[float] = None,
             **fields: Any) -> Dict[str, Any]:
        """Append one event (and stream it to the sink when file-backed).
        ``t`` defaults to the injected clock — pass the producer's own
        timestamp when it already read the clock this tick."""
        ev = {"t": self._clock() if t is None else float(t),
              "type": str(etype)}
        ev.update(fields)
        with self._lock:
            self._ring.append(ev)
            self._total += 1
            if self._sink is not None:
                self._sink.write(json.dumps(ev, default=_jsonable) + "\n")
                self._sink.flush()
        return ev

    def __len__(self) -> int:
        with self._lock:
            return len(self._ring)

    @property
    def total(self) -> int:
        with self._lock:
            return self._total

    @property
    def dropped(self) -> int:
        with self._lock:
            return self._total - len(self._ring)

    def events(self, etype: Optional[str] = None) -> List[Dict[str, Any]]:
        """Snapshot of the retained window, optionally filtered by type."""
        with self._lock:
            out = list(self._ring)
        if etype is not None:
            out = [e for e in out if e["type"] == etype]
        return out

    def counts(self) -> Dict[str, int]:
        """Event-type histogram of the retained window."""
        out: Dict[str, int] = {}
        for e in self.events():
            out[e["type"]] = out.get(e["type"], 0) + 1
        return out

    def write_jsonl(self, path: str) -> int:
        """Dump the retained window to ``path`` (one JSON object per
        line); returns the number of events written. Independent of the
        streaming sink — use it to snapshot an in-memory log at exit."""
        evs = self.events()
        with open(path, "w") as f:
            for ev in evs:
                f.write(json.dumps(ev, default=_jsonable) + "\n")
        return len(evs)

    def close(self) -> None:
        with self._lock:
            if self._sink is not None:
                self._sink.close()
                self._sink = None


def _jsonable(x: Any):
    """Fallback serializer: numpy scalars and anything else stringify."""
    item = getattr(x, "item", None)
    if callable(item):
        try:
            return item()
        except Exception:
            pass
    return str(x)


def read_jsonl(path: str) -> List[Dict[str, Any]]:
    """Load a JSONL event file (benchmark/CI artifact checks)."""
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out
