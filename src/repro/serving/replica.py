"""Engine-agnostic replica protocol (DESIGN.md section 8).

``ServingCluster`` fronts N engine replicas without knowing which model
family they serve: everything the cluster (and the autoscaler) touches is
the ``EngineReplica`` surface below. ``VisionEngine`` (batched MoE-ViT
classification) and ``ServeEngine`` (slot-based LM decode with the int8
K/V cache) both implement it, so one front-end multiplexes heterogeneous
workloads — the serving analogue of the paper's reusable-operator
orchestration (Edge-MoE's task-level multi-workload serving makes the same
argument at the accelerator level).

The contract, all host-side:

  =================  ======================================================
  ``submit(req)``    admit one request; raise ``scheduler.Backpressure``
                     when the replica's own bound is hit; preserve an
                     upstream ``req.submitted_at`` stamp
  ``step()``         one non-blocking pump: admit / dispatch / retire
  ``warmup()``       compile every program shape outside the measured path
  ``flush()``        serve everything queued + in flight (blocking drain)
  ``load``           queued + in-flight requests — the least-loaded routing
                     key. Vision: queue depth + in-flight batch rows; LM:
                     queue depth + occupied decode slots
  ``free_room``      admission headroom before ``submit`` raises (inf when
                     unbounded). LM replicas count free decode slots here —
                     decode slots are the load signal
  ``idle``           nothing queued and nothing in flight (public surface:
                     the cluster never reads private engine state)
  ``metrics``        the replica's ``EngineMetrics`` (merge-safe roll-up)
  ``reset_metrics``  fresh ``EngineMetrics`` after the cluster folds the old
                     one into its retired accumulator (replica leave)
  ``mesh``           the device-mesh slice the replica is pinned to (None =
                     process default devices)
  =================  ======================================================

``isinstance(obj, EngineReplica)`` is a runtime structural check (method /
attribute presence), used by the conformance tests and by ``ServingCluster``
to validate custom engine factories.

Observability attributes are deliberately **not** part of the protocol:
``tracer`` (serving/trace.py) and ``events`` (serving/events.py) are
optional — the cluster reads them with ``getattr(engine, "tracer", None)``
so a minimal custom replica (or a test fake) conforms without carrying the
tracing machinery (DESIGN.md section 11).

``evict()`` is likewise optional (fault tolerance, DESIGN.md section 14):
a replica that implements it returns its stranded queued + in-flight
requests — marked ``evicted``, without running further device work — when
the cluster quarantines it; the cluster re-dispatches the returned
requests to healthy replicas. The cluster discovers it via
``getattr(engine, "evict", None)``; a replica without it simply loses its
in-flight work on eviction (the at-most-once retirement guard still
protects against duplicate delivery).
"""
from __future__ import annotations

from typing import Any, Optional, Protocol, runtime_checkable

from jax.sharding import Mesh

from repro.serving.metrics import EngineMetrics


@runtime_checkable
class EngineReplica(Protocol):
    """Structural protocol every cluster-manageable engine implements."""

    metrics: EngineMetrics
    mesh: Optional[Mesh]

    def submit(self, req: Any) -> None:
        """Admit one request (raises ``Backpressure`` at the bound)."""
        ...

    def step(self) -> None:
        """One non-blocking pump: admit, dispatch, retire."""
        ...

    def warmup(self) -> None:
        """Compile every program shape outside the measured path."""
        ...

    def flush(self) -> None:
        """Blocking drain: serve everything queued and in flight."""
        ...

    def reset_metrics(self) -> None:
        """Replace ``metrics`` with a fresh instance (cluster replica
        leave: the old one was folded into the retired accumulator)."""
        ...

    @property
    def load(self) -> float:
        """Queued + in-flight requests (least-loaded routing key)."""
        ...

    @property
    def free_room(self) -> float:
        """Admission headroom before ``submit`` raises (inf = unbounded)."""
        ...

    @property
    def idle(self) -> bool:
        """True when nothing is queued and nothing is in flight."""
        ...
