from repro.serving.engine import Request, ServeEngine, build_serve_step
from repro.serving.metrics import EngineMetrics, LatencyTracker
from repro.serving.scheduler import Backpressure, MicroBatch, MicroBatcher
from repro.serving.vision import VisionEngine, VisionRequest, synth_requests

__all__ = [
    "Backpressure",
    "EngineMetrics",
    "LatencyTracker",
    "MicroBatch",
    "MicroBatcher",
    "Request",
    "ServeEngine",
    "VisionEngine",
    "VisionRequest",
    "build_serve_step",
    "synth_requests",
]
