from repro.serving.autoscaler import Autoscaler
from repro.serving.cluster import ServingCluster, replica_meshes
from repro.serving.engine import Request, ServeEngine, build_serve_step
from repro.serving.events import EventLog, read_jsonl
from repro.serving.metrics import (
    ClusterMetrics,
    EngineMetrics,
    LatencyTracker,
    hist_percentile,
)
from repro.serving.replica import EngineReplica
from repro.serving.scheduler import Backpressure, MicroBatch, MicroBatcher
from repro.serving.trace import (
    FlightRecorder,
    Span,
    Tracer,
    chrome_trace,
    make_tracer,
    validate_chrome_trace,
    validate_request_timelines,
    write_chrome_trace,
)
from repro.serving.vision import VisionEngine, VisionRequest, synth_requests

__all__ = [
    "Autoscaler",
    "Backpressure",
    "ClusterMetrics",
    "EngineMetrics",
    "EngineReplica",
    "EventLog",
    "FlightRecorder",
    "LatencyTracker",
    "MicroBatch",
    "MicroBatcher",
    "Request",
    "ServeEngine",
    "ServingCluster",
    "Span",
    "Tracer",
    "VisionEngine",
    "VisionRequest",
    "build_serve_step",
    "chrome_trace",
    "hist_percentile",
    "make_tracer",
    "read_jsonl",
    "replica_meshes",
    "synth_requests",
    "validate_chrome_trace",
    "validate_request_timelines",
    "write_chrome_trace",
]
