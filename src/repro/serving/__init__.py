from repro.serving.autoscaler import Autoscaler
from repro.serving.cluster import ServingCluster, replica_meshes
from repro.serving.engine import Request, ServeEngine, build_serve_step
from repro.serving.events import EventLog, read_jsonl
from repro.serving.faults import (
    FaultInjector,
    FaultyReplica,
    InjectedFault,
    InjectedOOM,
    ReplicaWatchdog,
    is_oom_error,
)
from repro.serving.introspect import (
    ExpertHealthMonitor,
    capture_cost,
    memory_watermark,
    normalize_cost_analysis,
    parse_program_key,
)
from repro.serving.metrics import (
    ClusterMetrics,
    EngineMetrics,
    LatencyTracker,
    hist_percentile,
    program_perf,
)
from repro.serving.metrics_server import (
    MetricsServer,
    cluster_healthz,
    serve_cluster_metrics,
)
from repro.serving.replica import EngineReplica
from repro.serving.scheduler import Backpressure, MicroBatch, MicroBatcher
from repro.serving.trace import (
    FlightRecorder,
    Span,
    Tracer,
    chrome_trace,
    make_tracer,
    validate_chrome_trace,
    validate_request_timelines,
    write_chrome_trace,
)
from repro.serving.vision import VisionEngine, VisionRequest, synth_requests

__all__ = [
    "Autoscaler",
    "Backpressure",
    "ClusterMetrics",
    "EngineMetrics",
    "EngineReplica",
    "EventLog",
    "ExpertHealthMonitor",
    "FaultInjector",
    "FaultyReplica",
    "FlightRecorder",
    "InjectedFault",
    "InjectedOOM",
    "ReplicaWatchdog",
    "LatencyTracker",
    "MetricsServer",
    "MicroBatch",
    "MicroBatcher",
    "Request",
    "ServeEngine",
    "ServingCluster",
    "Span",
    "Tracer",
    "VisionEngine",
    "VisionRequest",
    "build_serve_step",
    "capture_cost",
    "chrome_trace",
    "cluster_healthz",
    "hist_percentile",
    "is_oom_error",
    "make_tracer",
    "memory_watermark",
    "normalize_cost_analysis",
    "parse_program_key",
    "program_perf",
    "read_jsonl",
    "replica_meshes",
    "serve_cluster_metrics",
    "synth_requests",
    "validate_chrome_trace",
    "validate_request_timelines",
    "write_chrome_trace",
]
