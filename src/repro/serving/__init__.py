from repro.serving.engine import ServeEngine, build_serve_step

__all__ = ["ServeEngine", "build_serve_step"]
