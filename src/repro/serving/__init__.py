from repro.serving.autoscaler import Autoscaler
from repro.serving.cluster import ServingCluster, replica_meshes
from repro.serving.engine import Request, ServeEngine, build_serve_step
from repro.serving.metrics import (
    ClusterMetrics,
    EngineMetrics,
    LatencyTracker,
    hist_percentile,
)
from repro.serving.replica import EngineReplica
from repro.serving.scheduler import Backpressure, MicroBatch, MicroBatcher
from repro.serving.vision import VisionEngine, VisionRequest, synth_requests

__all__ = [
    "Autoscaler",
    "Backpressure",
    "ClusterMetrics",
    "EngineMetrics",
    "EngineReplica",
    "LatencyTracker",
    "MicroBatch",
    "MicroBatcher",
    "Request",
    "ServeEngine",
    "ServingCluster",
    "VisionEngine",
    "VisionRequest",
    "build_serve_step",
    "hist_percentile",
    "replica_meshes",
    "synth_requests",
]
