"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract).

These are the ground truth the kernel tests ``assert_allclose`` against, and
the CPU execution path for tests/benchmarks/dry-run lowering.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.quant.softmax_quant import logsqrt2_dequantize

LOG2E = 1.4426950408889634  # log2(e)


# ---------------------------------------------------------------------------
# Streaming (flash-style) attention oracle — mirrors kernels/quant_attention.py
# ---------------------------------------------------------------------------

def flash_attention_ref(
    q: jnp.ndarray,  # [B, Sq, H, hd]
    k: jnp.ndarray,  # [B, Sk, KVH, hd] (GQA native; KVH divides H)
    v: jnp.ndarray,  # [B, Sk, KVH, hd]
    *,
    causal: bool = True,
    q_offset=0,  # absolute position of q[0] (decode: cache index; traceable)
    quant_bits: int = 0,
    logit_softcap: float = 0.0,
    local_window: int = 0,
    k_scale: Optional[jnp.ndarray] = None,  # [B, Sk, KVH] int8-KV dequant scales
    v_scale: Optional[jnp.ndarray] = None,
    kv_valid_len: Optional[jnp.ndarray] = None,  # [B] cache fill level
    q_segment_ids: Optional[jnp.ndarray] = None,  # [B, Sq] packed-prefill ids
    kv_segment_ids: Optional[jnp.ndarray] = None,  # [B, Sk]
) -> jnp.ndarray:
    """The single attention oracle: GQA, local windows, softcap, log-sqrt2
    quantized softmax numerator (paper sections 3.2/4.3), int8 KV dequant.

    ``q_segment_ids``/``kv_segment_ids`` (packed variable-length prefill,
    DESIGN.md section 10): positions attend only where the ids are equal, so
    N prompts concatenated in one batch row never see each other. Causality
    and local windows then operate on *buffer* indices, which inside a
    contiguous segment equal within-segment distances."""
    B, Sq, H, hd = q.shape
    Sk, KVH = k.shape[1], k.shape[2]
    G = H // KVH
    qg = q.reshape(B, Sq, KVH, G, hd)
    # Never materialize an f32 copy of the K/V cache: the QK^T einsum takes
    # the cache dtype directly and accumulates in f32 (what the Pallas
    # kernel does per-tile in VMEM). An explicit astype here doubles the
    # per-layer cache HBM traffic at the XLA level (EXPERIMENTS.md
    # section Perf, iteration 2).
    if k_scale is not None:
        # int8 cache: fold the per-position dequant scale into the scores
        # (cheaper than scaling K: [B,S,KVH] vs [B,S,KVH,hd])
        scores = jnp.einsum(
            "bqkgh,bskh->bkgqs", qg, k,
            preferred_element_type=jnp.float32,
        ) * k_scale.transpose(0, 2, 1)[:, :, None, None, :] / math.sqrt(hd)
    else:
        scores = jnp.einsum(
            "bqkgh,bskh->bkgqs", qg, k,
            preferred_element_type=jnp.float32,
        ) / math.sqrt(hd)
    if logit_softcap > 0:
        scores = logit_softcap * jnp.tanh(scores / logit_softcap)
    # q_offset: scalar, or [B] (continuous batching: per-slot positions)
    off = jnp.broadcast_to(jnp.asarray(q_offset, jnp.int32), (B,))
    qpos = off[:, None] + jnp.arange(Sq)  # [B, Sq]
    kpos = jnp.arange(Sk)
    ok = jnp.ones((B, Sq, Sk), bool)
    if causal:
        ok &= kpos[None, None, :] <= qpos[:, :, None]
    if local_window > 0:
        ok &= qpos[:, :, None] - kpos[None, None, :] < local_window
    if q_segment_ids is not None:
        kv_seg = kv_segment_ids if kv_segment_ids is not None else q_segment_ids
        ok &= q_segment_ids[:, :, None] == kv_seg[:, None, :]
    mask = ok[:, None, None]  # [B,1,1,Sq,Sk]
    if kv_valid_len is not None:
        valid = kpos[None, :] < kv_valid_len[:, None]  # [B, Sk]
        mask = mask & valid[:, None, None, None, :]
    scores = jnp.where(mask, scores, -jnp.inf)
    m = jnp.maximum(jnp.max(scores, axis=-1, keepdims=True), -1e30)
    f = jnp.exp(scores - m)
    l = jnp.sum(f, axis=-1, keepdims=True)
    if quant_bits > 0:
        # Eq. 18 in affine-code form: -2 log2(exp(s - m)) == -2 log2(e) (s - m)
        # (what the kernel computes: no log needed, one fma per logit).
        # Structural (-inf) mask positions are exactly zero — the FPGA PEs
        # simply never stream those K blocks; the clip ceiling only applies
        # to *in-range* small probabilities (paper section 3.2 semantics).
        codes = jnp.clip(
            jnp.round(-2.0 * LOG2E * (scores - m)), 0, 2**quant_bits - 1
        )
        f = jnp.where(mask, logsqrt2_dequantize(codes.astype(jnp.int32)), 0.0)
    if v_scale is not None:
        # fold the V dequant scale into the probabilities (f: [B,KVH,G,Sq,Sk])
        f = f * v_scale.transpose(0, 2, 1)[:, :, None, None, :]
    out = jnp.einsum(
        "bkgqs,bskh->bqkgh", f.astype(v.dtype) if v.dtype != jnp.int8 else f,
        v if v.dtype != jnp.int8 else v.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    ) / jnp.maximum(l.transpose(0, 3, 1, 2, 4), 1e-30)
    return out.reshape(B, Sq, H, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# Unified sparse/dense grouped matmul oracle — mirrors kernels/expert_linear.py
# ---------------------------------------------------------------------------

def grouped_matmul_ref(
    x: jnp.ndarray,  # [T, Din] rows sorted by group
    w: jnp.ndarray,  # [G, Din, Dout]
    group_sizes: jnp.ndarray,  # [G] int32, sum == T
) -> jnp.ndarray:
    """Row t multiplies the weight of its group: y[t] = x[t] @ w[g(t)]."""
    T = x.shape[0]
    ends = jnp.cumsum(group_sizes)
    seg = jnp.searchsorted(ends, jnp.arange(T), side="right")  # [T] group ids
    w_per_row = w[seg]  # [T, Din, Dout] (oracle only; never materialized on TPU)
    return jnp.einsum("td,tdf->tf", x.astype(jnp.float32),
                      w_per_row.astype(jnp.float32)).astype(x.dtype)


def grouped_matmul_q_ref(
    x_q: jnp.ndarray,  # int8 [T, Din] rows sorted by group
    w_q: jnp.ndarray,  # int8 [G, Din, Dout]
    group_sizes: jnp.ndarray,  # [G] int32, sum == T
    w_scale: jnp.ndarray,  # f32 [G, Dout] per-expert per-channel dequant
    a_scale: Optional[jnp.ndarray] = None,  # f32 scalar activation dequant
) -> jnp.ndarray:
    """int8 grouped oracle: exact int32 accumulate, then the Eq. 9
    product-of-scales rescale (per-expert per-channel x per-tensor)."""
    T = x_q.shape[0]
    ends = jnp.cumsum(group_sizes)
    seg = jnp.searchsorted(ends, jnp.arange(T), side="right")  # [T] group ids
    acc = jnp.einsum(
        "td,tdf->tf", x_q.astype(jnp.int32), w_q[seg].astype(jnp.int32)
    )  # oracle only: the int8 gather is never materialized on TPU
    y = acc.astype(jnp.float32) * w_scale[seg]
    if a_scale is not None:
        y = y * a_scale
    return y


def grouped_matmul_q4_ref(
    x_q: jnp.ndarray,  # int8 [T, Din] rows sorted by group
    w_packed: jnp.ndarray,  # uint8 [G, ceil(Din/2), Dout] nibble-packed int4
    group_sizes: jnp.ndarray,  # [G] int32, sum == T
    w_scale: jnp.ndarray,  # f32 [G, Dout] per-expert per-channel dequant
    a_scale: Optional[jnp.ndarray] = None,  # f32 scalar activation dequant
) -> jnp.ndarray:
    """Nibble-packed int4 grouped oracle (W4A8): unpack to int4 values held
    in int8, then the exact-int32-accumulate int8 oracle — the bit-exactness
    ground truth for the packed Pallas path (DESIGN.md section 13)."""
    from repro.core.quant.qtypes import unpack_int4

    w_q = unpack_int4(w_packed, x_q.shape[1])
    return grouped_matmul_q_ref(x_q, w_q, group_sizes, w_scale, a_scale)


def grouped_mlp_ref(
    x: jnp.ndarray,  # [T, D] sorted by group
    wi: jnp.ndarray,  # [G, D, Dh]  (Dh = 2*ff for GLU)
    wo: jnp.ndarray,  # [G, ff, D]
    group_sizes: jnp.ndarray,
    act: str = "silu",
    glu: bool = True,
) -> jnp.ndarray:
    from repro.models.layers import act_fn  # local import avoids cycle

    h = grouped_matmul_ref(x, wi, group_sizes)
    if glu:
        g, u = jnp.split(h, 2, axis=-1)
        h = act_fn(act)(g) * u
    else:
        h = act_fn(act)(h)
    return grouped_matmul_ref(h, wo, group_sizes)


# ---------------------------------------------------------------------------
# Selective-scan oracle — mirrors kernels/selective_scan.py
# ---------------------------------------------------------------------------

def selective_scan_ref(
    x: jnp.ndarray,  # [B, S, di]
    dt: jnp.ndarray,  # [B, S, di] (post-softplus)
    b: jnp.ndarray,  # [B, S, N]
    c: jnp.ndarray,  # [B, S, N]
    a: jnp.ndarray,  # [di, N] negative decay rates
    d: jnp.ndarray,  # [di]
) -> jnp.ndarray:
    """h_t = exp(dt_t a) h_{t-1} + (dt_t x_t) B_t;  y_t = h_t C_t + D x_t."""
    decay = jnp.exp(dt[..., None].astype(jnp.float32) * a)  # [B,S,di,N]
    u = (dt * x)[..., None].astype(jnp.float32) * b[:, :, None, :].astype(jnp.float32)

    def op(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    _, h = jax.lax.associative_scan(op, (decay, u), axis=1)
    y = jnp.einsum("bsdn,bsn->bsd", h, c.astype(jnp.float32))
    return (y + x.astype(jnp.float32) * d).astype(x.dtype)


# ---------------------------------------------------------------------------
# INT8 tiled matmul oracle — mirrors kernels/int8_matmul.py
# ---------------------------------------------------------------------------

def int8_matmul_ref(
    x_q: jnp.ndarray,  # int8 [M, K]
    w_q: jnp.ndarray,  # int8 [K, N]
    x_scale: jnp.ndarray,  # f32 scalar
    w_scale: jnp.ndarray,  # f32 [N] per-output-channel
    bias: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    acc = jnp.matmul(x_q.astype(jnp.int32), w_q.astype(jnp.int32))
    y = acc.astype(jnp.float32) * (x_scale * w_scale)
    if bias is not None:
        y = y + bias
    return y
