"""Pallas TPU tiled W8A8 matmul (paper Eqs. 7/9).

int8 x int8 -> int32 tiles accumulate on the MXU's int8 datapath (2x bf16
throughput on TPU — the MXU analogue of the paper's INT8 DSP packing); the
single product-of-scales rescale of Eq. 9 (per-tensor activation scale x
per-output-channel weight scale) is applied once on the int32 accumulator at
the flush, exactly as the FPGA design applies it once after the systolic
array. Bias add is fused into the same flush.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _int8_mm_kernel(
    x_ref,  # [bm, bk] int8
    w_ref,  # [bk, bn] int8
    xs_ref,  # [1, 1] f32 per-tensor activation scale
    ws_ref,  # [1, bn] f32 per-channel weight scale
    *rest,  # (bias_ref?, o_ref, acc)
    n_k: int,
    has_bias: bool,
):
    if has_bias:
        b_ref, o_ref, acc = rest
    else:
        b_ref = None
        o_ref, acc = rest
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    acc[...] += jax.lax.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.int32
    )

    @pl.when(ik == n_k - 1)
    def _flush():
        y = acc[...].astype(jnp.float32) * (xs_ref[0, 0] * ws_ref[0][None, :])
        if has_bias:
            y = y + b_ref[0][None, :]
        o_ref[...] = y.astype(o_ref.dtype)


def int8_matmul(
    x_q: jnp.ndarray,  # int8 [M, K]
    w_q: jnp.ndarray,  # int8 [K, N]
    x_scale: jnp.ndarray,  # f32 scalar
    w_scale: jnp.ndarray,  # f32 [N]
    bias: Optional[jnp.ndarray] = None,  # f32 [N]
    *,
    block_m: int = 256,
    block_n: int = 256,
    block_k: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    M, K = x_q.shape
    _, N = w_q.shape
    block_m = min(block_m, max(M, 1))
    block_n = min(block_n, N)
    block_k = min(block_k, K)
    n_m, n_n, n_k = pl.cdiv(M, block_m), pl.cdiv(N, block_n), pl.cdiv(K, block_k)
    mp, np_, kp = n_m * block_m, n_n * block_n, n_k * block_k

    xp = jnp.pad(x_q, ((0, mp - M), (0, kp - K)))
    wp = jnp.pad(w_q, ((0, kp - K), (0, np_ - N)))
    xs = jnp.asarray(x_scale, jnp.float32).reshape(1, 1)
    ws = jnp.pad(w_scale.astype(jnp.float32).reshape(1, -1), ((0, 0), (0, np_ - N)))
    has_bias = bias is not None

    in_specs = [
        pl.BlockSpec((block_m, block_k), lambda m, n, k: (m, k)),
        pl.BlockSpec((block_k, block_n), lambda m, n, k: (k, n)),
        pl.BlockSpec((1, 1), lambda m, n, k: (0, 0), memory_space=pltpu.SMEM),
        pl.BlockSpec((1, block_n), lambda m, n, k: (0, n)),
    ]
    args = [xp, wp, xs, ws]
    if has_bias:
        bp = jnp.pad(bias.astype(jnp.float32).reshape(1, -1), ((0, 0), (0, np_ - N)))
        in_specs.append(pl.BlockSpec((1, block_n), lambda m, n, k: (0, n)))
        args.append(bp)

    out = pl.pallas_call(
        functools.partial(_int8_mm_kernel, n_k=n_k, has_bias=has_bias),
        grid=(n_m, n_n, n_k),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_m, block_n), lambda m, n, k: (m, n)),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.int32)],
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=interpret,
    )(*args)
    return out[:M, :N]
