"""Pallas TPU selective-scan kernel (Mamba-1) — the SSM hot-spot.

The XLA-level chunked associative scan must materialize the discretized
states ``[B, C, d_inner, N]`` at every fusion boundary (the dominant memory
term of the falcon-mamba train/prefill cells — EXPERIMENTS.md §Roofline).
This kernel is the TPU-native fix: the recurrent state ``h [bd, N]`` lives
in VMEM scratch for the whole sequence; HBM sees only the streamed inputs
``x/dt`` ([S, bd]) and ``B/C`` ([S, N]) plus the output — O(S·d) traffic
instead of O(S·d·N).

Grid: (B, d_inner/bd, S/bs) with the sequence dim innermost (sequential on
TPU, so the scratch state carries across S blocks). Inside a block the
recurrence steps row-by-row with a fori_loop: h = exp(dt·A)·h + (dt·x)⊗B;
y_t = h·C_t + D·x_t.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scan_kernel(
    x_ref,  # [1, bs, bd]
    dt_ref,  # [1, bs, bd]
    b_ref,  # [1, bs, N]
    c_ref,  # [1, bs, N]
    a_ref,  # [bd, N]
    d_ref,  # [1, bd]
    o_ref,  # [1, bs, bd]
    hout_ref,  # [1, bd, N] final state (for prefill -> decode handoff)
    h_s,  # scratch [bd, N] f32
    y_s,  # scratch [bs, bd] f32
    *,
    block_s: int,
):
    i_s = pl.program_id(2)
    n_s = pl.num_programs(2)

    @pl.when(i_s == 0)
    def _init():
        h_s[...] = jnp.zeros_like(h_s)

    a = a_ref[...]  # [bd, N] (negative)
    x = x_ref[0].astype(jnp.float32)  # [bs, bd]
    dt = dt_ref[0].astype(jnp.float32)
    bb = b_ref[0].astype(jnp.float32)  # [bs, N]
    cc = c_ref[0].astype(jnp.float32)

    def step(t, h):
        dt_t = dt[t][:, None]  # [bd, 1]
        decay = jnp.exp(dt_t * a)  # [bd, N]
        u = (dt[t] * x[t])[:, None] * bb[t][None, :]  # [bd, N]
        h = decay * h + u
        y_s[t, :] = h @ cc[t]  # [bd]
        return h

    h = jax.lax.fori_loop(0, block_s, step, h_s[...])
    h_s[...] = h
    o_ref[0] = (y_s[...] + x * d_ref[0][None, :]).astype(o_ref.dtype)

    @pl.when(i_s == n_s - 1)
    def _emit_state():
        hout_ref[0] = h_s[...]


def selective_scan(
    x: jnp.ndarray,  # [B, S, di]
    dt: jnp.ndarray,  # [B, S, di] (post-softplus)
    b: jnp.ndarray,  # [B, S, N]
    c: jnp.ndarray,  # [B, S, N]
    a: jnp.ndarray,  # [di, N] (negative decay rates)
    d: jnp.ndarray,  # [di] skip weight
    *,
    block_s: int = 128,
    block_d: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    B, S, di = x.shape
    N = b.shape[-1]
    block_s = min(block_s, S)
    block_d = min(block_d, di)
    n_s = pl.cdiv(S, block_s)
    n_d = pl.cdiv(di, block_d)
    s_pad = n_s * block_s
    if s_pad != S:
        # pad with dt=0 (identity decay, zero input — exact no-op steps)
        pad = ((0, 0), (0, s_pad - S), (0, 0))
        x, dt = jnp.pad(x, pad), jnp.pad(dt, pad)
        b, c = jnp.pad(b, pad), jnp.pad(c, pad)
    assert di % block_d == 0, (di, block_d)

    grid = (B, n_d, n_s)
    out = pl.pallas_call(
        functools.partial(_scan_kernel, block_s=block_s),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_s, block_d), lambda ib, id_, is_: (ib, is_, id_)),
            pl.BlockSpec((1, block_s, block_d), lambda ib, id_, is_: (ib, is_, id_)),
            pl.BlockSpec((1, block_s, N), lambda ib, id_, is_: (ib, is_, 0)),
            pl.BlockSpec((1, block_s, N), lambda ib, id_, is_: (ib, is_, 0)),
            pl.BlockSpec((block_d, N), lambda ib, id_, is_: (id_, 0)),
            pl.BlockSpec((1, block_d), lambda ib, id_, is_: (0, id_)),
        ],
        out_specs=[
            pl.BlockSpec(
                (1, block_s, block_d), lambda ib, id_, is_: (ib, is_, id_)
            ),
            pl.BlockSpec((1, block_d, N), lambda ib, id_, is_: (ib, id_, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_d, N), jnp.float32),
            pltpu.VMEM((block_s, block_d), jnp.float32),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, s_pad, di), x.dtype),
            jax.ShapeDtypeStruct((B, di, N), jnp.float32),
        ],
        interpret=interpret,
    )(x, dt, b, c, a, d.reshape(1, -1))
    y, h_last = out
    return y[:, :S], h_last
