"""Kernel tile autotuner — per-device search with persistent tuning tables
(DESIGN.md section 9).

CoQMoE re-synthesizes its FPGA accelerator per deployment to balance latency
against the resource budget (section 4); the TPU analogue is picking Pallas
tile sizes per (shape bucket, dtype, device kind). Auto-ViT-Acc (PAPERS.md)
shows automatic hardware-aware search over acceleration configs beats
hand-tuned ones — here the search space is the ``(block_m, block_n)`` grid
of ``grouped_matmul`` and the ``(block_q, block_k)`` grid of
``streaming_attention``.

Pipeline (engine ``warmup()`` drives it, before admission opens):

  1. **collect** — the replica's programs are traced abstractly
     (``jax.eval_shape``: no compile, no device work); every
     ``kernels.ops`` dispatch records the shape-bucket key it would look
     up (tokens/sequence lengths bucket to the next power of two, so one
     entry covers a range of runtime shapes);
  2. **sweep** — for each key missing from the table, legal candidate tile
     configs are benchmarked on the actual device (default config is
     always candidate #1, so the winner is never slower than the default);
     on CPU / interpret backends there is nothing meaningful to time and
     the key is filled with the deterministic default tiles;
  3. **persist** — winners land in a versioned JSON table keyed by device
     kind (one file per kind under ``AutotuneConfig.cache_dir``). A later
     ``ensure_tuned`` on the same device kind is a pure cache hit: zero
     re-sweep. Stale (kernel-version bump), corrupt, or
     foreign-device tables are discarded gracefully — the tuner never
     fails a serving launch, it falls back to defaults.

At serving time ``kernels.ops`` consults the ambient active table at trace
time (tile sizes are jit-static); a lookup miss costs nothing but the
default tiles — sweeps only ever run inside ``ensure_tuned``.
"""
from __future__ import annotations

import contextlib
import json
import os
import re
import time
from typing import Callable, Dict, Iterable, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import AutotuneConfig
from repro.kernels.expert_linear import legal_gmm_blocks
from repro.kernels.quant_attention import legal_attn_blocks

# Bumped when a kernel's tiling/legality logic changes (the sublane/lane
# clamp-rounding fix shipped as version 2; nibble-packed int4 weights and
# the ``pk`` key facet shipped as grouped_matmul version 3): entries swept
# against an older kernel are dropped at load so a tuned table can never
# pin obsolete tiles.
KERNEL_VERSIONS: Dict[str, int] = {
    "grouped_matmul": 3,
    "streaming_attention": 2,
}
TABLE_VERSION = 1

GMM_DEFAULT = (128, 128)  # the former hard-coded expert_linear tiles
ATTN_DEFAULT = (128, 256)  # the former hard-coded quant_attention tiles

# candidate grids (clamped + legal-rounded per shape before timing)
_GMM_BLOCK_M = (32, 64, 128, 256, 512)
_GMM_BLOCK_N = (128, 256, 512)
_ATTN_BLOCK_Q = (32, 64, 128, 256)
_ATTN_BLOCK_K = (128, 256, 512)

_VMEM_BUDGET = 12 * 1024 * 1024  # leave headroom under the ~16 MB/core VMEM


# ---------------------------------------------------------------------------
# Shape-bucket keys
# ---------------------------------------------------------------------------

def bucket_pow2(n: int, lo: int = 8, hi: int = 1 << 20) -> int:
    """Next power of two >= n, clamped to [lo, hi] — one tuning entry
    covers every runtime shape that rounds to the same bucket."""
    n = max(int(n), 1)
    b = 1
    while b < n:
        b <<= 1
    return max(lo, min(b, hi))


class TuneRequest(NamedTuple):
    """One (kernel, shape-bucket) tuning unit. ``params`` is a sorted
    tuple of (name, value) pairs — everything needed to synthesize sweep
    inputs and to rebuild the entry key deterministically."""

    kernel: str
    params: Tuple[Tuple[str, object], ...]

    @property
    def key(self) -> str:
        parts = [f"{k}={v}" for k, v in self.params]
        return "|".join([self.kernel] + parts)

    def get(self, name: str):
        return dict(self.params)[name]


def _dt(dtype) -> str:
    return jnp.dtype(dtype).name


def gmm_request(T: int, G: int, Din: int, Dout: int, *, x_dtype, w_dtype,
                scaled: bool, ascaled: bool) -> TuneRequest:
    # ``din`` is always the LOGICAL input dim (== x.shape[1]); ``pk`` marks
    # nibble-packed int4 weights (uint8 storage, rows = ceil(din/2)) so the
    # packed and int8 paths can never share a tuning entry even though the
    # wdt facet already differs — the packed layout is part of the key
    # contract (DESIGN.md sections 9/13).
    packed = jnp.dtype(w_dtype) == jnp.uint8
    return TuneRequest("grouped_matmul", (
        ("T", bucket_pow2(T)),
        ("G", int(G)),
        ("din", int(Din)),
        ("dout", int(Dout)),
        ("xdt", _dt(x_dtype)),
        ("wdt", _dt(w_dtype)),
        ("ws", int(bool(scaled))),
        ("as", int(bool(ascaled))),
        ("pk", int(packed)),
    ))


def attn_request(B: int, H: int, KVH: int, hd: int, Sq: int, Sk: int, *,
                 causal: bool, quant_bits: int, scaled: bool,
                 q_dtype, k_dtype, local_window: int = 0) -> TuneRequest:
    return TuneRequest("streaming_attention", (
        ("B", bucket_pow2(B, lo=1)),
        ("H", int(H)),
        ("kvh", int(KVH)),
        ("hd", int(hd)),
        ("sq", bucket_pow2(Sq, lo=1)),
        ("sk", bucket_pow2(Sk, lo=8)),
        ("causal", int(bool(causal))),
        # the sliding window changes which K tiles a Q tile visits
        # (block-level skip), so it is a tile-choice facet; it is a config
        # constant, not a runtime shape — no bucketing
        ("lw", int(local_window)),
        ("qb", int(quant_bits)),
        ("ks", int(bool(scaled))),
        ("qdt", _dt(q_dtype)),
        ("kdt", _dt(k_dtype)),
    ))


# ---------------------------------------------------------------------------
# Candidate grids (legal, deduped, default first)
# ---------------------------------------------------------------------------

def _bytes(dtype) -> int:
    return jnp.dtype(dtype).itemsize


def gmm_candidates(req: TuneRequest) -> List[Tuple[int, int]]:
    """Effective (block_m, block_n) candidates for one grouped_matmul key:
    clamp-rounded to the problem, VMEM-bounded, deduped; the effective
    default config is always first."""
    T, Din, Dout = req.get("T"), req.get("din"), req.get("dout")
    xdt = jnp.dtype(req.get("xdt"))
    xb, wb = _bytes(req.get("xdt")), _bytes(req.get("wdt"))
    out: List[Tuple[int, int]] = []
    seen = set()
    for bm, bn in [GMM_DEFAULT] + [
        (m, n) for m in _GMM_BLOCK_M for n in _GMM_BLOCK_N
    ]:
        eff = legal_gmm_blocks(bm, bn, T, Dout, xdt)
        if eff in seen:
            continue
        # resident tiles: x [bm, Din] + w [Din, bn] + f32 acc/out [bm, bn]
        # (packed int4: the w tile holds ceil(Din/2) nibble-pair rows).
        # The default (first) candidate is exempt: it is what an untuned
        # process runs, so it must stay in the sweep as the baseline —
        # dropping it would let a "tuned" pick be slower than untuned.
        w_rows = -(-Din // 2) if req.get("pk") else Din
        vmem = (eff[0] * Din * xb + w_rows * eff[1] * wb
                + 2 * eff[0] * eff[1] * 4)
        if out and vmem > _VMEM_BUDGET:
            continue
        seen.add(eff)
        out.append(eff)
    return out


def attn_candidates(req: TuneRequest) -> List[Tuple[int, int]]:
    """Effective (block_q, block_k) candidates for one attention key."""
    Sq, Sk, hd = req.get("sq"), req.get("sk"), req.get("hd")
    qdt, kdt = jnp.dtype(req.get("qdt")), jnp.dtype(req.get("kdt"))
    out: List[Tuple[int, int]] = []
    seen = set()
    for bq, bk in [ATTN_DEFAULT] + [
        (q, k) for q in _ATTN_BLOCK_Q for k in _ATTN_BLOCK_K
    ]:
        eff = legal_attn_blocks(bq, bk, Sq, Sk, qdt)
        if eff in seen:
            continue
        # q tile + k/v tiles + m/l scratch (bq, 128) + acc (bq, hd), all f32
        # in-kernel plus the dtype-sized HBM tiles; the default (first)
        # candidate is exempt — see gmm_candidates
        vmem = (eff[0] * hd * 4 + 2 * eff[1] * hd * max(4, kdt.itemsize)
                + 2 * eff[0] * 128 * 4 + eff[0] * hd * 4)
        if out and vmem > _VMEM_BUDGET:
            continue
        seen.add(eff)
        out.append(eff)
    return out


def candidates_for(req: TuneRequest) -> List[Tuple[int, int]]:
    if req.kernel == "grouped_matmul":
        return gmm_candidates(req)
    if req.kernel == "streaming_attention":
        return attn_candidates(req)
    raise KeyError(f"unknown kernel {req.kernel!r}")


def default_blocks_for(req: TuneRequest) -> Tuple[int, int]:
    return candidates_for(req)[0]


# ---------------------------------------------------------------------------
# Tuning table (persistent, versioned, per device kind)
# ---------------------------------------------------------------------------

def device_kind() -> str:
    d = jax.devices()[0]
    return getattr(d, "device_kind", None) or d.platform


def _sanitize(kind: str) -> str:
    return re.sub(r"[^A-Za-z0-9_.-]+", "-", kind).strip("-") or "unknown"


def table_path(cfg: AutotuneConfig, kind: Optional[str] = None) -> str:
    base = cfg.cache_dir or os.environ.get("REPRO_AUTOTUNE_CACHE",
                                           ".repro_autotune")
    return os.path.join(base, f"autotune_{_sanitize(kind or device_kind())}.json")


class TuningTable:
    """In-memory tuning table bound to one device kind + cache file.

    ``entries`` maps the key string to
    ``{"blocks": [a, b], "ms": float|None, "source": "swept"|"default"|
    "override"}``. ``stats`` counts lookup ``hits``/``misses`` and
    ``swept`` (new entries created) — the cache-hit acceptance check is
    "a second warmup leaves ``swept`` unchanged"."""

    def __init__(self, kind: str, path: Optional[str] = None) -> None:
        self.device_kind = kind
        self.path = path
        self.entries: Dict[str, dict] = {}
        self.stats = {"hits": 0, "misses": 0, "swept": 0}
        self.dirty = False

    # -- lookups ------------------------------------------------------------

    def lookup(self, key: str) -> Optional[Tuple[int, int]]:
        e = self.entries.get(key)
        if e is None:
            self.stats["misses"] += 1
            return None
        self.stats["hits"] += 1
        return tuple(e["blocks"])  # type: ignore[return-value]

    def get(self, key: str) -> Optional[dict]:
        return self.entries.get(key)

    def put(self, key: str, blocks: Tuple[int, int], ms: Optional[float],
            source: str) -> None:
        entry = {"blocks": [int(blocks[0]), int(blocks[1])],
                 "ms": None if ms is None else float(ms),
                 "source": source}
        if self.entries.get(key) != entry:
            self.entries[key] = entry
            self.dirty = True

    # -- persistence --------------------------------------------------------

    def to_json(self) -> dict:
        return {
            "table_version": TABLE_VERSION,
            "device_kind": self.device_kind,
            "kernel_versions": dict(KERNEL_VERSIONS),
            "entries": self.entries,
        }

    def save(self, path: Optional[str] = None) -> str:
        path = path or self.path
        assert path, "tuning table has no cache path"
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(self.to_json(), f, indent=1, sort_keys=True)
        os.replace(tmp, path)
        self.dirty = False
        return path

    @classmethod
    def load(cls, path: Optional[str], kind: str) -> "TuningTable":
        """Load a table, discarding anything unusable: a corrupt file, a
        version or device-kind mismatch, stale per-kernel entries, or
        malformed blocks. Never raises — worst case is an empty table
        (deterministic default tiles)."""
        table = cls(kind, path)
        if not path or not os.path.exists(path):
            return table
        try:
            with open(path) as f:
                raw = json.load(f)
        except (OSError, ValueError):
            return table
        if not isinstance(raw, dict):
            return table
        if raw.get("table_version") != TABLE_VERSION:
            return table
        if raw.get("device_kind") != kind:
            return table
        file_kv = raw.get("kernel_versions") or {}
        for key, entry in (raw.get("entries") or {}).items():
            kernel = str(key).split("|", 1)[0]
            if file_kv.get(kernel) != KERNEL_VERSIONS.get(kernel):
                continue  # swept against an older kernel: stale
            try:
                blocks = [int(entry["blocks"][0]), int(entry["blocks"][1])]
                ms = entry.get("ms")
                source = str(entry.get("source", "swept"))
            except (TypeError, KeyError, IndexError, ValueError):
                continue
            table.entries[key] = {
                "blocks": blocks,
                "ms": None if ms is None else float(ms),
                "source": source,
            }
        return table


# ---------------------------------------------------------------------------
# Ambient state: active table + collection scope
# ---------------------------------------------------------------------------

_ACTIVE: Optional[TuningTable] = None
_COLLECT: Optional[Dict[str, TuneRequest]] = None


def active_table() -> Optional[TuningTable]:
    return _ACTIVE


def activate(table: Optional[TuningTable]) -> None:
    """Install (or clear, with None) the process-wide active table —
    consulted by every ``kernels.ops`` dispatch at trace time."""
    global _ACTIVE
    _ACTIVE = table


deactivate = lambda: activate(None)  # noqa: E731 — test/teardown sugar


@contextlib.contextmanager
def collecting():
    """Scope in which ops dispatches *record* the tuning keys they would
    look up (used around ``jax.eval_shape`` traces of replica programs).
    Yields the key -> TuneRequest dict being filled."""
    global _COLLECT
    prev, _COLLECT = _COLLECT, {}
    try:
        yield _COLLECT
    finally:
        keys, _COLLECT = _COLLECT, prev
        if prev is not None:
            prev.update(keys)  # nested scopes fold outward


def _resolve(req: TuneRequest, default: Tuple[int, int]) -> Tuple[int, int]:
    if _COLLECT is not None:
        _COLLECT.setdefault(req.key, req)
    if _ACTIVE is None:
        return default
    return _ACTIVE.lookup(req.key) or default


def gmm_blocks(T: int, G: int, Din: int, Dout: int, *, x_dtype, w_dtype,
               scaled: bool, ascaled: bool) -> Tuple[int, int]:
    """Tile config for one grouped_matmul dispatch: the tuned entry when
    the active table has this shape bucket, the defaults otherwise."""
    req = gmm_request(T, G, Din, Dout, x_dtype=x_dtype, w_dtype=w_dtype,
                      scaled=scaled, ascaled=ascaled)
    return _resolve(req, GMM_DEFAULT)


def attn_blocks(B: int, H: int, KVH: int, hd: int, Sq: int, Sk: int, *,
                causal: bool, quant_bits: int, scaled: bool,
                q_dtype, k_dtype, local_window: int = 0) -> Tuple[int, int]:
    """Tile config for one streaming_attention dispatch (see gmm_blocks)."""
    req = attn_request(B, H, KVH, hd, Sq, Sk, causal=causal,
                       quant_bits=quant_bits, scaled=scaled,
                       q_dtype=q_dtype, k_dtype=k_dtype,
                       local_window=local_window)
    return _resolve(req, ATTN_DEFAULT)


# ---------------------------------------------------------------------------
# Sweeping
# ---------------------------------------------------------------------------

def _mode() -> str:
    from repro.kernels.ops import _mode as m

    return m()


def should_time() -> bool:
    """Real timing only makes sense on the compiled TPU path; interpret
    mode is a python emulation and the ref path ignores tiles entirely."""
    return jax.default_backend() == "tpu" and _mode() == "pallas"


def _balanced_sizes(T: int, G: int) -> jnp.ndarray:
    base = T // G
    sizes = [base] * G
    sizes[0] += T - base * G
    return jnp.asarray(sizes, jnp.int32)


def build_candidate(req: TuneRequest, blocks: Tuple[int, int], *,
                    interpret: bool = False) -> Callable[[], jax.Array]:
    """A zero-arg jitted callable running the kernel for this request at
    the given tiles, over synthetic operands (committed to device once)."""
    import functools

    if req.kernel == "grouped_matmul":
        from repro.kernels.expert_linear import grouped_matmul

        T, G = req.get("T"), req.get("G")
        Din, Dout = req.get("din"), req.get("dout")
        xdt, wdt = jnp.dtype(req.get("xdt")), jnp.dtype(req.get("wdt"))
        x = jnp.ones((T, Din), xdt)
        if req.get("pk"):  # nibble-packed int4: uint8 rows of ceil(Din/2)
            w = jnp.full((G, -(-Din // 2), Dout), 0x11, jnp.uint8)
        else:
            w = jnp.ones((G, Din, Dout), wdt)
        gs = _balanced_sizes(T, G)
        kw = dict(block_m=blocks[0], block_n=blocks[1], interpret=interpret)
        if req.get("ws"):
            kw["w_scale"] = jnp.ones((G, Dout), jnp.float32)
        if req.get("as"):
            kw["a_scale"] = jnp.float32(1.0)
        fn = jax.jit(functools.partial(grouped_matmul, **kw))
        return lambda: fn(x, w, gs)

    if req.kernel == "streaming_attention":
        from repro.kernels.quant_attention import streaming_attention

        B, H, KVH, hd = (req.get("B"), req.get("H"), req.get("kvh"),
                         req.get("hd"))
        Sq, Sk = req.get("sq"), req.get("sk")
        qdt, kdt = jnp.dtype(req.get("qdt")), jnp.dtype(req.get("kdt"))
        q = jnp.ones((B, Sq, H, hd), qdt)
        k = jnp.ones((B, Sk, KVH, hd), kdt)
        v = jnp.ones((B, Sk, KVH, hd), kdt)
        kw = dict(
            causal=bool(req.get("causal")), quant_bits=req.get("qb"),
            local_window=req.get("lw"),
            block_q=blocks[0], block_k=blocks[1], interpret=interpret,
        )
        if req.get("ks"):
            kw["k_scale"] = jnp.ones((B, Sk, KVH), jnp.float32)
            kw["v_scale"] = jnp.ones((B, Sk, KVH), jnp.float32)
        fn = jax.jit(functools.partial(streaming_attention, **kw))
        return lambda: fn(q, k, v)

    raise KeyError(f"unknown kernel {req.kernel!r}")


def wall_timer(fn: Callable[[], jax.Array], blocks: Tuple[int, int], *,
               reps: int = 5) -> float:
    """Median wall-time (ms) of ``fn`` after one untimed compile+run.

    ``blocks`` identifies the candidate being timed; the real timer does
    not need it, but it is part of the ``timer(fn, blocks, reps=)``
    injection contract so tests/benchmarks can rank candidates
    deterministically without executing them."""
    jax.block_until_ready(fn())  # compile + warm
    times = []
    for _ in range(max(1, reps)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        times.append((time.perf_counter() - t0) * 1e3)
    times.sort()
    return times[len(times) // 2]


def sweep_request(req: TuneRequest, cfg: AutotuneConfig, *,
                  timer=None, collect_all: bool = False):
    """Pick the fastest legal tile config for one tuning key.

    Returns the entry dict (``collect_all=True`` additionally returns the
    full ``[(blocks, ms), ...]`` candidate list, default first — the
    benchmark consumes it). ``timer(fn, blocks, reps=)`` can be injected
    (tests, benchmarks); with the default timer nothing is timed off-TPU
    and the entry is the deterministic default config."""
    cands = candidates_for(req)[: max(1, int(cfg.budget))]
    if timer is None and not should_time():
        entry = {"blocks": list(cands[0]), "ms": None, "source": "default"}
        return (entry, [(cands[0], None)]) if collect_all else entry
    timer = timer or wall_timer
    results: List[Tuple[Tuple[int, int], float]] = []
    for blocks in cands:
        try:
            ms = timer(build_candidate(req, blocks), blocks, reps=cfg.reps)
        except Exception:  # illegal on this hardware: skip the candidate
            continue
        results.append((blocks, float(ms)))
    if not results:  # even the default failed to time — fall back
        entry = {"blocks": list(cands[0]), "ms": None, "source": "default"}
        return (entry, [(cands[0], None)]) if collect_all else entry
    best = min(results, key=lambda r: r[1])
    entry = {"blocks": list(best[0]), "ms": best[1], "source": "swept"}
    return (entry, results) if collect_all else entry


# ---------------------------------------------------------------------------
# ensure_tuned — the warmup entry point
# ---------------------------------------------------------------------------

def _apply_overrides(table: TuningTable, cfg: AutotuneConfig) -> None:
    for key, blocks in cfg.overrides:
        table.put(str(key), (int(blocks[0]), int(blocks[1])), None,
                  "override")


def ensure_tuned(cfg: AutotuneConfig,
                 trace_fn: Optional[Callable[[], None]] = None, *,
                 timer=None) -> Optional[TuningTable]:
    """Load (or reuse) this device kind's tuning table, collect the keys
    ``trace_fn`` touches, sweep the missing ones, persist, and leave the
    table active for every subsequent kernel dispatch.

    Engine ``warmup()`` calls this once per replica before admission
    opens; the table is process-global and persisted per device kind, so
    the second replica (or a relaunch on the same device kind) is a pure
    cache hit — ``stats['swept']`` does not move."""
    global _ACTIVE
    if not cfg.enable:
        return _ACTIVE
    kind = device_kind()
    path = table_path(cfg, kind)
    if _ACTIVE is None or _ACTIVE.device_kind != kind \
            or _ACTIVE.path != path:
        _ACTIVE = TuningTable.load(path, kind)
    table = _ACTIVE
    _apply_overrides(table, cfg)
    if trace_fn is not None:
        with collecting() as reqs:
            trace_fn()
        for req in reqs.values():
            if table.get(req.key) is not None:
                table.stats["hits"] += 1
                continue
            entry = sweep_request(req, cfg, timer=timer)
            table.put(req.key, tuple(entry["blocks"]), entry["ms"],
                      entry["source"])
            table.stats["swept"] += 1
    if table.dirty and table.path:
        table.save()
    return table


def summary(table: Optional[TuningTable] = None) -> str:
    """One-line human summary for launchers."""
    t = table or _ACTIVE
    if t is None:
        return "autotune: inactive"
    swept = sum(1 for e in t.entries.values() if e["source"] == "swept")
    return (f"autotune[{t.device_kind}]: {len(t.entries)} entries "
            f"({swept} swept) hits={t.stats['hits']} "
            f"swept_now={t.stats['swept']} table={t.path}")
