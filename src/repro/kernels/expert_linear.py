"""Pallas TPU unified sparse/dense grouped matmul — CoQMoE section 4.2(b).

The paper deploys N_L compute units behind one round-robin router that
streams token tiles to CUs while each expert's weights are fetched from
off-chip exactly once per layer (temporal locality, Fig. 5(c)); a
runtime-reconfigurable selection policy switches the same hardware between
sparse (MoE expert) and dense (MLP) modes.

TPU-native realization: tokens arrive pre-sorted by expert id (the sort is
the router); the kernel walks *work items* = (group, m-tile) pairs built from
``group_sizes`` and streamed in via scalar prefetch. For each work item the
group's weight tile stays HBM-resident exactly as long as its token rows
need it — each expert's weights cross HBM->VMEM once per layer regardless of
token parallelism (the paper's O(1) weight-traffic property). Dense mode is
the same kernel with num_groups == 1.

Work-item construction (the "router table"): group g covers sorted rows
[start_g, end_g); it touches m-tiles floor(start/bm) .. floor((end-1)/bm).
Total work items <= nm + G (each group adds at most one partial tile), a
static bound, so the grid is static while the routing stays fully dynamic.

Grid is (n_tiles_n, n_work): n outer so all visits to one output tile are
consecutive; a VMEM accumulator carries partial sums across the (<=2) groups
sharing a tile and flushes on the last visit. Optional ``w_scale`` [G, N]
applies per-expert per-channel dequant (int8 expert weights) to each
partial before accumulation; optional ``a_scale`` (scalar, SMEM) applies the
per-tensor activation dequant once at the flush — together they realize the
single product-of-scales rescale of Eq. 9 on the int32 accumulator, so the
expert weights are never dequantized outside the kernel (the executable
QuantizedParams contract, DESIGN.md section 4).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANE = 128  # TPU vector-lane width (last tile dim)


def _round_up(n: int, m: int) -> int:
    return -(-int(n) // m) * m


def _sublane(dtype) -> int:
    """Min second-to-last tile dim for a dtype: 8 f32, 16 bf16, 32 int8
    (8 * packing factor vs 4-byte lanes)."""
    return 8 * max(1, 4 // jnp.dtype(dtype).itemsize)


def legal_gmm_blocks(block_m: int, block_n: int, T: int, Dout: int,
                     x_dtype=jnp.float32) -> Tuple[int, int]:
    """Clamp a requested (block_m, block_n) to the problem, then round UP
    to legal TPU tile multiples.

    A bare ``min(block_m, T)`` clamp yields TPU-illegal or wasteful tiny
    tiles (T=1 decode -> a 1-row m-tile); instead the clamped block rounds
    up to the x tile's sublane multiple (8 f32 / 16 bf16 / 32 int8 rows)
    and the lane multiple (128) — the kernel pads the operands to the
    rounded tile and slices the padding off, which is free, while the
    tile stays legal. The autotuner (kernels/autotune.py) uses the same
    function so its candidate grid and the kernel's effective tiles can
    never drift."""
    bm = _round_up(max(1, min(block_m, max(T, 1))), _sublane(x_dtype))
    bn = _round_up(max(1, min(block_n, max(Dout, 1))), LANE)
    return bm, bn


def _route_metadata(group_sizes: jnp.ndarray, block_m: int, n_work: int):
    """Work-item table of length ``n_work``: (g_ids, m_ids, row_start,
    row_end) per item. Padding items ride on the final tile with an *empty*
    row range so they contribute nothing and trigger no extra tile visits."""
    sizes = group_sizes.astype(jnp.int32)
    ends = jnp.cumsum(sizes)
    starts = ends - sizes
    n_m = jnp.maximum((ends[-1] + block_m - 1) // block_m, 1)
    first = starts // block_m
    last = jnp.where(sizes > 0, (ends - 1) // block_m, first)
    tiles = jnp.where(sizes > 0, last - first + 1, 0)
    off = jnp.cumsum(tiles)  # inclusive prefix
    w = jnp.arange(n_work, dtype=jnp.int32)
    active = w < off[-1]
    g = jnp.searchsorted(off, w, side="right").astype(jnp.int32)
    g = jnp.clip(g, 0, sizes.shape[0] - 1)
    off_excl = off - tiles  # exclusive prefix per group
    m = jnp.clip(first[g] + (w - off_excl[g]), 0, n_m - 1)
    row_start = jnp.where(active, starts[g], 0)
    row_end = jnp.where(active, ends[g], 0)
    return g, m.astype(jnp.int32), row_start, row_end


def _gmm_kernel(
    g_ids,  # [n_work] scalar prefetch
    m_ids,  # [n_work]
    row_start,  # [n_work] first sorted row of this work item's group
    row_end,  # [n_work] one-past-last row (start == end for padding)
    x_ref,  # [bm, Din]
    w_ref,  # [1, Din, bn] (int4_packed: [1, Din//2, bn] uint8 nibble pairs)
    *rest,  # (w_scale_ref?, a_scale_ref?, o_ref, acc)
    block_m: int,
    n_work: int,
    has_scale: bool,
    has_ascale: bool,
    int8_full: bool,
    int4_packed: bool,
):
    rest = list(rest)
    ws_ref = rest.pop(0) if has_scale else None
    as_ref = rest.pop(0) if has_ascale else None
    o_ref, acc = rest
    w = pl.program_id(1)
    g = g_ids[w]
    m = m_ids[w]

    prev = jnp.where(w > 0, m_ids[jnp.maximum(w - 1, 0)], -1)
    nxt = jnp.where(w < n_work - 1, m_ids[jnp.minimum(w + 1, n_work - 1)], -2)

    @pl.when(prev != m)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    rows = m * block_m + jax.lax.broadcasted_iota(
        jnp.int32, (block_m, 1), 0
    )
    in_group = (rows >= row_start[w]) & (rows < row_end[w])  # [bm, 1]

    if int4_packed:
        # Unpack the nibble-packed int4 tile in-register, right where the
        # fused w_scale/a_scale flush already lives: low nibble = even input
        # row 2p, high nibble = odd row 2p+1 (DESIGN.md section 13). Sign
        # extension of a 4-bit field: v - 16*(v>>3). The unpacked tile only
        # ever exists at [Din//2*2, bn] VMEM-tile granularity — no full
        # int8 expert stack is materialized anywhere.
        xi = jnp.where(in_group, x_ref[...], 0).astype(jnp.int8)
        wq = w_ref[0].astype(jnp.int32)  # [P, bn] packed nibble pairs
        lo = wq & 0xF
        hi = (wq >> 4) & 0xF
        lo = lo - ((lo & 0x8) << 1)
        hi = hi - ((hi & 0x8) << 1)
        wu = jnp.stack([lo, hi], axis=1)  # [P, 2, bn]
        wu = wu.reshape(2 * wq.shape[0], wq.shape[1]).astype(jnp.int8)
        part = jax.lax.dot(
            xi, wu, preferred_element_type=jnp.int32
        ).astype(jnp.float32)
    elif int8_full:
        xi = jnp.where(in_group, x_ref[...], 0).astype(jnp.int8)
        part = jax.lax.dot(
            xi, w_ref[0], preferred_element_type=jnp.int32
        ).astype(jnp.float32)
    else:
        xm = jnp.where(in_group, x_ref[...].astype(jnp.float32), 0.0)
        part = jax.lax.dot(
            xm, w_ref[0].astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )
    if has_scale:
        part = part * ws_ref[0][None, :]
    acc[...] += part

    @pl.when(nxt != m)
    def _flush():
        out = acc[...]
        if has_ascale:
            out = out * as_ref[0, 0]
        o_ref[...] = out.astype(o_ref.dtype)


def grouped_matmul(
    x: jnp.ndarray,  # [T, Din] rows sorted by group
    w: jnp.ndarray,  # [G, Din, Dout]; uint8 = nibble-packed int4 [G, ceil(Din/2), Dout]
    group_sizes: jnp.ndarray,  # [G] int32, sum == T
    *,
    w_scale: Optional[jnp.ndarray] = None,  # [G, Dout] per-expert dequant
    a_scale: Optional[jnp.ndarray] = None,  # f32 scalar activation dequant
    out_dtype=None,
    block_m: int = 128,
    block_n: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    T, Din = x.shape
    G, _, Dout = w.shape
    int4_packed = w.dtype == jnp.uint8
    if int4_packed:
        if x.dtype != jnp.int8:
            raise TypeError(
                "nibble-packed int4 weights require int8 activations "
                f"(W4A8); got x dtype {x.dtype}"
            )
        P = w.shape[1]
        if -(-Din // 2) != P:
            raise ValueError(
                f"packed weight dim {P} does not match input dim {Din} "
                f"(expected ceil(Din/2) = {-(-Din // 2)})"
            )
        if Din != 2 * P:  # odd Din: the packed pad row pairs with a zero col
            x = jnp.pad(x, ((0, 0), (0, 2 * P - Din)))
            Din = 2 * P
    int8_in = int4_packed or (x.dtype == jnp.int8 and w.dtype == jnp.int8)
    if T == 0:  # all groups empty: nothing routed this step
        return jnp.zeros(
            (0, Dout),
            out_dtype or (jnp.float32 if int8_in else x.dtype),
        )
    block_m, block_n = legal_gmm_blocks(block_m, block_n, T, Dout, x.dtype)
    n_m = pl.cdiv(T, block_m)
    n_n = pl.cdiv(Dout, block_n)
    t_pad, n_pad = n_m * block_m, n_n * block_n
    n_work = n_m + G

    xp = jnp.pad(x, ((0, t_pad - T), (0, 0)))
    wp = jnp.pad(w, ((0, 0), (0, 0), (0, n_pad - Dout)))

    g_ids, m_ids, row_start, row_end = _route_metadata(
        group_sizes, block_m, n_work
    )

    int8_full = x.dtype == jnp.int8 and w.dtype == jnp.int8
    if out_dtype is None:
        out_dtype = jnp.float32 if (int8_full or int4_packed) else x.dtype
    has_scale = w_scale is not None
    has_ascale = a_scale is not None

    w_rows = w.shape[1]  # Din, or ceil(Din/2) packed
    in_specs = [
        pl.BlockSpec((block_m, Din), lambda n, wk, g_, m_, s_, e_: (m_[wk], 0)),
        pl.BlockSpec((1, w_rows, block_n), lambda n, wk, g_, m_, s_, e_: (g_[wk], 0, n)),
    ]
    args = [xp, wp]
    if has_scale:
        wsp = jnp.pad(w_scale.astype(jnp.float32), ((0, 0), (0, n_pad - Dout)))
        in_specs.append(
            pl.BlockSpec((1, block_n), lambda n, wk, g_, m_, s_, e_: (g_[wk], n))
        )
        args.append(wsp)
    if has_ascale:
        in_specs.append(
            pl.BlockSpec((1, 1), lambda n, wk, g_, m_, s_, e_: (0, 0),
                         memory_space=pltpu.SMEM)
        )
        args.append(jnp.asarray(a_scale, jnp.float32).reshape(1, 1))

    kernel = functools.partial(
        _gmm_kernel,
        block_m=block_m,
        n_work=n_work,
        has_scale=has_scale,
        has_ascale=has_ascale,
        int8_full=int8_full,
        int4_packed=int4_packed,
    )

    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=4,
            grid=(n_n, n_work),
            in_specs=in_specs,
            out_specs=pl.BlockSpec(
                (block_m, block_n), lambda n, wk, g_, m_, s_, e_: (m_[wk], n)
            ),
            scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((t_pad, n_pad), out_dtype),
        interpret=interpret,
    )(g_ids, m_ids, row_start, row_end, *args)

    return out[:T, :Dout]
