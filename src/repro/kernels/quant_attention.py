"""Pallas TPU streaming attention kernel — CoQMoE sections 4.2(a) + 4.3.

The paper's FPGA design broadcasts one K/V stream to all PEs while each PE
holds a distinct Q row, so off-chip traffic is O(1) in PE count; softmax is a
fused 3-pass (max -> numerator+denominator -> shift-based P.V with one final
recip(l) rescale). On TPU that layout IS the flash-attention grid
decomposition: the grid walks Q blocks (the "PEs"); every grid step streams
the *same* K/V HBM tiles through VMEM while its Q tile stays resident.

Two execution schedules:

  * quant_bits == 0 — classic online single-pass flash (running max/denom).
  * quant_bits > 0  — the paper's 3-pass schedule: pass 1 over K computes the
    exact row max (the log-sqrt2 codes must be taken against the *final* max,
    as on the FPGA, or the power-of-two grid shifts per block); pass 2
    computes codes, the exact denominator, and the P.V accumulation; the
    recip(l) rescale happens once at the flush (the paper's Pass 3 trick).

The log-sqrt2 quantizer (Eqs. 17-21) is fused in affine-code form:
codes = clip(round(-2 log2(e) (s - m)), 0, 2^b - 1) — identical math to
-2 log2(exp(s - m)) with no transcendental. A_hat = 2^{-ceil(c/2)} scaled by
the parity LUT (1, sqrt2-1): powers of two are exact in f32/bf16, so the MXU
P.V matmul is exact w.r.t. the quantizer (the TPU answer to the FPGA's
``V_q >> c/2`` shifter; DESIGN.md section 2).

Supports GQA (KVH-native), causal/local/softcap masking, int8 K/V cache with
per-position dequant scales, and a per-batch valid length (decode fill level).
"""
from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.expert_linear import LANE, _round_up, _sublane

LOG2E = 1.4426950408889634
SQRT2M1 = 0.41421356237309515  # sqrt(2) - 1


def legal_attn_blocks(block_q: int, block_k: int, Sq: int, Sk: int,
                      q_dtype=jnp.float32) -> Tuple[int, int]:
    """Clamp a requested (block_q, block_k) to the sequence, then round UP
    to legal TPU tile multiples (shared rule: expert_linear._sublane).

    ``min(block_q, Sq)`` alone produces a 1-row Q tile for decode (Sq=1),
    which is illegal/wasteful on TPU; the clamped block rounds up to the Q
    dtype's sublane multiple (8 f32 / 16 bf16) and block_k to the lane
    multiple (128, which also covers the int8 K/V sublane minimum of 32).
    Padded rows/keys are masked (``kpos < valid``) and sliced off, so the
    rounding changes layout only, never values. The autotuner uses the
    same function so candidate tiles match the kernel's effective tiles."""
    bq = _round_up(max(1, min(block_q, max(Sq, 1))), _sublane(q_dtype))
    bk = _round_up(max(1, min(block_k, max(Sk, 1))), LANE)
    return bq, bk


def _attn_kernel(
    # scalar prefetch
    meta_ref,  # [B] int32: q_offset per batch row (continuous batching)
    valid_ref,  # [B] int32: kv valid length per batch row
    # blocked operands
    q_ref,  # [1, 1, bq, hd]
    k_ref,  # [1, 1, bk, hd]
    v_ref,  # [1, 1, bk, hd]
    *rest,  # (k_scale?, v_scale?, qseg?, kseg?, out, m_s, l_s, acc_s)
    causal: bool,
    local_window: int,
    logit_softcap: float,
    quant_bits: int,
    has_scales: bool,
    has_segs: bool,
    block_q: int,
    block_k: int,
    n_k: int,
    sm_scale: float,
):
    rest = list(rest)
    ks_ref = vs_ref = qseg_ref = kseg_ref = None
    if has_scales:
        ks_ref, vs_ref = rest[0], rest[1]
        rest = rest[2:]
    if has_segs:
        qseg_ref, kseg_ref = rest[0], rest[1]
        rest = rest[2:]
    o_ref, m_s, l_s, acc_s = rest

    b = pl.program_id(0)
    iq = pl.program_id(2)
    ikp = pl.program_id(3)
    nk_total = pl.num_programs(3)
    two_pass = quant_bits > 0
    phase = ikp // n_k if two_pass else 0
    ik = ikp % n_k if two_pass else ikp

    @pl.when(ikp == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, -1e30)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    q_off = meta_ref[b]
    valid = jnp.minimum(valid_ref[b], jnp.int32(n_k * block_k))

    qpos = (
        q_off
        + iq * block_q
        + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    )
    kpos = ik * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1
    )
    mask = kpos < valid
    if causal:
        mask &= kpos <= qpos
    if local_window > 0:
        mask &= (qpos - kpos) < local_window
    if has_segs:
        # Packed variable-length prefill (DESIGN.md section 10): a position
        # only attends within its own segment. Padded tails carry id -1 on
        # the q side and -2 on the k side so they can never match.
        mask &= qseg_ref[0][:, None] == kseg_ref[0][None, :]

    # Block-level skip: nothing in this K tile can be visible.
    row0 = q_off + iq * block_q  # first (smallest) q position of the tile
    block_alive = jnp.logical_and(
        ik * block_k < valid,
        (ik * block_k <= row0 + block_q - 1) if causal else True,
    )
    if local_window > 0:
        block_alive = jnp.logical_and(
            block_alive, (ik + 1) * block_k > row0 - local_window + 1
        )

    @pl.when(block_alive)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * sm_scale  # [bq, bk]
        if has_scales:
            s = s * ks_ref[0, 0][None, :]
        if logit_softcap > 0.0:
            s = logit_softcap * jnp.tanh(s / logit_softcap)
        s = jnp.where(mask, s, -jnp.inf)

        if two_pass:
            @pl.when(phase == 0)
            def _pass1():
                # Pass 1 (paper section 4.3): exact row max only.
                m_blk = jnp.max(s, axis=1, keepdims=True)  # [bq, 1]
                m_s[...] = jnp.maximum(m_s[...], jnp.maximum(m_blk, -1e30))

            @pl.when(phase == 1)
            def _pass2():
                # Pass 2: log-sqrt2 codes against the final max + exact denom.
                v = v_ref[0, 0].astype(jnp.float32)
                m = m_s[:, :1]  # [bq, 1]
                f_exact = jnp.exp(s - m)
                codes = jnp.clip(
                    jnp.round(-2.0 * LOG2E * (s - m)),
                    0.0,
                    2.0**quant_bits - 1.0,
                ).astype(jnp.int32)
                shift = (codes + 1) // 2  # ceil(c / 2)
                parity = (codes & 1).astype(jnp.float32)
                f_hat = jnp.exp2(-shift.astype(jnp.float32)) * (
                    1.0 + parity * SQRT2M1
                )
                f_hat = jnp.where(mask, f_hat, 0.0)
                l_s[...] += jnp.sum(f_exact, axis=1, keepdims=True)
                if has_scales:
                    f_hat = f_hat * vs_ref[0, 0][None, :]
                acc_s[...] += jax.lax.dot(
                    f_hat, v, preferred_element_type=jnp.float32
                )
        else:
            # Online single-pass flash (running max / denominator).
            v = v_ref[0, 0].astype(jnp.float32)
            m_old = m_s[:, :1]
            m_blk = jnp.maximum(jnp.max(s, axis=1, keepdims=True), -1e30)
            m_new = jnp.maximum(m_old, m_blk)
            corr = jnp.exp(m_old - m_new)
            p = jnp.exp(s - m_new)
            l_s[...] = l_s[...] * corr + jnp.sum(p, axis=1, keepdims=True)
            if has_scales:
                p = p * vs_ref[0, 0][None, :]
            acc_s[...] = acc_s[...] * corr + jax.lax.dot(
                p, v, preferred_element_type=jnp.float32
            )
            m_s[...] = jnp.broadcast_to(m_new, m_s.shape)

    @pl.when(ikp == nk_total - 1)
    def _flush():
        # Pass 3: one recip(l) per row (all of a row's outputs share it).
        l = jnp.maximum(l_s[:, :1], 1e-30)
        o_ref[0, 0, :, :] = (acc_s[...] / l).astype(o_ref.dtype)


def streaming_attention(
    q: jnp.ndarray,  # [B, Sq, H, hd]
    k: jnp.ndarray,  # [B, Sk, KVH, hd] (fp or int8)
    v: jnp.ndarray,
    *,
    causal: bool = True,
    q_offset=0,  # scalar or [B]: per-batch absolute position of q[0]
                 # (continuous batching: every serving slot decodes at its
                 # own fill level; pairs with per-slot kv_valid_len)
    quant_bits: int = 0,
    logit_softcap: float = 0.0,
    local_window: int = 0,
    k_scale: Optional[jnp.ndarray] = None,  # [B, Sk, KVH]
    v_scale: Optional[jnp.ndarray] = None,
    kv_valid_len: Optional[jnp.ndarray] = None,  # [B]
    q_segment_ids: Optional[jnp.ndarray] = None,  # [B, Sq] packed prefill
    kv_segment_ids: Optional[jnp.ndarray] = None,  # [B, Sk]
    block_q: int = 128,
    block_k: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    B, Sq, H, hd = q.shape
    Sk, KVH = k.shape[1], k.shape[2]
    assert H % KVH == 0, (H, KVH)
    group = H // KVH

    block_q, block_k = legal_attn_blocks(block_q, block_k, Sq, Sk, q.dtype)
    has_segs = q_segment_ids is not None
    if has_segs:
        # Segment ids ride along as 2D [B, S] blocked inputs; their minor
        # dim is the block size, so the Q block must be lane-rounded to keep
        # the (1, block_q) tile legal (block_k is already a LANE multiple).
        block_q = _round_up(block_q, LANE)
    n_q = pl.cdiv(Sq, block_q)
    n_k = pl.cdiv(Sk, block_k)
    sq_pad, sk_pad = n_q * block_q, n_k * block_k

    # [B, heads, S, hd] layout for clean (b, h, s-block) tiling.
    qt = jnp.pad(
        q.transpose(0, 2, 1, 3), ((0, 0), (0, 0), (0, sq_pad - Sq), (0, 0))
    )
    kt = jnp.pad(
        k.transpose(0, 2, 1, 3), ((0, 0), (0, 0), (0, sk_pad - Sk), (0, 0))
    )
    vt = jnp.pad(
        v.transpose(0, 2, 1, 3), ((0, 0), (0, 0), (0, sk_pad - Sk), (0, 0))
    )

    meta = jnp.broadcast_to(jnp.asarray(q_offset, jnp.int32), (B,))
    valid = (
        jnp.full((B,), Sk, jnp.int32)
        if kv_valid_len is None
        else kv_valid_len.astype(jnp.int32)
    )

    has_scales = k_scale is not None
    two_pass = quant_bits > 0
    grid = (B, H, n_q, (2 * n_k) if two_pass else n_k)

    # NB: with PrefetchScalarGridSpec, index maps receive
    # (*grid_indices, *scalar_prefetch_refs) — scalars LAST.
    def kmap(b, h, iq, ikp, m, vl):
        return (b, h // group, ikp % n_k if two_pass else ikp, 0)

    def vmap_(b, h, iq, ikp, m, vl):
        # V is consumed only in pass 2; pin pass-1 visits to tile 0 so the
        # max pass issues no V HBM traffic (K streams twice, V once — the
        # paper's Pass-1/Pass-2 split).
        if two_pass:
            return (b, h // group, jnp.where(ikp < n_k, 0, ikp - n_k), 0)
        return (b, h // group, ikp, 0)

    def smap(b, h, iq, ikp, m, vl):
        return (b, h // group, ikp % n_k if two_pass else ikp)

    in_specs = [
        pl.BlockSpec((1, 1, block_q, hd), lambda b, h, iq, ikp, m, vl: (b, h, iq, 0)),
        pl.BlockSpec((1, 1, block_k, hd), kmap),
        pl.BlockSpec((1, 1, block_k, hd), vmap_),
    ]
    args = [qt, kt, vt]
    if has_scales:
        kst = jnp.pad(
            k_scale.transpose(0, 2, 1), ((0, 0), (0, 0), (0, sk_pad - Sk))
        ).astype(jnp.float32)
        vst = jnp.pad(
            v_scale.transpose(0, 2, 1), ((0, 0), (0, 0), (0, sk_pad - Sk))
        ).astype(jnp.float32)
        in_specs += [
            pl.BlockSpec((1, 1, block_k), smap),
            pl.BlockSpec((1, 1, block_k), smap),
        ]
        args += [kst, vst]
    if has_segs:
        kv_seg = (
            kv_segment_ids if kv_segment_ids is not None else q_segment_ids
        )
        qsegp = jnp.pad(
            q_segment_ids.astype(jnp.int32), ((0, 0), (0, sq_pad - Sq)),
            constant_values=-1,
        )
        ksegp = jnp.pad(
            kv_seg.astype(jnp.int32), ((0, 0), (0, sk_pad - Sk)),
            constant_values=-2,  # != q pad id: padded tails never match
        )
        in_specs += [
            pl.BlockSpec((1, block_q), lambda b, h, iq, ikp, m, vl: (b, iq)),
            pl.BlockSpec(
                (1, block_k),
                lambda b, h, iq, ikp, m, vl: (b, ikp % n_k if two_pass else ikp),
            ),
        ]
        args += [qsegp, ksegp]

    kernel = functools.partial(
        _attn_kernel,
        causal=causal,
        local_window=local_window,
        logit_softcap=logit_softcap,
        quant_bits=quant_bits,
        has_scales=has_scales,
        has_segs=has_segs,
        block_q=block_q,
        block_k=block_k,
        n_k=n_k,
        sm_scale=1.0 / math.sqrt(hd),
    )

    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=in_specs,
            out_specs=pl.BlockSpec(
                (1, 1, block_q, hd), lambda b, h, iq, ikp, m, vl: (b, h, iq, 0)
            ),
            scratch_shapes=[
                pltpu.VMEM((block_q, 128), jnp.float32),  # running max
                pltpu.VMEM((block_q, 128), jnp.float32),  # running denom
                pltpu.VMEM((block_q, hd), jnp.float32),  # P.V accumulator
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, H, sq_pad, hd), q.dtype),
        interpret=interpret,
    )(meta, valid, *args)

    return out[:, :, :Sq, :].transpose(0, 2, 1, 3)
