"""jit'd dispatch wrappers for the Pallas kernels.

Backend selection:
  * TPU backend          -> pl.pallas_call kernels (VMEM-tiled)
  * CPU / tests          -> pure-jnp reference (ref.py)
  * REPRO_PALLAS=interpret -> pallas kernels in interpret mode (correctness
                              validation of the kernel bodies on CPU)
"""
from __future__ import annotations

import contextlib
import os
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref


def _mode() -> str:
    env = os.environ.get("REPRO_PALLAS", "auto")
    if env in ("interpret", "pallas", "ref"):
        return env
    return "pallas" if jax.default_backend() == "tpu" else "ref"


# -- kernel trace annotations (DESIGN.md section 11) -------------------------
#
# With annotations on, the hot dispatch wrappers wrap their bodies in
# jax.named_scope so device profiles (jax.profiler traces) carry kernel-level
# names with their shape signatures. named_scope is trace-time metadata: it
# costs nothing at execution time, and with the flag off (the default) the
# wrappers don't even build the scope name — serving without profiling pays
# one module-global read. TraceConfig.annotate_kernels flips this via
# serving/trace.make_tracer.

_ANNOTATE = False


def set_kernel_annotations(on: bool = True) -> None:
    """Enable/disable named_scope annotations on the kernel wrappers."""
    global _ANNOTATE
    _ANNOTATE = bool(on)


def kernel_annotations_enabled() -> bool:
    return _ANNOTATE


def _scope(name_fn):
    """named_scope from a lazy name thunk — the f-string only renders when
    annotations are on (the disabled path allocates nothing)."""
    if not _ANNOTATE:
        return contextlib.nullcontext()
    return jax.named_scope(name_fn())


def attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    q_offset=0,
    quant_bits: int = 0,
    logit_softcap: float = 0.0,
    local_window: int = 0,
    k_scale: Optional[jnp.ndarray] = None,
    v_scale: Optional[jnp.ndarray] = None,
    kv_valid_len: Optional[jnp.ndarray] = None,
    q_segment_ids: Optional[jnp.ndarray] = None,  # [B, Sq] packed prefill
    kv_segment_ids: Optional[jnp.ndarray] = None,  # [B, Sk]
) -> jnp.ndarray:
    """Streaming attention; GQA-native (k/v carry KVH heads)."""
    with _scope(lambda: (
            f"attention[B={q.shape[0]},H={q.shape[1]},Sq={q.shape[2]},"
            f"Sk={k.shape[2]},q{quant_bits}]")):
        return _attention(
            q, k, v, causal=causal, q_offset=q_offset,
            quant_bits=quant_bits, logit_softcap=logit_softcap,
            local_window=local_window, k_scale=k_scale, v_scale=v_scale,
            kv_valid_len=kv_valid_len, q_segment_ids=q_segment_ids,
            kv_segment_ids=kv_segment_ids)


def _attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool,
    q_offset,
    quant_bits: int,
    logit_softcap: float,
    local_window: int,
    k_scale: Optional[jnp.ndarray],
    v_scale: Optional[jnp.ndarray],
    kv_valid_len: Optional[jnp.ndarray],
    q_segment_ids: Optional[jnp.ndarray],
    kv_segment_ids: Optional[jnp.ndarray],
) -> jnp.ndarray:
    mode = _mode()
    if mode in ("pallas", "interpret"):
        from repro.kernels import autotune
        from repro.kernels.quant_attention import streaming_attention

        # trace-time tile lookup: tuned entry for this shape bucket when a
        # tuning table is active (kernels/autotune.py), defaults otherwise
        bq, bk = autotune.attn_blocks(
            q.shape[0], q.shape[2], k.shape[2], q.shape[3],
            q.shape[1], k.shape[1],
            causal=causal, quant_bits=quant_bits,
            scaled=k_scale is not None, q_dtype=q.dtype, k_dtype=k.dtype,
            local_window=local_window,
        )
        return streaming_attention(
            q, k, v,
            causal=causal, q_offset=q_offset, quant_bits=quant_bits,
            logit_softcap=logit_softcap, local_window=local_window,
            k_scale=k_scale, v_scale=v_scale, kv_valid_len=kv_valid_len,
            q_segment_ids=q_segment_ids, kv_segment_ids=kv_segment_ids,
            block_q=bq, block_k=bk,
            interpret=(mode == "interpret"),
        )
    return _ref.flash_attention_ref(
        q, k, v,
        causal=causal, q_offset=q_offset, quant_bits=quant_bits,
        logit_softcap=logit_softcap, local_window=local_window,
        k_scale=k_scale, v_scale=v_scale, kv_valid_len=kv_valid_len,
        q_segment_ids=q_segment_ids, kv_segment_ids=kv_segment_ids,
    )


def grouped_matmul(
    x: jnp.ndarray,
    w: jnp.ndarray,
    group_sizes: jnp.ndarray,
    *,
    w_scale: Optional[jnp.ndarray] = None,  # [G, Dout] per-expert dequant
    a_scale: Optional[jnp.ndarray] = None,  # f32 scalar activation scale
    a_bits: int = 8,
) -> jnp.ndarray:
    """Unified sparse/dense linear: y[t] = x[t] @ w[group(t)].

    int8 weights (QuantizedParams expert stacks) execute as stored: an fp
    ``x`` is quantized here with the folded ``a_scale``, the contraction
    accumulates int8 x int8 -> int32, and the product-of-scales dequant
    lands once on the accumulator — the full-precision expert weights are
    never materialized outside the kernel.
    """
    with _scope(lambda: (
            f"grouped_matmul[T={x.shape[0]},G={w.shape[0]},"
            f"Din={w.shape[1]},Dout={w.shape[2]},{w.dtype}]")):
        return _grouped_matmul(x, w, group_sizes, w_scale=w_scale,
                               a_scale=a_scale, a_bits=a_bits)


def _grouped_matmul(
    x: jnp.ndarray,
    w: jnp.ndarray,
    group_sizes: jnp.ndarray,
    *,
    w_scale: Optional[jnp.ndarray],
    a_scale: Optional[jnp.ndarray],
    a_bits: int,
) -> jnp.ndarray:
    mode = _mode()
    int8_w = w.dtype == jnp.int8
    int4_w = w.dtype == jnp.uint8  # nibble-packed int4 stack (W4A8)
    if (int8_w or int4_w) and x.dtype != jnp.int8:
        if a_scale is None:
            raise ValueError(
                f"{'int4' if int4_w else 'int8'} grouped weights need the "
                "folded activation scale (a PTQ QuantizedParams tree "
                "carries it as the `wi_as` / `wo_a_scale` leaf — was the "
                "model calibrated with taps?)"
            )
        from repro.core.quant.qtypes import quantize_sym

        x = quantize_sym(x.astype(jnp.float32), a_scale, a_bits)
    if mode in ("pallas", "interpret"):
        from repro.kernels import autotune
        from repro.kernels.expert_linear import grouped_matmul as gmm

        bm, bn = autotune.gmm_blocks(
            x.shape[0], w.shape[0], x.shape[1], w.shape[2],
            x_dtype=x.dtype, w_dtype=w.dtype,
            scaled=w_scale is not None, ascaled=a_scale is not None,
        )
        return gmm(x, w, group_sizes, w_scale=w_scale, a_scale=a_scale,
                   block_m=bm, block_n=bn,
                   interpret=(mode == "interpret"))
    # ragged_dot is the fast XLA path on CPU/GPU (grouped_matmul_ref is the
    # oracle used by tests; ragged_dot matches it exactly).
    if int4_w:
        # Nibble-planar contraction: the low-nibble plane multiplies the
        # even activation columns, the high-nibble plane the odd columns —
        # two half-width ragged_dots whose int32 sum equals the unpacked
        # contraction exactly. The full-width int8 expert stack is never
        # materialized (the jaxpr only holds [G, Din/2, Dout] planes).
        P = w.shape[1]
        xp = x if x.shape[1] == 2 * P else jnp.pad(
            x, ((0, 0), (0, 2 * P - x.shape[1])))
        w32 = w.astype(jnp.int32)
        lo = ((w32 & 0xF) - ((w32 & 0x8) << 1)).astype(jnp.int8)
        h4 = (w32 >> 4) & 0xF
        hi = (h4 - ((h4 & 0x8) << 1)).astype(jnp.int8)
        gs = group_sizes.astype(jnp.int32)
        acc = (
            jax.lax.ragged_dot(xp[:, 0::2], lo, gs,
                               preferred_element_type=jnp.int32)
            + jax.lax.ragged_dot(xp[:, 1::2], hi, gs,
                                 preferred_element_type=jnp.int32)
        )
        y = acc.astype(jnp.float32)
        seg = _row_groups(group_sizes, x.shape[0])
        if w_scale is not None:
            y = y * w_scale[seg]
        if a_scale is not None:
            y = y * a_scale
        return y
    if int8_w:
        acc = jax.lax.ragged_dot(x, w, group_sizes.astype(jnp.int32),
                                 preferred_element_type=jnp.int32)
        y = acc.astype(jnp.float32)
        seg = _row_groups(group_sizes, x.shape[0])
        if w_scale is not None:
            y = y * w_scale[seg]
        if a_scale is not None:
            y = y * a_scale
        return y
    return jax.lax.ragged_dot(x, w, group_sizes.astype(jnp.int32))


def _row_groups(group_sizes: jnp.ndarray, n_rows: int) -> jnp.ndarray:
    ends = jnp.cumsum(group_sizes)
    return jnp.searchsorted(ends, jnp.arange(n_rows), side="right")


def grouped_mlp(
    x: jnp.ndarray,
    wi: jnp.ndarray,
    wo: jnp.ndarray,
    group_sizes: jnp.ndarray,
    *,
    act: str = "silu",
    glu: bool = True,
    bi: Optional[jnp.ndarray] = None,  # [G, hid] per-expert fc1 bias
    bo: Optional[jnp.ndarray] = None,  # [G, out] per-expert fc2 bias
    taps=None,  # PTQ calibration collector (records the fc2 input site)
    mid_a_scale: Optional[jnp.ndarray] = None,  # PTQ runtime fc2-input scale
    a_bits: int = 8,  # activation quantizer width (fc1 + fc2 inputs)
    wi_scale: Optional[jnp.ndarray] = None,  # [G, hid] int8 fc1 dequant
    wo_scale: Optional[jnp.ndarray] = None,  # [G, out] int8 fc2 dequant
    wi_a_scale: Optional[jnp.ndarray] = None,  # folded fc1 input scale
) -> jnp.ndarray:
    from repro.core.quant.calibrate import maybe_record
    from repro.models.layers import act_fn

    seg = None
    if bi is not None or bo is not None:
        seg = _row_groups(group_sizes, x.shape[0])
    h = grouped_matmul(x, wi, group_sizes, w_scale=wi_scale,
                       a_scale=wi_a_scale, a_bits=a_bits)
    if bi is not None:
        h = h + bi[seg]
    if glu:
        g, u = jnp.split(h, 2, axis=-1)
        h = act_fn(act)(g) * u
    else:
        h = act_fn(act)(h)
    maybe_record(taps, "moe_mid", h)
    if wo.dtype in (jnp.int8, jnp.uint8):
        # real-int8/packed-int4 fc2: mid_a_scale is the *actual* quantizer
        # here (same value the fake-quant oracle clips to — identical grids)
        y = grouped_matmul(h, wo, group_sizes, w_scale=wo_scale,
                           a_scale=mid_a_scale, a_bits=a_bits)
    else:
        if mid_a_scale is not None:
            from repro.core.quant.linear_quant import fake_quant_activation

            h = fake_quant_activation(
                h.astype(jnp.float32), mid_a_scale, bits=a_bits
            ).astype(h.dtype)
        y = grouped_matmul(h, wo, group_sizes)
    if bo is not None:
        y = y + bo[seg]
    return y


def selective_scan(x, dt, b, c, a, d):
    """Mamba-1 selective scan: VMEM-resident state on TPU (O(S*d) HBM).

    Returns (y [B,S,di], h_last [B,di,N]). The ref path exists for the
    kernel tests; the model's CPU lowering keeps the chunked associative
    scan in models/ssm.py (bounded memory without a kernel).
    """
    mode = _mode()
    if mode in ("pallas", "interpret"):
        from repro.kernels.selective_scan import selective_scan as ss

        return ss(x, dt, b, c, a, d, interpret=(mode == "interpret"))
    y = _ref.selective_scan_ref(x, dt, b, c, a, d)
    # ref h_last for parity (small shapes only — test/debug path)
    decay = jnp.exp(dt[..., None].astype(jnp.float32) * a)
    u = (dt * x)[..., None].astype(jnp.float32) * b[:, :, None, :].astype(jnp.float32)
    import jax as _jax

    def op(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    _, h = _jax.lax.associative_scan(op, (decay, u), axis=1)
    return y, h[:, -1]


def int8_matmul(
    x_q: jnp.ndarray,
    w_q: jnp.ndarray,
    x_scale: jnp.ndarray,
    w_scale: jnp.ndarray,
    bias: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    mode = _mode()
    if mode in ("pallas", "interpret"):
        from repro.kernels.int8_matmul import int8_matmul as imm

        return imm(x_q, w_q, x_scale, w_scale, bias,
                   interpret=(mode == "interpret"))
    return _ref.int8_matmul_ref(x_q, w_q, x_scale, w_scale, bias)
