"""Learning-rate schedules (pure functions of the int32 step)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    def f(step):
        return jnp.asarray(lr, jnp.float32)

    return f


def warmup_linear(lr: float, warmup: int, total: int, floor: float = 0.0):
    def f(step):
        step = step.astype(jnp.float32)
        w = jnp.minimum(step / max(warmup, 1), 1.0)
        decay = jnp.maximum(
            (total - step) / max(total - warmup, 1), floor / max(lr, 1e-30)
        )
        return lr * w * jnp.minimum(decay, 1.0)

    return f


def warmup_cosine(lr: float, warmup: int, total: int, floor: float = 0.0):
    def f(step):
        step = step.astype(jnp.float32)
        w = jnp.minimum(step / max(warmup, 1), 1.0)
        t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor / max(lr, 1e-30) + (1 - floor / max(lr, 1e-30)) * 0.5 * (
            1 + jnp.cos(jnp.pi * t)
        )
        return lr * w * cos

    return f
