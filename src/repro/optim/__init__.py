from repro.optim.optimizers import (
    Optimizer,
    adafactor,
    adamw,
    clip_by_global_norm,
    global_norm,
    make_optimizer,
)
from repro.optim.schedules import constant, warmup_cosine, warmup_linear
from repro.optim.compress import (
    CompressState,
    compress_grads,
    decompress_sum,
    init_compress_state,
)

__all__ = [k for k in dir() if not k.startswith("_")]
