"""Optimizers as pure (init, update) pairs over param pytrees.

AdamW for the small/medium archs; Adafactor (factored second moment, no
momentum) for the 100B+ archs so optimizer state fits HBM at scale
(DESIGN.md section 5). Optimizer state inherits the param sharding (ZeRO via
GSPMD: same PartitionSpec tree as the params).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, jnp.ndarray], tuple]  # (g, s, p, step)
    # (param_specs, param_shapes) -> opt-state PartitionSpec tree (ZeRO:
    # state inherits the param sharding; tiny factored vectors replicate)
    state_specs: Callable[[Any, Any], Any] = lambda ps, sh: None


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.asarray(leaves)))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda x: (x * scale).astype(x.dtype), tree), norm


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def adamw(schedule, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros_like(p, jnp.float32)
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params)}

    def update(grads, state, params, step):
        lr = schedule(step)
        t = step.astype(jnp.float32) + 1.0
        bc1 = 1.0 - b1**t
        bc2 = 1.0 - b2**t

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * jnp.square(g)
            mh = m / bc1
            vh = v / bc2
            step_ = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * step_).astype(p.dtype), m, v

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_m = tdef.flatten_up_to(state["m"])
        flat_v = tdef.flatten_up_to(state["v"])
        out = [upd(g, m, v, p)
               for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
        new_p = tdef.unflatten([o[0] for o in out])
        new_m = tdef.unflatten([o[1] for o in out])
        new_v = tdef.unflatten([o[2] for o in out])
        return new_p, {"m": new_m, "v": new_v}

    def state_specs(param_specs, param_shapes):
        return {"m": param_specs, "v": param_specs}

    return Optimizer(init=init, update=update, state_specs=state_specs)


# ---------------------------------------------------------------------------
# Adafactor (Shazeer & Stern 2018) — factored second moment
# ---------------------------------------------------------------------------

def _factored(shape) -> bool:
    return len(shape) >= 2 and shape[-1] > 1 and shape[-2] > 1


def adafactor(schedule, decay: float = 0.8, eps: float = 1e-30,
              clip_threshold: float = 1.0,
              weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        def one(p):
            if _factored(p.shape):
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros_like(p, jnp.float32)}

        return {"v": jax.tree.map(one, params)}

    def update(grads, state, params, step):
        lr = schedule(step)
        t = step.astype(jnp.float32) + 1.0
        beta = 1.0 - t ** (-decay)  # increasing decay schedule

        def upd(g, s, p):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + eps
            if _factored(p.shape):
                vr = beta * s["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * s["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
                denom = jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), eps)
                u = g * jax.lax.rsqrt(vr[..., None] / denom[..., None]) \
                    * jax.lax.rsqrt(vc[..., None, :])
                new_s = {"vr": vr, "vc": vc}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                u = g * jax.lax.rsqrt(v)
                new_s = {"v": v}
            # update clipping (RMS of the step bounded by clip_threshold)
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-30)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            newp = p.astype(jnp.float32) - lr * (
                u + weight_decay * p.astype(jnp.float32)
            )
            return newp.astype(p.dtype), new_s

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_s = tdef.flatten_up_to(state["v"])
        out = [upd(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
        return (tdef.unflatten([o[0] for o in out]),
                {"v": tdef.unflatten([o[1] for o in out])})

    def state_specs(param_specs, param_shapes):
        from jax.sharding import PartitionSpec as P

        def one(spec, shape_leaf):
            if _factored(shape_leaf.shape):
                return {"vr": P(), "vc": P()}  # tiny: replicate
            return {"v": spec}

        flat_sp, tdef = jax.tree.flatten(
            param_specs, is_leaf=lambda x: isinstance(x, P))
        flat_sh = tdef.flatten_up_to(param_shapes)
        return {"v": tdef.unflatten(
            [one(sp, sh) for sp, sh in zip(flat_sp, flat_sh)])}

    return Optimizer(init=init, update=update, state_specs=state_specs)


def make_optimizer(name: str, schedule, **kw) -> Optimizer:
    if name == "adamw":
        return adamw(schedule, **kw)
    if name == "adafactor":
        return adafactor(schedule, **kw)
    raise ValueError(name)
