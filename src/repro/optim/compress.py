"""INT8 gradient compression with error feedback — the paper's quantization
theme applied to the distributed substrate (beyond-paper; DESIGN.md section 5).

Gradients are quantized per-tensor symmetric INT8 *before* the data-parallel
all-reduce and dequantized after, cutting collective bytes 4x vs f32 (2x vs
bf16). The quantization error is carried in a per-tensor residual and added
back into the next step's gradient (error feedback), which keeps SGD-style
convergence (Karimireddy et al. 2019).

Used by train_step when ``grad_compress=True``: the all-reduce runs over the
int8 payload inside shard_map; under pjit the same compress/decompress pair
brackets the implicit reduction (XLA reduces the int32-summed codes).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class CompressState(NamedTuple):
    residual: Any  # pytree of f32 error-feedback residuals


def init_compress_state(params) -> CompressState:
    return CompressState(
        residual=jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
    )


def _quantize_one(g: jnp.ndarray, r: jnp.ndarray):
    gf = g.astype(jnp.float32) + r
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-30) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127)
    new_r = gf - q * scale  # error feedback residual
    return q.astype(jnp.int8), scale, new_r


def compress_grads(grads, state: CompressState) -> Tuple[Any, Any, CompressState]:
    """Returns (int8 codes tree, scales tree, new residual state)."""
    flat_g, tdef = jax.tree.flatten(grads)
    flat_r = tdef.flatten_up_to(state.residual)
    qs = [_quantize_one(g, r) for g, r in zip(flat_g, flat_r)]
    codes = tdef.unflatten([q[0] for q in qs])
    scales = tdef.unflatten([q[1] for q in qs])
    new_state = CompressState(residual=tdef.unflatten([q[2] for q in qs]))
    return codes, scales, new_state


def decompress_sum(codes_sum, scales, n_participants: int):
    """Dequantize an all-reduced (summed) int32 code tree.

    Every participant quantizes with its own scale; psum of codes requires a
    shared scale, so the caller psum-maxes the scale first (see train_step).
    The mean over participants divides by ``n_participants``.
    """
    return jax.tree.map(
        lambda c, s: c.astype(jnp.float32) * s / n_participants,
        codes_sum, scales,
    )
