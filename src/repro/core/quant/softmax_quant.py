"""Post-softmax log-sqrt2 quantization (CoQMoE section 3.2, Eqs. 17-21).

The quantizer acts on the softmax *numerator* f(x) = exp(x - max) in (0, 1],
so the scale is s = 1 (paper section 3.2). Dequantization is reparameterized
into an exponent shift plus a two-value parity LUT:

    A_q  = clip(round(-2 log2 A), 0, 2^b - 1)            (Eq. 18)
    A_hat = 2^{-ceil(A_q/2)} * (1 + odd(A_q) (sqrt2 - 1))  (Eq. 19)

TPU adaptation (DESIGN.md section 2): the FPGA executes Eq. 21 as
``(V_q >> floor(A_q/2)) * s'``; the TPU MXU has no shifter datapath, so we
materialize A_hat directly -- its values are powers of two (exact in bf16,
zero mantissa error) times the parity constant. The exact two-matmul parity
decomposition used for validation:

    A_hat @ V = (A_even @ V) + sqrt2 * (A_odd @ V)

where A_even/A_odd hold exact powers of two. (Eq. 21 prints floor; ceil is
required for odd codes to land on 2^{-(2k+1)/2} -- typo noted in DESIGN.md.)
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp

SQRT2 = 1.4142135623730951


def logsqrt2_quantize(a: jnp.ndarray, bits: int = 4) -> jnp.ndarray:
    """Eq. 18: A_q = clip(round(-2 log2 A), 0, 2^b - 1); returns int8 codes."""
    a = jnp.maximum(a, 2.0 ** (-(2.0**bits)))  # guard log(0)
    q = jnp.round(-2.0 * jnp.log2(a))
    return jnp.clip(q, 0, 2**bits - 1).astype(jnp.int8)


def logsqrt2_dequantize(a_q: jnp.ndarray, dtype=jnp.float32) -> jnp.ndarray:
    """Eq. 19: exponent shift + parity LUT (exact)."""
    a_q = a_q.astype(jnp.int32)
    shift = (a_q + 1) // 2  # ceil(A_q / 2)
    parity = (a_q & 1).astype(dtype)  # 1 at odd codes
    base = jnp.exp2(-shift.astype(dtype))
    return base * (1.0 + parity * (SQRT2 - 1.0))


def logsqrt2_scale_factor(a_q: jnp.ndarray) -> jnp.ndarray:
    """Eq. 20: s' = 1 + odd(A_q)(sqrt2 - 1)."""
    return 1.0 + (a_q.astype(jnp.int32) & 1).astype(jnp.float32) * (SQRT2 - 1.0)


def parity_decomposition(a_q: jnp.ndarray, dtype=jnp.float32) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Split codes into (even, odd) exact power-of-two planes (Eq. 21 analogue).

    Returns (a_even, a_odd) with a_even + sqrt2 * a_odd == A_hat, where both
    planes contain only exact powers of two (or zero).
    """
    a_q = a_q.astype(jnp.int32)
    shift = (a_q + 1) // 2
    base = jnp.exp2(-shift.astype(dtype))
    odd = (a_q & 1) == 1
    a_even = jnp.where(odd, 0.0, base).astype(dtype)
    a_odd = jnp.where(odd, base, 0.0).astype(dtype)
    return a_even, a_odd


def quantized_softmax_numerator(
    scores: jnp.ndarray, bits: int = 4
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """3-pass fused softmax (paper section 4.3), numerator-quantized.

    Pass 1: row max. Pass 2: numerator f(x) and denominator l(x) (exact).
    Returns (A_q int codes of the numerator, l row-denominator). The caller
    applies Pass 3: out = (A_hat @ V) * recip(l).
    """
    m = jnp.max(scores, axis=-1, keepdims=True)
    f = jnp.exp(scores - m)
    l = jnp.sum(f, axis=-1, keepdims=True)
    a_q = logsqrt2_quantize(f, bits=bits)
    return a_q, l
