"""INT8 symmetric linear-layer quantization (paper Eqs. 7/9).

Weights: per-output-channel symmetric INT8. Activations: per-tensor symmetric
INT8 with a calibrated static scale (the post-norm activations' scale is the
reparam s_tilde). The matmul runs int8 x int8 -> int32 on the MXU; the single
product-of-scales rescale of Eq. 9 is applied once on the int32 accumulator.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax.numpy as jnp

from repro.core.quant.qtypes import int_matmul, qmax, quantize_sym, sym_scale_from_absmax


class QLinear(NamedTuple):
    """Quantized linear layer y = dequant(x_q @ w_q) + b."""

    w_q: jnp.ndarray  # int8 [in, out]  (or [E, in, out] for expert stacks)
    w_scale: jnp.ndarray  # f32 [out]   per-output-channel
    a_scale: jnp.ndarray  # f32 scalar  per-tensor activation scale
    b: Optional[jnp.ndarray]  # f32 [out] or None


def quantize_weight(w: jnp.ndarray, bits: int = 8) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Per-output-channel symmetric quant; w: [..., in, out] -> scale [..., out]."""
    absmax = jnp.max(jnp.abs(w), axis=-2, keepdims=True)
    scale = sym_scale_from_absmax(absmax, bits)
    w_q = quantize_sym(w, scale, bits)
    return w_q, scale.squeeze(-2)


def make_qlinear(
    w: jnp.ndarray,
    b: Optional[jnp.ndarray],
    a_absmax: jnp.ndarray,
    w_bits: int = 8,
    a_bits: int = 8,
) -> QLinear:
    w_q, w_scale = quantize_weight(w, w_bits)
    a_scale = sym_scale_from_absmax(jnp.asarray(a_absmax, jnp.float32), a_bits)
    return QLinear(w_q=w_q, w_scale=w_scale, a_scale=a_scale,
                   b=None if b is None else jnp.asarray(b, jnp.float32))


def qlinear_apply(x: jnp.ndarray, q: QLinear, a_bits: int = 8) -> jnp.ndarray:
    """Eq. 9: y = s_x s_w (X_q W_q) + b. x: [..., in] f32/bf16."""
    x_q = quantize_sym(x.astype(jnp.float32), q.a_scale, a_bits)
    acc = int_matmul(x_q, q.w_q)  # int32 [..., out]
    y = acc.astype(jnp.float32) * (q.a_scale * q.w_scale)
    if q.b is not None:
        y = y + q.b
    return y


def qlinear_apply_prequant(x_q: jnp.ndarray, q: QLinear) -> jnp.ndarray:
    """Same as qlinear_apply but the input is already int8 (fused pipelines)."""
    acc = int_matmul(x_q, q.w_q)
    y = acc.astype(jnp.float32) * (q.a_scale * q.w_scale)
    if q.b is not None:
        y = y + q.b
    return y


def fake_quant_activation(x: jnp.ndarray, a_scale: jnp.ndarray, bits: int = 8) -> jnp.ndarray:
    """Quantize-dequantize (used by the oracle and fidelity benchmarks)."""
    q = jnp.clip(jnp.round(x / a_scale), -(2 ** (bits - 1)), qmax(bits))
    return q * a_scale


def fake_quant_weight(w: jnp.ndarray, bits: int = 8) -> jnp.ndarray:
    """Per-output-channel symmetric quantize-dequantize (PTQ simulation).

    Numerically identical values to the int8 deployment path; the int32
    accumulation itself is exercised by the kernel tests.
    """
    w_q, scale = quantize_weight(w, bits)
    return (w_q.astype(jnp.float32) * scale[..., None, :]).astype(w.dtype)
