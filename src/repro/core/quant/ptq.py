"""End-to-end PTQ driver (CoQMoE section 3): calibrate -> reparameterize ->
quantize.

Pipeline (offline, mirrors the paper's 32-image calibration):

  1. ``calibrate_model``  — run the FP model eagerly over a small calibration
     set; ``TapCollector`` records per-channel min/max at every post-norm
     site and per-tensor absmax at every other linear-input site.
  2. ``ptq_model``        — per layer:
       * post-norm reparam (Eqs. 10-16): per-channel asymmetric params fold
         into the norm's (gamma, beta) and inversely into EVERY consumer —
         QKV, MLP fc1, and in MoE blocks every expert's fc1 plus the gating
         network. RMSNorm archs use the symmetric (r2 == 0) variant
         (DESIGN.md section 4).
       * inserts ``a_scale`` leaves (the per-layer symmetric scale s_tilde)
         that the runtime quantizer in ``models.layers.apply_norm`` uses;
       * inserts ``wo_a_scale`` per-tensor scales for the remaining linear
         inputs (attention out-proj, MLP/expert fc2);
       * weight int8 per-output-channel symmetric quantization. Two
         materializations (DESIGN.md section 4):
           - ``materialize="fake"`` (default): quantize-dequantize in f32 —
             the reference oracle, identical values to the int8 kernels;
           - ``materialize="int8"``: a **QuantizedParams** tree — each
             quantizable weight leaf is stored ``jnp.int8`` with a sibling
             ``<key>_scale`` per-output-channel dequant leaf and (where a
             static activation scale exists) a folded ``<key>_as`` per-site
             activation-scale leaf. ``models.layers.quant_linear`` executes
             these leaves through the int8 Pallas kernels
             (kernels/int8_matmul.py, kernels/expert_linear.py) — the
             weights are *executed* in the format they are stored in.

  ``fold_only=True`` performs ONLY the Eq. 10-16 fold — the result must be
  numerically equivalent to the FP model (the property the reparam is built
  on; tested in tests/test_quant.py).

The 4-bit log-sqrt2 post-softmax quantizer is runtime behaviour
(``cfg.quant.enable`` routes attention through the quantized kernel), not a
param transform, so it needs no work here.

Embedding lookups are not matmuls and stay FP (noted in DESIGN.md); the
modality-frontend input projection consumes raw stub embeddings and is
weight-quantized only.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.quant.calibrate import TapCollector
from repro.core.quant.linear_quant import fake_quant_weight, quantize_weight
from repro.core.quant.qtypes import (
    ASCALE_SUFFIX, SCALE_SUFFIX, pack_int4, qmax,
)

# Families whose every linear call site routes through the
# ``models.layers.quant_linear`` seam (int8 materialization supported).
INT8_FAMILIES = frozenset({"dense", "moe", "vlm", "vit", "vit_moe"})

# Leaf keys treated as quantizable linear weights (per-out-channel int8).
QUANT_WEIGHT_KEYS = frozenset(
    {
        "wq", "wk", "wv", "wo", "wi", "gate", "lm_head", "head",
        "patch_proj", "frontend_proj", "in_proj", "out_proj",
    }
)

# Supported ``ptq_model(materialize=...)`` modes (satellite: the validation
# error enumerates these).
MATERIALIZE_MODES = ("fake", "int8", "int4")

# Per-site schemes a scheme-map entry may name (DESIGN.md section 13).
SITE_SCHEMES = ("int8", "int4")

# The documented default when ``materialize="int4"`` is requested without a
# scheme map: ONLY the MoE expert stacks drop to int4; every sensitive site
# (router/gate, head, patch/frontend projections, attention) stays int8.
DEFAULT_INT4_SCHEME = (("moe.wi", "int4"), ("moe.wo", "int4"))

# Leaf keys that may legally carry an int4 scheme (the grouped expert path
# is the only consumer that executes nibble-packed leaves).
_INT4_SITE_KEYS = frozenset({"wi", "wo"})


def _scheme_dict(scheme_map) -> Dict[Tuple[str, ...], str]:
    """Validate a scheme map and key it by dotted-path pattern components."""
    out = {}
    for pat, sch in dict(scheme_map).items():
        if sch not in SITE_SCHEMES:
            raise ValueError(
                f"unknown scheme {sch!r} for site pattern {pat!r}; "
                f"supported schemes: {', '.join(SITE_SCHEMES)}"
            )
        parts = tuple(pat.split("."))
        if sch == "int4" and parts[-1] not in _INT4_SITE_KEYS:
            raise ValueError(
                f"int4 scheme requested for site pattern {pat!r}, but only "
                f"MoE expert stacks (moe.wi / moe.wo) execute nibble-packed "
                f"int4; sensitive sites (router, head, frontend, attention) "
                f"must stay int8"
            )
        out[parts] = sch
    return out


def _scheme_for(path: Tuple[str, ...], scheme: Dict[Tuple[str, ...], str]):
    """Longest dotted-suffix match of ``path`` against the scheme map;
    unmatched sites default to int8 (DESIGN.md section 13)."""
    best, best_len = "int8", 0
    for parts, sch in scheme.items():
        if len(parts) <= len(path) and path[-len(parts):] == parts \
                and len(parts) > best_len:
            best, best_len = sch, len(parts)
    return best


# ---------------------------------------------------------------------------
# Calibration
# ---------------------------------------------------------------------------

def calibrate_model(cfg: ModelConfig, params, batches: Sequence[dict]) -> TapCollector:
    """Run the FP model eagerly over calibration batches, recording taps."""
    from repro import models

    taps = TapCollector()
    for batch in batches:
        models.forward(params, cfg, batch, taps=taps)
    return taps


# ---------------------------------------------------------------------------
# Fold machinery
# ---------------------------------------------------------------------------

def _copy(tree):
    if isinstance(tree, dict):
        return {k: _copy(v) for k, v in tree.items()}
    return tree


def _get(tree, path: Tuple[str, ...]):
    for k in path:
        if not isinstance(tree, dict) or k not in tree:
            return None
        tree = tree[k]
    return tree


def _set(tree, path: Tuple[str, ...], val):
    for k in path[:-1]:
        tree = tree[k]
    tree[path[-1]] = val


def _stacked_factors(taps: TapCollector, names: List[str], bits: int,
                     symmetric: bool):
    """Per-layer reparam factors from recorded min/max: arrays [L, D]."""
    mins, maxs = [], []
    for n in names:
        st = taps.stats[n]
        mins.append(st["min"])
        maxs.append(st["max"])
    xmin = jnp.asarray(np.stack(mins))  # [L, D]
    xmax = jnp.asarray(np.stack(maxs))
    if symmetric:
        absmax = jnp.maximum(jnp.maximum(jnp.abs(xmin), jnp.abs(xmax)), 1e-8)
        s = absmax / qmax(bits)
        z = None
    else:
        span = jnp.maximum(xmax - xmin, 1e-8)
        s = span / (2**bits - 1)
        z = jnp.round(-xmin / s)
    s_tilde = jnp.mean(s, axis=-1)  # [L]
    r1 = s / s_tilde[:, None]
    r2 = jnp.zeros_like(s) if z is None else z - 2.0 ** (bits - 1)
    return r1, r2, s, s_tilde


def _fold_norm(norm_p: dict, r1, r2, s, rms: bool):
    """Eq. 11 on (possibly stacked) norm params. r1/r2/s: [..., D]."""
    if rms:
        # (1 + gamma)' = (1 + gamma) / r1  (rmsnorm uses the (1+g) convention)
        norm_p["scale"] = (1.0 + norm_p["scale"]) / r1 - 1.0
    else:
        norm_p["bias"] = (norm_p["bias"] + s * r2) / r1
        norm_p["scale"] = norm_p["scale"] / r1


def _fold_consumer(layer_p: dict, w_path: Tuple[str, ...], b_key: str,
                   r1, sr2, add_bias: bool):
    """Eq. 14/15/16: W' = diag(r1) W, b' = b - W^T (s . r2).

    W: [..., D, O] with the reparam'd dim at axis -2; r1/sr2: [..., D] with
    leading axes broadcast against W's leading (layer/expert) axes.
    """
    w = _get(layer_p, w_path)
    if w is None:
        return
    extra = w.ndim - r1.ndim - 1  # expert axes between layer dim and D
    shp = r1.shape[:-1] + (1,) * extra + (r1.shape[-1], 1)
    _set(layer_p, w_path, w * r1.reshape(shp))
    corr = jnp.sum(w * sr2.reshape(shp), axis=-2)  # [..., O]
    b_path = w_path[:-1] + (b_key,)
    b = _get(layer_p, b_path)
    if b is not None:
        _set(layer_p, b_path, b - corr)
    elif add_bias:
        _set(layer_p, b_path, -corr)


def _insert_scale(layer_p: dict, path: Tuple[str, ...], key: str, val):
    node = _get(layer_p, path) if path else layer_p
    if node is not None:
        node[key] = val


def _insert_ascale(layer_p: dict, w_path: Tuple[str, ...], val):
    """Fold a per-site activation scale next to the weight it feeds
    (``<wkey>_as``) so ``quant_linear`` is self-contained at apply time."""
    node = _get(layer_p, w_path[:-1]) if len(w_path) > 1 else layer_p
    if node is not None and w_path[-1] in node:
        node[w_path[-1] + ASCALE_SUFFIX] = val


def _absmax_scale(taps: TapCollector, names: List[str], bits: int):
    """Per-tensor symmetric activation scales, stacked [L]."""
    vals = [taps.absmax(n) / qmax(bits) for n in names]
    return jnp.asarray(vals, jnp.float32)


# Per-family layer-group table: (params_key, scope_prefix, norm sites).
# Each norm site: (norm_path, tap_suffix, [(consumer_w_path, bias_key)]).
_ATTN_SITE = (("ln1",), "post_ln1", [(("attn", "wq"), "bq"),
                                     (("attn", "wk"), "bk"),
                                     (("attn", "wv"), "bv")])
_MLP_SITE = (("ln2",), "post_ln2", [(("mlp", "wi"), "bi")])
_MOE_SITE = (("ln2",), "post_ln2", [(("moe", "gate"), "gate_b"),
                                    (("moe", "wi"), "bi")])
_MID_SITES = [  # (subtree, tap_suffix) -> wo_a_scale insertion points
    (("attn",), "attn_out"),
    (("xattn",), "x.attn_out"),  # enc-dec cross attention
    (("mlp",), "mlp_mid"),
    (("moe",), "moe_mid"),
]


def _layer_groups(cfg: ModelConfig, params) -> List[Tuple[str, str, list]]:
    fam = cfg.family
    if fam in ("dense", "moe", "vlm", "vit", "vit_moe"):
        groups = []
        for key, prefix in (("layers", "L"), ("layers_local", "Llocal"),
                            ("layers_global", "Lglobal"),
                            ("pairs_dense", "Ldense"), ("pairs_moe", "Lmoe")):
            if key not in params:
                continue
            sub = params[key]
            sites = [_ATTN_SITE, _MOE_SITE if "moe" in sub else _MLP_SITE]
            groups.append((key, prefix, sites))
        return groups
    if fam in ("ssm", "hybrid"):
        return [("layers", "L", [((("ln",)), "post_ln1",
                                  [(("mamba", "in_proj"), "in_bias")])])]
    if fam == "encdec":
        return [
            ("enc_layers", "Lenc", [_ATTN_SITE, _MLP_SITE]),
            ("dec_layers", "Ldec", [
                _ATTN_SITE,
                ((("lnx",)), "post_lnx", [(("xattn", "wq"), "bq")]),
                _MLP_SITE,
            ]),
        ]
    raise ValueError(f"PTQ: unsupported family {fam!r}")


def _site_bits(path: Tuple[str, ...], scheme, default_bits: int) -> int:
    if scheme and _scheme_for(path, scheme) == "int4":
        return 4
    return default_bits


def _check_int4_site(path: Tuple[str, ...]) -> None:
    if path[-1] not in _INT4_SITE_KEYS or "moe" not in path[:-1]:
        raise NotImplementedError(
            f"int4 scheme matched non-expert site {'.'.join(path)!r}; only "
            f"MoE expert stacks (moe.wi / moe.wo) execute nibble-packed "
            f"int4 — keep sensitive sites int8 in the scheme map"
        )


def _quantize_weights(tree, bits: int, scheme=None,
                      path: Tuple[str, ...] = ()):
    """Fake (quantize-dequantize) materialization; with a scheme map the
    matched sites use the 4-bit grid — the oracle for mixed int4 trees."""
    if isinstance(tree, dict):
        out = {}
        for k, v in tree.items():
            if isinstance(v, dict):
                out[k] = _quantize_weights(v, bits, scheme, path + (k,))
            elif k in QUANT_WEIGHT_KEYS and hasattr(v, "ndim") and v.ndim >= 2:
                out[k] = fake_quant_weight(
                    v, _site_bits(path + (k,), scheme, bits))
            else:
                out[k] = v
        return out
    return tree


def _materialize_stored(tree, bits: int, scheme=None,
                        path: Tuple[str, ...] = (), n_int4=None):
    """Replace quantizable weight leaves with stored-integer + dequant scale.

    int8 sites store ``jnp.int8``; scheme-matched int4 sites store
    nibble-packed ``jnp.uint8`` (two weights per byte along the input dim,
    :func:`repro.core.quant.qtypes.pack_int4`). Same per-output-channel
    symmetric grids as ``fake_quant_weight`` — the fake-quant tree (built
    with the same scheme map) is the numerical oracle for this one."""
    if isinstance(tree, dict):
        out = {}
        for k, v in tree.items():
            if isinstance(v, dict):
                out[k] = _materialize_stored(v, bits, scheme, path + (k,),
                                             n_int4)
            elif k in QUANT_WEIGHT_KEYS and hasattr(v, "ndim") and v.ndim >= 2:
                leaf_path = path + (k,)
                site_bits = _site_bits(leaf_path, scheme, bits)
                if site_bits == 4:
                    _check_int4_site(leaf_path)
                    w_q, w_scale = quantize_weight(v, 4)
                    out[k] = pack_int4(w_q)
                    if n_int4 is not None:
                        n_int4[0] += 1
                else:
                    w_q, w_scale = quantize_weight(v, site_bits)
                    out[k] = w_q
                out[k + SCALE_SUFFIX] = w_scale.astype(jnp.float32)
            else:
                out[k] = v
        return out
    return tree


def _n_stack(sub: dict) -> int:
    leaf = jax.tree.leaves(sub)[0]
    return leaf.shape[0]


def _fold_group_unstacked(sub: dict, scope: str, sites, taps: TapCollector,
                          a_bits: int, rms: bool, fold_only: bool,
                          ascale: bool = False):
    """Fold one unstacked (no leading layer dim) block, e.g. zamba2's shared
    attention block."""
    for norm_path, suffix, consumers in sites:
        name = f"{scope}.{suffix}"
        if name not in taps.stats:
            continue
        r1, r2, s, s_tilde = _stacked_factors(taps, [name], a_bits, rms)
        _fold_norm(_get(sub, norm_path), r1[0], r2[0], s[0], rms)
        for w_path, b_key in consumers:
            _fold_consumer(sub, w_path, b_key, r1[0], (s * r2)[0],
                           add_bias=not rms)
            if ascale:
                _insert_ascale(sub, w_path, s_tilde[0])
        if not fold_only:
            _insert_scale(sub, norm_path, "a_scale", s_tilde[0])
    if not fold_only:
        for mid_path, suffix in _MID_SITES:
            name = f"{scope}.{suffix}"
            if _get(sub, mid_path) is None or name not in taps.stats:
                continue
            _insert_scale(sub, mid_path, "wo_a_scale",
                          _absmax_scale(taps, [name], a_bits)[0])


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------

def ptq_model(cfg: ModelConfig, params, taps: TapCollector, *,
              fold_only: bool = False, materialize: str = "fake"):
    """Return the PTQ-transformed param tree (original is untouched).

    ``materialize`` selects the weight representation (ignored by
    ``fold_only``):

      * ``"fake"``: quantize-dequantize in f32 — the reference oracle the
        deployment path is validated against. Honors a non-empty
        ``cfg.quant.scheme_map`` (matched sites use the 4-bit grid), so it
        stays the oracle for mixed int4 trees too;
      * ``"int8"``: a QuantizedParams tree — weight leaves stored
        ``jnp.int8`` plus ``<key>_scale`` / ``<key>_as`` leaves, executed
        through the int8 kernels by ``models.layers.quant_linear``;
      * ``"int4"``: mixed-scheme QuantizedParams tree driven by
        ``cfg.quant.scheme_map`` — scheme-matched MoE expert stacks stored
        as nibble-packed ``jnp.uint8`` int4 (two weights per byte along the
        input dim), every other site int8 as above. An empty scheme map
        falls back to the documented experts-only default
        (``DEFAULT_INT4_SCHEME``) rather than silently quantizing sensitive
        sites. DESIGN.md section 13.
    """
    if materialize not in MATERIALIZE_MODES:
        raise ValueError(
            f"unknown materialize mode {materialize!r}; supported modes: "
            f"{', '.join(MATERIALIZE_MODES)}"
        )
    if materialize in ("int8", "int4") and not fold_only \
            and cfg.family not in INT8_FAMILIES:
        raise NotImplementedError(
            f"{materialize} materialization requires every linear site of "
            f"the family to route through models.layers.quant_linear; "
            f"{cfg.family!r} is not threaded yet "
            f"(supported: {sorted(INT8_FAMILIES)})"
        )
    scheme = None
    if materialize == "int4":
        scheme = _scheme_dict(cfg.quant.scheme_map or DEFAULT_INT4_SCHEME)
        if not any(s == "int4" for s in scheme.values()):
            raise ValueError(
                "materialize='int4' with a scheme map that names no int4 "
                "site; drop the map to get the experts-only default "
                "(DEFAULT_INT4_SCHEME) or add moe.wi/moe.wo entries"
            )
    elif cfg.quant.scheme_map:
        scheme = _scheme_dict(cfg.quant.scheme_map)
        if materialize == "int8" and any(
                s == "int4" for s in scheme.values()):
            raise ValueError(
                "scheme map names int4 sites but materialize='int8'; use "
                "materialize='int4' for mixed-scheme trees"
            )
        if materialize == "int8":
            scheme = None  # all-int8 map is the int8 path exactly
    rms = cfg.norm == "rmsnorm"
    a_bits = cfg.quant.a_bits
    w_bits = cfg.quant.w_bits
    ascale = materialize in ("int8", "int4") and not fold_only
    p = _copy(params)

    for key, prefix, sites in _layer_groups(cfg, p):
        sub = p[key]
        n = _n_stack(sub)
        for norm_path, suffix, consumers in sites:
            names = [f"{prefix}{i:03d}.{suffix}" for i in range(n)]
            if any(nm not in taps.stats for nm in names):
                continue
            r1, r2, s, s_tilde = _stacked_factors(taps, names, a_bits, rms)
            _fold_norm(_get(sub, norm_path), r1, r2, s, rms)
            for w_path, b_key in consumers:
                _fold_consumer(sub, w_path, b_key, r1, s * r2,
                               add_bias=not rms)
                if ascale:
                    _insert_ascale(sub, w_path, s_tilde)
            if not fold_only:
                _insert_scale(sub, norm_path, "a_scale", s_tilde)
        if not fold_only:
            for mid_path, suffix in _MID_SITES:
                names = [f"{prefix}{i:03d}.{suffix}" for i in range(n)]
                if _get(sub, mid_path) is None:
                    continue
                if any(nm not in taps.stats for nm in names):
                    continue
                # mid sites carry only wo_a_scale: quant_linear reads it as
                # the wo activation scale, same leaf the fake oracle uses
                _insert_scale(sub, mid_path, "wo_a_scale",
                              _absmax_scale(taps, names, a_bits))

    # zamba2: the single *shared* attention+MLP block (stats of all of its
    # applications merged during calibration — one weight set, Eq. 15 spirit).
    if cfg.family == "hybrid" and "shared" in p:
        _fold_group_unstacked(p["shared"], "shared",
                              [_ATTN_SITE, _MLP_SITE], taps, a_bits, rms,
                              fold_only, ascale=ascale)

    # Final norm -> head consumer (single, unstacked site).
    fn_site = "final_norm"
    head_key = None
    if cfg.family in ("vit", "vit_moe"):
        head_key = "head"
    elif not cfg.tie_embeddings and "lm_head" in p:
        head_key = "lm_head"
    if fn_site in taps.stats and head_key is not None:
        r1, r2, s, s_tilde = _stacked_factors(taps, [fn_site], a_bits, rms)
        _fold_norm(p["final_norm"], r1[0], r2[0], s[0], rms)
        w = p[head_key]
        corr = jnp.sum(w * (s[0] * r2[0])[:, None], axis=0)
        p[head_key] = w * r1[0][:, None]
        if cfg.family in ("vit", "vit_moe"):
            p["head_b"] = p["head_b"] - corr
        elif not rms:
            p["lm_head_b"] = -corr  # added to logits by logits_from_hidden
        if not fold_only:
            p["final_norm"]["a_scale"] = s_tilde[0]
        if ascale:
            p[head_key + ASCALE_SUFFIX] = s_tilde[0]

    # Encoder-output norm feeds every decoder layer's cross K/V (enc-dec).
    if cfg.family == "encdec" and "enc_norm_out" in taps.stats:
        r1, r2, s, s_tilde = _stacked_factors(
            taps, ["enc_norm_out"], a_bits, rms
        )
        _fold_norm(p["enc_norm"], r1[0], r2[0], s[0], rms)
        for wk, bk in ((("xattn", "wk"), "bk"), (("xattn", "wv"), "bv")):
            _fold_consumer(p["dec_layers"], wk, bk,
                           r1, s * r2, add_bias=not rms)
            if ascale:
                _insert_ascale(p["dec_layers"], wk, s_tilde[0])
        if not fold_only:
            p["enc_norm"]["a_scale"] = s_tilde[0]

    if not fold_only:
        if materialize in ("int8", "int4"):
            n_int4 = [0]
            p = _materialize_stored(p, w_bits, scheme, n_int4=n_int4)
            if materialize == "int4" and n_int4[0] == 0:
                raise ValueError(
                    "materialize='int4' produced no int4 leaves: the scheme "
                    "map matched no MoE expert stack in this model "
                    "(int4 targets moe.wi/moe.wo; dense models have none)"
                )
        else:
            p = _quantize_weights(p, w_bits, scheme)
    return p


def quantized_config(cfg: ModelConfig) -> ModelConfig:
    """The runtime config to pair with ``ptq_model`` output (W8A8 + Attn4)."""
    import dataclasses

    return cfg.replace(quant=dataclasses.replace(cfg.quant, enable=True))
