"""Quantization primitives: symmetric/asymmetric uniform quantizers (paper
section 2.2, Eqs. 6-9) and the quantized-tensor container.

All quantizers are pure jnp and differentiable-free (PTQ only, as in the
paper). Integer matmuls use ``preferred_element_type=int32`` so XLA lowers
them to the MXU int8 path on TPU.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Sequence

import jax.numpy as jnp
import numpy as np


# QuantizedParams leaf-naming contract (DESIGN.md section 4): a materialized
# int8 weight leaf ``<key>`` rides with a per-output-channel dequant scale
# ``<key>_scale`` (f32 [..., out]) and, at sites with a calibrated static
# activation scale, a folded per-site scale ``<key>_as`` (f32 scalar per
# layer). ``models.layers.quant_linear`` dispatches on the weight dtype.
SCALE_SUFFIX = "_scale"
ASCALE_SUFFIX = "_as"


def is_quantized_weight(leaf) -> bool:
    """True for a materialized int8 weight leaf of a QuantizedParams tree."""
    return (
        hasattr(leaf, "dtype")
        and leaf.dtype == jnp.int8
        and getattr(leaf, "ndim", 0) >= 2
    )


def qmax(bits: int) -> int:
    return 2 ** (bits - 1) - 1


def qmin(bits: int) -> int:
    return -(2 ** (bits - 1))


def int_dtype(bits: int):
    return jnp.int8 if bits <= 8 else jnp.int16


class QTensor(NamedTuple):
    """A symmetric-quantized tensor: ``x ~= q * scale`` (Eq. 7)."""

    q: jnp.ndarray  # int8/int16
    scale: jnp.ndarray  # f32, scalar (per-tensor) or broadcastable (per-channel)

    def dequant(self) -> jnp.ndarray:
        return self.q.astype(jnp.float32) * self.scale


class AsymParams(NamedTuple):
    """Asymmetric quantization parameters (Eq. 6): per-channel (s, z)."""

    scale: jnp.ndarray  # f32 [D]
    zero: jnp.ndarray  # int32 [D]


# ---------------------------------------------------------------------------
# Symmetric (Eq. 7)
# ---------------------------------------------------------------------------

def sym_scale_from_absmax(absmax: jnp.ndarray, bits: int) -> jnp.ndarray:
    return jnp.maximum(absmax, 1e-8) / qmax(bits)


def quantize_sym(x: jnp.ndarray, scale: jnp.ndarray, bits: int) -> jnp.ndarray:
    q = jnp.round(x / scale)
    return jnp.clip(q, qmin(bits), qmax(bits)).astype(int_dtype(bits))


def dequantize_sym(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def quantize_sym_calibrated(
    x: jnp.ndarray, bits: int, axis: Optional[Sequence[int]] = None
) -> QTensor:
    """Calibrate absmax over ``axis`` (None = per-tensor) and quantize."""
    absmax = jnp.max(jnp.abs(x)) if axis is None else jnp.max(
        jnp.abs(x), axis=tuple(axis), keepdims=True
    )
    scale = sym_scale_from_absmax(absmax, bits)
    return QTensor(quantize_sym(x, scale, bits), scale)


# ---------------------------------------------------------------------------
# Asymmetric (Eq. 6)
# ---------------------------------------------------------------------------

def asym_params_from_minmax(
    xmin: jnp.ndarray, xmax: jnp.ndarray, bits: int
) -> AsymParams:
    # the representable range must include 0 (standard convention) — also
    # keeps the zero-point finite for constant tensors far from zero
    xmin = jnp.minimum(xmin, 0.0)
    xmax = jnp.maximum(xmax, 0.0)
    span = jnp.maximum(xmax - xmin, 1e-8)
    scale = span / (2**bits - 1)
    zero = jnp.round(-xmin / scale) + qmin(bits)
    return AsymParams(scale.astype(jnp.float32), zero.astype(jnp.int32))


def quantize_asym(x: jnp.ndarray, p: AsymParams, bits: int) -> jnp.ndarray:
    q = jnp.round(x / p.scale) + p.zero
    return jnp.clip(q, qmin(bits), qmax(bits)).astype(jnp.int32)


def dequantize_asym(q: jnp.ndarray, p: AsymParams) -> jnp.ndarray:
    return (q - p.zero).astype(jnp.float32) * p.scale


# ---------------------------------------------------------------------------
# Integer matmul helper (MXU int8 path on TPU)
# ---------------------------------------------------------------------------

def int_matmul(a_q: jnp.ndarray, b_q: jnp.ndarray) -> jnp.ndarray:
    """int8 x int8 -> int32 accumulate; lowers to the TPU MXU int8 datapath."""
    return jnp.matmul(
        a_q.astype(jnp.int8), b_q.astype(jnp.int8),
        preferred_element_type=jnp.int32,
    )


def np_sqnr_db(x_ref: np.ndarray, x_hat: np.ndarray) -> float:
    """Signal-to-quantization-noise ratio in dB (benchmark metric)."""
    num = float(np.sum(x_ref.astype(np.float64) ** 2))
    den = float(np.sum((x_ref.astype(np.float64) - x_hat.astype(np.float64)) ** 2))
    if den == 0:
        return float("inf")
    return 10.0 * np.log10(num / den)
