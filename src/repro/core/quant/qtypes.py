"""Quantization primitives: symmetric/asymmetric uniform quantizers (paper
section 2.2, Eqs. 6-9) and the quantized-tensor container.

All quantizers are pure jnp and differentiable-free (PTQ only, as in the
paper). Integer matmuls use ``preferred_element_type=int32`` so XLA lowers
them to the MXU int8 path on TPU.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Sequence

import jax.numpy as jnp
import numpy as np


# QuantizedParams leaf-naming contract (DESIGN.md sections 4/13): a
# materialized sub-fp weight leaf ``<key>`` rides with a per-output-channel
# dequant scale ``<key>_scale`` (f32 [..., out]) and, at sites with a
# calibrated static activation scale, a folded per-site scale ``<key>_as``
# (f32 scalar per layer). ``models.layers.quant_linear`` and the grouped
# expert path dispatch on the weight dtype: ``jnp.int8`` = stored int8,
# ``jnp.uint8`` = nibble-packed int4 (two signed 4-bit weights per byte
# along the input dim — see pack_int4/unpack_int4).
SCALE_SUFFIX = "_scale"
ASCALE_SUFFIX = "_as"


def is_quantized_weight(leaf) -> bool:
    """True for a materialized int8 weight leaf of a QuantizedParams tree."""
    return (
        hasattr(leaf, "dtype")
        and leaf.dtype == jnp.int8
        and getattr(leaf, "ndim", 0) >= 2
    )


# canonical name for the int8 predicate (the int4 predicate's sibling)
is_int8_leaf = is_quantized_weight


def is_int4_leaf(leaf) -> bool:
    """True for a nibble-packed int4 weight leaf (``uint8`` storage, two
    signed 4-bit weights per byte along the input dim; DESIGN.md §13). No
    other QuantizedParams leaf is stored ``uint8``, so the dtype alone is
    the dispatch key."""
    return (
        hasattr(leaf, "dtype")
        and leaf.dtype == jnp.uint8
        and getattr(leaf, "ndim", 0) >= 2
    )


# ---------------------------------------------------------------------------
# Int4 nibble packing (DESIGN.md section 13)
#
# Layout: packing runs along the *input* (contraction) dim, axis -2 of a
# [..., Din, Dout] weight — so per-output-channel scales and Dout tiling
# are untouched.  byte[p] = (q[2p+1] & 0xF) << 4 | (q[2p] & 0xF): the LOW
# nibble holds the EVEN logical row 2p, the HIGH nibble the ODD row 2p+1.
# An odd Din is zero-padded to even before packing (a zero weight row
# contributes nothing regardless of the activation multiplied against it),
# so the packed dim is ceil(Din/2) and consumers pad x to 2*ceil(Din/2).
# ---------------------------------------------------------------------------

PACK_AXIS = -2  # the input/contraction dim of a [..., Din, Dout] weight


def packed_rows(din: int) -> int:
    """Packed-dim length for a logical input dim ``din``."""
    return -(-din // 2)


def pack_int4(q: jnp.ndarray) -> jnp.ndarray:
    """Pack int4-valued ``q`` ([..., Din, Dout], values in [-8, 7]) into
    nibble-packed ``uint8`` [..., ceil(Din/2), Dout]."""
    if q.shape[PACK_AXIS] % 2:
        pad = [(0, 0)] * q.ndim
        pad[PACK_AXIS] = (0, 1)
        q = jnp.pad(q, pad)
    lo = q[..., 0::2, :].astype(jnp.int32) & 0xF
    hi = q[..., 1::2, :].astype(jnp.int32) & 0xF
    return (lo | (hi << 4)).astype(jnp.uint8)


def unpack_int4(packed: jnp.ndarray, din: Optional[int] = None) -> jnp.ndarray:
    """Invert :func:`pack_int4`: ``uint8`` [..., P, Dout] -> sign-extended
    ``int8``-stored int4 values [..., din (default 2*P), Dout]."""
    b = packed.astype(jnp.int32)
    lo = b & 0xF
    hi = (b >> 4) & 0xF
    # two's-complement sign extension of a 4-bit field: v - 16*(v>>3)
    lo = lo - ((lo & 0x8) << 1)
    hi = hi - ((hi & 0x8) << 1)
    full = jnp.stack([lo, hi], axis=-2)  # [..., P, 2, Dout]
    full = full.reshape(packed.shape[:-2] + (2 * packed.shape[-2],
                                             packed.shape[-1]))
    if din is not None:
        full = full[..., :din, :]
    return full.astype(jnp.int8)


def qmax(bits: int) -> int:
    return 2 ** (bits - 1) - 1


def qmin(bits: int) -> int:
    return -(2 ** (bits - 1))


def int_dtype(bits: int):
    return jnp.int8 if bits <= 8 else jnp.int16


class QTensor(NamedTuple):
    """A symmetric-quantized tensor: ``x ~= q * scale`` (Eq. 7)."""

    q: jnp.ndarray  # int8/int16
    scale: jnp.ndarray  # f32, scalar (per-tensor) or broadcastable (per-channel)

    def dequant(self) -> jnp.ndarray:
        return self.q.astype(jnp.float32) * self.scale


class AsymParams(NamedTuple):
    """Asymmetric quantization parameters (Eq. 6): per-channel (s, z)."""

    scale: jnp.ndarray  # f32 [D]
    zero: jnp.ndarray  # int32 [D]


# ---------------------------------------------------------------------------
# Symmetric (Eq. 7)
# ---------------------------------------------------------------------------

def sym_scale_from_absmax(absmax: jnp.ndarray, bits: int) -> jnp.ndarray:
    return jnp.maximum(absmax, 1e-8) / qmax(bits)


def quantize_sym(x: jnp.ndarray, scale: jnp.ndarray, bits: int) -> jnp.ndarray:
    q = jnp.round(x / scale)
    return jnp.clip(q, qmin(bits), qmax(bits)).astype(int_dtype(bits))


def dequantize_sym(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def quantize_sym_calibrated(
    x: jnp.ndarray, bits: int, axis: Optional[Sequence[int]] = None
) -> QTensor:
    """Calibrate absmax over ``axis`` (None = per-tensor) and quantize."""
    absmax = jnp.max(jnp.abs(x)) if axis is None else jnp.max(
        jnp.abs(x), axis=tuple(axis), keepdims=True
    )
    scale = sym_scale_from_absmax(absmax, bits)
    return QTensor(quantize_sym(x, scale, bits), scale)


# ---------------------------------------------------------------------------
# Asymmetric (Eq. 6)
# ---------------------------------------------------------------------------

def asym_params_from_minmax(
    xmin: jnp.ndarray, xmax: jnp.ndarray, bits: int
) -> AsymParams:
    # the representable range must include 0 (standard convention) — also
    # keeps the zero-point finite for constant tensors far from zero
    xmin = jnp.minimum(xmin, 0.0)
    xmax = jnp.maximum(xmax, 0.0)
    span = jnp.maximum(xmax - xmin, 1e-8)
    scale = span / (2**bits - 1)
    zero = jnp.round(-xmin / scale) + qmin(bits)
    return AsymParams(scale.astype(jnp.float32), zero.astype(jnp.int32))


def quantize_asym(x: jnp.ndarray, p: AsymParams, bits: int) -> jnp.ndarray:
    q = jnp.round(x / p.scale) + p.zero
    return jnp.clip(q, qmin(bits), qmax(bits)).astype(jnp.int32)


def dequantize_asym(q: jnp.ndarray, p: AsymParams) -> jnp.ndarray:
    return (q - p.zero).astype(jnp.float32) * p.scale


# ---------------------------------------------------------------------------
# Integer matmul helper (MXU int8 path on TPU)
# ---------------------------------------------------------------------------

def int_matmul(a_q: jnp.ndarray, b_q: jnp.ndarray) -> jnp.ndarray:
    """int8 x int8 -> int32 accumulate; lowers to the TPU MXU int8 datapath."""
    return jnp.matmul(
        a_q.astype(jnp.int8), b_q.astype(jnp.int8),
        preferred_element_type=jnp.int32,
    )


def np_sqnr_db(x_ref: np.ndarray, x_hat: np.ndarray) -> float:
    """Signal-to-quantization-noise ratio in dB (benchmark metric)."""
    num = float(np.sum(x_ref.astype(np.float64) ** 2))
    den = float(np.sum((x_ref.astype(np.float64) - x_hat.astype(np.float64)) ** 2))
    if den == 0:
        return float("inf")
    return 10.0 * np.log10(num / den)
