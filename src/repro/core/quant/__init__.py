"""CoQMoE dual-stage quantization (paper section 3)."""
from repro.core.quant.calibrate import TapCollector, maybe_record
from repro.core.quant.linear_quant import (
    QLinear,
    fake_quant_activation,
    make_qlinear,
    qlinear_apply,
    qlinear_apply_prequant,
    quantize_weight,
)
from repro.core.quant.qtypes import (
    ASCALE_SUFFIX,
    AsymParams,
    SCALE_SUFFIX,
    asym_params_from_minmax,
    is_quantized_weight,
    QTensor,
    dequantize_asym,
    dequantize_sym,
    int_matmul,
    np_sqnr_db,
    qmax,
    qmin,
    quantize_asym,
    quantize_sym,
    quantize_sym_calibrated,
    sym_scale_from_absmax,
)
from repro.core.quant.reparam import (
    ReparamFactors,
    apply_to_consumer,
    apply_to_layernorm,
    apply_to_rmsnorm,
    calibrate_per_channel_asym,
    calibrate_per_channel_sym,
    reparam_factors,
    transform_activation,
)
from repro.core.quant.softmax_quant import (
    SQRT2,
    logsqrt2_dequantize,
    logsqrt2_quantize,
    logsqrt2_scale_factor,
    parity_decomposition,
    quantized_softmax_numerator,
)

__all__ = [k for k in dir() if not k.startswith("_")]
