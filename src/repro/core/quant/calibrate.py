"""Activation-statistics collection for PTQ calibration.

The paper calibrates from 32 images (section 5.1): the FP model runs eagerly
(unjitted) over a small calibration set while `Tap` objects record per-site
activation statistics. Model apply functions accept an optional ``taps``
collector and call ``taps.record(site, x)`` at quantization sites.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax.numpy as jnp
import numpy as np


class TapCollector:
    """Records running min/max/absmax per named site (host-side, eager)."""

    def __init__(self) -> None:
        self.stats: Dict[str, Dict[str, np.ndarray]] = {}
        self.samples: Dict[str, list] = {}
        self.keep_samples: bool = False

    def record(self, site: str, x: jnp.ndarray) -> None:
        d = x.shape[-1]
        flat = np.asarray(x, dtype=np.float32).reshape(-1, d)
        st = self.stats.get(site)
        if st is None:
            self.stats[site] = {
                "min": flat.min(axis=0),
                "max": flat.max(axis=0),
                "absmax": np.abs(flat).max(),
            }
        else:
            st["min"] = np.minimum(st["min"], flat.min(axis=0))
            st["max"] = np.maximum(st["max"], flat.max(axis=0))
            st["absmax"] = max(st["absmax"], float(np.abs(flat).max()))
        if self.keep_samples:
            self.samples.setdefault(site, []).append(flat)

    # -- views ---------------------------------------------------------------
    def channel_minmax(self, site: str):
        st = self.stats[site]
        return jnp.asarray(st["min"]), jnp.asarray(st["max"])

    def absmax(self, site: str) -> float:
        return float(self.stats[site]["absmax"])

    def sites(self):
        return sorted(self.stats)

    def scoped(self, prefix: str) -> "ScopedTaps":
        return ScopedTaps(self, prefix)


class ScopedTaps:
    """Per-layer view of a TapCollector: prepends ``prefix.`` to site names."""

    def __init__(self, base, prefix: str) -> None:
        self.base = base
        self.prefix = prefix

    def record(self, site: str, x: jnp.ndarray) -> None:
        self.base.record(f"{self.prefix}.{site}", x)

    def scoped(self, prefix: str) -> "ScopedTaps":
        return ScopedTaps(self.base, f"{self.prefix}.{prefix}")


def maybe_record(taps: Optional[TapCollector], site: str, x: jnp.ndarray) -> None:
    """No-op under jit (taps is None in jitted paths)."""
    if taps is not None:
        taps.record(site, x)
