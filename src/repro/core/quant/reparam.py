"""Post-norm scale reparameterization (CoQMoE section 3.1, Eqs. 10-16).

Converts *per-channel asymmetric* quantization of post-LayerNorm activations
into *per-layer symmetric* quantization by folding transformation factors into
the norm's (gamma, beta) and inversely into every consumer linear layer's
(W, b) -- QKV projections, MLP fc1, and in MoE blocks every expert's fc1 plus
the gating network (Eqs. 15-16).

Math note (recorded in DESIGN.md): the paper's Eq. 10 prints ``r1 = s_tilde/s``
but the equivalence in Eq. 13 together with integer-grid alignment requires
``r1 = s / s_tilde`` (the RepQ-ViT convention). With that choice:

    X'_d = (X_d + s_d r2_d) / r1_d            (Eq. 12)
    round(X'_d / s_tilde) = round(X_d / s_d) + z_d - 2^{b-1}

i.e. per-layer symmetric quantization of X' reproduces the per-channel
asymmetric integer grid of X exactly, and

    X' (diag(r1) W) + (b - W^T (s . r2)) == X W + b   (Eq. 13, any r1)

RMSNorm adaptation (no additive beta): we calibrate per-channel *symmetric*
scales (z == 2^{b-1}, r2 == 0) and fold only r1 -- see DESIGN.md section 4.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax.numpy as jnp

from repro.core.quant.qtypes import qmax


class ReparamFactors(NamedTuple):
    r1: jnp.ndarray  # f32 [D]   = s / s_tilde
    r2: jnp.ndarray  # f32 [D]   = z - 2^{b-1}  (zeros for symmetric/RMSNorm)
    s: jnp.ndarray  # f32 [D]    per-channel scales (calibrated)
    s_tilde: jnp.ndarray  # f32 scalar  unified per-layer scale


# ---------------------------------------------------------------------------
# Calibration of the *complex* quantizer (offline only; never runs on device)
# ---------------------------------------------------------------------------

def calibrate_per_channel_asym(x: jnp.ndarray, bits: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Unsigned-convention per-channel asymmetric params from samples.

    x: [..., D] activation samples. Returns (s[D], z[D]) with
    X_qu = round(X/s) + z in [0, 2^b - 1].
    """
    d = x.shape[-1]
    flat = x.reshape(-1, d)
    xmin = jnp.min(flat, axis=0)
    xmax = jnp.max(flat, axis=0)
    span = jnp.maximum(xmax - xmin, 1e-8)
    s = span / (2**bits - 1)
    # z is deliberately NOT clipped into [0, 2^b-1]: channels whose range does
    # not straddle zero need an out-of-range zero-point for an exact grid; it
    # is folded away by the reparameterization and never materialized on device.
    z = jnp.round(-xmin / s)
    return s.astype(jnp.float32), z.astype(jnp.float32)


def calibrate_per_channel_sym(x: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Per-channel symmetric scales (RMSNorm path: no zero-point home)."""
    d = x.shape[-1]
    flat = x.reshape(-1, d)
    absmax = jnp.maximum(jnp.max(jnp.abs(flat), axis=0), 1e-8)
    return (absmax / qmax(bits)).astype(jnp.float32)


def factors_from_minmax(
    xmin: jnp.ndarray, xmax: jnp.ndarray, bits: int, symmetric: bool
) -> ReparamFactors:
    """Factors straight from calibrated per-channel min/max (TapCollector).

    symmetric=True is the RMSNorm path (no zero-point home): per-channel
    symmetric scales, r2 == 0.
    """
    if symmetric:
        absmax = jnp.maximum(jnp.maximum(jnp.abs(xmin), jnp.abs(xmax)), 1e-8)
        s = absmax / qmax(bits)
        return reparam_factors(s.astype(jnp.float32), None, bits)
    span = jnp.maximum(xmax - xmin, 1e-8)
    s = span / (2**bits - 1)
    z = jnp.round(-xmin / s)
    return reparam_factors(s.astype(jnp.float32), z.astype(jnp.float32), bits)


def reparam_factors(
    s: jnp.ndarray, z: Optional[jnp.ndarray], bits: int
) -> ReparamFactors:
    """Eq. 10 (corrected): r1 = s/s_tilde, r2 = z - 2^{b-1}; s_tilde = E[s]."""
    s_tilde = jnp.mean(s)
    r1 = s / s_tilde
    if z is None:
        r2 = jnp.zeros_like(s)
    else:
        r2 = z - 2.0 ** (bits - 1)
    return ReparamFactors(r1=r1, r2=r2, s=s, s_tilde=s_tilde)


# ---------------------------------------------------------------------------
# Folding (Eqs. 11, 14, 15, 16)
# ---------------------------------------------------------------------------

def apply_to_layernorm(
    gamma: jnp.ndarray, beta: jnp.ndarray, f: ReparamFactors
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Eq. 11: beta' = (beta + s.r2)/r1, gamma' = gamma/r1."""
    beta_p = (beta + f.s * f.r2) / f.r1
    gamma_p = gamma / f.r1
    return gamma_p, beta_p


def apply_to_rmsnorm(gamma: jnp.ndarray, f: ReparamFactors) -> jnp.ndarray:
    """RMSNorm variant: r2 == 0 by construction, fold r1 only."""
    return gamma / f.r1


def apply_to_consumer(
    w: jnp.ndarray, b: Optional[jnp.ndarray], f: ReparamFactors
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Eq. 14 (and 15/16 for experts & gate): W' = diag(r1) W, b' = b - W^T(s.r2).

    w: [D, out] consumer weight whose *input* is the reparameterized activation.
    """
    w_p = w * f.r1[:, None]
    shift = f.s * f.r2
    corr = jnp.einsum("do,d->o", w, shift)
    b_p = (b if b is not None else 0.0) - corr
    return w_p, b_p


def transform_activation(x: jnp.ndarray, f: ReparamFactors) -> jnp.ndarray:
    """Eq. 12 (reference only -- at runtime the fold into gamma/beta does this)."""
    return (x + f.s * f.r2) / f.r1
