from repro.core.moe.dispatch import (
    GroupedDispatch,
    capacity,
    grouped_combine,
    grouped_dispatch,
    gshard_dispatch_combine,
    quantize_ep_payload,
)
from repro.core.moe.router import RouterOut, route_topk

__all__ = [k for k in dir() if not k.startswith("_")]
