"""Token dispatch for MoE expert computation — two execution modes
(DESIGN.md section 2: the paper's runtime-reconfigurable unified kernel).

``grouped``  — the paper's orchestration, TPU-adapted: tokens are *sorted by
               expert id* (the sort is the TPU-idiomatic analogue of the
               round-robin hardware router in Fig. 5(b)), then a single
               grouped matmul streams each expert's weights HBM->VMEM exactly
               once per layer — O(1) weight traffic w.r.t. token parallelism.
               Dense MLP is the same path with num_groups == 1.

``gshard``   — capacity-based dispatch/combine einsums (GSPMD-native EP for
               large-scale training; all-to-alls are inserted automatically
               when the expert dim is sharded over the 'model' mesh axis).
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


class GroupedDispatch(NamedTuple):
    x_sorted: jnp.ndarray  # [T*k, D] tokens gathered in expert order
    group_sizes: jnp.ndarray  # [E] int32 tokens per expert
    sort_idx: jnp.ndarray  # [T*k] permutation into expert order
    token_idx: jnp.ndarray  # [T*k] source token of each sorted row
    weights_sorted: jnp.ndarray  # [T*k] combine weight of each sorted row


def grouped_dispatch(x: jnp.ndarray, experts: jnp.ndarray,
                     weights: jnp.ndarray, num_experts: int) -> GroupedDispatch:
    """Sort-based dispatch. x: [T, D]; experts/weights: [T, k]."""
    T, k = experts.shape
    flat_e = experts.reshape(-1)  # [T*k]
    flat_w = weights.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)
    sort_idx = jnp.argsort(flat_e, stable=True)
    token_idx = flat_t[sort_idx]
    x_sorted = x[token_idx]
    group_sizes = jnp.bincount(flat_e, length=num_experts).astype(jnp.int32)
    return GroupedDispatch(
        x_sorted=x_sorted,
        group_sizes=group_sizes,
        sort_idx=sort_idx,
        token_idx=token_idx,
        weights_sorted=flat_w[sort_idx],
    )


def grouped_combine(y_sorted: jnp.ndarray, d: GroupedDispatch,
                    num_tokens: int) -> jnp.ndarray:
    """Weighted scatter-add back to token order (Eq. 5 aggregation)."""
    y_w = y_sorted * d.weights_sorted[:, None].astype(y_sorted.dtype)
    out = jnp.zeros((num_tokens, y_sorted.shape[-1]), y_sorted.dtype)
    return out.at[d.token_idx].add(y_w)


# ---------------------------------------------------------------------------
# Expert-parallel exchange plan (distributed/expert_parallel.py)
# ---------------------------------------------------------------------------

def expert_of_sorted_rows(group_sizes: jnp.ndarray, n_rows: int) -> jnp.ndarray:
    """Group (expert) id of each row of an expert-sorted buffer ([R] int32).

    Inverse of the ``group_sizes`` histogram: row i belongs to the group
    whose cumulative-size interval contains i. Rows beyond
    ``sum(group_sizes)`` map past the last group (callers treat them as
    padding)."""
    ends = jnp.cumsum(group_sizes)
    return jnp.searchsorted(ends, jnp.arange(n_rows), side="right").astype(
        jnp.int32
    )


class EPExchangePlan(NamedTuple):
    """Where each expert-sorted row goes in the all_to_all send buffer.

    Shard ``s`` of ``n_shards`` owns the contiguous expert range
    ``[s*E_local, (s+1)*E_local)`` — because rows are sorted by expert id,
    each destination shard's rows form one contiguous run."""

    row_shard: jnp.ndarray  # [R] destination shard of each sorted row
    row_pos: jnp.ndarray  # [R] position within that shard's send slice
    row_local_expert: jnp.ndarray  # [R] expert id local to the dest shard
    shard_counts: jnp.ndarray  # [n_shards] rows bound for each shard


def ep_exchange_plan(group_sizes: jnp.ndarray, n_shards: int,
                     n_rows: int) -> EPExchangePlan:
    """Static-shape send plan for the expert-parallel token exchange."""
    num_experts = group_sizes.shape[0]
    e_local = num_experts // n_shards
    shard_counts = group_sizes.reshape(n_shards, e_local).sum(-1)
    start = jnp.concatenate([
        jnp.zeros((1,), jnp.int32),
        jnp.cumsum(shard_counts)[:-1].astype(jnp.int32),
    ])
    row_expert = expert_of_sorted_rows(group_sizes, n_rows)
    # rows past sum(group_sizes) (none in practice: dispatch is dropless)
    # would index past the table; clamp keeps the gather in bounds
    row_expert = jnp.minimum(row_expert, num_experts - 1)
    row_shard = row_expert // e_local
    row_pos = jnp.arange(n_rows, dtype=jnp.int32) - start[row_shard]
    return EPExchangePlan(
        row_shard=row_shard,
        row_pos=row_pos,
        row_local_expert=row_expert % e_local,
        shard_counts=shard_counts.astype(jnp.int32),
    )


def quantize_ep_payload(x_sorted: jnp.ndarray, a_scale: jnp.ndarray,
                        bits: int = 8) -> jnp.ndarray:
    """Quantize expert-sorted exchange rows to int8 with the folded fc1
    activation scale (the ``wi_as`` leaf of a QuantizedParams tree).

    This is exactly the quantization ``kernels.ops.grouped_matmul`` would
    apply to fp rows *after* the exchange — it is elementwise per row, so
    quantize-then-exchange is bit-identical to exchange-then-quantize
    while moving 4x fewer bytes through the all_to_all. The grouped kernel
    consumes the int8 rows directly (int8 x int8 -> int32 with the
    product-of-scales dequant at the flush)."""
    from repro.core.quant.qtypes import quantize_sym

    return quantize_sym(x_sorted.astype(jnp.float32), a_scale, bits)


# ---------------------------------------------------------------------------
# GShard-style capacity dispatch (training at scale under GSPMD)
# ---------------------------------------------------------------------------

def capacity(T: int, k: int, E: int, factor: float) -> int:
    c = int(T * k * factor / E) + 1
    return max(4, min(c, T))


def gshard_dispatch_combine(
    x: jnp.ndarray,  # [T, D]
    experts: jnp.ndarray,  # [T, k]
    weights: jnp.ndarray,  # [T, k]
    num_experts: int,
    cap: int,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (dispatch [T, E, C] bool, combine [T, E, C] f32).

    Position-in-expert computed per (token, slot) in routing priority order;
    tokens overflowing an expert's capacity are dropped (standard GShard).
    """
    T, k = experts.shape
    onehot = jax.nn.one_hot(experts, num_experts, dtype=jnp.int32)  # [T,k,E]
    flat = onehot.reshape(T * k, num_experts)
    pos = jnp.cumsum(flat, axis=0) - flat  # [T*k, E] position in expert queue
    pos = jnp.sum(pos * flat, axis=-1).reshape(T, k)  # [T, k]
    keep = pos < cap
    pos = jnp.where(keep, pos, 0)  # clamped; masked out by `keep` below
    # dispatch [T, E, C]: for each (t, slot) mark (expert, position)
    disp = jnp.einsum(
        "tke,tkc->tec",
        jax.nn.one_hot(experts, num_experts, dtype=jnp.float32)
        * keep[..., None],
        jax.nn.one_hot(pos, cap, dtype=jnp.float32),
    )
    comb = jnp.einsum("tk,tke,tkc->tec",
                      weights.astype(jnp.float32),
                      jax.nn.one_hot(experts, num_experts, dtype=jnp.float32)
                      * keep[..., None],
                      jax.nn.one_hot(pos, cap, dtype=jnp.float32))
    return disp, comb
