"""Token dispatch for MoE expert computation — two execution modes
(DESIGN.md section 2: the paper's runtime-reconfigurable unified kernel).

``grouped``  — the paper's orchestration, TPU-adapted: tokens are *sorted by
               expert id* (the sort is the TPU-idiomatic analogue of the
               round-robin hardware router in Fig. 5(b)), then a single
               grouped matmul streams each expert's weights HBM->VMEM exactly
               once per layer — O(1) weight traffic w.r.t. token parallelism.
               Dense MLP is the same path with num_groups == 1.

``gshard``   — capacity-based dispatch/combine einsums (GSPMD-native EP for
               large-scale training; all-to-alls are inserted automatically
               when the expert dim is sharded over the 'model' mesh axis).
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


class GroupedDispatch(NamedTuple):
    x_sorted: jnp.ndarray  # [T*k, D] tokens gathered in expert order
    group_sizes: jnp.ndarray  # [E] int32 tokens per expert
    sort_idx: jnp.ndarray  # [T*k] permutation into expert order
    token_idx: jnp.ndarray  # [T*k] source token of each sorted row
    weights_sorted: jnp.ndarray  # [T*k] combine weight of each sorted row


def grouped_dispatch(x: jnp.ndarray, experts: jnp.ndarray,
                     weights: jnp.ndarray, num_experts: int) -> GroupedDispatch:
    """Sort-based dispatch. x: [T, D]; experts/weights: [T, k]."""
    T, k = experts.shape
    flat_e = experts.reshape(-1)  # [T*k]
    flat_w = weights.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)
    sort_idx = jnp.argsort(flat_e, stable=True)
    token_idx = flat_t[sort_idx]
    x_sorted = x[token_idx]
    group_sizes = jnp.bincount(flat_e, length=num_experts).astype(jnp.int32)
    return GroupedDispatch(
        x_sorted=x_sorted,
        group_sizes=group_sizes,
        sort_idx=sort_idx,
        token_idx=token_idx,
        weights_sorted=flat_w[sort_idx],
    )


def grouped_combine(y_sorted: jnp.ndarray, d: GroupedDispatch,
                    num_tokens: int) -> jnp.ndarray:
    """Weighted scatter-add back to token order (Eq. 5 aggregation)."""
    y_w = y_sorted * d.weights_sorted[:, None].astype(y_sorted.dtype)
    out = jnp.zeros((num_tokens, y_sorted.shape[-1]), y_sorted.dtype)
    return out.at[d.token_idx].add(y_w)


# ---------------------------------------------------------------------------
# GShard-style capacity dispatch (training at scale under GSPMD)
# ---------------------------------------------------------------------------

def capacity(T: int, k: int, E: int, factor: float) -> int:
    c = int(T * k * factor / E) + 1
    return max(4, min(c, T))


def gshard_dispatch_combine(
    x: jnp.ndarray,  # [T, D]
    experts: jnp.ndarray,  # [T, k]
    weights: jnp.ndarray,  # [T, k]
    num_experts: int,
    cap: int,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (dispatch [T, E, C] bool, combine [T, E, C] f32).

    Position-in-expert computed per (token, slot) in routing priority order;
    tokens overflowing an expert's capacity are dropped (standard GShard).
    """
    T, k = experts.shape
    onehot = jax.nn.one_hot(experts, num_experts, dtype=jnp.int32)  # [T,k,E]
    flat = onehot.reshape(T * k, num_experts)
    pos = jnp.cumsum(flat, axis=0) - flat  # [T*k, E] position in expert queue
    pos = jnp.sum(pos * flat, axis=-1).reshape(T, k)  # [T, k]
    keep = pos < cap
    pos = jnp.where(keep, pos, 0)  # clamped; masked out by `keep` below
    # dispatch [T, E, C]: for each (t, slot) mark (expert, position)
    disp = jnp.einsum(
        "tke,tkc->tec",
        jax.nn.one_hot(experts, num_experts, dtype=jnp.float32)
        * keep[..., None],
        jax.nn.one_hot(pos, cap, dtype=jnp.float32),
    )
    comb = jnp.einsum("tk,tke,tkc->tec",
                      weights.astype(jnp.float32),
                      jax.nn.one_hot(experts, num_experts, dtype=jnp.float32)
                      * keep[..., None],
                      jax.nn.one_hot(pos, cap, dtype=jnp.float32))
    return disp, comb
