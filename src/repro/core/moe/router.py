"""Top-k gating network (paper Eq. 4-5) with load-balance auxiliary loss."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class RouterOut(NamedTuple):
    weights: jnp.ndarray  # [T, k] combine weights (softmax over top-k logits)
    experts: jnp.ndarray  # [T, k] int32 expert ids
    aux_loss: jnp.ndarray  # scalar load-balance loss
    logits: jnp.ndarray  # [T, E] raw router logits


def route_topk(x: jnp.ndarray, w_gate: jnp.ndarray, b_gate: jnp.ndarray | None,
               top_k: int, *, logits: jnp.ndarray | None = None) -> RouterOut:
    """x: [T, D] tokens; w_gate: [D, E]. Eq. 4: softmax over the top-k logits.

    ``logits``: optional precomputed (pre-bias) gate logits [T, E] — callers
    with a quantized gate weight compute them through the
    ``models.layers.quant_linear`` seam and pass them here (``w_gate`` may
    then be an int8 leaf, used only for its shape)."""
    if logits is None:
        logits = (x.astype(jnp.float32) @ w_gate.astype(jnp.float32))
    logits = logits.astype(jnp.float32)
    if b_gate is not None:
        logits = logits + b_gate
    T, E = logits.shape
    top_vals, top_idx = jax.lax.top_k(logits, top_k)  # [T, k]
    weights = jax.nn.softmax(top_vals, axis=-1)
    # Switch-style load-balance loss: E * sum_e f_e * p_e
    probs = jax.nn.softmax(logits, axis=-1)  # [T, E]
    onehot = jax.nn.one_hot(top_idx[:, 0], E, dtype=jnp.float32)  # primary route
    f = jnp.mean(onehot, axis=0)
    p = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(f * p)
    return RouterOut(weights=weights, experts=top_idx.astype(jnp.int32),
                     aux_loss=aux, logits=logits)
