"""Deterministic synthetic data pipeline.

No datasets ship in-container, so training runs on synthetic tasks that are
(a) deterministic in (seed, step, host) — the property fault-tolerant resume
needs: restoring at step k regenerates exactly the batch stream from k — and
(b) *learnable*, so loss curves demonstrate real optimization:

  * token LM families: sequences from a fixed random bigram chain
    (next = perm[cur] with p=0.9, uniform otherwise). A model that learns
    the chain drops from ln(V) to ~the chain's conditional entropy.
  * vit families: patches whose class is a linear probe of a fixed random
    projection of the mean patch — linearly separable, learnable.
  * frontend (audio/vlm) families: stub embeddings drawn from per-class
    Gaussian means so the text loss can use the frontend signal.

Batches are generated on host with numpy (never jit-traced), sliced
per-host for multi-host data parallelism, and cheap enough to regenerate —
the pipeline never checkpoints data state, only the step counter.
"""
from __future__ import annotations

from typing import Dict, Optional

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import text_tokens_for


class SyntheticPipeline:
    def __init__(self, cfg: ModelConfig, shape: ShapeConfig, seed: int = 0,
                 host_id: int = 0, num_hosts: int = 1,
                 batch_override: Optional[int] = None) -> None:
        self.cfg = cfg
        self.shape = shape
        self.seed = seed
        self.host_id = host_id
        self.num_hosts = num_hosts
        self.global_batch = batch_override or shape.global_batch
        assert self.global_batch % num_hosts == 0
        self.host_batch = self.global_batch // num_hosts
        # Fixed task structure (seed-keyed, independent of step).
        structure_rng = np.random.default_rng(seed)
        v = max(cfg.vocab_size, 2)
        self._perm = structure_rng.permutation(v)
        if cfg.num_classes:
            self._probe = structure_rng.standard_normal(
                (16, cfg.num_classes)
            ).astype(np.float32)
        if cfg.frontend:
            self._fe_means = structure_rng.standard_normal(
                (8, cfg.frontend_dim)
            ).astype(np.float32)

    # -- deterministic per-(step, host) rng ---------------------------------
    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.host_id])
        )

    def _bigram_tokens(self, rng, B: int, S: int) -> np.ndarray:
        v = max(self.cfg.vocab_size, 2)
        toks = np.empty((B, S + 1), np.int64)
        toks[:, 0] = rng.integers(0, v, B)
        flips = rng.random((B, S)) < 0.1
        noise = rng.integers(0, v, (B, S))
        for t in range(S):
            nxt = self._perm[toks[:, t]]
            toks[:, t + 1] = np.where(flips[:, t], noise[:, t], nxt)
        return toks

    def batch_for_step(self, step: int) -> Dict[str, np.ndarray]:
        cfg, shape = self.cfg, self.shape
        rng = self._rng(step)
        B = self.host_batch
        if cfg.family in ("vit", "vit_moe"):
            n_patch = cfg.image_tokens - 1
            patches = rng.standard_normal((B, n_patch, 768)).astype(np.float32)
            probe_in = patches.mean(axis=1)[:, :16]
            labels = np.argmax(probe_in @ self._probe, axis=-1)
            return {"patches": patches.astype(np.float32),
                    "labels": labels.astype(np.int32)}
        S = text_tokens_for(cfg, shape)
        toks = self._bigram_tokens(rng, B, S)
        out = {"tokens": toks[:, :-1].astype(np.int32),
               "labels": toks[:, 1:].astype(np.int32)}
        if cfg.frontend:
            n_front = shape.seq_len if cfg.family == "encdec" else min(
                cfg.frontend_tokens, max(shape.seq_len // 2, 8)
            )
            cls = rng.integers(0, 8, B)
            fe = (self._fe_means[cls][:, None, :]
                  + 0.3 * rng.standard_normal((B, n_front, cfg.frontend_dim)))
            out["frontend_embeds"] = fe.astype(np.float32)
        return out

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_for_step(step)
            step += 1


def make_pipeline(cfg: ModelConfig, shape: ShapeConfig, **kw) -> SyntheticPipeline:
    return SyntheticPipeline(cfg, shape, **kw)
