from repro.data.pipeline import SyntheticPipeline, make_pipeline

__all__ = ["SyntheticPipeline", "make_pipeline"]
