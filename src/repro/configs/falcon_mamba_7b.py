"""falcon-mamba-7b [ssm]: 64L d_model=4096 attn-free vocab=65024 ssm_state=16.

Mamba-1 architecture [arXiv:2410.05355]. The paper's log-sqrt2 post-softmax
quantizer is inapplicable (no attention); post-RMSNorm reparam quant applies to
in_proj (DESIGN.md section 4).
"""
from repro.configs.base import ModelConfig, QuantConfig, SSMConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    family="ssm",
    num_layers=64,
    d_model=4096,
    d_ff=0,  # attention-free, MLP-free: pure Mamba blocks
    vocab_size=65024,
    norm="rmsnorm",
    ssm=SSMConfig(state_dim=16, version=1, expand=2, conv_width=4),
    tie_embeddings=True,
    quant=QuantConfig(enable=False),
    optimizer="adamw",
    microbatch_size=16,
)
