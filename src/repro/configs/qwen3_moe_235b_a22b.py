"""qwen3-moe-235b-a22b [moe]: 94L d_model=4096 64H (GQA kv=4) expert d_ff=1536
vocab=151936, MoE 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B family].

Flagship EP arch for the paper's unified sparse/dense expert kernel.
"""
from repro.configs.base import AttnConfig, ModelConfig, MoEConfig, QuantConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    num_layers=94,
    d_model=4096,
    d_ff=0,  # all layers MoE
    vocab_size=151936,
    norm="rmsnorm",
    act="silu",
    glu=True,
    attn=AttnConfig(
        num_heads=64, num_kv_heads=4, head_dim=128,
        rope_theta=1_000_000.0, qk_norm=True,
    ),
    moe=MoEConfig(num_experts=128, top_k=8, d_ff=1536, moe_every=1,
                  impl="gshard"),  # GSPMD-native EP at scale; "grouped" = paper kernel (serving)
    quant=QuantConfig(enable=False),
    optimizer="adafactor",
    microbatch_size=16,
)
