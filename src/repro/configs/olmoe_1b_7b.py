"""olmoe-1b-7b [moe]: 16L d_model=2048 16H (kv=16) expert d_ff=1024
vocab=50304, MoE 64 experts top-8 [arXiv:2409.02060].
"""
from repro.configs.base import AttnConfig, ModelConfig, MoEConfig, QuantConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    num_layers=16,
    d_model=2048,
    d_ff=0,  # all layers MoE
    vocab_size=50304,
    norm="rmsnorm",
    act="silu",
    glu=True,
    attn=AttnConfig(
        num_heads=16, num_kv_heads=16, head_dim=128,
        rope_theta=10_000.0, qk_norm=True,
    ),
    moe=MoEConfig(num_experts=64, top_k=8, d_ff=1024, moe_every=1,
                  impl="gshard"),  # GSPMD-native EP at scale; "grouped" = paper kernel (serving)
    quant=QuantConfig(enable=False),
    optimizer="adamw",
    microbatch_size=32,
)
