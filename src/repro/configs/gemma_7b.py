"""gemma-7b [dense]: 28L d_model=3072 16H (kv=16) d_ff=24576 vocab=256000 —
GeGLU, head_dim=256, tied embeddings, sqrt(d) embed scale [arXiv:2403.08295].
"""
from repro.configs.base import AttnConfig, ModelConfig, QuantConfig

CONFIG = ModelConfig(
    name="gemma-7b",
    family="dense",
    num_layers=28,
    d_model=3072,
    d_ff=24576,
    vocab_size=256000,
    norm="rmsnorm",
    act="gelu",
    glu=True,  # GeGLU
    attn=AttnConfig(num_heads=16, num_kv_heads=16, head_dim=256,
                    rope_theta=10_000.0),
    tie_embeddings=True,
    embed_scale=True,
    quant=QuantConfig(enable=False),
    optimizer="adamw",
    microbatch_size=32,
)
