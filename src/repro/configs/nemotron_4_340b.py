"""nemotron-4-340b [dense]: 96L d_model=18432 96H (GQA kv=8) d_ff=73728
vocab=256000 — GQA, squared-ReLU MLP, LayerNorm [arXiv:2402.16819].

Largest assigned arch. Trains with Adafactor (factored second moment) so
optimizer state fits 16 GB/chip HBM at 512 chips (DESIGN.md section 5).
"""
from repro.configs.base import AttnConfig, ModelConfig, QuantConfig

CONFIG = ModelConfig(
    name="nemotron-4-340b",
    family="dense",
    num_layers=96,
    d_model=18432,
    d_ff=73728,
    vocab_size=256000,
    norm="layernorm",
    act="relu2",  # squared ReLU
    glu=False,
    attn=AttnConfig(num_heads=96, num_kv_heads=8, head_dim=192,
                    rope_theta=10_000.0),
    quant=QuantConfig(enable=False),
    optimizer="adafactor",
    microbatch_size=8,
)
