"""The paper's own architectures: ViT-T/S/B, DeiT-T/S/B and M3ViT-T/S
(MoE-ViT per Fan et al. NeurIPS'22, the baseline CoQMoE deploys).

M3ViT replaces every other MLP with a 16-expert top-2 MoE block.
All operate on 224x224 images, patch 16 -> 196 patches + [CLS] = 197 tokens,
ImageNet-1k head. Quantization: W8 A8 Attn4 (the paper's 8/8/4 row).
"""
from repro.configs.base import AttnConfig, ModelConfig, MoEConfig, QuantConfig

_Q = QuantConfig(enable=True, w_bits=8, a_bits=8, attn_bits=4)


def _vit(name: str, layers: int, d: int, heads: int, moe: bool) -> ModelConfig:
    return ModelConfig(
        name=name,
        family="vit_moe" if moe else "vit",
        num_layers=layers,
        d_model=d,
        d_ff=4 * d,
        vocab_size=0,
        norm="layernorm",
        act="gelu",
        glu=False,
        attn=AttnConfig(num_heads=heads, num_kv_heads=heads,
                        head_dim=d // heads, rope_theta=0.0),
        moe=MoEConfig(num_experts=16, top_k=2, d_ff=4 * d, moe_every=2)
        if moe else None,
        num_classes=1000,
        image_tokens=197,
        quant=_Q,
        optimizer="adamw",
    )


VIT_TINY = _vit("vit-tiny", 12, 192, 3, moe=False)
VIT_SMALL = _vit("vit-small", 12, 384, 6, moe=False)
VIT_BASE = _vit("vit-base", 12, 768, 12, moe=False)
DEIT_TINY = VIT_TINY.replace(name="deit-tiny")
DEIT_SMALL = VIT_SMALL.replace(name="deit-small")
DEIT_BASE = VIT_BASE.replace(name="deit-base")
M3VIT_TINY = _vit("m3vit-tiny", 12, 192, 3, moe=True)
M3VIT_SMALL = _vit("m3vit-small", 12, 384, 6, moe=True)

CONFIG = M3VIT_SMALL  # the paper's headline deployment (CoQMoE-C on U280)

ALL = {
    c.name: c
    for c in (VIT_TINY, VIT_SMALL, VIT_BASE, DEIT_TINY, DEIT_SMALL, DEIT_BASE,
              M3VIT_TINY, M3VIT_SMALL)
}
