"""gemma2-2b [dense]: 26L d_model=2304 8H (GQA kv=4) d_ff=9216 vocab=256000 —
local(4096)+global alternating attention, logit softcap, sandwich norms
[arXiv:2408.00118].
"""
from repro.configs.base import AttnConfig, ModelConfig, QuantConfig

CONFIG = ModelConfig(
    name="gemma2-2b",
    family="dense",
    num_layers=26,
    d_model=2304,
    d_ff=9216,
    vocab_size=256000,
    norm="rmsnorm",
    act="gelu",
    glu=True,  # GeGLU
    attn=AttnConfig(
        num_heads=8, num_kv_heads=4, head_dim=256,
        rope_theta=10_000.0,
        local_window=4096,
        alternate_local_global=True,
        logit_softcap=50.0,
    ),
    tie_embeddings=True,
    embed_scale=True,
    post_block_norm=True,
    final_logit_softcap=30.0,
    quant=QuantConfig(enable=False),
    optimizer="adamw",
    microbatch_size=32,
)
