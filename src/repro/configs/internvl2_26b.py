"""internvl2-26b [vlm]: 48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92553
— InternViT frontend + InternLM2-20B backbone [arXiv:2404.16821].

The InternViT vision tower is a STUB per the assignment: ``input_specs()``
provides precomputed patch embeddings (B, frontend_tokens, frontend_dim)
which are linearly projected into the LM embedding space and prepended to the
text token embeddings.
"""
from repro.configs.base import AttnConfig, ModelConfig, QuantConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    family="vlm",
    num_layers=48,
    d_model=6144,
    d_ff=16384,
    vocab_size=92553,
    norm="rmsnorm",
    act="silu",
    glu=True,
    attn=AttnConfig(num_heads=48, num_kv_heads=8, head_dim=128,
                    rope_theta=1_000_000.0),
    frontend="patch",
    frontend_tokens=1024,  # 448x448 InternViT pixel-unshuffled token budget
    frontend_dim=3200,  # InternViT-6B hidden size
    quant=QuantConfig(enable=False),
    optimizer="adafactor",
    microbatch_size=16,
)
