"""seamless-m4t-medium [audio]: enc-dec 12L(enc)+12L(dec) d_model=1024 16H
(kv=16) d_ff=4096 vocab=256206 [arXiv:2308.11596].

Modality frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed audio frame embeddings (B, S, frontend_dim) for the encoder; the
decoder consumes text tokens. The 12L of the assignment maps to 12 encoder +
12 decoder layers (the released medium text model's split).
"""
from repro.configs.base import AttnConfig, ModelConfig, QuantConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="encdec",
    num_layers=24,
    encoder_layers=12,
    decoder_layers=12,
    d_model=1024,
    d_ff=4096,
    vocab_size=256206,
    norm="layernorm",
    act="gelu",
    glu=False,
    attn=AttnConfig(num_heads=16, num_kv_heads=16, head_dim=64,
                    rope_theta=10_000.0),
    frontend="frame",
    frontend_dim=1024,
    quant=QuantConfig(enable=False),
    optimizer="adamw",
    microbatch_size=32,
)
