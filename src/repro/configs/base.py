"""Config dataclasses for the repro framework.

Every selectable ``--arch`` is a ``ModelConfig``; every benchmark/dry-run
input shape is a ``ShapeConfig``. Configs are frozen dataclasses so they can
be hashed into jit static args.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class AttnConfig:
    num_heads: int
    num_kv_heads: int
    head_dim: int
    rope_theta: float = 10_000.0
    local_window: int = 0  # 0 = global attention
    alternate_local_global: bool = False  # gemma2: layer pairs (local, global)
    logit_softcap: float = 0.0  # gemma2 attention logit soft-capping
    qk_norm: bool = False  # qwen3 / olmoe per-head RMS QK-norm

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff: int  # per-expert hidden dim
    moe_every: int = 1  # every Nth layer is MoE (1 = all layers)
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # grouped = sort-based unified kernel (the paper's orchestration);
    # gshard  = capacity dispatch/combine einsums (GSPMD-native EP at scale)
    impl: str = "grouped"
    # single          = every device holds the full expert stack (default);
    # expert_parallel = grouped path under shard_map: expert stacks sharded
    #                   over the 'model' mesh axis, tokens exchanged with
    #                   all_to_all (distributed/expert_parallel.py). Serving
    #                   only (requires impl="grouped"); the mesh is supplied
    #                   via distributed.expert_parallel.use_ep_mesh.
    moe_exec: str = "single"


@dataclass(frozen=True)
class SSMConfig:
    state_dim: int
    version: int = 1  # 1 = Mamba-1 (falcon-mamba), 2 = Mamba-2 (zamba2)
    expand: int = 2
    conv_width: int = 4
    head_dim: int = 64  # mamba2 only
    dt_rank: int = 0  # mamba1; 0 = ceil(d_model / 16)
    scan_chunk: int = 128  # chunked selective-scan chunk length

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def num_ssm_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class QuantConfig:
    """The paper's dual-stage quantization scheme (CoQMoE §3)."""

    enable: bool = False
    w_bits: int = 8
    a_bits: int = 8
    attn_bits: int = 4  # post-softmax log-sqrt2 quantizer bits
    post_norm_reparam: bool = True  # Eqs. 10-16
    softmax_log_sqrt2: bool = True  # Eqs. 17-21
    kv_cache_int8: bool = True  # serving: int8 K/V cache
    # Per-site mixed-scheme map for ``ptq_model(materialize="int4")``
    # (DESIGN.md section 13): (dotted-path-suffix pattern, scheme) pairs,
    # e.g. (("moe.wi", "int4"), ("moe.wo", "int4")). Longest-suffix match
    # wins; unmatched sites stay int8. Empty = the documented experts-only
    # default (ptq.DEFAULT_INT4_SCHEME) when int4 materialization is
    # requested. Also honored by ``materialize="fake"`` to build the
    # fake-quant oracle of a mixed tree.
    scheme_map: Tuple[Tuple[str, str], ...] = ()


@dataclass(frozen=True)
class AutotuneConfig:
    """Per-device Pallas tile-size autotuning (kernels/autotune.py,
    DESIGN.md section 9) — the TPU analogue of re-synthesizing the FPGA
    kernels per deployment (CoQMoE section 4).

    When enabled, engine ``warmup()`` traces every program the replica will
    compile (``jax.eval_shape`` — no device work), collects the kernel
    shape-bucket keys those programs hit, benchmarks candidate tile grids
    for each missing key on the actual device, and persists the winners in
    a versioned JSON table keyed by device kind. A later warmup on the same
    device kind is a pure cache hit (zero re-sweep). On CPU / interpret
    backends no timing happens — keys are filled with the deterministic
    default tiles."""

    enable: bool = False
    # sweep budget: max candidate tile configs timed per (kernel,
    # shape-bucket) key (the default config is always candidate #1, so the
    # chosen config is never slower than the default by construction)
    budget: int = 12
    # timing repetitions per candidate (median is recorded)
    reps: int = 5
    # directory holding one table file per device kind; None falls back to
    # $REPRO_AUTOTUNE_CACHE or ".repro_autotune"
    cache_dir: Optional[str] = None
    # pre-pinned entries applied on top of the loaded table, as
    # (entry_key, (block_a, block_b)) pairs — the ship-a-pretuned-table
    # hook (keys are the strings kernels/autotune.py builds; see DESIGN.md
    # section 9 for the key contract)
    overrides: Tuple[Tuple[str, Tuple[int, int]], ...] = ()


@dataclass(frozen=True)
class AutoscaleConfig:
    """Target-range admission autoscaling for ``ServingCluster``
    (serving/autoscaler.py — DESIGN.md section 8).

    The controller reacts to two pressure signals: front-end queue depth
    per active replica and the *windowed* pooled p95 request latency vs the
    SLO. Hysteresis comes from patience (consecutive breached evaluations
    before acting) plus a post-action cooldown, so a bursty arrival process
    does not flap the replica set."""

    min_replicas: int = 1
    max_replicas: int = 8
    # pre-warmed standby pool size ServingCluster should hold (replicas
    # beyond it are spawned + warmed on demand, which is much slower)
    standby: int = 1
    # scale-up triggers: front-end depth per active replica, or pooled
    # windowed p95 over the SLO
    depth_high: float = 4.0
    slo_p95_ms: float = 250.0
    up_patience: int = 2
    # scale-down triggers: total load at/below depth_low AND p95 under
    # down_margin * SLO, sustained for down_patience evaluations
    depth_low: float = 0.0
    down_margin: float = 0.5
    down_patience: int = 16
    # evaluations to wait after any scale action before the next one
    cooldown: int = 8
    # samples needed before the windowed p95 advances (below it the window
    # keeps accumulating and the previous estimate holds)
    min_window_samples: int = 8
    # evaluations without a window close before the p95 estimate expires to
    # NaN — a breach measured during a surge must not keep scaling (or pin
    # the replica count) once traffic has stopped
    p95_ttl: int = 32


@dataclass(frozen=True)
class TraceConfig:
    """Serving-stack tracing/profiling knobs (serving/trace.py,
    DESIGN.md section 11).

    With ``enable`` off (the default) engines hold the no-op
    ``NULL_TRACER`` and every instrumentation site reduces to one boolean
    attribute read — the disabled-path overhead contract the trace-overhead
    benchmark measures. With it on, every request gets a typed span
    timeline (queue/pack/prefill/decode/retire) in a bounded flight
    recorder, and the engines record per-program step wall times keyed by
    the section-10 AOT program key into ``EngineMetrics`` histograms."""

    enable: bool = False
    # flight-recorder ring capacity in spans; the oldest spans evict first
    # (recorder.dropped counts them)
    capacity: int = 65536
    # per-program step-latency histograms (decode tick + packed-prefill
    # dispatch, keyed serve/<prog>|B=..|S=..|... — the per-bucket step
    # latency signal the ROADMAP autotuner-drift item reads)
    step_times: bool = True
    # wrap kernels/ops.py grouped_matmul/attention in jax.named_scope so
    # device profiles (jax.profiler) carry kernel-level names
    annotate_kernels: bool = False


@dataclass(frozen=True)
class IntrospectConfig:
    """Live performance-introspection knobs (serving/introspect.py,
    DESIGN.md section 12).

    With ``enable`` on (the default), ``warmup()`` captures a per-program
    ``ProgramCost`` row (cost_analysis + memory_analysis + call-graph HLO
    metrics, analytic fallback marked ``estimated``) for every AOT program,
    attaches a memory-watermark probe, and — for MoE configs — runs the
    windowed expert-routing health monitor that emits ``expert_drift``
    events into the engine's ``EventLog``. Capture happens entirely at
    warmup; the only steady-state cost is the drift monitor's histogram
    accumulation, bounded by the trace-overhead contract."""

    enable: bool = True
    # routed tokens per drift-monitor window; a window closes (and drift is
    # evaluated) once this many (token, expert) routings accumulate
    drift_window_tokens: int = 4096
    # total-variation distance (L1/2) between a closed window's occupancy
    # and the reference occupancy above which an expert_drift event fires
    drift_threshold: float = 0.25
    # EMA weight folding each non-drifting window into the reference
    # occupancy (slow tracking, so gradual shift is not repeatedly flagged)
    baseline_alpha: float = 0.1


@dataclass(frozen=True)
class FaultConfig:
    """Serving fault model: chaos injection + watchdog/recovery knobs
    (serving/faults.py, DESIGN.md section 14).

    Two independent halves share the config:

    **Injection** (``inject`` — default off): the deterministic chaos
    harness. With it on, every replica the cluster builds is wrapped in a
    ``FaultyReplica`` decorator whose seeded ``FaultInjector`` raises step
    exceptions / OOM-shaped allocation failures, stalls steps (fake-clock
    compatible), rejects submits, and poisons ``on_done`` callbacks at the
    configured rates and schedule. With it off nothing is wrapped — the
    injection path literally does not exist at runtime (the NULL-injector
    discipline of ``NULL_TRACER``).

    **Watchdog / recovery** (``watchdog`` — default on): the per-replica
    health monitor and the quarantine/re-dispatch machinery in
    ``ServingCluster``. Budgets below decide when a replica is evicted and
    how often one request may be re-dispatched before it fails terminally.
    """

    # -- chaos injection (all rates are per-boundary Bernoulli draws from
    #    a replica-ordinal-seeded generator; 0.0 everywhere = no faults
    #    even when inject=True) --------------------------------------------
    inject: bool = False
    seed: int = 0
    step_error_rate: float = 0.0  # step() raises InjectedFault
    oom_rate: float = 0.0  # step() raises InjectedOOM (RESOURCE_EXHAUSTED)
    step_stall_rate: float = 0.0  # step() stalls stall_s before running
    stall_s: float = 0.25  # injected stall duration (clock seconds)
    submit_reject_rate: float = 0.0  # replica submit() raises Backpressure
    callback_poison_rate: float = 0.0  # wrap on_done to raise after running
    # deterministic schedule: (replica_ordinal, local_step, kind) triples,
    # kind in {"error", "oom", "stall", "dead"}. "dead" kills the replica
    # permanently — every later step raises too (a crashed process, not a
    # transient fault). Scheduled entries override the random draws.
    kill_schedule: Tuple[Tuple[int, int, str], ...] = ()

    # -- watchdog / recovery ----------------------------------------------
    watchdog: bool = True
    # absolute step wall-time ceiling; one step slower than this counts as
    # a stall regardless of history
    step_timeout_s: float = 30.0
    # relative stall detector: step slower than stall_threshold x the EMA
    # of healthy steps (StragglerMonitor), armed after warmup_steps. Steps
    # under stall_floor_s never count as relative stalls: a serving pump
    # spins through idle no-op ticks whose microsecond durations would
    # otherwise seed an EMA that makes any real batch dispatch look like
    # an 8x stall
    stall_threshold: float = 8.0
    warmup_steps: int = 5
    stall_floor_s: float = 0.05
    # consecutive-fault budgets before quarantine (an OOM-classified error
    # evicts immediately — retrying into a full allocator wedges the pump)
    error_budget: int = 3
    stall_budget: int = 2
    # re-dispatches one request may consume across evictions before it is
    # terminally failed (its on_done fires exactly once with status
    # "failed" instead of retrying forever)
    retry_budget: int = 2


@dataclass(frozen=True)
class ContinuousBatchingConfig:
    """Continuous-batching knobs for ``ServeEngine`` (DESIGN.md section 10).

    Packed prefill concatenates up to ``batch_slots`` variable-length
    prompts into one ``[1, bucket]`` token buffer (segment-masked attention)
    so mixed-length admissions share a single prefill dispatch; buffer
    lengths bucket to a power-of-two ladder so the AOT program cache stays
    small. ``async_retire`` moves token materialization (device->host),
    EOS checks, and completion callbacks onto a retirement thread fed by a
    device-array queue, keeping the decode tick free of host syncs."""

    packed_prefill: bool = True
    # token budget of one packed prefill dispatch; 0 = the engine max_len
    # (must not exceed max_len — ServeEngine validates at construction)
    max_prefill: int = 0
    # smallest pack-buffer bucket (ladder doubles from here to max_prefill)
    min_bucket: int = 32
    # retirement thread on/off (off = inline retirement, same ordering)
    async_retire: bool = True
    # pre-compile every (bucket x prompt-count, decode) program at warmup()
    aot_warmup: bool = True


@dataclass(frozen=True)
class ModelConfig:
    name: str
    # dense | moe | ssm | hybrid | encdec | vlm | vit | vit_moe
    family: str
    num_layers: int
    d_model: int
    d_ff: int
    vocab_size: int
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    act: str = "silu"  # silu | gelu | geglu_gelu | relu2
    glu: bool = True  # gated linear unit MLP (silu->swiglu, gelu->geglu)
    attn: Optional[AttnConfig] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid (zamba2): one *shared* attention block applied every N ssm layers
    shared_attn_every: int = 0
    # enc-dec (seamless)
    encoder_layers: int = 0
    decoder_layers: int = 0
    # modality frontend stub: 'patch' (vlm) | 'frame' (audio) | None
    frontend: Optional[str] = None
    frontend_tokens: int = 0  # tokens contributed by the frontend embeds
    frontend_dim: int = 0  # raw embedding dim provided by the stub
    tie_embeddings: bool = False
    embed_scale: bool = False  # gemma: scale embeds by sqrt(d_model)
    post_block_norm: bool = False  # gemma2 sandwich norms
    final_logit_softcap: float = 0.0
    # vit classifier head (paper archs)
    num_classes: int = 0
    image_tokens: int = 0  # e.g. 197 for 224/16 ViT (196 patches + cls)
    quant: QuantConfig = field(default_factory=QuantConfig)
    # per-device kernel tile autotuning (serving warmup; kernels/autotune.py)
    autotune: AutotuneConfig = field(default_factory=AutotuneConfig)
    # continuous-batching serving path (serving/engine.py; DESIGN.md §10)
    serve: ContinuousBatchingConfig = field(
        default_factory=ContinuousBatchingConfig)
    # serving tracing/profiling (serving/trace.py; DESIGN.md §11)
    trace: TraceConfig = field(default_factory=TraceConfig)
    # live performance introspection (serving/introspect.py; DESIGN.md §12)
    introspect: IntrospectConfig = field(default_factory=IntrospectConfig)
    # serving fault model: chaos injection + watchdog (serving/faults.py;
    # DESIGN.md §14)
    faults: FaultConfig = field(default_factory=FaultConfig)
    dtype: str = "bfloat16"
    # training knobs
    remat: bool = True
    optimizer: str = "adamw"  # adamw | adafactor (big archs)
    microbatch_size: int = 0  # 0 = no gradient accumulation

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ---- derived sizes ----------------------------------------------------
    def param_count(self) -> int:
        """Approximate parameter count (embedding + blocks + head)."""
        d = self.d_model
        n = 0
        n += self.vocab_size * d  # embedding
        if not self.tie_embeddings and self.family not in ("vit", "vit_moe"):
            n += self.vocab_size * d  # lm head
        layers = self.num_layers
        if self.family == "encdec":
            layers = self.encoder_layers + self.decoder_layers
        per_layer = 0
        # hybrid: attention/MLP live only in the single shared block
        shared_only = bool(self.shared_attn_every)
        if self.attn is not None and not shared_only:
            a = self.attn
            per_layer += d * (a.q_dim + 2 * a.kv_dim)  # qkv
            per_layer += a.q_dim * d  # out proj
        if self.ssm is not None:
            s = self.ssm
            di = s.d_inner(d)
            per_layer += d * 2 * di  # in_proj (x, z)
            per_layer += di * s.conv_width  # conv
            if s.version == 1:
                dtr = s.dt_rank or -(-d // 16)
                per_layer += di * (dtr + 2 * s.state_dim)  # x_proj
                per_layer += dtr * di  # dt_proj
                per_layer += di * s.state_dim  # A
            else:
                nh = s.num_ssm_heads(d)
                per_layer += d * (2 * s.state_dim + nh)  # B,C,dt proj
                per_layer += nh  # A
            per_layer += di * d  # out_proj
        mlp_mult = 3 if self.glu else 2
        if self.moe is not None:
            moe_layers = layers // self.moe.moe_every
            dense_layers = layers - moe_layers
            per_layer_moe = (
                self.moe.num_experts * mlp_mult * d * self.moe.d_ff
                + d * self.moe.num_experts
            )
            n += moe_layers * per_layer_moe
            if self.d_ff and not shared_only:
                n += dense_layers * mlp_mult * d * self.d_ff
            n += layers * per_layer
        else:
            if self.d_ff and not shared_only:
                per_layer += mlp_mult * d * self.d_ff
            n += layers * per_layer
        if self.family == "encdec":
            # decoder cross-attention
            a = self.attn
            n += self.decoder_layers * (d * (a.q_dim + 2 * a.kv_dim) + a.q_dim * d)
        if self.shared_attn_every and self.attn is not None:
            a = self.attn
            n += d * (a.q_dim + 2 * a.kv_dim) + a.q_dim * d  # one shared block
            n += mlp_mult * d * self.d_ff
        if self.num_classes:
            n += d * self.num_classes
        return n

    def active_param_count(self) -> int:
        """Params active per token (MoE: top_k of num_experts)."""
        if self.moe is None:
            return self.param_count()
        full = self.param_count()
        layers = self.num_layers
        moe_layers = layers // self.moe.moe_every
        mlp_mult = 3 if self.glu else 2
        expert_params = moe_layers * self.moe.num_experts * mlp_mult * self.d_model * self.moe.d_ff
        active_expert = moe_layers * self.moe.top_k * mlp_mult * self.d_model * self.moe.d_ff
        return full - expert_params + active_expert


@dataclass(frozen=True)
class ShapeConfig:
    """One benchmark/dry-run input shape cell."""

    name: str
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int

    def replace(self, **kw) -> "ShapeConfig":
        return dataclasses.replace(self, **kw)


TRAIN_4K = ShapeConfig("train_4k", "train", 4_096, 256)
PREFILL_32K = ShapeConfig("prefill_32k", "prefill", 32_768, 32)
DECODE_32K = ShapeConfig("decode_32k", "decode", 32_768, 128)
LONG_500K = ShapeConfig("long_500k", "decode", 524_288, 1)

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}

# Families for which full attention makes long_500k intractable (skip per spec).
FULL_ATTENTION_FAMILIES = ("dense", "moe", "encdec", "vlm", "vit", "vit_moe")


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether a (arch x shape) cell is runnable; returns (ok, reason)."""
    if shape.name == "long_500k" and cfg.family in FULL_ATTENTION_FAMILIES:
        # gemma2 alternates local/global: global layers are still full attention.
        return False, "full-attention arch: 500k decode KV is not sub-quadratic-safe"
    if cfg.family in ("vit", "vit_moe") and shape.kind != "train":
        return False, "encoder-only classifier: no decode/prefill step"
    return True, ""
