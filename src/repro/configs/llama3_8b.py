"""llama3-8b [dense]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=128256 [arXiv:2407.21783].
"""
from repro.configs.base import AttnConfig, ModelConfig, QuantConfig

CONFIG = ModelConfig(
    name="llama3-8b",
    family="dense",
    num_layers=32,
    d_model=4096,
    d_ff=14336,
    vocab_size=128256,
    norm="rmsnorm",
    act="silu",
    glu=True,
    attn=AttnConfig(num_heads=32, num_kv_heads=8, head_dim=128,
                    rope_theta=500_000.0),
    quant=QuantConfig(enable=False),
    optimizer="adamw",
    microbatch_size=32,
)
