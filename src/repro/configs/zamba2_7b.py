"""zamba2-7b [hybrid]: 81L d_model=3584 32H (kv=32) d_ff=14336 vocab=32000
ssm_state=64 — Mamba-2 backbone + ONE shared attention block applied every 6
Mamba layers [arXiv:2411.15242].

Simplification vs. the released model (recorded in DESIGN.md): the shared
transformer block here operates on x + x_embed (residual re-injection of the
embedding stream) rather than concat(x, x_embed) with per-invocation LoRA.
"""
from repro.configs.base import AttnConfig, ModelConfig, QuantConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    num_layers=81,
    d_model=3584,
    d_ff=14336,  # shared attention block's MLP
    vocab_size=32000,
    norm="rmsnorm",
    act="gelu",
    glu=True,
    attn=AttnConfig(num_heads=32, num_kv_heads=32, head_dim=112,
                    rope_theta=10_000.0),
    ssm=SSMConfig(state_dim=64, version=2, expand=2, conv_width=4, head_dim=64),
    shared_attn_every=6,
    quant=QuantConfig(enable=False),
    optimizer="adamw",
    microbatch_size=16,
)
