"""Architecture registry: ``get_config(arch_id)`` for every ``--arch``."""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.configs import moe_vit as _moe_vit
from repro.configs.base import (
    AttnConfig,
    AutoscaleConfig,
    AutotuneConfig,
    DECODE_32K,
    FULL_ATTENTION_FAMILIES,
    FaultConfig,
    IntrospectConfig,
    LONG_500K,
    ModelConfig,
    MoEConfig,
    PREFILL_32K,
    QuantConfig,
    SSMConfig,
    ShapeConfig,
    SHAPES,
    TRAIN_4K,
    shape_applicable,
)
from repro.configs.falcon_mamba_7b import CONFIG as FALCON_MAMBA_7B
from repro.configs.gemma2_2b import CONFIG as GEMMA2_2B
from repro.configs.gemma_7b import CONFIG as GEMMA_7B
from repro.configs.internvl2_26b import CONFIG as INTERNVL2_26B
from repro.configs.llama3_8b import CONFIG as LLAMA3_8B
from repro.configs.nemotron_4_340b import CONFIG as NEMOTRON_4_340B
from repro.configs.olmoe_1b_7b import CONFIG as OLMOE_1B_7B
from repro.configs.qwen3_moe_235b_a22b import CONFIG as QWEN3_MOE_235B
from repro.configs.seamless_m4t_medium import CONFIG as SEAMLESS_M4T_MEDIUM
from repro.configs.zamba2_7b import CONFIG as ZAMBA2_7B

# The 10 assigned architectures (the 40-cell dry-run/roofline grid).
ASSIGNED: Dict[str, ModelConfig] = {
    c.name: c
    for c in (
        FALCON_MAMBA_7B,
        QWEN3_MOE_235B,
        OLMOE_1B_7B,
        NEMOTRON_4_340B,
        LLAMA3_8B,
        GEMMA_7B,
        GEMMA2_2B,
        ZAMBA2_7B,
        SEAMLESS_M4T_MEDIUM,
        INTERNVL2_26B,
    )
}

# Paper's own archs (quant-accuracy + throughput tables).
PAPER_ARCHS: Dict[str, ModelConfig] = dict(_moe_vit.ALL)

REGISTRY: Dict[str, ModelConfig] = {**ASSIGNED, **PAPER_ARCHS}


def get_config(arch: str) -> ModelConfig:
    if arch not in REGISTRY:
        raise KeyError(
            f"unknown arch {arch!r}; available: {sorted(REGISTRY)}"
        )
    return REGISTRY[arch]


def get_shape(name: str) -> ShapeConfig:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; available: {sorted(SHAPES)}")
    return SHAPES[name]


def smoke_config(arch: str) -> ModelConfig:
    """A reduced config of the same family for CPU smoke tests.

    Small layers/width, few experts, tiny vocab -- preserves every structural
    feature (GQA ratio, GLU, local/global alternation, shared-attn period,
    SSM version) so the smoke test exercises the real code paths.
    """
    cfg = get_config(arch)
    kw = dict(
        name=cfg.name + "-smoke",
        num_layers=min(cfg.num_layers, 4),
        d_model=64,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=min(cfg.vocab_size, 256) if cfg.vocab_size else 0,
        microbatch_size=0,
    )
    if cfg.attn is not None:
        ratio = max(1, cfg.attn.num_heads // cfg.attn.num_kv_heads)
        heads = 4
        kw["attn"] = dataclasses.replace(
            cfg.attn,
            num_heads=heads,
            num_kv_heads=max(1, heads // ratio),
            head_dim=16,
            local_window=16 if cfg.attn.local_window else 0,
        )
    if cfg.moe is not None:
        kw["moe"] = dataclasses.replace(
            cfg.moe, num_experts=8, top_k=min(cfg.moe.top_k, 2), d_ff=32
        )
    if cfg.ssm is not None:
        kw["ssm"] = dataclasses.replace(
            cfg.ssm, state_dim=8, head_dim=16 if cfg.ssm.version == 2 else 64
        )
    if cfg.family == "encdec":
        kw["num_layers"] = 4
        kw["encoder_layers"] = 2
        kw["decoder_layers"] = 2
    if cfg.frontend:
        kw["frontend_tokens"] = 8 if cfg.frontend == "patch" else 0
        kw["frontend_dim"] = 48
    if cfg.shared_attn_every:
        kw["shared_attn_every"] = 2
        kw["num_layers"] = 5  # non-multiple on purpose: exercises remainder
    if cfg.num_classes:
        kw["num_classes"] = 10
        kw["image_tokens"] = 17
    return cfg.replace(**kw)


__all__ = [
    "ASSIGNED",
    "PAPER_ARCHS",
    "REGISTRY",
    "SHAPES",
    "AttnConfig",
    "ModelConfig",
    "MoEConfig",
    "QuantConfig",
    "SSMConfig",
    "ShapeConfig",
    "TRAIN_4K",
    "PREFILL_32K",
    "DECODE_32K",
    "LONG_500K",
    "FULL_ATTENTION_FAMILIES",
    "get_config",
    "get_shape",
    "smoke_config",
    "shape_applicable",
]
