"""Sharded atomic checkpointing with async save and elastic restore.

Design (tensorstore/orbax are not available in-container; this is a
self-contained implementation of the same contract):

  * **Atomic**: each checkpoint is written to ``step_<N>.tmp/`` and renamed
    to ``step_<N>/`` only after every array and the metadata manifest have
    been fsynced — a crash mid-save never corrupts the latest checkpoint.
  * **Async**: ``save()`` snapshots the device arrays to host (blocking only
    for the device->host copy), then writes on a background thread;
    ``wait()`` joins before the next save or process exit.
  * **Sharded layout**: every leaf is stored as its own ``.npy`` keyed by
    its pytree path, with a JSON manifest carrying step, tree structure and
    *global* shapes. On multi-host deployments each host writes the leaves
    it owns (addressable shards) under ``host_<i>/``; this container is
    single-host so the full array is written once.
  * **Elastic restore**: arrays are restored from their *global* shapes and
    then ``jax.device_put`` onto whatever sharding the *current* mesh
    prescribes — restoring a 512-chip checkpoint onto 256 chips (or a
    differently shaped mesh) is just a different placement of the same
    global arrays (re-mesh on restore).
  * **Quantized trees**: a QuantizedParams tree (int8 weight leaves +
    ``_scale``/``_as`` f32 siblings from ``ptq_model(materialize="int8")``)
    round-trips with exact dtypes — int8 stays int8 on disk (¼ the bytes of
    the fp tree) and on restore, so a serving process can load weights
    directly into the executable format. ``restore(None)`` rebuilds the
    nested dict structure from the manifest alone: deploying a quantized
    checkpoint needs no abstract-param template (whose structure a PTQ
    tree no longer matches).
  * **keep_last_k** garbage collection.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional

import jax
import ml_dtypes
import numpy as np

# numpy has no native bfloat16 et al.; store raw bits + logical dtype name
_EXTENDED_DTYPES = {
    "bfloat16": ml_dtypes.bfloat16,
    "float8_e4m3fn": ml_dtypes.float8_e4m3fn,
    "float8_e5m2": ml_dtypes.float8_e5m2,
}


def _flatten(tree, prefix=""):
    out: Dict[str, Any] = {}
    if tree is None:
        return out
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def _nest(flat: Dict[str, Any]):
    """Rebuild a nested tree from manifest keys alone (structure-free
    restore). Dict levels whose keys are exactly 0..n-1 were lists/tuples
    at save time and are rebuilt as lists."""
    root: Dict[str, Any] = {}
    for key, val in flat.items():
        parts = key.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val

    def fix(node):
        if not isinstance(node, dict):
            return node
        out = {k: fix(v) for k, v in node.items()}
        if out and all(k.isdigit() for k in out):
            order = sorted(out, key=int)
            if order == [str(i) for i in range(len(order))]:
                return [out[k] for k in order]
        return out

    return fix(root)


def _unflatten_into(structure, flat, prefix=""):
    if structure is None:
        return None
    if isinstance(structure, dict):
        return {k: _unflatten_into(v, flat, f"{prefix}{k}/")
                for k, v in structure.items()}
    if isinstance(structure, (list, tuple)):
        vals = [_unflatten_into(v, flat, f"{prefix}{i}/")
                for i, v in enumerate(structure)]
        if hasattr(structure, "_fields"):  # NamedTuple
            return type(structure)(*vals)
        return type(structure)(vals)
    return flat[prefix[:-1]]


class CheckpointManager:
    def __init__(self, directory: str, keep_last_k: int = 3) -> None:
        self.dir = directory
        self.keep = keep_last_k
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # -- save ----------------------------------------------------------------
    def save(self, step: int, tree, *, blocking: bool = False) -> None:
        """Snapshot to host, then write asynchronously (atomic rename)."""
        self.wait()
        flat = _flatten(tree)
        host = {k: np.asarray(v) for k, v in flat.items()}  # D2H snapshot

        def _write():
            try:
                tmp = os.path.join(self.dir, f"step_{step:08d}.tmp")
                final = os.path.join(self.dir, f"step_{step:08d}")
                os.makedirs(tmp, exist_ok=True)
                manifest = {"step": step, "leaves": {}}
                for key, arr in host.items():
                    fname = key.replace("/", "__") + ".npy"
                    logical = str(arr.dtype)
                    if logical in _EXTENDED_DTYPES:
                        arr = arr.view(np.uint16 if arr.dtype.itemsize == 2
                                       else np.uint8)
                    with open(os.path.join(tmp, fname), "wb") as f:
                        np.save(f, arr)
                        f.flush()
                        os.fsync(f.fileno())
                    manifest["leaves"][key] = {
                        "file": fname,
                        "shape": list(arr.shape),
                        "dtype": logical,
                    }
                mpath = os.path.join(tmp, "manifest.json")
                with open(mpath, "w") as f:
                    json.dump(manifest, f)
                    f.flush()
                    os.fsync(f.fileno())
                if os.path.exists(final):
                    shutil.rmtree(final)
                os.rename(tmp, final)
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()
        if blocking:
            self.wait()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    # -- restore ---------------------------------------------------------------
    def steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                out.append(int(name.removeprefix("step_")))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, structure=None, step: Optional[int] = None,
                shardings=None):
        """Restore into ``structure``'s pytree shape, or — with
        ``structure=None`` — rebuild the nested tree from the manifest
        (quantized/PTQ trees whose structure no template describes).

        ``shardings``: optional matching tree of NamedSharding — arrays are
        device_put onto it (elastic re-mesh: the target mesh can differ from
        the one that saved).
        """
        self.wait()
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        flat = {}
        for key, info in manifest["leaves"].items():
            arr = np.load(os.path.join(path, info["file"]))
            if info["dtype"] in _EXTENDED_DTYPES:
                arr = arr.view(_EXTENDED_DTYPES[info["dtype"]])
            flat[key] = arr
        tree = _nest(flat) if structure is None else _unflatten_into(
            structure, flat)
        if shardings is not None:
            tree = jax.tree.map(
                lambda arr, sh: jax.device_put(arr, sh), tree, shardings
            )
        return tree

    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[: -self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)
