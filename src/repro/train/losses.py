"""Loss functions: token LM cross-entropy (with z-loss) and classification."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def softmax_xent(logits: jnp.ndarray, labels: jnp.ndarray,
                 z_loss: float = 1e-4):
    """Mean token cross-entropy. logits [..., V], labels [...] int32.

    z_loss regularizes log Z toward 0 (MaxText/PaLM trick — keeps the final
    logits from drifting, which also helps the PTQ final-norm quantizer).
    """
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(
        logits, labels[..., None].astype(jnp.int32), axis=-1
    )[..., 0]
    nll = lse - ll
    loss = jnp.mean(nll)
    if z_loss > 0:
        loss = loss + z_loss * jnp.mean(jnp.square(lse))
    return loss


def loss_and_metrics(params, cfg: ModelConfig, batch: dict):
    """Uniform loss over a pipeline batch; returns (loss, metrics dict)."""
    from repro import models

    logits, aux = models.forward(params, cfg, batch)
    labels = batch["labels"]
    if logits.ndim == 3 and logits.shape[1] != labels.shape[1]:
        # frontend families: the frontend positions (prefix) carry no labels
        logits = logits[:, -labels.shape[1]:, :]
    xent = softmax_xent(logits, labels)
    loss = xent
    if cfg.moe is not None:
        loss = loss + cfg.moe.router_aux_weight * aux
    acc = jnp.mean(
        (jnp.argmax(logits, axis=-1) == batch["labels"]).astype(jnp.float32)
    )
    return loss, {"loss": loss, "xent": xent, "moe_aux": aux, "acc": acc}
