"""Fault-tolerant training loop: checkpoint/restart, preemption drain,
straggler monitoring, deterministic data resume."""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs.base import ModelConfig, ShapeConfig
from repro.data import SyntheticPipeline
from repro.distributed.fault_tolerance import (
    PreemptionGuard,
    StragglerMonitor,
    run_step_with_retry,
)
from repro.optim import make_optimizer, warmup_cosine
from repro.train.train_step import (
    TrainState,
    build_train_step,
    init_train_state,
)


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    lr: float = 3e-4
    warmup_steps: int = 10
    checkpoint_dir: Optional[str] = None
    checkpoint_every: int = 50
    keep_last_k: int = 3
    log_every: int = 10
    seed: int = 0
    grad_compress: bool = False
    max_grad_norm: float = 1.0


class Trainer:
    def __init__(self, cfg: ModelConfig, shape: ShapeConfig, mesh,
                 tc: TrainerConfig) -> None:
        self.cfg = cfg
        self.shape = shape
        self.mesh = mesh
        self.tc = tc
        schedule = warmup_cosine(tc.lr, tc.warmup_steps, tc.total_steps)
        self.optimizer = make_optimizer(cfg.optimizer, schedule)
        self.pipeline = SyntheticPipeline(cfg, shape, seed=tc.seed)
        self.ckpt = (
            CheckpointManager(tc.checkpoint_dir, tc.keep_last_k)
            if tc.checkpoint_dir else None
        )
        self.guard = PreemptionGuard()
        self.straggler = StragglerMonitor()
        self.history: List[Dict[str, float]] = []
        with mesh:
            self.step_fn = build_train_step(
                cfg, shape, mesh, self.optimizer,
                grad_compress=tc.grad_compress,
                max_grad_norm=tc.max_grad_norm,
            )

    # -- state ---------------------------------------------------------------
    def init_or_restore(self) -> TrainState:
        state = init_train_state(
            self.cfg, self.optimizer, jax.random.PRNGKey(self.tc.seed),
            grad_compress=self.tc.grad_compress,
        )
        if self.ckpt is not None and self.ckpt.latest_step() is not None:
            state = self.ckpt.restore(state)
            state = jax.tree.map(jnp.asarray, state)
        return state

    # -- loop ----------------------------------------------------------------
    def run(self, state: Optional[TrainState] = None,
            on_step: Optional[Callable] = None) -> TrainState:
        state = state if state is not None else self.init_or_restore()
        start = int(state.step)
        with self.mesh:
            for step in range(start, self.tc.total_steps):
                batch = {
                    k: jnp.asarray(v)
                    for k, v in self.pipeline.batch_for_step(step).items()
                }
                t0 = time.perf_counter()
                state, metrics = run_step_with_retry(
                    self.step_fn, state, batch
                )
                jax.block_until_ready(metrics["loss"])
                dt = time.perf_counter() - t0
                self.straggler.record(dt, step=step)
                rec = {k: float(v) for k, v in metrics.items()}
                rec["step"] = step
                rec["step_time_s"] = dt
                self.history.append(rec)
                if on_step is not None:
                    on_step(step, rec)
                if step % self.tc.log_every == 0:
                    print(
                        f"step {step:5d} loss {rec['loss']:.4f} "
                        f"acc {rec.get('acc', 0):.3f} {dt*1e3:.0f} ms"
                    )
                should_ckpt = (
                    self.ckpt is not None
                    and ((step + 1) % self.tc.checkpoint_every == 0
                         or self.guard.preempted)
                )
                if should_ckpt:
                    self.ckpt.save(int(state.step), state)
                if self.guard.preempted:
                    print(f"preemption requested: drained at step {step}")
                    break
        if self.ckpt is not None:
            self.ckpt.wait()
        return state
