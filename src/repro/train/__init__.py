from repro.train.losses import loss_and_metrics
from repro.train.train_step import TrainState, build_train_step, init_train_state
from repro.train.trainer import Trainer, TrainerConfig

__all__ = [k for k in dir() if not k.startswith("_")]
