"""Sharded train step: microbatch gradient accumulation (lax.scan) + remat
(inside the model), optimizer update, optional INT8 cross-pod gradient
compression with error feedback.

Collective overlap: the microbatch scan lets XLA's latency-hiding scheduler
overlap each microbatch's gradient reduce-scatter/all-reduce with the next
microbatch's forward; the pod axis (DCN) reduction happens once per step on
the accumulated gradient — optionally int8-compressed (4x fewer DCN bytes).
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import models
from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed.sharding_rules import (
    input_shardings,
    opt_state_specs,
    param_specs,
)
from repro.optim import (
    CompressState,
    Optimizer,
    clip_by_global_norm,
    init_compress_state,
)
from repro.train.losses import loss_and_metrics


class TrainState(NamedTuple):
    params: Any
    opt_state: Any
    step: jnp.ndarray
    compress: Optional[CompressState] = None


def init_train_state(cfg: ModelConfig, optimizer: Optimizer, rng,
                     *, grad_compress: bool = False,
                     dtype=jnp.float32) -> TrainState:
    params = models.init_model_params(cfg, rng, dtype)
    return TrainState(
        params=params,
        opt_state=optimizer.init(params),
        step=jnp.zeros((), jnp.int32),
        compress=init_compress_state(params) if grad_compress else None,
    )


def state_shapes(cfg: ModelConfig, optimizer: Optimizer,
                 *, grad_compress: bool = False, dtype=jnp.float32):
    """Abstract TrainState (dry-run path — no allocation)."""
    p_shapes = models.model_param_shapes(cfg, dtype)
    opt_shapes = jax.eval_shape(optimizer.init, p_shapes)
    comp = (
        jax.eval_shape(init_compress_state, p_shapes)
        if grad_compress else None
    )
    return TrainState(
        params=p_shapes, opt_state=opt_shapes,
        step=jax.ShapeDtypeStruct((), jnp.int32), compress=comp,
    )


def state_specs(cfg: ModelConfig, optimizer: Optimizer, mesh=None,
                *, grad_compress: bool = False, dtype=jnp.float32):
    p_specs = param_specs(cfg, mesh)
    shapes = state_shapes(cfg, optimizer, grad_compress=grad_compress,
                          dtype=dtype)
    o_specs = optimizer.state_specs(p_specs, shapes.params)
    comp_specs = (
        CompressState(residual=p_specs) if grad_compress else None
    )
    return TrainState(params=p_specs, opt_state=o_specs, step=P(),
                      compress=comp_specs)


def _split_microbatches(batch: dict, n_micro: int) -> dict:
    def split(x):
        b = x.shape[0]
        return x.reshape((n_micro, b // n_micro) + x.shape[1:])

    return {k: split(v) for k, v in batch.items()}


def build_train_step(
    cfg: ModelConfig,
    shape: ShapeConfig,
    mesh: Mesh,
    optimizer: Optimizer,
    *,
    grad_compress: bool = False,
    max_grad_norm: float = 1.0,
    donate: bool = True,
):
    """Returns a jitted (state, batch) -> (state, metrics) step."""
    micro = cfg.microbatch_size
    n_micro = 1
    if micro and shape.global_batch > micro:
        assert shape.global_batch % micro == 0
        n_micro = shape.global_batch // micro

    def grads_fn(params, batch):
        if n_micro == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_and_metrics, has_aux=True
            )(params, cfg, batch)
            return grads, metrics
        mb = _split_microbatches(batch, n_micro)

        def body(acc, one):
            (loss, metrics), g = jax.value_and_grad(
                loss_and_metrics, has_aux=True
            )(params, cfg, one)
            acc = jax.tree.map(
                lambda a, b: a + b.astype(jnp.float32) / n_micro, acc, g
            )
            return acc, metrics

        zero = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )
        grads, metrics = jax.lax.scan(body, zero, mb)
        metrics = jax.tree.map(lambda m: m[-1], metrics)
        return grads, metrics

    n_pods = mesh.shape.get("pod", 1)

    def _pod_compressed_grads(params, batch):
        """Cross-pod (DCN) gradient reduction in INT8.

        shard_map with only the 'pod' axis manual: inside, gradients are
        pod-LOCAL (the data/model axes stay auto/GSPMD), so the wire format
        of the one DCN all-reduce per step is ours to choose — int8 codes
        with a pmax-shared scale, 4x fewer DCN bytes than f32. (Under plain
        pjit the reduction happens inside backprop before user code can
        intercept it — measured identical collective bytes; EXPERIMENTS.md
        section Perf, iteration 11.)
        """
        from jax.sharding import PartitionSpec as P

        def inner(params, batch):
            # batch crosses the shard_map boundary pod-replicated (cheap:
            # tokens are int32) and each pod slices its half inside —
            # passing it P('pod') trips an XLA SPMD check when the manual
            # pod axis meets the FSDP embed-gather resharding (b/433785288)
            i = jax.lax.axis_index("pod")

            def slc(b):
                n = b.shape[0] // n_pods
                return jax.lax.dynamic_slice_in_dim(b, i * n, n, 0)

            batch = jax.tree.map(slc, batch)
            grads, metrics = grads_fn(params, batch)

            def one(g):
                scale = jax.lax.pmax(
                    jnp.max(jnp.abs(g)) / 127.0, "pod") + 1e-30
                q = jnp.clip(jnp.round(g / scale), -127, 127)
                s = jax.lax.psum(q.astype(jnp.int32), "pod")
                return s.astype(jnp.float32) * (scale / n_pods)

            grads = jax.tree.map(one, grads)
            metrics = jax.tree.map(
                lambda m: jax.lax.pmean(m, "pod"), metrics)
            return grads, metrics

        return jax.shard_map(
            inner, mesh=mesh,
            in_specs=(P(), P()), out_specs=(P(), P()),
            axis_names={"pod"}, check_vma=False,
        )(params, batch)

    def step_fn(state: TrainState, batch: dict):
        new_compress = state.compress
        if grad_compress and n_pods > 1:
            grads, metrics = _pod_compressed_grads(state.params, batch)
        else:
            grads, metrics = grads_fn(state.params, batch)
        if grad_compress and n_pods == 1 and state.compress is not None:
            # single-pod fallback: error-feedback quantize-dequantize (the
            # compressor itself; the DCN win needs the pod axis above)
            from repro.optim import compress_grads, decompress_sum

            codes, scales, new_compress = compress_grads(
                grads, state.compress
            )
            grads = decompress_sum(
                jax.tree.map(lambda c: c.astype(jnp.int32), codes),
                scales, 1,
            )
        grads, grad_norm = clip_by_global_norm(grads, max_grad_norm)
        new_params, new_opt = optimizer.update(
            grads, state.opt_state, state.params, state.step
        )
        metrics = dict(metrics)
        metrics["grad_norm"] = grad_norm
        new_state = TrainState(
            params=new_params, opt_state=new_opt, step=state.step + 1,
            compress=new_compress,
        )
        return new_state, metrics

    s_specs = state_specs(cfg, optimizer, mesh, grad_compress=grad_compress)
    b_spec_tree = input_shardings(
        cfg, shape, mesh,
        models.input_specs(cfg, shape),
    )
    named = lambda tree: jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda x: isinstance(x, P),
    )
    return jax.jit(
        step_fn,
        in_shardings=(named(s_specs), named(b_spec_tree)),
        out_shardings=(named(s_specs), None),
        donate_argnums=(0,) if donate else (),
    )
