"""Mamba-1 (falcon-mamba) and Mamba-2 (zamba2 backbone) SSM blocks.

The selective scan is a linear recurrence h_t = a_t * h_{t-1} + b_t executed
with ``jax.lax.associative_scan`` (parallel prefix — TPU-friendly, log-depth)
for train/prefill, and a single fused step for decode (O(1) state update —
this is what makes long_500k decode tractable for SSM archs).
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.param import PDef, dense, vector


# ---------------------------------------------------------------------------
# Shared pieces
# ---------------------------------------------------------------------------

def linear_recurrence(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """h_t = a_t * h_{t-1} + b_t (h_{-1}=0) scanned over axis=1 (seq).

    Reference form: materializes the full [B, S, ...] state. Production
    blocks use the chunked form below, which never holds more than one
    chunk's states (the discretized a/b tensors at full S x d_inner x N are
    ~1e14 bytes for the assigned shapes).
    """

    def op(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    _, h = jax.lax.associative_scan(op, (a, b), axis=1)
    return h


def _chunk_recurrence(a: jnp.ndarray, b: jnp.ndarray, h0: jnp.ndarray):
    """In-chunk recurrence with carried initial state.

    a, b: [B, C, ...]; h0: [B, ...]. Returns (h [B, C, ...], h_last).
    h_t = A_t . h0 + B_t where (A, B) is the cumulative affine composition.
    """

    def op(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    A_cum, B_cum = jax.lax.associative_scan(op, (a, b), axis=1)
    h = A_cum * h0[:, None] + B_cum
    return h, h[:, -1]


def _pad_chunks(x: jnp.ndarray, chunk: int):
    """[B, S, ...] -> [nch, B, C, ...] (zero-padded to a chunk multiple)."""
    B, S = x.shape[:2]
    nch = -(-S // chunk)
    pad = nch * chunk - S
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)) + ((0, 0),) * (x.ndim - 2))
    x = x.reshape((B, nch, chunk) + x.shape[2:])
    return jnp.moveaxis(x, 1, 0)


def causal_conv1d(x: jnp.ndarray, w: jnp.ndarray, b: Optional[jnp.ndarray],
                  state: Optional[jnp.ndarray] = None):
    """Depthwise causal conv. x: [B,S,C]; w: [C,W]; state: [B,W-1,C] history.

    Returns (y [B,S,C], new_state [B,W-1,C]).
    """
    B, S, C = x.shape
    W = w.shape[1]
    hist = state if state is not None else jnp.zeros((B, W - 1, C), x.dtype)
    xp = jnp.concatenate([hist, x], axis=1)  # [B, S+W-1, C]
    y = jnp.zeros((B, S, C), x.dtype)
    for i in range(W):  # width is 4: unrolled shift-multiply-accumulate
        y = y + xp[:, i : i + S, :] * w[:, i]
    if b is not None:
        y = y + b
    new_state = xp[:, S : S + W - 1, :]  # last W-1 inputs
    return y, new_state


def _softplus(x):
    return jax.nn.softplus(x)


def _use_scan_kernel() -> bool:
    """Route Mamba-1 through the Pallas selective-scan kernel on TPU (or in
    interpret mode); the CPU lowering keeps the chunked associative scan."""
    import os

    env = os.environ.get("REPRO_PALLAS", "auto")
    if env in ("interpret", "pallas"):
        return True
    if env == "ref":
        return False
    return jax.default_backend() == "tpu"


# ---------------------------------------------------------------------------
# Mamba-1 (falcon-mamba)
# ---------------------------------------------------------------------------

def mamba1_pdefs(cfg: ModelConfig) -> dict:
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    dtr = s.dt_rank or -(-d // 16)
    n = s.state_dim
    return {
        "in_proj": dense(d, 2 * di, "embed", "ssm_inner"),
        "conv_w": PDef((di, s.conv_width), ("ssm_inner", None), init="normal",
                       scale=1.0 / math.sqrt(s.conv_width)),
        "conv_b": vector(di, "ssm_inner"),
        "x_proj": dense(di, dtr + 2 * n, "ssm_inner", None),
        "dt_proj": dense(dtr, di, None, "ssm_inner"),
        "dt_bias": PDef((di,), ("ssm_inner",), init="ones"),
        "A_log": PDef((di, n), ("ssm_inner", None), init="ones"),
        "D": PDef((di,), ("ssm_inner",), init="ones"),
        "out_proj": dense(di, d, "ssm_inner", "embed"),
    }


def mamba1_block(x: jnp.ndarray, p: dict, cfg: ModelConfig,
                 state: Optional[dict] = None) -> Tuple[jnp.ndarray, Optional[dict]]:
    """x: [B,S,D] -> [B,S,D]. state (decode): {'h':[B,di,N], 'conv':[B,W-1,di]}."""
    s = cfg.ssm
    B, S, D = x.shape
    di = s.d_inner(D)
    dtr = s.dt_rank or -(-D // 16)
    n = s.state_dim
    xz = x @ p["in_proj"]
    xs, z = jnp.split(xz, 2, axis=-1)  # [B,S,di]
    xs, new_conv = causal_conv1d(
        xs, p["conv_w"], p["conv_b"],
        state=None if state is None else state["conv"],
    )
    xs = jax.nn.silu(xs)
    proj = xs @ p["x_proj"]  # [B,S,dtr+2n]
    dt, Bc, Cc = jnp.split(proj, [dtr, dtr + n], axis=-1)
    dt = _softplus(dt @ p["dt_proj"] + p["dt_bias"])  # [B,S,di]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))  # [di,N]
    if state is None and _use_scan_kernel():
        # TPU: the Pallas selective-scan kernel keeps h in VMEM for the
        # whole sequence — O(S*d) HBM instead of O(S*d*N) fusion boundaries
        # (y already includes the D*x skip term).
        from repro.kernels import ops

        y, new_h = ops.selective_scan(xs, dt, Bc, Cc, A, p["D"])
        y = y.astype(jnp.float32)
        y = y * jax.nn.silu(z.astype(jnp.float32))
        out = y.astype(x.dtype) @ p["out_proj"]
        return out, {"h": new_h, "conv": new_conv}
    if state is None:
        # Chunked selective scan: discretized (a, b) exist one chunk at a
        # time — [B, C, di, N] instead of [B, S, di, N].
        chunk = min(s.scan_chunk, S)

        def body(h0, sl):
            dtc = sl["dt"].astype(jnp.float32)
            a = jnp.exp(dtc[..., None] * A)  # [B,C,di,N]
            b = (dtc * sl["x"].astype(jnp.float32))[..., None] \
                * sl["B"][:, :, None, :].astype(jnp.float32)
            h, h_last = _chunk_recurrence(a, b, h0)
            y = jnp.einsum("bcdn,bcn->bcd", h, sl["C"].astype(jnp.float32))
            return h_last, y.astype(sl["x"].dtype)

        sls = {"dt": _pad_chunks(dt, chunk), "x": _pad_chunks(xs, chunk),
               "B": _pad_chunks(Bc, chunk), "C": _pad_chunks(Cc, chunk)}
        h0 = jnp.zeros((B, di, n), jnp.float32)
        new_h, ys = jax.lax.scan(body, h0, sls)
        y = jnp.moveaxis(ys, 0, 1).reshape(B, -1, di)[:, :S]
    else:
        a1 = jnp.exp(dt[:, 0, :, None].astype(jnp.float32) * A)
        b1 = (dt * xs)[:, 0, :, None].astype(jnp.float32) \
            * Bc[:, 0, None, :].astype(jnp.float32)
        h = (a1 * state["h"] + b1)[:, None]  # S==1 decode
        new_h = h[:, 0]
        y = jnp.einsum("bsdn,bsn->bsd", h, Cc.astype(jnp.float32))
    y = y + xs.astype(jnp.float32) * p["D"]
    y = y * jax.nn.silu(z.astype(jnp.float32))
    out = y.astype(x.dtype) @ p["out_proj"]
    return out, {"h": new_h, "conv": new_conv}


# ---------------------------------------------------------------------------
# Mamba-2 (zamba2 backbone): scalar per-head decay, SSD-style
# ---------------------------------------------------------------------------

def mamba2_pdefs(cfg: ModelConfig) -> dict:
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    nh = s.num_ssm_heads(d)
    n = s.state_dim
    conv_dim = di + 2 * n  # conv over (x, B, C)
    return {
        "in_proj": dense(d, 2 * di + 2 * n + nh, "embed", "ssm_inner"),
        "conv_w": PDef((conv_dim, s.conv_width), ("ssm_inner", None),
                       init="normal", scale=1.0 / math.sqrt(s.conv_width)),
        "conv_b": vector(conv_dim, "ssm_inner"),
        "A_log": PDef((nh,), ("ssm_inner",), init="ones"),
        "dt_bias": PDef((nh,), ("ssm_inner",), init="ones"),
        "D": PDef((nh,), ("ssm_inner",), init="ones"),
        "norm_scale": vector(di, "ssm_inner", "zeros"),
        "out_proj": dense(di, d, "ssm_inner", "embed"),
    }


def mamba2_block(x: jnp.ndarray, p: dict, cfg: ModelConfig,
                 state: Optional[dict] = None) -> Tuple[jnp.ndarray, Optional[dict]]:
    """SSD block. state (decode): {'h':[B,H,P,N], 'conv':[B,W-1,conv_dim]}."""
    s = cfg.ssm
    B, S, D = x.shape
    di = s.d_inner(D)
    nh = s.num_ssm_heads(D)
    P = s.head_dim
    n = s.state_dim
    zxbcdt = x @ p["in_proj"]
    z, xbc, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * n], axis=-1)
    xbc, new_conv = causal_conv1d(
        xbc, p["conv_w"], p["conv_b"],
        state=None if state is None else state["conv"],
    )
    xbc = jax.nn.silu(xbc)
    xs, Bc, Cc = jnp.split(xbc, [di, di + n], axis=-1)
    dt = _softplus(dt + p["dt_bias"])  # [B,S,H]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))  # [H]
    xh = xs.reshape(B, S, nh, P)
    if state is None:
        # Chunked SSD in *matrix form* (Mamba-2 paper section 6; perf
        # iteration 5): per-head scalar decay lets the intra-chunk
        # contribution collapse to an attention-like [B, H, C, C] score
        # matmul — never materializing the [B, C, H, P, N] discretized
        # states (235 GB/chunk at zamba2 train_4k; scores are 0.12 GB).
        # All exponents are of non-positive values (decay), so it is
        # numerically stable.
        chunk = min(s.scan_chunk, S)

        def body(h0, sl):
            dtc = sl["dt"].astype(jnp.float32)  # [B,C,H]
            x = sl["x"].astype(jnp.float32)  # [B,C,H,P]
            Bcc = sl["B"].astype(jnp.float32)  # [B,C,N]
            Ccc = sl["C"].astype(jnp.float32)  # [B,C,N]
            lam = jnp.cumsum(dtc * A, axis=1)  # [B,C,H], non-increasing
            cb = jnp.einsum("btn,bsn->bts", Ccc, Bcc)  # [B,C,C]
            seg = lam[:, :, None, :] - lam[:, None, :, :]  # [B,t,s,H] <= 0
            C_ = dtc.shape[1]
            tri = jnp.tril(jnp.ones((C_, C_), bool))[None, :, :, None]
            # double-where: above the diagonal seg > 0 and exp overflows;
            # zeroing seg first keeps the *backward* free of inf*0 = NaN
            seg = jnp.where(tri, seg, 0.0)
            M = jnp.where(
                tri,
                jnp.exp(seg) * dtc[:, None, :, :] * cb[..., None],
                0.0,
            )  # [B,t,s,H]
            y_intra = jnp.einsum("btsh,bshp->bthp", M, x)
            y_inter = jnp.exp(lam)[..., None] * jnp.einsum(
                "bcn,bhpn->bchp", Ccc, h0)
            dec = jnp.exp(lam[:, -1:, :] - lam) * dtc  # [B,C,H]
            h_new = jnp.einsum("bshp,bsh,bsn->bhpn", x, dec, Bcc) \
                + jnp.exp(lam[:, -1])[..., None, None] * h0
            # stack chunk outputs at the activation dtype: the f32 scan
            # carry (h) keeps full state precision; the per-chunk y stream
            # is ordinary activation data (perf iteration 6)
            return h_new, (y_intra + y_inter).astype(sl["x"].dtype)

        sls = {"dt": _pad_chunks(dt, chunk), "x": _pad_chunks(xh, chunk),
               "B": _pad_chunks(Bc, chunk), "C": _pad_chunks(Cc, chunk)}
        h0 = jnp.zeros((B, nh, P, n), jnp.float32)
        new_h, ys = jax.lax.scan(body, h0, sls)
        y = jnp.moveaxis(ys, 0, 1).reshape(B, -1, nh, P)[:, :S]
    else:
        a1 = jnp.exp(dt[:, 0].astype(jnp.float32) * A)[..., None, None]
        b1 = (dt[:, 0, :, None] * xh[:, 0].astype(jnp.float32))[..., None] \
            * Bc[:, 0, None, None, :].astype(jnp.float32)
        h = (a1 * state["h"] + b1)[:, None]
        new_h = h[:, 0]
        y = jnp.einsum("bshpn,bsn->bshp", h, Cc.astype(jnp.float32))
    y = y + xh.astype(jnp.float32) * p["D"][:, None]
    y = y.reshape(B, S, di)
    # gated RMSNorm (mamba2): norm(y * silu(z))
    y = y * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(jnp.square(y), axis=-1, keepdims=True)
    y = y * jax.lax.rsqrt(var + 1e-6) * (1.0 + p["norm_scale"])
    out = y.astype(x.dtype) @ p["out_proj"]
    new_state = {"h": new_h, "conv": new_conv}
    return out, new_state
