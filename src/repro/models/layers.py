"""Shared model layers: norms, RoPE, GQA attention (fp + CoQMoE-quantized),
MLP variants. Pure functions over param pytrees.

Attention dispatches through ``repro.kernels.ops`` so the TPU build uses the
Pallas streaming kernels while CPU (tests / dry-run) uses the jnp reference.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import AttnConfig, ModelConfig

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm(x: jnp.ndarray, gamma: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + gamma)).astype(dt)  # gemma-style (1+g); init gamma=0


def layernorm(x: jnp.ndarray, gamma: jnp.ndarray, beta: jnp.ndarray,
              eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * gamma + beta).astype(dt)


def apply_norm(x: jnp.ndarray, p: dict, cfg: ModelConfig) -> jnp.ndarray:
    if cfg.norm == "layernorm":
        y = layernorm(x, p["scale"], p["bias"])
    else:
        y = rmsnorm(x, p["scale"])
    if "a_scale" in p:
        # PTQ runtime: per-layer symmetric quantizer with the reparam scale
        # s_tilde (the ``a_scale`` leaf is inserted by core.quant.ptq after
        # the Eq. 10-16 fold; absent in FP models).
        from repro.core.quant.linear_quant import fake_quant_activation

        y = fake_quant_activation(y.astype(jnp.float32), p["a_scale"],
                                  bits=cfg.quant.a_bits).astype(y.dtype)
    return y


def maybe_fake_quant(x: jnp.ndarray, p: dict, key: str, cfg: ModelConfig):
    """Per-tensor symmetric activation quant at a linear input site."""
    if key in p:
        from repro.core.quant.linear_quant import fake_quant_activation

        return fake_quant_activation(
            x.astype(jnp.float32), p[key], bits=cfg.quant.a_bits
        ).astype(x.dtype)
    return x


# ---------------------------------------------------------------------------
# The quantized/full-precision linear seam (DESIGN.md section 4)
# ---------------------------------------------------------------------------

def quant_linear(x: jnp.ndarray, p: dict, key: str,
                 cfg: ModelConfig) -> jnp.ndarray:
    """Apply the linear layer stored at ``p[key]`` — the single seam every
    linear call site (QKV, out-proj, MLP fc1/fc2, gate, head, patch/frontend
    projections) routes through.

    Dispatch is on the weight leaf dtype:

      * fp leaf — the plain matmul (FP and fake-quant models; fake-quant
        weights are f32 values on the int8 grid, the numerical oracle);
      * int8 leaf (``ptq_model(..., materialize="int8")``) — quantize the
        incoming activation with the folded per-site ``<key>_as`` scale and
        run the int8 kernel (``kernels/int8_matmul.py``), dequantizing once
        on the int32 accumulator (Eq. 9). Sites with no calibrated
        activation scale (raw-input projections, e.g. patch_proj) keep the
        activation fp; the per-output-channel weight scale factors out of
        the contraction and is applied once to the accumulator.

    MoE expert *stacks* do not pass through here — they go through
    ``kernels.ops.grouped_mlp`` with ``w_scale=`` (the grouped analogue of
    the same contract).
    """
    w = p[key]
    if w.dtype == jnp.uint8:
        # Nibble-packed int4 leaf at a quant_linear site. The packed
        # *kernel* execution exists only for the grouped expert path (the
        # scheme-map policy keeps quant_linear sites int8 — ptq validates
        # that), so this is a compatibility path for hand-built trees:
        # unpack once to int4 values held in int8 and fall through the
        # int8 dispatch below — same grids, same Eq. 9 rescale.
        from repro.core.quant.qtypes import unpack_int4

        w = unpack_int4(w, x.shape[-1])
    if w.dtype != jnp.int8:
        return x @ w
    from repro.core.quant.qtypes import (
        ASCALE_SUFFIX,
        SCALE_SUFFIX,
        quantize_sym,
    )
    from repro.kernels import ops  # lazy: avoids import cycle

    w_scale = p[key + SCALE_SUFFIX]
    # out-proj sites reuse the oracle's per-tensor mid scale (one leaf, no
    # duplicated `wo_as` copy that could drift from it)
    a_scale = p.get(key + ASCALE_SUFFIX,
                    p.get("wo_a_scale") if key == "wo" else None)
    lead, d_in = x.shape[:-1], x.shape[-1]
    x2 = x.reshape(-1, d_in)
    if a_scale is None:
        # Weight-only site: x stays fp; s_w is per-output-channel, so it
        # commutes out of the contraction — the int8->f32 convert fuses
        # into the dot and the rescale touches only the [out] vector.
        y = (x2.astype(jnp.float32) @ w.astype(jnp.float32)) * w_scale
    else:
        x_q = quantize_sym(x2.astype(jnp.float32), a_scale,
                           cfg.quant.a_bits)
        y = ops.int8_matmul(x_q, w, a_scale, w_scale)
    return y.reshape(lead + (w.shape[-1],)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Activations
# ---------------------------------------------------------------------------

def act_fn(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return partial(jax.nn.gelu, approximate=True)
    if name == "relu2":
        return lambda x: jnp.square(jax.nn.relu(x))
    raise ValueError(name)


def mlp_apply(x: jnp.ndarray, p: dict, cfg: ModelConfig, taps=None) -> jnp.ndarray:
    """GLU (wi fused [d, 2ff]) or plain MLP (wi [d, ff]); wo [ff, d]."""
    from repro.core.quant.calibrate import maybe_record

    a = act_fn(cfg.act)
    h = quant_linear(x, p, "wi", cfg)
    if "bi" in p:
        h = h + p["bi"]
    if cfg.glu:
        gate, up = jnp.split(h, 2, axis=-1)
        h = a(gate) * up
    else:
        h = a(h)
    maybe_record(taps, "mlp_mid", h)
    if p["wo"].dtype != jnp.int8:
        h = maybe_fake_quant(h, p, "wo_a_scale", cfg)
    y = quant_linear(h, p, "wo", cfg)
    if "bo" in p:
        y = y + p["bo"]
    return y


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: [..., S, H, hd]; positions: [..., S] int32."""
    if theta <= 0:
        return x
    hd = x.shape[-1]
    half = hd // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., :, None].astype(jnp.float32) * freq  # [..., S, half]
    cos = jnp.cos(ang)[..., :, None, :]  # [..., S, 1, half]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = jnp.split(x, 2, axis=-1)
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention block (projections + rope + cache handling; the core attention
# math lives behind kernels/ops.attention -> Pallas on TPU, ref.py on CPU)
# ---------------------------------------------------------------------------

def quantize_kv(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Per (position, head) symmetric int8: x [B,S,KVH,hd] -> (int8, scale)."""
    absmax = jnp.maximum(jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1), 1e-6)
    scale = absmax / 127.0  # [B, S, KVH]
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]), -128, 127)
    return q.astype(jnp.int8), scale.astype(jnp.float32)


def project_memory_kv(memory: jnp.ndarray, p: dict, a: AttnConfig,
                      cfg: Optional[ModelConfig] = None) -> tuple:
    """Cross-attention K/V from encoder memory (computed once, then cached)."""
    B, S_enc = memory.shape[0], memory.shape[1]
    k = quant_linear(memory, p, "wk", cfg).reshape(
        B, S_enc, a.num_kv_heads, a.head_dim)
    v = quant_linear(memory, p, "wv", cfg).reshape(
        B, S_enc, a.num_kv_heads, a.head_dim)
    if "bk" in p:
        k = k + p["bk"].reshape(1, 1, a.num_kv_heads, a.head_dim)
        v = v + p["bv"].reshape(1, 1, a.num_kv_heads, a.head_dim)
    return k, v


def attention_block(
    x: jnp.ndarray,  # [B, S, D]
    p: dict,
    cfg: ModelConfig,
    a: AttnConfig,
    *,
    positions: jnp.ndarray,  # [S] (decode: absolute positions, traceable)
    causal: bool = True,
    local_window: int = 0,
    cache: Optional[dict] = None,
    cache_index=None,  # scalar int32, decode fill position
    memory: Optional[jnp.ndarray] = None,  # cross-attention (enc-dec)
    memory_kv: Optional[tuple] = None,  # precomputed cross (k, v) [B,S,KVH,hd]
    segment_ids: Optional[jnp.ndarray] = None,  # [B, S] packed prefill
    taps=None,
) -> tuple[jnp.ndarray, Optional[dict]]:
    """Full MSA block: qkv proj -> rope -> streaming attention -> out proj.

    cache (decode): {"k": [B,Smax,KVH,hd] (int8 or fp), "v": ...,
    optional "k_scale"/"v_scale": [B,Smax,KVH]}.

    segment_ids (packed prefill, DESIGN.md section 10): marks each buffer
    position with its prompt id; attention is confined to equal ids. RoPE
    still uses ``positions`` (within-segment), while causal/window masking
    runs on buffer indices — equal to within-segment distances because
    segments are contiguous.
    """
    from repro.kernels import ops  # lazy: avoids import cycle

    B, S, D = x.shape
    src = memory if memory is not None else x
    q = quant_linear(x, p, "wq", cfg).reshape(B, S, a.num_heads, a.head_dim)
    if "bq" in p:
        q = q + p["bq"].reshape(1, 1, a.num_heads, a.head_dim)
    if memory_kv is not None:
        k, v = memory_kv
    else:
        k = quant_linear(src, p, "wk", cfg).reshape(
            B, src.shape[1], a.num_kv_heads, a.head_dim)
        v = quant_linear(src, p, "wv", cfg).reshape(
            B, src.shape[1], a.num_kv_heads, a.head_dim)
        if "bk" in p:
            k = k + p["bk"].reshape(1, 1, a.num_kv_heads, a.head_dim)
            v = v + p["bv"].reshape(1, 1, a.num_kv_heads, a.head_dim)
    if a.qk_norm:
        q = rmsnorm(q, p["q_norm"])
        if memory_kv is None:
            k = rmsnorm(k, p["k_norm"])
    is_cross = memory is not None or memory_kv is not None
    if not is_cross:
        q = rope(q, positions, a.rope_theta)
        k = rope(k, positions, a.rope_theta)
    quant_bits = cfg.quant.attn_bits if cfg.quant.enable else 0
    new_cache = None
    if cache is not None:
        # cache_index: scalar (lockstep batch) or [B] vector (continuous
        # batching: every serving slot fills its own position).
        idx = jnp.asarray(cache_index, jnp.int32)
        ragged = idx.ndim == 1
        smax = cache["k"].shape[1]
        # Sliding-window ring cache (perf iteration 4): local-attention
        # layers allocate only `window` slots; positions write at
        # idx % smax. RoPE is applied at the *absolute* position before
        # caching, so slot order never matters; the ring size itself
        # enforces the window, and the window mask is dropped at decode.
        ring = 0 < local_window and smax <= local_window

        def put(buf, new, base_idx):
            if not ragged:
                start = (0, base_idx) + (0,) * (buf.ndim - 2)
                return jax.lax.dynamic_update_slice(
                    buf, new.astype(buf.dtype), start
                )
            return jax.vmap(
                lambda c, n, i: jax.lax.dynamic_update_slice(
                    c, n.astype(c.dtype), (i,) + (0,) * (c.ndim - 1)
                )
            )(buf, new, base_idx)

        int8_kv = cache["k"].dtype == jnp.int8
        if int8_kv:
            k_q, k_s = quantize_kv(k)
            v_q, v_s = quantize_kv(v)
        else:
            k_q, v_q, k_s, v_s = k, v, None, None

        if segment_ids is not None and ring:
            raise NotImplementedError(
                "packed prefill is incompatible with ring (sliding-window) "
                "caches — the engine keeps the grouped admission path for "
                "alternating local/global archs"
            )

        if ring and S > 1:
            # prefill into a ring: keep the last `smax` entries, rotated so
            # entry for position p lands in slot p % smax
            def ring_fill(buf, new):
                kept = new[:, -smax:] if new.shape[1] >= smax else new
                if new.shape[1] >= smax:
                    shift = (new.shape[1] - smax) % smax
                    kept = jnp.roll(kept, shift, axis=1)
                    return put(buf, kept, jnp.int32(0))
                return put(buf, kept, jnp.int32(0))

            new_cache = {"k": ring_fill(cache["k"], k_q),
                         "v": ring_fill(cache["v"], v_q)}
            if int8_kv:
                new_cache["k_scale"] = ring_fill(cache["k_scale"], k_s)
                new_cache["v_scale"] = ring_fill(cache["v_scale"], v_s)
            # prefill attention runs over the fresh full-length K/V
            out = ops.attention(
                q, k_q if not int8_kv else k_q, v_q,
                causal=causal, q_offset=idx, quant_bits=quant_bits,
                logit_softcap=a.logit_softcap, local_window=local_window,
                k_scale=k_s, v_scale=v_s,
            )
        else:
            write_idx = idx % smax if ring else idx
            k_cache = put(cache["k"], k_q, write_idx)
            v_cache = put(cache["v"], v_q, write_idx)
            new_cache = {"k": k_cache, "v": v_cache}
            ks = vs = None
            if int8_kv:
                ks = put(cache["k_scale"], k_s, write_idx)
                vs = put(cache["v_scale"], v_s, write_idx)
                new_cache["k_scale"], new_cache["v_scale"] = ks, vs
            valid = jnp.broadcast_to(
                jnp.minimum(idx + S, smax) if ring else idx + S, (B,)
            ).astype(jnp.int32)
            kv_segs = None
            if segment_ids is not None:
                # cache rows beyond the packed region are masked by
                # kv_valid_len; pad with a never-matching id for shape only
                kv_segs = jnp.pad(
                    segment_ids.astype(jnp.int32),
                    ((0, 0), (0, smax - S)), constant_values=-2,
                )
            out = ops.attention(
                q, k_cache, v_cache,
                causal=causal, q_offset=idx, quant_bits=quant_bits,
                logit_softcap=a.logit_softcap,
                local_window=0 if ring else local_window,
                k_scale=ks, v_scale=vs, kv_valid_len=valid,
                q_segment_ids=(None if segment_ids is None
                               else segment_ids.astype(jnp.int32)),
                kv_segment_ids=kv_segs,
            )
    else:
        out = ops.attention(
            q, k, v,
            causal=causal and not is_cross,
            quant_bits=quant_bits,
            logit_softcap=a.logit_softcap,
            local_window=0 if is_cross else local_window,
            q_segment_ids=(None if segment_ids is None or is_cross
                           else segment_ids.astype(jnp.int32)),
        )
    from repro.core.quant.calibrate import maybe_record

    out = out.reshape(B, S, a.num_heads * a.head_dim)
    maybe_record(taps, "attn_out", out)
    if p["wo"].dtype != jnp.int8:
        out = maybe_fake_quant(out, p, "wo_a_scale", cfg)
    y = quant_linear(out, p, "wo", cfg)
    if "bo" in p:
        y = y + p["bo"]
    return y, new_cache
