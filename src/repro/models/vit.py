"""ViT / DeiT / M3ViT (MoE-ViT) — the paper's own architectures.

Input is flattened 16x16x3 patches [B, 196, 768] (ImageNet is not available
in-container; the benchmark harness feeds calibrated synthetic patches).
M3ViT replaces every other MLP with a 16-expert top-2 MoE block (scan over
(dense, moe) layer pairs).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.quant.calibrate import maybe_record
from repro.models.layers import (
    apply_norm,
    attention_block,
    mlp_apply,
    quant_linear,
)
from repro.models.param import PDef, dense, stack_tree, vector
from repro.models.transformer import (
    _attn_pdefs,
    _expert_count_zeros,
    _mlp_pdefs,
    _moe_pdefs,
    _moe_apply,
    _norm_pdefs,
)

PATCH_DIM = 768  # 16*16*3


def _vit_layer_pdefs(cfg: ModelConfig, moe: bool) -> dict:
    p = {
        "ln1": _norm_pdefs(cfg),
        "attn": _attn_pdefs(cfg, bias=True),
        "ln2": _norm_pdefs(cfg),
    }
    if moe:
        m = _moe_pdefs(cfg)
        m["gate_b"] = vector(cfg.moe.num_experts, None)
        hid = 2 * cfg.moe.d_ff if cfg.glu else cfg.moe.d_ff
        m["bi"] = PDef((cfg.moe.num_experts, hid), ("expert", "mlp"))
        m["bo"] = PDef((cfg.moe.num_experts, cfg.d_model), ("expert", "embed"))
        p["moe"] = m
    else:
        p["mlp"] = _mlp_pdefs(cfg, cfg.d_ff, bias=True)
    return p


def abstract_params(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    tree: dict = {
        "patch_proj": dense(PATCH_DIM, d, None, "embed"),
        "patch_bias": vector(d, "embed"),
        "cls_token": PDef((1, 1, d), (None, None, "embed"), init="small_normal"),
        "pos_embed": PDef((cfg.image_tokens, d), (None, "embed"), init="small_normal"),
        "final_norm": _norm_pdefs(cfg),
        "head": dense(d, cfg.num_classes, "embed", None, scale=0.02),
        "head_b": vector(cfg.num_classes, None),
    }
    if cfg.family == "vit_moe":
        n_pairs = cfg.num_layers // 2
        tree["pairs_dense"] = stack_tree(_vit_layer_pdefs(cfg, moe=False), n_pairs)
        tree["pairs_moe"] = stack_tree(_vit_layer_pdefs(cfg, moe=True), n_pairs)
    else:
        tree["layers"] = stack_tree(_vit_layer_pdefs(cfg, moe=False), cfg.num_layers)
    return tree


def _vit_block(x, lp, cfg, *, positions, taps=None):
    h = apply_norm(x, lp["ln1"], cfg)
    maybe_record(taps, "post_ln1", h)
    attn, _ = attention_block(h, lp["attn"], cfg, cfg.attn,
                              positions=positions, causal=False, taps=taps)
    x = x + attn
    h = apply_norm(x, lp["ln2"], cfg)
    maybe_record(taps, "post_ln2", h)
    aux = jnp.zeros((), jnp.float32)
    ec = _expert_count_zeros(cfg)
    if "moe" in lp:
        ff, aux, ec = _moe_apply(h, lp["moe"], cfg, taps=taps)
    else:
        ff = mlp_apply(h, lp["mlp"], cfg, taps=taps)
    return x + ff, aux, ec


def _forward(params, cfg: ModelConfig, patches: jnp.ndarray, taps=None):
    """Shared forward body.

    patches [B, image_tokens-1, PATCH_DIM] -> (logits [B, C], aux,
    expert_counts [E] int32) — expert_counts is the routed-token histogram
    summed over all MoE layers ([0] for plain ViT), consumed by the serving
    occupancy metric (DESIGN.md section 6)."""
    B = patches.shape[0]
    w_pp = params["patch_proj"]
    patches = patches.astype(
        jnp.float32 if w_pp.dtype == jnp.int8 else w_pp.dtype
    )
    x = quant_linear(patches, params, "patch_proj", cfg) + params["patch_bias"]
    cls = jnp.broadcast_to(params["cls_token"], (B, 1, cfg.d_model)).astype(x.dtype)
    x = jnp.concatenate([cls, x], axis=1) + params["pos_embed"]
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)
    aux_total = jnp.zeros((), jnp.float32)
    ec_total = _expert_count_zeros(cfg)

    if taps is not None:  # eager calibration path
        if cfg.family == "vit_moe":
            for i in range(cfg.num_layers // 2):
                for kind in ("pairs_dense", "pairs_moe"):
                    lp = jax.tree.map(lambda a: a[i], params[kind])
                    scope = f"L{kind.removeprefix('pairs_')}{i:03d}"
                    x, aux, ec = _vit_block(x, lp, cfg, positions=positions,
                                            taps=taps.scoped(scope))
                    aux_total += aux
                    ec_total += ec
        else:
            for i in range(cfg.num_layers):
                lp = jax.tree.map(lambda a: a[i], params["layers"])
                x, aux, ec = _vit_block(x, lp, cfg, positions=positions,
                                        taps=taps.scoped(f"L{i:03d}"))
                aux_total += aux
                ec_total += ec
    elif cfg.family == "vit_moe":
        def body(carry, xs):
            x, aux, ec = carry
            x, a1, e1 = _vit_block(x, xs["dense"], cfg, positions=positions)
            x, a2, e2 = _vit_block(x, xs["moe"], cfg, positions=positions)
            return (x, aux + a1 + a2, ec + e1 + e2), None

        if cfg.remat:
            body = jax.checkpoint(body)
        (x, aux_total, ec_total), _ = jax.lax.scan(
            body, (x, aux_total, ec_total),
            {"dense": params["pairs_dense"], "moe": params["pairs_moe"]},
        )
    else:
        def body(carry, lp):
            x, aux, ec = carry
            x, a, e = _vit_block(x, lp, cfg, positions=positions)
            return (x, aux + a, ec + e), None

        if cfg.remat:
            body = jax.checkpoint(body)
        (x, aux_total, ec_total), _ = jax.lax.scan(
            body, (x, aux_total, ec_total), params["layers"])

    x = apply_norm(x, params["final_norm"], cfg)
    maybe_record(taps, "final_norm", x)
    logits = quant_linear(x[:, 0, :], params, "head", cfg) + params["head_b"]
    return logits, aux_total, ec_total


def forward(params, cfg: ModelConfig, patches: jnp.ndarray,
            frontend_embeds=None, taps=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """patches: [B, image_tokens-1, PATCH_DIM] -> (class logits [B, C], aux)."""
    logits, aux, _ = _forward(params, cfg, patches, taps=taps)
    return logits, aux


def classify(params, cfg: ModelConfig, patches: jnp.ndarray,
             *, top_k: int = 5) -> dict:
    """Batched serving entry point (what ``VisionEngine`` jits per bucket).

    patches [B, image_tokens-1, PATCH_DIM] -> {"classes" [B, k] int32,
    "probs" [B, k] f32 (descending), "expert_tokens" [E] int32}. Accepts fp,
    fake-quant, or materialized-int8 ``QuantizedParams`` trees through the
    same ``quant_linear`` seam as ``forward``."""
    logits, _, ec = _forward(params, cfg, patches)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    top_p, top_i = jax.lax.top_k(probs, min(top_k, cfg.num_classes))
    return {"classes": top_i.astype(jnp.int32), "probs": top_p,
            "expert_tokens": ec}
