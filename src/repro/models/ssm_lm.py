"""falcon-mamba LM: embed -> scanned Mamba-1 blocks (pre-RMSNorm, residual)
-> final norm -> tied head. Decode state is O(1) per layer (long_500k-safe).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.quant.calibrate import maybe_record
from repro.models.layers import apply_norm
from repro.models.param import PDef, stack_tree
from repro.models.ssm import mamba1_block, mamba1_pdefs
from repro.models.transformer import logits_from_hidden, _norm_pdefs


def abstract_params(cfg: ModelConfig) -> dict:
    layer = {"ln": _norm_pdefs(cfg), "mamba": mamba1_pdefs(cfg)}
    tree = {
        "embed": PDef((cfg.vocab_size, cfg.d_model), ("vocab", "embed"),
                      init="small_normal"),
        "layers": stack_tree(layer, cfg.num_layers),
        "final_norm": _norm_pdefs(cfg),
    }
    if not cfg.tie_embeddings:
        tree["lm_head"] = PDef((cfg.d_model, cfg.vocab_size), ("embed", "vocab"),
                               init="small_normal")
    return tree


def _run(params, cfg, x, states=None, taps=None):
    if taps is not None:
        for i in range(cfg.num_layers):
            lp = jax.tree.map(lambda a: a[i], params["layers"])
            h = apply_norm(x, lp["ln"], cfg)
            maybe_record(taps.scoped(f"L{i:03d}"), "post_ln1", h)
            y, _ = mamba1_block(h, lp["mamba"], cfg)
            x = x + y
        return x, None

    def body(x, xs):
        lp = xs["p"]
        h = apply_norm(x, lp["ln"], cfg)
        y, new_state = mamba1_block(
            h, lp["mamba"], cfg, state=xs.get("state")
        )
        return x + y, new_state

    if cfg.remat and states is None:
        body = jax.checkpoint(body)
    xs = {"p": params["layers"]}
    if states is not None:
        xs["state"] = states
    x, new_states = jax.lax.scan(body, x, xs)
    return x, new_states


def forward(params, cfg: ModelConfig, tokens: jnp.ndarray,
            frontend_embeds=None, taps=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    x = params["embed"][tokens]
    x, _ = _run(params, cfg, x, taps=taps)
    return logits_from_hidden(params, cfg, x, taps=taps), jnp.zeros((), jnp.float32)


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    """SSM 'cache' = recurrent state; max_len is irrelevant (O(1) state)."""
    s = cfg.ssm
    di = s.d_inner(cfg.d_model)
    L = cfg.num_layers
    return {
        "h": jnp.zeros((L, batch, di, s.state_dim), jnp.float32),
        "conv": jnp.zeros((L, batch, s.conv_width - 1, di), dtype),
    }


def cache_shapes(cfg, batch, max_len, dtype=jnp.bfloat16):
    return jax.eval_shape(lambda: init_cache(cfg, batch, max_len, dtype))


def prefill(params, cfg: ModelConfig, tokens: jnp.ndarray,
            frontend_embeds=None, max_len: Optional[int] = None):
    x = params["embed"][tokens]
    # parallel scan path (states=None) still emits each layer's final state,
    # which lax.scan stacks into exactly the init_cache structure.
    x, new_states = _run(params, cfg, x, states=None)
    logits = logits_from_hidden(params, cfg, x[:, -1:, :])
    return logits, new_states


def decode_step(params, cfg: ModelConfig, tokens: jnp.ndarray, states,
                index=None):
    x = params["embed"][tokens]  # [B,1,D]
    x, new_states = _run(params, cfg, x, states=states)
    return logits_from_hidden(params, cfg, x), new_states
