"""Model registry: one uniform API over every family.

``module_for(cfg)`` returns the family module; each module exposes
``abstract_params(cfg)``, ``forward(params, cfg, tokens, frontend_embeds,
taps)`` and (decoder families) ``prefill`` / ``decode_step`` / ``init_cache``
/ ``cache_shapes``.

``input_specs(cfg, shape)`` builds the ShapeDtypeStruct stand-ins for every
model input of a (arch x shape) dry-run cell — weak-type-correct, shardable,
no device allocation.
"""
from __future__ import annotations

from types import ModuleType
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import encdec, hybrid, ssm_lm, transformer, vit
from repro.models.param import init_params, param_logical_axes, param_shapes

_FAMILY_MODULES: Dict[str, ModuleType] = {
    "dense": transformer,
    "moe": transformer,
    "vlm": transformer,
    "ssm": ssm_lm,
    "hybrid": hybrid,
    "encdec": encdec,
    "vit": vit,
    "vit_moe": vit,
}


def module_for(cfg: ModelConfig) -> ModuleType:
    return _FAMILY_MODULES[cfg.family]


def abstract_params(cfg: ModelConfig):
    return module_for(cfg).abstract_params(cfg)


def model_param_shapes(cfg: ModelConfig, dtype=jnp.float32):
    return param_shapes(abstract_params(cfg), dtype)


def model_param_axes(cfg: ModelConfig):
    return param_logical_axes(abstract_params(cfg))


def init_model_params(cfg: ModelConfig, rng, dtype=jnp.float32):
    return init_params(abstract_params(cfg), rng, dtype)


# ---------------------------------------------------------------------------
# Input specs (dry-run stand-ins) and matching synthetic-batch construction
# ---------------------------------------------------------------------------

def _frontend_tokens(cfg: ModelConfig, shape: ShapeConfig) -> int:
    """Tokens contributed by the modality frontend for a given cell."""
    if not cfg.frontend:
        return 0
    if cfg.family == "encdec":
        return shape.seq_len  # frames ARE the encoder sequence
    return min(cfg.frontend_tokens, max(shape.seq_len // 2, 8))


def text_tokens_for(cfg: ModelConfig, shape: ShapeConfig) -> int:
    """Text-token length such that frontend + text == shape.seq_len."""
    if cfg.family == "encdec":
        return encdec.dec_len_for(shape.seq_len)
    if cfg.family in ("vit", "vit_moe"):
        return cfg.image_tokens - 1  # patches; +CLS makes image_tokens
    return shape.seq_len - _frontend_tokens(cfg, shape)


def input_specs(
    cfg: ModelConfig, shape: ShapeConfig, *, batch_override: Optional[int] = None
) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for one (arch x shape) cell.

    train/prefill: the full sequence; decode: one new token + cache structs
    (the cache is an *argument* of serve_step, so it appears here).
    """
    B = batch_override or shape.global_batch
    mod = module_for(cfg)
    t32 = jnp.int32
    if cfg.family in ("vit", "vit_moe"):
        return {
            "patches": jax.ShapeDtypeStruct((B, cfg.image_tokens - 1, vit.PATCH_DIM), jnp.bfloat16),
            "labels": jax.ShapeDtypeStruct((B,), t32),
        }
    s_text = text_tokens_for(cfg, shape)
    specs: Dict[str, Any] = {}
    if cfg.frontend:
        n_front = _frontend_tokens(cfg, shape)
        specs["frontend_embeds"] = jax.ShapeDtypeStruct(
            (B, n_front, cfg.frontend_dim), jnp.bfloat16
        )
    if shape.kind in ("train", "prefill"):
        specs["tokens"] = jax.ShapeDtypeStruct((B, s_text), t32)
        if shape.kind == "train":
            specs["labels"] = jax.ShapeDtypeStruct((B, s_text), t32)
        return specs
    # decode: one token step against a seq_len-deep cache
    specs["tokens"] = jax.ShapeDtypeStruct((B, 1), t32)
    specs["cache"] = mod.cache_shapes(cfg, B, shape.seq_len, dtype=jnp.bfloat16)
    specs["index"] = jax.ShapeDtypeStruct((), t32)
    return specs


def synth_batch(cfg: ModelConfig, shape: ShapeConfig, rng,
                *, batch_override: Optional[int] = None) -> Dict[str, Any]:
    """Materialized random batch matching ``input_specs`` (smoke/examples)."""
    specs = input_specs(cfg, shape, batch_override=batch_override)
    out: Dict[str, Any] = {}
    for name, spec in specs.items():
        if name == "cache":
            out["cache"] = module_for(cfg).init_cache(
                cfg, batch_override or shape.global_batch, shape.seq_len,
                dtype=jnp.bfloat16,
            )
            continue
        rng, k = jax.random.split(rng)
        if isinstance(spec, jax.ShapeDtypeStruct):
            if spec.dtype == jnp.int32:
                hi = cfg.vocab_size or cfg.num_classes or 2
                out[name] = (
                    jnp.zeros(spec.shape, jnp.int32)
                    if spec.shape == ()
                    else jax.random.randint(k, spec.shape, 0, hi, jnp.int32)
                )
            else:
                out[name] = jax.random.normal(k, spec.shape, jnp.float32).astype(spec.dtype)
    return out


def forward(params, cfg: ModelConfig, batch: Dict[str, Any], taps=None):
    """Uniform teacher-forced forward over a synth/input batch."""
    mod = module_for(cfg)
    if cfg.family in ("vit", "vit_moe"):
        return mod.forward(params, cfg, batch["patches"], taps=taps)
    return mod.forward(
        params, cfg, batch["tokens"],
        frontend_embeds=batch.get("frontend_embeds"), taps=taps,
    )


def classify(params, cfg: ModelConfig, patches, *, top_k: int = 5) -> dict:
    """Batched vision classification (vit families): patches [B, T, P] ->
    {"classes", "probs", "expert_tokens"} — the serving engine's unit of
    work (see models/vit.py:classify)."""
    if cfg.family not in ("vit", "vit_moe"):
        raise ValueError(f"classify: vision families only, got {cfg.family!r}")
    return module_for(cfg).classify(params, cfg, patches, top_k=top_k)


__all__ = [
    "abstract_params",
    "classify",
    "forward",
    "init_model_params",
    "input_specs",
    "model_param_axes",
    "model_param_shapes",
    "module_for",
    "synth_batch",
    "text_tokens_for",
]
