"""seamless-m4t encoder-decoder. The audio frontend is a stub: the encoder
consumes precomputed frame embeddings [B, S_enc, frontend_dim].

Decoder layers carry self-attention (causal, cached) + cross-attention over
the encoder memory (K/V computed once at prefill and cached).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.quant.calibrate import maybe_record
from repro.models.layers import (
    apply_norm,
    attention_block,
    mlp_apply,
    project_memory_kv,
)
from repro.models.param import PDef, dense, stack_tree
from repro.models.transformer import (
    _attn_pdefs,
    _mlp_pdefs,
    _norm_pdefs,
    logits_from_hidden,
)


def dec_len_for(seq_len: int) -> int:
    """Decoder token length for a given encoder frame length (shape cells)."""
    return max(seq_len // 4, 128)


def abstract_params(cfg: ModelConfig) -> dict:
    enc_layer = {
        "ln1": _norm_pdefs(cfg),
        "attn": _attn_pdefs(cfg, bias=True),
        "ln2": _norm_pdefs(cfg),
        "mlp": _mlp_pdefs(cfg, cfg.d_ff, bias=True),
    }
    dec_layer = {
        "ln1": _norm_pdefs(cfg),
        "attn": _attn_pdefs(cfg, bias=True),
        "lnx": _norm_pdefs(cfg),
        "xattn": _attn_pdefs(cfg, bias=True),
        "ln2": _norm_pdefs(cfg),
        "mlp": _mlp_pdefs(cfg, cfg.d_ff, bias=True),
    }
    return {
        "embed": PDef((cfg.vocab_size, cfg.d_model), ("vocab", "embed"),
                      init="small_normal"),
        "frontend_proj": dense(cfg.frontend_dim, cfg.d_model, None, "embed"),
        "enc_layers": stack_tree(enc_layer, cfg.encoder_layers),
        "enc_norm": _norm_pdefs(cfg),
        "dec_layers": stack_tree(dec_layer, cfg.decoder_layers),
        "final_norm": _norm_pdefs(cfg),
        "lm_head": dense(cfg.d_model, cfg.vocab_size, "embed", "vocab", scale=0.02),
    }


def encode(params, cfg: ModelConfig, frames: jnp.ndarray, taps=None) -> jnp.ndarray:
    x = frames.astype(params["frontend_proj"].dtype) @ params["frontend_proj"]
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)

    def body(x, lp):
        h = apply_norm(x, lp["ln1"], cfg)
        attn, _ = attention_block(h, lp["attn"], cfg, cfg.attn,
                                  positions=positions, causal=False)
        x = x + attn
        h = apply_norm(x, lp["ln2"], cfg)
        return x + mlp_apply(h, lp["mlp"], cfg), None

    if taps is not None:
        for i in range(cfg.encoder_layers):
            lp = jax.tree.map(lambda a: a[i], params["enc_layers"])
            lt = taps.scoped(f"Lenc{i:03d}")
            h = apply_norm(x, lp["ln1"], cfg)
            maybe_record(lt, "post_ln1", h)
            attn, _ = attention_block(h, lp["attn"], cfg, cfg.attn,
                                      positions=positions, causal=False,
                                      taps=lt)
            x = x + attn
            h = apply_norm(x, lp["ln2"], cfg)
            maybe_record(lt, "post_ln2", h)
            x = x + mlp_apply(h, lp["mlp"], cfg, taps=lt)
    else:
        if cfg.remat:
            x, _ = jax.lax.scan(jax.checkpoint(body), x, params["enc_layers"])
        else:
            x, _ = jax.lax.scan(body, x, params["enc_layers"])
    x = apply_norm(x, params["enc_norm"], cfg)
    maybe_record(taps, "enc_norm_out", x)
    return x


def _decoder(params, cfg, x, memory, *, positions, caches=None,
             cross_kv=None, cache_index=None):
    """Scanned decoder. ``cross_kv`` (decode): per-layer precomputed
    cross-attention K/V {"k": [L,B,S_enc,KVH,hd], "v": ...}; when absent the
    cross K/V is projected from ``memory`` inline (train/prefill)."""

    def body(x, xs):
        lp = xs["p"]
        h = apply_norm(x, lp["ln1"], cfg)
        attn, new_self = attention_block(
            h, lp["attn"], cfg, cfg.attn, positions=positions, causal=True,
            cache=xs.get("self_kv"), cache_index=cache_index,
        )
        x = x + attn
        h = apply_norm(x, lp["lnx"], cfg)
        if "cross_kv" in xs:
            mkv = (xs["cross_kv"]["k"], xs["cross_kv"]["v"])
            xattn, _ = attention_block(
                h, lp["xattn"], cfg, cfg.attn, positions=positions,
                memory_kv=mkv,
            )
        else:
            xattn, _ = attention_block(
                h, lp["xattn"], cfg, cfg.attn, positions=positions,
                memory=memory,
            )
        x = x + xattn
        h = apply_norm(x, lp["ln2"], cfg)
        x = x + mlp_apply(h, lp["mlp"], cfg)
        return x, new_self

    if cfg.remat and caches is None:
        body = jax.checkpoint(body)
    xs = {"p": params["dec_layers"]}
    if caches is not None:
        xs["self_kv"] = caches
    if cross_kv is not None:
        xs["cross_kv"] = cross_kv
    x, new_caches = jax.lax.scan(body, x, xs)
    return x, new_caches


def compute_cross_kv(params, cfg: ModelConfig, memory: jnp.ndarray) -> dict:
    """Per-decoder-layer cross K/V from encoder memory (prefill, once)."""
    def one(lp):
        k, v = project_memory_kv(memory, lp["xattn"], cfg.attn, cfg)
        return {"k": k, "v": v}

    return jax.lax.map(one, params["dec_layers"])


def forward(params, cfg: ModelConfig, tokens: jnp.ndarray,
            frontend_embeds: Optional[jnp.ndarray] = None,
            taps=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Teacher-forced: encode frames, decode tokens. Returns (logits, aux)."""
    memory = encode(params, cfg, frontend_embeds, taps=taps)
    x = params["embed"][tokens]
    positions = jnp.arange(tokens.shape[1], dtype=jnp.int32)
    if taps is not None:
        # eager decoder for calibration
        for i in range(cfg.decoder_layers):
            lp = jax.tree.map(lambda a: a[i], params["dec_layers"])
            lt = taps.scoped(f"Ldec{i:03d}")
            h = apply_norm(x, lp["ln1"], cfg)
            maybe_record(lt, "post_ln1", h)
            attn, _ = attention_block(h, lp["attn"], cfg, cfg.attn,
                                      positions=positions, causal=True,
                                      taps=lt)
            x = x + attn
            h = apply_norm(x, lp["lnx"], cfg)
            maybe_record(lt, "post_lnx", h)
            xattn, _ = attention_block(h, lp["xattn"], cfg, cfg.attn,
                                       positions=positions, memory=memory,
                                       taps=lt.scoped("x"))
            x = x + xattn
            h = apply_norm(x, lp["ln2"], cfg)
            maybe_record(lt, "post_ln2", h)
            x = x + mlp_apply(h, lp["mlp"], cfg, taps=lt)
    else:
        x, _ = _decoder(params, cfg, x, memory, positions=positions)
    return logits_from_hidden(params, cfg, x, taps=taps), jnp.zeros((), jnp.float32)


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    """Decode cache: decoder self-attn KV (depth max_len//4, the decoder's
    share of the cell budget) + per-layer precomputed cross K/V over an
    encoder memory of length max_len. Quantized serving stores the self
    cache in int8 (the cross K/V is written once at prefill and stays at
    the activation dtype — a single pass, not a growing stream)."""
    a = cfg.attn
    L = cfg.decoder_layers
    dec_len = dec_len_for(max_len)
    int8 = cfg.quant.enable and cfg.quant.kv_cache_int8
    self_dt = jnp.int8 if int8 else dtype
    kv = lambda n, dt: jnp.zeros(
        (L, batch, n, a.num_kv_heads, a.head_dim), dt)
    cache = {
        "self": {"k": kv(dec_len, self_dt), "v": kv(dec_len, self_dt)},
        "cross": {"k": kv(max_len, dtype), "v": kv(max_len, dtype)},
    }
    if int8:
        sc = lambda: jnp.zeros((L, batch, dec_len, a.num_kv_heads),
                               jnp.float32)
        cache["self"]["k_scale"] = sc()
        cache["self"]["v_scale"] = sc()
    return cache


def cache_shapes(cfg, batch, max_len, dtype=jnp.bfloat16):
    return jax.eval_shape(lambda: init_cache(cfg, batch, max_len, dtype))


def prefill(params, cfg: ModelConfig, tokens: jnp.ndarray,
            frontend_embeds: Optional[jnp.ndarray] = None,
            max_len: Optional[int] = None):
    """Encode + run decoder prompt. Returns (last_logits, caches) where
    caches = {'self': self-attn KV, 'cross': per-layer cross K/V}."""
    B, S = tokens.shape
    max_len = max_len or S
    memory = encode(params, cfg, frontend_embeds)
    cross_kv = compute_cross_kv(params, cfg, memory)
    x = params["embed"][tokens]
    positions = jnp.arange(S, dtype=jnp.int32)
    a = cfg.attn
    int8 = cfg.quant.enable and cfg.quant.kv_cache_int8
    kv_dt = jnp.int8 if int8 else x.dtype
    kv = lambda n: jnp.zeros((cfg.decoder_layers, B, n, a.num_kv_heads, a.head_dim), kv_dt)
    self_kv = {"k": kv(max_len), "v": kv(max_len)}
    if int8:
        self_kv["k_scale"] = jnp.zeros(
            (cfg.decoder_layers, B, max_len, a.num_kv_heads), jnp.float32)
        self_kv["v_scale"] = jnp.zeros(
            (cfg.decoder_layers, B, max_len, a.num_kv_heads), jnp.float32)
    x, new_self = _decoder(params, cfg, x, None, positions=positions,
                           caches=self_kv, cross_kv=cross_kv,
                           cache_index=jnp.zeros((), jnp.int32))
    logits = logits_from_hidden(params, cfg, x[:, -1:, :])
    return logits, {"self": new_self, "cross": cross_kv}


def decode_step(params, cfg: ModelConfig, tokens: jnp.ndarray, caches,
                index: jnp.ndarray):
    x = params["embed"][tokens]
    idx = jnp.asarray(index, jnp.int32)
    positions = (idx[:, None] if idx.ndim else idx) + jnp.arange(1, dtype=jnp.int32)
    x, new_self = _decoder(params, cfg, x, None,
                           positions=positions, caches=caches["self"],
                           cross_kv=caches["cross"], cache_index=index)
    logits = logits_from_hidden(params, cfg, x)
    return logits, {"self": new_self, "cross": caches["cross"]}
