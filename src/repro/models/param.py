"""Single-source param definitions.

Every model family describes its parameters once as a pytree of ``PDef``
(shape + logical axes + initializer). From that single tree we derive:

  * ``init_params``  — materialized arrays (smoke tests, examples, training)
  * ``param_shapes`` — ShapeDtypeStructs (multi-pod dry-run: no allocation)
  * logical axes     — resolved to PartitionSpecs by distributed/sharding_rules

Logical axis vocabulary (resolved by sharding rules):
  embed | vocab | qkv | kv | mlp | expert | ssm_inner | heads | layers | null
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class PDef:
    """Declarative parameter definition."""

    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]  # logical axis per dim (None = replicated)
    init: str = "normal"  # normal | zeros | ones | small_normal | conv
    scale: Optional[float] = None  # stddev override for normal init
    dtype: Any = jnp.float32

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def stack(pdef: PDef, n: int) -> PDef:
    """Prepend a scanned-layers dim."""
    return dataclasses.replace(
        pdef, shape=(n,) + pdef.shape, axes=("layers",) + pdef.axes
    )


def stack_tree(tree, n: int):
    return jax.tree.map(
        lambda p: stack(p, n), tree, is_leaf=lambda x: isinstance(x, PDef)
    )


def _fan_in(shape: Tuple[int, ...]) -> int:
    return shape[-2] if len(shape) >= 2 else max(shape[-1], 1)


def _materialize(pdef: PDef, key, dtype) -> jnp.ndarray:
    if pdef.init == "zeros":
        return jnp.zeros(pdef.shape, dtype)
    if pdef.init == "ones":
        return jnp.ones(pdef.shape, dtype)
    std = pdef.scale if pdef.scale is not None else 1.0 / math.sqrt(_fan_in(pdef.shape))
    if pdef.init == "small_normal":
        std = 0.02
    return (jax.random.normal(key, pdef.shape, jnp.float32) * std).astype(dtype)


def is_pdef(x) -> bool:
    return isinstance(x, PDef)


def init_params(tree, rng, dtype=jnp.float32):
    """Materialize a PDef tree into arrays (one fold of the rng per leaf)."""
    leaves, treedef = jax.tree.flatten(tree, is_leaf=is_pdef)
    keys = jax.random.split(rng, len(leaves))
    out = [_materialize(p, k, p.dtype if p.dtype != jnp.float32 else dtype)
           for p, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, out)


def param_shapes(tree, dtype=jnp.float32):
    """ShapeDtypeStruct stand-ins — the dry-run path (no allocation)."""
    return jax.tree.map(
        lambda p: jax.ShapeDtypeStruct(
            p.shape, p.dtype if p.dtype != jnp.float32 else dtype
        ),
        tree,
        is_leaf=is_pdef,
    )


def param_logical_axes(tree):
    return jax.tree.map(lambda p: p.axes, tree, is_leaf=is_pdef)


def param_count_tree(tree) -> int:
    leaves = jax.tree.leaves(tree, is_leaf=is_pdef)
    return sum(int(jnp.prod(jnp.asarray(p.shape))) for p in leaves)


def tree_shapes(tree):
    """ShapeDtypeStructs of a *concrete* param tree (PDef trees go through
    ``param_shapes``). Works on transformed trees — e.g. a QuantizedParams
    tree whose int8/scale leaves no abstract template describes — and is
    what serving/checkpointing use as a restore/lowering template."""
    return jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), tree
    )


def tree_bytes(tree) -> int:
    """Total parameter bytes of a concrete tree, honoring leaf dtypes —
    an int8-materialized tree reports ~4x less than its fp32 ancestor."""
    return sum(
        int(jnp.prod(jnp.asarray(a.shape))) * jnp.dtype(a.dtype).itemsize
        for a in jax.tree.leaves(tree)
    )


# Convenience constructors -------------------------------------------------

def dense(d_in: int, d_out: int, ax_in: Optional[str], ax_out: Optional[str],
          init: str = "normal", scale: Optional[float] = None) -> PDef:
    return PDef((d_in, d_out), (ax_in, ax_out), init=init, scale=scale)


def vector(d: int, ax: Optional[str], init: str = "zeros") -> PDef:
    return PDef((d,), (ax,), init=init)
