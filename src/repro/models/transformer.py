"""Generic decoder-only LM: dense, MoE (all-layer), and VLM families.

Layers are *scanned* (stacked params, jax.lax.scan) so 90+-layer archs lower
to compact HLO; remat wraps the scan body for training. gemma2's local/global
alternation scans over layer *pairs* so the window stays static per sub-block.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.moe.dispatch import (
    capacity,
    grouped_combine,
    grouped_dispatch,
    gshard_dispatch_combine,
)
from repro.core.moe.router import route_topk
from repro.core.quant.calibrate import maybe_record
from repro.models.layers import (
    apply_norm,
    attention_block,
    mlp_apply,
    quant_linear,
)
from repro.models.param import PDef, dense, stack_tree, vector


# ---------------------------------------------------------------------------
# Param definitions
# ---------------------------------------------------------------------------

def _norm_pdefs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    if cfg.norm == "layernorm":
        return {"scale": vector(d, "embed", "ones"), "bias": vector(d, "embed", "zeros")}
    return {"scale": vector(d, "embed", "zeros")}  # rmsnorm (1+g) convention


def _attn_pdefs(cfg: ModelConfig, bias: bool = False) -> dict:
    a = cfg.attn
    d = cfg.d_model
    p = {
        "wq": dense(d, a.q_dim, "embed", "qkv"),
        "wk": dense(d, a.kv_dim, "embed", "qkv"),
        "wv": dense(d, a.kv_dim, "embed", "qkv"),
        "wo": dense(a.q_dim, d, "qkv", "embed"),
    }
    if bias:
        p["bq"] = vector(a.q_dim, "qkv")
        p["bk"] = vector(a.kv_dim, "qkv")
        p["bv"] = vector(a.kv_dim, "qkv")
        p["bo"] = vector(d, "embed")
    if a.qk_norm:
        p["q_norm"] = vector(a.head_dim, None, "zeros")
        p["k_norm"] = vector(a.head_dim, None, "zeros")
    return p


def _mlp_pdefs(cfg: ModelConfig, d_ff: int, bias: bool = False) -> dict:
    d = cfg.d_model
    hid = 2 * d_ff if cfg.glu else d_ff
    p = {"wi": dense(d, hid, "embed", "mlp"), "wo": dense(d_ff, d, "mlp", "embed")}
    if bias:
        p["bi"] = vector(hid, "mlp")
        p["bo"] = vector(d, "embed")
    return p


def _moe_pdefs(cfg: ModelConfig) -> dict:
    m = cfg.moe
    d = cfg.d_model
    hid = 2 * m.d_ff if cfg.glu else m.d_ff
    return {
        "gate": dense(d, m.num_experts, "embed", None, scale=0.02),
        "wi": PDef((m.num_experts, d, hid), ("expert", "embed", "mlp")),
        "wo": PDef((m.num_experts, m.d_ff, d), ("expert", "mlp", "embed")),
    }


def _layer_pdefs(cfg: ModelConfig) -> dict:
    p = {"ln1": _norm_pdefs(cfg), "ln2": _norm_pdefs(cfg), "attn": _attn_pdefs(cfg)}
    if cfg.moe is not None and cfg.moe.moe_every == 1:
        p["moe"] = _moe_pdefs(cfg)
    else:
        p["mlp"] = _mlp_pdefs(cfg, cfg.d_ff)
    if cfg.post_block_norm:
        p["post_ln1"] = _norm_pdefs(cfg)
        p["post_ln2"] = _norm_pdefs(cfg)
    return p


def abstract_params(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    n_layers = cfg.num_layers
    tree: dict = {
        "embed": PDef((cfg.vocab_size, d), ("vocab", "embed"), init="small_normal"),
        "final_norm": _norm_pdefs(cfg),
    }
    if cfg.attn is not None and cfg.attn.alternate_local_global:
        assert n_layers % 2 == 0
        tree["layers_local"] = stack_tree(_layer_pdefs(cfg), n_layers // 2)
        tree["layers_global"] = stack_tree(_layer_pdefs(cfg), n_layers // 2)
    else:
        tree["layers"] = stack_tree(_layer_pdefs(cfg), n_layers)
    if not cfg.tie_embeddings:
        tree["lm_head"] = dense(d, cfg.vocab_size, "embed", "vocab", scale=0.02)
    if cfg.frontend:
        tree["frontend_proj"] = dense(cfg.frontend_dim, d, None, "embed")
    return tree


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def _expert_count_zeros(cfg: ModelConfig) -> jnp.ndarray:
    """Per-expert routed-token counter carry ([E] int32; [0] for non-MoE)."""
    n_e = cfg.moe.num_experts if cfg.moe is not None else 0
    return jnp.zeros((n_e,), jnp.int32)


def _moe_apply(x: jnp.ndarray, p: dict, cfg: ModelConfig, taps=None):
    """MoE FFN on [B,S,D]; returns (y, aux_loss, expert_counts [E] int32).

    ``expert_counts`` is the routed (token, slot) histogram of this layer —
    the serving engines accumulate it into the per-expert occupancy metric
    (DESIGN.md section 6)."""
    from repro.kernels import ops

    from repro.models.layers import act_fn

    m = cfg.moe
    if m.moe_exec == "expert_parallel" and taps is None:
        # serving-time expert parallelism: same grouped kernel per shard,
        # expert stacks sharded over 'model', tokens exchanged all_to_all
        # (distributed/expert_parallel.py; calibration keeps the eager
        # single-device path so taps record on one process)
        from repro.distributed.expert_parallel import expert_parallel_moe

        return expert_parallel_moe(x, p, cfg)
    B, S, D = x.shape
    T = B * S
    xt = x.reshape(T, D)
    # int8 gate: its matmul runs through the quant seam (the gate weight is
    # quantized like any other post-norm consumer); fp gates keep the
    # router's own f32 matmul
    gate_logits = (quant_linear(xt, p, "gate", cfg)
                   if p["gate"].dtype == jnp.int8 else None)
    r = route_topk(xt, p["gate"], p.get("gate_b"), m.top_k,
                   logits=gate_logits)
    wi, wo = p["wi"], p["wo"]
    if wi.dtype in (jnp.int8, jnp.uint8) and m.impl == "gshard":
        # The capacity-einsum path is the training/dry-run fallback; it has
        # no integer contraction, so dequantize on the fly (nibble-packed
        # int4 stacks unpack first). The serving path (impl="grouped")
        # executes int8/packed-int4 inside the kernel instead.
        if wi.dtype == jnp.uint8:
            from repro.core.quant.qtypes import unpack_int4

            hid = wi.shape[-1]
            wi = unpack_int4(wi, D)
            wo = unpack_int4(wo, hid // 2 if cfg.glu else hid)
        wi = wi.astype(jnp.float32) * p["wi_scale"][..., None, :]
        wo = wo.astype(jnp.float32) * p["wo_scale"][..., None, :]
    if m.impl == "gshard":
        # Hierarchical (grouped) GShard: tokens split into G groups with
        # per-group capacity so the dispatch one-hot is [G, Tg, E, Cg]
        # (the flat [T, E, C] form is O(T^2) bytes at 1M-token cells).
        if T >= 2048 and T % 2048 == 0:
            G = T // 2048
        elif T % B == 0:
            G = B
        else:
            G = 1
        Tg = T // G
        cap = capacity(Tg, m.top_k, m.num_experts, m.capacity_factor)
        xg = xt.reshape(G, Tg, D)
        eg = r.experts.reshape(G, Tg, m.top_k)
        wg = r.weights.reshape(G, Tg, m.top_k)
        disp, comb = jax.vmap(
            lambda xx, ee, ww: gshard_dispatch_combine(
                xx, ee, ww, m.num_experts, cap
            )
        )(xg, eg, wg)
        ein = jnp.einsum("gtec,gtd->gecd", disp.astype(x.dtype), xg)
        h = jnp.einsum("gecd,edh->gech", ein, wi)
        if "bi" in p:
            h = h + p["bi"][None, :, None, :]
        if cfg.glu:
            g, u = jnp.split(h, 2, axis=-1)
            h = act_fn(cfg.act)(g) * u
        else:
            h = act_fn(cfg.act)(h)
        # record the fc2-input site here too: gshard-calibrated models must
        # still produce the wo_a_scale leaf the grouped serving path
        # (fake-quant AND materialized-int8) quantizes with
        maybe_record(taps, "moe_mid", h)
        eout = jnp.einsum("gech,ehd->gecd", h, wo)
        if "bo" in p:
            eout = eout + p["bo"][None, :, None, :]
        y = jnp.einsum("gtec,gecd->gtd", comb.astype(x.dtype), eout)
        y = y.reshape(T, D)
        # routed (not dropped) slots per expert
        counts = jnp.sum(disp, axis=(0, 1, 3)).astype(jnp.int32)
    else:  # grouped: the paper's sort-based unified kernel
        dsp = grouped_dispatch(xt, r.experts, r.weights, m.num_experts)
        counts = dsp.group_sizes
        y_sorted = ops.grouped_mlp(
            dsp.x_sorted, p["wi"], p["wo"], dsp.group_sizes,
            act=cfg.act, glu=cfg.glu, bi=p.get("bi"), bo=p.get("bo"),
            taps=taps, mid_a_scale=p.get("wo_a_scale"),
            a_bits=cfg.quant.a_bits,
            wi_scale=p.get("wi_scale"), wo_scale=p.get("wo_scale"),
            wi_a_scale=p.get("wi_as"),
        )
        y = grouped_combine(y_sorted, dsp, B * S)
    return y.reshape(B, S, D), r.aux_loss, counts


def _block(x, p, cfg, *, positions, local_window, causal=True,
           cache=None, cache_index=None, segment_ids=None, taps=None):
    """One transformer block; returns (x, aux_loss, expert_counts,
    new_cache)."""
    h = apply_norm(x, p["ln1"], cfg)
    maybe_record(taps, "post_ln1", h)
    attn_out, new_cache = attention_block(
        h, p["attn"], cfg, cfg.attn,
        positions=positions, causal=causal, local_window=local_window,
        cache=cache, cache_index=cache_index, segment_ids=segment_ids,
        taps=taps,
    )
    if cfg.post_block_norm:
        attn_out = apply_norm(attn_out, p["post_ln1"], cfg)
    x = x + attn_out
    h = apply_norm(x, p["ln2"], cfg)
    maybe_record(taps, "post_ln2", h)
    aux = jnp.zeros((), jnp.float32)
    ec = _expert_count_zeros(cfg)
    if "moe" in p:
        ff, aux, ec = _moe_apply(h, p["moe"], cfg, taps=taps)
    else:
        ff = mlp_apply(h, p["mlp"], cfg, taps=taps)
    if cfg.post_block_norm:
        ff = apply_norm(ff, p["post_ln2"], cfg)
    x = x + ff
    return x, aux, ec, new_cache


# ---------------------------------------------------------------------------
# Forward (train / prefill teacher-forced)
# ---------------------------------------------------------------------------

def _embed_inputs(params, cfg, tokens, frontend_embeds):
    x = params["embed"][tokens]  # [B, S_text, D]
    if cfg.frontend and frontend_embeds is not None:
        fe = quant_linear(frontend_embeds.astype(x.dtype), params,
                          "frontend_proj", cfg)
        x = jnp.concatenate([fe, x], axis=1)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model**0.5, x.dtype)
    return x


def _run_layers(params, cfg, x, *, positions, caches=None, cache_index=None,
                segment_ids=None, taps=None):
    """Scan over stacked layers.

    Returns (x, aux_total, expert_counts, new_caches); expert_counts is the
    routed-token histogram summed over all MoE layers ([E] int32, [0] for
    dense archs)."""
    alternating = cfg.attn is not None and cfg.attn.alternate_local_global
    remat = cfg.remat and caches is None

    def make_body(local_window, causal=True):
        def body(carry, xs):
            x = carry["x"]
            layer_p = xs["p"]
            cache = xs.get("cache")
            x, aux, ec, new_cache = _block(
                x, layer_p, cfg,
                positions=positions, local_window=local_window, causal=causal,
                cache=cache, cache_index=cache_index,
                segment_ids=segment_ids, taps=None,
            )
            carry = {"x": x, "aux": carry["aux"] + aux,
                     "ec": carry["ec"] + ec}
            return carry, new_cache

        return jax.checkpoint(body) if remat else body

    aux0 = jnp.zeros((), jnp.float32)
    ec0 = _expert_count_zeros(cfg)
    if taps is not None:
        # calibration path: run layers eagerly (unscanned) to record taps
        return _run_layers_eager(params, cfg, x, positions=positions, taps=taps)
    if alternating:
        # pairs: (local, global) x L/2 — window static per scan
        carry = {"x": x, "aux": aux0, "ec": ec0}

        def pair_body(carry, xs):
            carry, c1 = make_body(cfg.attn.local_window)(carry, {"p": xs["local"], **({"cache": xs["cache_local"]} if caches else {})})
            carry, c2 = make_body(0)(carry, {"p": xs["global"], **({"cache": xs["cache_global"]} if caches else {})})
            return carry, {"local": c1, "global": c2}

        xs = {"local": params["layers_local"], "global": params["layers_global"]}
        if caches is not None:
            xs["cache_local"] = caches["local"]
            xs["cache_global"] = caches["global"]
        carry, new_caches = jax.lax.scan(pair_body, carry, xs)
        return carry["x"], carry["aux"], carry["ec"], (new_caches if caches is not None else None)
    carry = {"x": x, "aux": aux0, "ec": ec0}
    xs = {"p": params["layers"]}
    if caches is not None:
        xs["cache"] = caches
    body = make_body(cfg.attn.local_window if (cfg.attn and cfg.attn.local_window and not alternating) else 0)
    carry, new_caches = jax.lax.scan(body, carry, xs)
    return carry["x"], carry["aux"], carry["ec"], (new_caches if caches is not None else None)


def _run_layers_eager(params, cfg, x, *, positions, taps):
    """Unscanned layer loop for PTQ calibration (records activation taps)."""
    alternating = cfg.attn is not None and cfg.attn.alternate_local_global
    aux_total = jnp.zeros((), jnp.float32)
    ec_total = _expert_count_zeros(cfg)
    if alternating:
        n = cfg.num_layers // 2
        for i in range(n):
            for kind, win in (("layers_local", cfg.attn.local_window), ("layers_global", 0)):
                lp = jax.tree.map(lambda a: a[i], params[kind])
                scope = f"L{kind.removeprefix('layers_')}{i:03d}"
                x, aux, ec, _ = _block(x, lp, cfg, positions=positions,
                                       local_window=win, taps=taps.scoped(scope))
                aux_total += aux
                ec_total += ec
    else:
        for i in range(cfg.num_layers):
            lp = jax.tree.map(lambda a: a[i], params["layers"])
            x, aux, ec, _ = _block(x, lp, cfg, positions=positions,
                                   local_window=cfg.attn.local_window if cfg.attn else 0,
                                   taps=taps.scoped(f"L{i:03d}"))
            aux_total += aux
            ec_total += ec
    return x, aux_total, ec_total, None


def logits_from_hidden(params, cfg, x, taps=None):
    from repro.core.quant.calibrate import maybe_record

    x = apply_norm(x, params["final_norm"], cfg)
    maybe_record(taps, "final_norm", x)
    if cfg.tie_embeddings:
        logits = x @ params["embed"].T
    else:
        logits = quant_linear(x, params, "lm_head", cfg)
        if "lm_head_b" in params:  # PTQ final-norm fold correction
            logits = logits + params["lm_head_b"]
    if cfg.final_logit_softcap > 0:
        c = cfg.final_logit_softcap
        logits = c * jnp.tanh(logits / c)
    return logits


def forward(params, cfg: ModelConfig, tokens: jnp.ndarray,
            frontend_embeds: Optional[jnp.ndarray] = None,
            taps=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Teacher-forced forward. Returns (logits [B,S,V], moe_aux_loss)."""
    x = _embed_inputs(params, cfg, tokens, frontend_embeds)
    S = x.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)
    x, aux, _, _ = _run_layers(params, cfg, x, positions=positions, taps=taps)
    return logits_from_hidden(params, cfg, x, taps=taps), aux


# ---------------------------------------------------------------------------
# KV cache / prefill / decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    a = cfg.attn
    int8 = cfg.quant.enable and cfg.quant.kv_cache_int8
    kv_dtype = jnp.int8 if int8 else dtype
    def one(n, length):
        c = {
            "k": jnp.zeros((n, batch, length, a.num_kv_heads, a.head_dim), kv_dtype),
            "v": jnp.zeros((n, batch, length, a.num_kv_heads, a.head_dim), kv_dtype),
        }
        if int8:
            c["k_scale"] = jnp.zeros((n, batch, length, a.num_kv_heads), jnp.float32)
            c["v_scale"] = jnp.zeros((n, batch, length, a.num_kv_heads), jnp.float32)
        return c
    if a.alternate_local_global:
        # sliding-window layers keep a ring of window slots, not max_len
        # (perf iteration 4: 8x less KV capacity/traffic at 32k decode)
        n = cfg.num_layers // 2
        local_len = min(max_len, a.local_window) if a.local_window else max_len
        return {"local": one(n, local_len), "global": one(n, max_len)}
    return one(cfg.num_layers, max_len)


def cache_shapes(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    """ShapeDtypeStructs of the cache (dry-run: no allocation)."""
    return jax.eval_shape(lambda: init_cache(cfg, batch, max_len, dtype))


def prefill(params, cfg: ModelConfig, tokens: jnp.ndarray,
            frontend_embeds: Optional[jnp.ndarray] = None,
            max_len: Optional[int] = None):
    """Run the prompt, building the cache. Returns (last_logits, cache)."""
    B = tokens.shape[0]
    x = _embed_inputs(params, cfg, tokens, frontend_embeds)
    S = x.shape[1]
    max_len = max_len or S
    positions = jnp.arange(S, dtype=jnp.int32)
    cache = init_cache(cfg, B, max_len, dtype=x.dtype)
    x, aux, _, new_caches = _run_layers(
        params, cfg, x, positions=positions, caches=cache,
        cache_index=jnp.zeros((), jnp.int32),
    )
    logits = logits_from_hidden(params, cfg, x[:, -1:, :])
    return logits, new_caches


def prefill_packed(params, cfg: ModelConfig, tokens: jnp.ndarray,
                   positions: jnp.ndarray, segment_ids: jnp.ndarray,
                   last_idx: jnp.ndarray, max_len: Optional[int] = None):
    """Continuous-batching prefill: N variable-length prompts packed into ONE
    batch row (DESIGN.md section 10).

    tokens       [1, P]  prompts concatenated back-to-back (+ pad tail)
    positions    [P]     within-segment position of each buffer slot (RoPE)
    segment_ids  [P]     prompt index per slot; pad tail carries -1
    last_idx     [N]     buffer index of each prompt's final token

    Attention is confined to equal segment ids; causality/local windows run
    on buffer indices, which equal within-segment distances because segments
    are contiguous. Returns (logits [N, V] — next-token logits per prompt —
    and the packed cache [layers, 1, max_len, ...]); the caller scatters each
    segment's K/V rows into its decode slot (``ServeEngine._admit``).
    """
    x = _embed_inputs(params, cfg, tokens, None)
    B, S = x.shape[0], x.shape[1]
    assert B == 1, "packed prefill uses a single batch row"
    max_len = max_len or S
    seg = segment_ids.reshape(B, S).astype(jnp.int32)
    cache = init_cache(cfg, B, max_len, dtype=x.dtype)
    x, aux, _, new_caches = _run_layers(
        params, cfg, x, positions=positions.reshape(S).astype(jnp.int32),
        caches=cache, cache_index=jnp.zeros((), jnp.int32),
        segment_ids=seg,
    )
    h_last = jnp.take(x[0], last_idx.astype(jnp.int32), axis=0)  # [N, D]
    logits = logits_from_hidden(params, cfg, h_last)
    return logits, new_caches


def decode_step(params, cfg: ModelConfig, tokens: jnp.ndarray, caches,
                index: jnp.ndarray, *, with_stats: bool = False):
    """One decode step. tokens [B,1]; index = cache fill position —
    scalar (lockstep) or [B] (continuous batching, per-slot).

    ``with_stats=True`` additionally returns ``{"expert_tokens": [E] int32}``
    — the routed-token histogram of this step summed over MoE layers, which
    the serving engine folds into its occupancy metric."""
    x = _embed_inputs(params, cfg, tokens, None)
    idx = jnp.asarray(index, jnp.int32)
    positions = (idx[:, None] if idx.ndim else idx) + jnp.arange(1, dtype=jnp.int32)
    x, aux, ec, new_caches = _run_layers(
        params, cfg, x, positions=positions, caches=caches, cache_index=index
    )
    logits = logits_from_hidden(params, cfg, x)
    if with_stats:
        return logits, new_caches, {"expert_tokens": ec}
    return logits, new_caches
