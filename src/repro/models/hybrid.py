"""zamba2-7b hybrid: scanned Mamba-2 backbone + ONE shared attention+MLP
block (single weight set) applied after every ``shared_attn_every``-th layer.

Each application of the shared block has its own KV cache slice (indexed by
application number); the block input re-injects the embedding stream
(x + x0) — DESIGN.md notes this simplification vs. the released concat+LoRA.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.quant.calibrate import maybe_record
from repro.models.layers import apply_norm, attention_block, mlp_apply
from repro.models.param import PDef, stack_tree
from repro.models.ssm import mamba2_block, mamba2_pdefs
from repro.models.transformer import (
    _attn_pdefs,
    _mlp_pdefs,
    _norm_pdefs,
    logits_from_hidden,
)


def _n_apps(cfg: ModelConfig) -> int:
    return cfg.num_layers // cfg.shared_attn_every


def abstract_params(cfg: ModelConfig) -> dict:
    layer = {"ln": _norm_pdefs(cfg), "mamba": mamba2_pdefs(cfg)}
    tree = {
        "embed": PDef((cfg.vocab_size, cfg.d_model), ("vocab", "embed"),
                      init="small_normal"),
        "layers": stack_tree(layer, cfg.num_layers),
        "shared": {
            "ln1": _norm_pdefs(cfg),
            "attn": _attn_pdefs(cfg),
            "ln2": _norm_pdefs(cfg),
            "mlp": _mlp_pdefs(cfg, cfg.d_ff),
        },
        "final_norm": _norm_pdefs(cfg),
    }
    if not cfg.tie_embeddings:
        tree["lm_head"] = PDef((cfg.d_model, cfg.vocab_size), ("embed", "vocab"),
                               init="small_normal")
    return tree


def _shared_block(x, x0, params, cfg, *, positions, cache=None,
                  cache_index=None, taps=None):
    """One application of the shared attention+MLP block."""
    sp = params["shared"]
    inp = x + x0
    h = apply_norm(inp, sp["ln1"], cfg)
    maybe_record(taps, "post_ln1", h)
    attn_out, new_cache = attention_block(
        h, sp["attn"], cfg, cfg.attn,
        positions=positions, causal=True,
        cache=cache, cache_index=cache_index, taps=taps,
    )
    y = inp + attn_out
    h = apply_norm(y, sp["ln2"], cfg)
    maybe_record(taps, "post_ln2", h)
    y = y + mlp_apply(h, sp["mlp"], cfg, taps=taps)
    return x + y - inp, new_cache  # residual delta back onto the mamba stream


def _run(params, cfg, x, *, positions, states=None, kv=None, cache_index=None,
         taps=None):
    every = cfg.shared_attn_every
    x0 = x

    def apply_shared(x, kv_carry, app_idx):
        if kv_carry is None:
            y, _ = _shared_block(x, x0, params, cfg, positions=positions)
            return y, None
        cache = jax.tree.map(lambda a: a[app_idx], kv_carry)
        y, new_cache = _shared_block(
            x, x0, params, cfg, positions=positions,
            cache=cache, cache_index=cache_index,
        )
        kv_carry = jax.tree.map(
            lambda full, c: jax.lax.dynamic_update_index_in_dim(full, c, app_idx, 0),
            kv_carry, new_cache,
        )
        return y, kv_carry

    if taps is not None:  # eager calibration path
        for i in range(cfg.num_layers):
            lp = jax.tree.map(lambda a: a[i], params["layers"])
            h = apply_norm(x, lp["ln"], cfg)
            maybe_record(taps.scoped(f"L{i:03d}"), "post_ln1", h)
            y, _ = mamba2_block(h, lp["mamba"], cfg)
            x = x + y
            if i % every == every - 1:
                # one weight set: stats of every application merge (correct)
                x, _ = _shared_block(x, x0, params, cfg, positions=positions,
                                     taps=taps.scoped("shared"))
        return x, None, None

    def body(carry, xs):
        x, kv_carry = carry
        lp = xs["p"]
        i = xs["i"]
        h = apply_norm(x, lp["ln"], cfg)
        y, new_state = mamba2_block(h, lp["mamba"], cfg, state=xs.get("state"))
        x = x + y

        def with_shared(args):
            x, kv_carry = args
            return apply_shared(x, kv_carry, i // every)

        def without(args):
            return args

        if kv is None:
            # training/prefill-lowering without kv cache: still must apply the
            # shared block; cond keeps HLO compact across the scan.
            x, kv_carry2 = jax.lax.cond(
                i % every == every - 1,
                lambda a: (apply_shared(a[0], None, 0)[0], a[1]),
                without, (x, kv_carry),
            )
            kv_carry = kv_carry2
        else:
            x, kv_carry = jax.lax.cond(
                i % every == every - 1, with_shared, without, (x, kv_carry)
            )
        return (x, kv_carry), new_state

    if cfg.remat and states is None and kv is None:
        body = jax.checkpoint(body)
    xs = {"p": params["layers"], "i": jnp.arange(cfg.num_layers, dtype=jnp.int32)}
    if states is not None:
        xs["state"] = states
    (x, kv_out), new_states = jax.lax.scan(body, (x, kv), xs)
    return x, new_states, kv_out


def forward(params, cfg: ModelConfig, tokens: jnp.ndarray,
            frontend_embeds=None, taps=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    x = params["embed"][tokens]
    positions = jnp.arange(tokens.shape[1], dtype=jnp.int32)
    x, _, _ = _run(params, cfg, x, positions=positions, taps=taps)
    return logits_from_hidden(params, cfg, x, taps=taps), jnp.zeros((), jnp.float32)


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    s = cfg.ssm
    a = cfg.attn
    di = s.d_inner(cfg.d_model)
    nh = s.num_ssm_heads(cfg.d_model)
    L, A = cfg.num_layers, _n_apps(cfg)
    conv_dim = di + 2 * s.state_dim
    int8 = cfg.quant.enable and cfg.quant.kv_cache_int8
    kv_dtype = jnp.int8 if int8 else dtype
    cache = {
        "ssm": {
            "h": jnp.zeros((L, batch, nh, s.head_dim, s.state_dim), jnp.float32),
            "conv": jnp.zeros((L, batch, s.conv_width - 1, conv_dim), dtype),
        },
        "kv": {
            "k": jnp.zeros((A, batch, max_len, a.num_kv_heads, a.head_dim), kv_dtype),
            "v": jnp.zeros((A, batch, max_len, a.num_kv_heads, a.head_dim), kv_dtype),
        },
    }
    if int8:
        cache["kv"]["k_scale"] = jnp.zeros((A, batch, max_len, a.num_kv_heads), jnp.float32)
        cache["kv"]["v_scale"] = jnp.zeros((A, batch, max_len, a.num_kv_heads), jnp.float32)
    return cache


def cache_shapes(cfg, batch, max_len, dtype=jnp.bfloat16):
    return jax.eval_shape(lambda: init_cache(cfg, batch, max_len, dtype))


def prefill(params, cfg: ModelConfig, tokens: jnp.ndarray,
            frontend_embeds=None, max_len: Optional[int] = None):
    B, S = tokens.shape
    max_len = max_len or S
    x = params["embed"][tokens]
    positions = jnp.arange(S, dtype=jnp.int32)
    cache = init_cache(cfg, B, max_len, dtype=x.dtype)
    x, new_states, kv_out = _run(
        params, cfg, x, positions=positions, states=None, kv=cache["kv"],
        cache_index=jnp.zeros((), jnp.int32),
    )
    logits = logits_from_hidden(params, cfg, x[:, -1:, :])
    return logits, {"ssm": new_states, "kv": kv_out}


def decode_step(params, cfg: ModelConfig, tokens: jnp.ndarray, caches,
                index: jnp.ndarray):
    x = params["embed"][tokens]
    positions = index + jnp.arange(1, dtype=jnp.int32)
    x, new_states, kv_out = _run(
        params, cfg, x, positions=positions, states=caches["ssm"],
        kv=caches["kv"], cache_index=index,
    )
    return logits_from_hidden(params, cfg, x), {"ssm": new_states, "kv": kv_out}
