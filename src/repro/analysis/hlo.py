"""Call-graph-aware optimized-HLO analysis.

``compiled.cost_analysis()`` counts each while-loop (lax.scan) body ONCE —
for a scanned-layers model that under-counts FLOPs by ~num_layers x. This
module re-derives roofline inputs from ``compiled.as_text()`` with proper
trip-count multipliers:

  * dot_flops          — 2 * numel(out) * prod(contracting dims) per dot,
  * collective bytes   — all-gather / all-reduce / reduce-scatter /
                         all-to-all / collective-permute output bytes,
  * hbm_bytes          — fusion-boundary traffic (XLA's memory model: every
                         fusion reads operands from and writes results to
                         HBM; in-fusion intermediates stay in registers),

each accumulated over the call graph (fusion/call: x1; while body: x trip
count, recovered from the loop condition's comparison constant). Operand
types are resolved through a per-computation symbol table (optimized HLO
prints types only at definition sites).
"""
from __future__ import annotations

import re
from typing import Dict, List, Tuple

_DTYPE_BYTES = {"f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4,
                "bf16": 2, "f16": 2, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}
_TYPE_RE = re.compile(
    r"(f64|f32|bf16|f16|f8e4m3fn|f8e5m2|s64|u64|s32|u32|s16|u16|s8|u8|pred)"
    r"\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_HEADER_RE = re.compile(r"^\s*(?:ENTRY\s+)?%?([\w.\-]+)\s+\((.*)\)\s*->")
_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _type_bytes(tstr: str) -> int:
    total = 0
    for m in _TYPE_RE.finditer(tstr):
        dt, dims = m.groups()
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _type_dims(tstr: str) -> List[int]:
    m = _TYPE_RE.search(tstr)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


class _Comp:
    def __init__(self, name: str, header: str):
        self.name = name
        self.lines: List[str] = []
        self.symbols: Dict[str, str] = {}  # instr name -> type string
        # header params: "(param.2: f32[64,64], param.3: f32[5,...])"
        for pm in re.finditer(r"%?([\w.\-]+):\s*((?:\([^)]*\))|[^,)]+)",
                              header):
            self.symbols[pm.group(1)] = pm.group(2)


def parse(hlo: str) -> Tuple[Dict[str, "_Comp"], str]:
    comps: Dict[str, _Comp] = {}
    entry = ""
    cur = None
    for line in hlo.splitlines():
        hm = _HEADER_RE.match(line)
        if hm and ("{" in line or line.rstrip().endswith("->")
                   or "->" in line):
            cur = _Comp(hm.group(1), hm.group(2))
            comps[cur.name] = cur
            if line.lstrip().startswith("ENTRY"):
                entry = cur.name
            continue
        if cur is None:
            continue
        cur.lines.append(line)
        dm = _DEF_RE.match(line)
        if dm:
            name, rhs = dm.groups()
            tm = re.match(r"((?:\([^)]*\))|\S+\[[^\]]*\][^\s]*)", rhs)
            if tm:
                cur.symbols[name] = tm.group(1)
    return comps, entry


def _operands(line: str, opcode: str) -> List[str]:
    m = re.search(re.escape(opcode) + r"\(([^)]*)\)", line)
    if not m:
        return []
    return re.findall(r"%([\w.\-]+)", m.group(1))


def analyze(hlo: str) -> dict:
    comps, entry = parse(hlo)

    local: Dict[str, dict] = {}
    edges: Dict[str, List[Tuple[str, int]]] = {}
    for name, comp in comps.items():
        met = {"dot_flops": 0, "hbm_bytes": 0, "convert_bytes": 0}
        for k in _COLL_KINDS:
            met[k] = 0
        outs: List[Tuple[str, int]] = []
        for ln in comp.lines:
            dm = _DEF_RE.match(ln)
            if not dm:
                continue
            out_name, rhs = dm.groups()
            out_type = comp.symbols.get(out_name, "")
            opm = re.match(
                r"(?:\([^)]*\)|\S+)\s+([\w\-]+)(?:-start)?\(", rhs)
            op = opm.group(1) if opm else ""

            if op == "dot":
                dims = _type_dims(out_type)
                numel = 1
                for d in dims:
                    numel *= d
                ops = _operands(ln, "dot")
                lhs_dims = _type_dims(comp.symbols.get(ops[0], "")) if ops else []
                cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ln)
                contract = 1
                if cm and cm.group(1):
                    for i in (int(x) for x in cm.group(1).split(",")):
                        if i < len(lhs_dims):
                            contract *= lhs_dims[i]
                met["dot_flops"] += 2 * numel * contract

            if op in _COLL_KINDS:
                met[op] += _type_bytes(out_type)

            if op in ("fusion", "custom-call"):
                b = _type_bytes(out_type)
                for o in _operands(ln, op):
                    b += _type_bytes(comp.symbols.get(o, ""))
                met["hbm_bytes"] += b
                # pure dtype-convert fusions are an XLA:CPU artifact (no
                # bf16 dot on host); on the TPU MXU the cast happens in the
                # datapath with zero HBM traffic — tracked separately so the
                # roofline can report a TPU-adjusted memory term
                if ("convert" in out_name
                        and "dynamic-update-slice" not in out_name
                        and "dynamic_update" not in out_name
                        and "transpose" not in out_name
                        and "dot" not in out_name):
                    met["convert_bytes"] += b

            wm = re.search(
                r"while\(.*?\), condition=%?([\w.\-]+), body=%?([\w.\-]+)",
                ln)
            if wm:
                cond, body = wm.groups()
                trip = 1
                if cond in comps:
                    for cl in comps[cond].lines:
                        km = re.search(r"constant\((\d+)\)", cl)
                        if km:
                            trip = max(trip, int(km.group(1)))
                outs.append((body, trip))
                continue
            fm = re.search(r"(?:calls|to_apply)=%?([\w.\-]+)", ln)
            if fm:
                outs.append((fm.group(1), 1))
            bm = re.search(r"branch_computations=\{([^}]*)\}", ln)
            if bm:
                for b in bm.group(1).replace("%", "").split(","):
                    outs.append((b.strip(), 1))
        local[name] = met
        edges[name] = outs

    memo: Dict[str, dict] = {}

    def total(name: str, depth=0) -> dict:
        if name in memo:
            return memo[name]
        if name not in local or depth > 64:
            return {}
        agg = dict(local[name])
        memo[name] = agg
        for callee, mult in edges.get(name, ()):
            sub = total(callee, depth + 1)
            for k, v in sub.items():
                agg[k] = agg.get(k, 0) + v * mult
        memo[name] = agg
        return agg

    result = dict(total(entry)) if entry else {}
    result["collective_bytes"] = sum(result.get(k, 0) for k in _COLL_KINDS)
    return result
