"""Offline + live program analysis: HLO cost modelling and hardware peaks.

``repro.analysis.hlo``  — call-graph-aware optimized-HLO roofline inputs
                          (moved from ``benchmarks/hlo_analysis.py``, which
                          re-exports for script compatibility).
``repro.analysis.hw``   — target-hardware constants and per-device-kind
                          peak lookup (canonical home of ``benchmarks/hw.py``).
"""
from repro.analysis import hlo, hw

__all__ = ["hlo", "hw"]
