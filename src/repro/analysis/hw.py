"""Target-hardware constants and per-device-kind peak lookup.

Canonical home of the roofline constants (``benchmarks/hw.py`` re-exports
from here).  The defaults describe the TPU v5e-class target the roofline
sections of DESIGN.md argue against; ``device_peaks()`` resolves the peaks
for the devices actually attached, falling back to the target constants —
flagged ``assumed=True`` — when the platform is unknown (e.g. the CPU
backend used in CI).
"""
from __future__ import annotations

from typing import Optional

PEAK_FLOPS_BF16 = 197e12  # per chip
PEAK_FLOPS_INT8 = 394e12  # MXU int8 path (2x bf16)
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link (~per chip for ring collectives)
DCN_BW = 25e9  # bytes/s per host across pods (assumed)
CHIPS_SINGLE_POD = 256
CHIPS_MULTI_POD = 512

# Known device kinds -> (bf16 peak, int8 peak, HBM BW, ICI BW), per chip.
# ``device.device_kind`` strings are matched case-insensitively by prefix.
_KNOWN_PEAKS = {
    "tpu v5e": (197e12, 394e12, 819e9, 50e9),
    "tpu v5 lite": (197e12, 394e12, 819e9, 50e9),
    "tpu v4": (275e12, 275e12, 1228e9, 100e9),
}


def device_peaks(device=None, *, use_int8: bool = False) -> dict:
    """Resolve roofline peaks for ``device`` (default: first local device).

    Returns a dict with ``peak_flops`` already selected for the bf16/int8
    datapath (``use_int8``), plus the raw per-precision peaks, bandwidths,
    the device kind, and ``assumed`` marking whether the numbers are real
    for this device or the TPU-target defaults (CPU CI runs).
    """
    kind = "unknown"
    if device is None:
        try:
            import jax

            device = jax.devices()[0]
        except Exception:  # pragma: no cover - no backend at all
            device = None
    if device is not None:
        kind = str(getattr(device, "device_kind", "unknown")).lower()
    match = None
    for prefix, peaks in _KNOWN_PEAKS.items():
        if kind.startswith(prefix):
            match = peaks
            break
    if match is None:
        match = (PEAK_FLOPS_BF16, PEAK_FLOPS_INT8, HBM_BW, ICI_BW)
        assumed = True
    else:
        assumed = False
    bf16, int8, hbm, ici = match
    return {
        "device_kind": kind,
        "assumed": assumed,
        "peak_kind": "int8" if use_int8 else "bf16",
        "peak_flops": int8 if use_int8 else bf16,
        "peak_flops_bf16": bf16,
        "peak_flops_int8": int8,
        "hbm_bw": hbm,
        "ici_bw": ici,
    }


def pick_int8(params=None, quant_enabled: Optional[bool] = None) -> bool:
    """Should the MFU denominator use the int8 peak?

    True when quantization is enabled in config or any materialized weight
    leaf is int8 (the post-PR-3 materialized int8 path) OR nibble-packed
    int4 (uint8 storage). Int4 stacks deliberately use the *int8* peak
    (DESIGN.md §13): the TPU MXU has no separate int4 datapath — packed
    weights unpack to int8 in-register and contract on the int8 path, so
    int4's win is HBM bytes (roofline memory-bound rows), not peak FLOPs.
    """
    if quant_enabled:
        return True
    if params is not None:
        try:
            import jax
            import jax.numpy as jnp

            for leaf in jax.tree_util.tree_leaves(params):
                dt = getattr(leaf, "dtype", None)
                if dt == jnp.int8:
                    return True
                if dt == jnp.uint8 and getattr(leaf, "ndim", 0) >= 2:
                    return True  # nibble-packed int4 weight stack
        except Exception:
            return False
    return False
