"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — the dry-run must set XLA_FLAGS before the
first jax init.

Target: TPU v5e-class pods. Single pod = 16x16 = 256 chips, axes
(data, model); multi-pod = 2 pods x 256 = 512 chips with the leading 'pod'
axis riding DCN (pure DP + int8-compressed gradient reduction).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_axis: int = 1):
    """Tiny mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    model_axis = min(model_axis, n)
    return jax.make_mesh((n // model_axis, model_axis), ("data", "model"))


def make_ep_mesh(n: int = 0):
    """1-axis ('model',) mesh for expert-parallel serving — over all
    devices, or the first ``n`` (distributed/expert_parallel.py; tests run
    it on fake CPU devices via --xla_force_host_platform_device_count)."""
    devs = jax.devices()
    n = n or len(devs)
    return jax.make_mesh((n,), ("model",), devices=devs[:n])
