"""Serving launcher: batched generation with the CoQMoE quantized path.

Single engine:

  PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --smoke \
      --requests 8 --new-tokens 16 --quantized

Multi-replica LM cluster (engine-agnostic front-end, DESIGN.md section 8):

  PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --smoke \
      --requests 16 --replicas 2

Observability (DESIGN.md section 11): ``--trace-out`` writes a Chrome-trace
/Perfetto JSON of the run's span timelines (and enables tracing),
``--events-out`` streams the structured decision/event JSONL, and
``--metrics-out`` writes the Prometheus text rendering of the final
cluster snapshot. Both serving paths report through the same
``ClusterMetrics.snapshot()`` so every tracked counter appears in one
consistent summary.

Live introspection (DESIGN.md section 12): ``--metrics-port`` serves
``/metrics`` (Prometheus), ``/healthz`` and ``/snapshot`` over HTTP for
the duration of the run; ``--metrics-interval N`` rewrites
``--metrics-out`` every N seconds so a crashed run still leaves its
last metrics snapshot behind.

Fault tolerance (DESIGN.md section 14): SIGTERM/SIGINT trigger a graceful
drain (stop admission, serve what is in flight, write final metrics)
instead of a hard exit — the seed's ``PreemptionGuard`` wired into the
submit loop. ``--chaos`` turns on the deterministic fault-injection layer
(serving/faults.py) with ``--chaos-*`` rates and ``--chaos-kill
ORDINAL:STEP`` scheduled replica kills; the watchdog/quarantine machinery
is on by default for the cluster path regardless.
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import signal
import threading
import time

import jax
import numpy as np

from repro import models
from repro.configs import get_config, smoke_config
from repro.distributed.fault_tolerance import PreemptionGuard
from repro.serving.cluster import ServingCluster
from repro.serving.engine import Request, ServeEngine
from repro.serving.events import EventLog
from repro.serving.metrics import ClusterMetrics
from repro.serving.metrics_server import MetricsServer, cluster_healthz
from repro.serving.trace import write_chrome_trace


class _PeriodicMetricsWriter(threading.Thread):
    """Rewrite ``--metrics-out`` every ``interval`` seconds during the run
    (atomic tmp+rename), so a crashed or killed run still leaves its last
    metrics snapshot behind instead of nothing at all."""

    def __init__(self, cm, path: str, interval: float) -> None:
        super().__init__(daemon=True, name="metrics-writer")
        self._cm = cm
        self._path = path
        self._interval = interval
        self._stop = threading.Event()
        self.writes = 0

    def write_once(self) -> None:
        try:
            tmp = self._path + ".tmp"
            with open(tmp, "w") as f:
                f.write(self._cm.export_prometheus())
            os.replace(tmp, self._path)
            self.writes += 1
        except Exception:
            pass  # a failed periodic write must not kill the run

    def run(self) -> None:
        while not self._stop.wait(self._interval):
            self.write_once()

    def stop(self) -> None:
        self._stop.set()


def _fmt_ms(d: dict) -> str:
    if d["n"] == 0:
        return "n=0"
    return (f"n={d['n']} p50={d['p50']:.2f}ms p95={d['p95']:.2f}ms "
            f"p99={d['p99']:.2f}ms max={d['max']:.2f}ms")


def _print_report(snap: dict) -> None:
    """One consistent final summary off a ``ClusterMetrics.snapshot()`` —
    every counter the engines track is surfaced here, nothing hand-picked."""
    agg = snap["aggregate"]
    print(f"aggregate: fps={agg['fps']:.1f} "
          f"replicas_active={snap['replicas_active']}")
    print("  latency: " + _fmt_ms(agg["latency_ms"]))
    print("  queue_wait: " + _fmt_ms(agg["queue_wait_ms"]))
    if agg["batch_latency_ms"]["n"]:
        print("  batch_latency: " + _fmt_ms(agg["batch_latency_ms"]))
    counters = agg["counters"]
    if counters:
        body = " ".join(f"{k}={v}" for k, v in sorted(counters.items()))
        print(f"  counters: {body}")
    for key, d in agg["step_latency_ms"].items():
        print(f"  step {key}: " + _fmt_ms(d))
    depth = agg["front_queue_depth"]
    if depth["max"]:
        print(f"  front_queue_depth: mean={depth['mean']:.2f} "
              f"max={depth['max']}")
    if agg["expert_tokens"]:
        occ = ", ".join(f"{x:.3f}" for x in agg["expert_occupancy"])
        print(f"  expert occupancy: [{occ}]")
    _print_padding_summary(counters)
    for i, rep in enumerate(snap["replicas"]):
        print(f"  replica {i}: tokens={rep['counters'].get('tokens', 0)} "
              f"completed={rep['counters'].get('completed', 0)} "
              f"p50={rep['latency_ms']['p50']:.0f}ms")


def _print_padding_summary(counters: dict) -> None:
    """Padding-waste + retrace line (DESIGN.md section 10): how much of
    every dispatched prefill buffer was real prompt tokens, and whether any
    serving-path compiles happened after warmup (must be 0)."""
    real = counters.get("pack_real_tokens", 0)
    pad = counters.get("pack_pad_tokens", 0)
    if real + pad:
        util = 100.0 * real / (real + pad)
        print(f"  prefill padding: real={real} pad={pad} "
              f"({util:.1f}% buffer utilization, "
              f"{counters.get('prefill_batches', 0)} dispatches)")
    retr = counters.get("retraces", 0)
    cxl = counters.get("cancelled", 0)
    print(f"  retraces after warmup: {retr}"
          + (f", cancelled (deadline): {cxl}" if cxl else ""))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--replicas", type=int, default=0,
                    help=">=2 serves through a ServingCluster of ServeEngine "
                         "replicas (one front-end, least-loaded routing)")
    ap.add_argument("--quantized", action="store_true",
                    help="enable W8A8 + int8 KV + 4-bit log-sqrt2 attention")
    ap.add_argument("--autotune", action="store_true",
                    help="per-device Pallas tile autotuning at warmup "
                         "(kernels/autotune.py; persistent table under "
                         "--autotune-cache, pure cache hit on relaunch)")
    ap.add_argument("--autotune-cache", default=None,
                    help="tuning-table cache dir (default .repro_autotune "
                         "or $REPRO_AUTOTUNE_CACHE)")
    ap.add_argument("--trace-out", default=None,
                    help="write a Chrome-trace/Perfetto JSON of the run's "
                         "span timelines here (implies tracing on)")
    ap.add_argument("--events-out", default=None,
                    help="stream structured serving events (rejections, "
                         "cancellations, retirement faults) as JSONL here")
    ap.add_argument("--metrics-out", default=None,
                    help="write the final cluster snapshot as Prometheus "
                         "text exposition here")
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="serve live /metrics, /healthz and /snapshot over "
                         "HTTP on this port for the duration of the run "
                         "(0 picks a free port)")
    ap.add_argument("--metrics-interval", type=float, default=None,
                    help="with --metrics-out, rewrite the metrics file "
                         "every N seconds during the run instead of only "
                         "at exit")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--chaos", action="store_true",
                    help="enable the deterministic fault-injection layer "
                         "(serving/faults.py): replicas are wrapped in "
                         "seeded FaultyReplica decorators")
    ap.add_argument("--chaos-seed", type=int, default=0)
    ap.add_argument("--chaos-error-rate", type=float, default=0.0,
                    help="per-step probability of an injected step error")
    ap.add_argument("--chaos-oom-rate", type=float, default=0.0,
                    help="per-step probability of an injected OOM")
    ap.add_argument("--chaos-stall-rate", type=float, default=0.0,
                    help="per-step probability of an injected stall")
    ap.add_argument("--chaos-reject-rate", type=float, default=0.0,
                    help="per-submit probability of an injected rejection")
    ap.add_argument("--chaos-kill", action="append", default=[],
                    metavar="ORDINAL:STEP",
                    help="kill replica ORDINAL permanently at its local "
                         "step STEP (repeatable)")
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if args.quantized:
        cfg = cfg.replace(quant=dataclasses.replace(cfg.quant, enable=True))
    if args.chaos:
        kills = []
        for spec in args.chaos_kill:
            ordn, step = spec.split(":")
            kills.append((int(ordn), int(step), "dead"))
        cfg = cfg.replace(faults=dataclasses.replace(
            cfg.faults, inject=True, seed=args.chaos_seed,
            step_error_rate=args.chaos_error_rate,
            oom_rate=args.chaos_oom_rate,
            step_stall_rate=args.chaos_stall_rate,
            submit_reject_rate=args.chaos_reject_rate,
            kill_schedule=tuple(kills)))
    if args.autotune:
        cfg = cfg.replace(autotune=dataclasses.replace(
            cfg.autotune, enable=True, cache_dir=args.autotune_cache))
    if args.trace_out:
        cfg = cfg.replace(trace=dataclasses.replace(cfg.trace, enable=True))
    events = EventLog(path=args.events_out) if args.events_out else None
    params = models.init_model_params(cfg, jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)
    reqs = [
        Request(
            uid=uid,
            prompt=rng.integers(0, cfg.vocab_size,
                                args.prompt_len).astype(np.int32),
            max_new_tokens=args.new_tokens,
        )
        for uid in range(args.requests)
    ]

    # Build the serving stack and its metrics roll-up BEFORE the run so a
    # live --metrics-port endpoint and --metrics-interval writer observe
    # the run in flight, not just the final snapshot.
    cluster = engine = None
    if args.replicas >= 2:
        cluster = ServingCluster(cfg, params, replicas=args.replicas,
                                 engine="lm", batch_slots=args.slots,
                                 max_len=args.max_len, events=events)
        cluster.warmup()
        cm = cluster.metrics
        healthz = lambda: cluster_healthz(cluster)  # noqa: E731
    else:
        engine = ServeEngine(cfg, params, batch_slots=args.slots,
                             max_len=args.max_len, events=events)
        engine.warmup()
        # the single-engine path reports through the same ClusterMetrics
        # roll-up as the cluster path: one summary schema, every counter
        cm = ClusterMetrics([engine.metrics])
        healthz = None
    if args.autotune:
        from repro.kernels import autotune

        print(autotune.summary())

    server = None
    if args.metrics_port is not None:
        server = MetricsServer(cm.export_prometheus, healthz_fn=healthz,
                               snapshot_fn=cm.snapshot,
                               port=args.metrics_port)
        server.start()
        print(f"metrics endpoint: {server.url}/metrics")
    writer = None
    if args.metrics_interval and args.metrics_out:
        writer = _PeriodicMetricsWriter(cm, args.metrics_out,
                                        args.metrics_interval)
        writer.start()

    # graceful preemption: SIGTERM/SIGINT stop admission; everything
    # already accepted is served to completion and the final metrics write
    # below still happens (distributed/fault_tolerance.py PreemptionGuard)
    guard = PreemptionGuard(signals=(signal.SIGTERM, signal.SIGINT))
    shed = 0
    try:
        t0 = time.perf_counter()
        if cluster is not None:
            for r in reqs:
                if guard.preempted:
                    shed += 1
                    continue
                cluster.submit(r)
                cluster.step()
            cluster.flush()
        else:
            for r in reqs:
                if guard.preempted:
                    shed += 1
                    continue
                engine.submit(r)
            engine.run_until_drained()
        dt = time.perf_counter() - t0
    finally:
        if writer is not None:
            writer.stop()
        if server is not None:
            server.close()
    if shed:
        print(f"preempted: drained {args.requests - shed} accepted "
              f"requests, shed {shed} unsubmitted")
    total = (args.requests - shed) * args.new_tokens
    extra = (f"replicas={cluster.num_replicas}, " if cluster is not None
             else "")
    print(f"generated {total} tokens in {dt:.2f}s "
          f"({total / dt:.1f} tok/s, {extra}quantized={args.quantized})")
    if cluster is not None:
        recorders = cluster.flight_recorders()
    else:
        recorders = ({engine.tracer.label: engine.tracer.recorder}
                     if engine.tracer.enabled else {})

    _print_report(cm.snapshot())
    if args.trace_out:
        doc = write_chrome_trace(args.trace_out, recorders)
        print(f"trace: {args.trace_out} "
              f"({sum(1 for e in doc['traceEvents'] if e['ph'] == 'X')} "
              f"spans)")
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            f.write(cm.export_prometheus())
        print(f"metrics: {args.metrics_out}")
    if events is not None:
        events.close()
        print(f"events: {args.events_out} ({events.total} events)")


if __name__ == "__main__":
    main()
