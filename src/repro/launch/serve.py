"""Serving launcher: batched generation with the CoQMoE quantized path.

Single engine:

  PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --smoke \
      --requests 8 --new-tokens 16 --quantized

Multi-replica LM cluster (engine-agnostic front-end, DESIGN.md section 8):

  PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --smoke \
      --requests 16 --replicas 2
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import models
from repro.configs import get_config, smoke_config
from repro.serving.cluster import ServingCluster
from repro.serving.engine import Request, ServeEngine


def _print_padding_summary(counters: dict) -> None:
    """Padding-waste + retrace line (DESIGN.md section 10): how much of
    every dispatched prefill buffer was real prompt tokens, and whether any
    serving-path compiles happened after warmup (must be 0)."""
    real = counters.get("pack_real_tokens", 0)
    pad = counters.get("pack_pad_tokens", 0)
    if real + pad:
        util = 100.0 * real / (real + pad)
        print(f"prefill padding: real={real} pad={pad} "
              f"({util:.1f}% buffer utilization, "
              f"{counters.get('prefill_batches', 0)} dispatches)")
    retr = counters.get("retraces", 0)
    cxl = counters.get("cancelled", 0)
    print(f"retraces after warmup: {retr}"
          + (f", cancelled (deadline): {cxl}" if cxl else ""))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--replicas", type=int, default=0,
                    help=">=2 serves through a ServingCluster of ServeEngine "
                         "replicas (one front-end, least-loaded routing)")
    ap.add_argument("--quantized", action="store_true",
                    help="enable W8A8 + int8 KV + 4-bit log-sqrt2 attention")
    ap.add_argument("--autotune", action="store_true",
                    help="per-device Pallas tile autotuning at warmup "
                         "(kernels/autotune.py; persistent table under "
                         "--autotune-cache, pure cache hit on relaunch)")
    ap.add_argument("--autotune-cache", default=None,
                    help="tuning-table cache dir (default .repro_autotune "
                         "or $REPRO_AUTOTUNE_CACHE)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if args.quantized:
        import dataclasses

        cfg = cfg.replace(quant=dataclasses.replace(cfg.quant, enable=True))
    if args.autotune:
        import dataclasses

        cfg = cfg.replace(autotune=dataclasses.replace(
            cfg.autotune, enable=True, cache_dir=args.autotune_cache))
    params = models.init_model_params(cfg, jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)
    reqs = [
        Request(
            uid=uid,
            prompt=rng.integers(0, cfg.vocab_size,
                                args.prompt_len).astype(np.int32),
            max_new_tokens=args.new_tokens,
        )
        for uid in range(args.requests)
    ]

    if args.replicas >= 2:
        cluster = ServingCluster(cfg, params, replicas=args.replicas,
                                 engine="lm", batch_slots=args.slots,
                                 max_len=args.max_len)
        cluster.warmup()
        if args.autotune:
            from repro.kernels import autotune

            print(autotune.summary())
        t0 = time.perf_counter()
        for r in reqs:
            cluster.submit(r)
            cluster.step()
        cluster.flush()
        dt = time.perf_counter() - t0
        total = args.requests * args.new_tokens
        print(f"generated {total} tokens in {dt:.2f}s "
              f"({total / dt:.1f} tok/s, replicas={cluster.num_replicas}, "
              f"quantized={args.quantized})")
        snap = cluster.metrics.snapshot()
        agg = snap["aggregate"]
        print(f"aggregate: tokens/s={agg['fps']:.1f} "
              f"latency p50={agg['latency_ms']['p50']:.0f}ms "
              f"p99={agg['latency_ms']['p99']:.0f}ms "
              f"queue_wait p95={agg['queue_wait_ms']['p95']:.1f}ms")
        for i, rep in enumerate(snap["replicas"]):
            print(f"  replica {i}: tokens={rep['counters'].get('tokens', 0)} "
                  f"completed={rep['counters'].get('completed', 0)} "
                  f"p50={rep['latency_ms']['p50']:.0f}ms")
        if agg["expert_tokens"]:
            occ = ", ".join(f"{x:.3f}" for x in agg["expert_occupancy"])
            print(f"expert occupancy (summed over replicas): [{occ}]")
        _print_padding_summary(agg["counters"])
        return

    engine = ServeEngine(cfg, params, batch_slots=args.slots,
                         max_len=args.max_len)
    engine.warmup()
    if args.autotune:
        from repro.kernels import autotune

        print(autotune.summary())
    for r in reqs:
        engine.submit(r)
    t0 = time.perf_counter()
    engine.run_until_drained()
    dt = time.perf_counter() - t0
    total = args.requests * args.new_tokens
    print(f"generated {total} tokens in {dt:.2f}s "
          f"({total / dt:.1f} tok/s, quantized={args.quantized})")
    snap = engine.metrics.snapshot()
    print(f"metrics: tokens/s={snap['fps']:.1f} "
          f"latency p50={snap['latency_ms']['p50']:.0f}ms "
          f"p99={snap['latency_ms']['p99']:.0f}ms "
          f"queue_depth max={snap['queue_depth']['max']}")
    if snap["expert_tokens"]:
        occ = ", ".join(f"{x:.3f}" for x in snap["expert_occupancy"])
        print(f"expert occupancy: [{occ}]")
    _print_padding_summary(snap["counters"])


if __name__ == "__main__":
    main()
