"""Serving launcher: batched generation with the CoQMoE quantized path.

Single engine:

  PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --smoke \
      --requests 8 --new-tokens 16 --quantized

Multi-replica LM cluster (engine-agnostic front-end, DESIGN.md section 8):

  PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --smoke \
      --requests 16 --replicas 2

Observability (DESIGN.md section 11): ``--trace-out`` writes a Chrome-trace
/Perfetto JSON of the run's span timelines (and enables tracing),
``--events-out`` streams the structured decision/event JSONL, and
``--metrics-out`` writes the Prometheus text rendering of the final
cluster snapshot. Both serving paths report through the same
``ClusterMetrics.snapshot()`` so every tracked counter appears in one
consistent summary.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro import models
from repro.configs import get_config, smoke_config
from repro.serving.cluster import ServingCluster
from repro.serving.engine import Request, ServeEngine
from repro.serving.events import EventLog
from repro.serving.metrics import ClusterMetrics
from repro.serving.trace import write_chrome_trace


def _fmt_ms(d: dict) -> str:
    if d["n"] == 0:
        return "n=0"
    return (f"n={d['n']} p50={d['p50']:.2f}ms p95={d['p95']:.2f}ms "
            f"p99={d['p99']:.2f}ms max={d['max']:.2f}ms")


def _print_report(snap: dict) -> None:
    """One consistent final summary off a ``ClusterMetrics.snapshot()`` —
    every counter the engines track is surfaced here, nothing hand-picked."""
    agg = snap["aggregate"]
    print(f"aggregate: fps={agg['fps']:.1f} "
          f"replicas_active={snap['replicas_active']}")
    print("  latency: " + _fmt_ms(agg["latency_ms"]))
    print("  queue_wait: " + _fmt_ms(agg["queue_wait_ms"]))
    if agg["batch_latency_ms"]["n"]:
        print("  batch_latency: " + _fmt_ms(agg["batch_latency_ms"]))
    counters = agg["counters"]
    if counters:
        body = " ".join(f"{k}={v}" for k, v in sorted(counters.items()))
        print(f"  counters: {body}")
    for key, d in agg["step_latency_ms"].items():
        print(f"  step {key}: " + _fmt_ms(d))
    depth = agg["front_queue_depth"]
    if depth["max"]:
        print(f"  front_queue_depth: mean={depth['mean']:.2f} "
              f"max={depth['max']}")
    if agg["expert_tokens"]:
        occ = ", ".join(f"{x:.3f}" for x in agg["expert_occupancy"])
        print(f"  expert occupancy: [{occ}]")
    _print_padding_summary(counters)
    for i, rep in enumerate(snap["replicas"]):
        print(f"  replica {i}: tokens={rep['counters'].get('tokens', 0)} "
              f"completed={rep['counters'].get('completed', 0)} "
              f"p50={rep['latency_ms']['p50']:.0f}ms")


def _print_padding_summary(counters: dict) -> None:
    """Padding-waste + retrace line (DESIGN.md section 10): how much of
    every dispatched prefill buffer was real prompt tokens, and whether any
    serving-path compiles happened after warmup (must be 0)."""
    real = counters.get("pack_real_tokens", 0)
    pad = counters.get("pack_pad_tokens", 0)
    if real + pad:
        util = 100.0 * real / (real + pad)
        print(f"  prefill padding: real={real} pad={pad} "
              f"({util:.1f}% buffer utilization, "
              f"{counters.get('prefill_batches', 0)} dispatches)")
    retr = counters.get("retraces", 0)
    cxl = counters.get("cancelled", 0)
    print(f"  retraces after warmup: {retr}"
          + (f", cancelled (deadline): {cxl}" if cxl else ""))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--replicas", type=int, default=0,
                    help=">=2 serves through a ServingCluster of ServeEngine "
                         "replicas (one front-end, least-loaded routing)")
    ap.add_argument("--quantized", action="store_true",
                    help="enable W8A8 + int8 KV + 4-bit log-sqrt2 attention")
    ap.add_argument("--autotune", action="store_true",
                    help="per-device Pallas tile autotuning at warmup "
                         "(kernels/autotune.py; persistent table under "
                         "--autotune-cache, pure cache hit on relaunch)")
    ap.add_argument("--autotune-cache", default=None,
                    help="tuning-table cache dir (default .repro_autotune "
                         "or $REPRO_AUTOTUNE_CACHE)")
    ap.add_argument("--trace-out", default=None,
                    help="write a Chrome-trace/Perfetto JSON of the run's "
                         "span timelines here (implies tracing on)")
    ap.add_argument("--events-out", default=None,
                    help="stream structured serving events (rejections, "
                         "cancellations, retirement faults) as JSONL here")
    ap.add_argument("--metrics-out", default=None,
                    help="write the final cluster snapshot as Prometheus "
                         "text exposition here")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if args.quantized:
        cfg = cfg.replace(quant=dataclasses.replace(cfg.quant, enable=True))
    if args.autotune:
        cfg = cfg.replace(autotune=dataclasses.replace(
            cfg.autotune, enable=True, cache_dir=args.autotune_cache))
    if args.trace_out:
        cfg = cfg.replace(trace=dataclasses.replace(cfg.trace, enable=True))
    events = EventLog(path=args.events_out) if args.events_out else None
    params = models.init_model_params(cfg, jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)
    reqs = [
        Request(
            uid=uid,
            prompt=rng.integers(0, cfg.vocab_size,
                                args.prompt_len).astype(np.int32),
            max_new_tokens=args.new_tokens,
        )
        for uid in range(args.requests)
    ]

    if args.replicas >= 2:
        cluster = ServingCluster(cfg, params, replicas=args.replicas,
                                 engine="lm", batch_slots=args.slots,
                                 max_len=args.max_len, events=events)
        cluster.warmup()
        if args.autotune:
            from repro.kernels import autotune

            print(autotune.summary())
        t0 = time.perf_counter()
        for r in reqs:
            cluster.submit(r)
            cluster.step()
        cluster.flush()
        dt = time.perf_counter() - t0
        total = args.requests * args.new_tokens
        print(f"generated {total} tokens in {dt:.2f}s "
              f"({total / dt:.1f} tok/s, replicas={cluster.num_replicas}, "
              f"quantized={args.quantized})")
        cm = cluster.metrics
        recorders = cluster.flight_recorders()
    else:
        engine = ServeEngine(cfg, params, batch_slots=args.slots,
                             max_len=args.max_len, events=events)
        engine.warmup()
        if args.autotune:
            from repro.kernels import autotune

            print(autotune.summary())
        for r in reqs:
            engine.submit(r)
        t0 = time.perf_counter()
        engine.run_until_drained()
        dt = time.perf_counter() - t0
        total = args.requests * args.new_tokens
        print(f"generated {total} tokens in {dt:.2f}s "
              f"({total / dt:.1f} tok/s, quantized={args.quantized})")
        # the single-engine path reports through the same ClusterMetrics
        # roll-up as the cluster path: one summary schema, every counter
        cm = ClusterMetrics([engine.metrics])
        recorders = ({engine.tracer.label: engine.tracer.recorder}
                     if engine.tracer.enabled else {})

    _print_report(cm.snapshot())
    if args.trace_out:
        doc = write_chrome_trace(args.trace_out, recorders)
        print(f"trace: {args.trace_out} "
              f"({sum(1 for e in doc['traceEvents'] if e['ph'] == 'X')} "
              f"spans)")
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            f.write(cm.export_prometheus())
        print(f"metrics: {args.metrics_out}")
    if events is not None:
        events.close()
        print(f"events: {args.events_out} ({events.total} events)")


if __name__ == "__main__":
    main()
