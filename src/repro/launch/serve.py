"""Serving launcher: batched generation with the CoQMoE quantized path.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --smoke \
      --requests 8 --new-tokens 16 --quantized
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import models
from repro.configs import get_config, smoke_config
from repro.serving.engine import Request, ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--quantized", action="store_true",
                    help="enable W8A8 + int8 KV + 4-bit log-sqrt2 attention")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if args.quantized:
        import dataclasses

        cfg = cfg.replace(quant=dataclasses.replace(cfg.quant, enable=True))
    params = models.init_model_params(cfg, jax.random.PRNGKey(args.seed))
    engine = ServeEngine(cfg, params, batch_slots=args.slots,
                         max_len=args.max_len)
    rng = np.random.default_rng(args.seed)
    for uid in range(args.requests):
        engine.submit(Request(
            uid=uid,
            prompt=rng.integers(0, cfg.vocab_size,
                                args.prompt_len).astype(np.int32),
            max_new_tokens=args.new_tokens,
        ))
    t0 = time.perf_counter()
    engine.run_until_drained()
    dt = time.perf_counter() - t0
    total = args.requests * args.new_tokens
    print(f"generated {total} tokens in {dt:.2f}s "
          f"({total / dt:.1f} tok/s, quantized={args.quantized})")
    snap = engine.metrics.snapshot()
    print(f"metrics: tokens/s={snap['fps']:.1f} "
          f"latency p50={snap['latency_ms']['p50']:.0f}ms "
          f"p99={snap['latency_ms']['p99']:.0f}ms "
          f"queue_depth max={snap['queue_depth']['max']}")
    if snap["expert_tokens"]:
        occ = ", ".join(f"{x:.3f}" for x in snap["expert_occupancy"])
        print(f"expert occupancy: [{occ}]")


if __name__ == "__main__":
    main()
