"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch llama3-8b \
      --steps 200 --batch 8 --seq 256 --ckpt /tmp/ckpt

Runs the fault-tolerant trainer on the current host's devices (a reduced
mesh); the production 256/512-chip mesh is exercised by the dry-run. The
same Trainer/TrainState/step code path serves both — only the mesh and the
batch geometry differ.
"""
from __future__ import annotations

import argparse

from repro.configs import TRAIN_4K, get_config, smoke_config
from repro.launch.mesh import make_host_mesh
from repro.train.trainer import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config of the same family (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--grad-compress", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    shape = TRAIN_4K.replace(seq_len=args.seq, global_batch=args.batch)
    mesh = make_host_mesh()
    tc = TrainerConfig(
        total_steps=args.steps, lr=args.lr,
        checkpoint_dir=args.ckpt, checkpoint_every=args.ckpt_every,
        grad_compress=args.grad_compress, seed=args.seed,
    )
    trainer = Trainer(cfg, shape, mesh, tc)
    state = trainer.run()
    print(f"finished at step {int(state.step)}; "
          f"final loss {trainer.history[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
