import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes and record memory/cost/collective metrics.

This is how the distribution config is proven coherent without hardware:
``jax.jit(step).lower(shapes).compile()`` runs the full GSPMD partitioner —
sharding mismatches, unsupported collectives, and per-device OOM all surface
here. Results land in ``experiments/dryrun/<cell>.json`` (resumable: existing
cells are skipped unless --force).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                  # all cells
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --multi-pod      # 512-chip mesh
"""
import argparse
import json
import re
import sys
import time
import traceback

import jax
import jax.numpy as jnp

# persistent compilation cache: re-running the sweep after analysis-only
# changes skips recompiles
jax.config.update("jax_compilation_cache_dir", "/tmp/jax_cache")
jax.config.update("jax_persistent_cache_min_compile_time_secs", 2.0)

from repro import models
from repro.configs import ASSIGNED, SHAPES, get_config, get_shape, shape_applicable
from repro.launch.mesh import make_production_mesh
from repro.optim import make_optimizer, constant
from repro.serving.engine import build_serve_step
from repro.train.train_step import build_train_step, state_shapes
from repro.distributed.sharding_rules import input_shardings, param_specs

_COLL_RE = re.compile(
    r"(\w[\w.\-]*)\s*=\s*((?:\([^)]*\))|(?:\w+\[[^\]]*\]))\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
)
_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|s32|u32|s8|u8|s16|u16|pred|s64)\[([\d,]*)\]")
_DTYPE_BYTES = {"f64": 8, "s64": 8, "f32": 4, "s32": 4, "u32": 4, "bf16": 2,
                "f16": 2, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1}


def _bytes_of(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.groups()
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo_text: str) -> dict:
    """Sum collective output bytes per op kind, accounting for while-loop
    (scan) trip counts: bytes inside a loop body count trip_count times.

    Trip counts are recovered from the loop condition's comparison constant
    (lax.scan lowers to a counted while loop).
    """
    # split into computations
    comps = {}
    cur = None
    for line in hlo_text.splitlines():
        m = re.match(r"^\s*(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->", line)
        if m:
            cur = m.group(1)
            comps[cur] = []
        elif cur is not None:
            comps[cur].append(line)

    # per-computation collective bytes
    per_comp = {}
    for name, lines in comps.items():
        agg = {}
        for ln in lines:
            cm = _COLL_RE.search(ln)
            if cm:
                kind = cm.group(3)
                agg[kind] = agg.get(kind, 0) + _bytes_of(cm.group(2))
        per_comp[name] = agg

    # while loops: body -> trip count
    body_trips = {}
    for name, lines in comps.items():
        for ln in lines:
            wm = re.search(r"while\(.*condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)", ln)
            if wm:
                cond, body = wm.groups()
                trip = 1
                for cl in comps.get(cond, []):
                    km = re.search(r"constant\((\d+)\)", cl)
                    if km:
                        trip = max(trip, int(km.group(1)))
                body_trips[body] = trip

    total = {}
    for name, agg in per_comp.items():
        mult = body_trips.get(name, 1)
        for kind, b in agg.items():
            total[kind] = total.get(kind, 0) + b * mult
    total["total_bytes"] = sum(v for k, v in total.items() if k != "total_bytes")
    return total


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               quantized: bool = False, grad_compress: bool = False) -> dict:
    cfg = get_config(arch)
    if quantized:
        # the paper's serving path: W8A8 weights stay fp in the dry-run
        # (weight-only int8 halves reads identically), int8 K/V cache +
        # 4-bit log-sqrt2 attention probabilities become part of the graph
        import dataclasses

        cfg = cfg.replace(quant=dataclasses.replace(
            cfg.quant, enable=True, kv_cache_int8=True))
    shape = get_shape(shape_name)
    ok, reason = shape_applicable(cfg, shape)
    if not ok:
        return {"status": "skipped", "reason": reason}
    mesh = make_production_mesh(multi_pod=multi_pod)
    mod = models.module_for(cfg)
    in_tree = models.input_specs(cfg, shape)
    t0 = time.time()
    with mesh:
        if shape.kind == "train":
            opt = make_optimizer(cfg.optimizer, constant(1e-4))
            step = build_train_step(cfg, shape, mesh, opt,
                                    grad_compress=grad_compress)
            st = state_shapes(cfg, opt, dtype=jnp.bfloat16,
                              grad_compress=grad_compress)
            lowered = step.lower(st, in_tree)
        elif shape.kind == "prefill":
            p_specs = param_specs(cfg, mesh)
            b_specs = input_shardings(cfg, shape, mesh, in_tree)
            from jax.sharding import NamedSharding, PartitionSpec as P
            named = lambda tree: jax.tree.map(
                lambda s: NamedSharding(mesh, s), tree,
                is_leaf=lambda x: isinstance(x, P))

            def prefill_step(params, batch):
                return mod.prefill(
                    params, cfg, batch["tokens"],
                    frontend_embeds=batch.get("frontend_embeds"),
                    max_len=shape.seq_len,
                )

            fn = jax.jit(prefill_step,
                         in_shardings=(named(p_specs), named(b_specs)))
            lowered = fn.lower(
                models.model_param_shapes(cfg, jnp.bfloat16), in_tree)
        else:  # decode
            step = build_serve_step(cfg, shape, mesh, for_lowering=True)
            lowered = step.lower(
                models.model_param_shapes(cfg, jnp.bfloat16),
                in_tree["tokens"], in_tree["cache"], in_tree["index"],
            )
        compiled = lowered.compile()
    t1 = time.time()
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    text = compiled.as_text()
    colls = collective_stats(text)
    # call-graph-aware metrics (scan trip counts applied — cost_analysis
    # counts while bodies once; see repro/analysis/hlo.py)
    from repro.analysis.hlo import analyze

    deep = analyze(text)
    rec = {
        "status": "ok",
        "arch": arch,
        "shape": shape_name,
        "mesh": "pod2x16x16" if multi_pod else "16x16",
        "compile_s": round(t1 - t0, 1),
        "flops_per_device": cost.get("flops", -1.0),
        "bytes_accessed_per_device": cost.get("bytes accessed", -1.0),
        "dot_flops_per_device": deep.get("dot_flops", -1),
        "hbm_bytes_per_device": deep.get("hbm_bytes", -1),
        "convert_bytes_per_device": deep.get("convert_bytes", 0),
        "collective_bytes_per_device": deep.get("collective_bytes", -1),
        "collective_kinds": {
            k: deep.get(k, 0)
            for k in ("all-gather", "all-reduce", "reduce-scatter",
                      "all-to-all", "collective-permute")
        },
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", -1),
            "output_bytes": getattr(mem, "output_size_in_bytes", -1),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", -1),
            "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes", -1),
        },
        "collectives": colls,
    }
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="one arch id (default: all)")
    ap.add_argument("--shape", default=None, help="one shape (default: all)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--quantized", action="store_true",
                    help="CoQMoE serving quantization (int8 KV + attn4)")
    ap.add_argument("--grad-compress", action="store_true",
                    help="INT8 gradient compression with error feedback")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(ASSIGNED)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    os.makedirs(args.out, exist_ok=True)

    failures = 0
    for arch in archs:
        for shape_name in shapes:
            for mp in meshes:
                tag = f"{arch}__{shape_name}__{'pod2' if mp else 'pod1'}"
                if args.quantized:
                    tag += "__q"
                if args.grad_compress:
                    tag += "__gc"
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path) and not args.force:
                    print(f"[skip existing] {tag}")
                    continue
                print(f"[lower+compile] {tag} ...", flush=True)
                try:
                    rec = lower_cell(arch, shape_name, mp,
                                     quantized=args.quantized,
                                     grad_compress=args.grad_compress)
                except Exception as e:  # record the failure — it's a bug
                    rec = {"status": "error", "error": repr(e),
                           "traceback": traceback.format_exc()}
                    failures += 1
                    print(f"  ERROR: {e}", flush=True)
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                if rec["status"] == "ok":
                    print(
                        f"  ok: {rec['compile_s']}s, "
                        f"flops/dev={rec['flops_per_device']:.3g}, "
                        f"coll={rec['collectives'].get('total_bytes', 0):.3g}B",
                        flush=True,
                    )
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
