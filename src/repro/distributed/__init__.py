from repro.distributed.expert_parallel import (
    expert_parallel_moe,
    get_ep_mesh,
    set_ep_mesh,
    use_ep_mesh,
    validate_ep,
)
from repro.distributed.sharding_rules import (
    EXPERT_PARALLEL_RULES,
    SERVING_RULES,
    batch_axes,
    cache_specs,
    input_shardings,
    opt_state_specs,
    param_specs,
    spec_for_axes,
)

__all__ = [k for k in dir() if not k.startswith("_")]
