from repro.distributed.sharding_rules import (
    batch_axes,
    cache_specs,
    input_shardings,
    opt_state_specs,
    param_specs,
    spec_for_axes,
)

__all__ = [k for k in dir() if not k.startswith("_")]
