"""Expert-parallel grouped MoE execution (DESIGN.md section 7).

The grouped (sort-based unified-kernel) MoE path run under ``shard_map``
over the ``'model'`` mesh axis:

  * the expert stacks — fp *or* materialized-int8 ``wi``/``wo`` plus their
    ``_scale`` dequant vectors and per-expert biases — are **sharded over
    the expert dim** (each of the ``n`` shards holds ``E/n`` experts; the
    full stack is never replicated);
  * routing runs replicated (the gate is tiny), then tokens are sharded
    over ``'model'``, locally expert-sorted, and **exchanged with
    ``all_to_all``** so each shard receives exactly the rows bound for its
    local experts;
  * the per-shard compute is the *same* ``kernels.ops.grouped_mlp`` the
    single-device path uses (Pallas grouped kernel on TPU, ``ragged_dot``
    on CPU, int8-in-int8 for QuantizedParams trees) over local experts
    only, with one zero "dump" expert appended to absorb exchange padding;
  * results return to their source shard with a second ``all_to_all`` and
    combine locally with the routing weights (Eq. 5).

Capacity is worst-case (``C = T_local * top_k`` rows per (src, dst) pair),
so the exchange is **dropless** — expert-parallel output equals the
single-device grouped output up to fp summation order, which is what the
equivalence tests assert.

The mesh is ambient state: engines wrap their jitted forward in
``use_ep_mesh(mesh)`` so the ``shard_map`` closure captures it at trace
time. ``moe_exec="expert_parallel"`` on ``MoEConfig`` routes
``models.transformer._moe_apply`` through here.
"""
from __future__ import annotations

import contextlib
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core.moe.dispatch import (
    ep_exchange_plan,
    grouped_combine,
    grouped_dispatch,
    quantize_ep_payload,
)
from repro.core.moe.router import route_topk

EP_AXIS = "model"

# Expert-stack leaves sharded over the expert dim (axis 0); everything else
# in the moe subtree (gate, per-tensor activation scales) stays replicated.
_SHARDED_LEAVES = ("wi", "wo", "wi_scale", "wo_scale", "bi", "bo")
_SCALAR_LEAVES = ("wi_as", "wo_a_scale")

_EP_MESH: Optional[Mesh] = None


def set_ep_mesh(mesh: Optional[Mesh]) -> None:
    """Install (or clear, with None) the ambient expert-parallel mesh."""
    global _EP_MESH
    _EP_MESH = mesh


def get_ep_mesh() -> Optional[Mesh]:
    return _EP_MESH


@contextlib.contextmanager
def use_ep_mesh(mesh: Mesh):
    """Scope the ambient EP mesh — wrap the *trace* of any forward whose
    config carries ``moe_exec="expert_parallel"`` (engines wrap every call;
    only the first, tracing, call actually reads the mesh)."""
    global _EP_MESH
    prev = _EP_MESH
    _EP_MESH = mesh
    try:
        yield mesh
    finally:
        _EP_MESH = prev


def validate_ep(cfg: ModelConfig, mesh: Mesh) -> int:
    """Check (cfg, mesh) supports expert parallelism; returns shard count."""
    if cfg.moe is None:
        raise ValueError("expert_parallel: config has no MoE block")
    if cfg.moe.impl != "grouped":
        raise ValueError(
            "expert_parallel requires the grouped MoE path "
            f"(impl={cfg.moe.impl!r}); gshard is GSPMD-native already"
        )
    if EP_AXIS not in mesh.axis_names:
        raise ValueError(f"expert_parallel mesh needs a {EP_AXIS!r} axis: "
                         f"{mesh.axis_names}")
    n = mesh.shape[EP_AXIS]
    if cfg.moe.num_experts % n != 0:
        raise ValueError(
            f"num_experts={cfg.moe.num_experts} not divisible by "
            f"{EP_AXIS!r} axis size {n}"
        )
    return n


def _append_dump_expert(leaf: jnp.ndarray) -> jnp.ndarray:
    """Append one all-zero expert slot (absorbs exchange-padding rows —
    their outputs are zero and are dropped before the return exchange)."""
    pad = [(0, 1)] + [(0, 0)] * (leaf.ndim - 1)
    return jnp.pad(leaf, pad)


def _ep_shard_body(x_loc, experts_loc, weights_loc, w_shard, scalars, *,
                   cfg: ModelConfig, n_shards: int,
                   quantize_exchange: bool):
    """Per-shard program: local dispatch -> all_to_all -> grouped_mlp over
    local experts -> all_to_all back -> local combine.

    x_loc [T_loc, D]; experts/weights [T_loc, k]; ``w_shard`` leaves carry
    the local expert slice (axis 0 == E_local). With ``quantize_exchange``
    the token payload crosses the all_to_all as int8 (4x fewer bytes):
    rows are quantized with the folded fc1 activation scale *before*
    packing — elementwise, so bit-identical to quantizing after the
    exchange, which is what the grouped kernel would otherwise do — and
    the kernel consumes the int8 rows directly."""
    from repro.kernels import ops

    m = cfg.moe
    E = m.num_experts
    e_local = E // n_shards
    T_loc, D = x_loc.shape
    R = T_loc * m.top_k  # rows this shard contributes to the exchange
    C = R  # worst-case per-destination capacity: dropless by construction

    d = grouped_dispatch(x_loc, experts_loc, weights_loc, E)
    plan = ep_exchange_plan(d.group_sizes, n_shards, R)

    x_rows = d.x_sorted
    if quantize_exchange:
        x_rows = quantize_ep_payload(x_rows, scalars["wi_as"],
                                     cfg.quant.a_bits)

    # pack: row i of the sorted buffer -> send[dest_shard, pos]; unfilled
    # slots keep expert id == e_local (the dump group on the receiver)
    send_x = jnp.zeros((n_shards, C, D), x_rows.dtype)
    send_x = send_x.at[plan.row_shard, plan.row_pos].set(x_rows)
    send_e = jnp.full((n_shards, C), e_local, jnp.int32)
    send_e = send_e.at[plan.row_shard, plan.row_pos].set(
        plan.row_local_expert)

    # exchange: recv[s] = the slice source shard s bound for OUR experts
    recv_x = jax.lax.all_to_all(send_x, EP_AXIS, 0, 0)
    recv_e = jax.lax.all_to_all(send_e, EP_AXIS, 0, 0)

    # re-sort received rows by local expert (stable: sources stay FIFO);
    # padding (id == e_local) sorts last, into the dump group
    flat_x = recv_x.reshape(n_shards * C, D)
    flat_e = recv_e.reshape(n_shards * C)
    order = jnp.argsort(flat_e, stable=True)
    xs = flat_x[order]
    gs = jnp.bincount(flat_e, length=e_local + 1).astype(jnp.int32)

    wi = _append_dump_expert(w_shard["wi"])
    wo = _append_dump_expert(w_shard["wo"])
    opt = {
        k: _append_dump_expert(w_shard[k])
        for k in ("wi_scale", "wo_scale", "bi", "bo") if k in w_shard
    }
    y_sorted = ops.grouped_mlp(
        xs, wi, wo, gs,
        act=cfg.act, glu=cfg.glu,
        bi=opt.get("bi"), bo=opt.get("bo"),
        mid_a_scale=scalars.get("wo_a_scale"),
        a_bits=cfg.quant.a_bits,
        wi_scale=opt.get("wi_scale"), wo_scale=opt.get("wo_scale"),
        wi_a_scale=scalars.get("wi_as"),
    )

    # unsort to exchange positions and return rows to their source shard
    y_flat = jnp.zeros_like(y_sorted).at[order].set(y_sorted)
    y_back = jax.lax.all_to_all(y_flat.reshape(n_shards, C, D), EP_AXIS, 0, 0)
    y_rows = y_back[plan.row_shard, plan.row_pos]  # [R, D] sorted-row order
    return grouped_combine(y_rows, d, T_loc)


def expert_parallel_moe(x: jnp.ndarray, p: dict, cfg: ModelConfig, *,
                        quantize_exchange: Optional[bool] = None):
    """Expert-parallel MoE FFN on [B, S, D]; drop-in for the grouped branch
    of ``_moe_apply`` — returns (y, aux_loss, expert_counts [E] int32).

    Requires an ambient mesh (``use_ep_mesh``) whose ``'model'`` axis size
    divides ``num_experts``. ``quantize_exchange`` quantizes the token
    all_to_all payload to int8 with the folded activation scales; the
    default (None) enables it automatically for materialized-int8 expert
    stacks (where the kernel would quantize the rows anyway — moving them
    fp32 first wastes 4x interconnect bytes)."""
    from repro.models.layers import quant_linear

    mesh = _EP_MESH
    if mesh is None:
        raise RuntimeError(
            "moe_exec='expert_parallel' but no EP mesh is set — wrap the "
            "forward in distributed.expert_parallel.use_ep_mesh(mesh)"
        )
    n = validate_ep(cfg, mesh)
    m = cfg.moe
    B, S, D = x.shape
    T = B * S
    xt = x.reshape(T, D)

    # routing is replicated: identical to the single-device path, so the
    # expert-parallel output is bit-compatible routing-wise
    gate_logits = (quant_linear(xt, p, "gate", cfg)
                   if p["gate"].dtype == jnp.int8 else None)
    r = route_topk(xt, p["gate"], p.get("gate_b"), m.top_k,
                   logits=gate_logits)
    counts = jnp.bincount(
        r.experts.reshape(-1), length=m.num_experts
    ).astype(jnp.int32)

    # pad the token dim to the shard count; pad rows route to expert 0 with
    # combine weight 0 (they cost exchange slots, never output)
    T_pad = -(-T // n) * n
    pad = T_pad - T
    xp = jnp.pad(xt, ((0, pad), (0, 0)))
    ep = jnp.pad(r.experts, ((0, pad), (0, 0)))
    wp = jnp.pad(r.weights, ((0, pad), (0, 0)))

    w_shard = {k: p[k] for k in _SHARDED_LEAVES if k in p}
    scalars = {k: p[k] for k in _SCALAR_LEAVES if k in p}
    if quantize_exchange is None:
        # int8 and nibble-packed-int4 (uint8) stacks both consume int8
        # activations, so the exchange quantizes in either case
        quantize_exchange = (p["wi"].dtype in (jnp.int8, jnp.uint8)
                             and "wi_as" in p)
    elif quantize_exchange and "wi_as" not in p:
        raise ValueError(
            "quantize_exchange needs the folded fc1 activation scale "
            "(`wi_as`) — only materialized int8/int4 QuantizedParams "
            "trees carry it")

    y = shard_map(
        partial(_ep_shard_body, cfg=cfg, n_shards=n,
                quantize_exchange=bool(quantize_exchange)),
        mesh=mesh,
        in_specs=(
            P(EP_AXIS), P(EP_AXIS), P(EP_AXIS),
            {k: P(EP_AXIS) for k in w_shard},
            {k: P() for k in scalars},
        ),
        out_specs=P(EP_AXIS),
        check_rep=False,
    )(xp, ep, wp, w_shard, scalars)
    return y[:T].reshape(B, S, D), r.aux_loss, counts
