"""Logical-axis -> mesh-axis sharding rules (MaxText-style GSPMD).

Weight rules (single- and multi-pod; the pod axis carries pure DP):

  vocab / qkv / kv / mlp / expert / ssm_inner -> 'model'   (TP / EP)
  embed                                       -> 'data'    (FSDP)
  layers / None                               -> replicated

A PartitionSpec may not reuse a mesh axis, so rules apply left-to-right and
later duplicates degrade to replicated — e.g. MoE expert tensors
[layers, expert, embed, mlp] become P(None, 'model', 'data', None): EP wins
the 'model' axis, expert-internal mlp stays unsharded (re-sharded during the
perf pass if profitable).

Activations: batch -> ('pod', 'data'); long-context decode (global_batch=1)
shards the KV/state *sequence* dim over 'data' instead (context parallelism).
Optimizer state inherits the param spec when shapes match (ZeRO), else is
replicated (Adafactor's tiny factored vectors).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig

DEFAULT_RULES: Tuple[Tuple[str, Optional[str]], ...] = (
    ("vocab", "model"),
    ("embed", "data"),
    ("qkv", "model"),
    ("kv", "model"),
    ("heads", "model"),
    ("mlp", "model"),
    ("expert", "model"),
    ("ssm_inner", "model"),
    ("layers", None),
)

# Serving (decode) rules: weight-stationary TP — no FSDP on the embed dim.
# Decode re-gathers FSDP-sharded params every step (pure overhead once the
# model fits TP-sharded in HBM); EXPERIMENTS.md section Perf, iteration 3.
SERVING_RULES: Tuple[Tuple[str, Optional[str]], ...] = tuple(
    (k, None if k == "embed" else v) for k, v in DEFAULT_RULES
)

# Expert-parallel serving rules: ONLY the expert dim is sharded (over
# 'model'); attention / dense MLP / norms replicate per replica. The grouped
# kernel then runs per-shard on local experts inside shard_map — see
# distributed/expert_parallel.py and DESIGN.md section 7.
EXPERT_PARALLEL_RULES: Tuple[Tuple[str, Optional[str]], ...] = tuple(
    (k, v if k == "expert" else None) for k, v in DEFAULT_RULES
)


def spec_for_axes(axes: Tuple[Optional[str], ...], rules=DEFAULT_RULES,
                  shape: Optional[Tuple[int, ...]] = None,
                  mesh: Optional[Mesh] = None) -> P:
    """Resolve one tensor's logical axes, deduping mesh axes left-to-right.

    When (shape, mesh) are given, axes whose dim is not divisible by the
    mesh-axis size degrade to replicated — jit in_shardings requires exact
    divisibility (e.g. seamless's vocab 256206 is not 16-divisible)."""
    table = dict(rules)
    used = set()
    out = []
    for i, ax in enumerate(axes):
        mesh_ax = table.get(ax) if ax is not None else None
        if mesh_ax is not None and mesh is not None \
                and mesh_ax not in mesh.shape:
            mesh_ax = None  # mesh lacks the axis (e.g. ('model',)-only)
        if mesh_ax is not None and shape is not None and mesh is not None:
            if shape[i] % mesh.shape.get(mesh_ax, 1) != 0:
                mesh_ax = None
        if mesh_ax is None or mesh_ax in used:
            out.append(None)
        else:
            used.add(mesh_ax)
            out.append(mesh_ax)
    return P(*out)


def param_specs(cfg: ModelConfig, mesh: Optional[Mesh] = None,
                rules=DEFAULT_RULES):
    """PartitionSpec tree matching the model's param tree."""
    from repro import models

    axes_tree = models.model_param_axes(cfg)
    if mesh is None:
        return jax.tree.map(
            lambda ax: spec_for_axes(ax, rules),
            axes_tree,
            is_leaf=lambda x: isinstance(x, tuple),
        )
    shapes_tree = models.model_param_shapes(cfg)
    return jax.tree.map(
        lambda ax, sh: spec_for_axes(ax, rules, tuple(sh.shape), mesh),
        axes_tree, shapes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            a is None or isinstance(a, str) for a in x
        ),
    )


def fit_specs_to_tree(specs_tree, params_tree):
    """Extend a PDef-derived spec tree to cover a *transformed* param tree.

    A PTQ'd tree (and especially a QuantizedParams tree from
    ``ptq_model(..., materialize="int8")``) carries leaves the abstract
    param tree does not: ``<w>_scale`` per-channel dequant vectors,
    ``<w>_as`` / ``a_scale`` / ``wo_a_scale`` activation scales, and folded
    bias corrections. Leaves whose path exists in the base spec tree keep
    their spec (the int8 weight has the same shape/axes as its fp
    ancestor); everything else replicates — scale vectors are tiny.
    """
    def walk(spec_node, tree_node):
        if isinstance(tree_node, dict):
            base = spec_node if isinstance(spec_node, dict) else {}
            return {k: walk(base.get(k), v) for k, v in tree_node.items()}
        return spec_node if isinstance(spec_node, P) else P()

    return walk(specs_tree, params_tree)


def batch_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _axis_size(mesh: Mesh, ax) -> int:
    if ax is None:
        return 1
    if isinstance(ax, tuple):
        return int(np.prod([mesh.shape.get(a, 1) for a in ax]))
    return mesh.shape.get(ax, 1)


def _fit(entries, shape, mesh: Mesh) -> P:
    """Drop spec entries whose dim is not divisible, whose mesh axis is
    already used, or whose axis the mesh does not carry (replica slices are
    1-axis ('model',) meshes — batch entries naming 'data' degrade)."""
    used = set()
    out = []
    for dim, ax in zip(shape, entries):
        axes = ax if isinstance(ax, tuple) else ((ax,) if ax else ())
        if (ax is None or any(a in used for a in axes)
                or any(a not in mesh.shape for a in axes)
                or dim % _axis_size(mesh, ax) != 0):
            out.append(None)
        else:
            used.update(axes)
            out.append(ax)
    return P(*out)


def _cache_leaf_spec(key: str, shape, mesh: Mesh, batch, seq_ax):
    """Spec for one KV-cache / SSM-state leaf by key name and rank.

    GQA archs with fewer KV heads than the model axis shard the cache
    *sequence* over 'model' (context-parallel decode). Perf note
    (EXPERIMENTS.md section Perf, iteration 1): the earlier head_dim
    fallback made QK^T contract over a sharded dim -> a psum of the full
    [B, H, 1, S] score tensor every layer; sequence sharding leaves QK/PV
    local and reduces only the per-row softmax stats and the [B, H, 1, hd]
    output (~1000x fewer collective bytes on gemma2-2b decode_32k)."""
    ndim = len(shape)
    if key in ("k", "v"):  # [L, B, S, KVH, hd]
        if shape[3] % _axis_size(mesh, "model") == 0:
            ent = (None, batch, seq_ax, "model", None)
        elif seq_ax is None:
            ent = (None, batch, "model", None, None)  # context parallel
        else:
            ent = (None, batch, seq_ax, None, "model")
        return _fit(ent, shape, mesh)
    if key in ("k_scale", "v_scale"):  # [L, B, S, KVH]
        if shape[3] % _axis_size(mesh, "model") == 0:
            ent = (None, batch, seq_ax, "model")
        elif seq_ax is None:
            ent = (None, batch, "model", None)
        else:
            ent = (None, batch, seq_ax, None)
        return _fit(ent, shape, mesh)
    if key == "h":  # mamba1 [L,B,di,N] | mamba2 [L,B,H,P,N]
        return _fit((None, batch, "model") + (None,) * (ndim - 3), shape, mesh)
    if key == "conv":  # [L, B, W-1, C]
        return _fit((None, batch, None, "model"), shape, mesh)
    return P()


def cache_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                cache_tree):
    """Spec tree for a decode cache (same structure as cache_shapes)."""
    ba = batch_axes(mesh)
    if shape.global_batch == 1:
        batch, seq_ax = None, "data"  # context parallelism
    else:
        batch, seq_ax = (ba if len(ba) > 1 else ba[0]), None

    def walk(tree):
        return {
            k: walk(v) if isinstance(v, dict)
            else _cache_leaf_spec(k, tuple(v.shape), mesh, batch, seq_ax)
            for k, v in tree.items()
        }

    return walk(cache_tree)


def input_shardings(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                    specs_tree):
    """Spec tree matching ``models.input_specs`` output."""
    ba = batch_axes(mesh)
    batch = ba if len(ba) > 1 else ba[0]
    if shape.global_batch == 1:
        batch = None
    out = {}
    for name, spec in specs_tree.items():
        if name == "cache":
            out["cache"] = cache_specs(cfg, shape, mesh, spec)
        elif name == "index":
            out["index"] = P()
        else:
            sh = tuple(spec.shape)
            out[name] = (
                _fit((batch,) + (None,) * (len(sh) - 1), sh, mesh)
                if sh else P()
            )
    return out


def opt_state_specs(opt_state_shapes, params_specs, params_shapes):
    """Optimizer-state specs: inherit the param spec when shapes match
    (AdamW m/v, Adafactor unfactored v), else replicate (factored vr/vc)."""
    flat_ps, _ = jax.tree.flatten(params_specs)
    flat_sh = [tuple(s.shape) for s in jax.tree.leaves(params_shapes)]
    by_shape = {}
    for sh, sp in zip(flat_sh, flat_ps):
        by_shape.setdefault(sh, sp)

    def one(leaf):
        return by_shape.get(tuple(leaf.shape), P())

    return jax.tree.map(one, opt_state_shapes)


def named(mesh: Mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )
