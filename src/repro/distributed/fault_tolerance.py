"""Fault-tolerance utilities: preemption hook, straggler monitor, elastic
re-mesh, step retry.

At 1000+ nodes the failure model is: (a) planned preemptions (signal) —
checkpoint immediately and exit clean; (b) hard node loss — the job
restarts on a reshaped slice and restores the latest atomic checkpoint onto
the new mesh (CheckpointManager.restore handles the re-mesh); (c) stragglers
— detected from per-step wall-time EMA and surfaced so the scheduler can
replace the slow host (XLA's collectives otherwise silently serialize on the
slowest participant).
"""
from __future__ import annotations

import signal
import time
from typing import Callable, List, Optional

import jax
import numpy as np


class PreemptionGuard:
    """Converts SIGTERM/SIGINT into a drain flag the train loop polls."""

    def __init__(self, signals=(signal.SIGTERM,)) -> None:
        self._requested = False
        self._prev = {}
        for s in signals:
            try:
                self._prev[s] = signal.signal(s, self._handler)
            except ValueError:  # non-main thread (tests)
                pass

    def _handler(self, signum, frame):
        self._requested = True

    @property
    def preempted(self) -> bool:
        return self._requested

    def request(self) -> None:  # testable without raising a real signal
        self._requested = True


class StragglerMonitor:
    """Per-step wall-time EMA; flags steps slower than ``threshold`` x EMA.

    On a real multi-host deployment each host contributes its step time via
    a host-id-tagged all-gather; here the host dimension is simulated by the
    caller passing per-host durations (tests) or a single duration.
    """

    def __init__(self, alpha: float = 0.1, threshold: float = 2.0,
                 warmup_steps: int = 5) -> None:
        self.alpha = alpha
        self.threshold = threshold
        self.warmup = warmup_steps
        self.ema: Optional[float] = None
        self.count = 0
        self.events: List[dict] = []

    def record(self, duration_s: float, host_id: int = 0,
               step: int = -1) -> bool:
        """Returns True when this measurement is a straggler event."""
        self.count += 1
        if self.ema is None:
            self.ema = duration_s
            return False
        is_slow = (
            self.count > self.warmup
            and duration_s > self.threshold * self.ema
        )
        if is_slow:
            self.events.append(
                {"step": step, "host": host_id, "duration": duration_s,
                 "ema": self.ema}
            )
        else:
            # stragglers are excluded from the EMA so one slow host does not
            # mask the next
            self.ema = (1 - self.alpha) * self.ema + self.alpha * duration_s
        return is_slow


def run_step_with_retry(fn: Callable, *args, max_retries: int = 2,
                        on_retry: Optional[Callable] = None,
                        sleep: Callable[[float], None] = time.sleep):
    """Retry a step on transient runtime errors (host OOM spikes, flaky
    collective timeouts). Deterministic data keyed by step makes the retry
    exactly reproducible. Backoff is 0.1 * 2**attempt seconds via ``sleep``
    (injectable so tests assert the schedule without waiting it out)."""
    for attempt in range(max_retries + 1):
        try:
            return fn(*args)
        except (RuntimeError, jax.errors.JaxRuntimeError):
            if attempt == max_retries:
                raise
            if on_retry is not None:
                on_retry(attempt)
            sleep(0.1 * 2**attempt)


def elastic_mesh(preferred_shape, axis_names, devices=None):
    """Build the largest mesh of ``preferred_shape``'s aspect that fits the
    available devices (elastic scaling: lose a host, keep training).

    Shrinks the *data* (first) axis first, preserving the model axis, since
    TP degree is baked into layout efficiency while DP degree is free.
    """
    devices = devices if devices is not None else jax.devices()
    n = len(devices)
    shape = list(preferred_shape)
    while int(np.prod(shape)) > n and shape[0] > 1:
        shape[0] //= 2
    if int(np.prod(shape)) > n:
        raise ValueError(
            f"cannot fit mesh {preferred_shape} on {n} devices even after "
            f"shrinking the data axis"
        )
    use = int(np.prod(shape))
    dev_array = np.asarray(devices[:use]).reshape(shape)
    return jax.sharding.Mesh(dev_array, axis_names)
