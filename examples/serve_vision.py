"""Vision serving demo: the quantized MoE-ViT request path end to end.

Submits a ragged stream of synthetic image-patch requests to ``VisionEngine``
twice — once over the fp32 tree, once over the materialized-int8
``QuantizedParams`` tree (weights stored *and executed* as int8 + scales) —
and prints top-k agreement, measured FPS, latency percentiles, and the
per-expert routed-token occupancy histogram.

  PYTHONPATH=src python examples/serve_vision.py
  PYTHONPATH=src python examples/serve_vision.py --arch m3vit-small --requests 32
"""
import argparse

import jax
import numpy as np

import repro.models as M
from repro.configs import get_shape, smoke_config
from repro.core.quant.ptq import calibrate_model, ptq_model, quantized_config
from repro.models.param import tree_bytes
from repro.serving import VisionEngine, synth_requests


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="m3vit-tiny")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--buckets", type=int, nargs="*", default=[1, 4, 8])
    args = ap.parse_args()

    cfg = smoke_config(args.arch).replace(remat=False)
    params = M.init_model_params(cfg, jax.random.PRNGKey(0))

    # calibrate -> PTQ -> materialize the executable int8 tree
    shape = get_shape("train_4k").replace(seq_len=24, global_batch=2)
    calib = [M.synth_batch(cfg, shape, jax.random.PRNGKey(i)) for i in range(2)]
    taps = calibrate_model(cfg, params, calib)
    p_int8 = ptq_model(cfg, params, taps, materialize="int8")
    print(f"param bytes: fp={tree_bytes(params)/1e6:.2f}MB -> "
          f"int8={tree_bytes(p_int8)/1e6:.2f}MB "
          f"({tree_bytes(params)/tree_bytes(p_int8):.2f}x smaller)")

    results = {}
    for label, c, p in (("fp32", cfg, params),
                        ("int8", quantized_config(cfg), p_int8)):
        eng = VisionEngine(c, p, batch_buckets=tuple(args.buckets),
                           max_wait_s=1e-3, top_k=5)
        eng.warmup()
        reqs = synth_requests(cfg, args.requests)
        for r in reqs:
            eng.submit(r)
            eng.step()  # double-buffered: dispatch while more images arrive
        eng.flush()
        snap = eng.metrics.snapshot()
        results[label] = reqs
        print(f"\n{label}: {snap['fps']:.1f} FPS  "
              f"p50={snap['latency_ms']['p50']:.2f}ms "
              f"p95={snap['latency_ms']['p95']:.2f}ms "
              f"p99={snap['latency_ms']['p99']:.2f}ms")
        print(f"  counters: {snap['counters']}")
        if snap["expert_tokens"]:
            occ = ", ".join(f"{x:.3f}" for x in snap["expert_occupancy"])
            print(f"  expert occupancy: [{occ}]")

    top1 = np.mean([int(a.classes[0] == b.classes[0])
                    for a, b in zip(results["fp32"], results["int8"])])
    print(f"\ntop-1 agreement fp32 vs int8: {top1:.2%} "
          f"(random-init model; trained models track closer)")
    first = results["int8"][0]
    print(f"request 0 (int8): classes={first.classes.tolist()} "
          f"probs={np.round(first.probs, 3).tolist()}")


if __name__ == "__main__":
    main()
