"""End-to-end training driver: ~100M-param LM for a few hundred steps with
the full production substrate — sharded train step, checkpointing (resume
it by re-running the same command), straggler monitoring, preemption drain.

  PYTHONPATH=src python examples/train_lm.py            # ~100M llama-style
  PYTHONPATH=src python examples/train_lm.py --moe      # ~60M olmoe-style
  PYTHONPATH=src python examples/train_lm.py --compress # int8 grad payload
"""
import argparse

from repro.configs import get_config, get_shape
from repro.launch.mesh import make_host_mesh
from repro.train.trainer import Trainer, TrainerConfig


def hundred_m_config(moe: bool):
    """A ~100M-param member of an assigned family (real arch, scaled)."""
    if moe:
        base = get_config("olmoe-1b-7b")
        import dataclasses

        return base.replace(
            name="olmoe-100m", num_layers=8, d_model=512,
            vocab_size=8192, microbatch_size=0, remat=False,
            moe=dataclasses.replace(base.moe, num_experts=8, top_k=2,
                                    d_ff=512, impl="grouped"),
            attn=dataclasses.replace(base.attn, num_heads=8, num_kv_heads=8,
                                     head_dim=64),
        )
    base = get_config("llama3-8b")
    import dataclasses

    return base.replace(
        name="llama-100m", num_layers=10, d_model=768, d_ff=2048,
        vocab_size=16384, microbatch_size=0, remat=False,
        attn=dataclasses.replace(base.attn, num_heads=12, num_kv_heads=4,
                                 head_dim=64),
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--moe", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--compress", action="store_true")
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm_ckpt")
    args = ap.parse_args()

    cfg = hundred_m_config(args.moe)
    print(f"arch {cfg.name}: {cfg.param_count()/1e6:.0f} M params")
    shape = get_shape("train_4k").replace(seq_len=args.seq,
                                          global_batch=args.batch)
    tc = TrainerConfig(
        total_steps=args.steps, lr=3e-4, warmup_steps=20,
        checkpoint_dir=args.ckpt, checkpoint_every=50, log_every=10,
        grad_compress=args.compress,
    )
    trainer = Trainer(cfg, shape, make_host_mesh(), tc)
    state = trainer.run()  # restores + resumes if a checkpoint exists
    losses = [h["loss"] for h in trainer.history]
    if losses:
        print(f"loss: first {losses[0]:.3f} -> last {losses[-1]:.3f} "
              f"({len(losses)} steps this run; step={int(state.step)})")
    if trainer.straggler.events:
        print(f"straggler events: {trainer.straggler.events}")


if __name__ == "__main__":
    main()
