"""Quickstart: the CoQMoE pipeline end-to-end on a reduced MoE-ViT.

  1. build an M3ViT (MoE-ViT) model and train it briefly on the synthetic
     classification task,
  2. run the paper's PTQ pipeline: calibrate (32 samples) -> post-LayerNorm
     reparameterization (Eqs. 10-16) -> weight INT8 + activation scales ->
     4-bit log-sqrt2 attention (Eqs. 17-21),
  3. compare FP vs quantized predictions.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

import repro.models as M
from repro.configs import PAPER_ARCHS, get_shape
from repro.core.quant.ptq import calibrate_model, ptq_model, quantized_config
from repro.data import SyntheticPipeline
from repro.launch.mesh import make_host_mesh
from repro.train.trainer import Trainer, TrainerConfig


def main() -> None:
    # -- 1. a reduced M3ViT (same family/structure as the paper's arch) ----
    cfg = PAPER_ARCHS["m3vit-tiny"].replace(num_layers=4, remat=False)
    shape = get_shape("train_4k").replace(global_batch=16)
    print(f"model: {cfg.name} ({cfg.param_count()/1e6:.1f} M params, "
          f"{cfg.moe.num_experts} experts top-{cfg.moe.top_k})")

    tc = TrainerConfig(total_steps=40, lr=1e-3, warmup_steps=5, log_every=10)
    trainer = Trainer(cfg, shape, make_host_mesh(), tc)
    state = trainer.run()
    params = state.params

    # -- 2. CoQMoE PTQ ------------------------------------------------------
    pipe = SyntheticPipeline(cfg, shape, seed=123)
    calib = [{k: jnp.asarray(v) for k, v in pipe.batch_for_step(s).items()}
             for s in range(2)]  # 2 x 16 = the paper's 32 calibration images
    print("calibrating from 32 samples ...")
    taps = calibrate_model(cfg, params, calib)
    print(f"  recorded {len(taps.sites())} activation sites")
    p_q = ptq_model(cfg, params, taps)
    qcfg = quantized_config(cfg)

    # -- 3. FP vs quantized -------------------------------------------------
    agree = []
    for s in range(100, 104):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch_for_step(s).items()}
        lg_fp, _ = M.forward(params, cfg, batch)
        lg_q, _ = M.forward(p_q, qcfg, batch)
        agree.append(float(jnp.mean(
            (jnp.argmax(lg_fp, -1) == jnp.argmax(lg_q, -1)).astype(jnp.float32))))
    print(f"top-1 agreement FP vs W8A8+Attn4: {np.mean(agree):.3f} "
          f"(paper: 0.28% top-1 drop on full M3ViT)")


if __name__ == "__main__":
    main()
