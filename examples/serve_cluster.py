"""Multi-replica MoE-ViT serving cluster demo (DESIGN.md section 7).

Builds a smoke-scale M3ViT, PTQs it to a stored-int8 tree, then serves a
burst of synthetic images through ``ServingCluster``: one admission
front-end, one ``VisionEngine`` replica per device (least-loaded routing),
merged metrics. With 2+ devices whose count divides the expert count, a
second pass serves the same traffic in **expert-parallel** mode — expert
stacks sharded over all devices, tokens exchanged with all_to_all.

Fake a multi-device CPU with:

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
      PYTHONPATH=src python examples/serve_cluster.py
"""
import dataclasses

import jax

import repro.models as M
from repro.configs import get_shape, smoke_config
from repro.core.quant.ptq import calibrate_model, ptq_model, quantized_config
from repro.serving.cluster import ServingCluster
from repro.serving.vision import synth_requests


def print_aggregate(tag: str, cluster: ServingCluster) -> None:
    snap = cluster.metrics.snapshot()
    agg = snap["aggregate"]
    lat = agg["latency_ms"]
    print(f"\n[{tag}] {cluster.num_replicas} replica(s) over "
          f"{jax.device_count()} device(s)")
    print(f"  aggregate: {agg['fps']:.1f} FPS  "
          f"p50={lat['p50']:.1f}ms p95={lat['p95']:.1f}ms "
          f"p99={lat['p99']:.1f}ms  (n={lat['n']})")
    for i, rep in enumerate(snap["replicas"]):
        c = rep["counters"]
        print(f"  replica {i}: frames={c.get('frames', 0)} "
              f"batches={c.get('batches', 0)} "
              f"p50={rep['latency_ms']['p50']:.1f}ms")
    occ = agg["expert_occupancy"]
    if occ:
        print("  expert occupancy (summed over replicas): "
              + " ".join(f"{x:.2f}" for x in occ))


def serve_burst(cfg, params, n_images: int, **cluster_kw) -> ServingCluster:
    cluster = ServingCluster(cfg, params, batch_buckets=(1, 4),
                             max_wait_s=1e-3, **cluster_kw)
    cluster.warmup()
    for r in synth_requests(cfg, n_images, seed=0):
        cluster.submit(r)
        cluster.step()
    cluster.flush()
    return cluster


def main() -> None:
    cfg = smoke_config("m3vit-small").replace(remat=False)
    print(f"arch={cfg.name}  experts={cfg.moe.num_experts}  "
          f"devices={jax.device_count()}")

    params = M.init_model_params(cfg, jax.random.PRNGKey(0))
    shape = get_shape("train_4k").replace(seq_len=24, global_batch=2)
    calib = [M.synth_batch(cfg, shape, jax.random.PRNGKey(i))
             for i in range(2)]
    taps = calibrate_model(cfg, params, calib)
    p_int8 = ptq_model(cfg, params, taps, materialize="int8")
    qcfg = quantized_config(cfg)

    n_images = 32
    # data-parallel: one replica per device, replicated int8 params
    cluster = serve_burst(qcfg, p_int8, n_images)
    print_aggregate("int8 / data-parallel", cluster)

    n_dev = jax.device_count()
    if n_dev > 1 and qcfg.moe.num_experts % n_dev == 0:
        # expert-parallel: one replica spanning every device; each holds
        # E/n experts, tokens move over all_to_all
        ep_cfg = qcfg.replace(moe=dataclasses.replace(
            qcfg.moe, moe_exec="expert_parallel"))
        cluster = serve_burst(ep_cfg, p_int8, n_images, replicas=1)
        print_aggregate("int8 / expert-parallel", cluster)
    else:
        print("\n(expert-parallel pass skipped: need >1 devices dividing "
              f"num_experts={qcfg.moe.num_experts}; try XLA_FLAGS="
              "--xla_force_host_platform_device_count=8)")


if __name__ == "__main__":
    main()
