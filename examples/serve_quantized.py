"""Quantized batched serving: continuous batching over ragged requests with
the CoQMoE inference path — INT8 K/V cache, 4-bit log-sqrt2 attention
probabilities, (for MoE archs) the dropless unified expert kernel, and the
full *materialized int8* weight path: weights stored as int8 + scales
(``ptq_model(materialize="int8")``) and executed through the int8 kernels,
at ~1/4 the parameter bytes of the fp tree.

  PYTHONPATH=src python examples/serve_quantized.py
  PYTHONPATH=src python examples/serve_quantized.py --arch olmoe-1b-7b
"""
import argparse
import dataclasses
import time

import jax
import numpy as np

import repro.models as M
from repro.configs import get_shape, smoke_config
from repro.core.quant.ptq import INT8_FAMILIES, calibrate_model, ptq_model
from repro.models.param import tree_bytes
from repro.serving.engine import Request, ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--new-tokens", type=int, default=12)
    args = ap.parse_args()

    cfg = smoke_config(args.arch).replace(remat=False)
    qcfg = cfg.replace(quant=dataclasses.replace(cfg.quant, enable=True))
    params = M.init_model_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, int(n)).astype(np.int32)
               for n in rng.integers(4, 24, args.requests)]

    rows = [("fp", cfg, params), ("int8-kv + attn4", qcfg, params)]
    if cfg.family in INT8_FAMILIES:
        # calibrate -> PTQ -> materialize the executable int8 tree
        shape = get_shape("train_4k").replace(seq_len=24, global_batch=2)
        calib = [M.synth_batch(cfg, shape, jax.random.PRNGKey(i))
                 for i in range(2)]
        taps = calibrate_model(cfg, params, calib)
        p_int8 = ptq_model(cfg, params, taps, materialize="int8")
        print(f"param bytes: fp={tree_bytes(params)/1e6:.2f}MB -> "
              f"int8={tree_bytes(p_int8)/1e6:.2f}MB "
              f"({tree_bytes(params)/tree_bytes(p_int8):.2f}x smaller)")
        rows.append(("w8 stored-int8", qcfg, p_int8))
    else:
        print(f"family {cfg.family!r}: linear sites not yet threaded for "
              f"stored-int8 execution; serving fp weights only")

    results = {}
    for label, c, p in rows:
        eng = ServeEngine(c, p, batch_slots=3, max_len=64)
        reqs = [Request(uid=i, prompt=p, max_new_tokens=args.new_tokens)
                for i, p in enumerate(prompts)]
        for r in reqs:
            eng.submit(r)
        t0 = time.perf_counter()
        eng.run_until_drained()
        dt = time.perf_counter() - t0
        results[label] = [tuple(r.generated) for r in reqs]
        total = args.requests * args.new_tokens
        kv_dtype = eng.cache["k"].dtype if "k" in eng.cache else "n/a"
        print(f"{label:16s}: {total} tokens in {dt:.2f}s "
              f"({total/dt:5.1f} tok/s), kv cache dtype={kv_dtype}")

    for other in [label for label, _, _ in rows[1:]]:
        match = np.mean([
            np.mean([a == b for a, b in zip(x, y)])
            for x, y in zip(results["fp"], results[other])
        ])
        print(f"token agreement fp vs {other}: {match:.2%} "
              f"(random-init model; trained models track much closer)")


if __name__ == "__main__":
    main()
