"""Quantized batched serving: continuous batching over ragged requests with
the CoQMoE inference path — INT8 K/V cache, 4-bit log-sqrt2 attention
probabilities, and (for MoE archs) the dropless unified expert kernel.

  PYTHONPATH=src python examples/serve_quantized.py
  PYTHONPATH=src python examples/serve_quantized.py --arch olmoe-1b-7b
"""
import argparse
import dataclasses
import time

import jax
import numpy as np

import repro.models as M
from repro.configs import smoke_config
from repro.serving.engine import Request, ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--new-tokens", type=int, default=12)
    args = ap.parse_args()

    cfg = smoke_config(args.arch).replace(remat=False)
    qcfg = cfg.replace(quant=dataclasses.replace(cfg.quant, enable=True))
    params = M.init_model_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, int(n)).astype(np.int32)
               for n in rng.integers(4, 24, args.requests)]

    results = {}
    for label, c in (("fp", cfg), ("int8-kv + attn4", qcfg)):
        eng = ServeEngine(c, params, batch_slots=3, max_len=64)
        reqs = [Request(uid=i, prompt=p, max_new_tokens=args.new_tokens)
                for i, p in enumerate(prompts)]
        for r in reqs:
            eng.submit(r)
        t0 = time.perf_counter()
        eng.run_until_drained()
        dt = time.perf_counter() - t0
        results[label] = [tuple(r.generated) for r in reqs]
        total = args.requests * args.new_tokens
        kv_dtype = eng.cache["k"].dtype if "k" in eng.cache else "n/a"
        print(f"{label:16s}: {total} tokens in {dt:.2f}s "
              f"({total/dt:5.1f} tok/s), kv cache dtype={kv_dtype}")

    match = np.mean([
        np.mean([a == b for a, b in zip(x, y)])
        for x, y in zip(results["fp"], results["int8-kv + attn4"])
    ])
    print(f"token agreement fp vs quantized: {match:.2%} "
          f"(random-init model; trained models track much closer)")


if __name__ == "__main__":
    main()
