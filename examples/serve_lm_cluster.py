"""Multi-replica int8 LM serving cluster demo (DESIGN.md section 8).

The engine-agnostic counterpart of ``serve_cluster.py``: the same
``ServingCluster`` front-end (one admission queue, least-loaded routing,
merged metrics) now fronts ``ServeEngine`` replicas — slot-based continuous
LM decode with the int8 K/V cache, free decode slots as the load signal.

Builds a smoke-scale OLMoE (MoE LM), PTQs it to a stored-int8 tree, then
serves a burst of random prompts through 2 replicas and verifies the
greedy outputs match a single-engine run (routing and slot sharing leak
nothing into generation).

  PYTHONPATH=src python examples/serve_lm_cluster.py
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
      PYTHONPATH=src python examples/serve_lm_cluster.py   # adds an EP pass
"""
import dataclasses

import jax
import numpy as np

import repro.models as M
from repro.configs import get_shape, smoke_config
from repro.core.quant.ptq import calibrate_model, ptq_model, quantized_config
from repro.serving.cluster import ServingCluster
from repro.serving.engine import Request, ServeEngine


def make_requests(cfg, n, seed=0, max_new=6):
    rng = np.random.default_rng(seed)
    return [
        Request(uid=i,
                prompt=rng.integers(0, cfg.vocab_size,
                                    int(rng.integers(4, 12))).astype(np.int32),
                max_new_tokens=max_new)
        for i in range(n)
    ]


def print_aggregate(tag, cluster):
    snap = cluster.metrics.snapshot()
    agg = snap["aggregate"]
    lat = agg["latency_ms"]
    print(f"\n[{tag}] {cluster.num_replicas} replica(s) over "
          f"{jax.device_count()} device(s)")
    print(f"  aggregate: {agg['fps']:.1f} tok/s  "
          f"p50={lat['p50']:.1f}ms p95={lat['p95']:.1f}ms  (n={lat['n']})  "
          f"queue_wait p95={agg['queue_wait_ms']['p95']:.2f}ms")
    for i, rep in enumerate(snap["replicas"]):
        c = rep["counters"]
        print(f"  replica {i}: tokens={c.get('tokens', 0)} "
              f"completed={c.get('completed', 0)}")
    if agg["expert_occupancy"]:
        print("  expert occupancy (summed over replicas): "
              + " ".join(f"{x:.2f}" for x in agg["expert_occupancy"]))


def serve_burst(cfg, params, reqs, **kw):
    cluster = ServingCluster(cfg, params, engine="lm", batch_slots=2,
                             max_len=64, max_pending_per_replica=4, **kw)
    cluster.warmup()
    for r in reqs:
        cluster.submit(r)
        cluster.step()
    cluster.flush()
    return cluster


def main() -> None:
    cfg = smoke_config("olmoe-1b-7b").replace(remat=False)
    print(f"arch={cfg.name}  experts={cfg.moe.num_experts}  "
          f"devices={jax.device_count()}")

    params = M.init_model_params(cfg, jax.random.PRNGKey(0))
    shape = get_shape("train_4k").replace(seq_len=24, global_batch=2)
    calib = [M.synth_batch(cfg, shape, jax.random.PRNGKey(i))
             for i in range(2)]
    taps = calibrate_model(cfg, params, calib)
    p_int8 = ptq_model(cfg, params, taps, materialize="int8")
    qcfg = quantized_config(cfg)

    n_req = 10
    # reference: one engine, same int8 tree, same prompts
    solo = make_requests(cfg, n_req, seed=0)
    eng = ServeEngine(qcfg, p_int8, batch_slots=2, max_len=64)
    for r in solo:
        eng.submit(r)
    eng.run_until_drained()

    # 2-replica cluster (DP, replicated int8 params per replica)
    reqs = make_requests(cfg, n_req, seed=0)
    cluster = serve_burst(qcfg, p_int8, reqs, replicas=2)
    print_aggregate("int8 / 2-replica LM cluster", cluster)
    mismatches = sum(a.generated != b.generated for a, b in zip(reqs, solo))
    print(f"  greedy parity vs single engine: "
          f"{n_req - mismatches}/{n_req} requests identical")
    assert mismatches == 0

    n_dev = jax.device_count()
    if n_dev > 1 and qcfg.moe.num_experts % n_dev == 0:
        # expert-parallel: one replica spanning every device; each holds
        # E/n experts, decode tokens move over all_to_all
        ep_cfg = qcfg.replace(moe=dataclasses.replace(
            qcfg.moe, moe_exec="expert_parallel"))
        reqs_ep = make_requests(cfg, n_req, seed=0)
        cluster = serve_burst(ep_cfg, p_int8, reqs_ep, replicas=1)
        print_aggregate("int8 / expert-parallel LM replica", cluster)
        ep_ok = sum(a.generated == b.generated
                    for a, b in zip(reqs_ep, solo))
        print(f"  EP greedy parity vs single engine: {ep_ok}/{n_req}")
    else:
        print("\n(expert-parallel pass skipped: need >1 devices dividing "
              f"num_experts={qcfg.moe.num_experts}; try XLA_FLAGS="
              "--xla_force_host_platform_device_count=8)")


if __name__ == "__main__":
    main()
