"""Tracing-overhead benchmark for the serving observability layer
(DESIGN.md section 11) — writes ``BENCH_trace.json``.

Measures the closed-loop packed continuous-batching workload three ways on
the SAME engine configuration, best-of-``--repeats`` each:

  off    — ``TraceConfig.enable = False`` (the default): every
           instrumentation site is one ``tracer.enabled`` attribute read.
  on     — full tracing: per-request span timelines into the flight
           recorder + per-program step-time histograms.
  off2   — tracing disabled again. The off/off2 spread is the measurement
           noise floor, which is what "~zero overhead compiled out" means
           operationally: the disabled path is indistinguishable from not
           having the layer at all.

The acceptance bound (``--bound``, default 2%) applies to the traced run
against the best disabled run. The traced engine's artifacts are then
checked structurally — the exported Chrome trace validates, every completed
request's timeline is non-overlapping/ordered and its service phases sum to
the recorded end-to-end latency, per-bucket step histograms appear in the
snapshot — and a deadline + reject pass exercises the event log so the
JSONL artifact is non-trivial.

  PYTHONPATH=src python benchmarks/serve_trace_overhead.py --smoke
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
import time

import numpy as np
try:  # script sibling vs repo-root namespace import
    from benchmarks.provenance import stamp
except ImportError:
    from provenance import stamp


def _mixed_lengths(n: int, lo: int, hi: int) -> list:
    return [int(x) for x in np.linspace(lo, hi, n).round()]


def _requests(cfg, lengths, new_tokens, seed=0, uid0=0):
    from repro.serving.engine import Request

    rng = np.random.default_rng(seed)
    return [
        Request(uid=uid0 + i,
                prompt=rng.integers(0, cfg.vocab_size, L).astype(np.int32),
                max_new_tokens=new_tokens)
        for i, L in enumerate(lengths)
    ]


def _serve_once(engine, reqs) -> float:
    """One timed closed-loop pass; returns wall seconds."""
    t0 = time.perf_counter()
    for r in reqs:
        engine.submit(r)
    engine.run_until_drained()
    dt = time.perf_counter() - t0
    assert all(len(r.generated) == r.max_new_tokens for r in reqs)
    return dt


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--out", default="BENCH_trace.json")
    ap.add_argument("--trace-out", default="serve_trace.json",
                    help="Perfetto/Chrome trace artifact from the traced run")
    ap.add_argument("--events-out", default="serve_events.jsonl",
                    help="structured event-log artifact (JSONL)")
    ap.add_argument("--requests", type=int, default=0,
                    help="closed-loop requests (0 = batch_slots x 6)")
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--repeats", type=int, default=12,
                    help="interleaved rounds; best-of per variant")
    ap.add_argument("--bound", type=float, default=0.02,
                    help="max tolerated traced-run throughput overhead")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import jax

    import repro.models as M
    from repro.configs import get_config, smoke_config
    from repro.serving.engine import Request, ServeEngine
    from repro.serving.events import EventLog, read_jsonl
    from repro.serving.trace import (
        request_timelines,
        validate_chrome_trace,
        validate_request_timelines,
        write_chrome_trace,
    )

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    cfg = cfg.replace(remat=False)
    if cfg.attn is None:
        raise SystemExit(f"{args.arch}: the packed workload needs an "
                         "attention family")
    traced_cfg = cfg.replace(trace=dataclasses.replace(cfg.trace,
                                                       enable=True))
    params = M.init_model_params(cfg, jax.random.PRNGKey(args.seed))
    n = args.requests or args.slots * 6
    lo, hi = 8, max(10, args.max_len // 4)
    lengths = _mixed_lengths(n, lo, hi)
    uid0 = [0]

    def make():
        # fresh uids per pass: uid doubles as the trace id, and a reused id
        # would splice two requests into one (invalid) timeline
        reqs = _requests(cfg, lengths, args.new_tokens, seed=args.seed,
                         uid0=uid0[0])
        uid0[0] += len(lengths)
        return reqs

    print(f"arch={cfg.name} devices={jax.device_count()} requests={n} "
          f"new_tokens={args.new_tokens} repeats={args.repeats}")

    # all three engines up front; the timed passes are then interleaved
    # round-robin so machine drift lands on every variant equally and
    # best-of-``repeats`` compares like with like
    engines = {name: ServeEngine(rcfg, params, batch_slots=args.slots,
                                 max_len=args.max_len)
               for name, rcfg in (("off", cfg), ("on", traced_cfg),
                                  ("off2", cfg))}
    for name, eng in engines.items():
        assert eng._packed, "packed path must engage for this family"
        assert eng.tracer.enabled == (name == "on")
        eng.warmup()
        for r in make():  # untimed pass: residual compiles land here
            eng.submit(r)
        eng.run_until_drained()

    toks = n * args.new_tokens
    dts = {name: [] for name in engines}
    order = list(engines)
    for r in range(args.repeats):
        # rotate the in-round order so systematic position effects (cache
        # warmth, thermal ramp) spread over all variants equally
        for name in order[r % 3:] + order[:r % 3]:
            dts[name].append(_serve_once(engines[name], make()))
    runs = {name: {"tok_s": toks / min(ds), "wall_s": min(ds),
                   "tokens": toks}
            for name, ds in dts.items()}
    for name, r in runs.items():
        print(f"  {name:>5s}: {r['tok_s']:8.1f} tok/s "
              f"({r['wall_s'] * 1e3:.0f} ms)")
    traced_engine = engines["on"]

    # round-paired ratios: within one round the three passes run
    # back-to-back, so machine drift cancels; the median across rounds
    # rejects outlier rounds. The off/off2 ratio is the noise floor — the
    # spread between two IDENTICAL configurations — which is what "~zero
    # overhead compiled out" means operationally for the disabled path.
    overhead_on = float(np.median(
        [on / (0.5 * (a + b)) for on, a, b
         in zip(dts["on"], dts["off"], dts["off2"])])) - 1.0
    overhead_off = abs(float(np.median(
        [a / b for a, b in zip(dts["off"], dts["off2"])])) - 1.0)
    # the noise floor is what this environment can resolve: the traced run
    # must sit within `bound` of the baseline BEYOND that floor, so a
    # thrashing shared runner widens the tolerance instead of flaking
    effective_bound = args.bound + overhead_off
    print(f"  overhead: traced {100 * overhead_on:+.2f}% "
          f"(noise floor {100 * overhead_off:.2f}%, bound "
          f"{100 * args.bound:.0f}% + floor)")

    # -- artifact + structural checks on the traced engine -------------------
    # a small extra pass exercises the event paths (deadline cancellation,
    # unservable reject) so the JSONL artifact carries real decisions
    events = EventLog(path=args.events_out)
    traced_engine.events = events
    extra = _requests(cfg, lengths[:4], args.new_tokens, seed=args.seed + 1,
                      uid0=10_000)
    extra[0].deadline = 0.0  # expires in queue -> cancel event
    for r in extra:
        traced_engine.submit(r)
    try:
        traced_engine.submit(Request(
            uid=99_999,
            prompt=np.zeros(args.max_len + 64, np.int32),
            max_new_tokens=1))
    except ValueError:
        pass  # expected: unservable -> reject event
    traced_engine.run_until_drained()
    events.close()

    spans = traced_engine.tracer.recorder.spans()
    doc = write_chrome_trace(args.trace_out, traced_engine.tracer)
    n_events = validate_chrome_trace(doc)
    n_timelines = validate_request_timelines(spans)
    # service phases (everything but retire) must sum to the recorded
    # end-to-end latency — the retire span carries it as an attribute
    sums_ok, checked = True, 0
    for tid, tl in request_timelines(spans).items():
        ret = [s for s in tl if s.name == "retire"]
        if not ret or ret[0].attrs is None \
                or "latency_s" not in ret[0].attrs:
            continue  # still open / cancelled before admission
        service = sum(s.dur for s in tl if s.name != "retire")
        if abs(service - ret[0].attrs["latency_s"]) > 1e-6:
            sums_ok = False
        checked += 1
    snap = traced_engine.metrics.snapshot()
    step_keys = list(snap["step_latency_ms"])
    ev_rows = read_jsonl(args.events_out)
    ev_types = {e["type"] for e in ev_rows}

    checks = {
        "overhead_within_bound": overhead_on <= effective_bound,
        "trace_valid": n_events > 0,
        "timelines_valid": n_timelines > 0,
        "spans_sum_to_latency": sums_ok and checked > 0,
        "step_hists_present": any("decode" in k for k in step_keys)
        and any("packed_prefill" in k for k in step_keys),
        "events_recorded": {"cancel", "reject"} <= ev_types,
        "open_spans_drained": traced_engine.tracer.open_count() == 0,
    }
    for name, ok in checks.items():
        print(f"  [{'ok' if ok else 'MISS'}] {name}")
    print(f"  trace: {args.trace_out} ({n_events} events, "
          f"{n_timelines} request timelines, {checked} latency-checked); "
          f"events: {args.events_out} ({len(ev_rows)} rows: "
          f"{sorted(ev_types)})")

    report = {
        "meta": {
            "bench": "serve_trace_overhead",
            "mode": "smoke" if args.smoke else "full",
            "arch": cfg.name,
            "devices": jax.device_count(),
            "requests": n,
            "new_tokens": args.new_tokens,
            "repeats": args.repeats,
            "bound": args.bound,
        },
        "runs": runs,
        "overhead": {"traced": overhead_on, "noise_floor": overhead_off,
                     "effective_bound": effective_bound},
        "trace": {
            "chrome_events": n_events,
            "request_timelines": n_timelines,
            "latency_checked": checked,
            "spans_recorded": traced_engine.tracer.recorder.total,
            "spans_dropped": traced_engine.tracer.recorder.dropped,
            "step_keys": step_keys,
        },
        "events": {"rows": len(ev_rows), "types": sorted(ev_types)},
        "checks": checks,
        "fps": runs["on"]["tok_s"],
    }
    stamp(report, "serve_trace_overhead")
    with open(args.out, "w") as f:
        json.dump(report, f, indent=1)
    print(f"wrote {args.out}")
    if not all(checks.values()):
        sys.exit(1)


if __name__ == "__main__":
    main()
