"""Target-hardware constants (TPU v5e-class) for roofline terms."""

PEAK_FLOPS_BF16 = 197e12  # per chip
PEAK_FLOPS_INT8 = 394e12  # MXU int8 path (2x bf16)
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link (~per chip for ring collectives)
DCN_BW = 25e9  # bytes/s per host across pods (assumed)
CHIPS_SINGLE_POD = 256
CHIPS_MULTI_POD = 512
