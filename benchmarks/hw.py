"""Target-hardware constants (TPU v5e-class) for roofline terms.

Compatibility shim: the canonical constants now live in
``repro.analysis.hw`` so serving code can use them without path hacks.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.analysis.hw import (  # noqa: F401,E402
    CHIPS_MULTI_POD,
    CHIPS_SINGLE_POD,
    DCN_BW,
    HBM_BW,
    ICI_BW,
    PEAK_FLOPS_BF16,
    PEAK_FLOPS_INT8,
    device_peaks,
    pick_int8,
)
