"""Kernel autotune benchmark: default vs tuned tile configs per swept shape.

Runs the ``kernels/autotune.py`` candidate sweep over a grid of
``grouped_matmul`` (fp32 + int8 paths) and ``streaming_attention`` shapes
and records, per shape, the wall-time of the *default* tile config next to
the *tuned* (fastest-candidate) config. Because the default config is
always candidate #1 of the sweep, the tuned config is never slower than
the default on any swept shape — ``all_never_slower`` asserts it and the
process exits non-zero if measurement ever contradicts construction.

On a TPU backend every candidate is timed compiled; on CPU / interpret
backends there is nothing meaningful to time, so the tuner returns the
deterministic default config and this benchmark stamps one interpret-mode
wall-time as both sides (mode = "defaults") — the artifact still
documents the swept shapes, keys, and chosen tiles, and CI exercises the
sweep machinery end to end.

Writes ``BENCH_kernels.json`` and (with ``--table``) the generated tuning
table (schema in DESIGN.md section 9).

  PYTHONPATH=src python benchmarks/bench_kernels.py --smoke
  PYTHONPATH=src python benchmarks/bench_kernels.py --out BENCH_kernels.json
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import jax
import jax.numpy as jnp

from repro.configs.base import AutotuneConfig
from repro.kernels import autotune
try:  # script sibling vs repo-root namespace import
    from benchmarks.provenance import stamp
except ImportError:
    from provenance import stamp


def gmm_shapes(smoke: bool):
    """(T, G, Din, Dout, dtype) grid — fc1/fc2 of MoE expert stacks at
    decode/prefill-ish token counts."""
    if smoke:
        return [
            (64, 8, 32, 64, "float32"),
            (64, 8, 32, 64, "int8"),
            (64, 8, 32, 64, "int4"),  # nibble-packed expert stack
            (8, 8, 64, 32, "int8"),  # decode-sized: exercises the clamp
            (8, 8, 64, 32, "int4"),
        ]
    shapes = []
    for dt in ("float32", "int8", "int4"):
        for T in (256, 1024, 4096):
            shapes += [
                (T, 8, 256, 1024, dt),  # fc1 (glu: 2*d_ff)
                (T, 8, 512, 256, dt),  # fc2
            ]
    return shapes


def gmm_weight_bytes(G: int, Din: int, Dout: int, dt: str) -> int:
    """Measured expert-stack bytes for one swept shape — what actually sits
    in HBM: nibble-packed int4 stores ceil(Din/2) uint8 rows."""
    if dt == "int4":
        return G * (-(-Din // 2)) * Dout
    return G * Din * Dout * jnp.dtype(dt).itemsize


def attn_shapes(smoke: bool):
    """(B, H, KVH, hd, Sq, Sk, quant_bits, scaled) grid."""
    if smoke:
        return [
            (2, 2, 2, 32, 8, 64, 0, False),
            (2, 2, 2, 32, 8, 64, 4, True),  # int8 KV + log-sqrt2 codes
        ]
    return [
        (4, 8, 2, 64, 1, 4096, 0, False),  # decode
        (4, 8, 2, 64, 1, 4096, 4, True),
        (1, 8, 2, 64, 2048, 2048, 0, False),  # prefill
        (1, 8, 2, 64, 2048, 2048, 4, True),
    ]


def _wall_once(req, blocks) -> float:
    """One measured interpret/compiled call of this config (reference
    number for backends where the tuner does not time candidates)."""
    interpret = not autotune.should_time()
    fn = autotune.build_candidate(req, blocks, interpret=interpret)
    jax.block_until_ready(fn())  # compile / first-run
    t0 = time.perf_counter()
    jax.block_until_ready(fn())
    return (time.perf_counter() - t0) * 1e3


def bench_request(req, at_cfg: AutotuneConfig):
    """(result row, table entry) for one swept shape."""
    entry, cands = autotune.sweep_request(req, at_cfg, collect_all=True)
    # locate the default config by its blocks — sweep_request drops
    # candidates that fail to time, so position 0 is not guaranteed
    default_blocks = autotune.candidates_for(req)[0]
    default_ms = next(
        (ms for b, ms in cands if tuple(b) == default_blocks), None)
    tuned_blocks, tuned_ms = tuple(entry["blocks"]), entry["ms"]
    default_failed = False
    if tuned_ms is None:  # no timing on this backend: defaults both sides
        ms = _wall_once(req, default_blocks)
        default_ms = tuned_ms = ms
    elif default_ms is None:
        # the default config itself failed to time on this hardware — the
        # tuned config is the only baseline; flag it rather than mislabel
        # another candidate as "default"
        default_failed = True
        default_ms = tuned_ms
    return {
        "kernel": req.kernel,
        "key": req.key,
        "default": {"blocks": list(default_blocks),
                    "ms": round(float(default_ms), 4),
                    "failed_to_time": default_failed},
        "tuned": {"blocks": list(tuned_blocks),
                  "ms": round(float(tuned_ms), 4),
                  "source": entry["source"]},
        "speedup": round(float(default_ms) / max(float(tuned_ms), 1e-9), 4),
        "never_slower": float(tuned_ms) <= float(default_ms) + 1e-9,
        "candidates_timed": len(cands),
    }, entry


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny interpret-safe shapes (CI)")
    ap.add_argument("--out", default="BENCH_kernels.json")
    ap.add_argument("--table", default=None,
                    help="also write the generated tuning table here")
    ap.add_argument("--budget", type=int, default=16)
    ap.add_argument("--reps", type=int, default=5)
    args = ap.parse_args()

    at_cfg = AutotuneConfig(enable=True, budget=args.budget, reps=args.reps)
    kind = autotune.device_kind()
    timed = autotune.should_time()
    table = autotune.TuningTable(kind, args.table)

    rows = []
    for T, G, Din, Dout, dt in gmm_shapes(args.smoke):
        quant = dt in ("int8", "int4")
        x_dt = jnp.int8 if quant else jnp.dtype(dt)  # W4A8: int8 acts
        w_dt = jnp.uint8 if dt == "int4" else jnp.dtype(dt)
        req = autotune.gmm_request(
            T, G, Din, Dout, x_dtype=x_dt, w_dtype=w_dt,
            scaled=quant, ascaled=quant)
        row, entry = bench_request(req, at_cfg)
        row["weight_bytes"] = gmm_weight_bytes(G, Din, Dout, dt)
        table.put(req.key, tuple(entry["blocks"]), entry["ms"],
                  entry["source"])
        rows.append(row)
        print(f"{req.key}: default {row['default']['blocks']} "
              f"{row['default']['ms']}ms -> tuned {row['tuned']['blocks']} "
              f"{row['tuned']['ms']}ms (x{row['speedup']})")
    for B, H, KVH, hd, Sq, Sk, qb, scaled in attn_shapes(args.smoke):
        req = autotune.attn_request(
            B, H, KVH, hd, Sq, Sk, causal=True, quant_bits=qb,
            scaled=scaled, q_dtype=jnp.float32,
            k_dtype=jnp.int8 if scaled else jnp.float32)
        row, entry = bench_request(req, at_cfg)
        table.put(req.key, tuple(entry["blocks"]), entry["ms"],
                  entry["source"])
        rows.append(row)
        print(f"{req.key}: default {row['default']['blocks']} "
              f"{row['default']['ms']}ms -> tuned {row['tuned']['blocks']} "
              f"{row['tuned']['ms']}ms (x{row['speedup']})")

    ok = all(r["never_slower"] for r in rows)
    # measured expert-stack byte shrink: int8 vs int4 rows of the same
    # (T, G, din, dout) bucket (the acceptance number for the int4 scheme)
    by_shape = {}
    for r in rows:
        if r["kernel"] != "grouped_matmul" or "weight_bytes" not in r:
            continue
        kv = dict(p.split("=", 1) for p in r["key"].split("|")[1:])
        sig = (kv["T"], kv["G"], kv["din"], kv["dout"])
        by_shape.setdefault(sig, {})[kv["wdt"]] = r["weight_bytes"]
    shrinks = [b["int8"] / b["uint8"] for b in by_shape.values()
               if "int8" in b and "uint8" in b]
    out = {
        "benchmark": "kernel_autotune",
        "device_kind": kind,
        "backend": jax.default_backend(),
        "mode": "swept" if timed else "defaults",
        "kernel_versions": dict(autotune.KERNEL_VERSIONS),
        "rows": rows,
        "all_never_slower": ok,
        "int4_weight_shrink_vs_int8": (
            round(sum(shrinks) / len(shrinks), 4) if shrinks else None),
    }
    with open(args.out, "w") as f:
        json.dump(stamp(out, "bench_kernels"), f, indent=1)
    print(f"wrote {args.out}: {len(rows)} shapes, mode={out['mode']}, "
          f"all_never_slower={ok}")
    if args.table:
        print(f"wrote tuning table {table.save(args.table)}")
    if not ok:
        sys.exit(1)


if __name__ == "__main__":
    main()
