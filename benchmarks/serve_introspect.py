"""Introspection coverage + overhead benchmark (DESIGN.md section 12) —
writes ``BENCH_introspect.json``.

Three sections:

  coverage — warm an LM packed-prefill engine and a vision engine, then
    require a ``ProgramCost`` row for EVERY AOT program key each engine
    compiled, and — after a short serving pass — a measured MFU +
    achieved-HBM-bandwidth join in ``snapshot()["program_perf"]``.
  endpoint — a 2-replica ``ServingCluster`` behind
    ``serve_cluster_metrics``; ``GET /metrics`` must parse as Prometheus
    text exposition (and carry the per-program gauge families),
    ``/healthz`` must report ok, ``/snapshot`` must be valid JSON.
  overhead — the closed-loop packed workload three ways on identical
    engines (introspection off / on / off again), interleaved
    round-robin, where the "on" engine additionally has a live metrics
    endpoint being scraped while it serves. Round-paired median overhead
    must sit within ``--bound`` (default 2%) of the off/off2 noise floor
    — the contract stated in DESIGN.md section 12.

  PYTHONPATH=src python benchmarks/serve_introspect.py --smoke
"""
from __future__ import annotations

import argparse
import json
import re
import sys
import threading
import time
import urllib.request

import numpy as np
try:  # script sibling vs repo-root namespace import
    from benchmarks.provenance import stamp
except ImportError:
    from provenance import stamp

# one sample line of Prometheus text exposition: name{labels} value
_PROM_LINE = re.compile(
    r"^[a-zA-Z_:][a-zA-Z0-9_:]*(\{[^{}]*\})?\s+"
    r"([+-]?(\d+\.?\d*([eE][+-]?\d+)?|\.\d+)|[+-]?[Ii]nf|NaN|nan)$")


def parse_prometheus(text: str) -> dict:
    """Parse text exposition; returns {family: n_samples}. Raises
    ValueError on any malformed sample line — "parseable" is the check."""
    families: dict = {}
    for line in text.splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        if not _PROM_LINE.match(line):
            raise ValueError(f"malformed exposition line: {line!r}")
        name = re.split(r"[{\s]", line, 1)[0]
        families[name] = families.get(name, 0) + 1
    return families


def _get(url: str, timeout: float = 5.0) -> bytes:
    with urllib.request.urlopen(url, timeout=timeout) as r:
        return r.read()


class _Scraper(threading.Thread):
    """Hits ``url`` every ``period`` seconds while ``active`` is set —
    the live-scrape load the "on" variant carries during its timed
    passes."""

    def __init__(self, url: str, period: float = 1.0) -> None:
        super().__init__(daemon=True, name="bench-scraper")
        self.url = url
        self.period = period
        self.active = threading.Event()
        self._stop = threading.Event()
        self.scrapes = 0
        self.errors = 0

    def run(self) -> None:
        while not self._stop.is_set():
            if not self.active.wait(timeout=0.05):
                continue
            try:
                _get(self.url, timeout=1.0)
                self.scrapes += 1
            except Exception:
                self.errors += 1
            time.sleep(self.period)

    def stop(self) -> None:
        self._stop.set()


def _requests(cfg, lengths, new_tokens, seed=0, uid0=0):
    from repro.serving.engine import Request

    rng = np.random.default_rng(seed)
    return [
        Request(uid=uid0 + i,
                prompt=rng.integers(0, cfg.vocab_size, L).astype(np.int32),
                max_new_tokens=new_tokens)
        for i, L in enumerate(lengths)
    ]


def _serve_once(engine, reqs) -> float:
    t0 = time.perf_counter()
    for r in reqs:
        engine.submit(r)
    engine.run_until_drained()
    dt = time.perf_counter() - t0
    assert all(len(r.generated) == r.max_new_tokens for r in reqs)
    return dt


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmoe-1b-7b",
                    help="LM arch (MoE so expert health engages)")
    ap.add_argument("--vision-arch", default="m3vit-tiny")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--out", default="BENCH_introspect.json")
    ap.add_argument("--requests", type=int, default=0,
                    help="closed-loop requests (0 = batch_slots x 6)")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--repeats", type=int, default=12,
                    help="interleaved overhead rounds; round-paired median")
    ap.add_argument("--bound", type=float, default=0.02,
                    help="max tolerated introspection overhead beyond the "
                         "off/off2 noise floor")
    ap.add_argument("--scrape-period", type=float, default=1.0,
                    help="live /metrics scrape period during the 'on' "
                         "passes (1 Hz default — still 15x a real "
                         "Prometheus 15s interval; the scraper runs "
                         "in-process, so aggressive periods measure the "
                         "client's GIL theft, not introspection)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import dataclasses

    import jax

    import repro.models as M
    from repro.configs import get_config, smoke_config
    from repro.serving.engine import ServeEngine
    from repro.serving.cluster import ServingCluster
    from repro.serving.metrics import ClusterMetrics
    from repro.serving.metrics_server import (MetricsServer,
                                              serve_cluster_metrics)
    from repro.serving.vision import VisionEngine, synth_requests

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    cfg = cfg.replace(remat=False)
    if cfg.attn is None:
        raise SystemExit(f"{args.arch}: the packed workload needs an "
                         "attention family")
    params = M.init_model_params(cfg, jax.random.PRNGKey(args.seed))
    n = args.requests or args.slots * 6
    lengths = [int(x) for x in
               np.linspace(8, max(10, args.max_len // 4), n).round()]
    uid0 = [0]

    def make():
        reqs = _requests(cfg, lengths, args.new_tokens, seed=args.seed,
                         uid0=uid0[0])
        uid0[0] += len(lengths)
        return reqs

    print(f"arch={cfg.name} devices={jax.device_count()} requests={n} "
          f"new_tokens={args.new_tokens} repeats={args.repeats}")
    checks = {}

    # -- coverage: every AOT program key has a ProgramCost row ---------------
    eng = ServeEngine(cfg, params, batch_slots=args.slots,
                      max_len=args.max_len)
    assert eng._packed, "packed path must engage for this family"
    eng.warmup()
    lm_programs = set(eng._programs)
    lm_costs = set(eng.metrics.program_costs)
    checks["lm_cost_rows_cover_programs"] = (
        bool(lm_programs) and lm_programs <= lm_costs)
    checks["lm_costs_measured_not_estimated"] = any(
        not c["estimated"] for c in eng.metrics.program_costs.values())
    for r in make():
        eng.submit(r)
    eng.run_until_drained()
    perf = eng.metrics.snapshot()["program_perf"]
    lm_mfu = {k: v.get("mfu") for k, v in perf.items()}
    checks["lm_mfu_measured"] = any(v is not None for v in lm_mfu.values())
    checks["lm_bandwidth_measured"] = any(
        v.get("achieved_hbm_gbps") is not None for v in perf.values())
    print(f"  lm: {len(lm_programs)} programs, "
          f"{len(lm_costs)} cost rows, "
          f"mfu keys: {[k for k, v in lm_mfu.items() if v is not None]}")

    vcfg = (smoke_config(args.vision_arch) if args.smoke
            else get_config(args.vision_arch))
    vparams = M.init_model_params(vcfg, jax.random.PRNGKey(args.seed))
    veng = VisionEngine(vcfg, vparams, batch_buckets=(1, 4),
                        max_wait_s=0.0, max_pending=0)
    veng.warmup()
    v_costs = set(veng.metrics.program_costs)
    checks["vision_cost_rows_cover_buckets"] = (
        {"classify|b=1", "classify|b=4"} <= v_costs)
    for r in synth_requests(vcfg, 8, seed=args.seed):
        veng.submit(r)
    veng.flush()
    vsnap = veng.metrics.snapshot()
    checks["vision_mfu_measured"] = any(
        v.get("mfu") is not None for v in vsnap["program_perf"].values())
    checks["vision_expert_health"] = (
        vcfg.moe is None or vsnap["expert_health"] is not None)
    print(f"  vision: cost rows {sorted(v_costs)}")

    # -- endpoint: live cluster scrape ---------------------------------------
    cluster = ServingCluster(cfg, params, replicas=2, engine="lm",
                             batch_slots=args.slots, max_len=args.max_len)
    cluster.warmup()
    server = serve_cluster_metrics(cluster)
    for r in make():
        cluster.submit(r)
        cluster.step()
    cluster.flush()
    try:
        families = parse_prometheus(_get(server.url + "/metrics").decode())
        checks["endpoint_metrics_parse"] = True
        checks["endpoint_program_gauges"] = (
            "repro_program_mfu" in families
            and "repro_program_roofline_bound" in families)
        hz = json.loads(_get(server.url + "/healthz"))
        checks["endpoint_healthz_ok"] = hz.get("status") == "ok"
        checks["endpoint_snapshot_json"] = isinstance(
            json.loads(_get(server.url + "/snapshot")), dict)
    except (ValueError, OSError) as e:
        print(f"  endpoint scrape failed: {e}")
        for k in ("endpoint_metrics_parse", "endpoint_program_gauges",
                  "endpoint_healthz_ok", "endpoint_snapshot_json"):
            checks.setdefault(k, False)
    finally:
        server.stop()
    print(f"  endpoint: {sum(families.values()) if checks.get('endpoint_metrics_parse') else 0} samples, "
          f"{len(families) if checks.get('endpoint_metrics_parse') else 0} families")

    # -- overhead: off / on(+live scrape) / off2 -----------------------------
    off_cfg = cfg.replace(
        introspect=dataclasses.replace(cfg.introspect, enable=False))
    engines = {name: ServeEngine(rcfg, params, batch_slots=args.slots,
                                 max_len=args.max_len)
               for name, rcfg in (("off", off_cfg), ("on", cfg),
                                  ("off2", off_cfg))}
    for name, e in engines.items():
        e.warmup()
        for r in make():  # untimed pass: residual compiles land here
            e.submit(r)
        e.run_until_drained()
    on_server = MetricsServer(
        ClusterMetrics([engines["on"].metrics]).export_prometheus)
    on_server.start()
    scraper = _Scraper(on_server.url + "/metrics",
                       period=args.scrape_period)
    scraper.start()

    toks = n * args.new_tokens
    dts = {name: [] for name in engines}
    order = list(engines)
    for rnd in range(args.repeats):
        for name in order[rnd % 3:] + order[:rnd % 3]:
            if name == "on":
                scraper.active.set()
            dts[name].append(_serve_once(engines[name], make()))
            scraper.active.clear()
    scraper.stop()
    on_server.stop()
    runs = {name: {"tok_s": toks / min(ds), "wall_s": min(ds),
                   "tokens": toks}
            for name, ds in dts.items()}
    for name, r in runs.items():
        print(f"  {name:>5s}: {r['tok_s']:8.1f} tok/s "
              f"({r['wall_s'] * 1e3:.0f} ms)")

    # round-paired ratios cancel machine drift; the off/off2 spread is the
    # noise floor this environment can resolve (same contract as
    # serve_trace_overhead.py)
    overhead_on = float(np.median(
        [on / (0.5 * (a + b)) for on, a, b
         in zip(dts["on"], dts["off"], dts["off2"])])) - 1.0
    overhead_off = abs(float(np.median(
        [a / b for a, b in zip(dts["off"], dts["off2"])])) - 1.0)
    effective_bound = args.bound + overhead_off
    checks["overhead_within_bound"] = overhead_on <= effective_bound
    checks["live_scrapes_happened"] = scraper.scrapes > 0
    print(f"  overhead: introspected {100 * overhead_on:+.2f}% "
          f"(noise floor {100 * overhead_off:.2f}%, bound "
          f"{100 * args.bound:.0f}% + floor; {scraper.scrapes} live "
          f"scrapes, {scraper.errors} errors)")

    for name, ok in checks.items():
        print(f"  [{'ok' if ok else 'MISS'}] {name}")

    report = {
        "meta": {
            "bench": "serve_introspect",
            "mode": "smoke" if args.smoke else "full",
            "arch": cfg.name,
            "vision_arch": vcfg.name,
            "devices": jax.device_count(),
            "requests": n,
            "new_tokens": args.new_tokens,
            "repeats": args.repeats,
            "bound": args.bound,
        },
        "coverage": {
            "lm_programs": sorted(lm_programs),
            "lm_cost_rows": sorted(lm_costs),
            "lm_program_perf": perf,
            "vision_cost_rows": sorted(v_costs),
            "vision_program_perf": vsnap["program_perf"],
        },
        "endpoint": {
            "families": (len(families)
                         if checks.get("endpoint_metrics_parse") else 0),
        },
        "runs": runs,
        "overhead": {"introspected": overhead_on,
                     "noise_floor": overhead_off,
                     "effective_bound": effective_bound,
                     "live_scrapes": scraper.scrapes,
                     "scrape_errors": scraper.errors},
        "checks": checks,
        "fps": runs["on"]["tok_s"],
    }
    stamp(report, "serve_introspect")
    with open(args.out, "w") as f:
        json.dump(report, f, indent=1)
    print(f"wrote {args.out}")
    if not all(checks.values()):
        sys.exit(1)


if __name__ == "__main__":
    main()
